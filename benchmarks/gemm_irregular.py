"""Paper Fig 6: irregular GEMM shapes.

(a) M=N=32768, K in 256..2048 — K is sequential within a block, so dataflow
    choice matters little (paper: "both TL and TTNN exhibit behavior similar
    to the 1D and 2D baselines");
(b) M=K=32768, N in 256..2048 — the preferred dataflow flips from 1D-like to
    2D-like as N grows (paper: TTNN missteps at N=1024; TL adapts).
"""
from __future__ import annotations

from repro.core import get_hw, simulate, templates

from .common import row, tl_gemm


def sweep():
    hw = get_hw("wormhole_8x8")
    lines = []
    for tag, fixed, var in (("varyK", "MN32768", "K"), ("varyN", "MK32768", "N")):
        for v in (256, 512, 1024, 2048):
            if tag == "varyK":
                M = N = 32768
                K = v
            else:
                M = K = 32768
                N = v
            res = tl_gemm(M, N, K, hw)
            tl_t = res.best.sim.total_s
            tt1 = simulate(templates.tt1d_matmul_plan(M, N, K, hw), hw).total_s
            tt2 = simulate(templates.tt2d_matmul_plan(M, N, K, hw), hw).total_s
            ttnn = simulate(templates.ttnn_matmul_plan(M, N, K, hw), hw).total_s
            best_kind = "1D-like" if tt1 < tt2 else "2D-like"
            lines.append(row(
                f"gemm_fig6/{tag}/{fixed}_{var}{v}", tl_t * 1e6,
                f"vs_ttnn={ttnn / tl_t:.3f};vs_tt1d={tt1 / tl_t:.3f};"
                f"vs_tt2d={tt2 / tl_t:.3f};template_best={best_kind};"
                f"tl_plan={res.best.plan.describe().replace(',', ' ')}"))
    return lines


def main():
    for ln in sweep():
        print(ln)


if __name__ == "__main__":
    main()
