"""Spatial-reduction (split-K) suite: reduction-bound cells on the mesh.

The tentpole claim of the spatial-reduction plan space is that binding a
reduction dim to a mesh axis — with partial-sum forwarding over the NoC or
accumulate-in-place through the store path — beats serializing the reduction
on single cores exactly where the parallel grid is too thin to fill the
machine.  This table measures that end to end on three reduction-bound
kernel families (Moon et al.'s spatially-mapped-reduction regime;
StreamTensor's decode-streaming case):

* **tall-skinny GEMM** — few output tiles, enormous K;
* **flash_decode** — one query row per head vs a long KV cache (the whole
  KV walk is an online-softmax reduction);
* **moe_gmm** — grouped per-expert GEMM with a deep ``d_in`` contraction.

Every cell is planned twice: with the split-K space enabled (the default
``SearchBudget``) and with ``spatial_reduction=False`` (the pre-split-K
parallel-only space).  The CSV reports both simulated/model times and the
improvement ratio; ``benchmarks/plan_speed.py`` embeds the same cells into
``BENCH_plan_speed.json`` with a ``baseline_sim_us`` column and gates their
best-plan selections through the golden check.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Callable, Dict, Iterator, List, Tuple

from repro.core import (SearchBudget, flash_decode_program, get_hw,
                        matmul_program, moe_gmm_program, plan_kernel_multi)

from .common import geomean, row

HW_NAME = "wormhole_8x8"
REDUCTION_BUDGET = SearchBudget(top_k=5, max_plans_per_mapping=48,
                                max_candidates=8000)

TALL_SKINNY = ((256, 256, 65536), (512, 256, 32768),
               (256, 1024, 32768), (512, 512, 16384))
FLASH_DECODE = ((16, 32768, 128), (32, 65536, 64), (8, 131072, 128))
MOE_GMM = ((8, 128, 16384, 512), (4, 256, 32768, 256))


def cells() -> List[Tuple[str, Callable[[], list]]]:
    """(cell name, program-factory) pairs; factories build the block-shape
    candidate lists ``plan_kernel_multi`` pools (kept small on purpose — the
    suite runs every cell twice)."""
    out: List[Tuple[str, Callable[[], list]]] = []
    for M, N, K in TALL_SKINNY:
        out.append((
            f"gemm_ts/M{M}_N{N}_K{K}",
            lambda M=M, N=N, K=K: [
                matmul_program(M, N, K, bm=bm, bn=bn, bk=bk)
                for bm in (32, 64) for bn in (32, 64) for bk in (64, 128)]))
    for H, S, D in FLASH_DECODE:
        out.append((
            f"flash_decode/h{H}_kv{S}_d{D}",
            lambda H=H, S=S, D=D: [
                flash_decode_program(H, S, D, bkv=bkv)
                for bkv in (32, 64, 128)]))
    for E, cap, din, dout in MOE_GMM:
        out.append((
            f"moe_gmm/e{E}_c{cap}_{din}x{dout}",
            lambda E=E, cap=cap, din=din, dout=dout: [
                moe_gmm_program(E, cap, din, dout, bm=bm, bn=64, bk=bk)
                for bm in (64, 128) for bk in (64, 128)]))
    return out


def plan_cells(workers: int = 1, hw_name: str = HW_NAME) -> Iterator[tuple]:
    """Yield ``(name, with_reduction, baseline)`` plan results per cell.

    The baseline run disables only the split-K space
    (``spatial_reduction=False``); budget, block candidates, and the
    two-step selection are otherwise identical, so the delta is purely the
    new plan space."""
    hw = get_hw(hw_name)
    budget = replace(REDUCTION_BUDGET, workers=workers)
    base_budget = replace(budget, spatial_reduction=False)
    for name, mk in cells():
        red = plan_kernel_multi(mk(), hw, budget=budget)
        base = plan_kernel_multi(mk(), hw, budget=base_budget)
        yield name, red, base


def sweep(workers: int = 1) -> Tuple[List[str], Dict[str, float]]:
    lines: List[str] = []
    improvements: List[float] = []
    splitk_wins = 0
    for name, red, base in plan_cells(workers=workers):
        sim = red.best.sim.total_s
        base_sim = base.best.sim.total_s
        imp = base_sim / sim if sim > 0 else 0.0
        improvements.append(imp)
        is_splitk = bool(red.best.plan.mapping.reduce_binds())
        splitk_wins += is_splitk
        lines.append(row(
            f"reduction/{name}", sim * 1e6,
            f"baseline_us={base_sim * 1e6:.2f};improvement={imp:.3f};"
            f"splitk={'y' if is_splitk else 'n'};"
            f"plan={red.best.plan.describe().replace(',', ' ')}"))
    summary = {
        "sim_improvement_geomean": geomean(improvements),
        "n_cells": len(improvements),
        "n_splitk_best": splitk_wins,
        "n_improved_15pct": sum(1 for i in improvements if i >= 1.15),
    }
    lines.append(row(
        "reduction/geomean", 0.0,
        f"sim_improvement={summary['sim_improvement_geomean']:.3f};"
        f"splitk_best={splitk_wins}/{len(improvements)};"
        f"improved_15pct={summary['n_improved_15pct']}"))
    return lines, summary


def main(full: bool = False, cache=None) -> Dict[str, float]:
    """``full``/``cache`` accepted for run.py uniformity; the suite always
    re-plans cold (it compares two plan spaces, which a shared cache would
    simply serve back)."""
    lines, summary = sweep()
    for ln in lines:
        print(ln)
    return summary


if __name__ == "__main__":
    main()
