"""Multi-tenant partitioning benchmark (DESIGN_TENANCY.md).

Two questions, answered per k in {2, 4} tenants on wormhole_8x8:

* **Isolation overhead** — each tenant's simulated time on its partition
  vs the same kernel planned solo on the whole mesh, *normalized by core
  share*: ``overhead = (t_part * part_cores) / (t_solo * total_cores)``.
  1.0 means the tenant runs exactly at its proportional share of the
  fabric; the acceptance bar is geomean <= 1.5x (partition-edge DRAM
  attribution and lost NoC planes are real costs, not noise).
* **Re-plan containment** — a seeded single-core kill per layout: blast
  radius must be 1 (only the owning tenant re-plans), every other
  tenant's plan digest byte-unchanged, resolved within the ladder budget.

Tenant workloads mix the Fig-5 GEMM and Fig-7 FlashAttention suites so
partitions host heterogeneous neighbors, the case isolation exists for.
"""
from __future__ import annotations

import random

from repro.core import (SearchBudget, block_shape_candidates,
                        flash_attention_program, get_hw, matmul_program,
                        plan_kernel_multi)
from repro.planservice import PlanService
from repro.tenancy import (IsolationValidator, MeshPartitioner,
                           TenantRuntime, TenantSpec)

from .common import geomean, row

HW_NAME = "wormhole_8x8"
BUDGET = SearchBudget(top_k=3, max_mappings=32, max_plans_per_mapping=16,
                      max_candidates=1000)
SEED = 20260807


def _gemm_tenant(name: str, M: int, N: int, K: int, qos: str) -> TenantSpec:
    progs = [matmul_program(M, N, K, bm=bm, bn=bn, bk=bk)
             for bm, bn, bk in block_shape_candidates(M, N, K)][:8]
    return TenantSpec(name, progs, qos=qos)


def _flash_tenant(name: str, bh: int, seq: int, head_dim: int,
                  qos: str) -> TenantSpec:
    progs = [flash_attention_program(bh, seq, seq, head_dim, bq=bq, bkv=bkv)
             for bq in (32, 64) for bkv in (32, 64)]
    return TenantSpec(name, progs, qos=qos)


def tenant_table(k: int):
    """The k-tenant mix: alternating gemm/flash cells, alternating QoS."""
    cells = [
        lambda q: _gemm_tenant("gemm_1k", 1024, 1024, 1024, q),
        lambda q: _flash_tenant("flash_s1k", 64, 1024, 64, q),
        lambda q: _gemm_tenant("gemm_wide", 512, 2048, 1024, q),
        lambda q: _flash_tenant("flash_s2k", 32, 2048, 64, q),
    ]
    out = []
    for i in range(k):
        qos = "guaranteed" if i % 2 == 0 else "best_effort"
        t = cells[i % len(cells)](qos)
        out.append(TenantSpec(f"{t.name}_{i}", t.programs, qos=qos,
                              weight=t.weight))
    return out


def sweep(cache=None, ks=(2, 4)):
    hw = get_hw(HW_NAME)
    service = PlanService(cache=cache) if cache is not None \
        else PlanService()
    lines = []
    summary = {}
    solo_memo = {}
    for k in ks:
        tenants = tenant_table(k)
        partitioner = MeshPartitioner(plan_layouts=2)
        plan = partitioner.plan(hw, tenants, service=service, budget=BUDGET,
                                budget_ms=float("inf"))
        bad = IsolationValidator().validate(plan)
        if bad:
            raise RuntimeError(f"k={k}: isolation validation failed: {bad}")

        overheads = []
        for p in plan.placements:
            key = p.tenant.name.rsplit("_", 1)[0]
            if key not in solo_memo:
                solo = plan_kernel_multi(list(p.tenant.programs), hw,
                                         budget=BUDGET,
                                         cache=service.cache)
                solo_memo[key] = solo.best.final_s
            t_solo = solo_memo[key]
            share = p.rect.n_cells / hw.n_cores
            overhead = (p.sim_s * share) / t_solo
            overheads.append(overhead)
            lines.append(row(
                f"tenancy/k{k}/{p.tenant.name}", p.sim_s * 1e6,
                f"part={p.rect.describe()};share={share:.3f};"
                f"solo_us={t_solo * 1e6:.2f};overhead={overhead:.3f};"
                f"qos={p.tenant.qos};rung={p.rung}"))
        g = geomean(overheads)

        # ---- containment under a seeded kill --------------------------
        rng = random.Random(SEED + k)
        victim = plan.placements[rng.randrange(len(plan.placements))]
        cells = sorted(victim.rect.cells())
        cell = cells[rng.randrange(len(cells))]
        runtime = TenantRuntime(plan, service=service, cache=service.cache,
                                budget=BUDGET, partitioner=partitioner,
                                latency_budget_s=60.0)
        ev = runtime.kill_core(cell)
        contained = ev.contained() and ev.blast_radius <= 1
        lines.append(row(
            f"tenancy/k{k}/containment", ev.seconds * 1e6,
            f"kill={cell};owner={ev.owner};rung={ev.rung};"
            f"blast_radius={ev.blast_radius};contained={contained};"
            f"within_budget={ev.within_budget}"))
        lines.append(row(
            f"tenancy/k{k}/geomean", 0.0,
            f"isolation_overhead={g:.3f};layouts={plan.n_layouts};"
            f"makespan_us={plan.layout_score * 1e6:.2f}"))
        summary[k] = (g, contained, ev)
    return lines, summary


def main(cache=None, ks=(2, 4)):
    lines, summary = sweep(cache=cache, ks=ks)
    for ln in lines:
        print(ln)
    failed = []
    for k, (g, contained, ev) in sorted(summary.items()):
        print(f"# k={k}: isolation overhead geomean {g:.3f}x "
              f"(bar <= 1.5x), containment "
              f"{'ok' if contained else 'VIOLATED'} "
              f"(rung={ev.rung}, blast={ev.blast_radius})")
        if g > 1.5:
            failed.append(f"k={k} overhead {g:.3f} > 1.5")
        if not contained:
            failed.append(f"k={k} containment violated")
    if failed:
        raise SystemExit("tenancy acceptance failed: " + "; ".join(failed))
    return summary


if __name__ == "__main__":
    main()
