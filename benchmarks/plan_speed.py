"""Planner-throughput benchmark: how fast is the *cold* search itself?

The paper's planning cost is amortized over compilation, but TileLoom's
pitch (and ROADMAP's) is planning cheap enough to run inline at trace time.
This table measures exactly that: for every GEMM (Fig 5) and FlashAttention
(Fig 7) cell it runs the full two-step selection with no plan cache and
reports

* ``plan_seconds`` — cold wall time of ``plan_kernel_multi`` at workers=1,
  plus ``plan_seconds_workers`` for the same search sharded across the
  process pool (``REPRO_PLANNER_WORKERS`` / ``--workers``), with the
  aggregate speedup in the summary;
* ``cands_per_s`` — ranked candidates per second;
* branch-and-bound efficiency — candidates whose estimate the admissible
  lower bound skipped (``n_pruned``), whole mappings skipped by the compute
  floor (``n_mappings_pruned``), estimates actually computed
  (``n_estimated``);
* simulator compression — wave equivalence classes costed vs waves
  simulated for the winning plan (``classes/waves``).

The sweep also embeds the reduction-bound cells of
``benchmarks/reduction_table.py`` (tall-skinny GEMM, flash_decode,
moe_gmm), each planned with the spatial-reduction (split-K) space on *and*
off — the off run's time lands in the ``baseline_sim_us`` column and the
ratio in ``sim_improvement``, so the split-K win is tracked PR-over-PR in
the same JSON — and the kernel-graph pipeline cells of
``benchmarks/pipeline_table.py`` (mlp2 / unfused attention / moe ffn),
each co-planned with on-chip edge forwarding and again with fully
independent per-kernel plans (``dram_roundtrip_us``), so the graph-level
win and the selected edge decisions are golden-gated the same way.

Output: CSV rows on stdout plus ``BENCH_plan_speed.json``, always written
at the repo root (regardless of CWD or flags) so the perf trajectory is
tracked PR-over-PR.  ``--check-golden <path>`` compares the best-plan
selections — of the sequential run *and* the sharded run — against a
checked-in golden summary and fails on drift (the CI perf-smoke job runs
this under ``REPRO_FAST_SEARCH=1`` + ``REPRO_PLANNER_WORKERS=2`` against
``benchmarks/golden_plan_speed.json``); ``--update-golden`` regenerates
that checked-in golden from the current run after an intentional
best-plan change.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from dataclasses import replace
from typing import Dict, Optional

from repro.core import (SearchBudget, fast_search_enabled,
                        flash_attention_program, get_hw, plan_kernel_multi)
from repro.obs import metrics, trace
from repro.parallel.search_exec import resolve_workers

from .common import HW_CONFIGS, geomean, row, tl_gemm
from . import flash_table, gemm_table, pipeline_table, reduction_table

# the repo root (this file's parent's parent): the perf trajectory is
# tracked PR-over-PR, so the table must land in one well-known place
JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_plan_speed.json")
# the checked-in golden the CI perf-smoke job gates against; regenerate
# with --update-golden after an intentional best-plan change
GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden_plan_speed.json")
FLASH_BUDGET = SearchBudget(top_k=5, max_plans_per_mapping=48)

# the planner's per-phase wall-time attribution (repro.obs.metrics counter
# ``planner_phase_seconds_total``); every cell reports its delta so the JSON
# carries a per-phase breakdown of the cold search
PHASES = ("enumerate", "estimate", "bnb", "simulate", "cache")


def _phase_totals() -> Dict[str, float]:
    c = metrics.counter("planner_phase_seconds_total")
    return {p: c.value(phase=p) for p in PHASES}


def _phase_delta(before: Dict[str, float]) -> Dict[str, float]:
    after = _phase_totals()
    return {p: after[p] - before[p] for p in PHASES
            if after[p] - before[p] > 0}


def tracing_active() -> bool:
    """Span tracing on (``REPRO_TRACE`` / ``--trace`` / explicit enable) —
    golden regeneration is refused while it is: goldens must be recorded
    from an uninstrumented run."""
    import os as _os
    return trace.enabled() or bool(
        _os.environ.get(trace.TRACE_ENV, "").strip())


def write_golden(cells: Dict[str, Dict], path: str) -> None:
    """Record the best-plan golden summary (shared by the standalone CLI's
    ``--write-golden``/``--update-golden`` and ``run.py --update-golden``).
    Refuses under tracing so instrumented runs can never redefine the
    reference selections."""
    if tracing_active():
        raise RuntimeError(
            "refusing to write plan_speed golden while tracing is enabled "
            "(unset REPRO_TRACE / drop --trace and re-run)")
    with open(path, "w") as f:
        json.dump({"fast_search": fast_search_enabled(),
                   "best_plans": {n: c["best"]
                                  for n, c in sorted(cells.items())}},
                  f, indent=1, sort_keys=True)


def _cell(res) -> Dict:
    sim = res.best.sim
    return {
        "best": res.best.plan.describe(),
        "model_us": res.best.cost.total_s * 1e6,
        "sim_us": sim.total_s * 1e6 if sim else None,
        "plan_seconds": res.plan_seconds,
        "n_candidates": res.n_candidates,
        "n_estimated": res.n_estimated,
        "n_pruned": res.n_pruned,
        "n_mappings": res.n_mappings,
        "n_mappings_pruned": res.n_mappings_pruned,
        "n_waves": sim.n_waves if sim else 0,
        "n_wave_classes": sim.n_wave_classes if sim else 0,
    }


def sweep(full: bool = False, workers: int = 1):
    cells: Dict[str, Dict] = {}
    from .common import DEFAULT_BUDGET
    gemm_budget = replace(DEFAULT_BUDGET, workers=workers)
    flash_budget = replace(FLASH_BUDGET, workers=workers)
    for hw_name in HW_CONFIGS:
        hw = get_hw(hw_name)
        for (M, N, K) in gemm_table.shape_table(full):
            ph0 = _phase_totals()
            res = tl_gemm(M, N, K, hw, budget=gemm_budget)
            c = _cell(res)
            c["phases"] = _phase_delta(ph0)
            cells[f"gemm/{hw_name}/M{M}_N{N}_K{K}"] = c
    hw = get_hw("wormhole_8x8")
    for bh, seq, head_dim in flash_table.shape_table():
        progs = [flash_attention_program(bh, seq, seq, head_dim, bq=bq,
                                         bkv=bkv)
                 for bq in (32, 64, 128) for bkv in (32, 64, 128)]
        ph0 = _phase_totals()
        res = plan_kernel_multi(progs, hw, budget=flash_budget)
        c = _cell(res)
        c["phases"] = _phase_delta(ph0)
        cells[f"flash/h{bh}_s{seq}"] = c
    # reduction-bound cells (tall-skinny gemm / flash_decode / moe_gmm):
    # planned twice — split-K space on and off — so the table records how
    # much the spatial-reduction plan space buys (`baseline_sim_us`), and
    # the golden gate pins the selected split-K plans against drift
    red_it = reduction_table.plan_cells(workers=workers)
    while True:
        ph0 = _phase_totals()       # the generator plans lazily on next()
        try:
            name, red, base = next(red_it)
        except StopIteration:
            break
        c = _cell(red)
        c["phases"] = _phase_delta(ph0)
        c["baseline_best"] = base.best.plan.describe()
        c["baseline_model_us"] = base.best.cost.total_s * 1e6
        c["baseline_sim_us"] = (base.best.sim.total_s * 1e6
                                if base.best.sim else None)
        c["baseline_plan_seconds"] = base.plan_seconds
        if c["sim_us"] and c["baseline_sim_us"]:
            c["sim_improvement"] = c["baseline_sim_us"] / c["sim_us"]
        cells[f"reduction/{name}"] = c
    # kernel-graph pipeline cells (mlp2 / unfused attention / moe ffn):
    # co-planned with on-chip forwarding vs fully independent per-kernel
    # plans with the DRAM handoff (`dram_roundtrip_us`); the golden gate
    # pins the selected graph plans (node candidates + edge decisions)
    pipe_it = pipeline_table.plan_cells(workers=workers)
    while True:
        ph0 = _phase_totals()
        try:
            name, co, base = next(pipe_it)
        except StopIteration:
            break
        cells[f"pipeline/{name}"] = {
            "phases": _phase_delta(ph0),
            "best": co.describe(),
            "model_us": None,
            "sim_us": co.total_s * 1e6,
            "plan_seconds": co.plan_seconds,
            "n_candidates": co.n_pairs,
            "n_estimated": co.n_graph_combos,
            "n_pruned": co.n_graph_pruned,
            "n_mappings": 0,
            "n_mappings_pruned": 0,
            "n_waves": 0,
            "n_wave_classes": 0,
            "dram_roundtrip_us": base.total_s * 1e6,
            "baseline_plan_seconds": base.plan_seconds,
            "n_edges_forwarded": co.n_forwarded(),
            "sim_improvement": (base.total_s / co.total_s
                                if co.total_s > 0 else None),
        }
    return cells


def summarize(cells: Dict[str, Dict]) -> Dict:
    total_s = sum(c["plan_seconds"] for c in cells.values())
    # the search-efficiency trajectory metrics (candidates/s, estimate
    # fraction, B&B counters) are defined over *single-kernel* searches —
    # pipeline cells report graph-level quantities (candidate pairs, graph
    # combos) in those fields, so they are excluded here to keep the
    # PR-over-PR numbers comparable with pre-pipeline snapshots
    kcells = {n: c for n, c in cells.items() if not n.startswith("pipeline/")}
    kernel_s = sum(c["plan_seconds"] for c in kcells.values())
    n_cand = sum(c["n_candidates"] for c in kcells.values())
    n_est = sum(c["n_estimated"] for c in kcells.values())
    n_pruned = sum(c["n_pruned"] for c in kcells.values())
    compress = [c["n_waves"] / c["n_wave_classes"] for c in kcells.values()
                if c["n_wave_classes"]]
    out = {
        "fast_search": fast_search_enabled(),
        "n_cells": len(cells),
        "plan_seconds_total": total_s,
        "candidates_per_s": n_cand / kernel_s if kernel_s > 0 else 0.0,
        "n_candidates": n_cand,
        "n_estimated": n_est,
        "n_pruned": n_pruned,
        "estimate_fraction": n_est / n_cand if n_cand else 0.0,
        "waves_per_class_geomean": geomean(compress),
        "phase_seconds": {
            p: sum(c.get("phases", {}).get(p, 0.0) for c in cells.values())
            for p in PHASES},
    }
    imp = [c["sim_improvement"] for n, c in cells.items()
           if c.get("sim_improvement") and n.startswith("reduction/")]
    if imp:
        out["reduction_sim_improvement_geomean"] = geomean(imp)
        out["reduction_cells_improved_15pct"] = sum(
            1 for i in imp if i >= 1.15)
    pimp = [c["sim_improvement"] for n, c in cells.items()
            if c.get("sim_improvement") and n.startswith("pipeline/")]
    if pimp:
        out["pipeline_sim_improvement_geomean"] = geomean(pimp)
        out["pipeline_cells_improved_20pct"] = sum(
            1 for i in pimp if i >= 1.20)
    par = [c["plan_seconds_workers"] for c in cells.values()
           if "plan_seconds_workers" in c]
    if par:
        total_w = sum(par)
        out["plan_seconds_total_workers"] = total_w
        out["workers_speedup"] = total_s / total_w if total_w > 0 else 0.0
        out["workers_best_mismatches"] = sum(
            1 for c in cells.values()
            if c.get("best_workers") not in (None, c["best"]))
    return out


def service_latency(budget_ms: float = 10.0) -> Dict[str, Dict]:
    """Plan-service resolve-latency probe: a cold pass (rungs 2-4 under
    the deadline), a drain of the background completions, and a warm pass
    (all rung-1), against a throwaway store.  Reports p50/p99 resolve
    time per rung from the ``planservice_resolve_seconds`` histogram —
    the service's latency trajectory, tracked like plan_speed."""
    import tempfile
    from repro import plancache
    from repro.core import block_shape_candidates, matmul_program
    from repro.planservice import PlanRequest, PlanService

    shapes = ((256, 256, 256), (512, 512, 256), (512, 256, 512),
              (1024, 512, 256))
    hw = get_hw("wormhole_1x8")
    old = os.environ.get(plancache.ENV_DIR)
    tmp = tempfile.mkdtemp(prefix="planservice_bench_")
    os.environ[plancache.ENV_DIR] = tmp
    plancache.reset_store()
    try:
        svc = PlanService()

        def requests():
            for M, N, K in shapes:
                progs = [matmul_program(M, N, K, bm=bm, bn=bn, bk=bk)
                         for bm, bn, bk in block_shape_candidates(M, N, K)]
                yield PlanRequest(progs, hw, budget_ms=budget_ms)

        for req in requests():
            svc.resolve(req)             # cold: family/search/fallback
        svc.drain()
        for req in requests():
            svc.resolve(req)             # warm: background-published hits
    finally:
        if old is None:
            os.environ.pop(plancache.ENV_DIR, None)
        else:
            os.environ[plancache.ENV_DIR] = old
        plancache.reset_store()
    hist = metrics.snapshot().get("planservice_resolve_seconds", {})
    out: Dict[str, Dict] = {"budget_ms": budget_ms}
    for s in hist.get("series", []):
        rung = s["labels"].get("rung", "?")
        p50 = metrics.hist_quantile(s, 0.5)
        p99 = metrics.hist_quantile(s, 0.99)
        out[rung] = {"count": s["count"],
                     "p50_ms": p50 * 1e3 if p50 is not None else None,
                     "p99_ms": p99 * 1e3 if p99 is not None else None}
    return out


def check_golden(cells: Dict[str, Dict], path: str) -> int:
    """Compare best-plan selections against a golden summary; returns the
    number of drifted cells (0 = pass)."""
    with open(path) as f:
        golden = json.load(f)
    if golden.get("fast_search") != fast_search_enabled():
        print(f"plan_speed/golden: search-config mismatch — golden was "
              f"recorded with fast_search={golden.get('fast_search')} but "
              f"this run has fast_search={fast_search_enabled()} "
              f"(set/unset REPRO_FAST_SEARCH to match)", file=sys.stderr)
        return 1
    want = golden["best_plans"]
    drift = 0
    for name, best in want.items():
        got = cells.get(name)
        if got is None:
            print(f"plan_speed/golden: MISSING cell {name}", file=sys.stderr)
            drift += 1
            continue
        if got["best"] != best:
            print(f"plan_speed/golden: DRIFT in {name}\n"
                  f"  golden: {best}\n  got:    {got['best']}",
                  file=sys.stderr)
            drift += 1
        if got.get("best_workers") not in (None, best):
            print(f"plan_speed/golden: PARALLEL DRIFT in {name}\n"
                  f"  golden:  {best}\n  workers: {got['best_workers']}",
                  file=sys.stderr)
            drift += 1
    extra = set(cells) - set(want)
    if extra:
        print(f"plan_speed/golden: {len(extra)} cells not in golden "
              f"(regenerate with --write-golden)", file=sys.stderr)
    return drift


def run(full: bool = False, workers: Optional[int] = None):
    """Sweep at workers=1, re-sweep sharded when workers resolve above 1,
    summarize, and write ``BENCH_plan_speed.json`` at the repo root (the
    shared core of the run.py suite entry and the standalone CLI)."""
    w_n = resolve_workers(workers)
    cells = sweep(full, workers=1)
    if w_n > 1:
        for name, c in sweep(full, workers=w_n).items():
            cells[name]["plan_seconds_workers"] = c["plan_seconds"]
            cells[name]["best_workers"] = c["best"]
    summary = summarize(cells)
    summary["workers"] = w_n
    summary["plan_latency"] = service_latency()
    with open(JSON_PATH, "w") as f:
        json.dump({"cells": cells, "summary": summary}, f, indent=1,
                  sort_keys=True)
    msg = (f"wrote {JSON_PATH} "
           f"({summary['plan_seconds_total']:.1f}s cold planning, "
           f"{summary['candidates_per_s']:.0f} candidates/s")
    if w_n > 1:
        msg += (f"; workers={w_n}: "
                f"{summary['plan_seconds_total_workers']:.1f}s, "
                f"{summary['workers_speedup']:.2f}x, "
                f"{summary['workers_best_mismatches']} best mismatches")
    print(msg + ")", file=sys.stderr)
    return cells, summary


def main(full: bool = False, cache=None, workers: Optional[int] = None
         ) -> Dict:
    """``cache`` is accepted for run.py uniformity but deliberately unused:
    this suite measures the cold search."""
    cells, summary = run(full, workers=workers)
    for name, c in sorted(cells.items()):
        derived = (f"cands={c['n_candidates']};est={c['n_estimated']};"
                   f"pruned={c['n_pruned']};"
                   f"map_pruned={c['n_mappings_pruned']}/{c['n_mappings']};"
                   f"classes={c['n_wave_classes']}/{c['n_waves']}")
        if "plan_seconds_workers" in c:
            derived += f";workers_us={c['plan_seconds_workers'] * 1e6:.0f}"
        if c.get("sim_improvement"):
            base_us = c.get("baseline_sim_us", c.get("dram_roundtrip_us"))
            derived += (f";baseline_sim_us={base_us:.1f}"
                        f";improvement={c['sim_improvement']:.3f}")
        print(row(f"plan_speed/{name}", c["plan_seconds"] * 1e6, derived))
    total_derived = (f"cands_per_s={summary['candidates_per_s']:.0f};"
                     f"est_frac={summary['estimate_fraction']:.3f};"
                     f"waves_per_class="
                     f"{summary['waves_per_class_geomean']:.1f}")
    if "workers_speedup" in summary:
        total_derived += (f";workers={summary['workers']};"
                          f"workers_speedup="
                          f"{summary['workers_speedup']:.2f}")
    print(row("plan_speed/total", summary["plan_seconds_total"] * 1e6,
              total_derived))
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="widen the GEMM sweep toward the paper's 140 cells")
    ap.add_argument("--workers", type=int, default=None,
                    help="worker count for the sharded pass (default: "
                         "REPRO_PLANNER_WORKERS / cpu count; <=1 skips it)")
    ap.add_argument("--check-golden", metavar="PATH",
                    help="fail if best-plan selections drift from PATH")
    ap.add_argument("--write-golden", metavar="PATH",
                    help="write the golden best-plan summary to PATH")
    ap.add_argument("--update-golden", action="store_true",
                    help="regenerate the checked-in golden "
                         f"({os.path.relpath(GOLDEN_PATH)}) from this run — "
                         "the supported way to record an intentional "
                         "best-plan change (hand-editing is error-prone); "
                         "CI still runs in check mode only")
    ap.add_argument("--trace", metavar="PATH",
                    help="collect planner spans and write a Chrome "
                         "trace-event JSON to PATH (implies golden writes "
                         "are refused)")
    args = ap.parse_args()
    if args.trace:
        os.environ[trace.TRACE_ENV] = args.trace
        trace.enable(args.trace)
    golden_out = args.write_golden or (GOLDEN_PATH if args.update_golden
                                       else None)
    if golden_out and tracing_active():
        ap.error("golden regeneration is refused while tracing is enabled "
                 "(drop --trace / unset REPRO_TRACE)")
    cells, _ = run(args.full, workers=args.workers)
    if golden_out:
        write_golden(cells, golden_out)
        print(f"wrote {golden_out}", file=sys.stderr)
    if args.trace:
        written = trace.write(args.trace)
        print(f"wrote trace {written}", file=sys.stderr)
    if args.check_golden:
        sys.exit(1 if check_golden(cells, args.check_golden) else 0)
