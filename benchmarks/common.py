"""Shared helpers for the paper-reproduction benchmarks (Scale A:
TileLoom planning on the Wormhole df model, profiled by the event simulator —
see DESIGN.md S3/S4)."""
from __future__ import annotations

import math
from typing import Iterable, List, Optional, Tuple

from repro.core import (SearchBudget, block_shape_candidates, estimate,
                        get_hw, matmul_program, plan_kernel, plan_kernel_multi,
                        simulate, templates)

HW_CONFIGS = ("wormhole_1x8", "wormhole_4x8", "wormhole_8x8")
DEFAULT_BUDGET = SearchBudget(top_k=5, max_plans_per_mapping=48,
                              max_candidates=8000)


def geomean(xs: Iterable[float]) -> float:
    xs = [x for x in xs if x > 0]
    if not xs:
        return 0.0
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def bench_hw(name: str):
    """Resolve a benchmark mesh, degraded by any hardware faults injected
    through ``REPRO_FAULTS`` (``repro.runtime.faults``); byte-identical
    pass-through when the variable is unset, so golden checks are
    unaffected."""
    from repro.runtime.faults import apply_env_faults
    return apply_env_faults(get_hw(name))


def tl_gemm(M: int, N: int, K: int, hw, budget=DEFAULT_BUDGET, cache=None,
            **kw):
    """Plan a GEMM with full block-shape exploration.  ``cache`` is an
    optional :class:`repro.plancache.PlanCache`: hits skip the search, and
    ``python -m repro.plancache warm --wormhole`` pre-populates it.
    ``REPRO_FAULTS`` hardware faults apply here unless the caller already
    passed a degraded mesh."""
    if not hw.is_degraded:
        from repro.runtime.faults import apply_env_faults
        hw = apply_env_faults(hw)
    progs = [matmul_program(M, N, K, bm=bm, bn=bn, bk=bk)
             for bm, bn, bk in block_shape_candidates(M, N, K)]
    return plan_kernel_multi(progs, hw, budget=budget, cache=cache, **kw)


def sim_time(plan, hw) -> float:
    return simulate(plan, hw).total_s


def row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.2f},{derived}"
