"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` widens the Fig 5 sweep
toward the paper's 140 configurations.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--suite", default=None,
                    help="comma-separated suite list (same filter as --only, "
                         "e.g. --suite gemm_fig5,flash_fig7)")
    ap.add_argument("--plan-cache", action="store_true",
                    help="resolve plans from the persistent registry "
                         "(pre-warm with `python -m repro.plancache warm "
                         "--wormhole`); off by default so suites that "
                         "measure planning time stay honest")
    ap.add_argument("--trace", metavar="PATH",
                    help="collect planner spans (repro.obs.trace) and write "
                         "a Chrome trace-event JSON to PATH at the end")
    ap.add_argument("--update-golden", action="store_true",
                    help="regenerate benchmarks/golden_plan_speed.json from "
                         "this run's plan_speed sweep (refused under "
                         "--trace / REPRO_TRACE: goldens must come from an "
                         "uninstrumented run)")
    args = ap.parse_args()

    import os

    from repro.obs import metrics, trace
    if args.trace:
        os.environ[trace.TRACE_ENV] = args.trace
        trace.enable(args.trace)

    from . import (ablation_spatial, ablation_temporal, flash_table,
                   gemm_irregular, gemm_table, perfmodel_validation,
                   pipeline_table, plan_speed, reduction_table, topk_table)
    if args.update_golden and plan_speed.tracing_active():
        ap.error("--update-golden is refused while tracing is enabled "
                 "(drop --trace / unset REPRO_TRACE)")
    cache = None
    if args.plan_cache:
        from repro.plancache import PlanCache
        cache = PlanCache()
    suites = {
        "gemm_fig5": lambda: gemm_table.main(full=args.full, cache=cache),
        "gemm_fig6": gemm_irregular.main,
        "flash_fig7": lambda: flash_table.main(cache=cache),
        "spatial_tbl1": ablation_spatial.main,
        "temporal_fig8": ablation_temporal.main,
        "perfmodel_fig9": perfmodel_validation.main,
        "topk_tbl2": topk_table.main,
        "plan_speed": lambda: plan_speed.main(full=args.full),
        "reduction_splitk": lambda: reduction_table.main(full=args.full),
        "pipeline": lambda: pipeline_table.main(full=args.full),
    }
    # plan_speed, reduction_splitk, and pipeline re-plan every cell cold on
    # purpose (they measure the search / compare two plan spaces and ignore
    # --plan-cache), so they only run when named
    opt_in = {"plan_speed", "reduction_splitk", "pipeline"}
    selected = set(args.only or [])
    if args.suite:
        selected |= {s.strip() for s in args.suite.split(",") if s.strip()}
    unknown = selected - set(suites)
    if unknown:
        ap.error(f"unknown suite name(s): {', '.join(sorted(unknown))}; "
                 f"valid suites: {', '.join(sorted(suites))}")
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if selected and name not in selected:
            continue
        if not selected and name in opt_in:
            continue
        t0 = time.perf_counter()
        fn()
        print(f"suite/{name},{(time.perf_counter() - t0) * 1e6:.0f},done",
              file=sys.stderr)
    if args.update_golden:
        cells, _ = plan_speed.run(args.full)
        plan_speed.write_golden(cells, plan_speed.GOLDEN_PATH)
        print(f"wrote {plan_speed.GOLDEN_PATH}", file=sys.stderr)
    if cache is not None:
        s = cache.store
        s.flush_stats()
        print(f"plancache,{0:.0f},hits={s.stats.hits};misses={s.stats.misses}",
              file=sys.stderr)
    if args.trace:
        written = trace.write(args.trace)
        print(f"trace,{0:.0f},path={written}", file=sys.stderr)
    dumped = metrics.dump()              # honors REPRO_METRICS=<path>
    if dumped:
        print(f"metrics,{0:.0f},path={dumped}", file=sys.stderr)


if __name__ == "__main__":
    main()
