"""Serving-observability smoke: live introspection scrape + incident render.

The CI perf-smoke job runs this after ``benchmarks.obs_smoke``.  It
drives the real serve launcher end to end (DESIGN_OBS.md):

1. spawn ``python -m repro.launch.serve --tenants 2 --tenant-kill 0,0
   --introspect-port 0 --flightrec <tmp>`` with an ``--introspect-hold``
   scrape window;
2. scrape ``/metrics`` while the process is alive and validate it as
   Prometheus text exposition format 0.0.4; scrape ``/slo``, ``/plans``
   and ``/tenants`` and sanity-check their JSON;
3. after exit, render the flight-recorder dump through the real
   ``python -m repro.obs incident`` CLI and assert the acceptance story:
   the core-kill fault event is there, exactly one tenant ran
   containment rungs, and the plan-service rung decisions are grouped
   under request-correlation IDs.

Exit code 0 = all assertions hold; failures raise with the scraped
evidence in the message.
"""
from __future__ import annotations

import json
import os
import re
import subprocess
import sys
import tempfile
import time
import urllib.request

from repro.obs import expo

from .common import row

KILL_CORE = "0,0"
HOLD_S = 6.0
SCRAPE_TIMEOUT_S = 5.0


def _scrape(url: str, path: str) -> str:
    with urllib.request.urlopen(url + path, timeout=SCRAPE_TIMEOUT_S) as r:
        return r.read().decode()


def main() -> dict:
    tmp = tempfile.mkdtemp(prefix="obs_serve_smoke_")
    dump_path = os.path.join(tmp, "flightrec.json")
    env = dict(os.environ)
    env.setdefault("REPRO_PLAN_DEADLINE_MS", "5000")
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--tenants", "2", "--tenant-kill", KILL_CORE,
           "--introspect-port", "0", "--flightrec", dump_path,
           "--introspect-hold", str(HOLD_S)]
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True, env=env)

    # 1: wait for the hold window (run done, endpoint still up), parsing
    # the bound ephemeral port from the announcement line
    url = None
    lines = []
    assert proc.stdout is not None
    for line in proc.stdout:
        lines.append(line.rstrip("\n"))
        m = re.search(r"introspection at (http://\S+)", line)
        if m:
            url = m.group(1)
        if "holding introspection open" in line:
            break
    else:
        proc.wait(timeout=30)
        raise AssertionError(
            "serve exited before the hold window:\n" + "\n".join(lines))
    if url is None:
        raise AssertionError(
            "no introspection URL line:\n" + "\n".join(lines))

    # 2: live scrapes
    metrics_text = _scrape(url, "/metrics")
    problems = expo.validate_exposition(metrics_text)
    if problems:
        raise AssertionError(f"invalid exposition: {problems[:5]}")
    if "tenancy_fault_events_total" not in metrics_text:
        raise AssertionError("scrape missing tenancy counters:\n"
                             + metrics_text[:2000])

    slo_rep = json.loads(_scrape(url, "/slo"))
    if not slo_rep["enabled"] or slo_rep["slow"]["total"] < 1:
        raise AssertionError(f"SLO tracker saw no requests: {slo_rep}")
    if not slo_rep["tenants"]:
        raise AssertionError(f"SLO tracker saw no containment: {slo_rep}")

    plans = json.loads(_scrape(url, "/plans"))
    if "entries" not in plans or "cumulative" not in plans:
        raise AssertionError(f"malformed /plans: {plans}")

    tenants = json.loads(_scrape(url, "/tenants"))
    names = [t["tenant"] for t in tenants["tenants"]]
    if len(names) != 2:
        raise AssertionError(f"expected 2 tenants, got {tenants}")
    if len(tenants["incidents"]) != 1:
        raise AssertionError(f"expected 1 incident, got {tenants}")

    out, _ = proc.communicate(timeout=120)
    lines += out.splitlines()
    if proc.returncode != 0:
        raise AssertionError(f"serve exited {proc.returncode}:\n"
                             + "\n".join(lines))

    # 3: incident render through the real CLI
    render = subprocess.run(
        [sys.executable, "-m", "repro.obs", "incident", dump_path],
        capture_output=True, text=True, env=env)
    if render.returncode != 0:
        raise AssertionError(f"incident render failed: {render.stderr}")
    text = render.stdout
    if "fault" not in text or "cause=core_kill" not in text:
        raise AssertionError("render missing the kill event:\n" + text)

    doc = json.loads(open(dump_path).read())
    events = doc["events"]
    contain = [e for e in events if e["kind"] == "containment"]
    if len(contain) != 1 or contain[0]["blast_radius"] != 1:
        raise AssertionError(
            f"expected exactly one contained tenant, got {contain}")
    # the fault, the replan rung and the containment verdict share one
    # incident correlation ID
    incident_rid = contain[0]["rid"]
    incident_kinds = {e["kind"] for e in events
                      if e["rid"] == incident_rid}
    if not {"fault", "replan", "containment"} <= incident_kinds:
        raise AssertionError(
            f"incident {incident_rid} not fully correlated: "
            f"{sorted(incident_kinds)}")
    # plan-service rung decisions are correlated per request
    plan_reqs = [e for e in events if e["kind"] == "plan_request"]
    if not plan_reqs or any(not e.get("rid") for e in plan_reqs):
        raise AssertionError(
            f"uncorrelated plan_request events: {plan_reqs}")
    if not all(e.get("rung") for e in plan_reqs):
        raise AssertionError(f"plan_request without a rung: {plan_reqs}")

    summary = {
        "n_events": len(events),
        "n_plan_requests": len(plan_reqs),
        "incident_rid": incident_rid,
        "slo_total": slo_rep["slow"]["total"],
        "metrics_lines": len(metrics_text.splitlines()),
    }
    print(row("obs_serve_smoke/exposition", 0.0,
              f"lines={summary['metrics_lines']};valid=yes"))
    print(row("obs_serve_smoke/slo", 0.0,
              f"total={slo_rep['slow']['total']};"
              f"alert={slo_rep['alert']['state']}"))
    print(row("obs_serve_smoke/incident", 0.0,
              f"events={len(events)};plan_requests={len(plan_reqs)};"
              f"contained=1"))
    return summary


if __name__ == "__main__":
    main()
    print("obs_serve_smoke: OK", file=sys.stderr)
