"""Paper Table 2: top-k trade-off.

Geomean performance (normalized to TTNN) and planning time for k = 1..5 on
the three mesh configs.  top-1 = fully static compilation (no profiling);
larger k profiles more candidates on the simulator.  Paper: -6.5% (top-1) ->
+2.8% (top-5) on the 8x8 mesh, most of the gap closed by top-2.
"""
from __future__ import annotations

import time

from repro.core import SearchBudget, get_hw, simulate, templates

from .common import HW_CONFIGS, geomean, row, tl_gemm

SHAPES = ((1024, 1024, 4096), (4096, 4096, 4096), (16384, 1024, 4096),
          (4096, 16384, 4096))


def sweep():
    lines = []
    for hw_name in HW_CONFIGS:
        hw = get_hw(hw_name)
        ttnn_times = {}
        for (M, N, K) in SHAPES:
            ttnn_times[(M, N, K)] = simulate(
                templates.ttnn_matmul_plan(M, N, K, hw), hw).total_s
        for k in range(1, 6):
            t0 = time.perf_counter()
            ratios = []
            for (M, N, K) in SHAPES:
                res = tl_gemm(M, N, K, hw,
                              budget=SearchBudget(top_k=k,
                                                  max_plans_per_mapping=48),
                              profile=(k > 1))
                # top-1 = static best (no profiling); otherwise profiled best
                t = (simulate(res.best.plan, hw).total_s)
                ratios.append(ttnn_times[(M, N, K)] / t)
            dt = time.perf_counter() - t0
            lines.append(row(
                f"topk_tbl2/{hw_name}/top{k}", dt * 1e6 / len(SHAPES),
                f"vs_ttnn_geomean={geomean(ratios):.3f};"
                f"plan_time_s={dt:.2f}"))
    return lines


def main():
    for ln in sweep():
        print(ln)


if __name__ == "__main__":
    main()
