"""Paper Fig 5: GEMM — TL vs TTNN / TT-1D / TT-2D across shapes x meshes.

For each (M, N, K, hw): TileLoom plans with the two-step top-5 selection; the
baselines use their fixed templates.  All on the Wormhole df model with the
event simulator as the profiling stage.  Output: per-config normalized perf
(TL / TTNN, higher is better) and the geomean + win-rate summary the paper
reports (S3.2: geomean +2.8% on 8x8; >=0.9x on 78.5% of configs; +30%/+9%
vs fixed TT-1D/TT-2D).
"""
from __future__ import annotations

from repro.core import estimate, get_hw, simulate, templates

from .common import DEFAULT_BUDGET, HW_CONFIGS, geomean, row, tl_gemm


def shape_table(full: bool = False):
    """The Fig-5 (M, N, K) grid; also consumed by the plancache AOT warmer
    (``python -m repro.plancache warm --wormhole``)."""
    Ms = (256, 1024, 4096, 16384) if full else (1024, 4096, 16384)
    Ks = (1024, 4096) if full else (4096,)
    return tuple((M, N, K) for K in Ks for M in Ms for N in Ms)


def sweep(full: bool = False, cache=None):
    """``cache`` (a ``repro.plancache.PlanCache``) lets a pre-warmed plan
    registry (``python -m repro.plancache warm --wormhole``) skip the
    searches; by default each shape is planned fresh."""
    lines = []
    summary = {}
    for hw_name in HW_CONFIGS:
        hw = get_hw(hw_name)
        ratios, r1d, r2d = [], [], []
        for (M, N, K) in shape_table(full):
            res = tl_gemm(M, N, K, hw, cache=cache)
            tl_t = res.best.sim.total_s
            tt1 = simulate(templates.tt1d_matmul_plan(M, N, K, hw), hw).total_s
            tt2 = simulate(templates.tt2d_matmul_plan(M, N, K, hw), hw).total_s
            ttnn = simulate(templates.ttnn_matmul_plan(M, N, K, hw), hw).total_s
            ratios.append(ttnn / tl_t)
            r1d.append(tt1 / tl_t)
            r2d.append(tt2 / tl_t)
            lines.append(row(
                f"gemm_fig5/{hw_name}/M{M}_N{N}_K{K}", tl_t * 1e6,
                f"vs_ttnn={ttnn / tl_t:.3f};vs_tt1d={tt1 / tl_t:.3f};"
                f"vs_tt2d={tt2 / tl_t:.3f};"
                f"tflops={res.best.sim.tflops:.1f}"))
        win = sum(1 for r in ratios if r >= 1.0) / len(ratios)
        within10 = sum(1 for r in ratios if r >= 0.9) / len(ratios)
        summary[hw_name] = (geomean(ratios), win, within10,
                            geomean(r1d), geomean(r2d))
        lines.append(row(
            f"gemm_fig5/{hw_name}/geomean", 0.0,
            f"tl_vs_ttnn={geomean(ratios):.3f};win_rate={win:.3f};"
            f"within10pct={within10:.3f};vs_tt1d={geomean(r1d):.3f};"
            f"vs_tt2d={geomean(r2d):.3f}"))
    return lines, summary


def main(full: bool = False, cache=None):
    lines, summary = sweep(full, cache=cache)
    for ln in lines:
        print(ln)
    return summary


if __name__ == "__main__":
    main()
