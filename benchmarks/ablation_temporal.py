"""Paper Fig 8: temporal-reuse ablation on GEMM.

Memory-bound shapes (K shrinks as M=N grow, as the paper does) with and
without the hoisting pass.  Paper: up to 1.12x, growing with M/N; shapes
where hoisting does not pay converge to the same chosen mapping.
"""
from __future__ import annotations

from repro.core import get_hw

from .common import row, tl_gemm


def sweep():
    hw = get_hw("wormhole_8x8")
    lines = []
    for (m, k) in ((4096, 2048), (8192, 1024), (16384, 512), (32768, 256)):
        with_t = tl_gemm(m, m, k, hw)
        without = tl_gemm(m, m, k, hw, temporal_reuse=False)
        sp = without.best.sim.total_s / with_t.best.sim.total_s
        lines.append(row(
            f"temporal_fig8/M=N={m}_K={k}", with_t.best.sim.total_s * 1e6,
            f"speedup={sp:.3f};with_tflops={with_t.best.sim.tflops:.2f};"
            f"without_tflops={without.best.sim.tflops:.2f}"))
    return lines


def main():
    for ln in sweep():
        print(ln)


if __name__ == "__main__":
    main()
