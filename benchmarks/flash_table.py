"""Paper Fig 7: FlashAttention — TL vs TTNN across head counts x seq lens.

Non-causal variant (as the paper evaluates); heads in {64, 128}, sequence
lengths 512..8192 with batch x seq fixed at 8192 tokens.  Paper reports
1.7-2.0x over TTNN, attributed to K/V on-chip reuse across query tiles —
exactly the spatial/temporal reuse TileLoom's planner discovers.
"""
from __future__ import annotations

from repro.core import (SearchBudget, flash_attention_program, get_hw,
                        plan_kernel_multi, simulate, templates)

from .common import geomean, row

TOTAL_TOKENS = 8192
HIDDEN = 2048

# (batch*heads, seq, head_dim) cells of the Fig-7 sweep; also consumed by
# the plancache AOT warmer
def shape_table():
    out = []
    for heads in (64, 128):
        for seq in (512, 1024, 2048, 4096, 8192):
            out.append(((TOTAL_TOKENS // seq) * heads, seq, 64))
    return tuple(out)


def sweep(cache=None):
    hw = get_hw("wormhole_8x8")
    lines = []
    ratios = []
    for bh, seq, head_dim in shape_table():
        batch = TOTAL_TOKENS // seq
        heads = bh // batch
        progs = []
        for bq in (32, 64, 128):
            for bkv in (32, 64, 128):
                progs.append(flash_attention_program(
                    bh, seq, seq, head_dim, bq=bq, bkv=bkv))
        res = plan_kernel_multi(
            progs, hw, budget=SearchBudget(top_k=5,
                                           max_plans_per_mapping=48),
            cache=cache)
        tl_t = res.best.sim.total_s
        ttnn = simulate(templates.ttnn_flash_plan(bh, seq, seq, head_dim,
                                                  hw), hw).total_s
        ratios.append(ttnn / tl_t)
        lines.append(row(
            f"flash_fig7/h{heads}_s{seq}_b{batch}", tl_t * 1e6,
            f"vs_ttnn={ttnn / tl_t:.3f};"
            f"plan={res.best.plan.describe().replace(',', ' ')}"))
    lines.append(row("flash_fig7/geomean", 0.0,
                     f"tl_vs_ttnn={geomean(ratios):.3f}"))
    return lines, geomean(ratios)


def main(cache=None):
    lines, g = sweep(cache=cache)
    for ln in lines:
        print(ln)
    return g


if __name__ == "__main__":
    main()
