"""Observability smoke: overhead budget, trace validity, explain render.

The CI perf-smoke job runs this after the golden check.  It plans one
plan_speed GEMM cell repeatedly and asserts the observability layer's
contract (DESIGN_OBS.md):

1. **bit-identity** — the traced search selects the same best plan with
   the same cost as the untraced search, sequentially and sharded;
2. **overhead budget** — best-of-N traced cold-plan time is within 10%
   of best-of-N untraced (interleaved runs, min-of-N on both sides, so a
   single scheduler hiccup cannot fail the gate);
3. **trace validity** — the sharded run's span buffer is a valid Chrome
   trace (required keys, numeric timestamps, proper per-track nesting)
   and contains worker-category spans from >= 2 distinct worker pids;
4. **explain** — ``repro.obs.explain`` renders the cell read-through the
   plan cache (winner-vs-runner-up diff included).

Exit code 0 = all assertions hold; failures raise with the measured
numbers in the message.
"""
from __future__ import annotations

import sys
from dataclasses import replace
from time import perf_counter

from repro.core import get_hw, matmul_program, block_shape_candidates, \
    plan_kernel_multi
from repro.obs import explain as obs_explain
from repro.obs import trace

from .common import DEFAULT_BUDGET, row

CELL = "gemm/wormhole_8x8/M1024_N1024_K4096"
M, N, K = 1024, 1024, 4096
N_RUNS = 5
REPS = 3            # cold plans per timed sample (averages out timer noise)
OVERHEAD_BUDGET = 0.10


def _programs():
    return [matmul_program(M, N, K, bm=bm, bn=bn, bk=bk)
            for bm, bn, bk in block_shape_candidates(M, N, K)]


def _plan(budget, traced: bool):
    """One timed sample: ``REPS`` cold plans of the cell back to back;
    returns (last PlanResult, wall seconds of the whole sample)."""
    if traced:
        trace.clear()
        trace.enable()
    else:
        trace.disable()
    hw = get_hw("wormhole_8x8")
    t0 = perf_counter()
    for _ in range(REPS):
        res = plan_kernel_multi(_programs(), hw, budget=budget)
    return res, perf_counter() - t0


def main(full: bool = False, cache=None) -> dict:
    budget1 = replace(DEFAULT_BUDGET, workers=1)

    # 1+2: interleaved best-of-N, untraced vs traced, bit-identity checked
    base_t, traced_t = [], []
    base_res = traced_res = None
    for _ in range(N_RUNS):
        r, dt = _plan(budget1, traced=False)
        base_res, base_t = r, base_t + [dt]
        r, dt = _plan(budget1, traced=True)
        traced_res, traced_t = r, traced_t + [dt]
    trace.disable()
    if base_res.best.plan.describe() != traced_res.best.plan.describe() \
            or base_res.best.cost.total_s != traced_res.best.cost.total_s:
        raise AssertionError(
            f"traced search drifted: {traced_res.best.plan.describe()} "
            f"vs {base_res.best.plan.describe()}")
    overhead = min(traced_t) / min(base_t) - 1.0
    if overhead > OVERHEAD_BUDGET:
        raise AssertionError(
            f"tracing overhead {overhead:.1%} exceeds "
            f"{OVERHEAD_BUDGET:.0%} budget (untraced best "
            f"{min(base_t):.3f}s, traced best {min(traced_t):.3f}s)")

    # 3: sharded traced run — valid Chrome trace, >= 2 worker pids
    trace.clear()
    trace.enable()
    sharded, _ = _plan(replace(DEFAULT_BUDGET, workers=4), traced=True)
    events = trace.events()
    trace.disable()
    trace.clear()
    if sharded.best.plan.describe() != base_res.best.plan.describe():
        raise AssertionError(
            f"sharded traced search drifted: "
            f"{sharded.best.plan.describe()}")
    problems = trace.validate_chrome_trace({"traceEvents": events})
    if problems:
        raise AssertionError(f"invalid trace: {problems[:5]}")
    worker_pids = {e["pid"] for e in events if e.get("cat") == "worker"}
    if len(worker_pids) < 2:
        raise AssertionError(
            f"expected worker spans from >= 2 processes, got pids "
            f"{sorted(worker_pids)}")

    # 4: explain read-through the plan cache (second resolve is a hit)
    if cache is None:
        from repro.plancache import PlanCache
        cache = PlanCache()
    obs_explain.resolve_kernel_cell(CELL, cache=cache)     # populate
    text = obs_explain.explain(CELL, cache=cache)
    if "winner vs runner-up" not in text or "mesh utilization" not in text:
        raise AssertionError("explain output missing expected sections")

    summary = {
        "overhead": overhead,
        "untraced_best_s": min(base_t),
        "traced_best_s": min(traced_t),
        "n_trace_events": len(events),
        "n_worker_pids": len(worker_pids),
    }
    print(row("obs_smoke/overhead", min(traced_t) * 1e6,
              f"untraced_us={min(base_t) * 1e6:.0f};"
              f"overhead={overhead:+.1%};budget={OVERHEAD_BUDGET:.0%}"))
    print(row("obs_smoke/trace", 0.0,
              f"events={len(events)};worker_pids={len(worker_pids)};"
              f"valid=yes"))
    print(row("obs_smoke/explain", 0.0, f"chars={len(text)};cell={CELL}"))
    return summary


if __name__ == "__main__":
    main()
    print("obs_smoke: OK", file=sys.stderr)
