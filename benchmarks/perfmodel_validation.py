"""Paper Fig 9: performance-model validation.

Model-predicted vs "measured" (event-simulator) GEMM throughput across
(M, N, K).  The paper reports ~17% geomean error with the important property
that compute/memory-bound transitions are tracked (small shapes degrade —
launch overheads the model omits, S3.2).
"""
from __future__ import annotations

from repro.core import estimate, get_hw, simulate

from .common import geomean, row, tl_gemm


def sweep():
    hw = get_hw("wormhole_8x8")
    lines = []
    errs = []
    for (M, N, K) in ((512, 512, 512), (1024, 1024, 1024),
                      (2048, 2048, 2048), (4096, 4096, 4096),
                      (8192, 2048, 1024), (2048, 8192, 4096),
                      (16384, 1024, 4096), (6144, 6144, 6144)):
        res = tl_gemm(M, N, K, hw)
        plan = res.best.plan
        pred = estimate(plan, hw)
        meas = simulate(plan, hw)
        err = abs(pred.total_s - meas.total_s) / meas.total_s
        errs.append(1.0 + err)
        lines.append(row(
            f"perfmodel_fig9/M{M}_N{N}_K{K}", meas.total_s * 1e6,
            f"predicted_us={pred.total_s * 1e6:.1f};"
            f"pred_tflops={pred.tflops:.2f};meas_tflops={meas.tflops:.2f};"
            f"err={err:.3f};bound={pred.bound}"))
    gm_err = geomean(errs) - 1.0
    lines.append(row("perfmodel_fig9/geomean_error", 0.0, f"{gm_err:.3f}"))
    return lines


def main():
    for ln in sweep():
        print(ln)


if __name__ == "__main__":
    main()
