"""Paper Table 1: spatial-reuse ablation on GEMM.

TL vs "DRAM only" (spatial-reuse pass disabled: every operand loaded per-core
from DRAM; temporal hoisting still searched, as in the paper).  Reports
TFLOP/s for both, the speedup, and the DRAM-traffic reduction (paper: 2.12x
-> 1.42x shrinking as kernels become compute-bound; avg -70% DRAM accesses).
"""
from __future__ import annotations

from repro.core import get_hw

from .common import DEFAULT_BUDGET, row, tl_gemm


def sweep():
    hw = get_hw("wormhole_8x8")
    lines = []
    reductions = []
    for n in (1024, 2048, 4096, 5120, 6144):
        with_r = tl_gemm(n, n, n, hw)
        without = tl_gemm(n, n, n, hw, spatial_reuse=False)
        sp = without.best.sim.total_s / with_r.best.sim.total_s
        dram_red = 1.0 - (with_r.best.sim.dram_bytes
                          / max(without.best.sim.dram_bytes, 1.0))
        reductions.append(dram_red)
        lines.append(row(
            f"spatial_tbl1/M=K=N={n}", with_r.best.sim.total_s * 1e6,
            f"tl_tflops={with_r.best.sim.tflops:.2f};"
            f"dram_only_tflops={without.best.sim.tflops:.2f};"
            f"speedup={sp:.2f};dram_reduction={dram_red:.2f}"))
    avg = sum(reductions) / len(reductions)
    lines.append(row("spatial_tbl1/avg_dram_reduction", 0.0, f"{avg:.2f}"))
    return lines


def main():
    for ln in sweep():
        print(ln)


if __name__ == "__main__":
    main()
