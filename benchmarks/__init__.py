# One benchmark per paper table/figure; `python -m benchmarks.run` runs all.
