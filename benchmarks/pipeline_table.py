"""Kernel-graph pipeline suite: co-planned chains vs DRAM-handoff baseline.

TileLoom's headline claim is that spatial accelerators win by forwarding
operands over the on-chip network and distributed memories instead of
round-tripping through global memory — and the biggest unexploited instance
of that is *between* kernels: a producer -> consumer edge planned
independently pays a full DRAM store + reload for the intermediate.  This
suite measures what graph-level co-planning (``repro.pipeline``) buys on
three chained-kernel families:

* **mlp2**    — two chained GEMMs (the transformer MLP), activation ``Y``
  forwarded;
* **attn**    — the unfused attention chain ``S = Q K^T`` ->
  ``O = softmax(S) V``, score matrix ``S`` forwarded;
* **moe_ffn** — the gate-routed MoE expert FFN (grouped up- and
  down-projection), hidden ``H`` forwarded.

Every cell is planned twice: co-planned with forwarding enabled (the
default ``SearchBudget``) and with ``pipeline_forwarding=False`` — fully
independent per-kernel plans where every edge spills, whose end-to-end time
is by construction the sum of the standalone kernel simulations.  The CSV
reports both times, the ``dram_roundtrip_us`` the spill baseline pays per
edge, and the improvement ratio; ``benchmarks/plan_speed.py`` embeds the
same cells into ``BENCH_plan_speed.json`` and gates their graph-plan
selections through the golden check.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Callable, Dict, Iterator, List, Tuple

from repro.core import SearchBudget, get_hw
from repro.pipeline import (PipelineGraph, attn_qk_pv_graph, mlp2_graph,
                            moe_ffn_graph, plan_pipeline)

from .common import geomean, row

HW_NAME = "wormhole_8x8"
PIPELINE_BUDGET = SearchBudget(top_k=4, max_plans_per_mapping=48,
                               max_candidates=8000)

# block-shape candidate lists per family (kept small on purpose — the suite
# plans every cell twice, and the per-node pools search each shape's space)
GEMM_BLOCKS = ((64, 64, 64), (128, 128, 64), (128, 64, 128),
               (128, 128, 128))
ATTN_BLOCKS = ((64, 64), (128, 128), (128, 256), (256, 128))

MLP2 = ((16384, 128, 512), (32768, 128, 512))
ATTN = ((8, 4096, 1024, 64), (8, 2048, 2048, 64))
MOE_FFN = ((8, 2048, 128, 512), (8, 1024, 128, 512))


def cells() -> List[Tuple[str, Callable[[], PipelineGraph]]]:
    """(cell name, graph factory) pairs for the 6-cell suite."""
    out: List[Tuple[str, Callable[[], PipelineGraph]]] = []
    for M, D, F in MLP2:
        out.append((
            f"mlp2/M{M}_d{D}_f{F}",
            lambda M=M, D=D, F=F: mlp2_graph(M, D, F, blocks=GEMM_BLOCKS)))
    for H, Sq, Skv, Dh in ATTN:
        out.append((
            f"attn/h{H}_q{Sq}_kv{Skv}_d{Dh}",
            lambda H=H, Sq=Sq, Skv=Skv, Dh=Dh: attn_qk_pv_graph(
                H, Sq, Skv, Dh, blocks=ATTN_BLOCKS)))
    for E, C, Dm, Df in MOE_FFN:
        out.append((
            f"moe_ffn/e{E}_c{C}_{Dm}x{Df}",
            lambda E=E, C=C, Dm=Dm, Df=Df: moe_ffn_graph(
                E, C, Dm, Df, blocks=GEMM_BLOCKS)))
    return out


def plan_cells(workers: int = 1, hw_name: str = HW_NAME) -> Iterator[tuple]:
    """Yield ``(name, co_planned, independent)`` GraphPlans per cell.

    The baseline run disables only the forwarding decisions
    (``pipeline_forwarding=False``); node pools, budget, and the graph
    composition are otherwise identical, so the delta is purely the
    inter-kernel on-chip handoff."""
    hw = get_hw(hw_name)
    budget = replace(PIPELINE_BUDGET, workers=workers)
    base_budget = replace(budget, pipeline_forwarding=False)
    for name, mk in cells():
        co = plan_pipeline(mk(), hw, budget=budget)
        base = plan_pipeline(mk(), hw, budget=base_budget)
        yield name, co, base


def sweep(workers: int = 1) -> Tuple[List[str], Dict[str, float]]:
    lines: List[str] = []
    improvements: List[float] = []
    forwarded = 0
    for name, co, base in plan_cells(workers=workers):
        imp = base.total_s / co.total_s if co.total_s > 0 else 0.0
        improvements.append(imp)
        forwarded += co.n_forwarded() > 0
        lines.append(row(
            f"pipeline/{name}", co.total_s * 1e6,
            f"dram_roundtrip_us={base.total_s * 1e6:.2f};"
            f"edge_roundtrip_us={co.dram_roundtrip_s * 1e6:.2f};"
            f"improvement={imp:.3f};"
            f"fwd={co.n_forwarded()}/{len(co.decisions)};"
            f"plan={co.describe().replace(',', ' ')}"))
    summary = {
        "sim_improvement_geomean": geomean(improvements),
        "n_cells": len(improvements),
        "n_forwarded_best": forwarded,
        "n_improved_20pct": sum(1 for i in improvements if i >= 1.20),
    }
    lines.append(row(
        "pipeline/geomean", 0.0,
        f"sim_improvement={summary['sim_improvement_geomean']:.3f};"
        f"forwarded_best={forwarded}/{len(improvements)};"
        f"improved_20pct={summary['n_improved_20pct']}"))
    return lines, summary


def main(full: bool = False, cache=None) -> Dict[str, float]:
    """``full``/``cache`` accepted for run.py uniformity; the suite always
    re-plans cold (it compares two plan spaces, which a shared cache would
    simply serve back)."""
    lines, summary = sweep()
    for ln in lines:
        print(ln)
    return summary


if __name__ == "__main__":
    main()
