"""Plan-service smoke suite: the CI gate for the deadline contract.

Cold-cache pass: every request must return a valid plan, never raise,
and finish within the deadline plus one rung-check of slack; no request
may claim a rung-1 hit (the store starts empty).  After draining the
background completions, the second identical pass must be 100% rung-1
exact hits — background completion working end to end.

Exit status 0 = all assertions hold; 1 otherwise (CI
``planservice-smoke`` lane).  Prints the rung distribution as JSON.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

from repro.core import (block_shape_candidates, flash_attention_program,
                        get_hw, matmul_program)

GEMM_SHAPES = ((256, 256, 256), (512, 512, 256), (512, 256, 512),
               (1024, 512, 256))
FLASH = (4, 1024, 64)      # (batch*heads, seq, head_dim)


def build_requests(hw, budget_ms):
    from repro.planservice import PlanRequest
    reqs = []
    for M, N, K in GEMM_SHAPES:
        progs = [matmul_program(M, N, K, bm=bm, bn=bn, bk=bk)
                 for bm, bn, bk in block_shape_candidates(M, N, K)]
        reqs.append((f"gemm/M{M}_N{N}_K{K}",
                     PlanRequest(progs, hw, budget_ms=budget_ms)))
    bh, seq, hd = FLASH
    progs = [flash_attention_program(bh, seq, seq, hd, bq=bq, bkv=bkv)
             for bq in (32, 64) for bkv in (32, 64)]
    reqs.append((f"flash/h{bh}_s{seq}",
                 PlanRequest(progs, hw, budget_ms=budget_ms)))
    return reqs


def run(budget_ms: float, slack_ms: float) -> int:
    from repro import plancache
    from repro.plancache.validate import validate_plan
    from repro.planservice import PlanService

    hw = get_hw("wormhole_1x8")
    old = os.environ.get(plancache.ENV_DIR)
    tmp = tempfile.mkdtemp(prefix="planservice_smoke_")
    os.environ[plancache.ENV_DIR] = tmp
    plancache.reset_store()
    failures = []
    dist = {"cold": {}, "warm": {}}
    try:
        svc = PlanService()
        # ---- pass 1: cold cache -----------------------------------------
        cold = []
        for name, req in build_requests(hw, budget_ms):
            resp = svc.resolve(req)
            cold.append((name, resp))
            dist["cold"][resp.rung] = dist["cold"].get(resp.rung, 0) + 1
            if not resp.ok:
                failures.append(f"cold {name}: no plan ({resp.outcome})")
                continue
            if validate_plan(resp.plan, resp.hw):
                failures.append(f"cold {name}: plan fails validation")
            if resp.rung == "cache":
                failures.append(f"cold {name}: rung-1 hit on an empty store")
            if resp.seconds * 1e3 > budget_ms + slack_ms:
                failures.append(
                    f"cold {name}: {resp.seconds * 1e3:.1f}ms exceeds "
                    f"deadline {budget_ms}ms + slack {slack_ms}ms")
        # ---- background completion --------------------------------------
        if not svc.drain(timeout_s=300.0):
            failures.append("drain: background completions did not finish")
        # ---- pass 2: warmed by background publishes ---------------------
        for name, req in build_requests(hw, budget_ms):
            resp = svc.resolve(req)
            dist["warm"][resp.rung] = dist["warm"].get(resp.rung, 0) + 1
            if resp.rung != "cache":
                failures.append(f"warm {name}: rung {resp.rung}, expected "
                                f"a rung-1 exact hit after drain")
    finally:
        if old is None:
            os.environ.pop(plancache.ENV_DIR, None)
        else:
            os.environ[plancache.ENV_DIR] = old
        plancache.reset_store()

    print(json.dumps({"budget_ms": budget_ms, "slack_ms": slack_ms,
                      "rungs": dist, "failures": failures}, indent=1))
    for f in failures:
        print(f"planservice_smoke: FAIL {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    # default above the service's 10ms production deadline: keying + probe
    # cost ~5ms alone on a 1-core CI runner, and the gate must assert the
    # warm pass is 100% rung-1 without scheduler-jitter flakes
    ap.add_argument("--budget-ms", type=float, default=50.0)
    ap.add_argument("--slack-ms", type=float, default=150.0,
                    help="one rung-check granularity: a rung that starts "
                         "just inside the deadline may finish this far "
                         "past it")
    args = ap.parse_args()
    sys.exit(run(args.budget_ms, args.slack_ms))
