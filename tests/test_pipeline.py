"""Kernel-graph pipeline planner: graph IR, forwarding legality, the
fwd-off reproduction property, scalar/batch bit-identity on forwarded
simulations, graph B&B exactness, schema-v3 cache behavior, and the
lowering specs (DESIGN_PIPELINE.md)."""
import json
from dataclasses import replace

import pytest

from repro.core import (SearchBudget, get_hw, matmul_program, simulate)
from repro.core.batch_cost import HAVE_NUMPY, simulate_plans
from repro.core.reuse import ForwardLeg
from repro.pipeline import (PipelineEdge, PipelineGraph, PipelineNode,
                            attn_qk_pv_graph, forward_spec, graph_from_spec,
                            mlp2_graph, moe_ffn_graph, plan_pipeline)
from repro.pipeline.planner import node_candidate_pool

HW = get_hw("wormhole_8x8")
BUDGET = SearchBudget(top_k=3, max_mappings=24, max_plans_per_mapping=12,
                      max_candidates=2000, max_per_load=6, workers=1)
SMALL_BLOCKS = ((64, 64, 64), (128, 128, 64), (128, 64, 128))


def small_graph():
    return mlp2_graph(4096, 128, 256, blocks=SMALL_BLOCKS)


# --------------------------------------------------------------- graph IR
def test_graph_validation_rejects_bad_graphs():
    g = small_graph()
    g.validate()                                    # the builder validates
    with pytest.raises(ValueError, match="duplicate node"):
        PipelineGraph("bad", (g.nodes[0], g.nodes[0]), ()).validate()
    with pytest.raises(ValueError, match="topological"):
        PipelineGraph("bad", (g.nodes[1], g.nodes[0]),
                      (PipelineEdge("up", "down", "Y"),)).validate()
    with pytest.raises(ValueError, match="unknown node"):
        PipelineGraph("bad", g.nodes,
                      (PipelineEdge("up", "nope", "Y"),)).validate()
    # consumer loading the tensor at a different logical shape
    bad_down = (matmul_program(4096, 128, 512, bm=64, bn=64, bk=64,
                               tensor_names=("Y", "W2", "Z")),)
    with pytest.raises(ValueError, match="disagrees"):
        PipelineGraph("bad", (g.nodes[0], PipelineNode("down", bad_down)),
                      (PipelineEdge("up", "down", "Y"),)).validate()


def test_graph_validation_rejects_tensor_fanout():
    """One intermediate leaving a producer on several edges would make the
    per-edge forward/spill decisions ambiguous (legs are keyed by tensor
    name within a node) — rejected at validation, never mispriced."""
    g = small_graph()
    third = PipelineNode("down2", g.nodes[1].programs)
    with pytest.raises(ValueError, match="multiple edges"):
        PipelineGraph("bad", g.nodes + (third,),
                      (PipelineEdge("up", "down", "Y"),
                       PipelineEdge("up", "down2", "Y"))).validate()


def test_graph_from_spec():
    assert graph_from_spec("mlp2:1024x128x256").name.startswith("mlp2_")
    assert graph_from_spec("attn:8x512x512x64").name.startswith("attn_")
    assert graph_from_spec("moe:4x512x128x256").name.startswith("moe_ffn_")
    with pytest.raises(ValueError, match="unknown pipeline graph kind"):
        graph_from_spec("nope:1x2x3")
    with pytest.raises(ValueError, match="needs 3"):
        graph_from_spec("mlp2:1x2")
    with pytest.raises(ValueError, match="malformed"):
        graph_from_spec("mlp2")


# ------------------------------------------------- forwarding legality
def test_forward_spec_legality_and_shuffle():
    g = small_graph()
    pools = [node_candidate_pool(list(n.programs), HW, BUDGET)
             for n in g.nodes]
    edge = g.edges[0]
    specs = [(pc, cc, forward_spec(g, edge, pc.plan, cc.plan, HW))
             for pc in pools[0] for cc in pools[1]]
    legal = [(pc, cc, sp) for pc, cc, sp in specs if sp is not None]
    assert legal, "at least one candidate pair must be forwardable"
    for pc, cc, sp in legal:
        st = g.edge_store(edge, pc.plan.program)
        ld = g.edge_load(edge, cc.plan.program)
        assert st.tile_shape == ld.tile_shape       # tiling legality
        assert sp.resident_bytes > 0
        assert sp.aligned == (not sp.shuffle_axes)
    for pc, cc, sp in specs:
        if sp is None:
            st = g.edge_store(edge, pc.plan.program)
            ld = g.edge_load(edge, cc.plan.program)
            reasons = (
                st.tile_shape != ld.tile_shape
                or any(s.reduce_axes for s in pc.plan.stores
                       if s.access.tensor.name == edge.tensor)
                or any(c.bcast_axes for c in cc.plan.loads
                       if c.access.tensor.name == edge.tensor)
                or pc.plan.buffer_bytes() + sp_resident(g, edge, pc)
                > HW.local_capacity()
                or cc.plan.buffer_bytes() + sp_resident(g, edge, pc)
                > HW.local_capacity())
            assert reasons, "illegal spec must have a legality reason"


def sp_resident(g, edge, pc):
    from repro.core.reuse import forward_resident_bytes
    return forward_resident_bytes(g.edge_store(edge, pc.plan.program),
                                  pc.plan.mapping)


def test_capacity_overflow_spills():
    """An intermediate too large to stay resident next to the working
    buffers must make every pair non-forwardable."""
    g = mlp2_graph(65536, 128, 4096, blocks=((128, 128, 128),))
    pools = [node_candidate_pool(list(n.programs), HW, BUDGET)
             for n in g.nodes]
    # Y = 64Ki x 4Ki bf16 = 512 MB >> 64 cores x 1.5 MB L1
    for pc in pools[0]:
        for cc in pools[1]:
            assert forward_spec(g, g.edges[0], pc.plan, cc.plan, HW) is None
    gp = plan_pipeline(g, HW, budget=BUDGET)
    assert gp.n_forwarded() == 0


# --------------------------------- fwd-off reproduces independent plans
def test_forwarding_disabled_reproduces_independent_plans():
    """The satellite property: ``pipeline_forwarding=False`` must select
    exactly the standalone per-kernel winners and its graph time must equal
    the sum of the standalone simulations (independent plans + the DRAM
    handoff both already price)."""
    g = small_graph()
    base = plan_pipeline(g, HW,
                         budget=replace(BUDGET, pipeline_forwarding=False))
    pools = [node_candidate_pool(list(n.programs), HW, BUDGET)
             for n in g.nodes]
    assert all(not d.forwarded for d in base.decisions)
    for node, pool in zip(g.nodes, pools):
        assert base.nodes[node.name].plan == pool[0].plan
        assert base.node_sims[node.name] == pool[0].sim
    assert base.total_s == sum(p[0].sim.total_s for p in pools)
    assert base.total_s == base.baseline_s
    assert base.dram_roundtrip_s > 0


def test_forwarding_improves_or_matches():
    g = small_graph()
    co = plan_pipeline(g, HW, budget=BUDGET)
    base = plan_pipeline(g, HW,
                         budget=replace(BUDGET, pipeline_forwarding=False))
    assert co.total_s <= base.total_s
    assert co.baseline_s == base.total_s


# ------------------------------------------- scalar/batch bit-identity
@pytest.mark.skipif(not HAVE_NUMPY, reason="batch engine needs numpy")
def test_batch_equals_scalar_on_forwarded_sims():
    g = small_graph()
    gp = plan_pipeline(g, HW, budget=BUDGET)
    pools = [node_candidate_pool(list(n.programs), HW, BUDGET)
             for n in g.nodes]
    edge = g.edges[0]
    checked = 0
    for pc in pools[0]:
        for cc in pools[1]:
            sp = forward_spec(g, edge, pc.plan, cc.plan, HW)
            legsets = [None, {edge.tensor: ForwardLeg(edge.tensor, "free")}]
            if sp is not None:
                legsets += [{edge.tensor: sp.send_leg()}]
            for plan, extra in ((pc.plan, legsets),
                                (cc.plan,
                                 [None,
                                  {edge.tensor: ForwardLeg(edge.tensor,
                                                           "free")}]
                                 + ([{edge.tensor: sp.recv_leg()},
                                     {edge.tensor: ForwardLeg(
                                         edge.tensor, "recv",
                                         ("x", "y"))}]
                                    if sp is not None else []))):
                for legs in extra:
                    s = simulate(plan, HW, fwd=legs)
                    b = simulate_plans([plan], HW, fwd=[legs])[0]
                    assert s == b                   # bit-identical
                    checked += 1
    assert checked > 4


def test_free_leg_floor_is_monotone():
    """The graph bound's free-leg simulation must lower-bound every
    realizable edge handling (spill and forward, aligned or shuffled)."""
    g = small_graph()
    pools = [node_candidate_pool(list(n.programs), HW, BUDGET)
             for n in g.nodes]
    edge = g.edges[0]
    for pool, mk_leg in ((pools[0], lambda sp: sp.send_leg()),
                         (pools[1], lambda sp: sp.recv_leg())):
        for cand in pool:
            free = simulate(cand.plan, HW,
                            fwd={edge.tensor: ForwardLeg(edge.tensor,
                                                         "free")})
            assert free.total_s <= cand.sim.total_s     # <= spilled
    for pc in pools[0]:
        for cc in pools[1]:
            sp = forward_spec(g, edge, pc.plan, cc.plan, HW)
            if sp is None:
                continue
            for plan, leg in ((pc.plan, sp.send_leg()),
                              (cc.plan, sp.recv_leg())):
                free = simulate(plan, HW,
                                fwd={edge.tensor: ForwardLeg(edge.tensor,
                                                             "free")})
                fwd = simulate(plan, HW, fwd={edge.tensor: leg})
                assert free.total_s <= fwd.total_s      # <= forwarded


# ------------------------------------------- analytic model fwd pricing
def test_estimate_with_forward_legs():
    """The analytic model's forwarded pricing (`estimate(fwd=)` /
    `forward_transfer`): a forwarded edge access contributes zero DRAM
    bytes, a shuffled recv contributes NoC bytes, and a free leg nothing."""
    from repro.core import estimate
    g = small_graph()
    pools = [node_candidate_pool(list(n.programs), HW, BUDGET)
             for n in g.nodes]
    edge = g.edges[0]
    pc, cc = pools[0][0], pools[1][0]
    store = g.edge_store(edge, pc.plan.program)
    load = g.edge_load(edge, cc.plan.program)

    base = estimate(pc.plan, HW)
    fwd = estimate(pc.plan, HW, fwd={edge.tensor: ForwardLeg(edge.tensor,
                                                             "send")})
    # the send leg removes exactly the store's DRAM bytes
    removed = store.tile_bytes * pc.plan.mapping.active_cores() \
        * [s for s in pc.plan.stores
           if s.access.tensor.name == edge.tensor][0].issues_per_core
    assert base.dram_bytes - fwd.dram_bytes == removed
    free = estimate(pc.plan, HW, fwd={edge.tensor: ForwardLeg(edge.tensor,
                                                              "free")})
    assert free.dram_bytes == fwd.dram_bytes
    assert free.total_s <= fwd.total_s

    cbase = estimate(cc.plan, HW)
    crecv = estimate(cc.plan, HW,
                     fwd={edge.tensor: ForwardLeg(edge.tensor, "recv",
                                                  ("x",))})
    ld = [c for c in cc.plan.loads
          if c.access.tensor.name == edge.tensor][0]
    removed = load.tile_bytes * cc.plan.mapping.active_cores() \
        * ld.hoist.tiles_per_issue * ld.hoist.issues_per_core
    assert cbase.dram_bytes - crecv.dram_bytes == removed
    assert crecv.noc_bytes > cbase.noc_bytes       # the re-shuffle leg


# --------------------------------------------------- graph B&B exactness
def test_graph_bnb_equals_exhaustive():
    for g in (small_graph(),
              attn_qk_pv_graph(4, 512, 512, 64,
                               blocks=((64, 64), (128, 128)))):
        bnb = plan_pipeline(g, HW, budget=BUDGET, use_bound=True)
        ex = plan_pipeline(g, HW, budget=BUDGET, use_bound=False)
        assert bnb.total_s == ex.total_s
        assert bnb.describe() == ex.describe()
        assert bnb.n_graph_pruned > 0 or bnb.n_graph_combos \
            == ex.n_graph_combos


# ------------------------------------------------------- plancache (v3)
def test_graph_cache_roundtrip(tmp_path, monkeypatch):
    from repro import plancache
    from repro.plancache.store import PlanCacheStore
    store = PlanCacheStore(root=tmp_path / "cache")
    cache = plancache.PlanCache(store)
    g = small_graph()
    gp = plan_pipeline(g, HW, budget=BUDGET, cache=cache)
    import repro.core.planner as P
    calls = dict(P.PLAN_CALLS)
    hit = plan_pipeline(g, HW, budget=BUDGET, cache=cache)
    assert P.PLAN_CALLS == calls            # zero planner invocations
    assert hit.total_s == gp.total_s
    assert hit.describe() == gp.describe()
    assert [d.forwarded for d in hit.decisions] \
        == [d.forwarded for d in gp.decisions]
    # a different budget (forwarding off) must not collide
    miss = cache.get_graph_result(
        g, HW, replace(BUDGET, pipeline_forwarding=False))
    assert miss is None


def test_v2_entries_read_as_misses_under_v3(tmp_path):
    """Schema compat: entries written under schema v2 (pre-pipeline layout)
    must read as misses under v3 — never deserialize, never crash."""
    from repro import plancache
    from repro.plancache.store import PlanCacheStore
    assert plancache.keying.SCHEMA_VERSION >= 3
    store = PlanCacheStore(root=tmp_path / "cache")
    cache = plancache.PlanCache(store)
    g = small_graph()
    key = plancache.keying.graph_key(g, HW, BUDGET)
    store.put(key, {"graph": {"arbitrary": "v2 payload"}}, {"template": "t"})
    p = store._path(key)
    data = json.loads(p.read_text())
    data["schema"] = 2                      # a real pre-bump entry
    p.write_text(json.dumps(data))
    store.clear_memory()
    misses = store.stats.misses
    assert cache.get_graph_result(g, HW, BUDGET) is None
    assert store.stats.misses == misses + 1


def test_graph_key_composition():
    from repro.plancache import keying
    g = small_graph()
    k1 = keying.graph_key(g, HW, BUDGET)
    # structurally identical graph -> identical key (content addressing)
    g2 = PipelineGraph(g.name, g.nodes,
                       (PipelineEdge("up", "down", "Y"),))
    assert keying.graph_key(g2, HW, BUDGET) == k1
    g3 = PipelineGraph(g.name, g.nodes, ())
    assert keying.graph_key(g3, HW, BUDGET) != k1
    # budget knob flips the key
    assert keying.graph_key(
        g, HW, replace(BUDGET, pipeline_forwarding=False)) != k1
    # node keys compose: a changed candidate list changes the graph key
    g4 = PipelineGraph(g.name,
                       (PipelineNode("up", g.nodes[0].programs[:1]),
                        g.nodes[1]), g.edges)
    assert keying.graph_key(g4, HW, BUDGET) != k1


def test_graph_plan_serialization_roundtrip():
    from repro.plancache import serialize
    g = small_graph()
    gp = plan_pipeline(g, HW, budget=BUDGET)
    d = json.loads(json.dumps(serialize.graph_plan_to_dict(gp)))
    back = serialize.graph_plan_from_dict(d)
    assert back.total_s == gp.total_s
    assert back.baseline_s == gp.baseline_s
    assert back.describe() == gp.describe()
    assert back.node_sims == gp.node_sims
    for a, b in zip(back.decisions, gp.decisions):
        assert a == b


# ------------------------------------------------------- lowering specs
def test_fused_pipeline_spec():
    from repro.core import lower_jax
    g = small_graph()
    co = plan_pipeline(g, HW, budget=BUDGET)
    spec = lower_jax.fused_pipeline_spec(co)
    if co.n_forwarded():
        assert len(spec["segments"]) == 1
        seg = spec["segments"][0]
        assert seg["nodes"] == ["up", "down"]
        assert seg["scratch"] == ["Y"]
        assert spec["materialized"] == []
    base = plan_pipeline(g, HW,
                         budget=replace(BUDGET, pipeline_forwarding=False))
    spec = lower_jax.fused_pipeline_spec(base)
    assert [s["nodes"] for s in spec["segments"]] == [["up"], ["down"]]
    assert spec["materialized"] == ["Y"]


def test_fused_pipeline_spec_materializes_cross_segment_edges():
    """A forwarded skip-edge whose chain was cut by a spill (endpoints in
    different segments) cannot ride a scratch ref across pallas_call
    boundaries — it must materialize, never vanish from the spec."""
    from types import SimpleNamespace
    from repro.core import lower_jax
    from repro.pipeline.planner import EdgeDecision
    gp = SimpleNamespace(
        nodes={"a": None, "b": None, "c": None},
        decisions=(EdgeDecision("a", "b", "T1", forwarded=False),
                   EdgeDecision("b", "c", "T2", forwarded=False),
                   EdgeDecision("a", "c", "T3", forwarded=True)))
    spec = lower_jax.fused_pipeline_spec(gp)
    assert [s["nodes"] for s in spec["segments"]] == [["a"], ["b"], ["c"]]
    assert sorted(spec["materialized"]) == ["T1", "T2", "T3"]


def test_lower_forwarded_edge():
    from repro.parallel.planner_bridge import lower_forwarded_edge
    from repro.pipeline.planner import EdgeDecision
    fwd = lower_forwarded_edge(EdgeDecision(
        "up", "down", "Y", forwarded=True, shuffle_axes=("x",)))
    assert fwd["placement"] == "resident"
    assert fwd["collectives"] == [{"axis": "x", "collective": "all_to_all"}]
    spill = lower_forwarded_edge(EdgeDecision("up", "down", "Y",
                                              forwarded=False))
    assert spill["placement"] == "offload" and spill["collectives"] == []


# ------------------------------------------------ node-pool sharding
def test_node_pools_sharded_matches_inline():
    from repro.parallel import search_exec
    g = small_graph()
    program_lists = [list(n.programs) for n in g.nodes]
    inline = [node_candidate_pool(p, HW, BUDGET) for p in program_lists]
    sharded = search_exec.plan_node_pools(program_lists, HW, BUDGET,
                                          engine=None, workers=2)
    assert sharded is not None
    for a, b in zip(inline, sharded):
        assert len(a) == len(b)
        for ca, cb in zip(a, b):
            assert ca.plan == cb.plan
            assert ca.cost == cb.cost
            assert ca.sim == cb.sim


# ----------------------------------------------------------- moe chain
def test_moe_ffn_graph_forwards():
    g = moe_ffn_graph(4, 512, 128, 256,
                      blocks=((64, 64, 64), (128, 128, 128)))
    co = plan_pipeline(g, HW, budget=BUDGET)
    base = plan_pipeline(g, HW,
                         budget=replace(BUDGET, pipeline_forwarding=False))
    assert co.total_s <= base.total_s
