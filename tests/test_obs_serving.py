"""Serving-layer observability contracts (DESIGN_OBS.md, PR 10):
request-correlation IDs, the bounded flight recorder and its incident
renderer, the sliding-window SLO/burn-rate tracker, and the Prometheus
exposition + introspection HTTP endpoint.

Everything here is stdlib-only plumbing — no planner, no jax — so the
file runs in milliseconds and pins the contracts the serve driver and
the CI smoke lane build on."""
import json
import urllib.error
import urllib.request

import pytest

from repro.obs import context, expo, flightrec, metrics, slo


@pytest.fixture(autouse=True)
def _clean_serving_obs():
    """The recorder and tracker are process-global singletons."""
    flightrec.disable()
    flightrec.clear()
    slo.disable()
    slo.clear()
    yield
    flightrec.disable()
    flightrec.clear()
    flightrec.RECORDER.path = None       # undo any armed dump destination
    flightrec.RECORDER.capacity = flightrec.DEFAULT_CAPACITY
    slo.disable()
    slo.clear()


# ------------------------------------------------------------------ context
def test_context_default_and_mint():
    assert context.current() is None
    with context.correlate("req") as rid:
        assert context.current() == rid
        assert rid.startswith("req-")
    assert context.current() is None


def test_context_nested_reuses_enclosing_id():
    with context.correlate("incident") as outer:
        with context.correlate("plan") as inner:
            assert inner == outer          # nested work stays on the incident
        assert context.current() == outer


def test_context_explicit_rid_and_attach():
    with context.correlate(rid="forced-1") as rid:
        assert rid == "forced-1"
    token = context.attach("worker-7")
    assert context.current() == "worker-7"
    context.detach(token)
    assert context.current() is None
    assert context.new_id("a") != context.new_id("a")


# ---------------------------------------------------------------- flightrec
def test_flightrec_off_by_default_records_nothing():
    flightrec.record("fault", cause="core_kill")
    assert flightrec.events() == []


def test_flightrec_stamps_and_normalizes():
    flightrec.enable()
    with context.correlate("incident") as rid:
        flightrec.record("fault", cause="core_kill", cores=[(0, 0)],
                         extra={"k": (1, 2)}, obj=object())
    [ev] = flightrec.events()
    assert ev["kind"] == "fault" and ev["rid"] == rid and ev["seq"] == 1
    assert ev["t"] > 0
    assert ev["cores"] == [[0, 0]]         # copy-normalized, JSON-safe
    assert ev["extra"] == {"k": [1, 2]}
    assert isinstance(ev["obj"], str)


def test_flightrec_ring_bounds_and_drop_counter():
    rec = flightrec.FlightRecorder(capacity=3)
    rec.enable()
    for i in range(5):
        rec.record("plan_request", i=i)
    evs = rec.events()
    assert [e["i"] for e in evs] == [2, 3, 4]
    assert [e["seq"] for e in evs] == [3, 4, 5]
    assert rec.dropped == 2


def test_flightrec_dump_load_and_meta(tmp_path):
    rec = flightrec.FlightRecorder(capacity=8)
    rec.enable()
    rec.record("breaker", key="k", **{"from": "closed", "to": "open"})
    path = tmp_path / "dump.json"
    assert rec.dump(str(path), reason="unit") == str(path)
    assert list(tmp_path.iterdir()) == [path]      # no tmp file left behind
    doc = flightrec.load_dump(str(path))
    assert doc["meta"]["reason"] == "unit"
    assert doc["meta"]["n_events"] == 1 and doc["meta"]["capacity"] == 8
    assert doc["events"][0]["kind"] == "breaker"


def test_flightrec_load_rejects_non_dump(tmp_path):
    p = tmp_path / "x.json"
    p.write_text("{\"not\": \"a dump\"}")
    with pytest.raises(ValueError):
        flightrec.load_dump(str(p))


def test_flightrec_refresh_from_env(tmp_path, monkeypatch):
    monkeypatch.setenv(flightrec.FLIGHTREC_ENV, str(tmp_path / "fr.json"))
    monkeypatch.setenv(flightrec.CAP_ENV, "5")
    flightrec.refresh_from_env()
    assert flightrec.enabled()
    assert flightrec.RECORDER.capacity == 5
    assert flightrec.RECORDER.path == str(tmp_path / "fr.json")


def test_render_incident_groups_by_rid(tmp_path):
    rec = flightrec.FlightRecorder()
    rec.enable()
    with context.correlate("incident") as rid:
        rec.record("fault", cause="core_kill", cores=[(0, 0)])
        rec.record("containment", cause="core_kill", owner="t0",
                   rung="shrink_in_place", blast_radius=1,
                   replanned=["t0"], seconds=0.01, log=["step one"])
    rec.record("pool_failure", error="BrokenProcessPool", where="rank")
    path = tmp_path / "d.json"
    rec.dump(str(path), reason="unit")
    doc = flightrec.load_dump(str(path))

    text = flightrec.render_incident(doc)
    assert "containment=1  fault=1  pool_failure=1" in text
    assert rid in text and "(uncorrelated)" in text
    # the incident group renders before the uncorrelated tail
    assert text.index(rid) < text.index("(uncorrelated)")
    assert "rung=shrink_in_place" in text and "| step one" in text
    assert "replanned=t0" in text

    only = flightrec.render_incident(doc, rid=rid)
    assert "(uncorrelated)" not in only and rid in only
    missing = flightrec.render_incident(doc, rid="nope")
    assert "no events for rid" in missing and rid in missing


def test_incident_cli(tmp_path, capsys):
    from repro.obs.__main__ import main
    rec = flightrec.FlightRecorder()
    rec.enable()
    rec.record("qos_shed", tenant="t1", qos="best_effort")
    path = str(tmp_path / "d.json")
    rec.dump(path, reason="unit")
    assert main(["incident", path]) == 0
    out = capsys.readouterr().out
    assert "flight recorder: 1 events" in out and "tenant=t1" in out
    assert main(["incident", path, "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["events"][0]["kind"] == "qos_shed"
    assert main(["incident", str(tmp_path / "missing.json")]) == 2


# ---------------------------------------------------------------------- slo
def _tracker(**kw):
    clk = {"t": 1000.0}
    kw.setdefault("target", 0.99)
    kw.setdefault("fast_s", 60.0)
    kw.setdefault("slow_s", 600.0)
    t = slo.SLOTracker(clock=lambda: clk["t"], **kw)
    t.enable()
    return t, clk


def test_slo_attainment_windows_and_pruning():
    t, clk = _tracker()
    for i in range(8):
        t.note_request(ok=(i != 0), rung="cache")
        clk["t"] += 1.0
    rep = t.report()
    assert rep["fast"]["total"] == 8 and rep["fast"]["miss"] == 1
    assert rep["fast"]["attainment"] == pytest.approx(7 / 8)
    assert rep["rungs"] == {"cache": 8}
    clk["t"] += 700.0                     # everything ages out of slow_s
    rep = t.report()
    assert rep["slow"]["total"] == 0 and rep["fast"]["total"] == 0


def test_slo_burn_alert_needs_both_windows():
    # slow window long: early misses keep the *fast* window clean later
    t, clk = _tracker()
    # a miss burst inside the fast window: both windows burn >= 14.4
    for _ in range(5):
        t.note_request(ok=False, rung="fallback")
    assert t.alert_state == "firing" and t.transitions == 1
    # recovery: the burst ages past the fast window while successes land
    clk["t"] += 90.0
    for _ in range(200):
        t.note_request(ok=True, rung="cache")
    assert t.alert_state == "ok" and t.transitions == 2
    rep = t.report()
    assert rep["alert"]["state"] == "ok" and rep["alert"]["transitions"] == 2


def test_slo_alert_emits_flightrec_event_and_metric():
    flightrec.enable()
    c = metrics.REGISTRY.counter("slo_alert_transitions_total")
    n0 = c.total()
    t, _clk = _tracker()
    for _ in range(3):
        t.note_request(ok=False, rung="fallback")
    alerts = [e for e in flightrec.events() if e["kind"] == "slo_alert"]
    assert len(alerts) == 1               # edge-triggered, not per-request
    assert alerts[0]["state"] == "firing"
    assert alerts[0]["fast_burn"] >= t.burn_threshold
    assert c.total() == n0 + 1


def test_slo_blast_radius_per_tenant():
    t, _clk = _tracker()
    t.note_containment("t0", 1, rung="shrink_in_place")
    t.note_containment("t0", 3, rung="repartition")
    t.note_containment("t1", 2, rung="claim_adjacent")
    rep = t.report()
    assert rep["tenants"]["t0"] == {
        "incidents": 2, "blast_radius_max": 3, "blast_radius_sum": 4,
        "rungs": {"shrink_in_place": 1, "repartition": 1}}
    assert rep["tenants"]["t1"]["incidents"] == 1


def test_slo_disabled_is_noop_and_env_config(monkeypatch):
    t = slo.SLOTracker()
    t.note_request(ok=False, rung="fallback")
    assert t.report()["slow"]["total"] == 0
    monkeypatch.setenv(slo.TARGET_ENV, "0.9")
    monkeypatch.setenv(slo.FAST_ENV, "5")
    monkeypatch.setenv(slo.SLOW_ENV, "2")         # < fast: clamped up
    monkeypatch.setenv(slo.BURN_ENV, "2.5")
    t.configure_from_env()
    assert t.target == 0.9 and t.burn_threshold == 2.5
    assert t.fast_s == 5.0 and t.slow_s == 5.0


# --------------------------------------------------------------------- expo
def test_escape_label_value():
    assert expo.escape_label_value('a"b\\c\nd') == 'a\\"b\\\\c\\nd'


def test_render_prometheus_counters_and_histograms():
    snap = {
        "_meta": {"pid": 42, "start_time": 1000.0, "uptime_s": 5.0,
                  "plancache_schema": 4},
        "reqs_total": {"type": "counter", "help": "requests",
                       "series": [{"labels": {"rung": 'c"ache'},
                                   "value": 3, "rid": "req-1-1"}]},
        "lat_seconds": {"type": "histogram", "series": [{
            "labels": {}, "count": 3, "sum": 0.6, "min": 0.1, "max": 0.3,
            "buckets": {"le": [0.1, 1.0, "inf"], "counts": [1, 2, 0]}}]},
    }
    text = expo.render_prometheus(snap)
    assert expo.validate_exposition(text) == []
    assert 'reqs_total{rung="c\\"ache"} 3' in text
    assert "repro_process_pid 42" in text
    assert "repro_plancache_schema_version 4" in text
    # cumulative ladder + +Inf terminal bucket
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 3' in text
    assert 'lat_seconds_bucket{le="+Inf"} 3' in text
    assert "lat_seconds_sum 0.6" in text and "lat_seconds_count 3" in text
    assert "rid" not in text               # exemplars stay JSON-only


def test_render_prometheus_live_registry_validates():
    metrics.inc("t_serving_total", rung="cache")
    metrics.observe("t_serving_seconds", 0.01, rung="cache")
    text = expo.render_prometheus()
    assert expo.validate_exposition(text) == []
    assert "t_serving_total" in text and "t_serving_seconds_bucket" in text


def test_validate_exposition_catches_problems():
    assert expo.validate_exposition("# TYPE x counter\nx 1\n") == []
    probs = expo.validate_exposition(
        "# TYPE x banana\n"          # bad type
        "x 1\n"
        "y 2\n"                      # no TYPE line
        "z{0bad=\"v\"} 1\n")         # bad label name (and no TYPE)
    assert len(probs) >= 3


def _get(url, path):
    try:
        with urllib.request.urlopen(url + path, timeout=5) as r:
            return r.status, r.read().decode(), r.headers.get("Content-Type")
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode(), e.headers.get("Content-Type")


def test_introspection_server_endpoints():
    slo.TRACKER.enable()
    slo.note_request(ok=True, rung="cache")
    metrics.inc("t_introspect_total")
    srv = expo.IntrospectionServer(port=0)
    srv.add_provider("plans", lambda: {"entries": 7})
    srv.add_provider("/boom", lambda: 1 / 0)
    srv.start()
    try:
        code, body, ctype = _get(srv.url, "/metrics")
        assert code == 200 and ctype == expo.CONTENT_TYPE
        assert expo.validate_exposition(body) == []
        assert "t_introspect_total" in body

        code, body, _ = _get(srv.url, "/healthz")
        assert code == 200 and json.loads(body)["ok"] is True

        code, body, _ = _get(srv.url, "/slo")
        rep = json.loads(body)
        assert rep["enabled"] and rep["rungs"].get("cache", 0) >= 1

        code, body, _ = _get(srv.url, "/plans")      # normalized to /plans
        assert code == 200 and json.loads(body) == {"entries": 7}

        code, body, _ = _get(srv.url, "/")
        assert set(json.loads(body)["endpoints"]) >= {
            "/metrics", "/healthz", "/slo", "/plans"}

        code, body, _ = _get(srv.url, "/nope")
        assert code == 404 and "error" in json.loads(body)

        code, body, _ = _get(srv.url, "/boom")       # broken provider: 500
        assert code == 500 and "ZeroDivisionError" in json.loads(body)["error"]
    finally:
        srv.stop()
