"""Hypothesis property tests on the system's invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import (enumerate_mappings, estimate, get_hw, make_plan,
                        matmul_program, pipelined_loop_time)
from repro.core.affine import AffineExpr, AffineMap, distinct_points
from repro.core.reuse import (analyze_reuse, enumerate_memop_choices,
                              hoist_options)
from repro.train.grad_compress import init_residual, roundtrip

HW = get_hw("wormhole_8x8")
SETTINGS = settings(max_examples=25, deadline=None,
                    suppress_health_check=[HealthCheck.too_slow])


# ------------------------------------------------------------ affine algebra
@given(st.integers(1, 6), st.integers(1, 6), st.integers(1, 6))
@SETTINGS
def test_distinct_points_product_bound(a, b, c):
    """Distinct points never exceed the product of extents, and the
    mixed-radix fast path agrees with exact enumeration."""
    m = AffineMap.from_terms({"t": b, "x": 1})
    extents = {"t": a, "x": b, "k": c}
    n = distinct_points(m, extents, ["t", "x"])
    assert 1 <= n <= a * b
    # mixed radix (stride b, extent of x = b) -> exactly a*b distinct
    assert n == a * b


@given(st.integers(0, 50), st.integers(0, 50), st.integers(2, 64))
@SETTINGS
def test_affine_substitution_is_evaluation_consistent(t, x, s):
    g = AffineExpr.linear({"t": s, "x": 1})
    m = AffineMap.from_terms({"g": 2}, {"k": 1}).substitute("g", g)
    direct = (2 * (t * s + x), 0)
    assert m.evaluate({"t": t, "x": x, "k": 0}) == direct


# ------------------------------------------------------- mapping invariants
@given(st.sampled_from([256, 512, 1024, 2048]),
       st.sampled_from([256, 512, 1024, 2048]),
       st.sampled_from([256, 1024]))
@SETTINGS
def test_mapping_covers_grid(M, N, K):
    """Every mapping covers the full logical grid: spatial_factor x
    wave_extent >= extent per grid dim, with utilization in (0, 1]."""
    prog = matmul_program(M, N, K, bm=64, bn=64, bk=64)
    for m in enumerate_mappings(prog, HW)[:32]:
        for d in prog.grid_dims:
            assert m.spatial_factor(d.name) * m.wave_extent(d.name) >= d.extent
        assert 0.0 < m.utilization() <= 1.0


@given(st.sampled_from([512, 1024, 4096]), st.sampled_from([512, 2048]))
@SETTINGS
def test_hoisting_traffic_monotone(M, K):
    """Hoisting outward never increases per-core traffic, and footprints
    stay within enumerated-capacity plans."""
    prog = matmul_program(M, M, K, bm=64, bn=64, bk=64)
    for m in enumerate_mappings(prog, HW)[:16]:
        for info in analyze_reuse(m, HW):
            if info.access.kind != "load":
                continue
            opts = hoist_options(info, m)
            traffic = [o.issues_per_core * o.tiles_per_issue for o in opts]
            assert all(a >= b for a, b in zip(traffic, traffic[1:]))


@given(st.sampled_from([1024, 2048]), st.sampled_from([1024, 2048]))
@SETTINGS
def test_capacity_pruning_invariant(M, N):
    """Every enumerated plan's buffer footprint fits L1."""
    prog = matmul_program(M, N, 1024, bm=128, bn=128, bk=64)
    for m in enumerate_mappings(prog, HW)[:8]:
        for loads in enumerate_memop_choices(m, HW)[:16]:
            plan = make_plan(m, loads, HW)
            assert plan.buffer_bytes() <= HW.local_capacity()


# --------------------------------------------------------- perf model sanity
@given(st.integers(1, 64), st.floats(1e-7, 1e-3), st.floats(1e-7, 1e-3),
       st.floats(1e-7, 1e-3))
@SETTINGS
def test_pipeline_formula_bounds(I, tl, ts, tc):
    """Pipelined time is within [max-term lower bound, serial upper bound]."""
    t = pipelined_loop_time(I, tl, ts, tc)
    serial = I * (tl + tc + ts)
    lower = max(I * tc, I * (tl + ts)) if I >= 2 else tl + tc + ts
    assert t <= serial + 1e-12
    assert t >= lower * 0.5            # steady-state dominance


@given(st.sampled_from([512, 1024, 2048]))
@SETTINGS
def test_estimate_positive_and_flops_exact(n):
    prog = matmul_program(n, n, n, bm=64, bn=64, bk=64)
    m = enumerate_mappings(prog, HW)[0]
    loads = enumerate_memop_choices(m, HW)[0]
    cost = estimate(make_plan(m, loads, HW), HW)
    assert cost.total_s > 0
    # padded grids may overcount, never undercount
    assert cost.flops >= 2 * n ** 3 * 0.999


# ------------------------------------------------------ gradient compression
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=4,
                max_size=32))
@SETTINGS
def test_compression_bounded_error(vals):
    g = {"w": jnp.asarray(np.array(vals, np.float32))}
    res = init_residual(g)
    deq, new_res = roundtrip(g, res)
    scale = max(abs(v) for v in vals) / 127.0 if any(vals) else 0.0
    err = np.abs(np.asarray(deq["w"]) - np.array(vals, np.float32))
    assert (err <= scale * 0.5 + 1e-6).all()       # within half a quantum
    # residual carries exactly the quantization error
    np.testing.assert_allclose(np.asarray(new_res["w"]),
                               np.array(vals, np.float32) - np.asarray(deq["w"]),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------- sharding spec
@given(st.sampled_from([(8, 16), (16, 16), (12, 16)]),
       st.sampled_from([(256, 512), (100, 512), (256, 300)]))
@SETTINGS
def test_sharding_spec_divisibility(mesh_shape, tensor_shape):
    """ShardingPlan.spec never produces a spec whose mesh axes do not divide
    the tensor dim, and never reuses a mesh axis."""
    import jax
    from repro.parallel.sharding import megatron_tp_plan
    devs = np.array(jax.devices() * math.prod(mesh_shape))[
        :math.prod(mesh_shape)].reshape(mesh_shape)
    from jax.sharding import Mesh
    mesh = Mesh(devs, ("data", "model"))
    plan = megatron_tp_plan()
    spec = plan.spec(("batch", "ffn"), tensor_shape, mesh)
    used = []
    for i, part in enumerate(spec):
        if part is None:
            continue
        axes = (part,) if isinstance(part, str) else part
        total = 1
        for a in axes:
            assert a not in used
            used.append(a)
            total *= mesh.shape[a]
        assert tensor_shape[i] % total == 0
