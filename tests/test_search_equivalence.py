"""Fast-search equivalence proofs (DESIGN_SEARCHPERF.md acceptance).

The cold-path optimizations must not change what the planner selects:

* wave-equivalence-class simulation == the wave-by-wave reference loop,
  exactly (same totals, traffic, wave counts) across ragged waves,
  broadcasts, and hoisted loads;
* branch-and-bound ranking picks the identical best/top-k as exhaustive
  ranking (every candidate estimated);
* the lower bound is admissible (never exceeds the true model cost), which
  is the property the pruning proof rests on;
* the batched (SoA / numpy) cost engine reproduces the scalar model
  bit-for-bit — estimates, simulations, and the selected top-k — and the
  process-sharded search merges to the identical top-k as the inline one
  for any worker count.
"""
import math

import pytest

try:                    # numpy is optional (the planner degrades to the
    import numpy as np  # scalar engine); only the batch tests need it
except ImportError:     # pragma: no cover - numpy ships in CI
    np = None

needs_numpy = pytest.mark.skipif(
    np is None, reason="numpy unavailable (batch engine disabled)")

from repro.core import (MappingBatch, SearchBudget, estimate,
                        flash_attention_program, get_hw, matmul_program,
                        plan_kernel, plan_kernel_multi, plan_lower_bound,
                        simulate, simulate_plans, simulate_reference)
from repro.core.plan import DataflowPlan
from repro.core.planner import _filtered_mappings, iter_plan_stream
from repro.core.program import LoopDim, TileProgram
from repro.core.reuse import memop_choices_with_stores


def _plan_grid():
    """A grid of small plans covering ragged final waves, broadcasts, and
    hoisted loads on all three mesh shapes."""
    cases = [
        # (program, hw): ragged extents force partially-active waves
        (matmul_program(320, 192, 256, bm=32, bn=32, bk=64),
         get_hw("wormhole_8x8")),
        (matmul_program(1000, 520, 260, bm=64, bn=32, bk=32),
         get_hw("wormhole_4x8")),
        (matmul_program(768, 768, 512, bm=64, bn=64, bk=64),
         get_hw("wormhole_1x8")),
        (flash_attention_program(9, 640, 640, 64, bq=64, bkv=32),
         get_hw("wormhole_8x8")),
        (flash_attention_program(64, 1024, 1024, 64, bq=64, bkv=64),
         get_hw("wormhole_4x8")),
    ]
    budget = SearchBudget(max_mappings=16, max_plans_per_mapping=10)
    for prog, hw in cases:
        n = 0
        for _, plan in iter_plan_stream(prog, hw, budget):
            yield plan, hw
            n += 1
            if n >= 25:
                break


def test_simulate_matches_reference():
    """The class-based simulator is exact: identical totals and traffic to
    the wave-by-wave loop (at stride 1) for every plan in the grid."""
    checked = broadcasts = hoisted = ragged = 0
    for plan, hw in _plan_grid():
        fast = simulate(plan, hw)
        ref = simulate_reference(plan, hw, max_waves_exact=10 ** 9)
        assert fast.total_s == pytest.approx(ref.total_s, rel=1e-12)
        assert fast.dram_bytes == pytest.approx(ref.dram_bytes, rel=1e-12)
        assert fast.noc_bytes == pytest.approx(ref.noc_bytes, rel=1e-12)
        assert fast.flops == ref.flops
        assert fast.n_waves == ref.n_waves
        assert 1 <= fast.n_wave_classes <= max(1, fast.n_waves)
        checked += 1
        broadcasts += any(c.bcast_axes for c in plan.loads)
        n_loops = len(plan.mapping.temporal) + len(plan.program.seq_dims)
        hoisted += any(c.hoist.level < n_loops for c in plan.loads)
        ragged += plan.mapping.utilization() < 1.0
    # the grid must actually exercise the features it claims to cover
    assert checked >= 50
    assert broadcasts > 0 and hoisted > 0 and ragged > 0


def test_wave_class_compression():
    """Large wave spaces collapse into a handful of classes — the reason the
    max_waves_exact sampling cut could be retired."""
    hw = get_hw("wormhole_8x8")
    res = plan_kernel(matmul_program(16384, 16384, 4096,
                                     bm=128, bn=128, bk=64), hw,
                      budget=SearchBudget(top_k=1))
    sim = res.best.sim
    assert sim.n_waves >= 256
    assert sim.n_wave_classes <= 16
    assert res.n_wave_classes == sim.n_wave_classes


def test_lower_bound_admissible():
    """plan_lower_bound(plan) <= estimate(plan).total_s in both overlap
    modes — the admissibility obligation of the branch-and-bound proof."""
    n = 0
    for plan, hw in _plan_grid():
        for pol in (False, True):
            lb = plan_lower_bound(plan, hw, pipeline_outer_levels=pol)
            cost = estimate(plan, hw, pipeline_outer_levels=pol)
            assert lb <= cost.total_s * (1 + 1e-12), plan.describe()
            assert lb > 0
            n += 1
    assert n >= 100


def _keyed(res):
    return [(c.plan.describe(), c.cost.total_s,
             c.sim.total_s if c.sim else None) for c in res.topk]


@pytest.mark.parametrize("seed_shape", [(512, 512, 512), (640, 384, 512),
                                        (1024, 1024, 1024)])
def test_bnb_matches_exhaustive_single(seed_shape):
    M, N, K = seed_shape
    hw = get_hw("wormhole_8x8")
    budget = SearchBudget(top_k=4)
    mk = lambda: matmul_program(M, N, K, bm=64, bn=64, bk=64)
    fast = plan_kernel(mk(), hw, budget=budget, use_bound=True)
    slow = plan_kernel(mk(), hw, budget=budget, use_bound=False)
    assert fast.best.plan == slow.best.plan
    assert _keyed(fast) == _keyed(slow)
    # n_candidates counts *ranked* candidates: whole-mapping pruning keeps
    # the fast path from even materializing provably-worse plans
    assert fast.n_candidates <= slow.n_candidates
    assert fast.n_estimated <= slow.n_estimated
    assert slow.n_pruned == 0 and slow.n_mappings_pruned == 0


def test_bnb_matches_exhaustive_multi():
    """Pooled block-shape search: identical best/top-k with and without
    pruning, across a seeded grid of programs."""
    hw = get_hw("wormhole_4x8")
    budget = SearchBudget(top_k=5, max_plans_per_mapping=24)
    mk = lambda: [matmul_program(768, 768, 768, bm=bm, bn=bn, bk=64)
                  for bm in (32, 64, 128) for bn in (32, 64, 128)]
    fast = plan_kernel_multi(mk(), hw, budget=budget, use_bound=True)
    slow = plan_kernel_multi(mk(), hw, budget=budget, use_bound=False)
    assert fast.best.plan == slow.best.plan
    assert _keyed(fast) == _keyed(slow)
    assert fast.n_pruned + fast.n_mappings_pruned > 0  # pruning did engage


def test_multi_counts_infeasible_and_reraises():
    hw = get_hw("wormhole_8x8")
    ok = matmul_program(512, 512, 512, bm=64, bn=64, bk=64)
    # capacity-infeasible: no memory-op combination fits the 1.5MB L1
    too_big = matmul_program(8192, 8192, 8192, bm=1024, bn=1024, bk=1024)
    res = plan_kernel_multi([too_big, ok], hw,
                            budget=SearchBudget(top_k=2), profile=False)
    assert res.n_infeasible_programs == 1
    assert any("no feasible plan" in line for line in res.log)
    assert res.best.plan.program.name == ok.name

    # a genuine bug (TypeError from a malformed program) must propagate,
    # not be swallowed as "infeasible"
    broken = TileProgram(name="broken",
                         grid_dims=(LoopDim("gx", None), LoopDim("gy", 8)),
                         seq_dims=(LoopDim("k", 8),),
                         loads=ok.loads, stores=ok.stores, body=ok.body)
    with pytest.raises(TypeError):
        plan_kernel_multi([broken, ok], hw, budget=SearchBudget(top_k=1),
                          profile=False)


def test_floor_pruned_program_is_not_infeasible():
    """A feasible program whose every mapping the compute floor skips
    (provably worse than the incumbent top-k) must not be reported as
    infeasible — pruned and infeasible are different outcomes."""
    hw = get_hw("wormhole_8x8")
    good = matmul_program(1024, 1024, 1024, bm=64, bn=64, bk=64)
    # same shape but 8x the K reduction: strictly more compute everywhere,
    # so with top_k=1 its mappings all fall below the incumbent's floor
    worse = matmul_program(1024, 1024, 8192, bm=64, bn=64, bk=64)
    res = plan_kernel_multi([good, worse], hw,
                            budget=SearchBudget(top_k=1), profile=False)
    assert res.n_infeasible_programs == 0
    assert res.log == []
    assert res.best.plan.program.name == good.name


# --------------------------------------------------------------------------
# Batched (SoA) cost engine vs the scalar oracle
# --------------------------------------------------------------------------
def _mapping_grid():
    """(mapping, stores, combos, demands, hw) cells spanning all three mesh
    shapes, both kernels, ragged grids, broadcasts, and hoisted loads."""
    cases = [
        (matmul_program(320, 192, 256, bm=32, bn=32, bk=64),
         get_hw("wormhole_8x8")),
        (matmul_program(1000, 520, 260, bm=64, bn=32, bk=32),
         get_hw("wormhole_4x8")),
        (matmul_program(768, 768, 512, bm=64, bn=64, bk=64),
         get_hw("wormhole_1x8")),
        (flash_attention_program(9, 640, 640, 64, bq=64, bkv=32),
         get_hw("wormhole_8x8")),
    ]
    budget = SearchBudget(max_mappings=16, max_plans_per_mapping=12)
    for prog, hw in cases:
        for mapping in _filtered_mappings(prog, hw, budget)[:6]:
            demands = {}
            combos, stores = memop_choices_with_stores(
                mapping, hw, max_per_load=budget.max_per_load,
                demands=demands)
            combos = combos[:12]
            if combos:
                yield mapping, stores, combos, demands, hw


@needs_numpy
def test_batch_estimates_bit_identical_to_scalar():
    """MappingBatch.estimate_rows == estimate() field-for-field (exact
    float equality, not just 1e-12): the SoA engine mirrors the scalar
    operation order, which is what makes engine choice selection-invariant.
    """
    n = 0
    for mapping, stores, combos, demands, hw in _mapping_grid():
        for pol in (False, True):
            batch = MappingBatch(mapping, stores, hw, combos,
                                 pipeline_outer_levels=pol, demands=demands)
            costs = batch.estimate_rows(np.arange(len(combos)))
            for j, combo in enumerate(combos):
                plan = DataflowPlan(mapping, combo, stores)
                ref = estimate(plan, hw, pipeline_outer_levels=pol)
                got = costs.cost(j)
                assert got == ref, (plan.describe(), pol)
                n += 1
    assert n >= 100


@needs_numpy
def test_batch_bounds_admissible_and_match_scalar():
    """Batched lower bounds stay admissible (<= the true model cost, the
    branch-and-bound obligation) and agree with the scalar BoundContext to
    1e-12 (summation-order rounding is all that may differ)."""
    n = 0
    for mapping, stores, combos, demands, hw in _mapping_grid():
        for pol in (False, True):
            batch = MappingBatch(mapping, stores, hw, combos,
                                 pipeline_outer_levels=pol, demands=demands)
            lbs = batch.lower_bounds()
            for j, combo in enumerate(combos):
                plan = DataflowPlan(mapping, combo, stores)
                ref_lb = plan_lower_bound(plan, hw,
                                          pipeline_outer_levels=pol)
                assert lbs[j] == pytest.approx(ref_lb, rel=1e-12)
                cost = estimate(plan, hw, pipeline_outer_levels=pol)
                assert lbs[j] <= cost.total_s * (1 + 1e-9)
                n += 1
    assert n >= 100


@needs_numpy
def test_simulate_plans_bit_identical_to_scalar():
    """The vectorized wave-class simulator == simulate() exactly: totals,
    traffic, wave and class counts."""
    checked = 0
    for plan, hw in _plan_grid():
        (got,) = simulate_plans([plan], hw)
        ref = simulate(plan, hw)
        assert (got.total_s, got.dram_bytes, got.noc_bytes, got.flops,
                got.n_waves, got.n_wave_classes) == \
               (ref.total_s, ref.dram_bytes, ref.noc_bytes, ref.flops,
                ref.n_waves, ref.n_wave_classes), plan.describe()
        checked += 1
    assert checked >= 50


@needs_numpy
def test_batch_engine_selects_identically_to_scalar():
    """plan_kernel / plan_kernel_multi pick the identical top-k (same
    candidate indices, same costs to the bit) under engine="batch" and
    engine="scalar"."""
    hw = get_hw("wormhole_4x8")
    budget = SearchBudget(top_k=5, max_plans_per_mapping=24)
    mk = lambda: [matmul_program(768, 768, 768, bm=bm, bn=bn, bk=64)
                  for bm in (32, 64, 128) for bn in (32, 64, 128)]
    b = plan_kernel_multi(mk(), hw, budget=budget, engine="batch")
    s = plan_kernel_multi(mk(), hw, budget=budget, engine="scalar")
    key = lambda r: [(c.plan.describe(), c.index, c.cost.total_s,
                      c.sim.total_s if c.sim else None) for c in r.topk]
    assert key(b) == key(s)
    single_b = plan_kernel(matmul_program(640, 384, 512, bm=64, bn=64,
                                          bk=64), hw, budget=budget,
                           engine="batch")
    single_s = plan_kernel(matmul_program(640, 384, 512, bm=64, bn=64,
                                          bk=64), hw, budget=budget,
                           engine="scalar")
    assert key(single_b) == key(single_s)


# --------------------------------------------------------------------------
# Process-sharded search vs inline
# --------------------------------------------------------------------------
@pytest.mark.parametrize("workers", [1, 2, 4])
def test_parallel_matches_inline(workers):
    """The sharded search merges per-chunk top-k by (cost, canonical
    index) into the exact inline result: identical candidate indices,
    identical tie-breaking, costs equal to the bit — for any worker count.
    """
    hw = get_hw("wormhole_8x8")
    mk = lambda: [matmul_program(640, 640, 512, bm=bm, bn=bn, bk=64)
                  for bm in (32, 64) for bn in (32, 64, 128)]
    inline = plan_kernel_multi(mk(), hw,
                               budget=SearchBudget(top_k=5, workers=1))
    sharded = plan_kernel_multi(mk(), hw,
                                budget=SearchBudget(top_k=5,
                                                    workers=workers))
    key = lambda r: [(c.plan.describe(), c.index, c.cost.total_s,
                      c.sim.total_s if c.sim else None) for c in r.topk]
    assert key(sharded) == key(inline)
    assert sharded.best.plan == inline.best.plan


def test_parallel_env_knob_and_infeasible_accounting(monkeypatch):
    """REPRO_PLANNER_WORKERS engages sharding; infeasible programs are
    counted identically, and planner bugs still propagate across the
    process boundary."""
    monkeypatch.setenv("REPRO_PLANNER_WORKERS", "2")
    hw = get_hw("wormhole_8x8")
    ok = matmul_program(512, 512, 512, bm=64, bn=64, bk=64)
    too_big = matmul_program(8192, 8192, 8192, bm=1024, bn=1024, bk=1024)
    res = plan_kernel_multi([too_big, ok], hw,
                            budget=SearchBudget(top_k=2), profile=False)
    assert res.n_infeasible_programs == 1
    assert any("no feasible plan" in line for line in res.log)
    assert res.best.plan.program.name == ok.name
    broken = TileProgram(name="broken",
                         grid_dims=(LoopDim("gx", None), LoopDim("gy", 8)),
                         seq_dims=(LoopDim("k", 8),),
                         loads=ok.loads, stores=ok.stores, body=ok.body)
    with pytest.raises(TypeError):
        plan_kernel_multi([broken, ok], hw, budget=SearchBudget(top_k=1),
                          profile=False)


def test_streamed_enumeration_matches_caps():
    """iter_plan_stream honors max_plans_per_mapping/max_candidates exactly
    like the historical list builder."""
    hw = get_hw("wormhole_8x8")
    prog = matmul_program(1024, 1024, 1024, bm=64, bn=64, bk=64)
    small = SearchBudget(max_plans_per_mapping=3, max_candidates=17)
    plans = [p for _, p in iter_plan_stream(prog, hw, small)]
    assert len(plans) == 17
    per_mapping = {}
    for p in plans:
        per_mapping[p.mapping] = per_mapping.get(p.mapping, 0) + 1
    assert max(per_mapping.values()) <= 3
