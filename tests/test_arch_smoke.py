"""Per-architecture smoke tests: a REDUCED config of the same family runs one
forward + train step on CPU, asserting output shapes and no NaNs; decode
steps advance their cache.  (Full configs are exercised only via the
dry-run's ShapeDtypeStruct lowering.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import TrainConfig
from repro.data import DataConfig, SyntheticLM
from repro.models import build_model
from repro.train import train_step as TS

B, S = 2, 32


def _batch(cfg, step=0):
    d = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seed=1), cfg)
    return jax.tree.map(jnp.asarray, d.batch_at(step, B, S))


# archs whose reduced configs still take many seconds per jit compile;
# their smoke tests carry the `slow` marker (deselect with -m "not slow")
_HEAVY = {"zamba2-1.2b", "rwkv6-3b", "deepseek-67b", "seamless-m4t-medium"}


@pytest.fixture(scope="module",
                params=[pytest.param(a, marks=pytest.mark.slow)
                        if a in _HEAVY else a for a in sorted(ARCHS)])
def arch(request):
    return request.param


def test_smoke_forward_and_loss(arch):
    cfg = ARCHS[arch].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = api.logits_fn(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, metrics = api.loss_fn(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))
    assert 2.0 < float(loss) < 15.0          # ~ln(V) at init


def test_smoke_train_step(arch):
    cfg = ARCHS[arch].reduced()
    api = build_model(cfg)
    tcfg = TrainConfig(total_steps=10, warmup_steps=1, microbatches=2)
    state = TS.init_state(api, tcfg, jax.random.PRNGKey(0))
    step = TS.make_train_step(api, tcfg)
    state, metrics = step(state, _batch(cfg, 0))
    assert not bool(jnp.isnan(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    before = jax.tree.leaves(TS.init_state(api, tcfg,
                                           jax.random.PRNGKey(0)).params)[0]
    after = jax.tree.leaves(state.params)[0]
    assert not np.allclose(np.asarray(before, np.float32),
                           np.asarray(after, np.float32))


def test_smoke_decode_step(arch):
    cfg = ARCHS[arch].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    t0 = jnp.full((B, 1), 7, jnp.int32)
    t1 = jnp.full((B, 1), 23, jnp.int32)
    # with history: decode t0 then t1
    cache = api.init_cache(cfg, B, 64)
    logits0, cache = api.decode_step(params, t0, cache)
    logits_hist, cache = api.decode_step(params, t1, cache)
    assert logits0.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits_hist.astype(jnp.float32)).all())
    assert int(cache["index"]) == 2
    # without history: decode t1 on a fresh cache — must differ (the state /
    # KV cache genuinely carries the past)
    fresh = api.init_cache(cfg, B, 64)
    logits_fresh, _ = api.decode_step(params, t1, fresh)
    assert not np.allclose(np.asarray(logits_hist, np.float32),
                           np.asarray(logits_fresh, np.float32), atol=1e-3)
