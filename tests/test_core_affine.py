"""Unit tests for the affine algebra underlying reuse analysis."""
import pytest

from repro.core.affine import (AffineExpr, AffineMap, _is_mixed_radix,
                               distinct_points, footprint_tiles)


def test_linear_algebra():
    a = AffineExpr.var("i")
    b = AffineExpr.var("j", 2)
    c = a + b + AffineExpr.const_expr(3)
    assert c.evaluate({"i": 1, "j": 2}) == 1 + 4 + 3
    assert c.depends_on("i") and c.depends_on("j") and not c.depends_on("k")
    assert (c * 2).evaluate({"i": 1, "j": 2}) == 16


def test_mod_floordiv():
    e = (AffineExpr.var("x") + AffineExpr.const_expr(1)).with_mod(8)
    assert e.evaluate({"x": 7}) == 0
    f = AffineExpr.var("x").with_floordiv(4)
    assert f.evaluate({"x": 7}) == 1
    with pytest.raises(ValueError):
        _ = e + f                     # non-linear exprs cannot be added


def test_substitute_mixed_radix():
    # g := 16*t + 2*x + y  (grid-index reconstruction)
    g = AffineExpr.linear({"t": 16, "x": 2, "y": 1})
    m = AffineMap.from_terms({"g": 1}, {"k": 1})
    m2 = m.substitute("g", g)
    assert m2.depends_on("t") and m2.depends_on("x") and m2.depends_on("k")
    assert m2.evaluate({"t": 1, "x": 1, "y": 1, "k": 5}) == (19, 5)


def test_distinct_points_product_rule_matches_enumeration():
    m = AffineMap.from_terms({"t": 4, "x": 1}, {"k": 1})
    extents = {"t": 3, "x": 4, "k": 5}
    # mixed radix: x stride 1 extent 4, t stride 4 -> all distinct
    exact = distinct_points(m, extents, ["t", "x", "k"])
    assert exact == 3 * 4 * 5
    assert _is_mixed_radix(m, extents, ["t", "x", "k"])


def test_distinct_points_non_injective_fallback():
    # overlapping strides: t stride 2 but x extent 4 -> collisions
    m = AffineMap.from_terms({"t": 2, "x": 1})
    extents = {"t": 2, "x": 4}
    assert not _is_mixed_radix(m, extents, ["t", "x"])
    # values: 2t + x for t in {0,1}, x in {0..3} -> {0..5} = 6 distinct, not 8
    assert distinct_points(m, extents, ["t", "x"]) == 6


def test_footprint_independent_dims_free():
    # access independent of "n": ranging n does not grow the footprint
    m = AffineMap.from_terms({"m": 1}, {"k": 1})
    extents = {"m": 4, "n": 7, "k": 3}
    assert footprint_tiles(m, extents, ["n"]) == 1
    assert footprint_tiles(m, extents, ["n", "k"]) == 3
    assert footprint_tiles(m, extents, ["m", "n", "k"]) == 12
