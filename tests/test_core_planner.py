"""System-behaviour tests for the TileLoom planner (paper S2.2-S2.5)."""
import math

import pytest

from repro.core import (SearchBudget, analyze_reuse, enumerate_mappings,
                        estimate, get_hw, hoist_options, make_plan,
                        matmul_program, flash_attention_program, plan_kernel,
                        simulate, templates)
from repro.core.reuse import enumerate_memop_choices, buffer_footprint_bytes
from repro.core.perfmodel import pipelined_loop_time


@pytest.fixture(scope="module")
def hw():
    return get_hw("wormhole_8x8")


@pytest.fixture(scope="module")
def prog():
    return matmul_program(1024, 1024, 1024, bm=64, bn=64, bk=64)


def test_get_hw_unknown_name_lists_presets():
    """An unknown preset name must fail loudly with the sorted list of
    valid names (mirroring the run.py --suite contract)."""
    from repro.core.hw import PRESETS
    with pytest.raises(KeyError) as exc:
        get_hw("wormhole_9x9")
    msg = str(exc.value)
    assert "unknown hardware preset 'wormhole_9x9'" in msg
    assert str(sorted(PRESETS)) in msg


def test_df_text_matches_paper_structure(hw):
    text = hw.df_text()
    for op in ("df.spatial_dim", "df.core", "df.memory", "df.mux",
               "df.interconnects", "df.mat", "df.vec"):
        assert op in text
    assert "mod 8" in text            # wrap-around ring links (Listing 6)


def test_mapping_enumeration_contains_canonical_dataflows(hw, prog):
    maps = enumerate_mappings(prog, hw)
    descs = [m.describe() for m in maps]
    # the 2D output-stationary mapping (gx->x, gy->y) must be in the space
    assert any("gx->%x(8)" in d and "gy->%y(8)" in d for d in descs)
    # the 1D flattened mapping (gx over both axes) must be in the space
    assert any("gx->%x(8)" in d and "gx->%y(8)" in d for d in descs)
    # tiling order matters: both orders of the flattened mapping exist
    assert any("gx->%y(8), gx->%x(8)" in d for d in descs)


def test_mapping_grid_index_reconstruction(hw, prog):
    maps = enumerate_mappings(prog, hw)
    m = next(m for m in maps if len(m.spatial_for("gx")) == 2)
    expr = m.grid_index_expr("gx")
    s2 = m.spatial[1].hw_size
    # mixed radix: outer digit stride = inner size
    env = {m.spatial[0].hw_dim: 1, m.spatial[1].hw_dim: 2, "t_gx": 0}
    assert expr.evaluate(env) == s2 + 2


def test_reuse_analysis_gemm(hw, prog):
    maps = enumerate_mappings(prog, hw)
    m2d = next(m for m in maps
               if m.spatial_for("gx") and m.spatial_for("gy")
               and m.spatial_for("gx")[0].hw_dim == "x")
    infos = {i.access.tensor.name: i for i in analyze_reuse(m2d, hw)}
    # A[gx,k] is identical along the y axis; B[k,gy] along x (paper Listing 3)
    assert "y" in infos["A"].spatial_axes and "x" not in infos["A"].spatial_axes
    assert "x" in infos["B"].spatial_axes and "y" not in infos["B"].spatial_axes
    # C store depends on both spatial dims -> no spatial reuse
    assert infos["C"].spatial_axes == ()


def test_hoisting_footprint_rules(hw, prog):
    """Paper Listing 4: hoisting across a dependent loop multiplies the
    footprint by its extent; across an independent loop it does not."""
    maps = enumerate_mappings(prog, hw)
    m2d = next(m for m in maps
               if m.spatial_for("gx") and m.spatial_for("gy") and m.temporal)
    infos = {i.access.tensor.name: i for i in analyze_reuse(m2d, hw)}
    opts = hoist_options(infos["A"], m2d)
    # innermost option: 1 tile; crossing k multiplies by K_tiles
    assert opts[0].footprint_tiles == 1
    k_tiles = prog.dim("k").extent
    assert any(o.footprint_tiles == k_tiles for o in opts)
    # traffic = issues x tiles_per_issue is monotonically non-increasing as
    # we hoist outward
    traffic = [o.issues_per_core * o.tiles_per_issue for o in opts]
    assert all(a >= b for a, b in zip(traffic, traffic[1:]))


def test_capacity_pruning(hw):
    # enormous blocks: no memory-op combination fits the 1.5MB L1
    big = matmul_program(8192, 8192, 8192, bm=1024, bn=1024, bk=1024)
    maps = enumerate_mappings(big, hw)
    assert all(len(enumerate_memop_choices(m, hw)) == 0 for m in maps[:8])


def test_pipelined_loop_formula():
    # I=1: no overlap possible
    assert pipelined_loop_time(1, 2.0, 3.0, 5.0) == 10.0
    # steady state limited by compute when compute dominates
    t = pipelined_loop_time(10, 1.0, 1.0, 5.0)
    assert t == 8 * 5.0 + 5.0 + 5.0 + 1.0 + 1.0
    # limited by load+store when memory dominates
    t = pipelined_loop_time(10, 3.0, 3.0, 1.0)
    assert t == 8 * 6.0 + 3.0 + 3.0 + 3.0 + 3.0


def test_planner_beats_or_matches_vendor_templates(hw):
    """The searched space includes both templates, so TL's model-best must be
    no worse than the better template under the model (paper S3.2) when
    planning at the template's own block shape."""
    M = N = K = 2048
    tpl = templates.tt2d_matmul_plan(M, N, K, hw)
    bm, _ = tpl.loads[0].access.tile_shape
    _, bn = tpl.loads[1].access.tile_shape
    bk = tpl.loads[0].access.tile_shape[1]
    res = plan_kernel(matmul_program(M, N, K, bm=bm, bn=bn, bk=bk), hw,
                      budget=SearchBudget(top_k=3))
    t2d = estimate(tpl, hw)
    assert res.topk[0].cost.total_s <= t2d.total_s * 1.001


def test_spatial_reuse_reduces_dram_traffic(hw, fast_search):
    """Paper Table 1: spatial reuse cuts DRAM accesses (avg -70%)."""
    M = N = K = 2048
    with_reuse = plan_kernel(matmul_program(M, N, K, bm=128, bn=128, bk=64),
                             hw, profile=False)
    without = plan_kernel(matmul_program(M, N, K, bm=128, bn=128, bk=64),
                          hw, profile=False, spatial_reuse=False)
    assert with_reuse.best.cost.dram_bytes < 0.5 * without.best.cost.dram_bytes


def test_two_step_selection_runs_simulator(hw):
    res = plan_kernel(matmul_program(512, 512, 512, bm=64, bn=64, bk=64), hw,
                      budget=SearchBudget(top_k=2))
    assert all(c.sim is not None for c in res.topk)
    assert res.best.final_s > 0


def test_flash_attention_planning(hw, fast_search):
    """TL exploits K/V reuse across query tiles (paper S3.2): the best plan
    must not reload K/V per-core from DRAM at the innermost level."""
    prog = flash_attention_program(64, 1024, 1024, 64, bq=64, bkv=64)
    res = plan_kernel(prog, hw, budget=SearchBudget(top_k=3))
    kv_choices = [c for c in res.best.plan.loads
                  if c.access.tensor.name in ("K", "V")]
    assert any(c.bcast_axes or c.hoist.level < 3 for c in kv_choices)
    ttnn = templates.ttnn_flash_plan(64, 1024, 1024, 64, hw)
    assert simulate(res.best.plan, hw).total_s < simulate(ttnn, hw).total_s


def test_simulator_traffic_consistency(hw):
    """Simulator and analytic model must agree on DRAM traffic for a plan
    with no broadcasts and no hoisting (both count every per-core load)."""
    res = plan_kernel(matmul_program(1024, 1024, 1024, bm=128, bn=128, bk=64),
                      hw, profile=False, spatial_reuse=False,
                      temporal_reuse=False)
    plan = res.best.plan
    sim = simulate(plan, hw)
    model = estimate(plan, hw)
    assert sim.dram_bytes == pytest.approx(model.dram_bytes, rel=0.05)


def test_tpu_pod_presets():
    pod = get_hw("tpu_v5e_pod")
    assert pod.n_cores == 256
    assert pod.peak_flops_per_core() == pytest.approx(197e12, rel=0.01)
    two = get_hw("tpu_v5e_2pod")
    assert two.n_cores == 512
    assert {a for a, _ in two.mesh_dims} == {"pod", "data", "model"}


def test_roofline_loop_weighting_sibling_scans():
    """Trip inference must distinguish sibling scans at one nesting depth
    (EXPERIMENTS.md SPerf B4): weights validated against scan-tuple dims."""
    import jax
    import jax.numpy as jnp
    from repro.launch import roofline as RL

    def f(w, x):
        def layer(h, wi):                      # "layer scan": 6 trips
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(layer, x, w)

        def chunk(acc, i):                     # sibling "chunk scan": 2 trips
            xs = jax.lax.dynamic_slice_in_dim(h, i * 8, 8, axis=0)
            return acc + jnp.sum(xs @ w[0]), None
        out, _ = jax.lax.scan(chunk, jnp.zeros(()), jnp.arange(2))
        return out

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((6, 32, 32), jnp.float32),
        jax.ShapeDtypeStruct((16, 32), jnp.float32)).compile()
    hlo = compiled.as_text()
    weighted, flat = RL.dot_flops(hlo, trips_by_depth=(6,))
    # layer dots (6x) dominate; chunk dots get 2x, never 6x:
    # flat = layer_dot + chunk_dot; weighted = 6*layer + 2*chunk
    layer_dot = 2 * 16 * 32 * 32
    chunk_dot = 2 * 8 * 32 * 32
    assert abs(weighted - (6 * layer_dot + 2 * chunk_dot)) <= \
        0.2 * weighted
