"""Spatial-reduction (split-K) plan space: mapping semantics, cost-engine
bit-identity, search equivalence, lowering, and serialization.

The reduction space rides the PR 2/3 machinery, so it inherits their hard
invariants and this file pins them *over mappings that include reduce
binds*: the batch engine equals the scalar ``estimate()`` with ``==`` (not
approx), branch-and-bound equals the exhaustive oracle, the wave-class
simulator equals the wave-by-wave reference, worker sharding is
selection-invariant, and the new plan fields survive JSON round-trips.
"""
import itertools
import math

import pytest

try:                    # numpy is optional (the planner degrades to the
    import numpy as np  # scalar engine); only the batch tests need it
except ImportError:     # pragma: no cover - numpy ships in CI
    np = None

needs_numpy = pytest.mark.skipif(
    np is None, reason="numpy unavailable (batch engine disabled)")

from repro.core import (MappingBatch, SearchBudget, estimate,
                        flash_decode_program, get_hw, matmul_program,
                        moe_gmm_program, plan_kernel, plan_kernel_multi,
                        plan_lower_bound, simulate, simulate_plans,
                        simulate_reference)
from repro.core.mapping import REDUCE_STYLES, enumerate_mappings
from repro.core.plan import DataflowPlan
from repro.core.planner import _filtered_mappings
from repro.core.reuse import memop_choices_with_stores
from repro.plancache import serialize

BUDGET = SearchBudget(max_mappings=64, max_plans_per_mapping=12)


def _cases():
    """Reduction-bound programs across all three mesh shapes, including a
    ragged split (8320/64 = 130 k-tiles over 8 slots -> 17-tile chunks,
    last slot partially filled)."""
    return [
        (matmul_program(256, 256, 65536, bm=64, bn=64, bk=64),
         get_hw("wormhole_8x8")),
        (matmul_program(320, 192, 8320, bm=32, bn=32, bk=64),
         get_hw("wormhole_4x8")),
        (flash_decode_program(16, 32768, 128, bkv=64),
         get_hw("wormhole_8x8")),
        (moe_gmm_program(8, 128, 16384, 512, bm=64, bn=64, bk=64),
         get_hw("wormhole_1x8")),
    ]


def _reduce_mappings(prog, hw, limit=8):
    return [m for m in _filtered_mappings(prog, hw, BUDGET)
            if m.reduce_binds()][:limit]


def _reduce_plan_grid(max_combos=6):
    for prog, hw in _cases():
        for mapping in _reduce_mappings(prog, hw):
            demands = {}
            combos, stores = memop_choices_with_stores(mapping, hw,
                                                       demands=demands)
            combos = combos[:max_combos]
            if combos:
                yield mapping, stores, combos, demands, hw


# --------------------------------------------------------------------------
# Mapping semantics
# --------------------------------------------------------------------------
def test_enumeration_contains_reduction_space():
    """The second enumeration pass adds reduce binds in every style on NoC
    axes (accumulate-only on axes without a ring), appended strictly after
    the parallel-only space, and the budget knob removes them entirely."""
    prog = matmul_program(256, 256, 65536, bm=64, bn=64, bk=64)
    hw = get_hw("wormhole_8x8")
    maps = enumerate_mappings(prog, hw)
    base = enumerate_mappings(prog, hw, allow_reduction=False)
    # prefix-identical: parallel mappings keep their canonical indices
    assert list(maps[:len(base)]) == list(base)
    red = [m for m in maps if m.reduce_binds()]
    assert red and all(m.reduce_style in REDUCE_STYLES for m in red)
    assert {m.reduce_style for m in red} == set(REDUCE_STYLES)
    assert all(b.grid_dim == "k" for m in red for b in m.reduce_binds())
    assert not any(m.reduce_binds() for m in base)
    # planner knob: spatial_reduction=False restores the parallel space
    off = _filtered_mappings(prog, hw,
                             SearchBudget(spatial_reduction=False))
    assert not any(m.reduce_binds() for m in off)


def test_split_covers_sequential_space_exactly():
    """Every sequential index is executed exactly once across the reduce
    digits (blocked split; ragged tails leave trailing digits idle)."""
    checked = 0
    for prog, hw in _cases():
        for m in _reduce_mappings(prog, hw, limit=4):
            for d in prog.seq_dims:
                if m.reduce_factor(d.name) <= 1:
                    continue
                binds = m.reduce_for(d.name)
                expr = m.seq_index_expr(d.name)
                covered = []
                for digits in itertools.product(
                        *[range(b.hw_size) for b in binds]):
                    env = {b.hw_dim: v for b, v in zip(binds, digits)}
                    for k in range(m.seq_extent(d.name)):
                        v = expr.evaluate({**env, d.name: k})
                        if v < d.extent:
                            covered.append(v)
                assert sorted(covered) == list(range(d.extent)), m.describe()
                checked += 1
    assert checked >= 10


def test_store_placement_carries_reduction():
    """Stores under a reduce mapping carry the bound axes + style; the
    rewritten output access is independent of the reduce axis (that is what
    makes the per-core results partial sums of the same tile)."""
    for prog, hw in _cases():
        for m in _reduce_mappings(prog, hw, limit=3):
            _, stores = memop_choices_with_stores(m, hw)
            axes = {b.hw_dim for b in m.reduce_binds()}
            for s in stores:
                assert set(s.reduce_axes) == axes, m.describe()
                assert s.reduce_style == m.reduce_style
                assert not any(m.rewrite_access(s.access).depends_on(a)
                               for a in axes)


def test_utilization_and_active_cores_account_for_split():
    """A ragged split (130 tiles over 8 slots -> 17-tile chunks) activates
    only ceil(130/17)=8 digits at utilization 130/(8*17)."""
    prog = matmul_program(320, 192, 8320, bm=32, bn=32, bk=64)  # 130 k-tiles
    hw = get_hw("wormhole_4x8")
    m = next(m for m in _reduce_mappings(prog, hw, limit=64)
             if m.reduce_binds()[0].hw_size == 8)
    assert m.seq_extent("k") == 17
    assert m.active_reduce_factor() == math.ceil(130 / 17)
    u = m.utilization()
    assert 0 < u <= 1.0
    # dropping the reduce bind idles its axis (u / 8) and removes the split
    # padding term (130 real tiles in 8 x 17 slots)
    flat = m.__class__(m.program, m.hw_name, m.hw_dims,
                       tuple(b for b in m.spatial if not b.reduce),
                       m.temporal)
    assert u == pytest.approx(flat.utilization() * 8 * (130 / (8 * 17)),
                              rel=1e-12)
    # active cores factor as (parallel actives) x (active digits)
    assert m.active_cores() == flat.active_cores() * m.active_reduce_factor()


# --------------------------------------------------------------------------
# Cost engines: bit-identity + admissibility over reduction plans
# --------------------------------------------------------------------------
def test_simulate_matches_reference_with_reduction():
    """Wave-class simulation == the wave-by-wave reference across split
    plans: totals, DRAM and NoC traffic (the forwarding epilogue bytes)."""
    checked = styles = 0
    seen_styles = set()
    for mapping, stores, combos, _, hw in _reduce_plan_grid(max_combos=2):
        for combo in combos:
            plan = DataflowPlan(mapping, combo, stores)
            fast = simulate(plan, hw)
            ref = simulate_reference(plan, hw, max_waves_exact=10 ** 9)
            assert fast.total_s == pytest.approx(ref.total_s, rel=1e-12)
            assert fast.dram_bytes == pytest.approx(ref.dram_bytes, rel=1e-12)
            assert fast.noc_bytes == pytest.approx(ref.noc_bytes, rel=1e-12)
            assert fast.n_waves == ref.n_waves
            seen_styles.add(mapping.reduce_style)
            checked += 1
    assert checked >= 30
    assert seen_styles == set(REDUCE_STYLES)


def test_forwarding_styles_order_in_simulator():
    """The analytic model ties tree and chain (same demand); the simulator
    separates them: log-depth tree <= neighbor chain, and both move the
    same bytes."""
    prog = matmul_program(256, 256, 65536, bm=64, bn=64, bk=64)
    hw = get_hw("wormhole_8x8")
    by_style = {}
    for m in _reduce_mappings(prog, hw, limit=64):
        key = (tuple((b.hw_dim, b.grid_dim, b.reduce) for b in m.spatial),
               m.temporal)
        by_style.setdefault(key, {})[m.reduce_style] = m
    compared = 0
    for styles in by_style.values():
        if not {"tree", "chain"} <= set(styles):
            continue
        combos_t, stores_t = memop_choices_with_stores(styles["tree"], hw)
        combos_c, stores_c = memop_choices_with_stores(styles["chain"], hw)
        pt = DataflowPlan(styles["tree"], combos_t[0], stores_t)
        pc = DataflowPlan(styles["chain"], combos_c[0], stores_c)
        assert estimate(pt, hw).total_s == estimate(pc, hw).total_s
        st, sc = simulate(pt, hw), simulate(pc, hw)
        assert st.total_s <= sc.total_s
        assert st.noc_bytes == pytest.approx(sc.noc_bytes, rel=1e-12)
        compared += 1
    assert compared >= 1


@needs_numpy
def test_batch_estimates_bit_identical_with_reduction():
    """MappingBatch == estimate() with exact float equality over reduction
    plans — the property that keeps engine choice selection-invariant."""
    n = 0
    for mapping, stores, combos, demands, hw in _reduce_plan_grid():
        for pol in (False, True):
            batch = MappingBatch(mapping, stores, hw, combos,
                                 pipeline_outer_levels=pol, demands=demands)
            costs = batch.estimate_rows(np.arange(len(combos)))
            for j, combo in enumerate(combos):
                plan = DataflowPlan(mapping, combo, stores)
                ref = estimate(plan, hw, pipeline_outer_levels=pol)
                assert costs.cost(j) == ref, (plan.describe(), pol)
                n += 1
    assert n >= 100


@needs_numpy
def test_bounds_admissible_with_reduction():
    """Scalar and batched lower bounds stay admissible for split plans —
    the branch-and-bound obligation now also covers forwarding chains."""
    n = 0
    for mapping, stores, combos, demands, hw in _reduce_plan_grid():
        for pol in (False, True):
            batch = MappingBatch(mapping, stores, hw, combos,
                                 pipeline_outer_levels=pol, demands=demands)
            lbs = batch.lower_bounds()
            for j, combo in enumerate(combos):
                plan = DataflowPlan(mapping, combo, stores)
                cost = estimate(plan, hw, pipeline_outer_levels=pol)
                lb = plan_lower_bound(plan, hw, pipeline_outer_levels=pol)
                assert lb <= cost.total_s * (1 + 1e-12), plan.describe()
                assert lbs[j] <= cost.total_s * (1 + 1e-9)
                assert lbs[j] == pytest.approx(lb, rel=1e-12)
                n += 1
    assert n >= 100


@needs_numpy
def test_simulate_plans_bit_identical_with_reduction():
    checked = 0
    for mapping, stores, combos, _, hw in _reduce_plan_grid(max_combos=2):
        for combo in combos:
            plan = DataflowPlan(mapping, combo, stores)
            (got,) = simulate_plans([plan], hw)
            ref = simulate(plan, hw)
            assert (got.total_s, got.dram_bytes, got.noc_bytes,
                    got.n_waves, got.n_wave_classes) == \
                   (ref.total_s, ref.dram_bytes, ref.noc_bytes,
                    ref.n_waves, ref.n_wave_classes), plan.describe()
            checked += 1
    assert checked >= 30


# --------------------------------------------------------------------------
# Search equivalence with the reduction space enabled
# --------------------------------------------------------------------------
def _keyed(res):
    return [(c.plan.describe(), c.index, c.cost.total_s,
             c.sim.total_s if c.sim else None) for c in res.topk]


def test_bnb_matches_exhaustive_with_reduction():
    hw = get_hw("wormhole_8x8")
    mk = lambda: matmul_program(256, 256, 65536, bm=64, bn=64, bk=64)
    budget = SearchBudget(top_k=5)
    fast = plan_kernel(mk(), hw, budget=budget, use_bound=True)
    slow = plan_kernel(mk(), hw, budget=budget, use_bound=False)
    assert _keyed(fast) == _keyed(slow)
    assert fast.best.plan.mapping.reduce_binds()    # split-K actually wins


@needs_numpy
def test_engines_select_identically_with_reduction():
    hw = get_hw("wormhole_8x8")
    mk = lambda: [matmul_program(256, 256, 65536, bm=bm, bn=bn, bk=64)
                  for bm in (32, 64) for bn in (32, 64)]
    budget = SearchBudget(top_k=5, max_plans_per_mapping=24)
    b = plan_kernel_multi(mk(), hw, budget=budget, engine="batch")
    s = plan_kernel_multi(mk(), hw, budget=budget, engine="scalar")
    assert _keyed(b) == _keyed(s)


@pytest.mark.parametrize("workers", [2, 4])
def test_parallel_matches_inline_with_reduction(workers):
    """plan_kernel_multi output is identical at workers 1/2/4 with
    reduction binds enabled (the acceptance criterion's golden-gate twin)."""
    hw = get_hw("wormhole_8x8")
    mk = lambda: [flash_decode_program(16, 32768, 128, bkv=bkv)
                  for bkv in (32, 64, 128)]
    inline = plan_kernel_multi(mk(), hw,
                               budget=SearchBudget(top_k=5, workers=1))
    sharded = plan_kernel_multi(mk(), hw,
                                budget=SearchBudget(top_k=5,
                                                    workers=workers))
    assert _keyed(sharded) == _keyed(inline)
    assert inline.best.plan.mapping.reduce_binds()


# --------------------------------------------------------------------------
# The point of it all: faster plans on reduction-bound cells
# --------------------------------------------------------------------------
def test_splitk_improves_reduction_bound_cells():
    """On reduction-bound shapes the selected plan's simulated time improves
    >= 15% over the reduction-free space (the issue's acceptance bar)."""
    hw = get_hw("wormhole_8x8")
    budget = SearchBudget(top_k=5)
    base_budget = SearchBudget(top_k=5, spatial_reduction=False)
    for mk in (lambda: matmul_program(256, 256, 65536, bm=64, bn=64, bk=64),
               lambda: flash_decode_program(16, 32768, 128, bkv=64)):
        on = plan_kernel(mk(), hw, budget=budget)
        off = plan_kernel(mk(), hw, budget=base_budget)
        assert on.best.sim.total_s <= off.best.sim.total_s / 1.15, \
            (on.best.plan.describe(), off.best.plan.describe())
        assert on.best.plan.mapping.reduce_binds()


def test_compute_bound_best_plan_unchanged():
    """A compute-dense square GEMM must select the identical best plan with
    the reduction space on and off — split-K only ever wins by strictly
    lower cost, and ties resolve to the earlier (parallel) index."""
    hw = get_hw("wormhole_8x8")
    mk = lambda: matmul_program(4096, 4096, 4096, bm=128, bn=128, bk=64)
    on = plan_kernel(mk(), hw, budget=SearchBudget(top_k=3))
    off = plan_kernel(mk(), hw, budget=SearchBudget(top_k=3,
                                                    spatial_reduction=False))
    assert on.best.plan == off.best.plan
    assert on.best.cost.total_s == off.best.cost.total_s
    assert not on.best.plan.mapping.reduce_binds()


# --------------------------------------------------------------------------
# Serialization + lowering of the new plan fields
# --------------------------------------------------------------------------
def test_serialization_roundtrip_reduce_plan():
    """plan/result round-trips preserve reduce binds, style, and store
    axes, and the deserialized plan reproduces identical costs."""
    hw = get_hw("wormhole_8x8")
    res = plan_kernel(matmul_program(256, 256, 65536, bm=64, bn=64, bk=64),
                      hw, budget=SearchBudget(top_k=4))
    assert res.best.plan.mapping.reduce_binds()
    rt = serialize.result_from_dict(serialize.result_to_dict(res))
    assert rt.best.plan == res.best.plan
    assert rt.best.plan.mapping.reduce_style == \
        res.best.plan.mapping.reduce_style
    assert [s.reduce_axes for s in rt.best.plan.stores] == \
        [s.reduce_axes for s in res.best.plan.stores]
    assert [c.plan for c in rt.topk] == [c.plan for c in res.topk]
    re_cost = estimate(rt.best.plan, hw)
    assert re_cost == res.best.cost


def test_splitk_pallas_spec():
    """lower_jax.splitk_pallas_spec turns a reduce bind into the Pallas
    accumulation-grid descriptor (output revisiting for accum; per-split
    partials for forwarding styles); flat plans lower to None."""
    from repro.core import lower_jax
    hw = get_hw("wormhole_8x8")
    res = plan_kernel(matmul_program(256, 256, 65536, bm=64, bn=64, bk=64),
                      hw, budget=SearchBudget(top_k=4))
    spec = lower_jax.splitk_pallas_spec(res.best.plan)
    m = res.best.plan.mapping
    assert spec is not None
    assert spec["grid_dim"] == "k"
    assert spec["n_split"] == m.active_reduce_factor()
    assert spec["n_split"] * spec["steps_per_split"] >= \
        m.program.dim("k").extent
    assert spec["style"] == m.reduce_style
    assert spec["revisit_output"] == (m.reduce_style == "accum")
    off = plan_kernel(matmul_program(256, 256, 65536, bm=64, bn=64, bk=64),
                      hw, budget=SearchBudget(top_k=1,
                                              spatial_reduction=False))
    assert lower_jax.splitk_pallas_spec(off.best.plan) is None


def test_reduction_bind_lowers_to_collective():
    """A pod-level reduce bind lowers to a psum-style collective descriptor
    (planner_bridge): accum -> psum, tree -> reduce_scatter."""
    from repro.core import tpu_v5e_pod
    from repro.parallel.planner_bridge import lower_reduction_bind
    hw = tpu_v5e_pod(4, 4)
    prog = matmul_program(512, 512, 65536, bm=128, bn=128, bk=128)
    maps = [m for m in enumerate_mappings(prog, hw) if m.reduce_binds()]
    assert maps
    by_style = {m.reduce_style: m for m in maps}
    (acc,) = lower_reduction_bind(by_style["accum"])
    assert acc["collective"] == "psum"
    assert acc["reduction_dim"] == "k"
    assert acc["axis"] in ("data", "model")
    (tree,) = lower_reduction_bind(by_style["tree"])
    assert tree["collective"] == "reduce_scatter"
    assert lower_reduction_bind(
        next(m for m in enumerate_mappings(prog, hw)
             if not m.reduce_binds())) == []
