"""Plan-registry tests: serialization round-trip, digest invalidation,
two-tier hit/miss behavior, concurrent multi-process store access,
cold-vs-warm block planning (including under the sharded search),
warm-start, the mesh-plan cache, and the AOT CLI."""
import json
import multiprocessing
import os

import pytest

from repro import plancache
from repro.core import (SearchBudget, estimate, flash_attention_program,
                        get_hw, matmul_program, plan_kernel,
                        plan_kernel_multi)
from repro.plancache import serialize as S

BUDGET = SearchBudget(top_k=3, max_mappings=24, max_plans_per_mapping=12)


@pytest.fixture()
def store(tmp_path, monkeypatch):
    monkeypatch.setenv(plancache.ENV_DIR, str(tmp_path))
    monkeypatch.delenv(plancache.ENV_TOGGLE, raising=False)
    plancache.reset_store()
    from repro.core.lower_jax import clear_block_caches
    clear_block_caches()
    yield plancache.get_store()
    clear_block_caches()
    plancache.reset_store()


def _gemm(M=512, N=512, K=512, b=64):
    return matmul_program(M, N, K, bm=b, bn=b, bk=b)


def _flash():
    return flash_attention_program(16, 512, 512, 64, bq=64, bkv=64)


# ------------------------------------------------------ serialization
@pytest.mark.parametrize("hw_name", ["wormhole_8x8", "tpu_v5e_chip"])
@pytest.mark.parametrize("kind", ["gemm", "flash"])
def test_result_roundtrip_reproduces_costs(hw_name, kind):
    """from_dict(to_dict(plan)) is JSON-stable and reproduces identical
    analytic costs for GEMM and flash plans, best and full top-k, on both
    hardware presets (acceptance criterion)."""
    hw = get_hw(hw_name)
    prog = _gemm() if kind == "gemm" else _flash()
    res = plan_kernel(prog, hw, budget=BUDGET, profile=True)
    wire = json.loads(json.dumps(S.result_to_dict(res)))
    res2 = S.result_from_dict(wire)
    assert res2.best.plan == res.best.plan
    assert res2.best.cost == res.best.cost
    assert res2.best.sim == res.best.sim
    assert estimate(res2.best.plan, hw) == estimate(res.best.plan, hw)
    assert len(res2.topk) == len(res.topk)
    for a, b in zip(res.topk, res2.topk):
        assert b.plan == a.plan and b.cost == a.cost and b.sim == a.sim
        assert estimate(b.plan, hw) == estimate(a.plan, hw)
    assert (res2.kernel, res2.hw_name, res2.n_candidates, res2.n_mappings) \
        == (res.kernel, res.hw_name, res.n_candidates, res.n_mappings)


def test_program_roundtrip_identity():
    for prog in (_gemm(), _flash()):
        wire = json.loads(json.dumps(S.program_to_dict(prog)))
        assert S.program_from_dict(wire) == prog


# ------------------------------------------------------------ keying
def test_digest_stable_and_invalidates():
    prog = _gemm()
    hw8 = get_hw("wormhole_8x8")
    k1 = plancache.kernel_key([prog], hw8, BUDGET)
    assert k1 == plancache.kernel_key([prog], hw8, BUDGET)   # deterministic
    # hardware model change (different df_text) => different key
    assert k1 != plancache.kernel_key([prog], get_hw("wormhole_4x8"), BUDGET)
    # search budget change => different key
    assert k1 != plancache.kernel_key([prog], hw8, SearchBudget(top_k=1))
    # profile flag => different key
    assert k1 != plancache.kernel_key([prog], hw8, BUDGET, profile=False)
    # program change => different key
    assert k1 != plancache.kernel_key([_gemm(K=1024)], hw8, BUDGET)


def test_schema_version_invalidates(store, monkeypatch):
    prog = _gemm()
    hw = get_hw("wormhole_8x8")
    k1 = plancache.kernel_key([prog], hw, BUDGET)
    monkeypatch.setattr(plancache.keying, "SCHEMA_VERSION", 999)
    assert plancache.kernel_key([prog], hw, BUDGET) != k1
    # entries written under another schema are treated as misses
    store.put("deadbeef", {"x": 1}, {})
    ent_path = store._path("deadbeef")
    data = json.loads(ent_path.read_text())
    assert data["schema"] == 999
    # monkeypatch restores the real SCHEMA_VERSION at teardown


def test_stale_schema_entry_is_a_miss(store, monkeypatch):
    store.put("cafe01", {"x": 1}, {})
    p = store._path("cafe01")
    data = json.loads(p.read_text())
    data["schema"] = -1
    p.write_text(json.dumps(data))
    store.clear_memory()
    assert store.get("cafe01") is None
    assert store.stats.misses == 1


# ------------------------------------------------------------ store
def test_two_tier_hit_miss_bypass(store, monkeypatch):
    assert store.get("k1") is None                       # cold miss
    store.put("k1", {"v": 42}, {"template": "t"})
    assert store.get("k1")["payload"]["v"] == 42         # memory hit
    store.clear_memory()
    assert store.get("k1")["payload"]["v"] == 42         # disk hit
    s = store.stats
    assert (s.misses, s.hits_mem, s.hits_disk, s.puts) == (1, 1, 1, 1)
    # bypass: disabled store never reads or writes
    monkeypatch.setenv(plancache.ENV_TOGGLE, "off")
    plancache.reset_store()
    off = plancache.get_store()
    assert off.get("k1") is None and off.put("k2", {}, {}) is None
    assert off.stats.bypassed == 2


def test_memory_tier_lru_eviction(tmp_path):
    st = plancache.PlanCacheStore(tmp_path, mem_capacity=2, enabled=True)
    for i in range(4):
        st.put(f"k{i}", {"i": i}, {})
    assert len(st._mem) == 2
    st.get("k0")                                         # evicted from mem...
    assert st.stats.hits_disk == 1                       # ...but on disk


def test_prune_by_age_and_count(store):
    for i in range(5):
        store.put(f"k{i}", {"i": i}, {})
    assert store.n_entries() == 5
    assert store.prune(max_entries=3) == 2
    assert store.n_entries() == 3
    assert store.prune(max_age_s=0.0) == 3               # everything is "old"
    assert store.n_entries() == 0


def test_nearest_matches_template_and_hw(store):
    store.put("a", {}, {"template": "gemm_blocks", "hw": "H1",
                        "shape": [1024, 1024, 1024]})
    store.put("b", {}, {"template": "gemm_blocks", "hw": "H1",
                        "shape": [8192, 8192, 8192]})
    store.put("c", {}, {"template": "flash_blocks", "hw": "H1",
                        "shape": [2048, 2048, 2048]})
    store.put("d", {}, {"template": "gemm_blocks", "hw": "H2",
                        "shape": [2048, 2048, 2048]})
    hit = store.nearest("gemm_blocks", "H1", (2048, 2048, 2048))
    assert hit["key"] == "a"                             # closest in log-space
    assert store.nearest("gemm_blocks", "H3", (1, 1, 1)) is None


# ------------------------------------------- concurrent store access
def _store_worker(args):
    """Hammer one store directory from a separate process: put/get a key
    every process shares plus a distinct per-process key, then flush stats
    (the advisory-lock read-modify-write)."""
    root, wid, n_ops = args
    os.environ[plancache.ENV_DIR] = root
    plancache.reset_store()
    store = plancache.get_store()
    ok = 0
    for i in range(n_ops):
        store.put("shared", {"writer": wid, "i": i}, {"template": "t"})
        store.put(f"w{wid}_{i}", {"wid": wid, "i": i}, {"template": "t"})
        ent = store.get("shared")
        if ent is not None and "writer" in ent["payload"]:
            ok += 1
        store.clear_memory()             # force the disk tier every round
        if store.get(f"w{wid}_{i}")["payload"]["wid"] != wid:
            return -1
    store.flush_stats()
    return ok


def test_concurrent_store_puts_and_gets(store):
    """N processes put/get the same and distinct keys simultaneously:
    pid-unique temp-file renames keep every entry intact (no torn JSON),
    and the advisory-lock stats merge loses no process's delta."""
    n_procs, n_ops = 4, 8
    # spawn, not fork: by this point the pytest process has JAX's thread
    # pools running, and forking a threaded parent is a documented
    # deadlock hazard.  Spawn children re-import this module by name
    # (pytest's rootdir is on sys.path, which multiprocessing forwards).
    ctx = multiprocessing.get_context("spawn")
    with ctx.Pool(n_procs) as pool:
        results = pool.map(_store_worker,
                           [(str(store.root), w, n_ops)
                            for w in range(n_procs)])
    assert all(r == n_ops for r in results), results
    # every write landed whole: shared key readable, all distinct keys there
    plancache.reset_store()
    fresh = plancache.get_store()
    assert fresh.get("shared")["payload"]["i"] == n_ops - 1
    for w in range(n_procs):
        for i in range(n_ops):
            assert fresh.get(f"w{w}_{i}")["payload"] == {"wid": w, "i": i}
    assert fresh.n_entries() == n_procs * n_ops + 1
    # cumulative stats accumulated every process's flush (2 puts per op)
    cum = fresh.cumulative_stats()
    assert cum["puts"] == n_procs * n_ops * 2
    # no stray temp files survived the renames
    assert not list(fresh.root.glob("*.tmp"))


# ------------------------------------------------- cold vs warm blocks
def test_plan_gemm_blocks_cold_populates_warm_skips_planner(
        store, monkeypatch, fast_search):
    """Acceptance criterion: a cold call populates the on-disk store and an
    equivalent fresh-process call resolves from it with zero planner
    invocations."""
    import repro.core.lower_jax as LJ
    calls = {"n": 0}
    real = LJ.plan_kernel_multi

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(LJ, "plan_kernel_multi", counting)
    LJ.clear_block_caches()
    cold = LJ.plan_gemm_blocks(1024, 1024, 1024)
    assert calls["n"] == 1
    assert store.n_entries() == 1                        # on-disk entry
    # "fresh process": drop both in-memory tiers, keep the disk
    LJ.clear_block_caches()
    store.clear_memory()
    warm = LJ.plan_gemm_blocks(1024, 1024, 1024)
    assert warm == cold
    assert calls["n"] == 1                               # planner not invoked
    assert store.stats.hits_disk >= 1


def test_plan_flash_blocks_cold_vs_warm(store, monkeypatch, fast_search):
    import repro.core.lower_jax as LJ
    calls = {"n": 0}
    real = LJ.plan_kernel_multi

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(LJ, "plan_kernel_multi", counting)
    LJ.clear_block_caches()
    cold = LJ.plan_flash_blocks(1024, 1024, 128)
    LJ.clear_block_caches()
    store.clear_memory()
    assert LJ.plan_flash_blocks(1024, 1024, 128) == cold
    assert calls["n"] == 1


def test_parallel_blocks_match_inline_and_warm_disk(store, monkeypatch,
                                                    fast_search):
    """Satellite acceptance: planning blocks under REPRO_PLANNER_WORKERS>1
    selects the same blocks as inline, and after clear_block_caches a warm
    disk store reproduces identical blocks with zero planner invocations
    even with the sharded search active."""
    import repro.core.lower_jax as LJ
    monkeypatch.setenv("REPRO_PLANNER_WORKERS", "1")
    LJ.clear_block_caches()
    inline = LJ.plan_gemm_blocks(1024, 1024, 1024)
    store.prune(max_entries=0)           # wipe the disk tier
    LJ.clear_block_caches()
    monkeypatch.setenv("REPRO_PLANNER_WORKERS", "2")
    sharded = LJ.plan_gemm_blocks(1024, 1024, 1024)
    assert sharded == inline             # deterministic merge
    calls = {"n": 0}
    real = LJ.plan_kernel_multi

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(LJ, "plan_kernel_multi", counting)
    LJ.clear_block_caches()
    store.clear_memory()
    assert LJ.plan_gemm_blocks(1024, 1024, 1024) == inline
    assert calls["n"] == 0               # served by the warm disk store


def test_warm_jobs_cli_parallel(store, fast_search, capsys):
    """`warm --jobs 2` shards the sweep across worker processes that
    publish into the shared disk store; the resulting entries serve a
    sequential consumer."""
    from repro.plancache.__main__ import main
    args = ["warm", "--gemm", "512x512x512", "--gemm", "768x768x768",
            "--skip-flash", "--skip-mesh", "--jobs", "2"]
    assert main(args) == 0
    out = capsys.readouterr().out
    assert out.count("[warm] gemm") == 2
    assert "across 2 jobs" in out
    store.clear_memory()
    from repro.core.lower_jax import clear_block_caches, plan_gemm_blocks
    clear_block_caches()
    import repro.core.planner as P
    before = P.PLAN_CALLS["plan_kernel_multi"]
    plan_gemm_blocks(512, 512, 512)      # resolves from the warmed store
    assert P.PLAN_CALLS["plan_kernel_multi"] == before


def test_cached_blocks_fallback_warns_and_counts(store, monkeypatch,
                                                 fast_search, caplog):
    """A planner failure in the block tables serves the fallback shape but
    is never silent: one warning line plus an inspectable counter."""
    import logging

    import repro.core.lower_jax as LJ
    LJ.clear_block_caches()

    def boom(*a, **kw):
        raise RuntimeError("no feasible plan (synthetic)")

    monkeypatch.setattr(LJ, "plan_kernel_multi", boom)
    with caplog.at_level(logging.WARNING, logger="repro.core.lower_jax"):
        blocks = LJ.plan_gemm_blocks(2048, 2048, 2048)
    assert blocks == (LJ.MXU_GRANULE,) * 3
    assert LJ.planner_fallback_count() == 1
    assert LJ.planner_fallback_count("gemm_blocks") == 1
    assert any("planner fallback" in r.message and "gemm_blocks" in r.message
               for r in caplog.records)
    LJ.clear_block_caches()
    assert LJ.planner_fallback_count() == 0


def test_reset_planner_fallbacks_rearms_degraded_signal(store, monkeypatch,
                                                        fast_search):
    """reset_planner_fallbacks() clears the fallback counters together with
    the lru/plancache block memo tiers: after the reset a repeat shape goes
    back through the planner (or disk registry) instead of the in-process
    memo that was populated while the planner was failing."""
    import repro.core.lower_jax as LJ
    import repro.core.planner as P
    LJ.clear_block_caches()

    real = LJ.plan_kernel_multi

    def boom(*a, **kw):
        raise RuntimeError("no feasible plan (synthetic)")

    monkeypatch.setattr(LJ, "plan_kernel_multi", boom)
    assert LJ.plan_gemm_blocks(2048, 2048, 2048) == (LJ.MXU_GRANULE,) * 3
    assert LJ.planner_fallback_count("gemm_blocks") == 1
    # while degraded, the memo keeps serving the fallback without replanning
    assert LJ.plan_gemm_blocks(2048, 2048, 2048) == (LJ.MXU_GRANULE,) * 3
    assert LJ.planner_fallback_count("gemm_blocks") == 1   # memo hit, no new

    monkeypatch.setattr(LJ, "plan_kernel_multi", real)     # planner "fixed"
    LJ.reset_planner_fallbacks()
    assert LJ.planner_fallback_count() == 0
    store.clear_memory()  # emulate nothing left warm anywhere
    before = P.PLAN_CALLS["plan_kernel_multi"]
    blocks = LJ.plan_gemm_blocks(2048, 2048, 2048)
    assert P.PLAN_CALLS["plan_kernel_multi"] == before + 1  # really replanned
    assert LJ.planner_fallback_count() == 0
    assert all(b >= LJ.MXU_GRANULE for b in blocks)


def test_old_schema_entries_are_misses_not_crashes(store):
    """Backward compat across the v1 -> v2 schema bump: entries written
    under the previous schema (no spatial-reduction plan fields) read as
    misses — counted in stats, never deserialized, never a crash."""
    assert plancache.keying.SCHEMA_VERSION >= 2
    store.put("v1entry", {"result": {"arbitrary": "v1 payload"}},
              {"template": "t"})
    p = store._path("v1entry")
    data = json.loads(p.read_text())
    data["schema"] = 1                      # a real pre-bump entry
    p.write_text(json.dumps(data))
    store.clear_memory()
    misses = store.stats.misses
    assert store.get("v1entry") is None
    assert store.stats.misses == misses + 1
    # and the planner-level cache treats it the same way: plant the stale
    # entry under the *real* kernel key, then verify the lookup is a miss
    # that triggers a fresh search rather than decoding the v1 layout
    import repro.core.planner as P
    hw = get_hw("wormhole_8x8")
    key = plancache.kernel_key([_gemm()], hw, BUDGET, profile=False)
    store.put(key, {"result": {"kernel": "stale-v1-layout"}}, {})
    p = store._path(key)
    data = json.loads(p.read_text())
    data["schema"] = 1
    p.write_text(json.dumps(data))
    store.clear_memory()
    cache = plancache.PlanCache(store)
    hit = cache.get_result([_gemm()], hw, BUDGET, profile=False,
                           spatial_reuse=True, temporal_reuse=True)
    assert hit is None
    calls = P.PLAN_CALLS["plan_kernel_multi"]
    res = plan_kernel_multi([_gemm()], hw, budget=BUDGET, profile=False,
                            cache=cache)
    assert P.PLAN_CALLS["plan_kernel_multi"] == calls + 1   # really searched
    assert res.best is not None


def test_warm_start_seeds_search_from_neighbor(store, fast_search):
    import repro.core.lower_jax as LJ
    LJ.clear_block_caches()
    LJ.plan_gemm_blocks(1024, 1024, 1024)
    assert store.stats.warm_starts == 0
    LJ.plan_gemm_blocks(2048, 2048, 2048)                # miss, but neighbor
    assert store.stats.warm_starts == 1


# -------------------------------------------------- planner cache= path
def test_plan_kernel_multi_cache_roundtrip(store, fast_search):
    import repro.core.planner as P
    hw = get_hw("wormhole_8x8")
    progs = [_gemm(b=64), _gemm(b=128)]
    pc = plancache.PlanCache(store)
    before = P.PLAN_CALLS["plan_kernel_multi"]
    r1 = plan_kernel_multi(progs, hw, budget=BUDGET, profile=False, cache=pc)
    assert P.PLAN_CALLS["plan_kernel_multi"] == before + 1
    r2 = plan_kernel_multi(progs, hw, budget=BUDGET, profile=False, cache=pc)
    assert P.PLAN_CALLS["plan_kernel_multi"] == before + 1   # cache hit
    assert r2.best.plan == r1.best.plan
    assert estimate(r2.best.plan, hw) == estimate(r1.best.plan, hw)


# ------------------------------------------------------- mesh planning
def test_plan_mesh_cache_hit_skips_estimation(store, monkeypatch):
    from repro.configs import ARCHS
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.models import build_model
    from repro.parallel import planner_bridge as PB
    api = build_model(ARCHS["qwen2.5-3b"])
    shape = ShapeConfig("t", seq_len=4096, global_batch=256, kind="train")
    r1 = PB.plan_mesh(api, shape, TrainConfig())
    calls = {"n": 0}
    real = PB.estimate_plan

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(PB, "estimate_plan", counting)
    store.clear_memory()                                 # force the disk tier
    r2 = PB.plan_mesh(api, shape, TrainConfig())
    assert calls["n"] == 0
    assert [r.plan.name for r in r2] == [r.plan.name for r in r1]
    assert [r.cost.total_s for r in r2] == \
        pytest.approx([r.cost.total_s for r in r1])
    assert [r.plan.rules for r in r2] == [r.plan.rules for r in r1]
    # cache=False forces a fresh ranking
    PB.plan_mesh(api, shape, TrainConfig(), cache=False)
    assert calls["n"] > 0


def test_plan_mesh_many_matches_per_cell(store):
    """plan_mesh_many returns per-cell rankings in cell order, equal to
    calling plan_mesh per cell (the sharded warm path rides this)."""
    from repro.configs import ARCHS
    from repro.configs.shapes import SHAPES
    from repro.configs.base import TrainConfig
    from repro.models import build_model
    from repro.parallel import planner_bridge as PB
    tcfg = TrainConfig()
    shape_name = sorted(SHAPES)[0]
    cells = [("qwen2.5-3b", shape_name)]
    many = PB.plan_mesh_many(cells, tcfg, workers=1)
    direct = PB.plan_mesh(build_model(ARCHS["qwen2.5-3b"]),
                          SHAPES[shape_name], tcfg)
    assert [r.plan.name for r in many[0]] == [r.plan.name for r in direct]
    assert [r.cost.total_s for r in many[0]] == \
        pytest.approx([r.cost.total_s for r in direct])


@pytest.mark.slow
def test_plan_mesh_many_sharded_matches_inline(store):
    """The workers>1 path of plan_mesh_many (cells ranked in worker
    processes, publishing into the shared registry) returns the same
    rankings in the same order as the inline path."""
    from repro.configs.base import TrainConfig
    from repro.parallel import planner_bridge as PB
    tcfg = TrainConfig()
    from repro.configs.shapes import SHAPES
    names = sorted(SHAPES)[:2]
    cells = [("qwen2.5-3b", s) for s in names]
    inline = PB.plan_mesh_many(cells, tcfg, workers=1)
    store.prune(max_entries=0)           # force the workers to re-rank
    sharded = PB.plan_mesh_many(cells, tcfg, workers=2)
    assert [[r.plan.name for r in cell] for cell in sharded] == \
        [[r.plan.name for r in cell] for cell in inline]
    assert store.n_entries() >= len(cells)   # workers published


def test_mesh_key_ignores_shape_name_and_schedule_fields(store):
    """The AOT warmer stores registry cells ("train_4k"...); the launchers
    look up ad-hoc ShapeConfig("serve"/"cli") instances — same planning
    inputs must map to the same key."""
    from repro.configs import ARCHS
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.parallel.planner_bridge import _mesh_key
    cfg = ARCHS["qwen2.5-3b"]
    a = _mesh_key(cfg, ShapeConfig("train_4k", 4096, 256, "train"),
                  TrainConfig(), False, 3)
    b = _mesh_key(cfg, ShapeConfig("cli", 4096, 256, "train"),
                  TrainConfig(learning_rate=1e-3, total_steps=7, seed=9),
                  False, 3)
    assert a == b
    # fields estimate_plan actually reads do invalidate
    c = _mesh_key(cfg, ShapeConfig("cli", 4096, 256, "train"),
                  TrainConfig(microbatches=4), False, 3)
    assert c != a


def test_kernel_and_multi_keys_are_disjoint():
    prog = _gemm()
    hw = get_hw("wormhole_8x8")
    k_single = plancache.kernel_key([prog], hw, BUDGET, entry="kernel")
    k_multi = plancache.kernel_key([prog], hw, BUDGET, entry="kernel_multi")
    assert k_single != k_multi


# --------------------------------------------------------------- CLI
def test_cli_warm_then_stats_reports_hits(store, fast_search, capsys):
    from repro.plancache.__main__ import main
    args = ["warm", "--gemm", "512x512x512", "--skip-flash", "--skip-mesh"]
    assert main(args) == 0
    out1 = capsys.readouterr().out
    assert "new entries" in out1
    assert store.n_entries() > 0
    # re-run: everything resolves from the lru/store => >0% hit rate
    from repro.core.lower_jax import clear_block_caches
    clear_block_caches()
    store.clear_memory()
    assert main(args) == 0
    capsys.readouterr()
    assert main(["stats"]) == 0
    out = capsys.readouterr().out
    assert "entries: 1" in out
    assert "hit rate: 50.0%" in out
    assert main(["ls"]) == 0
    assert "gemm_blocks" in capsys.readouterr().out
    assert main(["prune", "--max-entries", "0"]) == 0
    assert store.n_entries() == 0
