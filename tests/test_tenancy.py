"""Multi-tenant mesh partitioning with fault-domain isolation
(DESIGN_TENANCY.md acceptance).

The load-bearing properties:

* ``submesh()`` geometry: identity pass-through, rebuilt rings, dropped
  one-plane axes, local fault renumbering, origin-independent digests;
* partition isolation (property-tested over random disjoint layouts):
  an in-partition plan resolved through the joint search is **bit-for-bit**
  the plan of the standalone submesh model;
* fault containment (property-tested over seeded kills): a core kill
  re-plans exactly the owning tenant, every other tenant's plan digest
  unchanged;
* the escalation ladder: claim-adjacent into the spare strip, global
  repartition as last resort with best-effort eviction, never guaranteed;
* the satellites: multi-axis ``best_submesh`` cuts, ``parse_faults``
  validation, atomic metrics dumps.
"""
import json
import os
import random

import pytest

from repro import plancache
from repro.core import (SearchBudget, block_shape_candidates, get_hw,
                        matmul_program, plan_kernel_multi)
from repro.core.hw import wormhole
from repro.plancache import keying
from repro.plancache.validate import dram_residency_bytes
from repro.planservice import PlanRequest, PlanService
from repro.runtime.faults import FaultSpec, parse_faults
from repro.runtime.replan import ReplanOrchestrator, best_submesh
from repro.tenancy import (IsolationValidator, MeshPartitioner, Rect,
                           TenantAdmission, TenantRuntime, TenantSpec,
                           enumerate_layouts, plan_digest, submesh)

BUDGET = SearchBudget(top_k=3, max_mappings=16, max_plans_per_mapping=10,
                      max_candidates=500)


@pytest.fixture()
def fresh_store(tmp_path, monkeypatch):
    monkeypatch.setenv(plancache.ENV_DIR, str(tmp_path))
    monkeypatch.delenv(plancache.ENV_TOGGLE, raising=False)
    plancache.reset_store()
    yield plancache.get_store()
    plancache.reset_store()


def _gemm_progs(M=256, N=256, K=256, cap=6):
    return [matmul_program(M, N, K, bm=bm, bn=bn, bk=bk)
            for bm, bn, bk in block_shape_candidates(M, N, K)][:cap]


def _service(fresh_store):
    return PlanService(cache=plancache.PlanCache(store=fresh_store))


# ----------------------------------------------------------------- submesh
def test_submesh_identity_passthrough():
    hw = get_hw("wormhole_8x8")
    assert submesh(hw, (0, 0), (8, 8)) is hw


def test_submesh_geometry_matches_preset_shape():
    hw = get_hw("wormhole_8x8")
    sub = submesh(hw, (2, 0), (4, 8))
    assert sub.mesh_dims == (("x", 4), ("y", 8))
    assert sub.n_cores == 32
    # rings rebuilt with the new modulus, bandwidth preserved
    assert {ic.name for ic in sub.interconnects} == {"noc_h", "noc_v"}
    # an axis shrunk to one plane loses its ring (like the 1x8 preset)
    one = submesh(hw, (3, 0), (1, 8))
    assert [ic.name for ic in one.interconnects] == ["noc_v"]
    assert get_hw("wormhole_1x8").interconnects[0].name == "noc_v"


def test_submesh_digest_is_origin_independent():
    hw = get_hw("wormhole_8x8")
    a = submesh(hw, (0, 0), (4, 8))
    b = submesh(hw, (4, 0), (4, 8))
    assert a.df_text() == b.df_text()
    assert keying.hw_digest(a) == keying.hw_digest(b)
    # ...but shape forks the digest from the parent and from other shapes
    assert keying.hw_digest(a) != keying.hw_digest(hw)
    assert keying.hw_digest(a) != keying.hw_digest(submesh(hw, (0, 0),
                                                           (8, 4)))


def test_submesh_renumbers_local_faults():
    hw = get_hw("wormhole_8x8").with_faults(disabled_cores=[(5, 3), (1, 1)])
    sub = submesh(hw, (4, 0), (4, 8))
    # (5,3) is inside the window -> local (1,3); (1,1) is outside -> gone
    assert sub.disabled_cores == ((1, 3),)
    assert sub.is_degraded
    healthy = submesh(hw, (0, 2), (1, 1))   # window avoiding both faults
    assert not healthy.is_degraded


def test_submesh_rejects_bad_windows():
    hw = get_hw("wormhole_8x8")
    with pytest.raises(ValueError):
        submesh(hw, (6, 0), (4, 8))          # walks off the mesh
    with pytest.raises(ValueError):
        submesh(hw, (0, 0), (4,))            # rank mismatch
    dead = hw.with_faults(disabled_cores=[(0, 0)])
    with pytest.raises(ValueError):
        submesh(dead, (0, 0), (1, 1))        # no healthy cores inside


# ---------------------------------------------------------------- layouts
def test_enumerate_layouts_disjoint_and_covering():
    region = Rect((0, 0), (8, 8))
    layouts = enumerate_layouts(region, [1.0, 2.0, 1.0])
    assert layouts
    for layout in layouts:
        cells = [c for r in layout for c in r.cells()]
        assert len(cells) == len(set(cells)) == 64    # disjoint + covering
        for a_i, a in enumerate(layout):
            for b in layout[a_i + 1:]:
                assert not a.overlaps(b)
    # deterministic for fixed inputs
    again = enumerate_layouts(region, [1.0, 2.0, 1.0])
    assert layouts == again


def test_enumerate_layouts_weight_bias():
    # first layout's cut should track the weight share (6:2 on 8 planes)
    layouts = enumerate_layouts(Rect((0, 0), (8, 8)), [3.0, 1.0])
    first = layouts[0]
    assert first[0].n_cells == 48 and first[1].n_cells == 16


# ------------------------------------------- property: partition isolation
def test_partition_plans_equal_standalone_submesh_plans(fresh_store,
                                                        fast_search,
                                                        tmp_path):
    """For random disjoint partitions, the per-tenant plan resolved
    through the joint search is bit-for-bit the plan a *standalone*
    service resolves for the bare submesh model, given the identical
    request history.  Both services start cold and replay the same
    resolve sequence: warm-start reordering (cache.order_programs) is
    deterministic in the request stream, so any digest drift would mean
    partition origin or a co-tenant leaked into the search."""
    hw = wormhole(4, 4)
    service = _service(fresh_store)
    twin = PlanService(cache=plancache.PlanCache(
        store=plancache.PlanCacheStore(root=tmp_path / "twin")))
    rng = random.Random(7)
    progs_a = _gemm_progs(128, 128, 128, cap=4)
    progs_b = _gemm_progs(128, 256, 128, cap=4)
    layouts = enumerate_layouts(Rect((0, 0), (4, 4)), [1.0, 1.0])
    for layout in rng.sample(layouts, 2):
        tenants = [TenantSpec("a", progs_a), TenantSpec("b", progs_b)]
        mp = MeshPartitioner(plan_layouts=1, max_layouts=1,
                             cuts_per_split=1)
        # pin the partitioner to this exact layout so the comparison is
        # per-rect, not per-search-winner
        mp_layouts = lambda *a, **k: [layout]   # noqa: E731
        import repro.tenancy.partition as part_mod
        orig = part_mod.enumerate_layouts
        part_mod.enumerate_layouts = mp_layouts
        try:
            # regret_bound=0 disables the shape-family rung: tenant b must
            # not be served a certified transplant of tenant a's cached
            # plan (same template + origin-independent hw digest) — the
            # property is about exact in-partition searches
            plan = mp.plan(hw, tenants, service=service, budget=BUDGET,
                           budget_ms=float("inf"), regret_bound=0.0)
        finally:
            part_mod.enumerate_layouts = orig
        for p, progs in zip(plan.placements, (progs_a, progs_b)):
            standalone = twin.resolve(PlanRequest(
                programs=list(progs),
                hw=submesh(hw, p.rect.origin, p.rect.shape),
                budget=BUDGET, budget_ms=float("inf"), regret_bound=0.0))
            assert standalone.rung == p.rung
            assert plan_digest(p.plan) == \
                plan_digest(standalone.result.best.plan)


# ------------------------------------------- property: fault containment
def test_seeded_kill_replans_exactly_one_tenant(fresh_store, fast_search):
    hw = get_hw("wormhole_8x8")
    service = _service(fresh_store)
    tenants = [TenantSpec("a", _gemm_progs(256, 256, 256)),
               TenantSpec("b", _gemm_progs(128, 512, 256),
                          qos="best_effort")]
    mp = MeshPartitioner(plan_layouts=1)
    plan = mp.plan(hw, tenants, service=service, budget=BUDGET,
                   budget_ms=float("inf"))
    assert IsolationValidator().validate(plan) == []
    rng = random.Random(20260807)
    for trial in range(2):
        runtime = TenantRuntime(plan, service=service, cache=service.cache,
                                budget=BUDGET, partitioner=mp,
                                latency_budget_s=120.0)
        victim = plan.placements[trial % len(plan.placements)]
        cells = sorted(victim.rect.cells())
        cell = cells[rng.randrange(len(cells))]
        before = plan.digests()
        ev = runtime.kill_core(cell)
        assert ev.owner == victim.tenant.name
        assert ev.blast_radius == 1
        assert ev.replanned == (victim.tenant.name,)
        assert ev.contained()
        after = runtime.plan.digests()
        for name, d in before.items():
            if name != victim.tenant.name:
                assert after[name] == d       # byte-identical, on the bytes
        assert ev.within_budget
        assert IsolationValidator().validate(runtime.plan) == []
        plan = mp.plan(hw, tenants, service=service, budget=BUDGET,
                       budget_ms=float("inf"))   # fresh plan per trial


def test_kill_in_spare_region_replans_nobody(fresh_store, fast_search):
    hw = get_hw("wormhole_8x8")
    service = _service(fresh_store)
    tenants = [TenantSpec("a", _gemm_progs()), TenantSpec("b", _gemm_progs())]
    mp = MeshPartitioner(spare_planes=2, plan_layouts=1)
    plan = mp.plan(hw, tenants, service=service, budget=BUDGET,
                   budget_ms=float("inf"))
    assert plan.region.shape == (6, 8)
    runtime = TenantRuntime(plan, service=service, cache=service.cache,
                            budget=BUDGET, partitioner=mp)
    ev = runtime.kill_core((7, 7))            # inside the spare strip
    assert ev.owner is None and ev.rung == "none"
    assert ev.blast_radius == 0 and ev.contained()
    assert runtime.plan.digests() == plan.digests()


def test_claim_adjacent_grows_into_spare_strip(fresh_store, fast_search):
    hw = get_hw("wormhole_8x8")
    service = _service(fresh_store)
    tenants = [TenantSpec("a", _gemm_progs()), TenantSpec("b", _gemm_progs())]
    mp = MeshPartitioner(spare_planes=1, plan_layouts=1)
    plan = mp.plan(hw, tenants, service=service, budget=BUDGET,
                   budget_ms=float("inf"))
    # claim_threshold=0 makes every shrink "too slow", forcing escalation
    runtime = TenantRuntime(plan, service=service, cache=service.cache,
                            budget=BUDGET, partitioner=mp,
                            latency_budget_s=120.0, claim_threshold=0.0)
    edge = max(plan.placements, key=lambda p: p.rect.end[0])
    cell = next(iter(edge.rect.cells()))
    rect_before = edge.rect          # the runtime mutates placements in place
    ev = runtime.kill_core(cell)
    assert ev.owner == edge.tenant.name
    assert ev.rung == "claim_adjacent"
    assert ev.blast_radius == 1 and ev.contained()
    grown = runtime.plan.placement(edge.tenant.name).rect
    # exactly one plane claimed along exactly one axis of the old rect
    diffs = sorted(n - o for n, o in zip(grown.shape, rect_before.shape))
    assert diffs == [0, 1]
    assert grown.n_cells > rect_before.n_cells
    assert IsolationValidator().validate(runtime.plan) == []


def test_repartition_last_resort_evicts_best_effort_only(fresh_store,
                                                         fast_search):
    hw = wormhole(2, 2)
    service = _service(fresh_store)
    tenants = [TenantSpec("g", _gemm_progs(cap=3)),
               TenantSpec("e", _gemm_progs(128, 128, 128, cap=3),
                          qos="best_effort")]
    mp = MeshPartitioner(plan_layouts=1)
    plan = mp.plan(hw, tenants, service=service, budget=BUDGET,
                   budget_ms=float("inf"))
    runtime = TenantRuntime(plan, service=service, cache=service.cache,
                            budget=BUDGET, partitioner=mp,
                            latency_budget_s=120.0)
    victim = plan.placements[0]
    cells = sorted(victim.rect.cells())
    runtime.kill_core(cells[0])               # shrink in place (1 core left)
    ev = runtime.kill_core(cells[1])          # partition fully dead -> rung 3
    assert ev.rung == "repartition"
    assert IsolationValidator().validate(runtime.plan) == []
    # bounded disruption: best-effort rode the fallback rung, guaranteed
    # got a real resolve
    rungs = {p.tenant.name: p.response for p in runtime.plan.placements}
    assert getattr(rungs["e"], "rung", "") == "fallback"
    assert getattr(rungs["g"], "rung", "") != "fallback"
    # every surviving partition avoids the dead cells
    dead = set(runtime.hw.disabled_cores)
    for p in runtime.plan.placements:
        healthy = set(p.rect.cells()) - dead
        assert healthy


# ----------------------------------------------------------- QoS admission
def test_admission_guaranteed_never_shed():
    adm = TenantAdmission(max_best_effort=0)
    g = TenantSpec("g", _gemm_progs(cap=1))
    with adm.admit(g, 25.0) as ms:
        assert ms == 25.0


def test_admission_sheds_best_effort_to_fallback_deadline():
    adm = TenantAdmission(max_best_effort=1)
    e1 = TenantSpec("e1", _gemm_progs(cap=1), qos="best_effort")
    e2 = TenantSpec("e2", _gemm_progs(cap=1), qos="best_effort")
    with adm.admit(e1, 25.0) as ms1:
        assert ms1 == 25.0
        with adm.admit(e2, 25.0) as ms2:
            assert ms2 == 0.0                 # shed: fallback rung only
    with adm.admit(e2, 25.0) as ms:           # slot freed -> admitted
        assert ms == 25.0
    assert adm.shed_total == {"e2": 1}


def test_shed_deadline_walks_service_to_fallback(fresh_store, fast_search):
    service = _service(fresh_store)
    hw = get_hw("wormhole_4x8")
    resp = service.resolve(PlanRequest(programs=_gemm_progs(cap=3), hw=hw,
                                       budget=BUDGET, budget_ms=0.0))
    assert resp.rung == "fallback" and resp.ok


# ------------------------------------------------------ isolation validator
def test_validator_rejects_overlap_and_off_mesh(fresh_store, fast_search):
    hw = get_hw("wormhole_8x8")
    service = _service(fresh_store)
    tenants = [TenantSpec("a", _gemm_progs()), TenantSpec("b", _gemm_progs())]
    plan = MeshPartitioner(plan_layouts=1).plan(
        hw, tenants, service=service, budget=BUDGET, budget_ms=float("inf"))
    assert IsolationValidator().validate(plan) == []
    a, b = plan.placements
    b.rect = a.rect                           # force an overlap
    bad = IsolationValidator().validate(plan)
    assert any("overlap" in v for v in bad)
    b.rect = Rect((6, 0), (4, 8))             # walks off the mesh
    bad = IsolationValidator().validate(plan)
    assert any("exceeds" in v for v in bad)


def test_validator_checks_joint_dram_residency(fresh_store, fast_search):
    hw = get_hw("wormhole_8x8")
    service = _service(fresh_store)
    tenants = [TenantSpec("a", _gemm_progs()), TenantSpec("b", _gemm_progs())]
    plan = MeshPartitioner(plan_layouts=1).plan(
        hw, tenants, service=service, budget=BUDGET, budget_ms=float("inf"))
    for p in plan.placements:
        assert dram_residency_bytes(p.plan) > 0
    tight = IsolationValidator(dram_slack=1e-12)
    assert any("DRAM residency" in v for v in tight.validate(plan))


def test_validator_catches_out_of_partition_binds(fresh_store, fast_search):
    hw = get_hw("wormhole_8x8")
    service = _service(fresh_store)
    tenants = [TenantSpec("a", _gemm_progs()), TenantSpec("b", _gemm_progs())]
    plan = MeshPartitioner(plan_layouts=1).plan(
        hw, tenants, service=service, budget=BUDGET, budget_ms=float("inf"))
    p = plan.placements[0]
    # shrink the rect under the plan: binds now exceed the partition
    p.rect = Rect(p.rect.origin, (1, 1))
    p.hw = submesh(hw, p.rect.origin, p.rect.shape)
    bad = IsolationValidator().validate(plan)
    assert any("exceeds partition" in v or "outside mesh" in v
               or "size" in v for v in bad)


# --------------------------------------------------- orchestrator wiring
def test_orchestrator_routes_through_tenancy(fresh_store, fast_search):
    hw = get_hw("wormhole_8x8")
    service = _service(fresh_store)
    tenants = [TenantSpec("a", _gemm_progs()), TenantSpec("b", _gemm_progs())]
    mp = MeshPartitioner(plan_layouts=1)
    plan = mp.plan(hw, tenants, service=service, budget=BUDGET,
                   budget_ms=float("inf"))
    runtime = TenantRuntime(plan, service=service, cache=service.cache,
                            budget=BUDGET, partitioner=mp,
                            latency_budget_s=120.0)
    orch = ReplanOrchestrator(hw, _gemm_progs(), cache=service.cache,
                              budget=BUDGET, tenancy=runtime)
    cell = next(iter(plan.placements[0].rect.cells()))
    ev = orch.kill_cores([cell])
    assert ev.blast_radius == 1 and ev.contained()
    assert orch.current_hw.disabled_cores == (cell,)


# ----------------------------------------------------- satellite coverage
def test_best_submesh_single_fault_unchanged():
    hw = get_hw("wormhole_8x8")
    sub = best_submesh(hw.with_faults(disabled_cores=[(1, 2)]))
    assert sub.name == "wormhole_8x8_sub_x7"
    assert sub.mesh_dims == (("x", 7), ("y", 8))


def test_best_submesh_multi_axis_cut_keeps_more_cores():
    hw = get_hw("wormhole_8x8")
    # faults spanning both axes: one row + one column keeps 7x7=49 > 48
    sub = best_submesh(hw.with_faults(disabled_cores=[(1, 2), (5, 6)]))
    assert sub.n_cores == 49
    assert sub.mesh_dims == (("x", 7), ("y", 7))
    # same-row faults: single-plane drop still optimal (unchanged)
    sub2 = best_submesh(hw.with_faults(disabled_cores=[(1, 2), (1, 6)]))
    assert sub2.mesh_dims == (("x", 7), ("y", 8))
    # three faults, two rows + one shared column: 6x8=48 vs 7x7=49
    sub3 = best_submesh(hw.with_faults(
        disabled_cores=[(1, 2), (5, 2), (6, 3)]))
    assert sub3.n_cores == 49


def test_parse_faults_rejects_bad_factor_and_duplicates():
    with pytest.raises(ValueError, match=r"\(0, 1\]"):
        parse_faults("link:noc_h:0")
    with pytest.raises(ValueError, match=r"\(0, 1\]"):
        parse_faults("link:noc_h:1.5")
    with pytest.raises(ValueError, match="already killed"):
        parse_faults("core:3,5;core:3,5@2")
    with pytest.raises(ValueError, match="duplicate fault item"):
        parse_faults("link:noc_h:0.5;link:noc_h:0.5")
    ok = parse_faults("core:3,5;link:noc_h:0.5@2;straggler:1;crash")
    assert len(ok) == 4


def test_metrics_dump_is_atomic(tmp_path):
    from repro.obs import metrics
    metrics.inc("tenancy_test_total")
    path = tmp_path / "metrics.json"
    out = metrics.dump(str(path))
    assert out == str(path)
    data = json.loads(path.read_text())
    assert data["tenancy_test_total"]["type"] == "counter"
    assert [p.name for p in tmp_path.iterdir()] == ["metrics.json"]
    # the env-driven path still works and stays atomic
    os.environ["REPRO_METRICS"] = str(tmp_path / "env.json")
    try:
        assert metrics.dump() == str(tmp_path / "env.json")
        assert json.loads((tmp_path / "env.json").read_text())
    finally:
        os.environ.pop("REPRO_METRICS", None)


# -------------------------------------------------- tenancy metric labels
def test_containment_emits_blast_radius_metrics(fresh_store, fast_search):
    from repro.obs import metrics
    hw = get_hw("wormhole_8x8")
    service = _service(fresh_store)
    tenants = [TenantSpec("a", _gemm_progs()), TenantSpec("b", _gemm_progs())]
    mp = MeshPartitioner(plan_layouts=1)
    plan = mp.plan(hw, tenants, service=service, budget=BUDGET,
                   budget_ms=float("inf"))
    runtime = TenantRuntime(plan, service=service, cache=service.cache,
                            budget=BUDGET, partitioner=mp,
                            latency_budget_s=120.0)
    owner = plan.placements[0]
    before = metrics.REGISTRY.counter("tenancy_replan_total").value(
        tenant=owner.tenant.name, rung="shrink_in_place")
    # REGISTRY is process-global: assert on deltas, not absolute state
    h0 = metrics.REGISTRY.histogram("tenancy_blast_radius").series(
        cause="core_kill")
    count0, sum0 = (h0.count, h0.sum) if h0 is not None else (0, 0.0)
    runtime.kill_core(next(iter(owner.rect.cells())))
    after = metrics.REGISTRY.counter("tenancy_replan_total").value(
        tenant=owner.tenant.name, rung="shrink_in_place")
    assert after == before + 1
    hist = metrics.REGISTRY.histogram("tenancy_blast_radius").series(
        cause="core_kill")
    assert hist is not None and hist.count == count0 + 1
    assert hist.sum - sum0 == 1.0        # this kill's blast radius was 1
