"""Plan-service tests: the deadline ladder (exact hit, certified family
neighbor, bounded search, guaranteed fallback), the never-raise contract,
coalescing, admission shedding, the circuit breaker, background completion,
and the plancache integrity hardening (checksums, quarantine, validator,
warm-start robustness, stats-file corruption)."""
import dataclasses
import json
import threading

import pytest

from repro import plancache
from repro.core import SearchBudget, get_hw, matmul_program, plan_kernel_multi
from repro.core.planner import PLAN_CALLS
from repro.plancache import PlanCache, QUARANTINE_DIR, keying, warmstart
from repro.plancache.serialize import plan_to_dict
from repro.plancache.validate import validate_plan
from repro.planservice import PlanRequest, PlanService, default_regret

BUDGET = SearchBudget(top_k=2, max_mappings=16, max_plans_per_mapping=8,
                      max_candidates=400)
HW = "wormhole_1x8"


@pytest.fixture()
def store(tmp_path, monkeypatch):
    monkeypatch.setenv(plancache.ENV_DIR, str(tmp_path))
    monkeypatch.delenv(plancache.ENV_TOGGLE, raising=False)
    plancache.reset_store()
    yield plancache.get_store()
    plancache.reset_store()


def _candidates(M=256, N=256, K=256):
    return [matmul_program(M, N, K, bm=b, bn=b, bk=b) for b in (32, 64)]


# ------------------------------------------------------------ the ladder
def test_full_budget_is_bit_identical_then_cached(store):
    """No deadline => the service is a pass-through to plan_kernel_multi
    (acceptance criterion), and the result lands under the exact key so
    the repeat request is a rung-1 hit."""
    hw = get_hw(HW)
    progs = _candidates()
    svc = PlanService(PlanCache(store))
    req = PlanRequest(progs, hw, budget=BUDGET, budget_ms=float("inf"),
                      background=False)
    resp = svc.resolve(req)
    assert resp.ok and resp.rung == "search" and resp.outcome == "ok"
    direct = plan_kernel_multi(progs, hw, budget=BUDGET, cache=None)
    assert plan_to_dict(resp.plan) == plan_to_dict(direct.best.plan)
    assert resp.result.best.final_s == direct.best.final_s
    resp2 = svc.resolve(req)
    assert resp2.ok and resp2.rung == "cache" and resp2.outcome == "ok"
    assert plan_to_dict(resp2.plan) == plan_to_dict(direct.best.plan)


def test_zero_deadline_returns_generic_fallback(store):
    """budget_ms=0 leaves no time for any rung but the guaranteed one:
    still a valid runnable plan, still no exception."""
    svc = PlanService(PlanCache(store))
    resp = svc.resolve(PlanRequest(_candidates(), get_hw(HW), budget=BUDGET,
                                   budget_ms=0.0, background=False))
    assert resp.ok and resp.rung == "fallback" and resp.outcome == "deadline"
    assert validate_plan(resp.plan, resp.hw) == []


def test_empty_program_list_is_infeasible_not_an_exception(store):
    svc = PlanService(PlanCache(store))
    resp = svc.resolve(PlanRequest([], get_hw(HW), budget=BUDGET,
                                   budget_ms=5.0, background=False))
    assert not resp.ok and resp.outcome == "infeasible"
    assert resp.rung == "fallback" and resp.plan is None


def test_shed_to_fallback_when_no_search_slots(store):
    """max_concurrent_searches=0 models total overload: every request
    sheds to the fallback rung instead of queueing."""
    svc = PlanService(PlanCache(store), max_concurrent_searches=0)
    resp = svc.resolve(PlanRequest(_candidates(), get_hw(HW), budget=BUDGET,
                                   budget_ms=float("inf"), background=False))
    assert resp.ok and resp.rung == "fallback" and resp.outcome == "shed"
    assert resp.background is False
    assert validate_plan(resp.plan, resp.hw) == []


def test_family_rung_certifies_cached_neighbor(store):
    """Seed the store with a 512-cubed GEMM plan, then ask for a GEMM of
    a nearby shape with searching disabled: the service must answer from
    the shape-family rung, and the certified plan must be within the
    regret bound of the exact plan's simulated cost (acceptance
    criterion, via the admissible program floor)."""
    hw = get_hw(HW)
    cache = PlanCache(store)
    plan_kernel_multi(_candidates(512, 512, 512), hw, budget=BUDGET,
                      cache=cache)
    req_progs = [matmul_program(640, 512, 512, bm=64, bn=64, bk=64)]
    svc = PlanService(cache, max_concurrent_searches=0)
    resp = svc.resolve(PlanRequest(req_progs, hw, budget=BUDGET,
                                   budget_ms=float("inf"), background=False))
    assert resp.ok and resp.rung == "family" and resp.outcome == "ok"
    assert validate_plan(resp.plan, resp.hw) == []
    assert resp.plan.program == req_progs[0]        # retargeted, not reused
    exact = plan_kernel_multi(req_progs, hw, budget=BUDGET, cache=None)
    assert resp.result.best.final_s \
        <= default_regret() * exact.best.final_s


def test_coalesced_concurrent_requests_do_exactly_one_search(store):
    hw = get_hw(HW)
    progs = _candidates()
    svc = PlanService(PlanCache(store), max_concurrent_searches=4)
    req = PlanRequest(progs, hw, budget=BUDGET, budget_ms=float("inf"),
                      background=False)
    n = 4
    before = PLAN_CALLS["plan_kernel_multi"]
    barrier = threading.Barrier(n)
    out = [None] * n

    def worker(i):
        barrier.wait()
        out[i] = svc.resolve(req)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert PLAN_CALLS["plan_kernel_multi"] - before == 1
    assert all(r is not None and r.ok for r in out)
    assert sum(r.outcome == "coalesced" for r in out) == n - 1
    best = {plan_to_dict(r.plan) == plan_to_dict(out[0].plan) for r in out}
    assert best == {True}                            # one answer for all


def test_background_completion_promotes_to_exact_hit(store):
    """A deadline-forced fallback schedules the full search off-path; once
    drained, the identical request is a rung-1 exact hit (acceptance
    criterion)."""
    hw = get_hw(HW)
    progs = _candidates()
    svc = PlanService(PlanCache(store))
    r1 = svc.resolve(PlanRequest(progs, hw, budget=BUDGET, budget_ms=0.0,
                                 background=True))
    assert r1.ok and r1.rung == "fallback" and r1.background
    assert svc.drain(timeout_s=300.0)
    r2 = svc.resolve(PlanRequest(progs, hw, budget=BUDGET,
                                 budget_ms=float("inf"), background=False))
    assert r2.ok and r2.rung == "cache"


def test_breaker_opens_after_misses_and_recovers_half_open(store):
    """Two synthetic deadline misses open the (template, hw) breaker; while
    open the search rung is skipped outright; after the cooldown one
    half-open trial runs and, on success, closes it again."""
    hw = get_hw(HW)
    progs = _candidates()
    good = plan_kernel_multi(progs, hw, budget=BUDGET, cache=None)

    class Clock:
        def __init__(self):
            self.t = 0.0

        def __call__(self):
            return self.t

    clk = Clock()
    cache = PlanCache(store)
    svc = PlanService(cache, breaker_threshold=2, breaker_cooldown_s=10.0,
                      clock=clk)
    mode = {"slow": True}

    def fake_search(request, budget, remaining_s):
        if mode["slow"]:
            clk.t += 1.0                 # blow way past a 10ms deadline
            raise RuntimeError("synthetic slow search")
        return good, False, hw

    svc._do_search = fake_search
    req = PlanRequest(progs, hw, budget=BUDGET, budget_ms=10.0,
                      background=False)
    r1 = svc.resolve(req)
    assert r1.ok and r1.rung == "fallback" and r1.outcome == "deadline"
    svc._ewma.clear()   # the EWMA would (correctly) pre-skip the slow rung
    r2 = svc.resolve(req)
    assert r2.rung == "fallback"
    svc._ewma.clear()
    r3 = svc.resolve(req)
    assert r3.ok and r3.outcome == "breaker_open"    # skipped, not run
    (bkey,) = svc._breakers
    assert svc._breakers[bkey].state == "open"
    clk.t += 11.0                        # past the cooldown
    mode["slow"] = False
    svc._ewma.clear()
    r4 = svc.resolve(req)
    assert r4.ok and r4.rung == "search" and r4.outcome == "ok"
    assert svc._breakers[bkey].state == "closed"


# ---------------------------------------------------- integrity hardening
def test_corrupt_entry_is_quarantined_and_request_still_succeeds(store):
    hw = get_hw(HW)
    progs = _candidates()
    svc = PlanService(PlanCache(store))
    req = PlanRequest(progs, hw, budget=BUDGET, budget_ms=float("inf"),
                      background=False)
    r1 = svc.resolve(req)
    assert r1.rung == "search"
    path = store._path(r1.key)
    ent = json.loads(path.read_text())
    ent["payload"]["tampered"] = True    # payload no longer matches "sum"
    path.write_text(json.dumps(ent))
    store.clear_memory()
    corrupt0 = store.stats.corrupt
    r2 = svc.resolve(req)
    assert r2.ok
    assert r2.rung != "cache"            # the tampered entry was not served
    assert store.stats.corrupt == corrupt0 + 1
    qdir = store.root / QUARANTINE_DIR
    assert qdir.is_dir() and any(qdir.iterdir())


def test_truncated_entry_quarantined_as_decode(store):
    store.put("feedaa", {"x": 1}, {"template": "t", "hw": "h", "shape": [1]})
    path = store._path("feedaa")
    path.write_text("{truncated json")
    store.clear_memory()
    corrupt0 = store.stats.corrupt
    assert store.get("feedaa") is None
    assert store.stats.corrupt == corrupt0 + 1
    assert not path.exists()             # moved to quarantine, self-healing
    assert any((store.root / QUARANTINE_DIR).iterdir())


def test_validate_plan_accepts_real_and_rejects_tampered(store):
    hw = get_hw(HW)
    res = plan_kernel_multi(_candidates(), hw, budget=BUDGET, cache=None)
    plan = res.best.plan
    assert validate_plan(plan, hw) == []
    bind = dataclasses.replace(plan.mapping.spatial[0], hw_size=4096)
    bad_map = dataclasses.replace(
        plan.mapping, spatial=(bind,) + tuple(plan.mapping.spatial[1:]))
    bad = dataclasses.replace(plan, mapping=bad_map)
    problems = validate_plan(bad, hw)
    assert problems and any("exceeds" in p or "mesh" in p for p in problems)


def test_corrupt_stats_file_is_counted_and_reset(store):
    store.put("k1", {"v": 1}, {})
    store.flush_stats()
    stats_path = store.root / plancache.store.STATS_FILE
    assert stats_path.exists()
    stats_path.write_text("{broken")
    corrupt0 = store.stats.corrupt
    assert store.cumulative_stats() == {}
    assert store.stats.corrupt == corrupt0 + 1
    assert not stats_path.exists()


def test_nearest_k_is_deterministic_and_nearest_first(store):
    meta = lambda shape: {"template": "t", "hw": "h", "shape": shape}  # noqa: E731
    store.put("k256", {"v": 1}, meta([256, 256]))
    store.put("k512", {"v": 2}, meta([512, 512]))
    store.put("k1024", {"v": 3}, meta([1024, 1024]))
    got = [e["key"] for e in store.nearest_k("t", "h", [300, 300], k=3)]
    assert got[0] == "k256" and set(got) == {"k256", "k512", "k1024"}
    assert got == [e["key"] for e in store.nearest_k("t", "h", [300, 300])]
    assert store.nearest_k("other", "h", [1, 1]) == []


# ------------------------------------------------------ warm-start repair
def test_order_programs_empty_single_and_no_hint():
    progs = _candidates()
    assert warmstart.order_programs([], None) == []
    assert warmstart.order_programs([progs[0]], {"A": [1, 1, 1]}) \
        == [progs[0]]
    assert warmstart.order_programs(progs, None) == progs
    assert warmstart.order_programs(progs, {}) == progs


def test_order_programs_survives_corrupt_hints():
    progs = _candidates()
    assert warmstart.order_programs(progs, ["not", "a", "dict"]) == progs
    assert warmstart.order_programs(progs, {"A": "scalar"}) == progs
    assert warmstart.order_programs(progs, {"A": [1, "x"]}) == progs


def test_warm_order_from_store_empty_and_corrupt_tiles(store):
    hw = get_hw(HW)
    progs = _candidates()
    template = keying.template_signature(progs[0])
    hwd = keying.hw_digest(hw)
    shape = keying.shape_vector(progs[0])
    # empty store: original order, no exception
    assert warmstart.warm_order_from_store(store, template, hwd, shape,
                                           progs) == progs
    # an entry whose tiles hint is a list (corrupt) must not break ordering
    store.put("bad", {"x": 1}, {"template": template, "hw": hwd,
                                "shape": shape, "tiles": [64, 64, 64]})
    assert warmstart.warm_order_from_store(store, template, hwd, shape,
                                           progs) == progs
