"""Per-kernel allclose sweeps vs. the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import combine_partials, flash_decode_partials
from repro.kernels.gemm import gemm
from repro.kernels.moe_gmm import grouped_matmul
from repro.kernels.rwkv6 import wkv6

KEY = jax.random.PRNGKey(0)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------------- GEMM
@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 128, 384),
                                   (128, 256, 128), (512, 256, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_sweep(shape, dtype):
    M, N, K = shape
    k1, k2 = jax.random.split(KEY)
    a = _rand(k1, (M, K), dtype)
    b = _rand(k2, (K, N), dtype)
    out = gemm(a, b, block=(128, 128, 128), out_dtype=jnp.float32,
               interpret=True)
    expect = ref.gemm_ref(a, b, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), **_tol(dtype))


def test_gemm_ops_wrapper_fits_blocks():
    a = _rand(KEY, (96, 160), jnp.float32)
    b = _rand(KEY, (160, 64), jnp.float32)
    out = ops.matmul(a, b, block=(128, 128, 128))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.gemm_ref(a, b)),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------- FlashAttention
@pytest.mark.parametrize("seq,blocks", [(256, (128, 128)), (256, (64, 128)),
                                        (512, (128, 256))])
@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(seq, blocks, causal, dtype):
    BH, d = 4, 64
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (BH, seq, d), dtype)
    k = _rand(k2, (BH, seq, d), dtype)
    v = _rand(k3, (BH, seq, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=blocks[0],
                          block_kv=blocks[1], interpret=True)
    expect = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_flash_attention_cross_attention_shapes():
    """Sq != Skv (encoder-decoder cross attention)."""
    q = _rand(KEY, (2, 128, 64), jnp.float32)
    k = _rand(KEY, (2, 384, 64), jnp.float32)
    v = _rand(KEY, (2, 384, 64), jnp.float32)
    out = flash_attention(q, k, v, block_q=128, block_kv=128, interpret=True)
    expect = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ FlashDecode
@pytest.mark.parametrize("skv,splits", [(1024, 4), (2048, 8), (512, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(skv, splits, dtype):
    BH, d = 4, 64
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (BH, 1, d), dtype)
    k = _rand(k2, (BH, skv, d), dtype)
    v = _rand(k3, (BH, skv, d), dtype)
    m, l, acc = flash_decode_partials(q, k, v, kv_splits=splits,
                                      block_kv=256, interpret=True)
    out = combine_partials(m, l, acc)
    expect = ref.decode_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_flash_decode_matches_flash_attention():
    BH, skv, d = 2, 512, 64
    q = _rand(KEY, (BH, 1, d), jnp.float32)
    k = _rand(KEY, (BH, skv, d), jnp.float32)
    v = _rand(KEY, (BH, skv, d), jnp.float32)
    dec = ops.flash_decode(q, k, v, kv_splits=4)
    fa = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(fa),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ RWKV6
@pytest.mark.parametrize("T,chunk", [(64, 32), (128, 32), (96, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_wkv6_sweep(T, chunk, dtype):
    BH, d = 3, 32
    keys = jax.random.split(KEY, 5)
    r = _rand(keys[0], (BH, T, d), dtype)
    k = _rand(keys[1], (BH, T, d), dtype)
    v = _rand(keys[2], (BH, T, d), dtype)
    # realistic RWKV6 decay: log w = -exp(x), mildly negative
    log_w = -jnp.exp(jax.random.normal(keys[3], (BH, T, d)) * 0.5 - 1.0)
    u = _rand(keys[4], (BH, d), dtype) * 0.5
    out = wkv6(r, k, v, log_w, u, chunk=chunk, interpret=True)
    expect = ref.wkv6_ref(r, k, v, log_w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)


def test_wkv6_state_carries_across_chunks():
    """Chunked result must differ from concatenating independent chunks
    (i.e. the state genuinely propagates)."""
    BH, T, d = 1, 64, 16
    keys = jax.random.split(KEY, 5)
    r = _rand(keys[0], (BH, T, d), jnp.float32)
    k = _rand(keys[1], (BH, T, d), jnp.float32)
    v = _rand(keys[2], (BH, T, d), jnp.float32)
    log_w = -jnp.exp(jax.random.normal(keys[3], (BH, T, d)) * 0.3 - 1.0)
    u = _rand(keys[4], (BH, d), jnp.float32)
    full = wkv6(r, k, v, log_w, u, chunk=32, interpret=True)
    halves = jnp.concatenate([
        wkv6(r[:, :32], k[:, :32], v[:, :32], log_w[:, :32], u,
             chunk=32, interpret=True),
        wkv6(r[:, 32:], k[:, 32:], v[:, 32:], log_w[:, 32:], u,
             chunk=32, interpret=True)], axis=1)
    assert not np.allclose(np.asarray(full[:, 32:]),
                           np.asarray(halves[:, 32:]), atol=1e-3)


# ------------------------------------------------------------ MoE grouped
@pytest.mark.parametrize("shape", [(4, 128, 128, 128), (8, 256, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_sweep(shape, dtype):
    E, cap, din, dout = shape
    k1, k2 = jax.random.split(KEY)
    x = _rand(k1, (E, cap, din), dtype)
    w = _rand(k2, (E, din, dout), dtype)
    out = grouped_matmul(x, w, block=(128, 128, 128), out_dtype=jnp.float32,
                         interpret=True)
    expect = ref.grouped_matmul_ref(x, w, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), **_tol(dtype))


# -------------------------------------------------- planner-chosen blocks
def test_planner_blocks_are_mxu_aligned_and_fit_vmem(fast_search):
    from repro.core.lower_jax import (clear_block_caches, plan_gemm_blocks,
                                      plan_flash_blocks)
    from repro.core.hw import TPU_V5E_VMEM_BYTES
    clear_block_caches()
    bm, bn, bk = plan_gemm_blocks(4096, 4096, 4096, jnp.bfloat16)
    assert bm % 128 == 0 and bn % 128 == 0 and bk % 128 == 0
    # A + B double buffered + f32 accumulator within VMEM
    need = 2 * (bm * bk + bk * bn) * 2 + bm * bn * 4
    assert need <= TPU_V5E_VMEM_BYTES
    bq, bkv = plan_flash_blocks(4096, 4096, 128, jnp.bfloat16)
    assert bq % 128 == 0 and bkv % 128 == 0


# ------------------------------------------------- fused head + cross-entropy
def test_fused_head_xent_matches_reference():
    from repro.models import layers as L
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 64, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 100)) * 0.1
    lab = jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0, 100)
    ref = L.softmax_xent(jnp.einsum("bsd,dv->bsv", x, w), lab)
    fused = L.fused_head_xent(x, w, lab, chunk=16)
    np.testing.assert_allclose(float(ref), float(fused), rtol=1e-6)
    tied = L.fused_head_xent(x, w.T, lab, chunk=16, w_is_vd=True)
    np.testing.assert_allclose(float(ref), float(tied), rtol=1e-6)
    g1 = jax.grad(lambda xx: L.softmax_xent(
        jnp.einsum("bsd,dv->bsv", xx, w), lab))(x)
    g2 = jax.grad(lambda xx: L.fused_head_xent(xx, w, lab, chunk=16))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-6)


def test_chunked_attention_matches_dense():
    from repro.models.layers import _sdpa_xla_chunked, _sdpa_xla_dense
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 256, 4, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 384, 4, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 384, 4, 32))
    for causal in (False, True):
        a = _sdpa_xla_chunked(q, k, v, causal, 32 ** -0.5, kv_block=128)
        b = _sdpa_xla_dense(q, k, v, causal, 32 ** -0.5)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
