"""Shared test setup: isolate the persistent plan cache per test session so
tests never read from or write into the user's ~/.cache/repro-plancache."""
import os

import pytest


@pytest.fixture(autouse=True, scope="session")
def _isolated_plan_cache(tmp_path_factory):
    if os.environ.get("REPRO_PLAN_CACHE_DIR"):
        yield
        return
    os.environ["REPRO_PLAN_CACHE_DIR"] = str(
        tmp_path_factory.mktemp("plancache"))
    yield
    os.environ.pop("REPRO_PLAN_CACHE_DIR", None)


@pytest.fixture(autouse=True, scope="session")
def _inline_planner_by_default():
    """Default the suite to inline search (REPRO_PLANNER_WORKERS=1):
    spinning the process pool for every small plan_kernel_multi call adds
    ~1.7x wall time without adding coverage.  The sharded path is
    exercised explicitly by tests/test_search_equivalence.py and
    tests/test_plancache.py, which set workers themselves."""
    if os.environ.get("REPRO_PLANNER_WORKERS"):
        yield
        return
    os.environ["REPRO_PLANNER_WORKERS"] = "1"
    yield
    os.environ.pop("REPRO_PLANNER_WORKERS", None)


@pytest.fixture()
def fast_search(monkeypatch):
    """Shrink the planner's SearchBudget for latency-sensitive tests (the
    REPRO_FAST_SEARCH knob; see core/planner.py:effective_budget)."""
    monkeypatch.setenv("REPRO_FAST_SEARCH", "1")
    yield
