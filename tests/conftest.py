"""Shared test setup: isolate the persistent plan cache per test session so
tests never read from or write into the user's ~/.cache/repro-plancache."""
import os

import pytest


@pytest.fixture(autouse=True, scope="session")
def _isolated_plan_cache(tmp_path_factory):
    if os.environ.get("REPRO_PLAN_CACHE_DIR"):
        yield
        return
    os.environ["REPRO_PLAN_CACHE_DIR"] = str(
        tmp_path_factory.mktemp("plancache"))
    yield
    os.environ.pop("REPRO_PLAN_CACHE_DIR", None)


@pytest.fixture()
def fast_search(monkeypatch):
    """Shrink the planner's SearchBudget for latency-sensitive tests (the
    REPRO_FAST_SEARCH knob; see core/planner.py:effective_budget)."""
    monkeypatch.setenv("REPRO_FAST_SEARCH", "1")
    yield
