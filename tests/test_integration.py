"""Integration tests: end-to-end training with checkpoint/restart, sharded
execution on a small host mesh, decode consistency vs teacher forcing."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig, TrainConfig
from repro.ckpt import CheckpointManager
from repro.data import DataConfig, SyntheticLM
from repro.models import build_model
from repro.parallel.sharding import megatron_tp_plan
from repro.train import train_step as TS

CFG = ARCHS["qwen2.5-3b"].reduced()
TCFG = TrainConfig(total_steps=50, warmup_steps=2, learning_rate=1e-3)


def _stream(cfg, batch=4, seq=32):
    d = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seed=3), cfg)
    return lambda step: jax.tree.map(jnp.asarray, d.batch_at(step, batch, seq))


def test_train_loss_decreases():
    api = build_model(CFG)
    state = TS.init_state(api, TCFG, jax.random.PRNGKey(0))
    step_fn = jax.jit(TS.make_train_step(api, TCFG))
    data = _stream(CFG)
    losses = []
    for i in range(12):
        state, m = step_fn(state, data(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses


def test_checkpoint_restart_bitwise_resume(tmp_path):
    """Training interrupted at step 6 and resumed from the step-4 checkpoint
    replays to the same final loss as an uninterrupted run (deterministic
    data pipeline => recovery is exact)."""
    api = build_model(CFG)
    data = _stream(CFG)
    step_fn = jax.jit(TS.make_train_step(api, TCFG))

    def run(n, mgr=None, state=None, start=0):
        if state is None:
            state = TS.init_state(api, TCFG, jax.random.PRNGKey(0))
        loss = None
        for i in range(start, n):
            state, m = step_fn(state, data(i))
            if mgr and mgr.should_save(i + 1):
                mgr.save(state, i + 1, block=True)
            loss = float(m["loss"])
        return state, loss

    # uninterrupted reference
    _, ref_loss = run(8)
    # interrupted: save every 4, crash after 6, restore, resume
    mgr = CheckpointManager(tmp_path, save_every=4, keep=2, async_save=False)
    state, _ = run(6, mgr=mgr)
    del state                                          # "crash"
    template = TS.abstract_state(api, TCFG)
    restored, step = mgr.restore_latest(target_tree=template)
    assert step == 4
    _, resumed_loss = run(8, state=restored, start=step)
    np.testing.assert_allclose(resumed_loss, ref_loss, rtol=1e-5)


@pytest.mark.slow
@pytest.mark.skipif(len(jax.devices()) < 1, reason="needs a device")
def test_sharded_train_step_matches_unsharded():
    """The plan-sharded jitted step computes the same loss as the local step
    (on a 1x1 mesh the constraints are no-ops but the full path runs)."""
    from repro.launch.mesh import make_host_mesh
    api = build_model(CFG)
    mesh = make_host_mesh(1, 1)
    plan = megatron_tp_plan()
    data = _stream(CFG)
    state = TS.init_state(api, TCFG, jax.random.PRNGKey(0))
    batch = data(0)
    specs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
    with mesh:
        jitted = TS.jit_train_step(api, TCFG, plan, mesh, specs)
        state2, m2 = jitted(state, batch)
    plain = TS.make_train_step(api, TCFG)
    state_ref = TS.init_state(api, TCFG, jax.random.PRNGKey(0))
    _, m1 = plain(state_ref, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)


def test_decode_matches_teacher_forcing():
    """Greedy decode logits at position t equal full-forward logits at t."""
    cfg = ARCHS["qwen2.5-3b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 1,
                              cfg.vocab_size)
    full = api.logits_fn(params, {"tokens": toks, "labels": toks})
    cache = api.init_cache(cfg, 1, 16)
    outs = []
    for t in range(8):
        lg, cache = api.decode_step(params, toks[:, t:t + 1], cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=0.05, atol=0.05)


@pytest.mark.slow
def test_rwkv_decode_matches_teacher_forcing():
    """The recurrent decode path agrees with the chunked training path."""
    cfg = ARCHS["rwkv6-3b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 1,
                              cfg.vocab_size)
    full = api.logits_fn(params, {"tokens": toks, "labels": toks})
    cache = api.init_cache(cfg, 1, 16)
    outs = []
    for t in range(8):
        lg, cache = api.decode_step(params, toks[:, t:t + 1], cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=0.05, atol=0.05)
