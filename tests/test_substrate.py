"""Substrate tests: data pipeline, checkpointing, fault tolerance, elastic."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES
from repro.configs.base import ShapeConfig, TrainConfig
from repro.data import DataConfig, SyntheticLM, host_batch_slice, make_source
from repro.ckpt import CheckpointManager, latest, restore, save
from repro.models import build_model
from repro.runtime import (HeartbeatRegistry, ResilientDriver,
                           StragglerTracker, plan_rescale,
                           viable_mesh_shapes)


CFG = ARCHS["qwen2.5-3b"].reduced()


# ------------------------------------------------------------------- data
def test_pipeline_deterministic():
    d = SyntheticLM(DataConfig(seed=7), CFG)
    a = d.batch_at(3, 4, 16, host=0)
    b = d.batch_at(3, 4, 16, host=0)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch_at(3, 4, 16, host=1)
    assert not np.array_equal(a["tokens"], c["tokens"])    # per-host shards
    assert a["labels"].shape == (4, 16)
    # next-token alignment
    full = d.batch_at(0, 2, 8)
    assert (full["labels"][:, :-1] == full["tokens"][:, 1:]).all()


def test_host_batch_slice_covers_batch():
    slices = [host_batch_slice(100, 7, h) for h in range(7)]
    total = sum(s for _, s in slices)
    assert total == 100
    ends = [st + sz for st, sz in slices]
    starts = [st for st, _ in slices]
    assert starts[0] == 0 and ends[-1] == 100
    assert all(e == s for e, s in zip(ends[:-1], starts[1:]))


# ------------------------------------------------------------------- ckpt
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    save(tree, tmp_path, step=10)
    out, manifest = restore(latest(tmp_path), target_tree=tree)
    assert manifest["step"] == 10
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_atomic_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, save_every=2, keep=2, async_save=False)
    tree = {"w": jnp.zeros((4,))}
    for step in (2, 4, 6, 8):
        assert mgr.should_save(step)
        mgr.save(tree, step)
    from repro.ckpt.checkpoint import list_steps
    assert list_steps(tmp_path) == [6, 8]      # retention kept last 2
    restored, step = mgr.restore_latest(target_tree=tree)
    assert step == 8


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save({"w": jnp.zeros((4,))}, tmp_path, step=1)
    with pytest.raises(ValueError):
        restore(latest(tmp_path), target_tree={"w": jnp.zeros((5,))})


# -------------------------------------------------------------- resilience
def test_heartbeat_failure_detection():
    reg = HeartbeatRegistry(4, timeout_s=10.0)
    for h in range(4):
        reg.beat(h, step=1, step_time_s=1.0, now=100.0)
    reg.beat(0, 2, 1.0, now=120.0)
    assert set(reg.dead_hosts(now=120.0)) == {1, 2, 3}
    assert reg.alive_hosts(now=120.0) == [0]


def test_straggler_detection():
    reg = HeartbeatRegistry(4, timeout_s=1e9)
    for step in range(10):
        for h in range(4):
            t = 1.0 if h != 2 else 3.0       # host 2 is 3x slower
            reg.beat(h, step, t, now=float(step))
    assert StragglerTracker(reg).stragglers() == [2]


def test_silent_from_birth_host_times_out():
    """A host that registers but never beats must still be declared dead:
    registration counts as the first 'seen' instant."""
    reg = HeartbeatRegistry(3, timeout_s=10.0, now=0.0)
    reg.beat(1, 0, 1.0, now=5.0)
    reg.beat(2, 0, 1.0, now=5.0)
    assert reg.dead_hosts(now=9.0) == []             # within timeout
    assert reg.dead_hosts(now=10.0) == []            # edge: strictly >
    assert reg.dead_hosts(now=11.0) == [0]           # never beat -> dead
    assert set(reg.dead_hosts(now=16.0)) == {0, 1, 2}


def test_stragglers_need_two_reporting_hosts():
    """With fewer than two hosts reporting enough samples there is no
    population to compare against — nobody is flagged."""
    reg = HeartbeatRegistry(4, timeout_s=1e9, now=0.0)
    for step in range(10):
        reg.beat(0, step, 9.0, now=float(step))      # slow, but alone
    assert StragglerTracker(reg).stragglers() == []


def test_resilient_driver_restores_and_replays(tmp_path):
    """Inject a failure at step 5; the driver must restore from the last
    checkpoint and complete — with deterministic data the final state matches
    a failure-free run."""
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == 6 and not calls.get("failed"):
            calls["failed"] = True
            raise RuntimeError("injected device loss")
        return state + batch, {"loss": float(state)}

    mgr = CheckpointManager(tmp_path, save_every=2, keep=5, async_save=False)
    saved = {}

    def batches(step):
        return jnp.ones(())

    def restore_fn():
        tree, step = mgr.restore_latest(target_tree=jnp.zeros(()))
        return (tree if tree is not None else jnp.zeros(())), step

    drv = ResilientDriver(step_fn, mgr)
    state, step, _ = drv.run(jnp.zeros(()), batches, start_step=0, n_steps=10,
                             restore_fn=restore_fn)
    assert step == 10
    assert len(drv.events) == 1 and drv.events[0].kind == "restart"
    assert float(state) == 10.0            # replayed steps, exact recovery


def test_resilient_driver_requires_restore_path():
    """Replay-from-checkpoint is enforced: retrying against in-memory state
    after a failed step is unsafe (possibly-corrupt tree), so run() refuses
    up front when retries are allowed but no restore path exists."""
    drv = ResilientDriver(lambda s, b: (s, {}), None)
    with pytest.raises(ValueError, match="restore_fn"):
        drv.run(0, lambda step: None, start_step=0, n_steps=1)
    # max_retries=0 fails fast instead: no restore needed, first error raises
    drv0 = ResilientDriver(lambda s, b: 1 / 0, None, max_retries=0)
    with pytest.raises(ZeroDivisionError):
        drv0.run(0, lambda step: None, start_step=0, n_steps=1)
    assert [e.kind for e in drv0.events] == ["restart"]


def test_resilient_driver_retry_exhaustion_raises():
    def step_fn(state, batch):
        raise RuntimeError("persistent device loss")

    drv = ResilientDriver(step_fn, None, max_retries=2)
    with pytest.raises(RuntimeError, match="persistent"):
        drv.run(0, lambda step: None, start_step=0, n_steps=4,
                restore_fn=lambda: (0, 0))
    assert [e.kind for e in drv.events] == ["restart"] * 3   # 1 + 2 retries


def test_resilient_driver_emits_straggler_events():
    """Tracker detections surface as RecoveryEvents (each host once)."""
    clock = {"t": 100.0}
    reg = HeartbeatRegistry(3, timeout_s=1e9, now=clock["t"])
    for step in range(10):                       # pre-existing telemetry
        reg.beat(1, step, 5.0, now=100.0)        # host 1 is the straggler
        reg.beat(2, step, 1.0, now=100.0)

    def step_fn(state, batch):
        clock["t"] += 1.0
        return state + 1, {}

    drv = ResilientDriver(step_fn, None, max_retries=0,
                          registry=reg, tracker=StragglerTracker(reg),
                          clock=lambda: clock["t"])
    state, step, _ = drv.run(0, lambda step: None, start_step=0, n_steps=3)
    assert state == 3 and step == 3
    straggler = [e for e in drv.events if e.kind == "straggler"]
    assert len(straggler) == 1 and "host 1" in straggler[0].detail


def test_resilient_driver_emits_rescale_events():
    """A dead host triggers exactly one rescale event and the rescale_fn
    hook receives (dead, alive)."""
    clock = {"t": 0.0}
    reg = HeartbeatRegistry(2, timeout_s=5.0, now=0.0)
    calls = []

    def step_fn(state, batch):
        clock["t"] += 4.0
        return state, {}

    drv = ResilientDriver(step_fn, None, max_retries=0, registry=reg,
                          rescale_fn=lambda dead, alive:
                          calls.append((dead, alive)),
                          clock=lambda: clock["t"])
    drv.run(0, lambda step: None, start_step=0, n_steps=3)
    # host 1 never beat after registration at t=0; driver (host 0) kept
    # beating, so by t=8 only host 1 is dead — and it is reported once
    rescale = [e for e in drv.events if e.kind == "rescale"]
    assert len(rescale) == 1 and "[1]" in rescale[0].detail
    assert calls == [([1], [0])]


# ------------------------------------------------------------------ elastic
def test_viable_mesh_shapes():
    shapes = viable_mesh_shapes(256)
    assert (16, 16) == shapes[0]
    assert all(a * b == 256 for a, b in shapes)


def test_plan_rescale_shrink():
    api = build_model(ARCHS["qwen2.5-3b"])
    shape = SHAPES["train_4k"]
    rp = plan_rescale(api, shape, TrainConfig(microbatches=4),
                      old_devices=256, new_devices=192)
    assert rp.new_devices == 192
    assert rp.mesh_shape[0] * rp.mesh_shape[1] == 192
    assert shape.global_batch % rp.mesh_shape[0] == 0
    assert rp.plan_name


def test_plan_rescale_batch_divisibility_fallback():
    """When the squarest mesh's data axis does not divide the global batch,
    plan_rescale walks to the next factorization that does instead of
    silently breaking batch reproducibility."""
    api = build_model(ARCHS["qwen2.5-3b"])
    shape = ShapeConfig("odd_batch", seq_len=128, global_batch=3,
                        kind="train")
    rp = plan_rescale(api, shape, TrainConfig(microbatches=1),
                      old_devices=16, new_devices=8)
    # squarest is (2, 4) but 3 % 2 != 0 -> falls back to (1, 8)
    assert rp.mesh_shape == (1, 8)
    assert shape.global_batch % rp.mesh_shape[0] == 0
    assert rp.batch_note == ""


# ------------------------------------------------------ gradient compression
def test_grad_compression_error_feedback():
    from repro.train.grad_compress import init_residual, roundtrip
    g = {"w": jnp.array([0.1, -0.25, 3.0, 1e-4])}
    res = init_residual(g)
    total = jnp.zeros(4)
    exact = jnp.zeros(4)
    for _ in range(50):        # error feedback: accumulated sum converges
        deq, res = roundtrip(g, res)
        total = total + deq["w"]
        exact = exact + g["w"]
    np.testing.assert_allclose(np.asarray(total), np.asarray(exact),
                               rtol=0.02, atol=0.02)


@pytest.mark.slow
def test_train_step_runs_with_compression_and_microbatches():
    from repro.train import train_step as TS
    cfg = ARCHS["qwen2.5-3b"].reduced()
    api = build_model(cfg)
    tcfg = TrainConfig(microbatches=2, grad_compression="int8",
                       total_steps=10, warmup_steps=2)
    state = TS.init_state(api, tcfg, jax.random.PRNGKey(0))
    step = TS.make_train_step(api, tcfg)
    d = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size), cfg)
    batch = jax.tree.map(jnp.asarray, d.batch_at(0, 4, 16))
    state, metrics = step(state, batch)
    l0 = float(metrics["loss"])
    for i in range(1, 4):
        batch = jax.tree.map(jnp.asarray, d.batch_at(i, 4, 16))
        state, metrics = step(state, batch)
    assert float(metrics["loss"]) < l0     # it learns
    assert not np.isnan(float(metrics["loss"]))
