"""Benchmark-harness CLI contract: typos in suite names must fail loudly
instead of silently running nothing and printing an empty table."""
import sys

import pytest


def _run_main(monkeypatch, argv):
    from benchmarks import run
    monkeypatch.setattr(sys, "argv", ["run.py"] + argv)
    run.main()


@pytest.mark.parametrize("argv", [
    ["--suite", "gemm_fig5_typo"],
    ["--only", "nope"],
    ["--suite", "gemm_fig5,flash_fig7x"],
])
def test_unknown_suite_rejected(monkeypatch, capsys, argv):
    with pytest.raises(SystemExit) as exc:
        _run_main(monkeypatch, argv)
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert "unknown suite name" in err
    assert "valid suites:" in err and "gemm_fig5" in err


def test_known_suite_accepted_smoke(monkeypatch, capsys, fast_search):
    """A valid suite name still runs (the cheapest one, as a smoke check
    that the validation does not reject legitimate selections)."""
    _run_main(monkeypatch, ["--suite", "perfmodel_fig9"])
    out = capsys.readouterr().out
    assert "name,us_per_call,derived" in out
