"""Observability-layer contract (DESIGN_OBS.md): the tracer and metrics
registry only *observe* — instrumented searches select bit-identical plans
at any worker count — the exported trace is schema-valid Chrome JSON with
properly nested spans (including spans merged from worker processes), the
explain CLI renders kernel and pipeline cells, and golden regeneration is
refused while tracing."""
import json
import os

import pytest

from repro.core import SearchBudget, get_hw, matmul_program, \
    plan_kernel_multi, simulate
from repro.obs import metrics, trace


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with tracing off and an empty buffer (the
    tracer and registry are process-global)."""
    trace.disable()
    trace.clear()
    yield
    trace.disable()
    trace.clear()


def _mk_programs():
    return [matmul_program(640, 640, 512, bm=bm, bn=bn, bk=64)
            for bm in (32, 64) for bn in (32, 64, 128)]


def _key(res):
    return [(c.plan.describe(), c.index, c.cost.total_s,
             c.sim.total_s if c.sim else None) for c in res.topk]


# --------------------------------------------------------------------- trace
def test_span_noop_when_disabled():
    with trace.span("x.y", foo=1):
        pass
    assert trace.events() == []
    # the disabled path returns one shared null object (no allocation)
    assert trace.span("a") is trace.span("b")


def test_span_records_complete_events():
    trace.enable()
    with trace.span("outer", cat="t", k="v"):
        with trace.span("inner", cat="t"):
            pass
    evs = trace.events()
    assert [e["name"] for e in evs] == ["inner", "outer"]  # close order
    for e in evs:
        for k in trace.REQUIRED_KEYS:
            assert k in e
        assert e["ph"] == "X" and e["pid"] == os.getpid()
    assert evs[1]["args"] == {"k": "v"}
    # inner nests inside outer on the same track
    assert trace.validate_chrome_trace({"traceEvents": evs}) == []


def test_traced_decorator_and_drain():
    @trace.traced("decorated.fn", cat="t")
    def f(x):
        return x + 1

    assert f(1) == 2
    assert trace.events() == []          # disabled: zero events
    trace.enable()
    assert f(2) == 3
    drained = trace.drain()
    assert [e["name"] for e in drained] == ["decorated.fn"]
    assert trace.events() == []          # drain clears


def test_ingest_preserves_worker_identity():
    trace.enable()
    foreign = [{"name": "w", "cat": "worker", "ph": "X", "ts": 5.0,
                "dur": 2.0, "pid": 99999, "tid": 1}]
    trace.ingest(foreign)
    assert trace.events()[0]["pid"] == 99999


def test_write_and_validate_chrome_trace(tmp_path):
    trace.enable()
    with trace.span("a"):
        pass
    path = tmp_path / "trace.json"
    assert trace.write(str(path)) == str(path)
    doc = json.loads(path.read_text())
    assert "traceEvents" in doc and doc["displayTimeUnit"] == "ms"
    assert trace.validate_chrome_trace(doc) == []


def test_validate_rejects_malformed_and_overlapping():
    assert trace.validate_chrome_trace({"nope": 1})
    missing = {"traceEvents": [{"ph": "X", "ts": 0.0, "dur": 1.0,
                                "pid": 1}]}  # no tid/name
    assert any("missing key" in p
               for p in trace.validate_chrome_trace(missing))
    # partial overlap on one (pid, tid) track is not legal span nesting
    overlap = {"traceEvents": [
        {"name": "a", "ph": "X", "ts": 0.0, "dur": 10.0, "pid": 1, "tid": 1},
        {"name": "b", "ph": "X", "ts": 5.0, "dur": 10.0, "pid": 1, "tid": 1},
    ]}
    assert any("overlap" in p for p in trace.validate_chrome_trace(overlap))


def test_refresh_from_env_round_trip(monkeypatch, tmp_path):
    path = str(tmp_path / "t.json")
    monkeypatch.setenv(trace.TRACE_ENV, path)
    trace.refresh_from_env()
    assert trace.enabled()
    monkeypatch.delenv(trace.TRACE_ENV)
    trace.refresh_from_env()             # env withdrawn -> tracing off
    assert not trace.enabled()


# ------------------------------------------------------------------- metrics
def test_metrics_counter_gauge_histogram():
    metrics.inc("t_obs_counter", 2.0, phase="a")
    metrics.inc("t_obs_counter", phase="b")
    c = metrics.counter("t_obs_counter")
    assert c.value(phase="a") == 2.0 and c.total() == 3.0
    metrics.set_gauge("t_obs_gauge", 7.5)
    assert metrics.gauge("t_obs_gauge").value() == 7.5
    metrics.observe("t_obs_hist", 0.05, kind="x")
    s = metrics.histogram("t_obs_hist").series(kind="x")
    assert s.count == 1 and s.min == s.max == 0.05
    snap = metrics.snapshot()
    assert snap["t_obs_counter"]["type"] == "counter"
    assert {tuple(sorted(d["labels"].items()))
            for d in snap["t_obs_counter"]["series"]} == {
                (("phase", "a"),), (("phase", "b"),)}
    assert metrics.counter_totals(snap)["t_obs_counter"] == 3.0
    with pytest.raises(TypeError):
        metrics.gauge("t_obs_counter")   # type of first registration wins


def test_metrics_diff_counters():
    before = metrics.snapshot()
    metrics.inc("t_obs_diff", 3.0, phase="est")
    d = metrics.diff_counters(before, metrics.snapshot())
    assert d["t_obs_diff"] == {json.dumps({"phase": "est"}): 3.0}
    # a second diff against the newer snapshot is empty (zero deltas drop)
    assert "t_obs_diff" not in metrics.diff_counters(metrics.snapshot(),
                                                     metrics.snapshot())


def test_metrics_dump(tmp_path, monkeypatch):
    metrics.inc("t_obs_dump")
    monkeypatch.delenv(metrics.METRICS_ENV, raising=False)
    assert metrics.dump() is None        # no destination known
    path = tmp_path / "metrics.json"
    monkeypatch.setenv(metrics.METRICS_ENV, str(path))
    assert metrics.dump() == str(path)
    assert "t_obs_dump" in json.loads(path.read_text())


def test_planner_publishes_phase_and_search_metrics():
    before = metrics.snapshot()
    plan_kernel_multi(_mk_programs(), get_hw("wormhole_8x8"),
                      budget=SearchBudget(top_k=3, workers=1))
    d = metrics.diff_counters(before, metrics.snapshot())
    assert d["planner_searches_total"]
    assert d["planner_candidates_total"]
    phases = {json.loads(k)["phase"]: v
              for k, v in d["planner_phase_seconds_total"].items()}
    assert {"enumerate", "estimate"} <= set(phases)
    assert all(v > 0 for v in phases.values())


# ----------------------------------------------- bit-identity traced/untraced
@pytest.mark.parametrize("workers", [1, 2])
def test_traced_search_bit_identical(workers):
    """The hard invariant: tracing on vs off selects identical top-k
    (same plans, same canonical indices, costs equal to the bit) at any
    worker count — instrumentation must only observe."""
    hw = get_hw("wormhole_8x8")
    budget = SearchBudget(top_k=5, workers=workers)
    untraced = plan_kernel_multi(_mk_programs(), hw, budget=budget)
    trace.enable()
    traced = plan_kernel_multi(_mk_programs(), hw, budget=budget)
    events = trace.events()
    trace.disable()
    assert _key(traced) == _key(untraced)
    assert events, "tracing was on but no spans were recorded"
    assert trace.validate_chrome_trace({"traceEvents": events}) == []
    names = {e["name"] for e in events}
    assert "planner.plan_kernel_multi" in names
    if workers > 1:
        worker_evs = [e for e in events if e.get("cat") == "worker"]
        assert worker_evs, "sharded run must merge worker spans"
        assert all(e["pid"] != os.getpid() for e in worker_evs)


def test_sharded_trace_merges_multiple_worker_processes():
    """A sharded search at workers=4 lands spans from >= 2 distinct worker
    pids in the parent buffer, and the merged trace still validates."""
    hw = get_hw("wormhole_8x8")
    progs = [matmul_program(1024, 1024, 1024, bm=bm, bn=bn, bk=bk)
             for bm in (32, 64) for bn in (32, 64, 128)
             for bk in (64, 128)]
    trace.enable()
    plan_kernel_multi(progs, hw, budget=SearchBudget(top_k=3, workers=4))
    events = trace.events()
    trace.disable()
    assert trace.validate_chrome_trace({"traceEvents": events}) == []
    worker_pids = {e["pid"] for e in events if e.get("cat") == "worker"}
    assert len(worker_pids) >= 2, f"worker pids: {sorted(worker_pids)}"


def test_traced_pipeline_bit_identical(fast_search):
    from repro.pipeline import mlp2_graph, plan_pipeline
    hw = get_hw("wormhole_8x8")
    budget = SearchBudget(top_k=2, max_plans_per_mapping=8, workers=1)
    mk = lambda: mlp2_graph(4096, 128, 256,
                            blocks=((64, 64, 64), (128, 128, 64)))
    base = plan_pipeline(mk(), hw, budget=budget)
    trace.enable()
    traced = plan_pipeline(mk(), hw, budget=budget)
    events = trace.events()
    trace.disable()
    assert traced.total_s == base.total_s
    assert traced.describe() == base.describe()
    names = {e["name"] for e in events}
    assert {"pipeline.node_pools", "pipeline.graph_bnb"} <= names


def test_simulate_record_does_not_change_result():
    hw = get_hw("wormhole_8x8")
    res = plan_kernel_multi(_mk_programs(), hw,
                            budget=SearchBudget(top_k=1, workers=1))
    plan = res.best.plan
    bare = simulate(plan, hw)
    rec = []
    recorded = simulate(plan, hw, record=rec)
    assert recorded == bare              # bit-identical, frozen dataclass
    assert len(rec) == bare.n_wave_classes
    assert sum(r["population"] for r in rec) == bare.n_waves


# ------------------------------------------------------------------- explain
def test_explain_gemm_cell(fast_search):
    from repro.obs import explain
    text = explain.explain("gemm/wormhole_8x8/M1024_N1024_K4096",
                           cache=None)
    assert "wave-class timeline" in text
    assert "mesh utilization" in text
    assert "winner vs runner-up" in text
    assert "resource" in text and "dram" in text


def test_explain_pipeline_cell(fast_search):
    from repro.obs import explain
    text = explain.explain("pipeline/mlp2/M16384_d128_f512", cache=None)
    assert "edges forwarded" in text
    assert "flip_delta" in text          # per-edge forward-vs-spill delta
    assert "forward[" in text            # at least one forwarded edge
    assert "per-node edge-adjusted simulations" in text


def test_explain_rejects_unknown_cell():
    from repro.obs import explain
    with pytest.raises(explain.CellError):
        explain.resolve_kernel_cell("nope/such/cell")
    with pytest.raises(explain.CellError):
        explain.resolve_pipeline_cell("pipeline/nope/M1_d2_f3")


def test_explain_cli_list(capsys):
    from repro.obs.__main__ import main
    assert main(["explain", "--list"]) == 0
    out = capsys.readouterr().out
    assert "gemm/wormhole_8x8/M1024_N1024_K4096" in out
    assert "pipeline/mlp2/M16384_d128_f512" in out


# ----------------------------------------------------------- golden refusal
def test_write_golden_refused_while_tracing(tmp_path):
    from benchmarks import plan_speed
    trace.enable()
    with pytest.raises(RuntimeError, match="refusing to write"):
        plan_speed.write_golden({"cell": {"best": "x"}},
                                str(tmp_path / "g.json"))
    trace.disable()
    trace.clear()
    # untraced write succeeds
    plan_speed.write_golden({"cell": {"best": "x"}},
                            str(tmp_path / "g.json"))
    doc = json.loads((tmp_path / "g.json").read_text())
    assert doc["best_plans"] == {"cell": "x"}


def test_run_update_golden_refused_under_env(monkeypatch, capsys, tmp_path):
    import sys

    from benchmarks import run
    monkeypatch.setenv(trace.TRACE_ENV, str(tmp_path / "t.json"))
    monkeypatch.setattr(sys, "argv", ["run.py", "--update-golden"])
    with pytest.raises(SystemExit) as exc:
        run.main()
    assert exc.value.code == 2
    assert "--update-golden is refused" in capsys.readouterr().err


# --------------------------------------------------------- fallback dedup
def test_fallback_warns_once_per_cause_but_counts_all(caplog):
    import logging

    from repro.core import lower_jax
    lower_jax.clear_block_caches()
    before = lower_jax.planner_fallback_count()
    assert before == 0
    with caplog.at_level(logging.WARNING, logger=lower_jax.log.name):
        lower_jax._note_fallback("gemm_blocks", (64, 64, 64),
                                 RuntimeError("boom"), (32, 32, 32))
        lower_jax._note_fallback("gemm_blocks", (64, 64, 64),
                                 RuntimeError("boom"), (32, 32, 32))
        lower_jax._note_fallback("gemm_blocks", (64, 64, 64),
                                 RuntimeError("other"), (32, 32, 32))
    assert lower_jax.planner_fallback_count() == 3
    assert lower_jax.planner_fallback_count("gemm_blocks") == 3
    warned = [r for r in caplog.records
              if "planner fallback" in r.getMessage()]
    assert len(warned) == 2              # one per distinct (template, cause)
    lower_jax.clear_block_caches()
    assert lower_jax.planner_fallback_count() == 0


# ------------------------------------------------------- plancache metrics
def test_plancache_metrics_mirror_stats(tmp_path, monkeypatch, fast_search):
    from repro.plancache import PlanCache
    from repro.plancache.store import PlanCacheStore
    store = PlanCacheStore(root=tmp_path / "pc")
    cache = PlanCache(store)
    before = metrics.snapshot()
    hw = get_hw("wormhole_8x8")
    progs = [matmul_program(512, 512, 512, bm=64, bn=64, bk=64)]
    budget = SearchBudget(top_k=2, workers=1)
    r1 = plan_kernel_multi(progs, hw, budget=budget, cache=cache)
    r2 = plan_kernel_multi(progs, hw, budget=budget, cache=cache)
    assert r2.best.plan.describe() == r1.best.plan.describe()
    d = metrics.diff_counters(before, metrics.snapshot())
    gets = {json.loads(k)["result"]: v
            for k, v in d["plancache_get_total"].items()}
    assert gets.get("miss") == 1 and gets.get("hit_mem", 0) >= 1
    puts = {json.loads(k)["result"]: v
            for k, v in d["plancache_put_total"].items()}
    assert puts.get("stored") == 1
    phases = {json.loads(k)["phase"]: v
              for k, v in d["planner_phase_seconds_total"].items()}
    assert phases.get("cache", 0) > 0


def test_plancache_stats_json_cli(capsys):
    from repro.plancache.__main__ import main
    assert main(["stats", "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert "store" in doc and "metrics" in doc
    assert "entries" in doc["store"]


# ------------------------------------------- serving-layer additions (PR 10)
def test_snapshot_meta_block():
    from repro.plancache.keying import SCHEMA_VERSION
    snap = metrics.snapshot()
    meta = snap["_meta"]
    assert meta["pid"] == os.getpid()
    assert meta["start_time"] > 0 and meta["uptime_s"] >= 0
    assert meta["plancache_schema"] == SCHEMA_VERSION
    # existing consumers skip the block: it has no "type" key and never
    # lands in counter aggregations
    assert "type" not in meta
    assert "_meta" not in metrics.counter_totals(snap)
    assert metrics.diff_counters(snap, metrics.snapshot()) == {}


def test_metric_exemplar_rid():
    from repro.obs import context

    def series(snap):
        [s] = snap["t_obs_exemplar"]["series"]
        return s

    metrics.inc("t_obs_exemplar", case="x")
    assert "rid" not in series(metrics.snapshot())   # uncorrelated: no key
    with context.correlate("req") as rid:
        metrics.inc("t_obs_exemplar", case="x")
    assert series(metrics.snapshot())["rid"] == rid
    # an uncorrelated increment never erases the last-seen exemplar
    metrics.inc("t_obs_exemplar", case="x")
    assert series(metrics.snapshot())["rid"] == rid


def test_span_carries_rid():
    from repro.obs import context
    trace.enable()
    with context.correlate("req") as rid:
        with trace.span("corr.span", k="v"):
            pass
    with trace.span("plain.span"):
        pass
    by_name = {e["name"]: e for e in trace.events()}
    assert by_name["corr.span"]["args"] == {"k": "v", "rid": rid}
    assert "args" not in by_name["plain.span"]


def test_sharded_search_propagates_rid_to_workers(fast_search, monkeypatch):
    """Worker processes attach the parent's correlation ID per task, so
    worker spans of a correlated resolve land on the same request ID."""
    from repro.obs import context
    from repro.parallel import search_exec
    hw = get_hw("wormhole_8x8")
    progs = [matmul_program(1024, 1024, 1024, bm=bm, bn=bn, bk=bk)
             for bm in (32, 64) for bn in (32, 64, 128)
             for bk in (64, 128)]
    trace.enable()
    try:
        with context.correlate("req") as rid:
            plan_kernel_multi(progs, hw,
                              budget=SearchBudget(top_k=3, workers=2))
        worker_evs = [e for e in trace.events() if e.get("cat") == "worker"]
        assert worker_evs, "sharded run must merge worker spans"
        assert all(e["args"]["rid"] == rid for e in worker_evs)
        assert all(e["pid"] != os.getpid() for e in worker_evs)
    finally:
        search_exec.shutdown_pool()


def test_killed_worker_trace_and_flightrec(fast_search, monkeypatch,
                                           tmp_path):
    """A worker hard-exiting mid-search must not tear the observability
    stream: the search still succeeds, the merged Chrome trace validates,
    and the flight recorder holds the ``pool_failure`` event."""
    from repro.obs import flightrec
    from repro.parallel import search_exec
    from repro.runtime.faults import FaultSchedule, FaultSpec
    hw = get_hw("wormhole_4x8")
    progs = [matmul_program(256, 256, 256, bm=bm, bn=bn, bk=64)
             for bm in (32, 64) for bn in (32, 64, 128)]
    inline = plan_kernel_multi(progs, hw, profile=True)

    search_exec.shutdown_pool()      # fresh workers must see the marker env
    sched = FaultSchedule([FaultSpec("worker_crash")])
    marker = sched.arm_worker_crash(directory=str(tmp_path))
    flightrec.clear()
    flightrec.enable()
    trace.enable()
    try:
        monkeypatch.setenv("REPRO_PLANNER_WORKERS", "2")
        res = plan_kernel_multi(progs, hw, profile=True)
        assert not os.path.exists(marker)        # a worker really died
        assert res.best.plan.describe() == inline.best.plan.describe()
        assert res.best.final_s == inline.best.final_s
        evs = trace.events()
        assert evs and trace.validate_chrome_trace(
            {"traceEvents": evs}) == []          # not a torn buffer
        fails = [e for e in flightrec.events()
                 if e["kind"] == "pool_failure"]
        assert fails, "worker death must land a pool_failure event"
        assert fails[0]["error"] == "BrokenProcessPool"
        assert {"t", "seq", "attempt", "where"} <= set(fails[0])
    finally:
        FaultSchedule.disarm_worker_crash()
        search_exec.shutdown_pool()
        flightrec.disable()
        flightrec.clear()


def test_hist_quantile_boundary_grid():
    """Satellite (b): ``hist_quantile`` over the boundary grid — empty /
    missing series, q<=0, q>=1, one observation, single occupied bucket,
    and interpolation staying inside [min, max]."""
    def snap_series(kind):
        for s in metrics.snapshot()["t_obs_hq"]["series"]:
            if s["labels"] == {"kind": kind}:
                return s
        return None

    assert metrics.hist_quantile(None, 0.5) is None
    assert metrics.hist_quantile({}, 0.5) is None
    assert metrics.hist_quantile({"count": 0}, 0.5) is None

    metrics.observe("t_obs_hq", 0.2, kind="one")
    s1 = snap_series("one")
    for q in (-1.0, 0.0, 0.25, 0.5, 0.99, 1.0, 2.0):
        assert metrics.hist_quantile(s1, q) == pytest.approx(0.2)

    for v in (0.5, 0.5, 0.5):                    # lo == hi, count > 1
        metrics.observe("t_obs_hq", v, kind="flat")
    assert metrics.hist_quantile(snap_series("flat"), 0.5) \
        == pytest.approx(0.5)

    # a foreign/minimal series without buckets degrades to lerp(min, max)
    bare = {"count": 2, "min": 1.0, "max": 3.0}
    assert metrics.hist_quantile(bare, 0.5) == pytest.approx(2.0)

    for v in (1.0, 2.0, 4.0, 8.0):
        metrics.observe("t_obs_hq", v, kind="spread")
    s = snap_series("spread")
    assert metrics.hist_quantile(s, 0.0) == pytest.approx(1.0)   # exact min
    assert metrics.hist_quantile(s, 1.0) == pytest.approx(8.0)   # exact max
    for q in (0.1, 0.25, 0.5, 0.75, 0.9):
        v = metrics.hist_quantile(s, q)
        assert 1.0 <= v <= 8.0
    # quantiles are monotone in q
    qs = [metrics.hist_quantile(s, q / 20) for q in range(21)]
    assert qs == sorted(qs)
