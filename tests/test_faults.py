"""Degraded-mesh planning: fault injection, fault-aware search, elastic
re-planning (DESIGN_FAULTS.md acceptance).

Covers the whole degradation story with *injected* faults:

* the fault overlay on :class:`HardwareModel` (validation, df_text keys,
  link composition, byte-identical fault-free path);
* fault-aware enumeration + simulator masking, bit-identical between the
  scalar and batched engines on degraded fabrics;
* seeded :class:`FaultSchedule` determinism and the ``REPRO_FAULTS`` syntax;
* the re-plan ladder: detection -> re-plan -> resume on a wormhole_8x8
  single-core kill, warmed fault pools answering at rung 1 with zero cold
  searches, and the <=1.35x degraded/healthy quality bound;
* the search pool surviving a killed worker;
* the v3 -> v4 plan-cache schema bump.
"""
import math
import os

import pytest

from repro import plancache
from repro.core import (SearchBudget, block_shape_candidates, get_hw,
                        matmul_program, plan_kernel_multi, simulate,
                        simulate_plans, simulate_reference)
from repro.core.planner import PLAN_CALLS, iter_plan_stream
from repro.obs import metrics
from repro.runtime.faults import FaultSchedule, FaultSpec, parse_faults
from repro.runtime.replan import (ReplanOrchestrator, best_submesh,
                                  plan_degraded)

BUDGET = SearchBudget(top_k=3, max_mappings=16, max_plans_per_mapping=10,
                      max_candidates=500)


@pytest.fixture()
def fresh_store(tmp_path, monkeypatch):
    """An isolated plan store for ladder tests (same idiom as
    tests/test_plancache.py)."""
    monkeypatch.setenv(plancache.ENV_DIR, str(tmp_path))
    monkeypatch.delenv(plancache.ENV_TOGGLE, raising=False)
    plancache.reset_store()
    yield plancache.get_store()
    plancache.reset_store()


def _gemm_progs(M=256, N=256, K=256):
    return [matmul_program(M, N, K, bm=bm, bn=bn, bk=bk)
            for bm, bn, bk in block_shape_candidates(M, N, K)]


# ------------------------------------------------------------ hw overlay
def test_with_faults_validation():
    hw = get_hw("wormhole_4x8")
    with pytest.raises(ValueError):
        hw.with_faults(disabled_cores=[(99, 0)])          # out of range
    with pytest.raises(ValueError):
        hw.with_faults(disabled_cores=[(0,)])             # wrong arity
    with pytest.raises(ValueError):
        hw.with_faults(degraded_links=[("nope", 0.5)])    # unknown link
    with pytest.raises(ValueError):
        hw.with_faults(degraded_links=[("noc_h", 0.0)])   # factor not in (0,1]
    every = [(x, y) for x in range(4) for y in range(8)]
    with pytest.raises(ValueError):
        hw.with_faults(disabled_cores=every)              # nothing left


def test_fault_free_path_is_byte_identical():
    hw = get_hw("wormhole_8x8")
    assert hw.with_faults().df_text() == hw.df_text()
    assert not hw.is_degraded
    assert plancache.hw_digest(hw.with_faults()) == plancache.hw_digest(hw)


def test_fault_overlay_forks_df_text_and_digest():
    hw = get_hw("wormhole_8x8")
    deg = hw.with_faults(disabled_cores=[(3, 5)],
                         degraded_links=[("noc_h", 0.5)])
    assert deg.is_degraded
    assert "df.fault disable %cores[3, 5]" in deg.df_text()
    assert "df.fault degrade %noc_h {factor=0.5}" in deg.df_text()
    assert plancache.hw_digest(deg) != plancache.hw_digest(hw)
    assert deg.healthy_cores == 63
    assert deg.is_disabled({"x": 3, "y": 5})
    assert not deg.is_disabled({"x": 3, "y": 4})


def test_link_degradation_composes_multiplicatively():
    hw = get_hw("wormhole_8x8")
    bw0 = next(ic.bandwidth_gbps for ic in hw.interconnects
               if ic.name == "noc_h")
    deg = hw.with_faults(degraded_links=[("noc_h", 0.5)]) \
            .with_faults(degraded_links=[("noc_h", 0.5)])
    assert dict(deg.degraded_links)["noc_h"] == pytest.approx(0.25)
    bw = next(ic.bandwidth_gbps for ic in deg.interconnects
              if ic.name == "noc_h")
    assert bw == pytest.approx(bw0 * 0.25)


# ------------------------------------------- enumeration + simulator mask
def test_enumeration_routes_around_disabled_cores():
    """No enumerated mapping on a degraded mesh ever activates a disabled
    core, and the scalar / batched / reference simulators agree exactly on
    the masked fabric."""
    hw = get_hw("wormhole_4x8").with_faults(disabled_cores=[(1, 3)])
    prog = matmul_program(320, 192, 256, bm=32, bn=32, bk=64)
    n = 0
    for _, plan in iter_plan_stream(prog, hw, BUDGET):
        assert not plan.mapping.conflicts_with_faults(hw)
        fast = simulate(plan, hw)
        (got,) = simulate_plans([plan], hw)
        assert got.total_s == fast.total_s          # bit-identical engines
        assert got.dram_bytes == fast.dram_bytes
        ref = simulate_reference(plan, hw, max_waves_exact=10 ** 9)
        assert fast.total_s == pytest.approx(ref.total_s, rel=1e-12)
        n += 1
        if n >= 8:
            break
    assert n >= 4


def test_healthy_enumeration_unchanged_by_overlay_support():
    """The fault-free search space is untouched: the overlay-aware
    enumerator yields the identical plan list for an empty overlay."""
    hw = get_hw("wormhole_4x8")
    prog = matmul_program(256, 256, 256, bm=64, bn=64, bk=64)
    a = [p.describe() for _, p in iter_plan_stream(prog, hw, BUDGET)]
    b = [p.describe() for _, p in
         iter_plan_stream(prog, hw.with_faults(), BUDGET)]
    assert a == b and a


# ------------------------------------------------------- fault schedules
def test_parse_faults_syntax():
    s = parse_faults("core:3,5;link:noc_h:0.5@2;straggler:1;crash")
    kinds = [f.kind for f in s]
    assert sorted(kinds) == ["core_kill", "host_straggler", "link_slow",
                             "worker_crash"]
    hw = get_hw("wormhole_8x8")
    assert s.degraded_hw(hw, 0).degraded_links == ()     # link fault @2
    assert s.degraded_hw(hw, 2).degraded_links == (("noc_h", 0.5),)
    assert s.degraded_hw(hw, 0).disabled_cores == ((3, 5),)
    assert s.straggler_factor(1, 0) == 3.0
    assert s.straggler_factor(0, 0) == 1.0
    assert s.worker_crashes() == 1
    with pytest.raises(ValueError):
        parse_faults("core:banana")
    with pytest.raises(ValueError):
        FaultSpec("not_a_kind")


def test_seeded_schedules_are_deterministic():
    hw = get_hw("wormhole_8x8")
    a = FaultSchedule.seeded(7, hw=hw, n_steps=5, n_hosts=4, n_faults=4)
    b = FaultSchedule.seeded(7, hw=hw, n_steps=5, n_hosts=4, n_faults=4)
    assert a.describe() == b.describe()
    c = FaultSchedule.seeded(8, hw=hw, n_steps=5, n_hosts=4, n_faults=4)
    assert a.describe() != c.describe()
    assert FaultSchedule.seeded(1, hw=hw, kinds=["core_kill"]).faults[0] \
        .kind == "core_kill"
    # without hw/hosts only worker crashes are drawable
    assert all(f.kind == "worker_crash" for f in FaultSchedule.seeded(1))
    with pytest.raises(ValueError):
        FaultSchedule.seeded(1, kinds=["bogus"])
    with pytest.raises(ValueError):
        FaultSchedule.seeded(1, kinds=[])        # nothing drawable


def test_fault_free_schedule_passthrough():
    hw = get_hw("wormhole_8x8")
    s = FaultSchedule([FaultSpec("worker_crash")])
    assert s.degraded_hw(hw) is hw               # no hw faults -> same object


def test_schedule_skips_faults_that_do_not_fit_the_mesh():
    # one REPRO_FAULTS setting is applied across benchmark sweeps over many
    # mesh shapes: faults outside a given fabric are skipped, not raised
    s = parse_faults("core:3,5;link:noc_h:0.5")
    small = get_hw("wormhole_1x8")
    deg = s.degraded_hw(small)                   # core (3,5) out of range
    assert not deg.disabled_cores
    big = s.degraded_hw(get_hw("wormhole_8x8"))
    assert big.disabled_core_set() == {(3, 5)}
    # a schedule that would kill every core leaves the fabric alone
    wipe = FaultSchedule([FaultSpec("core_kill", core=(0, c))
                          for c in range(8)])
    assert wipe.degraded_hw(small) is small


# ------------------------------------------------------ submesh fallback
def test_best_submesh_drops_the_cheapest_axis():
    hw = get_hw("wormhole_8x8")
    sub = best_submesh(hw.with_faults(disabled_cores=[(3, 5)]))
    assert sub.mesh_dims in ((("x", 7), ("y", 8)), (("x", 8), ("y", 7)))
    assert sub.n_cores == 56 and not sub.is_degraded
    # two holes in one column still cost only that column
    sub2 = best_submesh(hw.with_faults(disabled_cores=[(2, 5), (6, 5)]))
    assert sub2.n_cores == 56
    # the submesh still has a full interconnect set to plan against
    assert len(sub.interconnects) == len(hw.interconnects)


# -------------------------------------------------------- re-plan ladder
def test_detection_replan_resume_on_single_core_kill(fast_search,
                                                     fresh_store):
    """The headline path: a host heartbeat goes silent on wormhole_8x8, the
    orchestrator disables its cores, walks the ladder, and hands back a
    runnable plan for the surviving fabric — then the *same* failure
    re-plans as a pure cache hit (zero cold searches), as a warmed fault
    pool would."""
    from repro.runtime.fault_tolerance import HeartbeatRegistry
    hw = get_hw("wormhole_8x8")
    progs = _gemm_progs(512, 512, 512)
    reg = HeartbeatRegistry(2, timeout_s=10.0, now=0.0)
    orch = ReplanOrchestrator(hw, progs, registry=reg,
                              cache=plancache.PlanCache(),
                              host_cores={1: [(3, 5)]})
    reg.beat(0, 0, 1.0, now=0.0)
    reg.beat(1, 0, 1.0, now=0.0)
    assert orch.poll(now=5.0) is None            # everyone healthy
    reg.beat(0, 1, 1.0, now=20.0)                # host 1 went silent
    out = orch.poll(now=20.0)
    assert out is not None and out.cause == "core_kill"
    assert out.rung in ("bounded_search", "warm_search", "submesh_fallback")
    assert orch.current_hw.disabled_cores == ((3, 5),)
    # resume: the chosen plan simulates on its target model
    sim = simulate(out.plan, out.hw)
    assert sim.total_s == out.result.best.final_s > 0
    # second identical failure: rung-1 hit, zero planner searches
    calls = dict(PLAN_CALLS)
    hits = metrics.REGISTRY.counter("plancache_get_total")
    h0 = hits.value(result="hit_mem") + hits.value(result="hit_disk")
    again = plan_degraded(progs, orch.current_hw, healthy_hw=hw,
                          cache=plancache.PlanCache(), cause="core_kill")
    assert again.rung == "cache_hit"
    assert dict(PLAN_CALLS) == calls             # no cold search at all
    assert hits.value(result="hit_mem") + hits.value(result="hit_disk") > h0
    assert again.result.best.final_s == out.result.best.final_s
    m = metrics.counter_totals(metrics.snapshot(), ["replan_total"])
    assert m.get("replan_total", 0) >= 2


def test_degraded_plan_quality_within_bound(fast_search):
    """Acceptance: geomean(degraded / healthy simulated time) <= 1.35 over
    the gemm suite for a single dead core on wormhole_8x8 — the submesh
    quality floor is what keeps the full-mesh hole-avoiding plans from
    dominating."""
    hw = get_hw("wormhole_8x8")
    deg = hw.with_faults(disabled_cores=[(3, 5)])
    ratios = []
    for (M, N, K) in ((256, 256, 256), (512, 512, 512), (512, 1024, 512)):
        progs = _gemm_progs(M, N, K)
        out = plan_degraded(progs, deg, healthy_hw=hw, cause="bench")
        healthy = plan_kernel_multi(progs, hw, profile=True)
        ratios.append(out.result.best.final_s / healthy.best.final_s)
    geo = math.exp(sum(math.log(r) for r in ratios) / len(ratios))
    assert geo <= 1.35, f"degraded/healthy geomean {geo:.3f} > 1.35x"


def test_plan_degraded_rejects_healthy_mesh():
    with pytest.raises(ValueError):
        plan_degraded(_gemm_progs(), get_hw("wormhole_4x8"))


def test_replan_latency_budget_falls_back_to_submesh(fast_search):
    """An already-blown latency budget skips the bounded search and goes
    straight to the guaranteed submesh fallback (and says so)."""
    hw = get_hw("wormhole_8x8").with_faults(disabled_cores=[(0, 0)])
    out = plan_degraded(_gemm_progs(), hw, latency_budget_s=0.0,
                        cause="core_kill")
    assert out.rung == "submesh_fallback"
    assert not out.within_budget
    assert any("skipping" in line for line in out.log)
    m = metrics.counter_totals(metrics.snapshot(),
                               ["replan_budget_exceeded_total"])
    assert m.get("replan_budget_exceeded_total", 0) >= 1


def test_orchestrator_straggler_and_link_paths(fast_search, fresh_store):
    from repro.runtime.fault_tolerance import (HeartbeatRegistry,
                                               StragglerTracker)
    hw = get_hw("wormhole_4x8")
    reg = HeartbeatRegistry(3, timeout_s=1e9, now=0.0)
    for step in range(10):
        for h in range(3):
            reg.beat(h, step, 4.0 if h == 2 else 1.0, now=float(step))
    orch = ReplanOrchestrator(hw, _gemm_progs(), registry=reg,
                              tracker=StragglerTracker(reg),
                              host_cores={2: [(0, 7)]})
    out = orch.poll(now=9.0)
    assert out is not None and out.cause == "straggler"
    assert (0, 7) in orch.current_hw.disabled_core_set()
    assert orch.poll(now=9.5) is None            # handled hosts don't repeat
    out2 = orch.degrade_links([("noc_h", 0.5)])
    assert out2.cause == "link_slow"
    assert dict(orch.current_hw.degraded_links)["noc_h"] == 0.5
    assert len(orch.outcomes) == 2


# ------------------------------------------------- pool worker hardening
def test_killed_search_worker_does_not_fail_plan(fast_search, monkeypatch,
                                                 tmp_path):
    """Acceptance: a search worker hard-exiting mid-shard no longer fails
    plan_kernel_multi — the pool is rebuilt and the result is identical to
    the inline search."""
    from repro.parallel import search_exec
    hw = get_hw("wormhole_4x8")
    progs = _gemm_progs(256, 256, 256)
    inline = plan_kernel_multi(progs, hw, profile=True)

    sched = FaultSchedule([FaultSpec("worker_crash")])
    marker = sched.arm_worker_crash(directory=str(tmp_path))
    try:
        monkeypatch.setenv("REPRO_PLANNER_WORKERS", "2")
        fails = metrics.REGISTRY.counter("search_pool_failures_total")
        f0 = fails.total()
        res = plan_kernel_multi(progs, hw, profile=True)
        assert not os.path.exists(marker)        # a worker really died
        assert fails.total() > f0
        assert res.best.plan.describe() == inline.best.plan.describe()
        assert res.best.final_s == inline.best.final_s
    finally:
        FaultSchedule.disarm_worker_crash()
        search_exec.shutdown_pool()


def test_degraded_hw_ships_to_pool_workers(fast_search, monkeypatch):
    """The preset_faults transport: a degraded preset round-trips into
    worker processes and the sharded search matches inline exactly."""
    from repro.parallel import search_exec
    hw = get_hw("wormhole_4x8").with_faults(disabled_cores=[(1, 3)])
    spec = search_exec.hw_spec(hw)
    assert spec is not None and spec[0] == "preset_faults"
    assert search_exec.hw_from_spec(spec).df_text() == hw.df_text()
    progs = _gemm_progs(256, 256, 256)
    inline = plan_kernel_multi(progs, hw, profile=True)
    try:
        monkeypatch.setenv("REPRO_PLANNER_WORKERS", "2")
        sharded = plan_kernel_multi(progs, hw, profile=True)
        assert sharded.best.plan.describe() == inline.best.plan.describe()
        assert sharded.best.final_s == inline.best.final_s
    finally:
        search_exec.shutdown_pool()


# -------------------------------------------------- schema compatibility
def test_v3_schema_entries_are_misses_after_fault_overlay_bump(tmp_path,
                                                               monkeypatch):
    """Backward compat across the v3 -> v4 schema bump (fault-overlay hw
    keys): pre-bump entries read as misses — counted, never deserialized —
    mirroring the v1 -> v2 and v2 -> v3 cases; and a degraded fabric keys
    differently from its healthy twin."""
    import json
    assert plancache.keying.SCHEMA_VERSION >= 4
    store = plancache.PlanCacheStore(tmp_path, enabled=True)
    hw = get_hw("wormhole_8x8")
    deg = hw.with_faults(disabled_cores=[(0, 0)])
    prog = matmul_program(256, 256, 256, bm=64, bn=64, bk=64)
    k_h = plancache.kernel_key([prog], hw, BUDGET)
    k_d = plancache.kernel_key([prog], deg, BUDGET)
    assert k_h != k_d                            # fault overlay forks the key
    store.put(k_d, {"result": {"kernel": "stale-v3-layout"}}, {})
    p = store._path(k_d)
    data = json.loads(p.read_text())
    data["schema"] = 3                           # a real pre-bump entry
    p.write_text(json.dumps(data))
    store.clear_memory()
    misses = store.stats.misses
    assert store.get(k_d) is None
    assert store.stats.misses == misses + 1
