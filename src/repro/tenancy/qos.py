"""Tenant QoS classes and per-tenant admission (DESIGN_TENANCY.md).

Two classes, the smallest vocabulary that expresses the serving contract:

* ``guaranteed``   — always admitted with its requested plan deadline; never
  preempted below its QoS by a repartition (the runtime gives it a full
  resolve, not the fallback rung);
* ``best_effort``  — admitted through a bounded gate layered on the PR 8
  semaphore machinery; under pressure its *deadline* is shed to 0 ms, which
  walks the :class:`~repro.planservice.PlanService` ladder straight to the
  memoized generic fallback (rung 4) — the tenant still gets a runnable
  plan, just not a searched one.  On a global repartition, best-effort
  tenants are evicted the same way (bounded disruption: the expensive joint
  search is spent on guaranteed tenants only).

Shedding by deadline rather than by rejection keeps the service's "always
return a runnable plan" contract intact across the tenancy layer — no
caller ever has to handle an admission error mid-decode.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Iterator, Optional

from repro.obs import flightrec, metrics

from .partition import QOS_CLASSES, TenantSpec


class TenantAdmission:
    """Per-tenant admission gate over the plan service.

    ``max_best_effort`` bounds *concurrent* best-effort resolutions (the
    guaranteed class is never gated).  :meth:`admit` yields the effective
    ``budget_ms`` to pass to the service: the tenant's requested deadline
    when admitted, ``0.0`` (straight to the fallback rung) when shed.
    Emits ``tenancy_admitted_total{tenant,qos,outcome}``.
    """

    def __init__(self, *, max_best_effort: int = 2) -> None:
        if max_best_effort < 0:
            raise ValueError("max_best_effort must be >= 0")
        self._no_best_effort = max_best_effort == 0
        self._gate = threading.BoundedSemaphore(max(1, max_best_effort))
        self.shed_total: Dict[str, int] = {}

    @contextmanager
    def admit(self, tenant: TenantSpec,
              budget_ms: Optional[float] = None
              ) -> Iterator[Optional[float]]:
        """``with admission.admit(tenant) as ms: service.resolve(...,
        budget_ms=ms)``.  Guaranteed tenants pass through untouched;
        best-effort tenants either hold a gate slot for the duration or
        are shed to a 0 ms deadline."""
        if tenant.qos not in QOS_CLASSES:
            raise ValueError(f"unknown qos {tenant.qos!r}")
        if tenant.qos == "guaranteed":
            metrics.inc("tenancy_admitted_total", tenant=tenant.name,
                        qos=tenant.qos, outcome="admitted")
            yield budget_ms
            return
        if not self._no_best_effort and self._gate.acquire(blocking=False):
            metrics.inc("tenancy_admitted_total", tenant=tenant.name,
                        qos=tenant.qos, outcome="admitted")
            try:
                yield budget_ms
            finally:
                self._gate.release()
            return
        self.shed_total[tenant.name] = self.shed_total.get(tenant.name,
                                                           0) + 1
        metrics.inc("tenancy_admitted_total", tenant=tenant.name,
                    qos=tenant.qos, outcome="shed")
        flightrec.record("qos_shed", tenant=tenant.name, qos=tenant.qos,
                         requested_ms=budget_ms)
        yield 0.0                          # deadline 0: fallback rung only
