"""Multi-tenant mesh partitioning with fault-domain isolation
(DESIGN_TENANCY.md).

* :mod:`~repro.tenancy.partition` — ``submesh()`` logical-partition
  models, guillotine layout enumeration, and the joint
  partition-shape x per-tenant-plan search (:class:`MeshPartitioner`);
* :mod:`~repro.tenancy.qos`       — guaranteed/best-effort admission
  (:class:`TenantAdmission`);
* :mod:`~repro.tenancy.validator` — the pre-serve isolation gate
  (:class:`IsolationValidator`);
* :mod:`~repro.tenancy.runtime`   — contained re-planning
  (:class:`TenantRuntime`, blast radius measured per event).
"""
from .partition import (MeshPartitioner, Rect, TenancyPlan, TenantPlacement,
                        TenantSpec, enumerate_layouts, plan_digest, submesh)
from .qos import TenantAdmission
from .runtime import TENANCY_RUNGS, ContainedReplan, TenantRuntime
from .validator import IsolationValidator

__all__ = [
    "ContainedReplan", "IsolationValidator", "MeshPartitioner", "Rect",
    "TENANCY_RUNGS", "TenancyPlan", "TenantAdmission", "TenantPlacement",
    "TenantRuntime", "TenantSpec", "enumerate_layouts", "plan_digest",
    "submesh",
]
