"""Contained re-planning: the per-partition degradation ladder.

The containment invariant this module enforces (and the property tests
state): **a fault re-plans only the tenant that owns the faulted cell** —
every other tenant's plan stays byte-identical (same
:func:`~repro.tenancy.partition.plan_digest`).  Ownership is rect
membership: a ``core_kill`` at global coords, or a ``link_slow`` localized
by an ``at=`` coordinate, belongs to exactly one partition (or to the free
/spare region, in which case *no* tenant re-plans at all).

The owning tenant walks a three-rung ladder, strictly widening the blast
radius only when the previous rung cannot deliver:

* ``shrink_in_place``  — PR 7's :func:`~repro.runtime.replan.plan_degraded`
  on the tenant's own submesh with the fault as a *local* overlay (warmed
  partition fault pools answer at its rung 1 with zero search);
* ``claim_adjacent``   — grow the rect one plane into all-free adjacent
  cells (the :class:`MeshPartitioner`'s ``spare_planes`` strip exists for
  this) and plan the expanded, still-degraded submesh; taken when
  shrinking is infeasible or costs more than ``claim_threshold``x the
  pre-fault time;
* ``repartition``      — the last resort with a deliberately bounded
  disruption contract: the full joint search re-runs for **guaranteed**
  tenants only, while best-effort tenants are evicted to the service's
  fallback rung (deadline 0 walks straight to the memoized generic plan).
  Never the other way around.

Every event emits ``tenancy_replan_total{tenant,rung}`` and a
``tenancy_blast_radius`` observation (number of tenants whose plan
changed), so containment is a measured property, not a comment.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.hw import HardwareModel
from repro.core.planner import SearchBudget
from repro.obs import context, flightrec, metrics, slo, trace
from repro.runtime.faults import FaultSpec
from repro.runtime.replan import plan_degraded

from .partition import (MeshPartitioner, Rect, TenancyPlan, TenantPlacement,
                        submesh)
from .validator import IsolationValidator

TENANCY_RUNGS = ("none", "shrink_in_place", "claim_adjacent", "repartition")


@dataclass
class ContainedReplan:
    """One handled fault event, with the evidence for containment."""
    cause: str                       # core_kill | link_slow
    owner: Optional[str]             # owning tenant; None = free/spare cell
    rung: str                        # member of TENANCY_RUNGS
    replanned: Tuple[str, ...]       # tenants whose plan changed
    blast_radius: int                # == len(replanned)
    seconds: float
    within_budget: bool
    digests_before: Dict[str, str]
    digests_after: Dict[str, str]
    log: List[str] = field(default_factory=list)

    @property
    def untouched(self) -> Tuple[str, ...]:
        return tuple(t for t in self.digests_before
                     if t not in self.replanned)

    def contained(self) -> bool:
        """True iff every non-replanned tenant's plan digest is unchanged
        — the invariant, checked on the actual bytes."""
        return all(self.digests_after.get(t) == d
                   for t, d in self.digests_before.items()
                   if t not in self.replanned)


class TenantRuntime:
    """Owns a live :class:`TenancyPlan` and applies fault events to it
    with contained blast radius.

    ``latency_budget_s`` bounds each owning tenant's trip down the
    ladder (None: ``REPRO_PLAN_DEADLINE_MS``, the same deadline the plan
    service answers under — with warmed partition fault pools the
    shrink-in-place rung is a cache hit and meets even the 10 ms
    default); ``claim_threshold`` is the shrink-vs-claim quality bar
    (shrinking that costs more than this factor over the pre-fault time
    escalates to claiming adjacent free cells).
    """

    def __init__(self, plan: TenancyPlan, *, service: Any,
                 cache: Optional[Any] = None,
                 budget: Optional[SearchBudget] = None,
                 partitioner: Optional[MeshPartitioner] = None,
                 validator: Optional[IsolationValidator] = None,
                 latency_budget_s: Optional[float] = None,
                 claim_threshold: float = 2.0) -> None:
        self.plan = plan
        self.service = service
        self.cache = cache if cache is not None \
            else getattr(service, "cache", None)
        self.budget = budget
        self.partitioner = partitioner if partitioner is not None \
            else MeshPartitioner()
        self.validator = validator if validator is not None \
            else IsolationValidator()
        if latency_budget_s is None:
            from repro.planservice.service import default_deadline_ms
            latency_budget_s = default_deadline_ms() / 1e3
        self.latency_budget_s = latency_budget_s
        self.claim_threshold = claim_threshold
        # current fabric with the cumulative fault overlay (global coords);
        # submesh() restricts + renumbers it per partition
        self.hw = plan.hw
        # pre-fault submesh per tenant: the warm-start seed for the ladder
        self._healthy_sub: Dict[str, HardwareModel] = {
            p.tenant.name: p.hw for p in plan.placements}
        self.events: List[ContainedReplan] = []

    # ----------------------------------------------------------- fault API
    def inject(self, fault: FaultSpec,
               at: Optional[Sequence[int]] = None) -> ContainedReplan:
        """Apply one :class:`FaultSpec`.  ``at`` localizes a ``link_slow``
        to the partition owning that core coordinate (switch telemetry
        names the failing port; its coords are the localization a real
        deployment has)."""
        if fault.kind == "core_kill":
            return self.kill_core(fault.core)
        if fault.kind == "link_slow":
            return self.slow_link(fault.link, fault.factor, at=at)
        raise ValueError(f"tenancy runtime handles hardware faults only, "
                         f"not {fault.kind!r}")

    def kill_core(self, core: Sequence[int]) -> ContainedReplan:
        core = tuple(int(v) for v in core)
        # one incident ID covers the fault, the owning tenant's ladder
        # trip, and every plan-service resolve nested under it
        with context.correlate("incident"):
            flightrec.record("fault", cause="core_kill", cell=core,
                             hw=self.hw.name)
            self.hw = self.hw.with_faults(disabled_cores=[core])
            owner = self.plan.owner_of(core)
            return self._handle("core_kill", owner, faulted_cell=core)

    def slow_link(self, link: str, factor: float,
                  at: Optional[Sequence[int]] = None) -> ContainedReplan:
        with context.correlate("incident"):
            flightrec.record("fault", cause="link_slow", link=link,
                             factor=factor,
                             cell=tuple(at) if at is not None else None,
                             hw=self.hw.name)
            if at is not None:
                at = tuple(int(v) for v in at)
                owner = self.plan.owner_of(at)
                if owner is not None:
                    # physically the links inside a partition are disjoint
                    # from every other partition's, even though the model
                    # names them once per fabric: degrade the owner's
                    # submesh only, and leave the global model untouched
                    return self._handle("link_slow", owner, faulted_cell=at,
                                        link=(link, factor))
                # fell on a free/spare cell: record on the fabric so future
                # repartitions see it, but nobody re-plans
                self.hw = self._degrade_global(link, factor)
                return self._handle("link_slow", None, faulted_cell=at)
            # unlocalized: the honest blast radius is every tenant
            self.hw = self._degrade_global(link, factor)
            return self._handle_global_link()

    def _degrade_global(self, link: str, factor: float) -> HardwareModel:
        try:
            return self.hw.with_faults(degraded_links=[(link, factor)])
        except ValueError:               # axis not present on this fabric
            return self.hw

    # ------------------------------------------------------------- ladder
    def _handle(self, cause: str, owner: Optional[TenantPlacement], *,
                faulted_cell: Tuple[int, ...],
                link: Optional[Tuple[str, float]] = None) -> ContainedReplan:
        t0 = time.perf_counter()
        before = self.plan.digests()
        metrics.inc("tenancy_fault_events_total", cause=cause)
        log: List[str] = []
        if owner is None:
            log.append(f"{cause} at {faulted_cell}: free/spare cell, "
                       f"no tenant affected")
            return self._finish(cause, None, "none", (), t0, before, log)

        name = owner.tenant.name
        log.append(f"{cause} at {faulted_cell}: owned by {name} "
                   f"({owner.rect.describe()})")
        with trace.span("tenancy.contain", cat="tenancy", cause=cause,
                        tenant=name):
            pre_fault_s = owner.sim_s
            rung, outcome, new_rect, new_hw = self._contain(
                owner, faulted_cell, link, pre_fault_s, log)
            if rung == "repartition":
                return self._repartition(cause, name, t0, before, log)
            owner.rect = new_rect
            owner.hw = new_hw
            owner.response = outcome
            owner.rung = f"tenancy:{rung}"
            bad = self.validator.validate(self.plan)
            if bad:
                log.append(f"isolation validation failed after {rung}: "
                           f"{bad}; escalating to repartition")
                return self._repartition(cause, name, t0, before, log)
            if self.service is not None and hasattr(self.service,
                                                    "note_fault"):
                self.service.note_fault(outcome)
            return self._finish(cause, name, rung, (name,), t0, before, log,
                                within=outcome.within_budget)

    def _contain(self, owner: TenantPlacement,
                 cell: Tuple[int, ...], link: Optional[Tuple[str, float]],
                 pre_fault_s: float, log: List[str]):
        """Rungs 1-2 for the owning tenant.  Returns
        (rung, outcome, rect, hw) or ("repartition", None, None, None)."""
        name = owner.tenant.name
        healthy = self._healthy_sub[name]
        programs = list(owner.tenant.programs)

        def degraded_sub(rect: Rect) -> HardwareModel:
            sub = submesh(self.hw, rect.origin, rect.shape)
            if link is not None:
                try:
                    sub = sub.with_faults(degraded_links=[link])
                except ValueError:
                    pass                 # link's axis collapsed away
            return sub

        # ---- rung 1: shrink in place ---------------------------------
        shrink = None
        try:
            sub = degraded_sub(owner.rect)
            if not sub.is_degraded:
                log.append("fault vanished inside the partition model; "
                           "keeping the current plan")
                return "none", owner.response, owner.rect, owner.hw
            shrink = plan_degraded(
                programs, sub, healthy_hw=healthy, cache=self.cache,
                budget=self.budget, latency_budget_s=self.latency_budget_s,
                cause=f"tenancy_{name}")
            log.append(f"shrink_in_place: {shrink.rung} "
                       f"{shrink.result.best.final_s * 1e6:.1f}us "
                       f"(pre-fault {pre_fault_s * 1e6:.1f}us)")
        except (RuntimeError, ValueError) as e:
            log.append(f"shrink_in_place infeasible: {e}")

        good_enough = (shrink is not None
                       and shrink.result.best.final_s
                       <= self.claim_threshold * pre_fault_s)
        if good_enough:
            return "shrink_in_place", shrink, owner.rect, shrink.hw

        # ---- rung 2: claim adjacent free cells -----------------------
        grown = self._claim_adjacent(owner, degraded_sub, programs,
                                     healthy, log)
        if grown is not None:
            rect, outcome = grown
            if (shrink is None or outcome.result.best.final_s
                    < shrink.result.best.final_s):
                return "claim_adjacent", outcome, rect, outcome.hw
        if shrink is not None:           # degraded but alive beats nothing
            return "shrink_in_place", shrink, owner.rect, shrink.hw
        return "repartition", None, None, None

    def _claim_adjacent(self, owner: TenantPlacement, degraded_sub,
                        programs, healthy, log: List[str]
                        ) -> Optional[Tuple[Rect, Any]]:
        free = self.plan.free_cells()
        sizes = [s for _, s in self.hw.mesh_dims]
        for axis in range(len(sizes)):
            for direction in (1, -1):
                try:
                    rect = owner.rect.expanded(axis, direction)
                except ValueError:
                    continue             # expansion walks off the mesh edge
                if not rect.within(sizes):
                    continue
                gained = set(rect.cells()) - set(owner.rect.cells())
                if not gained or not gained <= free:
                    continue
                try:
                    sub = degraded_sub(rect)
                    if not sub.is_degraded:
                        continue         # plan_degraded needs the overlay
                    out = plan_degraded(
                        programs, sub, healthy_hw=healthy,
                        cache=self.cache, budget=self.budget,
                        latency_budget_s=self.latency_budget_s,
                        cause=f"tenancy_{owner.tenant.name}")
                    log.append(
                        f"claim_adjacent: grew to {rect.describe()}, "
                        f"{out.rung} {out.result.best.final_s * 1e6:.1f}us")
                    return rect, out
                except (RuntimeError, ValueError) as e:
                    log.append(f"claim_adjacent {rect.describe()} "
                               f"infeasible: {e}")
        return None

    # ------------------------------------------- global-blast-radius paths
    def _handle_global_link(self) -> ContainedReplan:
        """An unlocalized link_slow degrades the shared fabric model: the
        honest answer is that every tenant re-plans in place (each on its
        own submesh, still inside its own rect — partitions don't move)."""
        t0 = time.perf_counter()
        before = self.plan.digests()
        metrics.inc("tenancy_fault_events_total", cause="link_slow")
        log: List[str] = ["unlocalized link_slow: all tenants re-plan "
                          "in place"]
        replanned: List[str] = []
        within = True
        for p in self.plan.placements:
            name = p.tenant.name
            sub = submesh(self.hw, p.rect.origin, p.rect.shape)
            if not sub.is_degraded:
                continue                 # link didn't survive into this rect
            out = plan_degraded(
                list(p.tenant.programs), sub,
                healthy_hw=self._healthy_sub[name], cache=self.cache,
                budget=self.budget, latency_budget_s=self.latency_budget_s,
                cause=f"tenancy_{name}")
            p.hw, p.response = out.hw, out
            p.rung = "tenancy:shrink_in_place"
            within = within and out.within_budget
            replanned.append(name)
        return self._finish("link_slow", None, "shrink_in_place",
                            tuple(replanned), t0, before, log, within=within)

    def _repartition(self, cause: str, owner: str, t0: float,
                     before: Dict[str, str],
                     log: List[str]) -> ContainedReplan:
        """Rung 3: re-run the joint search on the degraded fabric.
        Bounded disruption: best-effort tenants resolve at deadline 0
        (the service's memoized fallback rung), guaranteed tenants get
        the full deadline."""
        tenants = [p.tenant for p in self.plan.placements]
        evict = {t.name: 0.0 for t in tenants if t.qos == "best_effort"}
        if evict:
            log.append(f"repartition: evicting best-effort "
                       f"{sorted(evict)} to the fallback rung")
            for t in sorted(evict):
                metrics.inc("tenancy_evicted_total", tenant=t)
                flightrec.record("qos_evict", tenant=t, cause=cause)
        new_plan = self.partitioner.plan(
            self.hw, tenants, service=self.service, budget=self.budget,
            tenant_budget_ms=evict or None)
        bad = self.validator.validate(new_plan)
        if bad:
            raise RuntimeError(f"repartition of {self.hw.name} failed "
                               f"isolation validation: {bad}")
        self.plan = new_plan
        self._healthy_sub = {p.tenant.name: p.hw
                             for p in new_plan.placements}
        for p in new_plan.placements:
            p.rung = "tenancy:repartition"
        if self.service is not None and hasattr(self.service, "note_fault"):
            self.service.note_fault(
                type("_Evt", (), {"cause": cause})())
        after = self.plan.digests()
        replanned = tuple(t for t, d in after.items()
                          if before.get(t) != d)
        log.append(f"repartition: {len(replanned)}/{len(after)} tenant "
                   f"plans changed")
        return self._finish(cause, owner, "repartition", replanned, t0,
                            before, log)

    # ------------------------------------------------------------- finish
    def _finish(self, cause: str, owner: Optional[str], rung: str,
                replanned: Tuple[str, ...], t0: float,
                before: Dict[str, str], log: List[str], *,
                within: bool = True) -> ContainedReplan:
        seconds = time.perf_counter() - t0
        for t in replanned:
            metrics.inc("tenancy_replan_total", tenant=t, rung=rung)
        metrics.observe("tenancy_blast_radius", float(len(replanned)),
                        cause=cause)
        metrics.observe("tenancy_contain_seconds", seconds, rung=rung)
        flightrec.record("containment", cause=cause, owner=owner,
                         rung=rung, blast_radius=len(replanned),
                         replanned=replanned, seconds=seconds,
                         within_budget=within, log=log)
        slo.note_containment(owner if owner is not None else "(shared)",
                             len(replanned), rung=rung)
        ev = ContainedReplan(
            cause=cause, owner=owner, rung=rung, replanned=replanned,
            blast_radius=len(replanned), seconds=seconds,
            within_budget=within, digests_before=before,
            digests_after=self.plan.digests(), log=log)
        self.events.append(ev)
        return ev
