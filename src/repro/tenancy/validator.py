"""IsolationValidator: the gate run before any partitioned plan is served.

The single-tenant sanitizer (:func:`repro.plancache.validate.validate_plan`)
checks one plan against one model; multi-tenancy adds *cross-tenant*
failure modes it cannot see:

* overlapping partitions (two tenants' waves landing on the same cores);
* a rect that walks off the physical mesh;
* a plan whose spatial binds exceed its own partition (it was computed on
  the wrong submesh model, or the placement was edited after planning);
* joint DRAM residency: partitions slice the core mesh, but every tenant's
  tensors live in the *same* physical DRAM — the sum of per-tenant
  footprints must fit even though each fits alone.  (L1 needs no joint
  check: scratchpads are per-core and partitions are disjoint, so the
  per-plan residency check *is* the joint check.)

Like the sanitizer it wraps, :func:`IsolationValidator.validate` never
raises — it returns the violation list, empty when the partitioned plan
is servable.
"""
from __future__ import annotations

from typing import List

from repro.obs import flightrec, metrics
from repro.plancache.validate import dram_residency_bytes, validate_plan

from .partition import TenancyPlan


class IsolationValidator:
    """Structural isolation checks over a :class:`TenancyPlan`.

    ``dram_slack`` scales the joint-DRAM capacity check (1.0 = the full
    physical capacity; serving deployments reserve headroom for KV-cache
    growth by passing < 1.0).
    """

    def __init__(self, *, dram_slack: float = 1.0) -> None:
        if not 0.0 < dram_slack <= 1.0:
            raise ValueError(f"dram_slack must be in (0, 1], got {dram_slack}")
        self.dram_slack = dram_slack

    def validate(self, plan: TenancyPlan) -> List[str]:
        try:
            bad = self._validate(plan)
        except Exception as e:  # noqa: BLE001 — the gate must not throw
            bad = [f"isolation validator error: {e!r}"]
        if bad:
            metrics.inc("tenancy_isolation_violations_total", len(bad),
                        hw=plan.hw.name)
            flightrec.record("violation", hw=plan.hw.name, problems=bad)
            # an isolation violation is the incident the recorder exists
            # for: force the dump NOW, before any escalation path (or the
            # serve driver's SystemExit) can lose the buffer
            flightrec.dump(reason="isolation_violation")
        return bad

    def _validate(self, plan: TenancyPlan) -> List[str]:
        bad: List[str] = []
        hw = plan.hw
        sizes = [s for _, s in hw.mesh_dims]
        places = plan.placements

        # -- partition geometry: on-mesh, pairwise disjoint ----------------
        for p in places:
            if len(p.rect.origin) != len(sizes):
                bad.append(f"{p.tenant.name}: rect rank "
                           f"{len(p.rect.origin)} vs mesh rank {len(sizes)}")
            elif not p.rect.within(sizes):
                bad.append(f"{p.tenant.name}: rect {p.rect.describe()} "
                           f"exceeds {hw.name} mesh "
                           f"{'x'.join(str(s) for s in sizes)}")
        for i, a in enumerate(places):
            for b in places[i + 1:]:
                if a.rect.overlaps(b.rect):
                    bad.append(f"partitions overlap: {a.tenant.name} "
                               f"{a.rect.describe()} vs {b.tenant.name} "
                               f"{b.rect.describe()}")
        if bad:
            return bad                     # geometry broken: stop here

        # -- per-tenant plan vs its own submesh model ----------------------
        for p in places:
            if p.response is None or p.result is None:
                bad.append(f"{p.tenant.name}: no plan resolved")
                continue
            for v in validate_plan(p.plan, p.hw):
                bad.append(f"{p.tenant.name}: {v}")
            # binds may not reach outside the partition even if the plan
            # was (wrongly) computed against a larger model
            part = dict(p.hw.mesh_dims)
            for b in p.plan.mapping.spatial:
                limit = part.get(b.hw_dim)
                if limit is not None and b.hw_size > limit:
                    bad.append(
                        f"{p.tenant.name}: bind {b.grid_dim}->{b.hw_dim} "
                        f"size {b.hw_size} exceeds partition "
                        f"{p.rect.describe()}")

        # -- joint DRAM residency across co-located tenants ----------------
        cap = int(hw.global_mem.size_bytes * hw.global_mem.count(hw)
                  * self.dram_slack)
        total = sum(dram_residency_bytes(p.plan) for p in places
                    if p.response is not None and p.result is not None)
        if total > cap:
            bad.append(f"joint DRAM residency {total} B across "
                       f"{len(places)} tenants exceeds {cap} B on {hw.name}")
        return bad
