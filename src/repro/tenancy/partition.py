"""Disjoint rectangular mesh partitioning (DESIGN_TENANCY.md).

Production serving never runs one kernel on the whole fabric: k concurrent
tenants each get a **rectangular sub-mesh** of the physical mesh, planned
independently on a logical :func:`submesh` hardware model.  Because the
submesh is a full :class:`~repro.core.hw.HardwareModel` whose ``df_text()``
differs from the parent's, plan-cache digests fork automatically — warmed
partition pools behave exactly like PR 7's fault pools, and a plan found
for one 4x8 partition serves every 4x8 partition of the same fabric
(the submesh model is deliberately *origin-independent*, see below).

Layers in this module:

* :class:`Rect` — a half-open rectangular window over the core mesh;
* :func:`submesh` — the offset-aware generalization of
  ``runtime.replan._shrink_axis``: carve ``hw`` down to ``Rect(origin,
  shape)`` with rebuilt ring/torus interconnects and the fault overlay
  restricted to (and renumbered into) the window;
* :func:`enumerate_layouts` — ordered guillotine partitions of the mesh
  into k rectangles, cut positions biased toward the tenants' weight
  shares;
* :class:`MeshPartitioner` — the joint search: layouts are ranked by an
  admissible per-tenant roofline floor (``planservice.family
  .program_floor``), then the top few are *planned for real* through the
  PR 8 :class:`~repro.planservice.PlanService` and the best simulated
  makespan wins.

Origin independence: the submesh keeps the parent's DRAM-channel map
evaluated at the *renumbered* (local) coordinates — the same documented
approximation ``_shrink_axis`` makes — so two same-shape partitions at
different origins produce byte-identical ``df_text()`` and share one
plan-cache digest.  That is what makes partition pools warmable per
*shape* rather than per placement.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from dataclasses import dataclass, field
from typing import (Any, Dict, Iterator, List, Optional, Sequence, Set,
                    Tuple)

from repro.core.hw import HardwareModel, Interconnect, SpatialDim, _ring_map
from repro.core.planner import SearchBudget
from repro.core.program import TileProgram
from repro.obs import metrics, trace
from repro.plancache import keying, serialize

QOS_CLASSES = ("guaranteed", "best_effort")


# --------------------------------------------------------------------------
# Rect — a half-open window over the core mesh
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class Rect:
    """``[origin, origin + shape)`` over the mesh axes in
    ``hw.core.scaleout`` order."""
    origin: Tuple[int, ...]
    shape: Tuple[int, ...]

    def __post_init__(self) -> None:
        if len(self.origin) != len(self.shape):
            raise ValueError(f"origin {self.origin} and shape {self.shape} "
                             f"rank mismatch")
        if any(o < 0 for o in self.origin) or any(s < 1 for s in self.shape):
            raise ValueError(f"bad rect origin={self.origin} "
                             f"shape={self.shape}")

    @property
    def n_cells(self) -> int:
        return math.prod(self.shape)

    @property
    def end(self) -> Tuple[int, ...]:
        return tuple(o + s for o, s in zip(self.origin, self.shape))

    def contains(self, coords: Sequence[int]) -> bool:
        return all(o <= c < e for o, c, e in
                   zip(self.origin, coords, self.end))

    def local(self, coords: Sequence[int]) -> Tuple[int, ...]:
        """Global mesh coords -> partition-local coords."""
        return tuple(c - o for c, o in zip(coords, self.origin))

    def overlaps(self, other: "Rect") -> bool:
        return all(o1 < e2 and o2 < e1 for o1, e1, o2, e2 in
                   zip(self.origin, self.end, other.origin, other.end))

    def within(self, sizes: Sequence[int]) -> bool:
        return all(e <= s for e, s in zip(self.end, sizes))

    def cells(self) -> Iterator[Tuple[int, ...]]:
        return itertools.product(*(range(o, e) for o, e in
                                   zip(self.origin, self.end)))

    def expanded(self, axis: int, direction: int) -> "Rect":
        """The rect grown by one plane along ``axis`` (+1 after the end,
        -1 before the origin)."""
        origin = list(self.origin)
        shape = list(self.shape)
        if direction < 0:
            origin[axis] -= 1
        shape[axis] += 1
        return Rect(tuple(origin), tuple(shape))

    def describe(self) -> str:
        return ("x".join(str(s) for s in self.shape)
                + "@(" + ",".join(str(o) for o in self.origin) + ")")


# --------------------------------------------------------------------------
# submesh — offset-aware logical partition model
# --------------------------------------------------------------------------
def _ic_stride(ic: Interconnect, axis: str) -> int:
    moved = next((e for e in ic.map.exprs
                  if not (e.coeffs == ((axis, 1),) and e.const == 0
                          and e.mod is None and e.floordiv is None)), None)
    return moved.const if moved is not None else 1


def submesh(hw: HardwareModel, origin: Sequence[int],
            shape: Sequence[int]) -> HardwareModel:
    """A logical :class:`HardwareModel` for the rectangular window
    ``[origin, origin + shape)`` of ``hw``'s core mesh.

    * Mesh spatial dims are resized to ``shape``; non-mesh dims (DRAM
      channel indices etc.) are untouched.
    * Ring interconnects along resized axes are rebuilt with the new
      modulus (same per-link bandwidth, including any degradation the
      parent overlay already applied); an axis shrunk to a single plane
      drops its interconnect, matching the presets (``wormhole_1x8`` has
      no ``noc_h``).
    * The fault overlay is restricted to cores inside the window and
      renumbered into local coordinates; degradation factors for
      surviving interconnects carry over.
    * The DRAM-channel and L1 muxes are kept and evaluated at the local
      coordinates — the same documented approximation
      ``runtime.replan._shrink_axis`` makes — so the model depends only
      on the *shape* (plus local faults), never on the origin.

    The identity window returns ``hw`` itself, byte-identical: a k=1
    tenancy plans exactly like a solo whole-mesh run.
    """
    mesh = hw.mesh_dims
    origin = tuple(int(v) for v in origin)
    shape = tuple(int(v) for v in shape)
    if len(origin) != len(mesh) or len(shape) != len(mesh):
        raise ValueError(
            f"origin {origin} / shape {shape} must have one entry per mesh "
            f"axis {tuple(n for n, _ in mesh)} of {hw.name}")
    rect = Rect(origin, shape)
    sizes = tuple(s for _, s in mesh)
    if not rect.within(sizes):
        raise ValueError(f"window {rect.describe()} exceeds {hw.name} mesh "
                         f"{'x'.join(str(s) for s in sizes)}")
    if origin == (0,) * len(mesh) and shape == sizes:
        return hw

    new_size = {name: shape[i] for i, (name, _) in enumerate(mesh)}
    dims = tuple(SpatialDim(d.name, new_size[d.name])
                 if d.name in new_size else d for d in hw.spatial_dims)
    new_mesh = [(name, new_size[name]) for name, _ in mesh]
    old_size = dict(mesh)
    ics: List[Interconnect] = []
    for ic in hw.interconnects:
        ax = ic.axis(hw.core.scaleout)
        if ax in new_size and new_size[ax] != old_size[ax]:
            if new_size[ax] <= 1:
                continue                     # a one-plane ring is no link
            ics.append(Interconnect(ic.name, ic.src, ic.dst,
                                    _ring_map(new_mesh, ax,
                                              _ic_stride(ic, ax)),
                                    ic.bandwidth_gbps))
        else:
            ics.append(ic)
    kept = {ic.name for ic in ics}
    disabled = tuple(sorted(rect.local(c) for c in hw.disabled_cores
                            if rect.contains(c)))
    if len(disabled) >= rect.n_cells:
        raise ValueError(f"window {rect.describe()} of {hw.name} has no "
                         f"healthy cores")
    degraded = tuple((n, f) for n, f in hw.degraded_links if n in kept)
    name = f"{hw.name}_part_{'x'.join(str(s) for s in shape)}"
    note = f"partition of {hw.name}: window {rect.describe()}"
    return dataclasses.replace(
        hw, name=name, spatial_dims=dims, interconnects=tuple(ics),
        disabled_cores=disabled, degraded_links=degraded,
        notes=(hw.notes + "; " if hw.notes else "") + note)


# --------------------------------------------------------------------------
# Tenants and placements
# --------------------------------------------------------------------------
@dataclass
class TenantSpec:
    """One tenant's workload: candidate programs (block shapes) plus its
    QoS class.  ``weight`` biases the partition search toward giving the
    tenant a proportional share of the mesh."""
    name: str
    programs: Sequence[TileProgram]
    qos: str = "guaranteed"
    weight: float = 1.0

    def __post_init__(self) -> None:
        self.programs = list(self.programs)
        if not self.programs:
            raise ValueError(f"tenant {self.name!r} has no programs")
        if self.qos not in QOS_CLASSES:
            raise ValueError(f"tenant {self.name!r}: qos {self.qos!r} not in "
                             f"{QOS_CLASSES}")
        if not self.weight > 0:
            raise ValueError(f"tenant {self.name!r}: weight {self.weight} "
                             f"must be > 0")


def plan_digest(plan: Any) -> str:
    """Content digest of a concrete plan — the byte-identity handle the
    containment invariant is stated (and property-tested) in."""
    return keying.digest_of(serialize.plan_to_dict(plan))


@dataclass
class TenantPlacement:
    """One tenant's slice of the mesh plus the plan it runs there.
    ``response`` is whatever resolved the plan — a
    :class:`~repro.planservice.PlanResponse` at placement time, a
    :class:`~repro.runtime.replan.ReplanOutcome` after a contained
    re-plan — anything with a ``.result`` :class:`PlanResult`."""
    tenant: TenantSpec
    rect: Rect
    hw: HardwareModel
    response: Any
    rung: str = "cache"

    @property
    def result(self):
        return self.response.result

    @property
    def plan(self):
        return self.result.best.plan

    @property
    def sim_s(self) -> float:
        return self.result.best.final_s

    @property
    def digest(self) -> str:
        return plan_digest(self.plan)


@dataclass
class TenancyPlan:
    """The partitioned fabric: disjoint placements plus the spare region
    left for contained growth (``claim_adjacent``)."""
    hw: HardwareModel            # the full fabric the rects index into
    region: Rect                 # partitionable window (mesh minus spare)
    placements: List[TenantPlacement]
    layout_score: float          # simulated makespan of the chosen layout
    n_layouts: int               # layouts considered by the joint search
    log: List[str] = field(default_factory=list)

    def placement(self, tenant: str) -> TenantPlacement:
        for p in self.placements:
            if p.tenant.name == tenant:
                return p
        raise KeyError(tenant)

    def owner_of(self, coords: Sequence[int]) -> Optional[TenantPlacement]:
        """The placement whose rect contains the (global) core coords, or
        None for free/spare cells — fault-domain ownership is exactly
        rect membership."""
        for p in self.placements:
            if p.rect.contains(coords):
                return p
        return None

    def free_cells(self) -> Set[Tuple[int, ...]]:
        sizes = [s for _, s in self.hw.mesh_dims]
        owned: Set[Tuple[int, ...]] = set()
        for p in self.placements:
            owned |= set(p.rect.cells())
        return set(itertools.product(*(range(s) for s in sizes))) - owned

    def digests(self) -> Dict[str, str]:
        return {p.tenant.name: p.digest for p in self.placements}

    def describe(self) -> str:
        return "; ".join(
            f"{p.tenant.name}[{p.tenant.qos}]={p.rect.describe()} "
            f"rung={p.rung} sim={p.sim_s * 1e6:.1f}us"
            for p in self.placements)


# --------------------------------------------------------------------------
# Layout enumeration — ordered guillotine cuts
# --------------------------------------------------------------------------
def enumerate_layouts(region: Rect, weights: Sequence[float], *,
                      cuts_per_split: int = 3,
                      max_layouts: int = 128) -> List[Tuple[Rect, ...]]:
    """Ordered guillotine partitions of ``region`` into ``len(weights)``
    rectangles (the i-th rect hosts tenant i).  Cut positions are ranked
    by closeness to the weight-proportional split and capped at
    ``cuts_per_split`` per (axis, group-split), so the candidate count
    stays bounded while proportional layouts are enumerated first —
    deterministic for a fixed (region, weights, knobs) input."""
    k = len(weights)
    if k < 1:
        raise ValueError("at least one tenant required")
    if region.n_cells < k:
        raise ValueError(f"region {region.describe()} has fewer cells than "
                         f"{k} tenants")
    out: List[Tuple[Rect, ...]] = []
    seen: Set[Tuple[Rect, ...]] = set()

    def rec(rect: Rect, ws: Sequence[float]) -> List[Tuple[Rect, ...]]:
        if len(ws) == 1:
            return [(rect,)]
        results: List[Tuple[Rect, ...]] = []
        for k1 in range(1, len(ws)):
            wa = sum(ws[:k1])
            wb = sum(ws[k1:])
            for axis in range(len(rect.shape)):
                size = rect.shape[axis]
                if size < 2:
                    continue
                target = size * wa / (wa + wb)
                cuts = sorted(range(1, size),
                              key=lambda p: (abs(p - target), p))
                for p in cuts[:max(1, cuts_per_split)]:
                    a_shape = list(rect.shape)
                    a_shape[axis] = p
                    b_origin = list(rect.origin)
                    b_origin[axis] += p
                    b_shape = list(rect.shape)
                    b_shape[axis] = size - p
                    a = Rect(rect.origin, tuple(a_shape))
                    b = Rect(tuple(b_origin), tuple(b_shape))
                    if a.n_cells < k1 or b.n_cells < len(ws) - k1:
                        continue
                    for left in rec(a, ws[:k1]):
                        for right in rec(b, ws[k1:]):
                            results.append(left + right)
        return results

    for layout in rec(region, list(weights)):
        if layout in seen:
            continue
        seen.add(layout)
        out.append(layout)
        if len(out) >= max_layouts:
            break
    if not out:
        raise ValueError(f"no feasible {k}-way layout of "
                         f"{region.describe()}")
    return out


# --------------------------------------------------------------------------
# MeshPartitioner — the joint partition-shape x per-tenant-plan search
# --------------------------------------------------------------------------
class MeshPartitioner:
    """Carve a fabric into disjoint tenant partitions, searching partition
    shapes jointly with the per-tenant plans.

    Two-phase, mirroring the planner's own bound-then-profile structure:
    candidate layouts are ranked by an admissible roofline floor per
    tenant (``planservice.family.program_floor`` on the candidate
    submesh — cheap, no search), then the best ``plan_layouts`` layouts
    are resolved for real through the PlanService (per-tenant deadline,
    warmed partition pools answer at rung 1) and the layout with the
    smallest simulated makespan wins.  Per-(tenant, submesh-digest)
    resolutions are memoized, so layouts sharing a partition shape share
    the plan.

    ``spare_planes`` reserves trailing planes of the largest mesh axis as
    an unassigned hot-spare strip: contained re-planning
    (``runtime.TenantRuntime``) can grow a degraded partition into it
    without touching any other tenant.
    """

    def __init__(self, *, spare_planes: int = 0, cuts_per_split: int = 3,
                 max_layouts: int = 128, plan_layouts: int = 3) -> None:
        if spare_planes < 0:
            raise ValueError("spare_planes must be >= 0")
        self.spare_planes = spare_planes
        self.cuts_per_split = cuts_per_split
        self.max_layouts = max_layouts
        self.plan_layouts = max(1, plan_layouts)

    # ------------------------------------------------------------- region
    def region(self, hw: HardwareModel) -> Rect:
        """The partitionable window: the full mesh minus the hot-spare
        strip (trailing planes of the largest axis; ties -> first axis in
        scaleout order)."""
        mesh = hw.mesh_dims
        sizes = [s for _, s in mesh]
        if not self.spare_planes:
            return Rect((0,) * len(mesh), tuple(sizes))
        axis = max(range(len(mesh)), key=lambda i: (sizes[i], -i))
        if sizes[axis] - self.spare_planes < 1:
            raise ValueError(f"spare_planes={self.spare_planes} leaves no "
                             f"partitionable plane of {hw.name}")
        shape = list(sizes)
        shape[axis] -= self.spare_planes
        return Rect((0,) * len(mesh), tuple(shape))

    # --------------------------------------------------------------- plan
    def plan(self, hw: HardwareModel, tenants: Sequence[TenantSpec], *,
             service: Any, budget: Optional[SearchBudget] = None,
             budget_ms: Optional[float] = None,
             tenant_budget_ms: Optional[Dict[str, float]] = None,
             regret_bound: Optional[float] = None,
             ) -> TenancyPlan:
        """The joint search.  ``tenant_budget_ms`` overrides the resolve
        deadline per tenant (the repartition path uses it to evict
        best-effort tenants to the fallback rung: deadline 0 walks the
        service ladder straight to rung 4).  ``regret_bound=0.0``
        disables the service's shape-family rung, forcing exact searches
        — the isolation property tests use it so in-partition plans are
        bit-for-bit the standalone submesh plans."""
        from repro.planservice import PlanRequest
        from repro.planservice.family import program_floor

        tenants = list(tenants)
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {names}")
        region = self.region(hw)
        log: List[str] = []
        with trace.span("tenancy.plan", cat="tenancy", hw=hw.name,
                        k=len(tenants)):
            layouts = enumerate_layouts(
                region, [t.weight for t in tenants],
                cuts_per_split=self.cuts_per_split,
                max_layouts=self.max_layouts)
            metrics.inc("tenancy_layouts_total", len(layouts), hw=hw.name)

            # ---- phase 1: admissible roofline ranking (no search) -------
            sub_memo: Dict[Tuple[int, ...], HardwareModel] = {}
            floor_memo: Dict[Tuple[int, str], float] = {}

            def sub_of(rect: Rect) -> HardwareModel:
                key = rect.origin + rect.shape
                sub = sub_memo.get(key)
                if sub is None:
                    sub = sub_memo[key] = submesh(hw, rect.origin, rect.shape)
                return sub

            def floor_of(i: int, rect: Rect) -> float:
                try:
                    sub = sub_of(rect)
                except ValueError:       # window has no healthy cores
                    return float("inf")
                key = (i, keying.hw_digest(sub))
                f = floor_memo.get(key)
                if f is None:
                    f = floor_memo[key] = min(
                        program_floor(p, sub) for p in tenants[i].programs)
                return f

            def proxy_score(layout: Tuple[Rect, ...]) -> Tuple[float, float]:
                floors = [floor_of(i, r) for i, r in enumerate(layout)]
                return (max(floors), sum(floors))

            ranked = sorted(range(len(layouts)),
                            key=lambda j: proxy_score(layouts[j]) + (j,))
            finalists = ranked[:self.plan_layouts]
            log.append(f"{len(layouts)} layouts, "
                       f"{len(finalists)} planned for real")

            # ---- phase 2: plan the finalists through the service --------
            resolve_memo: Dict[Tuple[int, str], Any] = {}

            def resolve(i: int, rect: Rect) -> Any:
                sub = sub_of(rect)
                key = (i, keying.hw_digest(sub))
                if key in resolve_memo:
                    return resolve_memo[key]
                t = tenants[i]
                ms = budget_ms
                if tenant_budget_ms and t.name in tenant_budget_ms:
                    ms = tenant_budget_ms[t.name]
                resp = service.resolve(PlanRequest(
                    programs=list(t.programs), hw=sub, budget=budget,
                    budget_ms=ms, regret_bound=regret_bound))
                resolve_memo[key] = resp
                return resp

            best: Optional[Tuple[Tuple[float, float], int]] = None
            for j in finalists:
                if proxy_score(layouts[j])[0] == float("inf"):
                    log.append(f"layout {j} infeasible (dead partition)")
                    continue
                times = []
                feasible = True
                for i, rect in enumerate(layouts[j]):
                    resp = resolve(i, rect)
                    if resp.result is None:
                        feasible = False
                        break
                    times.append(resp.result.best.final_s)
                if not feasible:
                    log.append(f"layout {j} infeasible")
                    continue
                score = (max(times), sum(times))
                if best is None or score < best[0]:
                    best = (score, j)
            if best is None:
                raise RuntimeError(
                    f"no feasible {len(tenants)}-tenant layout of {hw.name} "
                    f"(every finalist had an unplannable partition)")
            score, j = best
            placements = []
            for i, rect in enumerate(layouts[j]):
                resp = resolve(i, rect)
                placements.append(TenantPlacement(
                    tenant=tenants[i], rect=rect, hw=sub_of(rect),
                    response=resp, rung=getattr(resp, "rung", "search")))
                metrics.inc("tenancy_plans_total", tenant=tenants[i].name,
                            rung=getattr(resp, "rung", "search"))
            log.append(f"layout {j} wins: makespan {score[0] * 1e6:.1f}us")
            return TenancyPlan(hw=hw, region=region, placements=placements,
                               layout_score=score[0],
                               n_layouts=len(layouts), log=log)
