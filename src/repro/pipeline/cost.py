"""Graph-level cost evaluation: fused two-phase simulation + handoff terms.

The co-planned execution model is *phase-wise*: the producer kernel runs to
completion (its waves writing the forwarded intermediates into the
distributed local memories), then the consumer kernel runs (its waves
reading them back, through the re-shuffle rings where the two mappings'
spatial digits disagree).  End-to-end graph time is therefore the sum of
the nodes' *edge-adjusted* simulations:

* a **spilled** edge leaves both sides untouched — the producer's DRAM
  store and the consumer's DRAM reload are already priced inside their own
  simulations (that sum is exactly the independent-planning baseline, the
  benchmarks' ``dram_roundtrip_us`` column);
* a **forwarded** edge reprices the producer's store and the consumer's
  load on-chip via :class:`~repro.core.reuse.ForwardLeg` overrides
  (``simulate(plan, hw, fwd=...)`` — the scalar and batch engines stay
  bit-identical on these adjusted simulations).

``edge_dram_roundtrip_s`` prices what a spilled edge pays on the DRAM pool
(the store + reload bytes over the aggregate bandwidth) — the reporting
term the benchmark table and the graph plan summary surface.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping as TMapping, Optional

from repro.core import batch_cost
from repro.core.hw import HardwareModel
from repro.core.perfmodel import _resource_pools
from repro.core.plan import DataflowPlan
from repro.core.planner import resolve_engine
from repro.core.reuse import ForwardLeg
from repro.core.simulator import SimResult, simulate

from .graph import PipelineEdge, PipelineGraph


@dataclass(frozen=True)
class GraphSim:
    """One graph-plan evaluation: per-node adjusted simulations + totals."""
    total_s: float
    node_sims: Dict[str, SimResult]
    dram_bytes: float
    noc_bytes: float


def simulate_nodes(graph: PipelineGraph,
                   plans: TMapping[str, DataflowPlan],
                   legs: TMapping[str, TMapping[str, ForwardLeg]],
                   hw: HardwareModel, *,
                   engine: Optional[str] = None) -> GraphSim:
    """Simulate every node with its forwarded-edge legs applied and sum.

    With empty legs this is exactly the sum of the standalone per-kernel
    simulations — the forwarding-disabled property the tests pin.  ``plans``
    may cover a subset of the graph's nodes (the co-planner evaluates nodes
    one at a time as their edges get decided)."""
    order = [n.name for n in graph.nodes if n.name in plans]
    plan_list = [plans[name] for name in order]
    fwd_list = [dict(legs.get(name) or {}) or None for name in order]
    if resolve_engine(engine) == "batch":
        sims = batch_cost.simulate_plans(plan_list, hw, fwd=fwd_list)
    else:
        sims = [simulate(p, hw, fwd=f)
                for p, f in zip(plan_list, fwd_list)]
    node_sims = dict(zip(order, sims))
    return GraphSim(
        total_s=sum(s.total_s for s in sims),
        node_sims=node_sims,
        dram_bytes=sum(s.dram_bytes for s in sims),
        noc_bytes=sum(s.noc_bytes for s in sims))


def edge_dram_roundtrip_s(graph: PipelineGraph, edge: PipelineEdge,
                          producer: DataflowPlan, consumer: DataflowPlan,
                          hw: HardwareModel) -> float:
    """The DRAM time a spilled edge pays for the intermediate's round trip:
    (store bytes + reload bytes) over the aggregate DRAM pool.  A reporting
    term (the simulator prices the real thing with per-channel contention);
    also a convenient upper-level summary of what forwarding removes."""
    pools = _resource_pools(hw)
    store = graph.edge_store(edge, producer.program)
    load = graph.edge_load(edge, consumer.program)
    store_bytes = 0.0
    for s in producer.stores:
        if s.access.tensor.name != edge.tensor:
            continue
        mult = 2.0 if (s.reduce_axes and s.reduce_style == "accum") else 1.0
        store_bytes += (mult * store.tile_bytes * s.issues_per_core
                        * producer.mapping.active_cores())
    load_bytes = 0.0
    for c in consumer.loads:
        if c.access.tensor.name != edge.tensor:
            continue
        load_bytes += (load.tile_bytes * c.hoist.tiles_per_issue
                       * c.hoist.issues_per_core
                       * consumer.mapping.active_cores())
    return (store_bytes + load_bytes) / pools["dram"]
