"""Kernel-graph co-planner: compose per-node plans + per-edge decisions.

Search structure (DESIGN_PIPELINE.md):

1. **per-node candidate pools** — each node runs the existing single-kernel
   two-step selection (``plan_kernel_multi``: block-shape pooling,
   branch-and-bound ranking, wave-class profiling) and keeps its top-k
   candidates *with* their standalone simulations.  Node searches shard
   across worker processes (one job per node,
   ``repro.parallel.search_exec.plan_node_pools``) when the budget allows;
2. **edge analysis** — every (producer candidate, consumer candidate) pair
   of every edge gets a forwarding spec (legality + re-shuffle axes +
   resident bytes) from ``repro.pipeline.forwarding``;
3. **graph branch-and-bound** — nodes are assigned candidates in
   topological order; a node's incoming edges are decided
   (forward vs spill) as soon as both endpoints are fixed, and a node's
   *edge-adjusted* simulation is finalized once all its edges are decided.
   The admissible bound for every unfinalized node is its **free-leg
   floor**: the node simulated with all edge accesses at zero cost — a
   float-monotone lower bound on any realizable edge handling — so pruning
   is exact (``use_bound=False`` is the exhaustive oracle the tests compare
   against).  Ties resolve to the earliest assignment in canonical
   enumeration order (candidates by standalone rank, forwarding before
   spilling), so results are deterministic.

Joint capacity: when a node is finalized, its working buffers plus the
resident bytes of *all* its live forwarded intermediates (incoming and
outgoing) must fit the local memory — branches that violate it are
infeasible, not merely expensive.

``SearchBudget.pipeline_forwarding=False`` restricts every edge to the
spill decision; the co-planner then provably reproduces the independent
per-kernel plans and the graph time equals the sum of the standalone
simulations (the DRAM-handoff baseline the benchmarks report).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.hw import HardwareModel
from repro.core.planner import (Candidate, SearchBudget, effective_budget,
                                plan_kernel, resolve_engine)
from repro.core.simulator import SimResult
from repro.obs import metrics, trace

from . import cost as gcost
from .forwarding import ForwardSpec, forward_spec, free_legs, node_legs
from .graph import PipelineGraph

EdgeKey = Tuple[str, str, str]          # (src, dst, tensor)


@dataclass(frozen=True)
class EdgeDecision:
    """The planned handling of one edge: forwarded on-chip (with its
    re-shuffle axes and per-core resident bytes) or spilled to DRAM."""
    src: str
    dst: str
    tensor: str
    forwarded: bool
    shuffle_axes: Tuple[str, ...] = ()
    resident_bytes: int = 0

    @property
    def key(self) -> EdgeKey:
        return (self.src, self.dst, self.tensor)

    def describe(self) -> str:
        if not self.forwarded:
            return f"{self.src}-({self.tensor})->{self.dst}: spill"
        tag = "aligned" if not self.shuffle_axes else \
            "shuffle:" + "+".join(self.shuffle_axes)
        return f"{self.src}-({self.tensor})->{self.dst}: forward[{tag}]"


@dataclass
class GraphPlan:
    """The co-planner's output: one candidate per node, one decision per
    edge, and the fused two-phase evaluation."""
    graph_name: str
    hw_name: str
    nodes: Dict[str, Candidate]          # chosen candidate per node
    decisions: Tuple[EdgeDecision, ...]
    node_sims: Dict[str, SimResult]      # edge-adjusted simulations
    total_s: float                       # end-to-end co-planned time
    baseline_s: float                    # independent plans + DRAM handoff
    dram_roundtrip_s: float              # what the spill baseline pays per edge
    plan_seconds: float = 0.0
    n_graph_combos: int = 0              # assignments streamed
    n_graph_pruned: int = 0              # assignments cut by the floor bound
    n_forwardable_pairs: int = 0         # candidate pairs with a legal spec
    n_pairs: int = 0                     # candidate pairs examined
    log: List[str] = field(default_factory=list)

    @property
    def improvement(self) -> float:
        return self.baseline_s / self.total_s if self.total_s > 0 else 0.0

    def n_forwarded(self) -> int:
        return sum(1 for d in self.decisions if d.forwarded)

    def describe(self) -> str:
        parts = []
        for name, cand in self.nodes.items():
            parts.append(f"{name}={cand.plan.describe()}")
        for d in self.decisions:
            parts.append(d.describe())
        return " | ".join(parts)

    def summary(self) -> str:
        lines = [
            f"graph={self.graph_name} hw={self.hw_name} "
            f"combos={self.n_graph_combos} "
            f"(pruned={self.n_graph_pruned}) plan_time="
            f"{self.plan_seconds:.2f}s",
            f"  co-planned: {self.total_s * 1e6:.1f}us   "
            f"independent+DRAM handoff: {self.baseline_s * 1e6:.1f}us   "
            f"({self.improvement:.2f}x, {self.n_forwarded()}/"
            f"{len(self.decisions)} edges forwarded)",
        ]
        for d in self.decisions:
            lines.append(f"  edge {d.describe()}")
        return "\n".join(lines)


def node_candidate_pool(programs: Sequence, hw: HardwareModel,
                        budget: SearchBudget, *,
                        engine: Optional[str] = None,
                        cache: Optional[Any] = None) -> List[Candidate]:
    """One node's candidate pool: the single-kernel two-step selection run
    *per block-shape candidate* and merged.

    Running the B&B top-k per program — rather than pooling all programs
    into one ranking as ``plan_kernel_multi`` does — keeps every block
    shape's best plan in the pool.  That diversity is what the graph
    composition needs: the forwarding legality rule matches producer store
    tiles against consumer load tiles, so a pool collapsed onto one block
    shape can starve every edge of compatible pairs.  The pool is sorted by
    (profiled time, program index, canonical index): position 0 is the
    node's standalone winner, and the order is deterministic.
    """
    pool: List[Tuple[float, int, tuple, Candidate]] = []
    for p_i, prog in enumerate(programs):
        try:
            res = plan_kernel(prog, hw, budget=budget, profile=True,
                              cache=cache, engine=engine)
        except RuntimeError:
            continue                     # infeasible block shape
        for c in res.topk:
            pool.append((c.final_s, p_i, c.index or (0, 0, 0), c))
    if not pool:
        raise RuntimeError(f"no feasible plan for any block shape of "
                           f"{programs[0].name if programs else '?'} "
                           f"on {hw.name}")
    pool.sort(key=lambda e: e[:3])
    return [c for _, _, _, c in pool]


def _node_pools(graph: PipelineGraph, hw: HardwareModel,
                budget: SearchBudget, engine: Optional[str],
                cache) -> List[List[Candidate]]:
    """Per-node candidate pools (with standalone sims), sharded
    one-job-per-node across the planner worker pool when available."""
    from repro.parallel import search_exec
    program_lists = [list(n.programs) for n in graph.nodes]
    workers = search_exec.resolve_workers(budget.workers)
    if workers > 1 and len(program_lists) > 1:
        results = search_exec.plan_node_pools(
            program_lists, hw, budget, engine=engine, workers=workers)
        if results is not None:
            return results
    return [node_candidate_pool(progs, hw, budget, engine=engine,
                                cache=cache)
            for progs in program_lists]


def plan_pipeline(graph: PipelineGraph, hw: HardwareModel, *,
                  budget: Optional[SearchBudget] = None,
                  cache: Optional[Any] = None,
                  engine: Optional[str] = None,
                  use_bound: bool = True) -> GraphPlan:
    """Co-plan a kernel graph end to end (see module docstring).

    ``cache`` is a :class:`repro.plancache.PlanCache`: graph-level hits
    return the persisted :class:`GraphPlan` without searching (schema-v3
    graph keys composed from the node program signatures + edge list);
    node-level entries additionally serve the per-node pools on a graph
    miss.  ``use_bound=False`` disables the graph branch-and-bound (the
    exhaustive oracle; selections are identical either way).
    """
    trace.refresh_from_env()
    graph.validate()
    budget = effective_budget(budget)
    engine = resolve_engine(engine)
    if cache is not None:
        hit = cache.get_graph_result(graph, hw, budget)
        if hit is not None:
            return hit
    t0 = time.perf_counter()
    names = [n.name for n in graph.nodes]
    with trace.span("pipeline.node_pools", cat="pipeline",
                    graph=graph.name, n_nodes=len(names)):
        pools: Dict[str, List[Candidate]] = dict(zip(
            names, _node_pools(graph, hw, budget, engine, cache)))

    # ---- per-(edge, candidate pair) forwarding specs -----------------------
    specs: Dict[Tuple[EdgeKey, int, int], Optional[ForwardSpec]] = {}
    n_pairs = n_fwd = 0
    if budget.pipeline_forwarding:
        with trace.span("pipeline.forward_specs", cat="pipeline",
                        graph=graph.name, n_edges=len(graph.edges)):
            for e in graph.edges:
                ek = (e.src, e.dst, e.tensor)
                for pi, pc in enumerate(pools[e.src]):
                    for ci, cc in enumerate(pools[e.dst]):
                        sp = forward_spec(graph, e, pc.plan, cc.plan, hw)
                        specs[(ek, pi, ci)] = sp
                        n_pairs += 1
                        n_fwd += sp is not None

    # ---- memoized edge-adjusted node simulation ----------------------------
    sim_memo: Dict[tuple, SimResult] = {}

    def node_sim(name: str, cand_idx: int,
                 legs: Dict[str, Any]) -> SimResult:
        sig = (name, cand_idx,
               tuple(sorted((t, l.kind, l.shuffle_axes)
                            for t, l in legs.items())))
        got = sim_memo.get(sig)
        if got is None:
            cand = pools[name][cand_idx]
            if not legs and cand.sim is not None:
                got = cand.sim              # standalone profile, already paid
            else:
                got = gcost.simulate_nodes(
                    graph, {name: cand.plan}, {name: legs}, hw,
                    engine=engine).node_sims[name]
            sim_memo[sig] = got
        return got

    # admissible per-node floor: all edge accesses free (monotone <= any
    # realizable edge handling), minimized over the candidate pool
    floors: Dict[str, float] = {}
    if use_bound:
        for name in names:
            fl = free_legs(graph, name)
            floors[name] = min(
                node_sim(name, i, dict(fl)).total_s
                for i in range(len(pools[name])))

    # ---- graph branch-and-bound --------------------------------------------
    cap = hw.local_capacity()
    # a node is finalizable once every adjacent edge is decided, i.e. after
    # the last adjacent node (by topo index) has been assigned
    final_at: Dict[int, List[str]] = {}
    for i, name in enumerate(names):
        fpoint = i
        for e in graph.out_edges(name):
            fpoint = max(fpoint, graph.node_index(e.dst))
        final_at.setdefault(fpoint, []).append(name)

    best: Dict[str, Any] = {"total": float("inf"), "assign": None,
                            "decisions": None}
    stats = {"combos": 0, "pruned": 0}

    def remaining_floor(finalized: set) -> float:
        if not use_bound:
            return 0.0
        return sum(floors[n] for n in names if n not in finalized)

    def edge_options(ek: EdgeKey, pi: int, ci: int) -> List[bool]:
        """Decision order: forward first (canonical), spill always legal."""
        opts: List[bool] = []
        if budget.pipeline_forwarding and specs.get((ek, pi, ci)) is not None:
            opts.append(True)
        opts.append(False)
        return opts

    def finalize(i: int, assign: Dict[str, int],
                 decided: Dict[EdgeKey, bool], partial: float,
                 finalized: set) -> Optional[float]:
        """Finalize nodes whose edges are all decided at step ``i``:
        joint-capacity check + adjusted sim.  None = infeasible branch."""
        for name in final_at.get(i, ()):
            spec_map = {}
            fwd_map = {}
            resident = 0
            for e in graph.in_edges(name) + graph.out_edges(name):
                ek = (e.src, e.dst, e.tensor)
                sp = specs.get((ek, assign[e.src], assign[e.dst]))
                spec_map[ek] = sp
                fwd_map[ek] = decided.get(ek, False)
                if fwd_map[ek] and sp is not None:
                    resident += sp.resident_bytes
            cand = pools[name][assign[name]]
            if resident and cand.plan.buffer_bytes() + resident > cap:
                return None             # joint live intermediates overflow L1
            legs = node_legs(graph, name, spec_map, fwd_map)
            partial += node_sim(name, assign[name], legs).total_s
            finalized.add(name)
        return partial

    def rec(i: int, assign: Dict[str, int], decided: Dict[EdgeKey, bool],
            partial: float, finalized: set) -> None:
        if i == len(names):
            stats["combos"] += 1
            if partial < best["total"]:
                best["total"] = partial
                best["assign"] = dict(assign)
                best["decisions"] = dict(decided)
            return
        name = names[i]
        in_edges = graph.in_edges(name)
        for cand_idx in range(len(pools[name])):
            assign[name] = cand_idx

            def decide(j: int, decided_now: Dict[EdgeKey, bool]) -> None:
                if j == len(in_edges):
                    fin = set(finalized)
                    got = finalize(i, assign, decided_now, partial, fin)
                    if got is None:
                        return
                    if use_bound and got + remaining_floor(fin) \
                            >= best["total"]:
                        stats["pruned"] += 1
                        return
                    rec(i + 1, assign, decided_now, got, fin)
                    return
                e = in_edges[j]
                ek = (e.src, e.dst, e.tensor)
                for f in edge_options(ek, assign[e.src], assign[e.dst]):
                    decided_now[ek] = f
                    decide(j + 1, decided_now)
                del decided_now[ek]

            decide(0, decided)
        del assign[name]

    with trace.span("pipeline.graph_bnb", cat="pipeline", graph=graph.name,
                    n_nodes=len(names), use_bound=use_bound):
        rec(0, {}, {}, 0.0, set())
    if best["assign"] is None:
        raise RuntimeError(f"no feasible graph plan for {graph.name} on "
                           f"{hw.name}")

    # ---- materialize the winner --------------------------------------------
    assign = best["assign"]
    decided = best["decisions"]
    chosen = {name: pools[name][assign[name]] for name in names}
    decisions = []
    for e in graph.edges:
        ek = (e.src, e.dst, e.tensor)
        sp = specs.get((ek, assign[e.src], assign[e.dst]))
        fwd = bool(decided.get(ek, False)) and sp is not None
        decisions.append(EdgeDecision(
            e.src, e.dst, e.tensor, forwarded=fwd,
            shuffle_axes=sp.shuffle_axes if fwd else (),
            resident_bytes=sp.resident_bytes if fwd else 0))
    node_sims = {}
    for name in names:
        spec_map = {d.key: specs.get((d.key, assign[d.src], assign[d.dst]))
                    for d in decisions}
        fwd_map = {d.key: d.forwarded for d in decisions}
        legs = node_legs(graph, name, spec_map, fwd_map)
        node_sims[name] = node_sim(name, assign[name], legs)
    total = best["total"]
    baseline = sum(pools[name][0].sim.total_s for name in names)
    roundtrip = sum(
        gcost.edge_dram_roundtrip_s(graph, e, pools[e.src][0].plan,
                                    pools[e.dst][0].plan, hw)
        for e in graph.edges)
    plan_seconds = time.perf_counter() - t0
    metrics.inc("pipeline_plans_total", graph=graph.name)
    metrics.inc("pipeline_graph_combos_total", stats["combos"])
    metrics.inc("pipeline_graph_pruned_total", stats["pruned"])
    metrics.inc("pipeline_forwardable_pairs_total", n_fwd)
    metrics.inc("pipeline_candidate_pairs_total", n_pairs)
    metrics.observe("pipeline_plan_seconds", plan_seconds, graph=graph.name)
    plan = GraphPlan(
        graph_name=graph.name, hw_name=hw.name, nodes=chosen,
        decisions=tuple(decisions), node_sims=node_sims, total_s=total,
        baseline_s=baseline, dram_roundtrip_s=roundtrip,
        plan_seconds=plan_seconds,
        n_graph_combos=stats["combos"], n_graph_pruned=stats["pruned"],
        n_forwardable_pairs=n_fwd, n_pairs=n_pairs)
    if cache is not None:
        cache.put_graph_result(graph, hw, budget, plan)
    return plan
