"""Inter-kernel reuse analysis: when can an edge be forwarded on-chip?

For a fixed (producer plan, consumer plan) pair and one graph edge, this
module decides whether the intermediate can stay in the distributed local
memories between the two kernel phases — and at what cost — instead of
round-tripping through DRAM:

* **tiling legality** — both sides must address the same logical tile grid:
  the producer's store tile shape must equal the consumer's load tile shape
  (the graph-level correspondence is the identity on the tensor dims);
* **placement compatibility** — rewriting both accesses through their
  mappings gives each tile-grid coordinate as an affine function of
  hardware spatial digits (+ wave/sequential indices).  Where the two
  rewritten maps agree on every spatial-digit coefficient, each tile is
  consumed by the core that produced it (zero-cost handoff through that
  core's L1); every hardware axis whose digit coefficients *disagree*
  contributes a **re-shuffle leg**: the tile crosses that axis' NoC ring
  once on its way to the consuming core;
* **reduction exclusion** — a store still carrying a spatial-reduction
  combine (``reduce_axes``) spills: the partial-sum epilogue already owns
  the store path and pinning it to L1 would change the combine semantics;
* **broadcast exclusion** — a consumer load realized as a NoC multicast
  (``bcast_axes``) spills: the multicast source is the DRAM-fetched copy,
  so serving it from distributed L1 would need a different (gather+
  multicast) dataflow that the cost layers do not model;
* **capacity** — the resident intermediate (each producer core keeps the
  tiles it produced until the consumer phase) must fit next to the working
  buffers of *both* phases.  The joint check across all live edges of a
  node happens in the co-planner; this module computes the per-edge
  resident bytes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.core.hw import HardwareModel
from repro.core.plan import DataflowPlan
from repro.core.reuse import ForwardLeg, forward_resident_bytes

from .graph import PipelineEdge, PipelineGraph


@dataclass(frozen=True)
class ForwardSpec:
    """The priced realization of one forwarded edge for one candidate pair:
    the mismatch axes the re-shuffle leg crosses and the per-core bytes the
    resident intermediate occupies on each side."""
    edge: PipelineEdge
    shuffle_axes: Tuple[str, ...]
    resident_bytes: int                 # per producer core, while live
    aligned: bool                       # True = zero-shuffle handoff

    def send_leg(self) -> ForwardLeg:
        return ForwardLeg(self.edge.tensor, "send")

    def recv_leg(self) -> ForwardLeg:
        return ForwardLeg(self.edge.tensor, "recv", self.shuffle_axes)


def _digit_mismatch_axes(store_map, load_map, hw: HardwareModel
                         ) -> Tuple[str, ...]:
    """Hardware axes whose spatial-digit coefficients differ between the
    producer's rewritten store map and the consumer's rewritten load map on
    any tile-grid coordinate — the axes the re-shuffle leg must cross."""
    mism = []
    for a, _ in hw.mesh_dims:
        for pe, ce in zip(store_map.exprs, load_map.exprs):
            if pe.coeff_of(a) != ce.coeff_of(a):
                mism.append(a)
                break
    return tuple(mism)


def forward_spec(graph: PipelineGraph, edge: PipelineEdge,
                 producer: DataflowPlan, consumer: DataflowPlan,
                 hw: HardwareModel) -> Optional[ForwardSpec]:
    """The forwarding realization of ``edge`` for one candidate plan pair,
    or ``None`` when the pair is not forwardable (see module docstring for
    the legality rules).  Capacity against each side's working buffers is
    checked here; the *joint* capacity across several simultaneously-live
    edges is the co-planner's job."""
    store = graph.edge_store(edge, producer.program)
    load = graph.edge_load(edge, consumer.program)
    if store.tile_shape != load.tile_shape:
        return None                     # different tile grids: re-tiling
    for s in producer.stores:
        if s.access.tensor.name == edge.tensor and s.reduce_axes:
            return None                 # partial-sum combine owns the store
    for c in consumer.loads:
        if c.access.tensor.name == edge.tensor and c.bcast_axes:
            return None                 # multicast loads source from DRAM
    p_map = producer.mapping.rewrite_access(store)
    c_map = consumer.mapping.rewrite_access(load)
    shuffle = _digit_mismatch_axes(p_map, c_map, hw)
    resident = forward_resident_bytes(store, producer.mapping)
    cap = hw.local_capacity()
    if producer.buffer_bytes() + resident > cap:
        return None
    if consumer.buffer_bytes() + resident > cap:
        return None
    return ForwardSpec(edge=edge, shuffle_axes=shuffle,
                       resident_bytes=resident, aligned=not shuffle)


def node_legs(graph: PipelineGraph, node: str,
              specs: Dict[Tuple[str, str, str], Optional[ForwardSpec]],
              forwarded: Dict[Tuple[str, str, str], bool]
              ) -> Dict[str, ForwardLeg]:
    """The ``fwd`` leg map one node's simulation needs, given the per-edge
    forwarding decisions (keys are ``(src, dst, tensor)`` triples)."""
    legs: Dict[str, ForwardLeg] = {}
    for e in graph.out_edges(node):
        key = (e.src, e.dst, e.tensor)
        spec = specs.get(key)
        if spec is not None and forwarded.get(key):
            legs[e.tensor] = spec.send_leg()
    for e in graph.in_edges(node):
        key = (e.src, e.dst, e.tensor)
        spec = specs.get(key)
        if spec is not None and forwarded.get(key):
            legs[e.tensor] = spec.recv_leg()
    return legs


def free_legs(graph: PipelineGraph, node: str) -> Dict[str, ForwardLeg]:
    """Zero-cost legs for every edge tensor of ``node`` — the admissible
    floor the graph branch-and-bound simulates against (any realizable
    edge handling prices these accesses at >= 0 on every resource)."""
    legs: Dict[str, ForwardLeg] = {}
    for e in graph.out_edges(node) + graph.in_edges(node):
        legs[e.tensor] = ForwardLeg(e.tensor, "free")
    return legs
