# Kernel-graph pipeline planning: co-plan chained tile programs with
# on-chip tile forwarding between kernels (DESIGN_PIPELINE.md).
#
# graph.py       — PipelineGraph IR (nodes = TileProgram candidate pools,
#                  edges = named intermediate tensors) + benchmark builders
# forwarding.py  — inter-kernel reuse analysis: forwarding legality,
#                  spatial-digit compatibility, re-shuffle axes, residency
# cost.py        — fused two-phase graph simulation + DRAM handoff terms
# planner.py     — per-node pools + exact graph branch-and-bound composition
from .graph import (PipelineEdge, PipelineGraph, PipelineNode,
                    attn_qk_pv_graph, graph_from_spec, mlp2_graph,
                    moe_ffn_graph)
from .forwarding import ForwardSpec, forward_spec, free_legs, node_legs
from .cost import GraphSim, edge_dram_roundtrip_s, simulate_nodes
from .planner import EdgeDecision, GraphPlan, plan_pipeline

__all__ = [
    "PipelineEdge", "PipelineGraph", "PipelineNode",
    "attn_qk_pv_graph", "graph_from_spec", "mlp2_graph", "moe_ffn_graph",
    "ForwardSpec", "forward_spec", "free_legs", "node_legs",
    "GraphSim", "edge_dram_roundtrip_s", "simulate_nodes",
    "EdgeDecision", "GraphPlan", "plan_pipeline",
]
