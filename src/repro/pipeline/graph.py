"""Kernel-graph IR for pipeline co-planning (DESIGN_PIPELINE.md).

A :class:`PipelineGraph` lifts the unit of planning from one
:class:`~repro.core.program.TileProgram` to a DAG of them: nodes carry the
per-kernel block-shape candidate lists the front-end would hand
``plan_kernel_multi``, and edges name the intermediate tensors flowing
producer -> consumer.  The tile-grid correspondence between the producer's
store and the consumer's load of an edge tensor is carried by the tensor
dimensions themselves: both sides address the *same* logical tile grid, so
an edge is forwardable exactly when the two accesses tile the tensor
identically (equal tile shapes — validated per candidate pair by
``repro.pipeline.forwarding``) and the live intermediate fits the joint
on-chip capacity.

Graph builders for the benchmark chains (2-GEMM MLP, unfused qk -> pv
attention, MoE expert FFN) live here so the AOT warm CLI and the benchmark
suite share one spec.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple

from repro.core.program import (TileAccess, TileProgram, matmul_program,
                                moe_gmm_program, qk_matmul_program,
                                softmax_pv_program)


@dataclass(frozen=True)
class PipelineNode:
    """One kernel of the graph: a name plus the block-shape candidate
    programs the per-node search pools (every candidate must expose the
    node's edge tensors — same names, same logical shapes)."""
    name: str
    programs: Tuple[TileProgram, ...]

    def candidates(self) -> Tuple[TileProgram, ...]:
        return self.programs


@dataclass(frozen=True)
class PipelineEdge:
    """An intermediate tensor flowing ``src`` -> ``dst``.

    ``tensor`` names a store of every ``src`` candidate and a load of every
    ``dst`` candidate; the tile-grid correspondence between the two sides is
    the identity on the tensor's dimensions (both accesses index the same
    logical tile grid of the same :class:`TensorSpec` shape)."""
    src: str
    dst: str
    tensor: str


@dataclass(frozen=True)
class PipelineGraph:
    """A DAG of tile programs with named intermediate tensors.

    ``nodes`` must be listed in a topological order (every edge points from
    an earlier node to a strictly later one) — that order is also the
    execution order of the co-planned two-phase schedule."""
    name: str
    nodes: Tuple[PipelineNode, ...]
    edges: Tuple[PipelineEdge, ...]

    # ------------------------------------------------------------ queries
    def node(self, name: str) -> PipelineNode:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def node_index(self, name: str) -> int:
        for i, n in enumerate(self.nodes):
            if n.name == name:
                return i
        raise KeyError(name)

    def in_edges(self, name: str) -> Tuple[PipelineEdge, ...]:
        return tuple(e for e in self.edges if e.dst == name)

    def out_edges(self, name: str) -> Tuple[PipelineEdge, ...]:
        return tuple(e for e in self.edges if e.src == name)

    def edge_store(self, edge: PipelineEdge,
                   program: TileProgram) -> TileAccess:
        """The producer-side store access of ``edge`` in one candidate."""
        for a in program.stores:
            if a.tensor.name == edge.tensor:
                return a
        raise KeyError(f"{program.name} does not store {edge.tensor!r}")

    def edge_load(self, edge: PipelineEdge, program: TileProgram) -> TileAccess:
        """The consumer-side load access of ``edge`` in one candidate."""
        for a in program.loads:
            if a.tensor.name == edge.tensor:
                return a
        raise KeyError(f"{program.name} does not load {edge.tensor!r}")

    # --------------------------------------------------------- validation
    def validate(self) -> None:
        """Front-end contract: unique node names, topological node order,
        every edge tensor stored by all src candidates and loaded by all
        dst candidates with one consistent logical shape/dtype."""
        names = [n.name for n in self.nodes]
        if len(set(names)) != len(names):
            raise ValueError(f"{self.name}: duplicate node names in {names}")
        if not self.nodes:
            raise ValueError(f"{self.name}: graph has no nodes")
        order = {n: i for i, n in enumerate(names)}
        for n in self.nodes:
            if not n.programs:
                raise ValueError(f"{self.name}/{n.name}: no candidate "
                                 f"programs")
            for p in n.programs:
                p.validate()
        seen_src = set()
        seen_dst = set()
        for e in self.edges:
            if e.src not in order or e.dst not in order:
                raise ValueError(f"{self.name}: edge {e.src}->{e.dst} names "
                                 f"an unknown node")
            # forwarding legs are keyed by tensor name within one node's
            # simulation, so one producer fanning a tensor out to several
            # consumers (or one consumer reading it from several producers)
            # would make the per-edge forward/spill decisions ambiguous —
            # rejected here rather than mispriced later
            if (e.src, e.tensor) in seen_src:
                raise ValueError(
                    f"{self.name}: tensor {e.tensor!r} leaves node {e.src} "
                    f"on multiple edges (fan-out of one intermediate is "
                    f"not supported; materialize it instead)")
            if (e.dst, e.tensor) in seen_dst:
                raise ValueError(
                    f"{self.name}: tensor {e.tensor!r} enters node {e.dst} "
                    f"on multiple edges")
            seen_src.add((e.src, e.tensor))
            seen_dst.add((e.dst, e.tensor))
            if order[e.src] >= order[e.dst]:
                raise ValueError(
                    f"{self.name}: edge {e.src}->{e.dst} violates the "
                    f"topological node order (src must precede dst)")
            spec = None
            for p in self.node(e.src).programs:
                st = self.edge_store(e, p)
                spec = spec or (st.tensor.shape, st.tensor.dtype_bytes)
                if (st.tensor.shape, st.tensor.dtype_bytes) != spec:
                    raise ValueError(
                        f"{self.name}: {e.tensor!r} shape/dtype differs "
                        f"across {e.src} candidates")
            for p in self.node(e.dst).programs:
                ld = self.edge_load(e, p)
                if (ld.tensor.shape, ld.tensor.dtype_bytes) != spec:
                    raise ValueError(
                        f"{self.name}: {e.tensor!r} disagrees between "
                        f"{e.src} stores and {e.dst} loads "
                        f"({spec} vs {(ld.tensor.shape, ld.tensor.dtype_bytes)})")

    def describe(self) -> str:
        parts = [f"{n.name}[{len(n.programs)} cands]" for n in self.nodes]
        for e in self.edges:
            parts.append(f"{e.src}-({e.tensor})->{e.dst}")
        return f"{self.name}: " + " ".join(parts)


# --------------------------------------------------------------------------
# Graph builders (the benchmark / AOT-warm chains)
# --------------------------------------------------------------------------
def graph_from_spec(spec: str) -> PipelineGraph:
    """Build a benchmark graph from a compact CLI spec string (the AOT warm
    CLI's ``--pipeline`` argument):

    * ``mlp2:MxDxF``        — :func:`mlp2_graph`
    * ``attn:HxSqxSkvxD``   — :func:`attn_qk_pv_graph`
    * ``moe:ExCxDmxDf``     — :func:`moe_ffn_graph`
    """
    try:
        kind, dims_text = spec.split(":", 1)
        dims = tuple(int(p) for p in dims_text.lower().split("x"))
    except ValueError:
        raise ValueError(f"malformed pipeline spec {spec!r} "
                         f"(expected kind:AxBx...)") from None
    builders = {"mlp2": (mlp2_graph, 3), "attn": (attn_qk_pv_graph, 4),
                "moe": (moe_ffn_graph, 4)}
    if kind not in builders:
        raise ValueError(f"unknown pipeline graph kind {kind!r}; "
                         f"valid kinds: {sorted(builders)}")
    fn, arity = builders[kind]
    if len(dims) != arity:
        raise ValueError(f"pipeline spec {spec!r} needs {arity} "
                         f"'x'-separated ints, got {len(dims)}")
    return fn(*dims)


def mlp2_graph(M: int, d_model: int, d_ff: int, *,
               blocks: Sequence[Tuple[int, int, int]] = ((32, 32, 32),
                                                         (64, 64, 32),
                                                         (64, 64, 64),
                                                         (128, 64, 64)),
               dtype_bytes: int = 2) -> PipelineGraph:
    """Two chained GEMMs (the transformer MLP): ``Y = X @ W1`` then
    ``Z = Y @ W2``, with the activation ``Y[M, d_ff]`` as the forwardable
    intermediate."""
    up = tuple(matmul_program(M, d_ff, d_model, bm=bm, bn=bn, bk=bk,
                              dtype_bytes=dtype_bytes, name="mlp_up",
                              tensor_names=("X", "W1", "Y"))
               for bm, bn, bk in blocks)
    down = tuple(matmul_program(M, d_model, d_ff, bm=bm, bn=bn, bk=bk,
                                dtype_bytes=dtype_bytes, name="mlp_down",
                                tensor_names=("Y", "W2", "Z"))
                 for bm, bn, bk in blocks)
    g = PipelineGraph(
        name=f"mlp2_M{M}_d{d_model}_f{d_ff}",
        nodes=(PipelineNode("up", up), PipelineNode("down", down)),
        edges=(PipelineEdge("up", "down", "Y"),))
    g.validate()
    return g


def attn_qk_pv_graph(batch_heads: int, seq_q: int, seq_kv: int,
                     head_dim: int, *,
                     blocks: Sequence[Tuple[int, int]] = ((32, 32), (64, 64),
                                                          (64, 128)),
                     dtype_bytes: int = 2) -> PipelineGraph:
    """The unfused attention chain ``S = Q K^T`` -> ``O = softmax(S) V``
    with the score matrix ``S[h, q, kv]`` as the forwardable intermediate —
    the canonical case where the DRAM round trip dwarfs the operand traffic
    (S is quadratic in sequence length)."""
    qk = tuple(qk_matmul_program(batch_heads, seq_q, seq_kv, head_dim,
                                 bq=bq, bkv=bkv, dtype_bytes=dtype_bytes)
               for bq, bkv in blocks)
    pv = tuple(softmax_pv_program(batch_heads, seq_q, seq_kv, head_dim,
                                  bq=bq, bkv=bkv, dtype_bytes=dtype_bytes)
               for bq, bkv in blocks)
    g = PipelineGraph(
        name=f"attn_h{batch_heads}_q{seq_q}_kv{seq_kv}_d{head_dim}",
        nodes=(PipelineNode("qk", qk), PipelineNode("pv", pv)),
        edges=(PipelineEdge("qk", "pv", "S"),))
    g.validate()
    return g


def moe_ffn_graph(n_experts: int, capacity: int, d_model: int, d_ff: int, *,
                  blocks: Sequence[Tuple[int, int, int]] = ((32, 32, 32),
                                                            (64, 64, 32),
                                                            (64, 64, 64)),
                  dtype_bytes: int = 2) -> PipelineGraph:
    """The gate-routed MoE expert FFN chain: after the (host-side) gate has
    scattered tokens to experts, ``H = X @ W_up`` then ``O = H @ W_down``
    per expert, with the hidden activation ``H[e, cap, d_ff]`` forwardable
    between the two grouped contractions."""
    up = tuple(moe_gmm_program(n_experts, capacity, d_model, d_ff,
                               bm=bm, bn=bn, bk=bk, dtype_bytes=dtype_bytes,
                               name="moe_up",
                               tensor_names=("X", "W_up", "H"))
               for bm, bn, bk in blocks)
    down = tuple(moe_gmm_program(n_experts, capacity, d_ff, d_model,
                                 bm=bm, bn=bn, bk=bk,
                                 dtype_bytes=dtype_bytes, name="moe_down",
                                 tensor_names=("H", "W_down", "O"))
                 for bm, bn, bk in blocks)
    g = PipelineGraph(
        name=f"moe_ffn_e{n_experts}_c{capacity}_{d_model}x{d_ff}",
        nodes=(PipelineNode("up", up), PipelineNode("down", down)),
        edges=(PipelineEdge("up", "down", "H"),))
    g.validate()
    return g
