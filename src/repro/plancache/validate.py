"""Plan sanitizer: structural checks run before any cached plan is served.

A plan-cache entry is attacker-free but not failure-free: a torn write, a
bit-flipped disk block, or an entry produced by a buggy build can
deserialize into a :class:`~repro.core.plan.DataflowPlan` that is
syntactically valid JSON yet semantically unrunnable — binds to mesh dims
the hardware doesn't have, tile footprints that overflow L1, mappings that
land waves on disabled cores.  :func:`validate_plan` is the gate the cache
(and the plan service's shape-family rung) runs before serving any plan it
did not just compute; a non-empty violation list quarantines the entry
(``PlanCacheStore.quarantine``) instead of handing the runtime a plan that
will fail at lowering or, worse, on hardware.

The checks are deliberately permissive about *provenance*: a plan computed
for a logical submesh of ``hw`` (the degraded-mesh ladder's rung-4 results
are cached under the full degraded fabric's key) binds fewer/smaller dims
than the mesh has, which is fine — only binds that *exceed* the hardware,
or fault conflicts on the exact model the plan was computed for, are
violations.
"""
from __future__ import annotations

from typing import List

from repro.core.hw import HardwareModel
from repro.core.plan import DataflowPlan


def dram_residency_bytes(plan: DataflowPlan) -> int:
    """The plan's resident DRAM footprint: total bytes of the distinct
    global tensors it loads or stores.  Per-plan this is bounded by
    ``hw.global_mem`` trivially on today's workloads; the multi-tenant
    isolation validator sums it across co-located tenants, whose tensors
    share one physical DRAM — the joint fit is the constraint a
    single-tenant sanitizer can never see."""
    seen = {}
    for acc in plan.program.loads + plan.program.stores:
        seen[acc.tensor.name] = acc.tensor.bytes
    return sum(seen.values())


def validate_plan(plan: DataflowPlan, hw: HardwareModel) -> List[str]:
    """Return the list of structural violations (empty = plan is servable).

    Never raises: an exception inside a check is itself reported as a
    violation, so a malformed plan can't crash the serving path it was
    supposed to protect.
    """
    try:
        return _validate(plan, hw)
    except Exception as e:  # noqa: BLE001 — the gate must not throw
        return [f"validator error: {e!r}"]


def _validate(plan: DataflowPlan, hw: HardwareModel) -> List[str]:
    bad: List[str] = []
    mapping = plan.mapping
    prog = plan.program

    # -- program contract (undeclared access dims, nonpositive extents) ----
    try:
        prog.validate()
    except ValueError as e:
        bad.append(f"program: {e}")
        return bad                     # everything below reads the dims

    dims = {d.name for d in prog.grid_dims} | {d.name for d in prog.seq_dims}
    mesh = dict(hw.mesh_dims)

    # -- spatial binds: inside the hardware mesh, over declared dims -------
    seen_hw = set()
    for b in mapping.spatial:
        if b.hw_dim not in mesh:
            bad.append(f"bind {b.grid_dim}->{b.hw_dim}: unknown hw dim")
            continue
        if b.hw_size < 1 or b.hw_size > mesh[b.hw_dim]:
            bad.append(f"bind {b.grid_dim}->{b.hw_dim}: size {b.hw_size} "
                       f"outside mesh dim of {mesh[b.hw_dim]}")
        if b.hw_dim in seen_hw:
            bad.append(f"hw dim {b.hw_dim} bound twice")
        seen_hw.add(b.hw_dim)
        if b.grid_dim not in dims:
            bad.append(f"bind {b.grid_dim}->{b.hw_dim}: undeclared loop dim")

    # -- temporal loops: declared grid dims, positive extents --------------
    for t in mapping.temporal:
        if t.grid_dim not in dims:
            bad.append(f"temporal {t.name}: undeclared dim {t.grid_dim}")
        if t.extent < 1:
            bad.append(f"temporal {t.name}: extent {t.extent}")

    # -- tile shapes: rank-matched, positive, L1-sized blocks --------------
    cap = hw.local_capacity()
    for acc in prog.loads + prog.stores:
        if len(acc.tile_shape) != len(acc.tensor.shape):
            bad.append(f"{acc.label()}: tile rank {len(acc.tile_shape)} vs "
                       f"tensor rank {len(acc.tensor.shape)}")
        if any(s < 1 for s in acc.tile_shape):
            bad.append(f"{acc.label()}: nonpositive tile shape "
                       f"{acc.tile_shape}")
        elif acc.tile_bytes > cap:
            bad.append(f"{acc.label()}: single tile "
                       f"({acc.tile_bytes} B) exceeds L1 ({cap} B)")

    if bad:
        return bad

    # -- residency: the full double-buffered footprint fits L1 -------------
    footprint = plan.buffer_bytes()
    if footprint > cap:
        bad.append(f"residency: footprint {footprint} B exceeds L1 {cap} B")

    # -- fault overlay: only meaningful for plans computed on this model ---
    # (a submesh plan cached under the degraded fabric's key renumbers
    # coordinates, so the conflict test would misfire on it)
    if mapping.hw_name == hw.name and hw.is_degraded \
            and mapping.conflicts_with_faults(hw):
        bad.append("fault conflict: mapping activates disabled cores")
    return bad
