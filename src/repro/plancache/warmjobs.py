"""Shardable units of the AOT warm sweep (``warm --jobs N``).

Each job is a ``(kind, payload)`` pair small enough to pickle into a
worker process; :func:`run_job` executes one and returns its log line.
The same function backs the inline path (``--jobs 1``), so the sharded
and sequential sweeps are one implementation.

Workers publish directly into the shared on-disk plan store resolved from
the inherited environment (``REPRO_PLAN_CACHE_DIR``): entry writes go
through pid-unique temp files + atomic renames, and each job flushes its
hit/miss counters under the store's advisory lock, so N concurrent jobs
keep the registry and its stats coherent.
"""
from __future__ import annotations

import os
from typing import Any, List, Tuple

Job = Tuple[str, Any]


def run_job(job: Job) -> str:
    """Execute one warm job and return its ``[warm] ...`` log line."""
    kind, payload = job
    fn = _KINDS[kind]
    msg = fn(payload)
    from .store import get_store
    get_store().flush_stats()
    return msg


def run_job_isolated(job: Job) -> str:
    """Worker-process entry: like :func:`run_job`, but pins the planner to
    inline search first — the sweep is already parallel at job granularity,
    so nested per-search pools would only oversubscribe."""
    os.environ["REPRO_PLANNER_WORKERS"] = "1"
    return run_job(job)


def _gemm(payload) -> str:
    from repro.core.lower_jax import plan_gemm_blocks
    M, N, K = payload
    blocks = plan_gemm_blocks(M, N, K)
    return f"[warm] gemm {M}x{N}x{K} -> blocks {blocks}"


def _flash(payload) -> str:
    from repro.core.lower_jax import plan_flash_blocks
    Sq, Skv, d = payload
    blocks = plan_flash_blocks(Sq, Skv, d)
    return f"[warm] flash q{Sq} kv{Skv} d{d} -> blocks {blocks}"


def _mesh(payload) -> str:
    arch, shape_name = payload
    # one implementation for every sharded mesh ranking: the same worker
    # entry backs planner_bridge.plan_mesh_many
    from repro.parallel.planner_bridge import _plan_mesh_job
    ranked = _plan_mesh_job((arch, shape_name, {}, False, 3))
    best = ranked[0].plan.name if ranked else "-"
    return f"[warm] mesh {arch}/{shape_name} -> {best}"


def _wormhole_gemm(payload) -> str:
    hw_name, (M, N, K) = payload
    from repro.core import get_hw
    from .cache import PlanCache
    tl_gemm, budget = _benchmark_gemm_entry()
    res = tl_gemm(M, N, K, get_hw(hw_name), budget=budget, cache=PlanCache())
    return f"[warm] {hw_name} gemm {M}x{N}x{K} -> {res.best.plan.describe()}"


def _wormhole_flash(payload) -> str:
    bh, seq, d = payload
    from repro.core import (SearchBudget, flash_attention_program, get_hw,
                            plan_kernel_multi)
    from .cache import PlanCache
    progs = [flash_attention_program(bh, seq, seq, d, bq=bq, bkv=bkv)
             for bq in (32, 64, 128) for bkv in (32, 64, 128)]
    res = plan_kernel_multi(progs, get_hw("wormhole_8x8"),
                            budget=SearchBudget(top_k=5,
                                                max_plans_per_mapping=48),
                            cache=PlanCache())
    return f"[warm] wormhole flash h*b{bh} s{seq} d{d} -> " \
           f"{res.best.plan.describe()}"


def _pipeline(payload) -> str:
    """Warm one kernel-graph co-planning cell (``warm --pipeline SPEC``):
    the graph-level entry plus the per-node kernel entries it resolves
    through."""
    spec, hw_name = payload
    from repro.core import SearchBudget, get_hw
    from repro.pipeline import graph_from_spec, plan_pipeline
    from .cache import PlanCache
    g = graph_from_spec(spec)
    gp = plan_pipeline(g, get_hw(hw_name),
                       budget=SearchBudget(top_k=4,
                                           max_plans_per_mapping=48,
                                           max_candidates=8000),
                       cache=PlanCache())
    return (f"[warm] pipeline {spec} on {hw_name} -> "
            f"{gp.total_s * 1e6:.1f}us ({gp.n_forwarded()}/"
            f"{len(gp.decisions)} edges forwarded, "
            f"{gp.improvement:.2f}x vs DRAM handoff)")


def _fault_gemm(payload) -> str:
    """Warm one cell of a single-core-failure plan pool (``warm --faults``):
    run the full degradation ladder for ``hw`` with ``core`` disabled and
    publish the winner under the *degraded* cache key, so a live failure of
    that core re-plans as a pure cache hit (zero cold searches).  Budgets
    and program lists must match :func:`repro.runtime.replan.plan_degraded`
    defaults exactly — they do, because this calls it."""
    hw_name, (M, N, K), core = payload
    from repro.core import block_shape_candidates, get_hw, matmul_program
    from repro.runtime.replan import plan_degraded
    from .cache import PlanCache
    hw = get_hw(hw_name)
    deg = hw.with_faults(disabled_cores=[tuple(core)])
    progs = [matmul_program(M, N, K, bm=bm, bn=bn, bk=bk)
             for bm, bn, bk in block_shape_candidates(M, N, K)]
    out = plan_degraded(progs, deg, healthy_hw=hw, cache=PlanCache(),
                        cause="warm")
    return (f"[warm] faults {hw_name} -core{tuple(core)} gemm {M}x{N}x{K} "
            f"-> {out.rung}, {out.result.best.final_s * 1e6:.1f}us "
            f"on {out.hw.name}")


def _benchmark_gemm_entry():
    """The benchmark suite's ``tl_gemm`` + budget when the repo checkout is
    importable, else an equivalent local fallback — budgets must match the
    benchmark sweeps' own invocations exactly, or the warmed entries are
    dead (same contract as the historical inline warm path)."""
    try:
        from benchmarks.common import DEFAULT_BUDGET, tl_gemm
        return tl_gemm, DEFAULT_BUDGET
    except ImportError:
        from repro.core import (SearchBudget, block_shape_candidates,
                                matmul_program, plan_kernel_multi)
        budget = SearchBudget(top_k=5, max_plans_per_mapping=48,
                              max_candidates=8000)

        def tl_gemm(M, N, K, hw, budget=budget, **kw):
            progs = [matmul_program(M, N, K, bm=bm, bn=bn, bk=bk)
                     for bm, bn, bk in block_shape_candidates(M, N, K)]
            return plan_kernel_multi(progs, hw, budget=budget, **kw)

        return tl_gemm, budget


_KINDS = {
    "gemm": _gemm,
    "flash": _flash,
    "mesh": _mesh,
    "wh_gemm": _wormhole_gemm,
    "wh_flash": _wormhole_flash,
    "pipeline": _pipeline,
    "fault_gemm": _fault_gemm,
}


def run_jobs(jobs: List[Job], n_jobs: int) -> List[str]:
    """Run warm jobs inline (``n_jobs <= 1``) or sharded across the worker
    pool; log lines return in submission order either way."""
    if n_jobs <= 1 or len(jobs) <= 1:
        return [run_job(j) for j in jobs]
    from repro.parallel import search_exec
    return search_exec.map_jobs(run_job_isolated, jobs, n_jobs)
