# Persistent plan registry: content-addressed dataflow-plan cache.
#
# Layers:  serialize.py (JSON round-trip for planner artifacts)
#       -> keying.py    (content digests: program + df_text + schema)
#       -> store.py     (two-tier LRU/disk store, stats, prune, quarantine)
#       -> validate.py  (structural plan sanitizer run before serving)
#       -> cache.py     (PlanResult-level cache, the planner's ``cache=``)
#       -> warmstart.py (nearest-neighbor search seeding)
#       -> __main__.py  (AOT tuning CLI: warm / ls / stats / prune)
from .cache import PlanCache
from .keying import (SCHEMA_VERSION, bucket_extent, budget_signature,
                     family_signature, hw_digest, kernel_key, request_key,
                     shape_vector, template_signature)
from .serialize import (plan_from_dict, plan_to_dict, program_from_dict,
                        program_to_dict, result_from_dict, result_to_dict)
from .store import (CacheStats, ENV_DIR, ENV_TOGGLE, PlanCacheStore,
                    QUARANTINE_DIR, cache_enabled, default_cache_dir,
                    get_store, lookup_source, reset_store, stats_blob)
from .validate import validate_plan
from .warmstart import order_programs, tile_signature, warm_order_from_store

__all__ = [
    "PlanCache", "PlanCacheStore", "CacheStats",
    "SCHEMA_VERSION", "ENV_DIR", "ENV_TOGGLE", "QUARANTINE_DIR",
    "bucket_extent", "budget_signature", "family_signature", "hw_digest",
    "kernel_key", "request_key", "shape_vector", "template_signature",
    "plan_from_dict", "plan_to_dict", "program_from_dict", "program_to_dict",
    "result_from_dict", "result_to_dict",
    "cache_enabled", "default_cache_dir", "get_store", "lookup_source",
    "reset_store", "stats_blob", "validate_plan",
    "order_programs", "tile_signature", "warm_order_from_store",
]
