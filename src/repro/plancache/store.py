"""Two-tier persistent plan store: in-memory LRU front, on-disk JSON back.

Layout: one ``<digest>.json`` file per entry under
``$REPRO_PLAN_CACHE_DIR`` (default ``~/.cache/repro-plancache``), plus a
``_stats.json`` accumulating cumulative hit/miss counters across processes
(flushed explicitly — the AOT CLI and the integration points call
:meth:`PlanCacheStore.flush_stats`).

Entry format::

    {"key": <digest>, "schema": <int>, "created": <unix ts>,
     "meta": {"template": ..., "shape": [...], "hw": <df digest>,
              "hw_name": ..., ...},
     "payload": {...}}              # arbitrary JSON (serialized PlanResult,
                                    # block tuple, mesh ranking, ...)

``meta`` is what ``ls``/``nearest`` scan; ``payload`` is what a hit
returns.  Set ``REPRO_PLAN_CACHE=off`` to bypass the store entirely
(every lookup counts as ``bypassed`` and planning proceeds uncached).
"""
from __future__ import annotations

import contextlib
import json
import os
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

from repro.obs import metrics

from . import keying

ENV_DIR = "REPRO_PLAN_CACHE_DIR"
ENV_TOGGLE = "REPRO_PLAN_CACHE"
_OFF_VALUES = ("0", "off", "false", "no", "disable", "disabled")
STATS_FILE = "_stats.json"
QUARANTINE_DIR = "quarantine"


def default_cache_dir() -> Path:
    env = os.environ.get(ENV_DIR)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro-plancache"


def cache_enabled() -> bool:
    return os.environ.get(ENV_TOGGLE, "").lower() not in _OFF_VALUES


@dataclass
class CacheStats:
    hits_mem: int = 0
    hits_disk: int = 0
    misses: int = 0
    bypassed: int = 0
    puts: int = 0
    warm_starts: int = 0
    corrupt: int = 0         # entries quarantined (decode/checksum/validate)

    @property
    def hits(self) -> int:
        return self.hits_mem + self.hits_disk

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self) -> Dict[str, int]:
        return {"hits_mem": self.hits_mem, "hits_disk": self.hits_disk,
                "misses": self.misses, "bypassed": self.bypassed,
                "puts": self.puts, "warm_starts": self.warm_starts,
                "corrupt": self.corrupt}

    def add(self, other: Dict[str, int]) -> None:
        for k, v in other.items():
            if hasattr(self, k):
                setattr(self, k, getattr(self, k) + int(v))


class PlanCacheStore:
    """The two-tier cache: an LRU dict of deserialized entries in front of
    the per-entry JSON files."""

    def __init__(self, root: Optional[Path] = None, *,
                 mem_capacity: int = 256,
                 enabled: Optional[bool] = None) -> None:
        self.root = Path(root) if root is not None else default_cache_dir()
        self.mem_capacity = mem_capacity
        self.enabled = cache_enabled() if enabled is None else enabled
        self._mem: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()
        self.stats = CacheStats()
        self._flushed = CacheStats()   # what has already been persisted
        self._meta: Optional[List[Tuple[str, Dict[str, Any]]]] = None
        self._meta_mtime = 0

    # ----------------------------------------------------------- paths
    def _path(self, key: str) -> Path:
        return self.root / f"{key}.json"

    # ----------------------------------------------------- integrity
    def quarantine(self, key: str, cause: str) -> None:
        """Move a corrupt/invalid entry to ``<root>/quarantine/`` (atomic
        rename, preserved for debugging) and count it.  Used by the read
        path on decode/checksum failures and by the cache layer when a
        deserialized plan fails :func:`~repro.plancache.validate
        .validate_plan`."""
        self._mem.pop(key, None)
        self._quarantine_path(self._path(key), cause)

    def _quarantine_path(self, path: Path, cause: str) -> None:
        self.stats.corrupt += 1
        metrics.inc("plancache_corrupt_entries_total", cause=cause)
        try:
            qdir = self.root / QUARANTINE_DIR
            qdir.mkdir(parents=True, exist_ok=True)
            os.replace(path, qdir / path.name)
        except OSError:
            with contextlib.suppress(OSError):
                path.unlink()
        self._meta = None          # the index no longer matches the dir

    @staticmethod
    def _checksum_ok(ent: Dict[str, Any]) -> bool:
        """Verify the per-entry payload checksum when present.  Entries
        written before the checksum existed carry no ``sum`` field and pass
        (back-compat: schema is unchanged — the payload layout did not)."""
        want = ent.get("sum")
        if want is None:
            return True
        return want == keying.digest_of(ent.get("payload"))

    # ----------------------------------------------------------- get/put
    def get(self, key: str) -> Optional[Dict[str, Any]]:
        if not self.enabled:
            self.stats.bypassed += 1
            metrics.inc("plancache_get_total", result="bypass")
            return None
        ent = self._mem.get(key)
        if ent is not None:
            self._mem.move_to_end(key)
            self.stats.hits_mem += 1
            metrics.inc("plancache_get_total", result="hit_mem")
            return ent
        path = self._path(key)
        if path.is_file():
            try:
                ent = json.loads(path.read_text())
            except json.JSONDecodeError:
                self.quarantine(key, "decode")
                ent = None
            except OSError:
                metrics.inc("plancache_io_errors_total", op="get")
                ent = None
            if ent is not None and ent.get("schema") != keying.SCHEMA_VERSION:
                ent = None           # stale schema: a plain miss, not corrupt
            if ent is not None and not self._checksum_ok(ent):
                self.quarantine(key, "checksum")
                ent = None
            if ent is not None:
                self._remember(key, ent)
                self.stats.hits_disk += 1
                metrics.inc("plancache_get_total", result="hit_disk")
                return ent
        self.stats.misses += 1
        metrics.inc("plancache_get_total", result="miss")
        return None

    def put(self, key: str, payload: Dict[str, Any],
            meta: Optional[Dict[str, Any]] = None) -> Optional[Dict[str, Any]]:
        if not self.enabled:
            self.stats.bypassed += 1
            metrics.inc("plancache_put_total", result="bypass")
            return None
        ent = {"key": key, "schema": keying.SCHEMA_VERSION,
               "created": time.time(),
               "sum": keying.digest_of(payload),
               "meta": meta or {}, "payload": payload}
        self._remember(key, ent)
        try:
            self.root.mkdir(parents=True, exist_ok=True)
            # pid-unique temp name: concurrent same-key writers must not
            # truncate each other's in-flight file before the atomic rename
            tmp = self.root / f"{key}.{os.getpid()}.tmp"
            tmp.write_text(json.dumps(ent))
            os.replace(tmp, self._path(key))
            self._index_add(key, ent["meta"])
        except OSError:
            self._meta = None        # disk tier is best-effort; rescan later
        self.stats.puts += 1
        metrics.inc("plancache_put_total", result="stored")
        return ent

    def _index_add(self, key: str, meta: Dict[str, Any]) -> None:
        """Keep the nearest() index incremental across our own puts — a full
        directory rescan per miss/put cycle would be quadratic in warm runs.
        The mtime stamp is refreshed so the next _meta_index() call doesn't
        discard the update (other processes' writes still trigger a rescan
        on their own mtime bumps after our next put)."""
        if self._meta is None:
            return
        self._meta = [(k, m) for k, m in self._meta if k != key]
        self._meta.append((key, meta))
        try:
            self._meta_mtime = self.root.stat().st_mtime_ns
        except OSError:
            self._meta = None

    def _read(self, key: str) -> Optional[Dict[str, Any]]:
        """Stat-free entry read (internal: nearest() must not count as a
        cache lookup)."""
        ent = self._mem.get(key)
        if ent is not None:
            return ent
        path = self._path(key)
        if not path.is_file():
            return None
        try:
            ent = json.loads(path.read_text())
        except json.JSONDecodeError:
            self.quarantine(key, "decode")
            return None
        except OSError:
            metrics.inc("plancache_io_errors_total", op="read")
            return None
        if ent.get("schema") != keying.SCHEMA_VERSION:
            return None
        if not self._checksum_ok(ent):
            self.quarantine(key, "checksum")
            return None
        return ent

    def _remember(self, key: str, ent: Dict[str, Any]) -> None:
        self._mem[key] = ent
        self._mem.move_to_end(key)
        while len(self._mem) > self.mem_capacity:
            self._mem.popitem(last=False)

    def clear_memory(self) -> None:
        """Drop the in-memory tier (tests use this to emulate a fresh
        process against a warm disk cache)."""
        self._mem.clear()

    def note_warm_start(self) -> None:
        self.stats.warm_starts += 1
        metrics.inc("plancache_warm_starts_total")

    # ----------------------------------------------------------- scanning
    def entries(self) -> Iterator[Dict[str, Any]]:
        """Iterate all on-disk entries (full JSON, including payload)."""
        if not self.root.is_dir():
            return
        for path in sorted(self.root.glob("*.json")):
            if path.name == STATS_FILE:
                continue
            try:
                yield json.loads(path.read_text())
            except json.JSONDecodeError:
                # self-healing: the corrupt file moves out of the cache dir
                # on first encounter, so scans don't re-count it forever
                self._mem.pop(path.stem, None)
                self._quarantine_path(path, "decode")
                continue
            except OSError:
                metrics.inc("plancache_io_errors_total", op="scan")
                continue

    def n_entries(self) -> int:
        if not self.root.is_dir():
            return 0
        return sum(1 for p in self.root.glob("*.json")
                   if p.name != STATS_FILE)

    def _meta_index(self) -> List[Tuple[str, Dict[str, Any]]]:
        """(key, meta) pairs for all disk entries, cached against the cache
        directory's mtime so repeated nearest() scans on misses don't
        re-parse every payload (entries hold full serialized PlanResults)."""
        try:
            mtime = self.root.stat().st_mtime_ns
        except OSError:
            return []
        if self._meta is None or self._meta_mtime != mtime:
            self._meta = [(ent.get("key", ""), ent.get("meta", {}))
                          for ent in self.entries()]
            self._meta_mtime = mtime
        return self._meta

    def nearest(self, template: str, hw: str,
                shape: Sequence[int]) -> Optional[Dict[str, Any]]:
        """The warm-start neighbor: the entry of the same kernel template on
        the same hardware whose shape vector is closest in log-space."""
        if not self.enabled:
            return None
        ranked = self._ranked_neighbors(template, hw, shape)
        return self._read(ranked[0][1]) if ranked else None

    def nearest_k(self, template: str, hw: str, shape: Sequence[int],
                  k: int = 3) -> List[Dict[str, Any]]:
        """The ``k`` closest same-template/same-hw entries, nearest first.
        Deterministic: ties in log-distance break on the entry key.  The
        plan service's shape-family rung walks this list so one corrupt or
        uncertifiable neighbor doesn't exhaust the rung."""
        if not self.enabled:
            return []
        out: List[Dict[str, Any]] = []
        for _, key in self._ranked_neighbors(template, hw, shape)[:max(0, k)]:
            ent = self._read(key)
            if ent is not None:
                out.append(ent)
        return out

    def _ranked_neighbors(self, template: str, hw: str,
                          shape: Sequence[int]) -> List[Tuple[float, str]]:
        shape = [max(1, int(s)) for s in shape]
        ranked: List[Tuple[float, str]] = []
        for key, meta in self._meta_index():
            if not key:
                continue             # unreadable/legacy entry: no key to load
            if meta.get("template") != template or meta.get("hw") != hw:
                continue
            cand = meta.get("shape")
            if not isinstance(cand, list) or len(cand) != len(shape):
                continue
            try:
                d = _log_distance(shape, cand)
            except (TypeError, ValueError):
                continue
            ranked.append((d, key))
        ranked.sort()
        return ranked

    # ----------------------------------------------------------- pruning
    def prune(self, *, max_entries: Optional[int] = None,
              max_age_s: Optional[float] = None) -> int:
        """Eviction policy for the disk tier: drop entries older than
        ``max_age_s``, entries with a stale schema, and (oldest-first) any
        beyond ``max_entries``.  Returns the number removed."""
        if not self.root.is_dir():
            return 0
        now = time.time()
        keep: List[Tuple[float, Path]] = []
        removed = 0
        for path in self.root.glob("*.json"):
            if path.name == STATS_FILE:
                continue
            try:
                ent = json.loads(path.read_text())
                created = float(ent.get("created", 0.0))
                stale = ent.get("schema") != keying.SCHEMA_VERSION
            except (json.JSONDecodeError, OSError, ValueError):
                created, stale = 0.0, True
            if stale or (max_age_s is not None and now - created > max_age_s):
                path.unlink(missing_ok=True)
                removed += 1
            else:
                keep.append((created, path))
        if max_entries is not None and len(keep) > max_entries:
            keep.sort()              # oldest first
            for _, path in keep[:len(keep) - max_entries]:
                path.unlink(missing_ok=True)
                removed += 1
        self.clear_memory()
        return removed

    # ----------------------------------------------------------- stats
    def flush_stats(self) -> Dict[str, int]:
        """Merge this process's counters into the on-disk cumulative stats
        (idempotent: only the delta since the last flush is added).  The
        read-modify-write runs under an advisory file lock so concurrent
        processes don't lose each other's deltas.  A disabled store never
        touches disk."""
        if not self.enabled:
            return self.cumulative_stats()
        snapshot = self.stats.as_dict()
        delta = {k: v - getattr(self._flushed, k)
                 for k, v in snapshot.items()}
        try:
            self.root.mkdir(parents=True, exist_ok=True)
            with open(self.root / (STATS_FILE + ".lock"), "w") as lock:
                try:
                    import fcntl
                    fcntl.flock(lock, fcntl.LOCK_EX)
                except (ImportError, OSError):
                    pass             # non-POSIX: best-effort, unlocked
                cum = self.cumulative_stats()
                for k, v in delta.items():
                    cum[k] = cum.get(k, 0) + v
                tmp = self.root / f"{STATS_FILE}.{os.getpid()}.tmp"
                tmp.write_text(json.dumps(cum))
                os.replace(tmp, self.root / STATS_FILE)
            # only after the persist lands: a failed write keeps the delta
            # pending so a later flush retries it
            self._flushed = CacheStats(**snapshot)
        except OSError:
            # counted, not silent: the delta stays pending for a retry and
            # the miss shows up in metrics instead of vanishing
            metrics.inc("plancache_io_errors_total", op="stats_flush")
            cum = self.cumulative_stats()
            for k, v in delta.items():
                cum[k] = cum.get(k, 0) + v
        return cum

    def cumulative_stats(self) -> Dict[str, int]:
        path = self.root / STATS_FILE
        if path.is_file():
            try:
                return {k: int(v) for k, v in
                        json.loads(path.read_text()).items()}
            except (json.JSONDecodeError, ValueError):
                # a torn stats file resets the cumulative counters; move it
                # aside so the next flush starts a clean one
                self._quarantine_path(path, "stats_decode")
                return {}
            except OSError:
                metrics.inc("plancache_io_errors_total", op="stats_read")
                return {}
        return {}


@contextlib.contextmanager
def lookup_source(store: PlanCacheStore):
    """Label whether the planning done inside the block resolved from the
    registry.  Yields a dict whose ``source`` key reads ``"cache"`` after
    the block iff a lookup hit landed and nothing new was planned (a
    genuine hit raises ``hits`` without a corresponding ``put``)."""
    probe = {"source": "search"}
    hits0, puts0 = store.stats.hits, store.stats.puts
    yield probe
    if store.stats.hits > hits0 and store.stats.puts == puts0:
        probe["source"] = "cache"


def _log_distance(a: Sequence[int], b: Sequence[int]) -> float:
    import math
    d = 0.0
    for x, y in zip(a, b):
        x, y = max(1, int(x)), max(1, int(y))
        d += abs(math.log2(x / y))
    return d


# --------------------------------------------------------------- singleton
_STORE: Optional[PlanCacheStore] = None


def get_store() -> PlanCacheStore:
    """Process-wide store singleton.  Re-resolved when the cache directory
    or toggle env vars change (so tests can redirect it per-tmpdir)."""
    global _STORE
    root = default_cache_dir()
    enabled = cache_enabled()
    if _STORE is None or _STORE.root != root or _STORE.enabled != enabled:
        _STORE = PlanCacheStore(root, enabled=enabled)
    return _STORE


def reset_store() -> None:
    global _STORE
    _STORE = None


def stats_blob(store: Optional[PlanCacheStore] = None) -> Dict[str, Any]:
    """One JSON-ready view of the registry's state: root, entry counts by
    template, cumulative cross-process hit/miss counters and the derived
    hit rate.  Shared by ``python -m repro.plancache stats --json`` and
    the ``launch/serve.py --introspect-port`` ``/plans`` endpoint."""
    store = store or get_store()
    cum = store.cumulative_stats()
    by_template: Dict[str, int] = {}
    for ent in store.entries():
        t = ent.get("meta", {}).get("template", "?")
        by_template[t] = by_template.get(t, 0) + 1
    hits = cum.get("hits_mem", 0) + cum.get("hits_disk", 0)
    total = hits + cum.get("misses", 0)
    return {
        "root": str(store.root), "enabled": store.enabled,
        "entries": store.n_entries(), "by_template": by_template,
        "cumulative": cum, "hit_rate": (hits / total if total else 0.0),
    }
