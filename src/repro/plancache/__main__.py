"""AOT tuning CLI for the persistent plan registry.

``python -m repro.plancache warm``    pre-tunes the GEMM / flash block-shape
                                      tables (chip df model) and the mesh
                                      sharding rankings for every registry
                                      (arch x shape) cell, so ``launch/serve``
                                      and ``launch/train`` start with a hot
                                      cache.  ``--wormhole`` additionally
                                      warms the paper's Wormhole benchmark
                                      tables (``benchmarks/gemm_table`` /
                                      ``topk_table`` shapes).
``python -m repro.plancache ls``      lists entries (template, shape, hw).
``python -m repro.plancache stats``   entry count + cumulative hit/miss
                                      counters across processes.
``python -m repro.plancache prune``   age/count-based disk eviction.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import List, Sequence, Tuple

from .store import get_store

# chip-level GEMM shapes always worth pre-tuning (mirrors the benchmark
# suite's shape tables; benchmarks/*.py import-level tables are merged in
# when the benchmarks package is importable)
BASE_GEMM_SHAPES: Tuple[Tuple[int, int, int], ...] = (
    (1024, 1024, 4096), (4096, 4096, 4096),
    (16384, 1024, 4096), (4096, 16384, 4096),
)
BASE_FLASH_SHAPES: Tuple[Tuple[int, int, int], ...] = (
    (4096, 4096, 64), (4096, 4096, 128), (8192, 8192, 128),
)


def _parse_shape(text: str, n: int) -> Tuple[int, ...]:
    parts = tuple(int(p) for p in text.lower().split("x"))
    if len(parts) != n:
        raise argparse.ArgumentTypeError(
            f"expected {n}'x'-separated ints, got {text!r}")
    return parts


def _registry_gemm_shapes(archs: Sequence[str], tokens: int = 4096
                          ) -> List[Tuple[int, int, int]]:
    from repro.configs import ARCHS
    shapes = set()
    for name in archs:
        cfg = ARCHS[name]
        d, f = cfg.d_model, cfg.d_ff
        shapes.add((tokens, f, d))              # up-projection
        shapes.add((tokens, d, f))              # down-projection
        shapes.add((tokens, cfg.padded_vocab, d))   # LM head
    return sorted(shapes)


def _registry_flash_shapes(archs: Sequence[str]
                           ) -> List[Tuple[int, int, int]]:
    from repro.configs import ARCHS
    from repro.configs.shapes import SHAPES
    shapes = set()
    seqs = sorted({s.seq_len for s in SHAPES.values() if s.seq_len <= 32768})
    for name in archs:
        hd = ARCHS[name].head_dim_
        for seq in seqs:
            shapes.add((seq, seq, hd))
    return sorted(shapes)


def _benchmark_gemm_shapes(full: bool) -> List[Tuple[int, int, int]]:
    try:
        from benchmarks import gemm_table, topk_table
        return sorted(set(gemm_table.shape_table(full))
                      | set(topk_table.SHAPES))
    except ImportError:
        return list(BASE_GEMM_SHAPES)


def _benchmark_flash_shapes() -> List[Tuple[int, int, int]]:
    """(Sq, Skv, d) cells derived from the Fig-7 benchmark sweep, when the
    benchmarks package is importable (repo checkout)."""
    try:
        from benchmarks import flash_table
        return sorted({(seq, seq, d)
                       for _bh, seq, d in flash_table.shape_table()})
    except ImportError:
        return []


def _wormhole_flash_shapes() -> List[Tuple[int, int, int]]:
    """(batch*heads, seq, head_dim) cells of the Fig-7 sweep itself."""
    try:
        from benchmarks import flash_table
        return list(flash_table.shape_table())
    except ImportError:
        return []


# ----------------------------------------------------------------- warm
def cmd_warm(args: argparse.Namespace) -> int:
    if args.fast:
        os.environ["REPRO_FAST_SEARCH"] = "1"
    from repro.core.planner import fast_search_enabled
    if fast_search_enabled():
        # keys include the effective (shrunk) budget, so these entries only
        # serve consumers that also run with REPRO_FAST_SEARCH set
        print("[warm] note: REPRO_FAST_SEARCH is on — entries are keyed for "
              "fast-search consumers; production lookups without the env "
              "var will not hit them")
    store = get_store()
    if not store.enabled:
        print("plan cache disabled (REPRO_PLAN_CACHE=off); nothing to warm")
        return 1
    archs = (args.archs.split(",") if args.archs else None)
    t0 = time.perf_counter()
    n_jobs = 0

    if not args.skip_gemm:
        from repro.configs import ARCHS
        from repro.core.lower_jax import plan_gemm_blocks
        names = archs or sorted(ARCHS)
        shapes = set(args.gemm or [])
        if not args.gemm:
            shapes.update(BASE_GEMM_SHAPES)
            shapes.update(_registry_gemm_shapes(names))
        for (M, N, K) in sorted(shapes):
            blocks = plan_gemm_blocks(M, N, K)
            n_jobs += 1
            print(f"[warm] gemm {M}x{N}x{K} -> blocks {blocks}")

    if not args.skip_flash:
        from repro.configs import ARCHS
        from repro.core.lower_jax import plan_flash_blocks
        names = archs or sorted(ARCHS)
        shapes = set(args.flash or [])
        if not args.flash:
            shapes.update(BASE_FLASH_SHAPES)
            shapes.update(_registry_flash_shapes(names))
            shapes.update(_benchmark_flash_shapes())
        for (Sq, Skv, d) in sorted(shapes):
            blocks = plan_flash_blocks(Sq, Skv, d)
            n_jobs += 1
            print(f"[warm] flash q{Sq} kv{Skv} d{d} -> blocks {blocks}")

    if not args.skip_mesh:
        from repro.configs import ARCHS
        from repro.configs.base import TrainConfig
        from repro.configs.registry import cells
        from repro.models import build_model
        from repro.parallel.planner_bridge import plan_mesh
        tcfg = TrainConfig()
        for cfg, shape, _ in cells():
            if archs and cfg.name not in archs:
                continue
            ranked = plan_mesh(build_model(cfg), shape, tcfg)
            n_jobs += 1
            best = ranked[0].plan.name if ranked else "-"
            print(f"[warm] mesh {cfg.name}/{shape.name} -> {best}")

    if args.wormhole:
        from repro.core import (SearchBudget, flash_attention_program,
                                get_hw, plan_kernel_multi)
        from .cache import PlanCache
        try:
            from benchmarks.common import DEFAULT_BUDGET, HW_CONFIGS, tl_gemm
            budget = DEFAULT_BUDGET
        except ImportError:
            from repro.core import block_shape_candidates, matmul_program
            HW_CONFIGS = ("wormhole_1x8", "wormhole_4x8", "wormhole_8x8")
            budget = SearchBudget(top_k=5, max_plans_per_mapping=48,
                                  max_candidates=8000)

            def tl_gemm(M, N, K, hw, budget=budget, **kw):
                progs = [matmul_program(M, N, K, bm=bm, bn=bn, bk=bk)
                         for bm, bn, bk in block_shape_candidates(M, N, K)]
                return plan_kernel_multi(progs, hw, budget=budget, **kw)

        pc = PlanCache(store)
        # budgets and profile (default True) must match the benchmark
        # sweeps' own invocations exactly, or the warmed entries are dead
        hw_names = HW_CONFIGS if args.hw == "all" else (args.hw,)
        for hw_name in hw_names:
            hw = get_hw(hw_name)
            for (M, N, K) in _benchmark_gemm_shapes(args.full):
                res = tl_gemm(M, N, K, hw, budget=budget, cache=pc)
                n_jobs += 1
                print(f"[warm] {hw_name} gemm {M}x{N}x{K} -> "
                      f"{res.best.plan.describe()}")
        # flash_fig7 cells (wormhole_8x8 only, as the benchmark runs them)
        flash_budget = SearchBudget(top_k=5, max_plans_per_mapping=48)
        hw = get_hw("wormhole_8x8")
        for bh, seq, d in _wormhole_flash_shapes():
            progs = [flash_attention_program(bh, seq, seq, d, bq=bq, bkv=bkv)
                     for bq in (32, 64, 128) for bkv in (32, 64, 128)]
            res = plan_kernel_multi(progs, hw, budget=flash_budget, cache=pc)
            n_jobs += 1
            print(f"[warm] wormhole flash h*b{bh} s{seq} d{d} -> "
                  f"{res.best.plan.describe()}")

    cum = store.flush_stats()
    dt = time.perf_counter() - t0
    s = store.stats
    print(f"[warm] {n_jobs} shapes in {dt:.1f}s: {s.hits} hits "
          f"({s.hits_mem} mem / {s.hits_disk} disk), {s.misses} misses, "
          f"{s.puts} new entries; store now {store.n_entries()} entries, "
          f"cumulative hit rate "
          f"{_rate(cum):.0%}")
    return 0


def _rate(cum: dict) -> float:
    hits = cum.get("hits_mem", 0) + cum.get("hits_disk", 0)
    total = hits + cum.get("misses", 0)
    return hits / total if total else 0.0


# ------------------------------------------------------------------- ls
def cmd_ls(args: argparse.Namespace) -> int:
    store = get_store()
    now = time.time()
    n = 0
    for ent in store.entries():
        meta = ent.get("meta", {})
        if args.template and meta.get("template") != args.template:
            continue
        n += 1
        age = now - float(ent.get("created", now))
        shape = "x".join(str(s) for s in meta.get("shape", [])) or "-"
        print(f"{ent['key'][:12]}  {meta.get('template', '?'):<12} "
              f"shape={shape:<20} hw={meta.get('hw_name', '?'):<16} "
              f"age={age / 3600:.1f}h")
    print(f"{n} entries in {store.root}")
    return 0


# ---------------------------------------------------------------- stats
def cmd_stats(args: argparse.Namespace) -> int:
    store = get_store()
    n = store.n_entries()
    cum = store.cumulative_stats()
    by_template: dict = {}
    for ent in store.entries():
        t = ent.get("meta", {}).get("template", "?")
        by_template[t] = by_template.get(t, 0) + 1
    print(f"store: {store.root}  (enabled={store.enabled})")
    print(f"entries: {n}")
    for t, c in sorted(by_template.items()):
        print(f"  {t}: {c}")
    hits = cum.get("hits_mem", 0) + cum.get("hits_disk", 0)
    print(f"cumulative: {hits} hits ({cum.get('hits_mem', 0)} mem / "
          f"{cum.get('hits_disk', 0)} disk), {cum.get('misses', 0)} misses, "
          f"{cum.get('puts', 0)} puts, {cum.get('warm_starts', 0)} "
          f"warm-starts, {cum.get('bypassed', 0)} bypassed")
    print(f"hit rate: {_rate(cum):.1%}")
    return 0


# ---------------------------------------------------------------- prune
def cmd_prune(args: argparse.Namespace) -> int:
    store = get_store()
    max_age = args.max_age_days * 86400.0 if args.max_age_days else None
    removed = store.prune(max_entries=args.max_entries, max_age_s=max_age)
    print(f"pruned {removed} entries; {store.n_entries()} remain "
          f"in {store.root}")
    return 0


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.plancache",
        description="Persistent dataflow-plan registry: AOT tuning + "
                    "maintenance")
    sub = ap.add_subparsers(dest="cmd", required=True)

    w = sub.add_parser("warm", help="pre-tune shape tables into the cache")
    w.add_argument("--gemm", action="append",
                   type=lambda t: _parse_shape(t, 3), metavar="MxNxK",
                   help="explicit GEMM shape (repeatable; overrides tables)")
    w.add_argument("--flash", action="append",
                   type=lambda t: _parse_shape(t, 3), metavar="SqxSkvxD",
                   help="explicit flash shape (repeatable; overrides tables)")
    w.add_argument("--archs", default=None,
                   help="comma-separated registry archs (default: all)")
    w.add_argument("--skip-gemm", action="store_true")
    w.add_argument("--skip-flash", action="store_true")
    w.add_argument("--skip-mesh", action="store_true")
    w.add_argument("--wormhole", action="store_true",
                   help="also warm the Wormhole benchmark GEMM/flash tables")
    w.add_argument("--hw", default="all",
                   help="hardware preset for --wormhole GEMM warming "
                        "(\"all\" = every benchmark mesh config)")
    w.add_argument("--full", action="store_true",
                   help="use the full benchmark shape tables")
    w.add_argument("--fast", action="store_true",
                   help="set REPRO_FAST_SEARCH=1 for this run")
    w.set_defaults(fn=cmd_warm)

    l = sub.add_parser("ls", help="list cache entries")
    l.add_argument("--template", default=None,
                   help="filter by entry template (gemm_blocks, mesh_plan...)")
    l.set_defaults(fn=cmd_ls)

    s = sub.add_parser("stats", help="entry counts + cumulative hit/miss")
    s.set_defaults(fn=cmd_stats)

    p = sub.add_parser("prune", help="evict old/stale entries")
    p.add_argument("--max-entries", type=int, default=None)
    p.add_argument("--max-age-days", type=float, default=None)
    p.set_defaults(fn=cmd_prune)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
