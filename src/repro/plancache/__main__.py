"""AOT tuning CLI for the persistent plan registry.

``python -m repro.plancache warm``    pre-tunes the GEMM / flash block-shape
                                      tables (chip df model) and the mesh
                                      sharding rankings for every registry
                                      (arch x shape) cell, so ``launch/serve``
                                      and ``launch/train`` start with a hot
                                      cache.  ``--wormhole`` additionally
                                      warms the paper's Wormhole benchmark
                                      tables (``benchmarks/gemm_table`` /
                                      ``topk_table`` shapes).  ``--jobs N``
                                      shards the sweep across N worker
                                      processes publishing into the shared
                                      disk store (see ``warmjobs.py``);
                                      individual searches instead parallelize
                                      via ``REPRO_PLANNER_WORKERS``.
``python -m repro.plancache ls``      lists entries (template, shape, hw).
``python -m repro.plancache stats``   entry count + cumulative hit/miss
                                      counters across processes; ``--json``
                                      emits a machine-readable snapshot
                                      including this process's unified
                                      metrics registry (``repro.obs``).
``python -m repro.plancache prune``   age/count-based disk eviction.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import List, Sequence, Tuple

from .store import get_store

# chip-level GEMM shapes always worth pre-tuning (mirrors the benchmark
# suite's shape tables; benchmarks/*.py import-level tables are merged in
# when the benchmarks package is importable)
BASE_GEMM_SHAPES: Tuple[Tuple[int, int, int], ...] = (
    (1024, 1024, 4096), (4096, 4096, 4096),
    (16384, 1024, 4096), (4096, 16384, 4096),
)
BASE_FLASH_SHAPES: Tuple[Tuple[int, int, int], ...] = (
    (4096, 4096, 64), (4096, 4096, 128), (8192, 8192, 128),
)
# GEMM cells of the single-core-failure plan pools (``warm --faults``) —
# must mirror the degraded-mesh acceptance suite (tests/test_faults.py)
FAULT_GEMM_SHAPES: Tuple[Tuple[int, int, int], ...] = (
    (256, 256, 256), (512, 512, 512), (512, 1024, 512),
)


def _parse_shape(text: str, n: int) -> Tuple[int, ...]:
    parts = tuple(int(p) for p in text.lower().split("x"))
    if len(parts) != n:
        raise argparse.ArgumentTypeError(
            f"expected {n}'x'-separated ints, got {text!r}")
    return parts


def _registry_gemm_shapes(archs: Sequence[str], tokens: int = 4096
                          ) -> List[Tuple[int, int, int]]:
    from repro.configs import ARCHS
    shapes = set()
    for name in archs:
        cfg = ARCHS[name]
        d, f = cfg.d_model, cfg.d_ff
        shapes.add((tokens, f, d))              # up-projection
        shapes.add((tokens, d, f))              # down-projection
        shapes.add((tokens, cfg.padded_vocab, d))   # LM head
    return sorted(shapes)


def _registry_flash_shapes(archs: Sequence[str]
                           ) -> List[Tuple[int, int, int]]:
    from repro.configs import ARCHS
    from repro.configs.shapes import SHAPES
    shapes = set()
    seqs = sorted({s.seq_len for s in SHAPES.values() if s.seq_len <= 32768})
    for name in archs:
        hd = ARCHS[name].head_dim_
        for seq in seqs:
            shapes.add((seq, seq, hd))
    return sorted(shapes)


def _benchmark_gemm_shapes(full: bool) -> List[Tuple[int, int, int]]:
    try:
        from benchmarks import gemm_table, topk_table
        return sorted(set(gemm_table.shape_table(full))
                      | set(topk_table.SHAPES))
    except ImportError:
        return list(BASE_GEMM_SHAPES)


def _benchmark_flash_shapes() -> List[Tuple[int, int, int]]:
    """(Sq, Skv, d) cells derived from the Fig-7 benchmark sweep, when the
    benchmarks package is importable (repo checkout)."""
    try:
        from benchmarks import flash_table
        return sorted({(seq, seq, d)
                       for _bh, seq, d in flash_table.shape_table()})
    except ImportError:
        return []


def _wormhole_flash_shapes() -> List[Tuple[int, int, int]]:
    """(batch*heads, seq, head_dim) cells of the Fig-7 sweep itself."""
    try:
        from benchmarks import flash_table
        return list(flash_table.shape_table())
    except ImportError:
        return []


# ----------------------------------------------------------------- warm
def cmd_warm(args: argparse.Namespace) -> int:
    if args.fast:
        os.environ["REPRO_FAST_SEARCH"] = "1"
    from repro.core.planner import fast_search_enabled
    if fast_search_enabled():
        # keys include the effective (shrunk) budget, so these entries only
        # serve consumers that also run with REPRO_FAST_SEARCH set
        print("[warm] note: REPRO_FAST_SEARCH is on — entries are keyed for "
              "fast-search consumers; production lookups without the env "
              "var will not hit them")
    store = get_store()
    if not store.enabled:
        print("plan cache disabled (REPRO_PLAN_CACHE=off); nothing to warm")
        return 1
    archs = (args.archs.split(",") if args.archs else None)
    t0 = time.perf_counter()
    jobs: List[tuple] = []

    if not args.skip_gemm:
        from repro.configs import ARCHS
        names = archs or sorted(ARCHS)
        shapes = set(args.gemm or [])
        if not args.gemm:
            shapes.update(BASE_GEMM_SHAPES)
            shapes.update(_registry_gemm_shapes(names))
        jobs += [("gemm", s) for s in sorted(shapes)]

    if not args.skip_flash:
        from repro.configs import ARCHS
        names = archs or sorted(ARCHS)
        shapes = set(args.flash or [])
        if not args.flash:
            shapes.update(BASE_FLASH_SHAPES)
            shapes.update(_registry_flash_shapes(names))
            shapes.update(_benchmark_flash_shapes())
        jobs += [("flash", s) for s in sorted(shapes)]

    if not args.skip_mesh:
        from repro.configs.registry import cells
        jobs += [("mesh", (cfg.name, shape.name)) for cfg, shape, _ in cells()
                 if not archs or cfg.name in archs]

    if args.pipeline:
        jobs += [("pipeline", (spec, args.pipeline_hw))
                 for spec in args.pipeline]

    if args.wormhole:
        try:
            from benchmarks.common import HW_CONFIGS
        except ImportError:
            HW_CONFIGS = ("wormhole_1x8", "wormhole_4x8", "wormhole_8x8")
        hw_names = HW_CONFIGS if args.hw == "all" else (args.hw,)
        jobs += [("wh_gemm", (hw_name, s)) for hw_name in hw_names
                 for s in _benchmark_gemm_shapes(args.full)]
        # flash_fig7 cells (wormhole_8x8 only, as the benchmark runs them)
        jobs += [("wh_flash", s) for s in _wormhole_flash_shapes()]

    if args.faults:
        from repro.core import get_hw
        fhw = get_hw(args.faults_hw)
        shapes = list(args.faults_gemm or FAULT_GEMM_SHAPES)
        if args.faults_core:
            cores = [tuple(int(v) for v in c.split(","))
                     for c in args.faults_core]
        else:
            import itertools
            cores = [tuple(c) for c in itertools.product(
                *(range(s) for _, s in fhw.mesh_dims))]
        jobs += [("fault_gemm", (args.faults_hw, s, core))
                 for core in cores for s in shapes]

    from . import warmjobs
    cum0 = store.cumulative_stats()       # workers flush into this file
    for line in warmjobs.run_jobs(jobs, args.jobs):
        print(line)

    cum = store.flush_stats()
    dt = time.perf_counter() - t0
    if args.jobs > 1:
        # this run's activity lives in the worker processes; the parent's
        # own store.stats saw nothing — report the cumulative-file delta
        # the workers flushed under the advisory lock
        d = {k: cum.get(k, 0) - cum0.get(k, 0) for k in
             ("hits_mem", "hits_disk", "misses", "puts")}
        hits = d["hits_mem"] + d["hits_disk"]
        line = (f"{hits} hits ({d['hits_mem']} mem / {d['hits_disk']} "
                f"disk), {d['misses']} misses, {d['puts']} new entries")
    else:
        s = store.stats
        line = (f"{s.hits} hits ({s.hits_mem} mem / {s.hits_disk} disk), "
                f"{s.misses} misses, {s.puts} new entries")
    print(f"[warm] {len(jobs)} shapes in {dt:.1f}s"
          + (f" across {args.jobs} jobs" if args.jobs > 1 else "")
          + f": {line}; store now {store.n_entries()} entries, "
          f"cumulative hit rate "
          f"{_rate(cum):.0%}")
    return 0


def _rate(cum: dict) -> float:
    hits = cum.get("hits_mem", 0) + cum.get("hits_disk", 0)
    total = hits + cum.get("misses", 0)
    return hits / total if total else 0.0


# ------------------------------------------------------------------- ls
def cmd_ls(args: argparse.Namespace) -> int:
    store = get_store()
    now = time.time()
    n = 0
    for ent in store.entries():
        meta = ent.get("meta", {})
        if args.template and meta.get("template") != args.template:
            continue
        n += 1
        age = now - float(ent.get("created", now))
        shape = "x".join(str(s) for s in meta.get("shape", [])) or "-"
        print(f"{ent['key'][:12]}  {meta.get('template', '?'):<12} "
              f"shape={shape:<20} hw={meta.get('hw_name', '?'):<16} "
              f"age={age / 3600:.1f}h")
    print(f"{n} entries in {store.root}")
    return 0


# ---------------------------------------------------------------- stats
def cmd_stats(args: argparse.Namespace) -> int:
    from .store import stats_blob
    store = get_store()
    blob = stats_blob(store)
    cum = blob["cumulative"]
    if getattr(args, "as_json", False):
        import json
        from repro.obs import metrics
        print(json.dumps({"store": blob, "metrics": metrics.snapshot()},
                         indent=1, sort_keys=True))
        return 0
    print(f"store: {store.root}  (enabled={store.enabled})")
    print(f"entries: {blob['entries']}")
    for t, c in sorted(blob["by_template"].items()):
        print(f"  {t}: {c}")
    hits = cum.get("hits_mem", 0) + cum.get("hits_disk", 0)
    print(f"cumulative: {hits} hits ({cum.get('hits_mem', 0)} mem / "
          f"{cum.get('hits_disk', 0)} disk), {cum.get('misses', 0)} misses, "
          f"{cum.get('puts', 0)} puts, {cum.get('warm_starts', 0)} "
          f"warm-starts, {cum.get('bypassed', 0)} bypassed")
    print(f"hit rate: {_rate(cum):.1%}")
    return 0


# ---------------------------------------------------------------- prune
def cmd_prune(args: argparse.Namespace) -> int:
    store = get_store()
    max_age = args.max_age_days * 86400.0 if args.max_age_days else None
    removed = store.prune(max_entries=args.max_entries, max_age_s=max_age)
    print(f"pruned {removed} entries; {store.n_entries()} remain "
          f"in {store.root}")
    return 0


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.plancache",
        description="Persistent dataflow-plan registry: AOT tuning + "
                    "maintenance")
    sub = ap.add_subparsers(dest="cmd", required=True)

    w = sub.add_parser("warm", help="pre-tune shape tables into the cache")
    w.add_argument("--gemm", action="append",
                   type=lambda t: _parse_shape(t, 3), metavar="MxNxK",
                   help="explicit GEMM shape (repeatable; overrides tables)")
    w.add_argument("--flash", action="append",
                   type=lambda t: _parse_shape(t, 3), metavar="SqxSkvxD",
                   help="explicit flash shape (repeatable; overrides tables)")
    w.add_argument("--archs", default=None,
                   help="comma-separated registry archs (default: all)")
    w.add_argument("--skip-gemm", action="store_true")
    w.add_argument("--skip-flash", action="store_true")
    w.add_argument("--skip-mesh", action="store_true")
    w.add_argument("--pipeline", action="append", metavar="KIND:DIMS",
                   help="warm a kernel-graph co-planning cell (repeatable): "
                        "mlp2:MxDxF, attn:HxSqxSkvxD, or moe:ExCxDmxDf "
                        "(graph-level entry + the per-node kernel entries)")
    w.add_argument("--pipeline-hw", default="wormhole_8x8",
                   help="hardware preset for --pipeline cells "
                        "(default: wormhole_8x8)")
    w.add_argument("--wormhole", action="store_true",
                   help="also warm the Wormhole benchmark GEMM/flash tables")
    w.add_argument("--hw", default="all",
                   help="hardware preset for --wormhole GEMM warming "
                        "(\"all\" = every benchmark mesh config)")
    w.add_argument("--full", action="store_true",
                   help="use the full benchmark shape tables")
    w.add_argument("--faults", action="store_true",
                   help="pre-warm single-core-failure plan pools: for each "
                        "core of --faults-hw, run the degradation ladder "
                        "(repro.runtime.replan) on the one-core-dead mesh "
                        "and publish under the degraded cache key, so a "
                        "live failure re-plans as a pure cache hit")
    w.add_argument("--faults-hw", default="wormhole_8x8",
                   help="hardware preset for --faults pools "
                        "(default: wormhole_8x8)")
    w.add_argument("--faults-gemm", action="append",
                   type=lambda t: _parse_shape(t, 3), metavar="MxNxK",
                   help="GEMM cells per failed core (repeatable; default: "
                        "the degraded-mesh acceptance suite)")
    w.add_argument("--faults-core", action="append", metavar="R,C",
                   help="restrict the pool to specific failed cores "
                        "(repeatable; default: every core of the mesh)")
    w.add_argument("--fast", action="store_true",
                   help="set REPRO_FAST_SEARCH=1 for this run")
    w.add_argument("--jobs", type=int, default=1,
                   help="shard the sweep across N worker processes (all "
                        "publish into the shared disk store; results are "
                        "identical to --jobs 1).  Each job runs its search "
                        "inline — the per-search process pool "
                        "(REPRO_PLANNER_WORKERS, default cpu count, 0/1 = "
                        "inline) applies when --jobs is 1.  Default: 1")
    w.set_defaults(fn=cmd_warm)

    l = sub.add_parser("ls", help="list cache entries")
    l.add_argument("--template", default=None,
                   help="filter by entry template (gemm_blocks, mesh_plan...)")
    l.set_defaults(fn=cmd_ls)

    s = sub.add_parser("stats", help="entry counts + cumulative hit/miss")
    s.add_argument("--json", action="store_true", dest="as_json",
                   help="machine-readable snapshot: store stats + this "
                        "process's unified metrics registry "
                        "(repro.obs.metrics)")
    s.set_defaults(fn=cmd_stats)

    p = sub.add_parser("prune", help="evict old/stale entries")
    p.add_argument("--max-entries", type=int, default=None)
    p.add_argument("--max-age-days", type=float, default=None)
    p.set_defaults(fn=cmd_prune)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
