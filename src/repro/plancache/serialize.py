"""Stable JSON round-trip for planner artifacts (no pickle).

Every dataclass the planner produces — :class:`TileProgram`,
:class:`Mapping`, :class:`MemOpChoice`, :class:`DataflowPlan`,
:class:`PlanCost`, :class:`SimResult`, :class:`Candidate`,
:class:`PlanResult` — gets a ``*_to_dict`` / ``*_from_dict`` pair whose
output survives ``json.dumps``/``json.loads`` unchanged.  The planner's
dataclasses are frozen and built from tuples of primitives, so round-trip
equality is structural: ``result_from_dict(result_to_dict(r))`` compares
equal field-by-field and ``estimate(plan, hw)`` reproduces identical costs.

The only non-trivial leaf is :class:`AffineExpr` — the plan-side algebra is
always the pure linear + ``mod``/``floordiv`` form (the composite channel
map of ``hw._channel_expr`` lives in hardware models, which are re-created
from presets, never serialized).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.core.affine import AffineExpr, AffineMap
from repro.core.mapping import Mapping, SpatialBind, TemporalLoop
from repro.core.perfmodel import PlanCost
from repro.core.plan import DataflowPlan
from repro.core.planner import Candidate, PlanResult
from repro.core.program import (LoopDim, TensorSpec, TileAccess, TileOp,
                                TileProgram)
from repro.core.reuse import HoistOption, MemOpChoice, StorePlacement
from repro.core.simulator import SimResult


# --------------------------------------------------------------- affine
def expr_to_dict(e: AffineExpr) -> Dict[str, Any]:
    return {"coeffs": [[k, v] for k, v in e.coeffs], "const": e.const,
            "mod": e.mod, "floordiv": e.floordiv}


def expr_from_dict(d: Dict[str, Any]) -> AffineExpr:
    return AffineExpr(coeffs=tuple((str(k), int(v)) for k, v in d["coeffs"]),
                      const=int(d["const"]), mod=d.get("mod"),
                      floordiv=d.get("floordiv"))


def map_to_dict(m: AffineMap) -> Dict[str, Any]:
    return {"exprs": [expr_to_dict(e) for e in m.exprs]}


def map_from_dict(d: Dict[str, Any]) -> AffineMap:
    return AffineMap(tuple(expr_from_dict(e) for e in d["exprs"]))


# --------------------------------------------------------------- program
def tensor_to_dict(t: TensorSpec) -> Dict[str, Any]:
    return {"name": t.name, "shape": list(t.shape),
            "dtype_bytes": t.dtype_bytes}


def tensor_from_dict(d: Dict[str, Any]) -> TensorSpec:
    return TensorSpec(d["name"], tuple(int(s) for s in d["shape"]),
                      int(d["dtype_bytes"]))


def access_to_dict(a: TileAccess) -> Dict[str, Any]:
    return {"tensor": tensor_to_dict(a.tensor), "index": map_to_dict(a.index),
            "tile_shape": list(a.tile_shape), "kind": a.kind, "name": a.name}


def access_from_dict(d: Dict[str, Any]) -> TileAccess:
    return TileAccess(tensor_from_dict(d["tensor"]),
                      map_from_dict(d["index"]),
                      tuple(int(s) for s in d["tile_shape"]),
                      d["kind"], d.get("name", ""))


def program_to_dict(p: TileProgram) -> Dict[str, Any]:
    return {
        "name": p.name,
        "grid_dims": [[d.name, d.extent] for d in p.grid_dims],
        "seq_dims": [[d.name, d.extent] for d in p.seq_dims],
        "loads": [access_to_dict(a) for a in p.loads],
        "stores": [access_to_dict(a) for a in p.stores],
        "body": [{"kind": o.kind, "unit": o.unit, "work": o.work,
                  "segment": o.segment} for o in p.body],
        "accumulators": [[n, b] for n, b in p.accumulators],
    }


def program_from_dict(d: Dict[str, Any]) -> TileProgram:
    return TileProgram(
        name=d["name"],
        grid_dims=tuple(LoopDim(n, int(e)) for n, e in d["grid_dims"]),
        seq_dims=tuple(LoopDim(n, int(e)) for n, e in d["seq_dims"]),
        loads=tuple(access_from_dict(a) for a in d["loads"]),
        stores=tuple(access_from_dict(a) for a in d["stores"]),
        body=tuple(TileOp(o["kind"], o["unit"], float(o["work"]),
                          int(o.get("segment", 0))) for o in d["body"]),
        accumulators=tuple((n, int(b)) for n, b in d["accumulators"]))


# --------------------------------------------------------------- mapping
def mapping_to_dict(m: Mapping) -> Dict[str, Any]:
    return {
        "program": program_to_dict(m.program),
        "hw_name": m.hw_name,
        "hw_dims": [[n, s] for n, s in m.hw_dims],
        "spatial": [{"hw_dim": b.hw_dim, "hw_size": b.hw_size,
                     "grid_dim": b.grid_dim, "reduce": b.reduce}
                    for b in m.spatial],
        "temporal": [{"name": t.name, "grid_dim": t.grid_dim,
                      "extent": t.extent} for t in m.temporal],
        "reduce_style": m.reduce_style,
    }


def mapping_from_dict(d: Dict[str, Any]) -> Mapping:
    return Mapping(
        program=program_from_dict(d["program"]),
        hw_name=d["hw_name"],
        hw_dims=tuple((n, int(s)) for n, s in d["hw_dims"]),
        spatial=tuple(SpatialBind(b["hw_dim"], int(b["hw_size"]),
                                  b["grid_dim"], bool(b.get("reduce", False)))
                      for b in d["spatial"]),
        temporal=tuple(TemporalLoop(t["name"], t["grid_dim"], int(t["extent"]))
                       for t in d["temporal"]),
        reduce_style=str(d.get("reduce_style", "")))


# ------------------------------------------------------------ memory ops
def memop_to_dict(c: MemOpChoice) -> Dict[str, Any]:
    h = c.hoist
    return {
        "access": access_to_dict(c.access),
        "bcast_axes": list(c.bcast_axes),
        "hoist": {"level": h.level, "footprint_tiles": h.footprint_tiles,
                  "issues_per_core": h.issues_per_core,
                  "tiles_per_issue": h.tiles_per_issue},
    }


def memop_from_dict(d: Dict[str, Any]) -> MemOpChoice:
    h = d["hoist"]
    return MemOpChoice(
        access_from_dict(d["access"]),
        tuple(str(a) for a in d["bcast_axes"]),
        HoistOption(int(h["level"]), int(h["footprint_tiles"]),
                    int(h["issues_per_core"]), int(h["tiles_per_issue"])))


def store_placement_to_dict(s: StorePlacement) -> Dict[str, Any]:
    return {"access": access_to_dict(s.access), "level": s.level,
            "issues_per_core": s.issues_per_core,
            "reduce_axes": list(s.reduce_axes),
            "reduce_style": s.reduce_style}


def store_placement_from_dict(d: Dict[str, Any]) -> StorePlacement:
    return StorePlacement(access_from_dict(d["access"]), int(d["level"]),
                          int(d["issues_per_core"]),
                          reduce_axes=tuple(str(a) for a in
                                            d.get("reduce_axes", [])),
                          reduce_style=str(d.get("reduce_style", "")))


# --------------------------------------------------------------- plan
def plan_to_dict(p: DataflowPlan) -> Dict[str, Any]:
    return {
        "mapping": mapping_to_dict(p.mapping),
        "loads": [memop_to_dict(c) for c in p.loads],
        "stores": [store_placement_to_dict(s) for s in p.stores],
    }


def plan_from_dict(d: Dict[str, Any]) -> DataflowPlan:
    return DataflowPlan(
        mapping_from_dict(d["mapping"]),
        tuple(memop_from_dict(c) for c in d["loads"]),
        tuple(store_placement_from_dict(s) for s in d["stores"]))


# --------------------------------------------------------------- costs
_COST_FIELDS = ("total_s", "compute_s", "inner_load_s", "inner_store_s",
                "hoisted_s", "dram_bytes", "noc_bytes", "flops",
                "buffer_bytes", "utilization", "bound")


def cost_to_dict(c: PlanCost) -> Dict[str, Any]:
    return {f: getattr(c, f) for f in _COST_FIELDS}


def cost_from_dict(d: Dict[str, Any]) -> PlanCost:
    return PlanCost(**{f: d[f] for f in _COST_FIELDS})


_SIM_FIELDS = ("total_s", "dram_bytes", "noc_bytes", "flops", "n_waves",
               "wave_overhead_s", "n_wave_classes")
_SIM_DEFAULTS = {"n_wave_classes": 0}      # absent in pre-fast-search entries


def sim_to_dict(s: SimResult) -> Dict[str, Any]:
    return {f: getattr(s, f) for f in _SIM_FIELDS}


def sim_from_dict(d: Dict[str, Any]) -> SimResult:
    # only fields with an explicit default may be absent (older entries);
    # anything else missing is a corrupt entry and should KeyError loudly
    return SimResult(**{f: d.get(f, _SIM_DEFAULTS[f]) if f in _SIM_DEFAULTS
                        else d[f] for f in _SIM_FIELDS})


# --------------------------------------------------------------- results
def candidate_to_dict(c: Candidate) -> Dict[str, Any]:
    return {"plan": plan_to_dict(c.plan), "cost": cost_to_dict(c.cost),
            "sim": sim_to_dict(c.sim) if c.sim is not None else None,
            # canonical (program, mapping, combo) stream index — what the
            # process-sharded search merges on (absent in older entries)
            "index": list(c.index) if c.index is not None else None}


def candidate_from_dict(d: Dict[str, Any]) -> Candidate:
    idx = d.get("index")
    return Candidate(plan_from_dict(d["plan"]), cost_from_dict(d["cost"]),
                     sim_from_dict(d["sim"]) if d.get("sim") else None,
                     index=tuple(int(i) for i in idx) if idx else None)


def graph_plan_to_dict(g) -> Dict[str, Any]:
    """Serialize a :class:`repro.pipeline.planner.GraphPlan` (imported
    duck-typed so the plancache package keeps zero import-time dependency
    on the pipeline subsystem)."""
    return {
        "graph_name": g.graph_name,
        "hw_name": g.hw_name,
        "nodes": {name: candidate_to_dict(c) for name, c in g.nodes.items()},
        "decisions": [{
            "src": d.src, "dst": d.dst, "tensor": d.tensor,
            "forwarded": d.forwarded,
            "shuffle_axes": list(d.shuffle_axes),
            "resident_bytes": d.resident_bytes,
        } for d in g.decisions],
        "node_sims": {name: sim_to_dict(s)
                      for name, s in g.node_sims.items()},
        "total_s": g.total_s,
        "baseline_s": g.baseline_s,
        "dram_roundtrip_s": g.dram_roundtrip_s,
        "plan_seconds": g.plan_seconds,
        "n_graph_combos": g.n_graph_combos,
        "n_graph_pruned": g.n_graph_pruned,
        "n_forwardable_pairs": g.n_forwardable_pairs,
        "n_pairs": g.n_pairs,
        "log": list(g.log),
    }


def graph_plan_from_dict(d: Dict[str, Any]):
    from repro.pipeline.planner import EdgeDecision, GraphPlan
    return GraphPlan(
        graph_name=d["graph_name"], hw_name=d["hw_name"],
        nodes={name: candidate_from_dict(c)
               for name, c in d["nodes"].items()},
        decisions=tuple(EdgeDecision(
            e["src"], e["dst"], e["tensor"], forwarded=bool(e["forwarded"]),
            shuffle_axes=tuple(str(a) for a in e["shuffle_axes"]),
            resident_bytes=int(e["resident_bytes"])) for e in d["decisions"]),
        node_sims={name: sim_from_dict(s)
                   for name, s in d["node_sims"].items()},
        total_s=float(d["total_s"]),
        baseline_s=float(d["baseline_s"]),
        dram_roundtrip_s=float(d["dram_roundtrip_s"]),
        plan_seconds=float(d["plan_seconds"]),
        n_graph_combos=int(d.get("n_graph_combos", 0)),
        n_graph_pruned=int(d.get("n_graph_pruned", 0)),
        n_forwardable_pairs=int(d.get("n_forwardable_pairs", 0)),
        n_pairs=int(d.get("n_pairs", 0)),
        log=[str(x) for x in d.get("log", [])])


def result_to_dict(r: PlanResult) -> Dict[str, Any]:
    return {
        "kernel": r.kernel,
        "hw_name": r.hw_name,
        "best": candidate_to_dict(r.best),
        "topk": [candidate_to_dict(c) for c in r.topk],
        "n_candidates": r.n_candidates,
        "n_mappings": r.n_mappings,
        "plan_seconds": r.plan_seconds,
        "log": list(r.log),
        "n_pruned": r.n_pruned,
        "n_estimated": r.n_estimated,
        "n_mappings_pruned": r.n_mappings_pruned,
        "n_wave_classes": r.n_wave_classes,
        "n_infeasible_programs": r.n_infeasible_programs,
    }


def result_from_dict(d: Dict[str, Any]) -> PlanResult:
    return PlanResult(
        kernel=d["kernel"], hw_name=d["hw_name"],
        best=candidate_from_dict(d["best"]),
        topk=[candidate_from_dict(c) for c in d["topk"]],
        n_candidates=int(d["n_candidates"]),
        n_mappings=int(d["n_mappings"]),
        plan_seconds=float(d["plan_seconds"]),
        log=[str(x) for x in d.get("log", [])],
        n_pruned=int(d.get("n_pruned", 0)),
        n_estimated=int(d.get("n_estimated", 0)),
        n_mappings_pruned=int(d.get("n_mappings_pruned", 0)),
        n_wave_classes=int(d.get("n_wave_classes", 0)),
        n_infeasible_programs=int(d.get("n_infeasible_programs", 0)))
