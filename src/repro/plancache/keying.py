"""Content-addressed keys for cached dataflow plans.

A cache key is the SHA-256 digest of a canonical-JSON *signature* built
from three ingredients (ISSUE: keying):

1. the planning request — either the full canonical program signature(s)
   (``program_to_dict``) or, for the request-level shape tables of
   ``lower_jax``, the request template + shape parameters;
2. the hardware — the digest of ``HardwareModel.df_text()``, so editing a
   preset (bandwidths, mesh, memory sizes) invalidates every plan computed
   against it;
3. :data:`SCHEMA_VERSION` plus the full :class:`SearchBudget` and search
   flags, so changing the planner's search or serialization format
   invalidates stale entries automatically.

Bump :data:`SCHEMA_VERSION` whenever the planner's search semantics, the
serialization layout, or the cost model change in a way that makes old
entries untrustworthy.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, Optional, Sequence

from repro.core.hw import HardwareModel
from repro.core.planner import SearchBudget
from repro.core.program import TileProgram

from .serialize import program_to_dict

# v2: spatial-reduction plan space — SpatialBind.reduce / Mapping.reduce_style
# / StorePlacement.reduce_axes+reduce_style entered the serialized layout and
# SearchBudget gained `spatial_reduction` (both change search semantics, so
# v1 entries must read as misses, never deserialize into wrong plans).
# v3: kernel-graph pipeline planning — graph-level entries (GraphPlan:
# per-node candidates + per-edge forward/spill decisions) joined the layout
# and SearchBudget gained `pipeline_forwarding`; v2 entries read as misses.
# v4: fault-overlay keys — HardwareModel grew disabled_cores/degraded_links
# and df_text() now emits `df.fault` lines, so a degraded fabric hashes to
# its own hw digest and the degraded-mesh re-plan ladder (runtime/replan)
# publishes plan pools under those keys; v3 entries read as misses.
SCHEMA_VERSION = 4


def canonical_json(obj: Any) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      default=str)


def digest_of(obj: Any) -> str:
    return hashlib.sha256(canonical_json(obj).encode()).hexdigest()


def hw_digest(hw: HardwareModel) -> str:
    """Digest of the full df description — the hardware side of every key."""
    return hashlib.sha256(hw.df_text().encode()).hexdigest()


def budget_signature(budget: Optional[SearchBudget]) -> Dict[str, Any]:
    if budget is None:
        budget = SearchBudget()
    sig = dataclasses.asdict(budget)
    # execution knobs that cannot change which plan wins (the sharded merge
    # is bit-identical to the inline search) must not invalidate entries —
    # a warm produced at --jobs 8 must serve a single-process consumer
    sig.pop("workers", None)
    return sig


def program_signature(program: TileProgram) -> Dict[str, Any]:
    return program_to_dict(program)


def kernel_key(programs: Sequence[TileProgram], hw: HardwareModel,
               budget: Optional[SearchBudget], *, profile: bool = True,
               spatial_reuse: bool = True, temporal_reuse: bool = True,
               entry: str = "kernel_multi") -> str:
    """Key for a ``plan_kernel`` / ``plan_kernel_multi`` invocation.

    ``entry`` separates the two planners' namespaces: they differ in search
    semantics (multi pools candidates, warm-starts, and trims programs) and
    in the ``kernel`` name they report, so a single-program ``plan_kernel``
    call must not resolve from a ``plan_kernel_multi`` entry or vice versa.
    """
    sig = {
        "schema": SCHEMA_VERSION,
        "kind": entry,
        "programs": [program_signature(p) for p in programs],
        "hw": hw_digest(hw),
        "budget": budget_signature(budget),
        "profile": profile,
        "spatial_reuse": spatial_reuse,
        "temporal_reuse": temporal_reuse,
    }
    return digest_of(sig)


def node_key(programs: Sequence[TileProgram]) -> str:
    """The request digest of one pipeline node's candidate-program list —
    the per-node building block :func:`graph_key` composes."""
    return digest_of([program_signature(p) for p in programs])


def graph_key(graph, hw: HardwareModel,
              budget: Optional[SearchBudget]) -> str:
    """Key for a pipeline co-planning invocation (``plan_pipeline``).

    Composed from the per-node keys (:func:`node_key` over each node's
    candidate programs) plus the edge list — so editing any node's
    block-shape candidates, rewiring an edge, renaming an intermediate, or
    changing the hardware/budget/schema all invalidate the graph entry,
    while two graphs sharing a node still share that node's key
    computation."""
    sig = {
        "schema": SCHEMA_VERSION,
        "kind": "pipeline_graph",
        "graph": graph.name,
        "nodes": [[n.name, node_key(n.programs)] for n in graph.nodes],
        "edges": [[e.src, e.dst, e.tensor] for e in graph.edges],
        "hw": hw_digest(hw),
        "budget": budget_signature(budget),
    }
    return digest_of(sig)


def request_key(template: str, params: Dict[str, Any],
                hw: Optional[HardwareModel] = None,
                budget: Optional[SearchBudget] = None,
                extra: Optional[Dict[str, Any]] = None) -> str:
    """Key for a request-level table entry (``plan_gemm_blocks`` & co.):
    cheaper than :func:`kernel_key` because it never materializes the
    candidate programs, while still covering hardware + schema + budget."""
    sig = {
        "schema": SCHEMA_VERSION,
        "kind": "request",
        "template": template,
        "params": params,
        "hw": hw_digest(hw) if hw is not None else None,
        "budget": budget_signature(budget) if budget is not None else None,
    }
    if extra:
        sig["extra"] = extra
    return digest_of(sig)


def template_signature(program: TileProgram) -> str:
    """A shape-independent structural fingerprint of a kernel family: the
    tensor roles and the tile-op sequence, but no extents or tile shapes.
    Programs of the same template with different shapes are warm-start
    neighbors of each other."""
    sig = {
        "tensors": [[a.tensor.name, a.tensor.dtype_bytes, a.kind]
                    for a in program.loads + program.stores],
        "ops": [[o.kind, o.unit, o.segment] for o in program.body],
        "grid": [d.name for d in program.grid_dims],
        "seq": [d.name for d in program.seq_dims],
    }
    return digest_of(sig)[:16]


def shape_vector(program: TileProgram) -> list:
    """The shape coordinates used for warm-start nearest-neighbor distance:
    the global tensor extents in declaration order."""
    out: list = []
    for a in program.loads + program.stores:
        out.extend(int(s) for s in a.tensor.shape)
    return out


def bucket_extent(n: int, granule: int = 32) -> int:
    """Bucket a dim extent for shape-family keys: the smallest
    power-of-two multiple of ``granule`` that covers ``n``.  Extents at or
    below the granule collapse to one bucket (ragged tails of a tiled dim
    plan identically), and beyond it buckets double — so a family spans
    e.g. (2048, 4096] while staying tile-aligned."""
    n = max(1, int(n))
    g = max(1, int(granule))
    b = g
    while b < n:
        b *= 2
    return b


def family_signature(template: str, hw: str, shape: Sequence[int],
                     granule: int = 32) -> str:
    """Shape-family key: the template + hardware + *bucketed* shape vector.
    All requests whose dims fall in the same pow2-of-granule buckets share
    one family — the plan service's rung-2 candidates are the cached
    neighbors of the request's family (and adjacent ones via the store's
    log-distance ranking)."""
    sig = {
        "schema": SCHEMA_VERSION,
        "kind": "family",
        "template": template,
        "hw": hw,
        "buckets": [bucket_extent(s, granule) for s in shape],
    }
    return digest_of(sig)[:16]
