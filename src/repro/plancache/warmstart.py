"""Warm-starting the block-shape search from the nearest cached plan.

On a cache miss the planner still has to search, but the cache usually
holds a plan for the *same kernel template at a different shape* (e.g. the
4096-cubed GEMM when the miss is the 8192-cubed one).  The winning block
shape is strongly shape-correlated, so we seed the candidate ranking by
reordering the program list: candidates whose per-tensor tile shapes are
closest to the cached winner's come first.  Combined with
``SearchBudget.max_programs`` (the fast-search program cap) this turns the
neighbor into a real search-space prior instead of just a tie-break.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Mapping, Optional, Sequence

from repro.core.program import TileProgram


def tile_signature(program: TileProgram) -> Dict[str, List[int]]:
    """tensor name -> tile shape of its load/store; what a cache entry
    records about the winning block shape (``meta["tiles"]``)."""
    out: Dict[str, List[int]] = {}
    for a in program.loads + program.stores:
        out[a.tensor.name] = [int(s) for s in a.tile_shape]
    return out


def tile_distance(program: TileProgram,
                  hint_tiles: Mapping[str, Sequence[int]]) -> float:
    """Log-space distance between a candidate program's tile shapes and the
    hinted winner's, summed over the tensors they share."""
    getter = getattr(hint_tiles, "get", None)
    if getter is None:               # corrupt meta (list, scalar, ...)
        return float("inf")
    d = 0.0
    matched = 0
    for name, tile in tile_signature(program).items():
        hint = getter(name)
        if not isinstance(hint, (list, tuple)) or len(hint) != len(tile):
            continue
        try:
            hint = [int(h) for h in hint]
        except (TypeError, ValueError):
            continue
        matched += 1
        for x, y in zip(tile, hint):
            d += abs(math.log2(max(1, x) / max(1, y)))
    return d if matched else float("inf")


def order_programs(programs: Sequence[TileProgram],
                   hint_tiles: Optional[Mapping[str, Sequence[int]]]
                   ) -> List[TileProgram]:
    """Stable-sort candidate programs by proximity to the hinted tiles.
    With no usable hint — ``None``, empty, a corrupt non-mapping, zero or
    one candidates — the original order is preserved and nothing raises."""
    programs = list(programs)
    if len(programs) < 2 or not hint_tiles:
        return programs
    try:
        return sorted(programs, key=lambda p: tile_distance(p, hint_tiles))
    except Exception:  # noqa: BLE001 — ordering is a hint, never a failure
        return programs


def warm_order_from_store(store, template: str, hw_digest: str,
                          shape: Sequence[int],
                          programs: Sequence[TileProgram]
                          ) -> List[TileProgram]:
    """The full warm-start policy: find the nearest same-template entry on
    the same hardware, extract its winning tiles, record the warm-start in
    the store's stats, and reorder the candidates.  Both integration points
    (``PlanCache.order_programs`` and the ``lower_jax`` block tables) go
    through here so the policy has one implementation."""
    programs = list(programs)
    if not programs:
        return programs
    try:
        hint = store.nearest(template, hw_digest, shape)
    except Exception:  # noqa: BLE001 — an empty/corrupt store is not an error
        return programs
    if hint is None:
        return programs
    meta = hint.get("meta")
    payload = hint.get("payload")
    tiles = (meta.get("tiles") if isinstance(meta, Mapping) else None) or \
        (payload.get("tiles") if isinstance(payload, Mapping) else None)
    if not isinstance(tiles, Mapping) or not tiles:
        return programs
    store.note_warm_start()
    return order_programs(programs, tiles)
