"""High-level plan cache consumed by ``plan_kernel`` / ``plan_kernel_multi``.

The planner takes ``cache=`` as a duck-typed object (so ``repro.core``
never imports this package at module scope); :class:`PlanCache` is the
canonical implementation.  It maps full kernel-planning invocations to
serialized :class:`~repro.core.planner.PlanResult` payloads in the two-tier
store, and supplies warm-start program ordering on misses.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence

from repro.core.hw import HardwareModel
from repro.core.planner import PlanResult, SearchBudget
from repro.core.program import TileProgram
from repro.obs import metrics, trace

from . import keying, serialize, warmstart
from .store import PlanCacheStore, get_store
from .validate import validate_plan


def _note_cache_seconds(t0: float) -> None:
    """Attribute cache lookup/publish time to the unified per-phase
    breakdown (the same counter the planner's search phases land in)."""
    metrics.inc("planner_phase_seconds_total", time.perf_counter() - t0,
                phase="cache")


class PlanCache:
    """Content-addressed cache of full planner results."""

    def __init__(self, store: Optional[PlanCacheStore] = None) -> None:
        self._store = store

    @property
    def store(self) -> PlanCacheStore:
        return self._store if self._store is not None else get_store()

    # ------------------------------------------------------------ planner API
    def get_result(self, programs: Sequence[TileProgram], hw: HardwareModel,
                   budget: Optional[SearchBudget], *, profile: bool,
                   spatial_reuse: bool, temporal_reuse: bool,
                   entry: str = "kernel_multi") -> Optional[PlanResult]:
        t0 = time.perf_counter()
        with trace.span("plancache.get", cat="plancache", entry=entry):
            key = keying.kernel_key(programs, hw, budget, profile=profile,
                                    spatial_reuse=spatial_reuse,
                                    temporal_reuse=temporal_reuse,
                                    entry=entry)
            ent = self.store.get(key)
            _note_cache_seconds(t0)
            if ent is None:
                return None
            try:
                result = serialize.result_from_dict(ent["payload"]["result"])
            except (KeyError, TypeError, ValueError):
                # structurally valid JSON that doesn't deserialize into a
                # PlanResult is corruption, not a schema skew: quarantine it
                self.store.quarantine(key, "deserialize")
                return None
            bad = validate_plan(result.best.plan, hw)
            if bad:
                self.store.quarantine(key, "invalid_plan")
                return None
            return result

    def put_result(self, programs: Sequence[TileProgram], hw: HardwareModel,
                   budget: Optional[SearchBudget], result: PlanResult, *,
                   profile: bool, spatial_reuse: bool, temporal_reuse: bool,
                   entry: str = "kernel_multi") -> None:
        t0 = time.perf_counter()
        with trace.span("plancache.put", cat="plancache", entry=entry):
            key = keying.kernel_key(programs, hw, budget, profile=profile,
                                    spatial_reuse=spatial_reuse,
                                    temporal_reuse=temporal_reuse,
                                    entry=entry)
            best_prog = result.best.plan.program
            meta = {
                "template": keying.template_signature(best_prog),
                "shape": keying.shape_vector(best_prog),
                "hw": keying.hw_digest(hw),
                "hw_name": hw.name,
                "kernel": result.kernel,
                "tiles": warmstart.tile_signature(best_prog),
            }
            self.store.put(key, {"result": serialize.result_to_dict(result),
                                 "tiles": meta["tiles"]}, meta)
            _note_cache_seconds(t0)

    # ------------------------------------------------------- pipeline API
    def get_graph_result(self, graph, hw: HardwareModel,
                         budget: Optional[SearchBudget]):
        """Graph-level hit for ``repro.pipeline.plan_pipeline`` (schema-v3
        keys composed from the node program signatures + edge list)."""
        t0 = time.perf_counter()
        with trace.span("plancache.get_graph", cat="plancache",
                        graph=graph.name):
            key = keying.graph_key(graph, hw, budget)
            ent = self.store.get(key)
            _note_cache_seconds(t0)
            if ent is None:
                return None
            try:
                return serialize.graph_plan_from_dict(ent["payload"]["graph"])
            except (KeyError, TypeError, ValueError):
                self.store.quarantine(key, "deserialize")
                return None

    def put_graph_result(self, graph, hw: HardwareModel,
                         budget: Optional[SearchBudget], plan) -> None:
        t0 = time.perf_counter()
        with trace.span("plancache.put_graph", cat="plancache",
                        graph=graph.name):
            key = keying.graph_key(graph, hw, budget)
            meta = {
                "template": "pipeline_graph",
                "graph": graph.name,
                "shape": [len(n.programs) for n in graph.nodes],
                "hw": keying.hw_digest(hw),
                "hw_name": hw.name,
                "kernel": graph.name,
                "edges": [[e.src, e.dst, e.tensor] for e in graph.edges],
            }
            self.store.put(key, {"graph": serialize.graph_plan_to_dict(plan)},
                           meta)
            _note_cache_seconds(t0)

    def order_programs(self, programs: Sequence[TileProgram],
                       hw: HardwareModel) -> List[TileProgram]:
        """Warm-start hook: on a miss, reorder candidates around the nearest
        cached winner of the same template on the same hardware."""
        programs = list(programs)
        if not programs:
            return programs
        with trace.span("plancache.warm_order", cat="plancache",
                        n_programs=len(programs)):
            return warmstart.warm_order_from_store(
                self.store, keying.template_signature(programs[0]),
                keying.hw_digest(hw), keying.shape_vector(programs[0]),
                programs)
