"""Lowering TileLoom decisions to JAX/Pallas artifacts (the "back-end" edge).

On the paper's stack this is the hand-off from the dataflow-aware IR to the
vendor backend (TT-Metalium).  On TPU the hand-off has two levels
(DESIGN.md S3):

* **intra-chip** (this module): the planner runs on the single-chip df model
  (``tpu_v5e_chip``: VMEM = local scratchpad, MXU = df.mat) to choose Pallas
  ``BlockSpec`` shapes for the kernels — exactly the paper's block-level
  planning with VMEM capacity pruning and MXU alignment;
* **cross-chip** (``parallel/planner_bridge.py``): the planner runs on the
  pod-level df model to choose sharding layouts, whose "broadcasts" lower to
  XLA collectives.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax.numpy as jnp

from .hw import tpu_v5e_chip
from .planner import SearchBudget, plan_kernel_multi
from .program import flash_attention_program, matmul_program

MXU_GRANULE = 128          # MXU systolic dimension: blocks must be multiples
_CHIP_BUDGET = SearchBudget(top_k=1, max_plans_per_mapping=24,
                            max_mappings=16)


def _pow2_options(limit: int, lo: int = MXU_GRANULE, hi: int = 1024):
    out = []
    b = lo
    while b <= min(hi, max(lo, limit)):
        out.append(b)
        b *= 2
    return out or [lo]


@functools.lru_cache(maxsize=512)
def plan_gemm_blocks(M: int, N: int, K: int, dtype=jnp.bfloat16
                     ) -> Tuple[int, int, int]:
    """Choose (bm, bn, bk) for the GEMM kernel on one TPU chip.

    Enumerates MXU-aligned block shapes, builds the corresponding tile
    programs, and lets the TileLoom planner rank them on the chip df model
    (VMEM capacity pruning included).  Falls back to (128,128,128) when the
    problem is smaller than one MXU tile.
    """
    dbytes = jnp.dtype(dtype).itemsize
    progs = []
    for bm in _pow2_options(M, hi=512):
        for bn in _pow2_options(N, hi=512):
            for bk in _pow2_options(K, hi=512):
                progs.append(matmul_program(max(M, bm), max(N, bn), max(K, bk),
                                            bm=bm, bn=bn, bk=bk,
                                            dtype_bytes=dbytes))
    if not progs:
        return (MXU_GRANULE,) * 3
    hw = tpu_v5e_chip()
    # size blocks against VMEM (scratch) rather than HBM: swap local memory
    hw = _with_vmem_as_local(hw)
    try:
        res = plan_kernel_multi(progs, hw, budget=_CHIP_BUDGET, profile=False)
    except RuntimeError:
        return (MXU_GRANULE,) * 3
    loads = {c.access.tensor.name: c for c in res.best.plan.loads}
    bm, bk = loads["A"].access.tile_shape
    _, bn = loads["B"].access.tile_shape
    return (bm, bn, bk)


@functools.lru_cache(maxsize=512)
def plan_flash_blocks(Sq: int, Skv: int, d: int, dtype=jnp.bfloat16
                      ) -> Tuple[int, int]:
    """Choose (block_q, block_kv) for the FlashAttention kernel."""
    dbytes = jnp.dtype(dtype).itemsize
    progs = []
    for bq in _pow2_options(Sq, lo=128, hi=512):
        for bkv in _pow2_options(Skv, lo=128, hi=1024):
            progs.append(flash_attention_program(
                8, max(Sq, bq), max(Skv, bkv), d, bq=bq, bkv=bkv,
                dtype_bytes=dbytes))
    hw = _with_vmem_as_local(tpu_v5e_chip())
    try:
        res = plan_kernel_multi(progs, hw, budget=_CHIP_BUDGET, profile=False)
    except RuntimeError:
        return (128, 128)
    loads = {c.access.tensor.name: c for c in res.best.plan.loads}
    bq = loads["Q"].access.tile_shape[1]
    bkv = loads["K"].access.tile_shape[1]
    return (bq, bkv)


def _with_vmem_as_local(hw):
    """The chip model's planning 'local memory' is VMEM; its 'global' memory
    is the chip's HBM (already set up by tpu_v5e_chip)."""
    return hw
