"""Lowering TileLoom decisions to JAX/Pallas artifacts (the "back-end" edge).

On the paper's stack this is the hand-off from the dataflow-aware IR to the
vendor backend (TT-Metalium).  On TPU the hand-off has two levels
(DESIGN.md S3):

* **intra-chip** (this module): the planner runs on the single-chip df model
  (``tpu_v5e_chip``: VMEM = local scratchpad, MXU = df.mat) to choose Pallas
  ``BlockSpec`` shapes for the kernels — exactly the paper's block-level
  planning with VMEM capacity pruning and MXU alignment;
* **cross-chip** (``parallel/planner_bridge.py``): the planner runs on the
  pod-level df model to choose sharding layouts, whose "broadcasts" lower to
  XLA collectives.

Block choices are memoized at three tiers: ``functools.lru_cache``
(in-process), the plancache memory LRU, and the on-disk plan registry —
so a fresh process (or a pre-warmed AOT cache, ``python -m repro.plancache
warm``) resolves repeat shapes without invoking the planner at all.
"""
from __future__ import annotations

import functools
import logging
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro import plancache
from repro.obs import metrics
from repro.plancache import warmstart

from .hw import tpu_v5e_chip
from .planner import (SearchBudget, effective_budget, fast_search_enabled,
                      plan_kernel_multi)
from .program import flash_attention_program, matmul_program

log = logging.getLogger(__name__)

MXU_GRANULE = 128          # MXU systolic dimension: blocks must be multiples
_CHIP_BUDGET = SearchBudget(top_k=1, max_plans_per_mapping=24,
                            max_mappings=16)

# Planner failures that silently served the fallback block shape now land
# in the unified metrics registry (``planner_fallbacks_total{template=}``,
# repro.obs.metrics) so deployments notice a degraded planner in the same
# snapshot as every other planner signal.  One warning is logged per
# *distinct cause* — (template, failure message) — not per call; the count
# still rises on every event.
_FALLBACK_WARNED: set = set()


def _fallback_counter():
    return metrics.counter(
        "planner_fallbacks_total",
        "block-shape requests served the fallback after a planner failure")


def planner_fallback_count(template: str | None = None) -> int:
    """Fallback-block events since process start (or cache clear) — thin
    compat shim over ``planner_fallbacks_total`` in the metrics registry."""
    c = _fallback_counter()
    if template is not None:
        return int(c.value(template=template))
    return int(c.total())


def _note_fallback(template: str, shape, err, fallback) -> None:
    _fallback_counter().inc(template=template)
    cause = (template, str(err))
    if cause not in _FALLBACK_WARNED:
        _FALLBACK_WARNED.add(cause)
        log.warning("planner fallback for %s shape=%s: %s "
                    "(serving fallback blocks %s)", template, shape, err,
                    fallback)


def _pow2_options(limit: int, lo: int = MXU_GRANULE, hi: int = 1024):
    out = []
    b = lo
    while b <= min(hi, max(lo, limit)):
        out.append(b)
        b *= 2
    return out or [lo]


@functools.lru_cache(maxsize=1)
def _chip():
    """The single-chip df model and its content digest.  ``tpu_v5e_chip``
    already places VMEM as the planner's local memory and the chip's HBM as
    its global memory, so block sizing is VMEM-capacity-pruned by
    construction — no model rewriting needed here."""
    hw = tpu_v5e_chip()
    return hw, plancache.hw_digest(hw)


def _cached_blocks(template: str, params: dict, shape: Tuple[int, ...],
                   progs, fallback: Tuple[int, ...], pick) -> Tuple[int, ...]:
    """Shared request-level cache path for the block-shape tables.

    On a key hit the stored block tuple is returned without touching the
    planner.  On a miss the search is warm-started from the nearest cached
    shape of the same template, then the winning blocks (plus the full
    serialized :class:`PlanResult`) are persisted.
    """
    hw, hw_dig = _chip()
    budget = effective_budget(_CHIP_BUDGET)
    store = plancache.get_store()
    key = plancache.request_key(template, params, hw, budget)
    ent = store.get(key)
    if ent is not None:
        try:
            return tuple(int(b) for b in ent["payload"]["blocks"])
        except (KeyError, TypeError, ValueError):
            pass                     # malformed entry: fall through and re-plan
    if not progs:
        return fallback
    # warm-start ordering; plan_kernel_multi itself applies the
    # budget.max_programs trim to the reordered list
    progs = warmstart.warm_order_from_store(store, template, hw_dig, shape,
                                            progs)
    try:
        res = plan_kernel_multi(progs, hw, budget=budget, profile=False)
    except RuntimeError as e:
        # infeasible space (e.g. no tiling fits VMEM) — serve the safe
        # fallback, but never silently: count it and say which request
        _note_fallback(template, shape, e, fallback)
        return fallback
    blocks = pick(res)
    best_prog = res.best.plan.program
    # only the block tuple + the warm-start tile hint are persisted: the
    # hit path reads payload["blocks"] and nothing re-reads the full
    # PlanResult at this request-level tier (kernel-level PlanCache entries
    # carry the serialized result; these stay small so meta scans stay fast)
    store.put(key, {"blocks": list(blocks)},
              meta={"template": template, "shape": list(shape),
                    "hw": hw_dig, "hw_name": hw.name,
                    "blocks": list(blocks),
                    "tiles": warmstart.tile_signature(best_prog),
                    # cold-search efficiency counters (plan_speed / AOT
                    # tuning reports read these off the registry)
                    "search": {"plan_seconds": res.plan_seconds,
                               "n_candidates": res.n_candidates,
                               "n_estimated": res.n_estimated,
                               "n_pruned": res.n_pruned,
                               "n_mappings_pruned": res.n_mappings_pruned,
                               "n_infeasible_programs":
                                   res.n_infeasible_programs}})
    return blocks


def plan_gemm_blocks(M: int, N: int, K: int, dtype=jnp.bfloat16
                     ) -> Tuple[int, int, int]:
    """Choose (bm, bn, bk) for the GEMM kernel on one TPU chip.

    Enumerates MXU-aligned block shapes, builds the corresponding tile
    programs, and lets the TileLoom planner rank them on the chip df model
    (VMEM capacity pruning included).  Falls back to (128,128,128) when the
    problem is smaller than one MXU tile.
    """
    # the in-process memo must key on the fast-search env too (the disk key
    # covers it via the effective budget; an env flip mid-process would
    # otherwise serve blocks computed under the other budget)
    return _gemm_blocks_memo(M, N, K, dtype, fast_search_enabled())


@functools.lru_cache(maxsize=512)
def _gemm_blocks_memo(M: int, N: int, K: int, dtype, _fast: bool
                      ) -> Tuple[int, int, int]:
    dbytes = jnp.dtype(dtype).itemsize
    progs = []
    for bm in _pow2_options(M, hi=512):
        for bn in _pow2_options(N, hi=512):
            for bk in _pow2_options(K, hi=512):
                progs.append(matmul_program(max(M, bm), max(N, bn), max(K, bk),
                                            bm=bm, bn=bn, bk=bk,
                                            dtype_bytes=dbytes))

    def pick(res) -> Tuple[int, int, int]:
        loads = {c.access.tensor.name: c for c in res.best.plan.loads}
        bm, bk = loads["A"].access.tile_shape
        _, bn = loads["B"].access.tile_shape
        return (bm, bn, bk)

    return _cached_blocks("gemm_blocks",
                          {"M": M, "N": N, "K": K, "dbytes": dbytes},
                          (M, N, K), progs, (MXU_GRANULE,) * 3, pick)


def plan_flash_blocks(Sq: int, Skv: int, d: int, dtype=jnp.bfloat16
                      ) -> Tuple[int, int]:
    """Choose (block_q, block_kv) for the FlashAttention kernel."""
    return _flash_blocks_memo(Sq, Skv, d, dtype, fast_search_enabled())


@functools.lru_cache(maxsize=512)
def _flash_blocks_memo(Sq: int, Skv: int, d: int, dtype, _fast: bool
                       ) -> Tuple[int, int]:
    dbytes = jnp.dtype(dtype).itemsize
    progs = []
    for bq in _pow2_options(Sq, lo=128, hi=512):
        for bkv in _pow2_options(Skv, lo=128, hi=1024):
            progs.append(flash_attention_program(
                8, max(Sq, bq), max(Skv, bkv), d, bq=bq, bkv=bkv,
                dtype_bytes=dbytes))

    def pick(res) -> Tuple[int, int]:
        loads = {c.access.tensor.name: c for c in res.best.plan.loads}
        bq = loads["Q"].access.tile_shape[1]
        bkv = loads["K"].access.tile_shape[1]
        return (bq, bkv)

    return _cached_blocks("flash_blocks",
                          {"Sq": Sq, "Skv": Skv, "d": d, "dbytes": dbytes},
                          (Sq, Skv, d), progs, (128, 128), pick)


def clear_block_caches() -> None:
    """Drop the in-process memo tiers (tests use this to emulate a fresh
    process against a warm disk cache)."""
    _gemm_blocks_memo.cache_clear()
    _flash_blocks_memo.cache_clear()
    _fallback_counter().clear()
    _FALLBACK_WARNED.clear()


def reset_planner_fallbacks() -> None:
    """Re-arm the degraded-planner signal in a long-lived (serve) process.

    Clears the fallback counters together with *every* in-process block-memo
    tier — the ``lru_cache`` tables and the plancache memory LRU — so the
    next repeat shape re-resolves through the disk registry (or a fresh
    search) instead of a memo populated while the planner was failing.
    Without this, a shape that fell back once keeps serving the fallback
    blocks for the life of the process even after the underlying cause
    (e.g. a full cache volume, a bad preset edit) is fixed.
    """
    clear_block_caches()
    plancache.get_store().clear_memory()


def fused_pipeline_spec(graph_plan) -> Dict[str, object]:
    """Lower a co-planned kernel graph (:class:`repro.pipeline.GraphPlan`)
    to its Pallas realization plan.

    Consecutive nodes joined by *forwarded* edges collapse into one
    **fused/chained Pallas call**: the chain's kernels run as phases of a
    single ``pallas_call`` whose grid covers the producer then the consumer
    blocks, and each forwarded intermediate lives in a ``pltpu.VMEM``
    scratch ref (``scratch_shapes``) instead of materializing as an output
    — the consumer phase reads the scratch tile the producer phase wrote
    (exactly the distributed-L1 residency the mesh plan prices).  A
    *spilled* edge is a segment boundary: the intermediate materializes as
    a normal HBM output and the next segment is a separate call.

    Returns::

        {"segments": [{"nodes": [...],          # fused chain, in order
                       "scratch": [tensor...],  # intermediates kept on-chip
                       "shuffle": {tensor: axes}},  # NoC re-shuffle legs
                      ...],
         "materialized": [tensor...]}           # spilled intermediates
    """
    order = list(graph_plan.nodes)
    fwd_edges = {(d.src, d.dst): d for d in graph_plan.decisions
                 if d.forwarded}
    segments: list = []
    current = {"nodes": [order[0]], "scratch": [], "shuffle": {}}
    for prev, node in zip(order, order[1:]):
        d = fwd_edges.get((prev, node))
        if d is not None:
            current["nodes"].append(node)
            current["scratch"].append(d.tensor)
            if d.shuffle_axes:
                current["shuffle"][d.tensor] = list(d.shuffle_axes)
        else:
            segments.append(current)
            current = {"nodes": [node], "scratch": [], "shuffle": {}}
    segments.append(current)
    # forwarded skip-edges (src and dst non-adjacent but fused into the same
    # segment by the chain in between) keep their intermediate in scratch too
    for d in graph_plan.decisions:
        if not d.forwarded:
            continue
        for seg in segments:
            if d.src in seg["nodes"] and d.dst in seg["nodes"] \
                    and d.tensor not in seg["scratch"]:
                seg["scratch"].append(d.tensor)
                if d.shuffle_axes:
                    seg["shuffle"][d.tensor] = list(d.shuffle_axes)
    # a forwarded edge whose endpoints land in *different* segments (its
    # chain was cut by a spilled edge in between) cannot ride a scratch ref
    # across pallas_call boundaries — it must materialize like a spill
    in_scratch = {t for seg in segments for t in seg["scratch"]}
    return {
        "segments": segments,
        "materialized": [d.tensor for d in graph_plan.decisions
                         if not d.forwarded or d.tensor not in in_scratch],
    }


def splitk_pallas_spec(plan) -> Optional[Dict[str, object]]:
    """Lower a spatial-reduction plan to its Pallas realization.

    A ``reduce=True`` bind becomes one extra *accumulation* grid dimension of
    ``n_split`` steps whose output BlockSpec maps every step to the same
    output block (output revisiting — the ``moe_gmm``/``flash_decode``
    kernels' ``acc_ref`` pattern):

    * ``accum`` — accumulate into the revisited output block in place
      (``o_ref += partial`` guarded by ``pl.when`` on the first/last step);
    * ``tree``/``chain`` — emit per-split partials and let the wrapper
      combine them after the kernel (sum, or log-sum-exp for the
      flash-decode statistics), matching the owner-core combine the mesh
      plan performs over the NoC.

    Returns ``None`` for plans without reduce binds.
    """
    m = plan.mapping
    binds = m.reduce_binds()
    if not binds:
        return None
    b = binds[0]
    n_split = m.active_reduce_factor()
    return {
        "grid_dim": b.grid_dim,
        "n_split": int(n_split),
        "steps_per_split": int(m.seq_extent(b.grid_dim)),
        "style": m.reduce_style,
        "revisit_output": m.reduce_style == "accum",
        "combine": "add" if m.reduce_style == "accum" else "partials",
    }
