# TileLoom core: the paper's primary contribution — automatic dataflow
# planning for tile-based programs on spatial dataflow architectures.
#
# Pipeline (paper Fig 2):  program.py (front-end IR)  ->  mapping.py (S2.2)
# -> reuse.py (S2.3) -> plan.py (dataflow-aware IR) -> perfmodel.py (S2.5)
# -> planner.py (two-step top-k selection; simulator.py plays the hardware
# profiling stage) -> lower_jax.py (back-end handoff).
from .affine import AffineExpr, AffineMap, footprint_tiles
from .hw import (HardwareModel, MatUnit, Memory, VecUnit, get_hw, tpu_v5e_chip,
                 tpu_v5e_pod, wormhole, spyre_triple_ring, PRESETS)
from .mapping import Mapping, SpatialBind, TemporalLoop, enumerate_mappings
from .perfmodel import (BoundContext, PlanCost, body_compute_seconds, estimate,
                        pipelined_loop_time, plan_lower_bound)
from .plan import DataflowPlan, make_plan
from .batch_cost import HAVE_NUMPY, MappingBatch, simulate_plans
from .planner import (Candidate, PlanResult, SearchBudget, effective_budget,
                      fast_search_enabled, iter_plan_stream, plan_kernel,
                      plan_kernel_multi, resolve_engine)
from .program import (LoopDim, TensorSpec, TileAccess, TileOp, TileProgram,
                      block_shape_candidates, flash_attention_program,
                      flash_decode_program, fused_matmul_program,
                      matmul_program, moe_gmm_program, qk_matmul_program,
                      softmax_pv_program)
from .reuse import (ForwardLeg, HoistOption, MemOpChoice, ReuseInfo,
                    analyze_reuse, broadcast_options, edge_forward_demand,
                    enumerate_memop_choices, forward_resident_bytes,
                    memop_choices_with_stores, memop_demand, hoist_options)
from .simulator import SimResult, simulate, simulate_reference
from . import templates

__all__ = [
    "AffineExpr", "AffineMap", "footprint_tiles",
    "HardwareModel", "MatUnit", "Memory", "VecUnit", "get_hw", "PRESETS",
    "tpu_v5e_chip", "tpu_v5e_pod", "wormhole", "spyre_triple_ring",
    "Mapping", "SpatialBind", "TemporalLoop", "enumerate_mappings",
    "BoundContext", "PlanCost", "body_compute_seconds", "estimate",
    "pipelined_loop_time", "plan_lower_bound",
    "DataflowPlan", "make_plan",
    "Candidate", "PlanResult", "SearchBudget", "effective_budget",
    "fast_search_enabled", "iter_plan_stream", "plan_kernel",
    "plan_kernel_multi", "resolve_engine",
    "HAVE_NUMPY", "MappingBatch", "simulate_plans",
    "LoopDim", "TensorSpec", "TileAccess", "TileOp", "TileProgram",
    "block_shape_candidates", "flash_attention_program",
    "flash_decode_program", "fused_matmul_program", "matmul_program",
    "moe_gmm_program", "qk_matmul_program", "softmax_pv_program",
    "ForwardLeg", "HoistOption", "MemOpChoice", "ReuseInfo", "analyze_reuse",
    "broadcast_options", "edge_forward_demand", "enumerate_memop_choices",
    "forward_resident_bytes", "memop_choices_with_stores", "memop_demand",
    "hoist_options",
    "SimResult", "simulate", "simulate_reference", "templates",
]
