"""Tiny affine-expression algebra used by the TileLoom planner.

The paper's front-end "affinizes" all memory-address arithmetic: every load and
store address is an affine function of loop induction variables (tile indices
and intra-tile indices).  This module provides exactly the algebra the
planner's reuse analysis (paper S2.3) needs:

* ``AffineExpr``   — integer-linear combination of named dims plus a constant,
                     with optional ``mod``/``floordiv`` wrappers (needed for the
                     wrap-around links in the df interconnect maps, Listing 6).
* ``AffineMap``    — a tuple of exprs, mapping an index space to another.
* dependence tests — "does this access depend on dim d?" drives both spatial
                     and temporal reuse detection.
* footprints       — number of distinct tiles touched while a set of dims
                     ranges over their extents (drives hoisting buffer sizes).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Iterable, Mapping, Sequence, Tuple


@dataclass(frozen=True)
class AffineExpr:
    """``sum_i coeffs[d_i] * d_i + const``, optionally followed by mod/floordiv.

    ``mod`` and ``floordiv`` are applied (in that order: ``(e mod m) // f``)
    after the linear part; either may be ``None``.  This is enough to express
    every map in the paper's listings (e.g. ``(d0 + 1) mod 8`` for ring links
    and ``d0 ceildiv 4`` for DRAM-channel muxes — ceildiv is normalised to
    floordiv by the caller).
    """

    coeffs: Tuple[Tuple[str, int], ...] = ()
    const: int = 0
    mod: int | None = None
    floordiv: int | None = None

    # -- constructors -------------------------------------------------------
    @staticmethod
    def var(name: str, coeff: int = 1) -> "AffineExpr":
        return AffineExpr(coeffs=((name, coeff),))

    @staticmethod
    def const_expr(c: int) -> "AffineExpr":
        return AffineExpr(const=c)

    @staticmethod
    def linear(terms: Mapping[str, int], const: int = 0) -> "AffineExpr":
        items = tuple(sorted((k, v) for k, v in terms.items() if v != 0))
        return AffineExpr(coeffs=items, const=const)

    # -- algebra (only valid on pure-linear exprs) ---------------------------
    def _check_linear(self) -> None:
        if self.mod is not None or self.floordiv is not None:
            raise ValueError("operation only defined for pure-linear exprs")

    def __add__(self, other: "AffineExpr") -> "AffineExpr":
        self._check_linear(); other._check_linear()
        terms: Dict[str, int] = dict(self.coeffs)
        for k, v in other.coeffs:
            terms[k] = terms.get(k, 0) + v
        return AffineExpr.linear(terms, self.const + other.const)

    def __mul__(self, scalar: int) -> "AffineExpr":
        self._check_linear()
        return AffineExpr.linear({k: v * scalar for k, v in self.coeffs}, self.const * scalar)

    def with_mod(self, m: int) -> "AffineExpr":
        return AffineExpr(self.coeffs, self.const, mod=m, floordiv=self.floordiv)

    def with_floordiv(self, f: int) -> "AffineExpr":
        return AffineExpr(self.coeffs, self.const, mod=self.mod, floordiv=f)

    # -- queries -------------------------------------------------------------
    @property
    def dims(self) -> frozenset:
        # dependence tests are the single hottest query of the reuse
        # analysis (tens of thousands per planned kernel) — cache the dim
        # set on the frozen instance (extra slot never enters dataclass
        # eq/hash, which are generated from the declared fields)
        ds = self.__dict__.get("_dims")
        if ds is None:
            ds = frozenset(k for k, v in self.coeffs if v != 0)
            object.__setattr__(self, "_dims", ds)
        return ds

    def depends_on(self, dim: str) -> bool:
        return dim in self.dims

    def coeff_of(self, dim: str) -> int:
        for k, v in self.coeffs:
            if k == dim:
                return v
        return 0

    def evaluate(self, env: Mapping[str, int]) -> int:
        val = self.const + sum(v * env[k] for k, v in self.coeffs)
        if self.mod is not None:
            val = val % self.mod
        if self.floordiv is not None:
            val = val // self.floordiv
        return val

    def rename(self, renames: Mapping[str, str]) -> "AffineExpr":
        return AffineExpr(
            tuple(sorted((renames.get(k, k), v) for k, v in self.coeffs)),
            self.const, self.mod, self.floordiv)

    def substitute(self, dim: str, replacement: "AffineExpr") -> "AffineExpr":
        """Substitute ``dim := replacement`` (replacement must be linear)."""
        replacement._check_linear()
        c = self.coeff_of(dim)
        if c == 0:
            return self
        base = AffineExpr.linear(
            {k: v for k, v in self.coeffs if k != dim}, self.const)
        out = base + replacement * c
        return AffineExpr(out.coeffs, out.const, self.mod, self.floordiv)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        parts = [f"{v}*{k}" for k, v in self.coeffs] or []
        if self.const or not parts:
            parts.append(str(self.const))
        s = " + ".join(parts)
        if self.mod is not None:
            s = f"({s}) mod {self.mod}"
        if self.floordiv is not None:
            s = f"({s}) floordiv {self.floordiv}"
        return s


@dataclass(frozen=True)
class AffineMap:
    """A tuple of affine expressions: index-space -> index-space map."""

    exprs: Tuple[AffineExpr, ...]

    @staticmethod
    def from_terms(*term_dicts: Mapping[str, int]) -> "AffineMap":
        return AffineMap(tuple(AffineExpr.linear(t) for t in term_dicts))

    @staticmethod
    def identity(dims: Sequence[str]) -> "AffineMap":
        return AffineMap(tuple(AffineExpr.var(d) for d in dims))

    @property
    def dims(self) -> frozenset:
        ds = self.__dict__.get("_dims")
        if ds is None:
            ds = frozenset()
            for e in self.exprs:
                ds = ds | e.dims
            object.__setattr__(self, "_dims", ds)
        return ds

    def depends_on(self, dim: str) -> bool:
        return dim in self.dims

    def evaluate(self, env: Mapping[str, int]) -> Tuple[int, ...]:
        return tuple(e.evaluate(env) for e in self.exprs)

    def rename(self, renames: Mapping[str, str]) -> "AffineMap":
        return AffineMap(tuple(e.rename(renames) for e in self.exprs))

    def substitute(self, dim: str, replacement: AffineExpr) -> "AffineMap":
        return AffineMap(tuple(e.substitute(dim, replacement) for e in self.exprs))

    def __iter__(self):
        return iter(self.exprs)

    def __len__(self) -> int:
        return len(self.exprs)


def distinct_points(map_: AffineMap, extents: Mapping[str, int],
                    over: Iterable[str]) -> int:
    """Number of distinct output points of ``map_`` as dims in ``over`` range
    over ``[0, extents[d])`` (other dims held fixed at 0).

    Used to size hoisted buffers (paper S2.3: hoisting across a loop the access
    *depends on* enlarges the buffered region proportionally to that loop's
    extent; hoisting across an independent loop does not).  Exact enumeration —
    the planner only ever calls this with small tile-grid extents, never with
    element-level extents.
    """
    over = [d for d in over if map_.depends_on(d)]
    if not over:
        return 1
    total = 1
    for d in over:
        total *= extents[d]
    # Fast path: each ranging dim appears in exactly one expr, all exprs are
    # pure-linear, and within each expr the coefficients form a mixed-radix
    # system (|c_{i+1}| >= |c_i| * extent_i when sorted by |coeff|).  Then
    # every combination yields a distinct output point and the count is just
    # the product of extents.  This covers all maps the mapper constructs
    # (grid-index reconstruction is mixed-radix by design).
    if _is_mixed_radix(map_, extents, over):
        return total
    if total > 4_000_000:  # pragma: no cover - safety net for degenerate input
        raise ValueError(f"footprint enumeration too large: {total}")
    seen = set()
    ranges = [range(extents[d]) for d in over]
    env = {d: 0 for d in map_.dims}
    for point in itertools.product(*ranges):
        env.update(zip(over, point))
        seen.add(map_.evaluate(env))
    return len(seen)


def _is_mixed_radix(map_: AffineMap, extents: Mapping[str, int],
                    over: Sequence[str]) -> bool:
    over_set = set(over)
    seen_dims = set()
    for e in map_.exprs:
        if e.mod is not None or e.floordiv is not None:
            if e.dims & over_set:
                return False
            continue
        terms = [(d, abs(c)) for d, c in e.coeffs if d in over_set and c != 0]
        for d, _ in terms:
            if d in seen_dims:         # dim feeds two exprs: cannot decouple
                return False
            seen_dims.add(d)
        terms.sort(key=lambda t: t[1])
        bound = 1
        for d, c in terms:
            if c < bound:
                return False
            bound = c * extents[d]
    return True


def footprint_tiles(map_: AffineMap, extents: Mapping[str, int],
                    inner_dims: Sequence[str]) -> int:
    """Tiles that must be simultaneously live when a load of ``map_`` is hoisted
    above all of ``inner_dims`` (paper's hoisting rule, Listing 4).

    Memoized per map instance: the result depends only on the ranging dims
    the map reads and their extents, and the reuse analysis shares rewritten
    maps across mappings (``Mapping.rewrite_access``), so repeated hoists of
    the same access shape hit the cache."""
    key = tuple((d, extents[d]) for d in inner_dims if d in map_.dims)
    cache = map_.__dict__.get("_fp_cache")
    if cache is None:
        cache = {}
        object.__setattr__(map_, "_fp_cache", cache)
    hit = cache.get(key)
    if hit is None:
        hit = cache[key] = distinct_points(map_, extents, inner_dims)
    return hit
