"""Data-reuse analysis and memory-operation mapping (paper S2.3).

For a fixed spatiotemporal mapping the loop nest contains spatial loops
(``affine.parallel`` over core indices), temporal wave loops (``affine.for``)
and sequential loops (``scf.for``).  Every access is an affine function of
these indices, so:

* an access **independent of a spatial index** is *spatially reusable* along
  that hardware dim -> candidate for a NoC broadcast instead of per-core
  global loads;
* an access **independent of a temporal/sequential loop** is *temporally
  reusable* across it -> candidate for hoisting the load outward, buffering
  the tile(s) locally.

Hoisting rules (paper Listing 4): crossing a loop the access does *not*
depend on increases reuse at no buffer cost; crossing a loop it *does* depend
on multiplies the buffered footprint by that loop's extent.  Consequently the
only *meaningful* hoist points are "just above the j-th dependent loop,
maximally hoisted across independent loops" — crossing an independent loop is
free and strictly reduces traffic, so we canonicalize to those points (this
prunes plans that are dominated under the paper's own cost model, keeping the
design space exact w.r.t. distinguishable costs).
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .affine import AffineMap, footprint_tiles
from .hw import HardwareModel
from .mapping import Mapping
from .program import TileAccess


@dataclass(frozen=True)
class ReuseInfo:
    """Reuse annotations for one access under one mapping (the paper's
    "reuse annotations on the memory operations")."""
    access: TileAccess
    rewritten: AffineMap
    spatial_axes: Tuple[str, ...]       # hw dims along which tile is identical
    temporal_loops: Tuple[str, ...]     # temporal/seq loops it is independent of


@dataclass(frozen=True)
class HoistOption:
    """One canonical hoist point for a load.

    ``level`` indexes the temporal+sequential loop nest (0 = outside all of
    them, i.e. once per core; n = innermost).  ``footprint_tiles`` is the
    number of distinct tiles that must be simultaneously live;
    ``issues_per_core`` how many times the (bulk) load is issued per core;
    ``tiles_per_issue`` tiles moved per issue.
    """
    level: int
    footprint_tiles: int
    issues_per_core: int
    tiles_per_issue: int


@dataclass(frozen=True)
class MemOpChoice:
    """A concrete realization of one load: broadcast pattern + hoist point.

    ``bcast_axes`` is an *ordered* tuple of hw spatial dims (the order encodes
    the multi-dim broadcast realization, paper S2.3 "several concrete ways");
    empty tuple = direct per-core global load.  Annotations mirror Listing 5's
    ``{type=..., resources=...}``.
    """
    access: TileAccess
    bcast_axes: Tuple[str, ...]
    hoist: HoistOption

    @property
    def load_type(self) -> str:
        return "broadcast" if self.bcast_axes else "global"

    def resources(self, hw: HardwareModel) -> Tuple[str, ...]:
        res = ["dram"] if True else []
        for a in self.bcast_axes:
            ic = hw.interconnect_along(a)
            if ic is not None:
                res.append(ic.name)
        res.append("l1")
        return tuple(res)

    def annotate(self, hw: HardwareModel) -> str:
        res = ", ".join(f"%{r}" for r in self.resources(hw))
        return (f"load_{self.access.tensor.name} "
                f"{{type=\"{self.load_type}\", level={self.hoist.level}, "
                f"footprint_tiles={self.hoist.footprint_tiles}, "
                f"resources={{{res}}}}}")


@dataclass(frozen=True)
class StorePlacement:
    access: TileAccess
    level: int
    issues_per_core: int


# --------------------------------------------------------------------------
# Analysis
# --------------------------------------------------------------------------
def analyze_reuse(mapping: Mapping, hw: HardwareModel) -> Tuple[ReuseInfo, ...]:
    """Paper S2.3 "Reuse analysis on affine accesses"."""
    infos = []
    noc_axes = set(hw.noc_axes())
    t_loops = [t.name for t in mapping.temporal] + \
              [d.name for d in mapping.program.seq_dims]
    for acc in mapping.program.loads + mapping.program.stores:
        rewritten = mapping.rewrite_access(acc)
        sp = tuple(b.hw_dim for b in mapping.spatial
                   if not rewritten.depends_on(b.hw_dim) and b.hw_dim in noc_axes)
        tp = tuple(l for l in t_loops if not rewritten.depends_on(l))
        infos.append(ReuseInfo(acc, rewritten, sp, tp))
    return tuple(infos)


def _nest_loops(mapping: Mapping) -> List[Tuple[str, int]]:
    """Temporal + sequential loops, outer -> inner (spatial excluded: those are
    parallel, not schedulable time)."""
    loops = [(t.name, t.extent) for t in mapping.temporal]
    loops += [(d.name, d.extent) for d in mapping.program.seq_dims]
    return loops


def hoist_options(info: ReuseInfo, mapping: Mapping) -> Tuple[HoistOption, ...]:
    """Canonical hoist points for one load (see module docstring).

    Enumerates, for j = 0..#dependent-loops, the point just above the j-th
    dependent loop counted from innermost, maximally hoisted across
    independent loops.  Footprints computed by exact affine enumeration.
    """
    loops = _nest_loops(mapping)
    n = len(loops)
    env = mapping.extents_env()
    dep = [info.rewritten.depends_on(name) for name, _ in loops]

    # candidate raw levels: innermost (n) and just-above each loop
    canonical: List[int] = []
    level = n
    while True:
        # hoist maximally across independent loops
        while level > 0 and not dep[level - 1]:
            level -= 1
        if level not in canonical:
            canonical.append(level)
        if level == 0:
            break
        level -= 1          # cross one dependent loop, then re-canonicalize

    out = []
    for lvl in canonical:
        inner = [name for name, _ in loops[lvl:]]
        fp = footprint_tiles(info.rewritten, env, inner)
        issues = 1
        for name, ext in loops[:lvl]:
            issues *= ext
        out.append(HoistOption(level=lvl, footprint_tiles=fp,
                               issues_per_core=issues, tiles_per_issue=fp))
    return tuple(out)


def broadcast_options(info: ReuseInfo) -> Tuple[Tuple[str, ...], ...]:
    """All legal broadcast patterns: every ordered arrangement of every subset
    of the spatially-reusable axes (paper: "from direct per-core global loads
    to one-dimensional and multi-dimensional broadcasts")."""
    axes = info.spatial_axes
    pats: List[Tuple[str, ...]] = [()]
    for r in range(1, len(axes) + 1):
        for sub in itertools.combinations(axes, r):
            for perm in itertools.permutations(sub):
                pats.append(perm)
    return tuple(dict.fromkeys(pats))


def store_placement(info: ReuseInfo, mapping: Mapping) -> StorePlacement:
    """Stores are issued at the deepest level whose inner loops are all
    independent of the store address (once per distinct output tile, after the
    reduction loops complete)."""
    loops = _nest_loops(mapping)
    n = len(loops)
    lvl = n
    while lvl > 0 and not info.rewritten.depends_on(loops[lvl - 1][0]):
        lvl -= 1
    issues = 1
    for name, ext in loops[:lvl]:
        issues *= ext
    return StorePlacement(info.access, lvl, issues)


def buffer_footprint_bytes(choices: Sequence[MemOpChoice],
                           stores: Sequence[StorePlacement],
                           mapping: Mapping) -> int:
    """Peak local-memory bytes implied by a set of choices: hoisted-load
    buffers (double-buffered when streamed at the innermost level), store
    staging tiles, and block accumulators."""
    n = len(_nest_loops(mapping))
    total = 0
    for c in choices:
        buf = c.hoist.footprint_tiles * c.access.tile_bytes
        if c.hoist.level == n:      # streamed in the innermost loop
            buf *= 2                # double buffering (paper Fig 4)
        total += buf
    for s in stores:
        total += s.access.tile_bytes
    total += mapping.program.accumulator_bytes()
    return total


def enumerate_memop_choices(
        mapping: Mapping, hw: HardwareModel, *,
        max_per_load: int = 12,
        capacity_fraction: float = 1.0) -> Tuple[Tuple[MemOpChoice, ...], ...]:
    """The per-mapping memory-operation design space: the cross product of
    (broadcast pattern x hoist point) over all loads, pruned by local-memory
    capacity (paper: "discards options whose footprint exceeds the capacity
    of the hardware model")."""
    infos = analyze_reuse(mapping, hw)
    load_infos = [i for i in infos if i.access.kind == "load"]
    store_infos = [i for i in infos if i.access.kind == "store"]
    stores = [store_placement(i, mapping) for i in store_infos]
    capacity = hw.local_capacity() * capacity_fraction

    sizes = dict(mapping.hw_dims)
    per_load: List[List[MemOpChoice]] = []
    for info in load_infos:
        opts = []
        for pat in broadcast_options(info):
            for h in hoist_options(info, mapping):
                opts.append(MemOpChoice(info.access, pat, h))
        # order by estimated per-core global traffic (issues x tiles, divided
        # by the broadcast replication factor) so that capped/truncated
        # enumeration explores the high-reuse region of the space first
        def _traffic(c: MemOpChoice) -> float:
            repl = math.prod(sizes[a] for a in c.bcast_axes) or 1
            return (c.hoist.issues_per_core * c.hoist.tiles_per_issue
                    * c.access.tile_bytes / repl)
        opts.sort(key=lambda c: (_traffic(c), c.hoist.footprint_tiles))
        per_load.append(opts[:max_per_load])

    plans = []
    for combo in itertools.product(*per_load):
        if buffer_footprint_bytes(combo, stores, mapping) <= capacity:
            plans.append(tuple(combo))
    return tuple(plans)
