"""Data-reuse analysis and memory-operation mapping (paper S2.3).

For a fixed spatiotemporal mapping the loop nest contains spatial loops
(``affine.parallel`` over core indices), temporal wave loops (``affine.for``)
and sequential loops (``scf.for``).  Every access is an affine function of
these indices, so:

* an access **independent of a spatial index** is *spatially reusable* along
  that hardware dim -> candidate for a NoC broadcast instead of per-core
  global loads;
* an access **independent of a temporal/sequential loop** is *temporally
  reusable* across it -> candidate for hoisting the load outward, buffering
  the tile(s) locally.

Hoisting rules (paper Listing 4): crossing a loop the access does *not*
depend on increases reuse at no buffer cost; crossing a loop it *does* depend
on multiplies the buffered footprint by that loop's extent.  Consequently the
only *meaningful* hoist points are "just above the j-th dependent loop,
maximally hoisted across independent loops" — crossing an independent loop is
free and strictly reduces traffic, so we canonicalize to those points (this
prunes plans that are dominated under the paper's own cost model, keeping the
design space exact w.r.t. distinguishable costs).
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .affine import AffineMap, footprint_tiles
from .hw import HardwareModel
from .mapping import Mapping
from .program import TileAccess


@dataclass(frozen=True)
class ReuseInfo:
    """Reuse annotations for one access under one mapping (the paper's
    "reuse annotations on the memory operations")."""
    access: TileAccess
    rewritten: AffineMap
    spatial_axes: Tuple[str, ...]       # hw dims along which tile is identical
    temporal_loops: Tuple[str, ...]     # temporal/seq loops it is independent of


@dataclass(frozen=True)
class HoistOption:
    """One canonical hoist point for a load.

    ``level`` indexes the temporal+sequential loop nest (0 = outside all of
    them, i.e. once per core; n = innermost).  ``footprint_tiles`` is the
    number of distinct tiles that must be simultaneously live;
    ``issues_per_core`` how many times the (bulk) load is issued per core;
    ``tiles_per_issue`` tiles moved per issue.
    """
    level: int
    footprint_tiles: int
    issues_per_core: int
    tiles_per_issue: int


@dataclass(frozen=True)
class MemOpChoice:
    """A concrete realization of one load: broadcast pattern + hoist point.

    ``bcast_axes`` is an *ordered* tuple of hw spatial dims (the order encodes
    the multi-dim broadcast realization, paper S2.3 "several concrete ways");
    empty tuple = direct per-core global load.  Annotations mirror Listing 5's
    ``{type=..., resources=...}``.
    """
    access: TileAccess
    bcast_axes: Tuple[str, ...]
    hoist: HoistOption

    @property
    def load_type(self) -> str:
        return "broadcast" if self.bcast_axes else "global"

    def resources(self, hw: HardwareModel) -> Tuple[str, ...]:
        res = ["dram"] if True else []
        for a in self.bcast_axes:
            ic = hw.interconnect_along(a)
            if ic is not None:
                res.append(ic.name)
        res.append("l1")
        return tuple(res)

    def annotate(self, hw: HardwareModel) -> str:
        res = ", ".join(f"%{r}" for r in self.resources(hw))
        return (f"load_{self.access.tensor.name} "
                f"{{type=\"{self.load_type}\", level={self.hoist.level}, "
                f"footprint_tiles={self.hoist.footprint_tiles}, "
                f"resources={{{res}}}}}")


@dataclass(frozen=True)
class ForwardLeg:
    """How one access of a kernel participates in an *inter-kernel* forwarded
    edge (the pipeline co-planner's on-chip handoff; DESIGN_PIPELINE.md).

    ``kind``:

    * ``"send"`` — a producer store pinned to the distributed local memories:
      the tile is written to the producing core's L1 instead of DRAM and stays
      resident until the consumer kernel runs;
    * ``"recv"`` — a consumer load served from the distributed local
      memories: the tile is read from the producing core's L1; when the two
      mappings' spatial digits disagree on ``shuffle_axes`` the tile
      additionally crosses one NoC ring per mismatched axis (the re-shuffle
      leg);
    * ``"free"`` — the access costs nothing (no time, no bytes, no
      contention).  Never a real dataflow: it is the admissible *floor* the
      graph-level branch-and-bound uses (any realizable edge handling —
      spilled or forwarded — prices the access at >= 0 on every resource,
      so the free-leg simulation lower-bounds them all).
    """
    tensor: str
    kind: str                          # "send" | "recv" | "free"
    shuffle_axes: Tuple[str, ...] = ()


def edge_forward_demand(access: TileAccess, mapping: Mapping,
                        shuffle_axes: Sequence[str], hw: HardwareModel
                        ) -> Tuple[Dict[str, float], float]:
    """Array-wide per-issue resource demand of one *forwarded* edge access
    (the on-chip analogue of :func:`memop_demand`): ``(demand, noc_bytes)``.

    A send touches the local memory once per active core; a recv touches it
    twice (remote read at the producer's L1 port + landing write at the
    consumer's) and moves the tile across one ring per mismatched spatial
    digit.  DRAM demand is zero by construction — that is the point of
    forwarding."""
    active = mapping.active_cores()
    tb = float(access.tile_bytes)
    demand: Dict[str, float] = {}
    noc_bytes = 0.0
    if access.kind == "store":
        demand["l1"] = tb * active
    else:
        demand["l1"] = 2.0 * tb * active
        for a in shuffle_axes:
            ic = hw.interconnect_along(a)
            if ic is None:
                continue
            leg = tb * active
            demand[ic.name] = demand.get(ic.name, 0.0) + leg
            noc_bytes += leg
    return demand, noc_bytes


def forward_resident_bytes(access: TileAccess, mapping: Mapping) -> int:
    """Per-core local-memory bytes the forwarded intermediate occupies while
    resident between the producer and consumer phases: the (padded) tile grid
    of the tensor, spread over the producer's active cores (each core keeps
    the tiles it produced)."""
    tiles = 1
    for dim, blk in zip(access.tensor.shape, access.tile_shape):
        tiles *= -(-dim // blk) if blk else 1
    per_core = -(-tiles // max(1, mapping.active_cores()))
    return per_core * access.tile_bytes


@dataclass(frozen=True)
class StorePlacement:
    """Where (and how) one store issues.

    ``reduce_axes`` names the mesh axes carrying ``reduce=True`` binds whose
    partial results this store must combine (empty = ordinary store);
    ``reduce_style`` is the mapping's combining style: ``accum`` =
    read-modify-write accumulation in global memory, ``tree``/``chain`` =
    partials forwarded over the axis NoC to an owner core (log-depth tree /
    neighbor chain) which performs the single final store.
    """
    access: TileAccess
    level: int
    issues_per_core: int
    reduce_axes: Tuple[str, ...] = ()
    reduce_style: str = ""


# --------------------------------------------------------------------------
# Analysis
# --------------------------------------------------------------------------
def analyze_reuse(mapping: Mapping, hw: HardwareModel) -> Tuple[ReuseInfo, ...]:
    """Paper S2.3 "Reuse analysis on affine accesses"."""
    infos = []
    noc_axes = set(hw.noc_axes())
    t_loops = [t.name for t in mapping.temporal] + \
              [d.name for d in mapping.program.seq_dims]
    for acc in mapping.program.loads + mapping.program.stores:
        rewritten = mapping.rewrite_access(acc)
        sp = tuple(b.hw_dim for b in mapping.spatial
                   if not rewritten.depends_on(b.hw_dim) and b.hw_dim in noc_axes)
        tp = tuple(l for l in t_loops if not rewritten.depends_on(l))
        infos.append(ReuseInfo(acc, rewritten, sp, tp))
    return tuple(infos)


def _nest_loops(mapping: Mapping) -> List[Tuple[str, int]]:
    """Temporal + sequential loops, outer -> inner (spatial excluded: those are
    parallel, not schedulable time).  Sequential extents are the *per-core*
    effective extents — reduce binds divide them (``Mapping.cost_loops``)."""
    return list(mapping.cost_loops())


def hoist_options(info: ReuseInfo, mapping: Mapping) -> Tuple[HoistOption, ...]:
    """Canonical hoist points for one load (see module docstring).

    Enumerates, for j = 0..#dependent-loops, the point just above the j-th
    dependent loop counted from innermost, maximally hoisted across
    independent loops.  Footprints computed by exact affine enumeration.
    """
    loops = _nest_loops(mapping)
    n = len(loops)
    env = mapping.extents_env()
    dep = [info.rewritten.depends_on(name) for name, _ in loops]

    # candidate raw levels: innermost (n) and just-above each loop
    canonical: List[int] = []
    level = n
    while True:
        # hoist maximally across independent loops
        while level > 0 and not dep[level - 1]:
            level -= 1
        if level not in canonical:
            canonical.append(level)
        if level == 0:
            break
        level -= 1          # cross one dependent loop, then re-canonicalize

    out = []
    for lvl in canonical:
        inner = [name for name, _ in loops[lvl:]]
        fp = footprint_tiles(info.rewritten, env, inner)
        issues = 1
        for name, ext in loops[:lvl]:
            issues *= ext
        out.append(HoistOption(level=lvl, footprint_tiles=fp,
                               issues_per_core=issues, tiles_per_issue=fp))
    return tuple(out)


def broadcast_options(info: ReuseInfo) -> Tuple[Tuple[str, ...], ...]:
    """All legal broadcast patterns: every ordered arrangement of every subset
    of the spatially-reusable axes (paper: "from direct per-core global loads
    to one-dimensional and multi-dimensional broadcasts")."""
    axes = info.spatial_axes
    pats: List[Tuple[str, ...]] = [()]
    for r in range(1, len(axes) + 1):
        for sub in itertools.combinations(axes, r):
            for perm in itertools.permutations(sub):
                pats.append(perm)
    return tuple(dict.fromkeys(pats))


def store_placement(info: ReuseInfo, mapping: Mapping) -> StorePlacement:
    """Stores are issued at the deepest level whose inner loops are all
    independent of the store address (once per distinct output tile, after the
    reduction loops complete).

    Under a spatial-reduction mapping the cores along each ``reduce=True``
    bind hold *partial sums* of the same output tile whenever the (rewritten)
    store address is independent of that axis; the placement then carries the
    axes and the mapping's combining style so the cost layers charge the
    partial-sum epilogue (accumulate-in-place vs forwarding + owner store).
    """
    loops = _nest_loops(mapping)
    n = len(loops)
    lvl = n
    while lvl > 0 and not info.rewritten.depends_on(loops[lvl - 1][0]):
        lvl -= 1
    issues = 1
    for name, ext in loops[:lvl]:
        issues *= ext
    red_axes = tuple(b.hw_dim for b in mapping.reduce_binds()
                     if not info.rewritten.depends_on(b.hw_dim))
    return StorePlacement(info.access, lvl, issues,
                          reduce_axes=red_axes,
                          reduce_style=mapping.reduce_style if red_axes
                          else "")


def memop_demand(c: MemOpChoice, mapping: Mapping, hw: HardwareModel
                 ) -> Tuple[Dict[str, float], float, float]:
    """Array-wide per-issue resource demand of one load realization.

    Returns ``(demand, dram_bytes, noc_bytes)`` where ``demand`` maps df
    resource names (``dram``, interconnect names, ``l1``) to bytes moved per
    issue summed over the whole core array.  This is the common currency of
    the analytic model's contention rule (perfmodel), the planner's
    branch-and-bound lower bound, and the dominance pruning below.
    """
    active = mapping.active_cores()
    bytes_per_core = c.access.tile_bytes * c.hoist.tiles_per_issue
    demand: Dict[str, float] = {}
    noc_bytes = 0.0
    if not c.bcast_axes:
        # direct per-core global load: every active core fetches its tiles
        dram = float(bytes_per_core * active)
        demand["dram"] = dram
        demand["l1"] = dram
    else:
        sizes = {a: s for a, s in mapping.hw_dims}
        repl = math.prod(sizes[a] for a in c.bcast_axes)
        producers = max(1, active // repl)
        demand["dram"] = float(bytes_per_core * producers)
        # staged multicast: along axis a_i, (s_i - 1) link-hops per receiving
        # plane; earlier stages fan out to progressively more planes
        planes = producers
        for a in c.bcast_axes:
            ic = hw.interconnect_along(a)
            s = sizes[a]
            leg = bytes_per_core * (s - 1) * planes
            if ic is not None:
                demand[ic.name] = demand.get(ic.name, 0.0) + leg
            noc_bytes += leg
            planes *= s
        demand["l1"] = float(bytes_per_core * active)  # every core lands a copy
    return demand, demand.get("dram", 0.0), noc_bytes


def buffer_footprint_bytes(choices: Sequence[MemOpChoice],
                           stores: Sequence[StorePlacement],
                           mapping: Mapping) -> int:
    """Peak local-memory bytes implied by a set of choices: hoisted-load
    buffers (double-buffered when streamed at the innermost level), store
    staging tiles (x2 for forwarding reductions: the owner stages an
    incoming partial next to its own accumulator), and block accumulators."""
    n = len(_nest_loops(mapping))
    total = 0
    for c in choices:
        buf = c.hoist.footprint_tiles * c.access.tile_bytes
        if c.hoist.level == n:      # streamed in the innermost loop
            buf *= 2                # double buffering (paper Fig 4)
        total += buf
    for s in stores:
        total += s.access.tile_bytes * _store_staging_tiles(s)
    total += mapping.program.accumulator_bytes()
    return total


def _store_staging_tiles(s: StorePlacement) -> int:
    """Forwarding reductions hold a receive buffer for the inbound partial
    alongside the local staging tile; plain and accumulate stores need one."""
    return 2 if s.reduce_style in ("tree", "chain") else 1


def _prune_dominated(opts: Sequence[MemOpChoice], mapping: Mapping,
                     hw: HardwareModel,
                     demands: Optional[Dict[int, tuple]] = None
                     ) -> List[MemOpChoice]:
    """Drop load realizations dominated on (dram_bytes, noc_bytes).

    Safety constraint (see DESIGN_SEARCHPERF.md): byte totals alone do not
    order *time* under either cost model — a hoist level changes overlap
    structure (inner streams pipeline with compute, hoisted transfers
    serialize), and equal byte totals can split differently across NoC
    rings.  So an option is pruned only when a same-hoist-level alternative
    is no worse on **every** per-resource demand (which subsumes dram/noc
    totals), no worse on buffer footprint, and strictly better somewhere —
    then the dominator wins at every composition of the analytic model and
    the pruned option can never be part of a distinguishable-best plan.
    Exact duplicates keep their first (stable-order) representative.
    """
    if demands is None:
        demands = {}
    for c in opts:
        if id(c) not in demands:
            demands[id(c)] = memop_demand(c, mapping, hw)
    infos = [(c, demands[id(c)]) for c in opts]
    keep: List[MemOpChoice] = []
    for i, (c, (dem_c, dram_c, noc_c)) in enumerate(infos):
        dominated = False
        for j, (a, (dem_a, dram_a, noc_a)) in enumerate(infos):
            if j == i or a.hoist.level != c.hoist.level:
                continue
            if a.hoist.footprint_tiles > c.hoist.footprint_tiles:
                continue
            res = set(dem_a) | set(dem_c)
            if any(dem_a.get(r, 0.0) > dem_c.get(r, 0.0) for r in res):
                continue
            strict = (dram_a < dram_c or noc_a < noc_c
                      or a.hoist.footprint_tiles < c.hoist.footprint_tiles
                      or any(dem_a.get(r, 0.0) < dem_c.get(r, 0.0)
                             for r in res))
            if strict or j < i:
                dominated = True
                break
        if not dominated:
            keep.append(c)
    return keep


def enumerate_memop_choices(
        mapping: Mapping, hw: HardwareModel, *,
        max_per_load: int = 12,
        capacity_fraction: float = 1.0) -> Tuple[Tuple[MemOpChoice, ...], ...]:
    """The per-mapping memory-operation design space: the cross product of
    (broadcast pattern x hoist point) over all loads, pruned by local-memory
    capacity (paper: "discards options whose footprint exceeds the capacity
    of the hardware model")."""
    combos, _ = memop_choices_with_stores(mapping, hw,
                                          max_per_load=max_per_load,
                                          capacity_fraction=capacity_fraction)
    return combos


def memop_choices_with_stores(
        mapping: Mapping, hw: HardwareModel, *,
        max_per_load: int = 12,
        capacity_fraction: float = 1.0,
        max_plans: Optional[int] = None,
        demands: Optional[Dict[int, tuple]] = None
) -> Tuple[Tuple[Tuple[MemOpChoice, ...], ...], Tuple[StorePlacement, ...]]:
    """As :func:`enumerate_memop_choices`, but also return the (per-mapping
    constant) store placements so streaming callers build plans without
    re-running reuse analysis per combo.

    ``max_plans`` is the caller's downstream combo-window size
    (``SearchBudget.max_plans_per_mapping``); dominance pruning only engages
    when the *unpruned* combo product fits inside it, so removing options can
    never shift which combos that window admits (see `_prune_dominated`).
    Without it (``None``) pruning stays off and the enumeration is exactly
    the historical one.

    ``demands``, when given an (empty) dict, is filled with
    ``id(option) -> memop_demand(option, ...)`` for every surviving option —
    the batched cost engine shares these with the dominance pruning instead
    of recomputing the demand model per option."""
    infos = analyze_reuse(mapping, hw)
    load_infos = [i for i in infos if i.access.kind == "load"]
    store_infos = [i for i in infos if i.access.kind == "store"]
    stores = tuple(store_placement(i, mapping) for i in store_infos)
    capacity = hw.local_capacity() * capacity_fraction

    sizes = dict(mapping.hw_dims)
    per_load: List[List[MemOpChoice]] = []
    for info in load_infos:
        opts = []
        for pat in broadcast_options(info):
            for h in hoist_options(info, mapping):
                opts.append(MemOpChoice(info.access, pat, h))
        # order by estimated per-core global traffic (issues x tiles, divided
        # by the broadcast replication factor) so that capped/truncated
        # enumeration explores the high-reuse region of the space first
        def _traffic(c: MemOpChoice) -> float:
            repl = math.prod(sizes[a] for a in c.bcast_axes) or 1
            return (c.hoist.issues_per_core * c.hoist.tiles_per_issue
                    * c.access.tile_bytes / repl)
        opts.sort(key=lambda c: (_traffic(c), c.hoist.footprint_tiles))
        per_load.append(opts[:max_per_load])

    # dominance pruning *after* the per-load truncation, and only when the
    # full (unpruned) combo product already fits the caller's downstream
    # window: then removal can never promote a previously-unexplored combo
    # into `combos[:max_plans]`, so the explored set stays a subset of the
    # historical one and only provably-no-better plans drop out
    if max_plans is not None and \
            math.prod(len(o) for o in per_load) <= max_plans:
        per_load = [_prune_dominated(opts, mapping, hw, demands)
                    if len(opts) > 1 else opts for opts in per_load]
    if demands is not None:
        for opts in per_load:
            for c in opts:
                if id(c) not in demands:
                    demands[id(c)] = memop_demand(c, mapping, hw)

    # combo capacity filter with per-option precomputed buffer contributions:
    # footprint = sum of per-load buffers (x2 when streamed innermost, paper
    # Fig 4) + store staging + accumulators — identical arithmetic to
    # buffer_footprint_bytes, hoisted out of the product loop
    n = len(_nest_loops(mapping))
    base = sum(s.access.tile_bytes * _store_staging_tiles(s) for s in stores) \
        + mapping.program.accumulator_bytes()
    per_load_buf = [
        [(c, c.hoist.footprint_tiles * c.access.tile_bytes
          * (2 if c.hoist.level == n else 1)) for c in opts]
        for opts in per_load]
    budget_left = capacity - base
    plans = []
    for combo in itertools.product(*per_load_buf):
        if sum(b for _, b in combo) <= budget_left:
            plans.append(tuple(c for c, _ in combo))
            if max_plans is not None and len(plans) >= max_plans:
                break       # caller only consumes combos[:max_plans]
    return tuple(plans), stores
