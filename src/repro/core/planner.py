"""End-to-end dataflow planner (paper S2.1 "two-step selection strategy").

Pipeline per kernel:

1. front-end block-shape exploration (``program_factory`` over candidate block
   shapes);
2. spatiotemporal mapping enumeration (S2.2);
3. memory-operation mapping: broadcast x hoist design space, capacity-pruned
   (S2.3);
4. analytic ranking with the performance model (S2.5) -> keep top-k;
5. "profiling": the event-driven simulator (the on-hardware stage stand-in,
   DESIGN.md S4) -> pick the final top-1.

``plan_kernel`` is the public entry point used by benchmarks and the JAX
lowering layer.
"""
from __future__ import annotations

import math
import os
import time
from dataclasses import dataclass, field, replace
from typing import Any, Callable, List, Optional, Sequence, Tuple

from .hw import HardwareModel
from .mapping import Mapping, enumerate_mappings
from .perfmodel import PlanCost, estimate
from .plan import DataflowPlan, make_plan
from .program import TileProgram
from .reuse import enumerate_memop_choices
from .simulator import SimResult, simulate


@dataclass
class Candidate:
    plan: DataflowPlan
    cost: PlanCost                       # analytic (ranking) cost
    sim: Optional[SimResult] = None      # "profiled" cost (top-k only)

    @property
    def final_s(self) -> float:
        return self.sim.total_s if self.sim is not None else self.cost.total_s


@dataclass
class PlanResult:
    kernel: str
    hw_name: str
    best: Candidate
    topk: List[Candidate]
    n_candidates: int
    n_mappings: int
    plan_seconds: float
    log: List[str] = field(default_factory=list)

    def summary(self) -> str:
        c = self.best
        lines = [
            f"kernel={self.kernel} hw={self.hw_name} "
            f"candidates={self.n_candidates} mappings={self.n_mappings} "
            f"plan_time={self.plan_seconds:.2f}s",
            f"  best: {c.plan.describe()}",
            f"  model: {c.cost.total_s * 1e6:.1f}us ({c.cost.tflops:.2f} TFLOP/s, "
            f"{c.cost.bound}-bound)  dram={c.cost.dram_bytes / 1e6:.1f}MB "
            f"noc={c.cost.noc_bytes / 1e6:.1f}MB",
        ]
        if c.sim:
            lines.append(f"  sim:   {c.sim.total_s * 1e6:.1f}us "
                         f"({c.sim.tflops:.2f} TFLOP/s)")
        return "\n".join(lines)


@dataclass
class SearchBudget:
    """Knobs bounding the search (paper Table 2 studies top-k; the others cap
    pathological spaces without changing small-space results)."""
    top_k: int = 5
    max_mappings: int = 256
    max_plans_per_mapping: int = 96
    max_candidates: int = 20000
    max_per_load: int = 12
    min_utilization: float = 0.0        # prune mappings below this (0 = keep all)
    pipeline_outer_levels: bool = False  # beyond-paper overlap (EXPERIMENTS SPerf)
    max_programs: int = 0               # cap block-shape candidates (0 = all);
                                        # honored by plan_kernel_multi after
                                        # warm-start ordering


FAST_SEARCH_ENV = "REPRO_FAST_SEARCH"

# invocation counters (tests and the plancache acceptance criteria assert a
# cache hit performs zero planner invocations)
PLAN_CALLS = {"plan_kernel": 0, "plan_kernel_multi": 0}


def fast_search_enabled() -> bool:
    return os.environ.get(FAST_SEARCH_ENV, "").lower() in ("1", "true", "on",
                                                           "yes")


def effective_budget(budget: Optional[SearchBudget] = None) -> SearchBudget:
    """Resolve the budget actually searched: the caller's budget, shrunk when
    ``REPRO_FAST_SEARCH=1`` (CI / test-latency knob).  Cache keys are computed
    from the *effective* budget so fast and full searches never collide."""
    b = budget or SearchBudget()
    if not fast_search_enabled():
        return b
    return replace(
        b,
        top_k=min(b.top_k, 2),
        max_mappings=min(b.max_mappings, 24),
        max_plans_per_mapping=min(b.max_plans_per_mapping, 12),
        max_candidates=min(b.max_candidates, 2000),
        max_per_load=min(b.max_per_load, 6),
        max_programs=min(b.max_programs, 16) if b.max_programs else 16)


def enumerate_plans(program: TileProgram, hw: HardwareModel,
                    budget: SearchBudget) -> Tuple[List[DataflowPlan], int]:
    mappings = enumerate_mappings(program, hw,
                                  max_candidates=budget.max_mappings)
    if budget.min_utilization > 0:
        best_u = max((m.utilization() for m in mappings), default=0.0)
        mappings = tuple(m for m in mappings
                         if m.utilization() >= budget.min_utilization * best_u)
    plans: List[DataflowPlan] = []
    for m in mappings:
        combos = enumerate_memop_choices(m, hw, max_per_load=budget.max_per_load)
        for loads in combos[:budget.max_plans_per_mapping]:
            plans.append(make_plan(m, loads, hw))
            if len(plans) >= budget.max_candidates:
                return plans, len(mappings)
    return plans, len(mappings)


def plan_kernel(program: TileProgram, hw: HardwareModel, *,
                budget: Optional[SearchBudget] = None,
                profile: bool = True,
                spatial_reuse: bool = True,
                temporal_reuse: bool = True,
                cache: Optional[Any] = None) -> PlanResult:
    """Run the full TileLoom pipeline for one program on one target.

    ``spatial_reuse`` / ``temporal_reuse`` disable the respective passes for
    the paper's ablations (Table 1 / Fig 8): with spatial reuse off every load
    is a per-core global load; with temporal reuse off every load stays at the
    innermost level.

    ``cache`` is a :class:`repro.plancache.PlanCache` (duck-typed); a hit
    returns the persisted result without searching, a miss stores the fresh
    result after planning.
    """
    budget = effective_budget(budget)
    if cache is not None:
        hit = cache.get_result([program], hw, budget, profile=profile,
                               spatial_reuse=spatial_reuse,
                               temporal_reuse=temporal_reuse, entry="kernel")
        if hit is not None:
            return hit
    PLAN_CALLS["plan_kernel"] += 1
    t0 = time.perf_counter()
    plans, n_mappings = enumerate_plans(program, hw, budget)
    plans = _apply_ablations(plans, spatial_reuse, temporal_reuse)
    if not plans:
        raise RuntimeError(f"no feasible plan for {program.name} on {hw.name} "
                           f"(local memory too small for any tiling?)")
    cands = [Candidate(p, estimate(p, hw,
                                   pipeline_outer_levels=budget.pipeline_outer_levels))
             for p in plans]
    cands.sort(key=lambda c: c.cost.total_s)
    topk = cands[:budget.top_k]
    if profile:
        for c in topk:
            c.sim = simulate(c.plan, hw)
        topk.sort(key=lambda c: c.final_s)
    best = topk[0]
    dt = time.perf_counter() - t0
    result = PlanResult(kernel=program.name, hw_name=hw.name, best=best,
                        topk=topk, n_candidates=len(cands),
                        n_mappings=n_mappings, plan_seconds=dt)
    if cache is not None:
        cache.put_result([program], hw, budget, result, profile=profile,
                         spatial_reuse=spatial_reuse,
                         temporal_reuse=temporal_reuse, entry="kernel")
    return result


def plan_kernel_multi(programs: Sequence[TileProgram], hw: HardwareModel, *,
                      budget: Optional[SearchBudget] = None,
                      profile: bool = True,
                      spatial_reuse: bool = True,
                      temporal_reuse: bool = True,
                      cache: Optional[Any] = None) -> PlanResult:
    """Front-end block-shape exploration (S2.1): plan every candidate program
    (one per block shape) and keep the global best.  Ranking pools candidates
    across programs before the top-k profiling cut, exactly as the paper's
    front-end + planner interact.

    With a ``cache``, a hit skips the search entirely; a miss warm-starts it
    by reordering the candidate programs around the nearest cached plan of
    the same kernel template (then ``budget.max_programs``, if set, trims
    the tail of the reordered list).
    """
    budget = effective_budget(budget)
    programs = list(programs)
    requested = programs                 # the cache key covers the full
    if cache is not None:                # requested candidate set, pre-trim
        hit = cache.get_result(requested, hw, budget, profile=profile,
                               spatial_reuse=spatial_reuse,
                               temporal_reuse=temporal_reuse)
        if hit is not None:
            return hit
        programs = cache.order_programs(programs, hw)
    if budget.max_programs and len(programs) > budget.max_programs:
        programs = programs[:budget.max_programs]
    PLAN_CALLS["plan_kernel_multi"] += 1
    t0 = time.perf_counter()
    all_c: List[Candidate] = []
    n_mappings = 0
    for prog in programs:
        try:
            plans, nm = enumerate_plans(prog, hw, budget)
        except Exception:
            continue
        n_mappings += nm
        plans = _apply_ablations(plans, spatial_reuse, temporal_reuse)
        for p in plans:
            all_c.append(Candidate(p, estimate(
                p, hw, pipeline_outer_levels=budget.pipeline_outer_levels)))
    if not all_c:
        raise RuntimeError("no feasible plan across any block shape")
    all_c.sort(key=lambda c: c.cost.total_s)
    topk = all_c[:budget.top_k]
    if profile:
        for c in topk:
            c.sim = simulate(c.plan, hw)
        topk.sort(key=lambda c: c.final_s)
    dt = time.perf_counter() - t0
    result = PlanResult(kernel=programs[0].name.split("_b")[0] if programs else "?",
                        hw_name=hw.name, best=topk[0], topk=topk,
                        n_candidates=len(all_c), n_mappings=n_mappings,
                        plan_seconds=dt)
    if cache is not None:
        cache.put_result(requested, hw, budget, result, profile=profile,
                         spatial_reuse=spatial_reuse,
                         temporal_reuse=temporal_reuse)
    return result


def _apply_ablations(plans: List[DataflowPlan], spatial: bool,
                     temporal: bool) -> List[DataflowPlan]:
    out = []
    for p in plans:
        if not spatial and any(c.bcast_axes for c in p.loads):
            continue
        if not temporal:
            n = len(p.mapping.temporal) + len(p.program.seq_dims)
            if any(c.hoist.level != n for c in p.loads):
                continue
        out.append(p)
    return out
