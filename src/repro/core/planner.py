"""End-to-end dataflow planner (paper S2.1 "two-step selection strategy").

Pipeline per kernel:

1. front-end block-shape exploration (``program_factory`` over candidate block
   shapes);
2. spatiotemporal mapping enumeration (S2.2);
3. memory-operation mapping: broadcast x hoist design space, capacity-pruned
   (S2.3);
4. analytic ranking with the performance model (S2.5) -> keep top-k;
5. "profiling": the event-driven simulator (the on-hardware stage stand-in,
   DESIGN.md S4) -> pick the final top-1.

Step 4 runs as **branch-and-bound over a streamed candidate space**
(DESIGN_SEARCHPERF.md): candidates are generated mapping by mapping, a cheap
admissible lower bound (:class:`~repro.core.perfmodel.BoundContext`) filters
plans that provably cannot enter the current top-k, and only the survivors
pay for a full :func:`~repro.core.perfmodel.estimate`.  Ties are broken by
stream order, so the selected top-k is bit-identical to ranking every
candidate and stable-sorting by model cost.

Two execution backends evaluate that candidate space (selection is
identical on either; see DESIGN_SEARCHPERF.md "Batched cost engine"):

* ``engine="batch"`` (default when numpy is available) — the
  structure-of-arrays engine (:mod:`repro.core.batch_cost`) bounds and
  estimates every combo of a mapping in vectorized numpy ops, bit-identical
  to the scalar model;
* ``engine="scalar"`` — the historical per-candidate loop (the oracle the
  equivalence tests compare against).

``plan_kernel_multi`` additionally shards its program list across a
process pool (``SearchBudget.workers`` / ``REPRO_PLANNER_WORKERS``,
default ``os.cpu_count()``; ``0``/``1`` = inline) — each worker ranks its
chunk and the per-program top-k are merged by (cost, canonical index), so
the result is bit-identical to the sequential search regardless of worker
count (``repro.parallel.search_exec``).

``plan_kernel`` is the public entry point used by benchmarks and the JAX
lowering layer.
"""
from __future__ import annotations

import heapq
import os
import time
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

from ..obs import metrics, trace
from . import batch_cost
from .hw import HardwareModel
from .mapping import Mapping, SpatialBind, enumerate_mappings
from .perfmodel import BoundContext, PlanCost, body_compute_seconds, estimate
from .plan import DataflowPlan
from .program import TileProgram
from .reuse import memop_choices_with_stores
from .simulator import SimResult, simulate


@dataclass
class Candidate:
    plan: DataflowPlan
    cost: PlanCost                       # analytic (ranking) cost
    sim: Optional[SimResult] = None      # "profiled" cost (top-k only)
    # canonical (program, mapping, combo) stream indices — the deterministic
    # tie-break key; carried explicitly so process-sharded searches merge
    # their per-chunk top-k exactly as the sequential stream order would
    index: Optional[Tuple[int, int, int]] = None

    @property
    def final_s(self) -> float:
        return self.sim.total_s if self.sim is not None else self.cost.total_s


@dataclass
class PlanResult:
    kernel: str
    hw_name: str
    best: Candidate
    topk: List[Candidate]
    n_candidates: int
    n_mappings: int
    plan_seconds: float
    log: List[str] = field(default_factory=list)
    # search-efficiency counters (benchmarks/plan_speed.py reports these)
    n_pruned: int = 0            # full estimates skipped via the lower bound
    n_estimated: int = 0         # candidates that paid a full estimate
    n_wave_classes: int = 0      # wave classes the best plan's profile costed
    n_mappings_pruned: int = 0   # whole mappings skipped by the floor bound
    n_infeasible_programs: int = 0

    def summary(self) -> str:
        c = self.best
        lines = [
            f"kernel={self.kernel} hw={self.hw_name} "
            f"candidates={self.n_candidates} mappings={self.n_mappings} "
            f"plan_time={self.plan_seconds:.2f}s "
            f"(estimated={self.n_estimated} bound-pruned={self.n_pruned})",
            f"  best: {c.plan.describe()}",
            f"  model: {c.cost.total_s * 1e6:.1f}us ({c.cost.tflops:.2f} TFLOP/s, "
            f"{c.cost.bound}-bound)  dram={c.cost.dram_bytes / 1e6:.1f}MB "
            f"noc={c.cost.noc_bytes / 1e6:.1f}MB",
        ]
        if c.sim:
            lines.append(f"  sim:   {c.sim.total_s * 1e6:.1f}us "
                         f"({c.sim.tflops:.2f} TFLOP/s, "
                         f"{c.sim.n_wave_classes}/{c.sim.n_waves} wave classes)")
        return "\n".join(lines)


@dataclass
class SearchBudget:
    """Knobs bounding the search (paper Table 2 studies top-k; the others cap
    pathological spaces without changing small-space results)."""
    top_k: int = 5
    max_mappings: int = 256
    max_plans_per_mapping: int = 96
    max_candidates: int = 20000
    max_per_load: int = 12
    min_utilization: float = 0.0        # prune mappings below this (0 = keep all)
    pipeline_outer_levels: bool = False  # beyond-paper overlap (EXPERIMENTS SPerf)
    max_programs: int = 0               # cap block-shape candidates (0 = all);
                                        # honored by plan_kernel_multi after
                                        # warm-start ordering
    # spatial-reduction (split-K) plan space: bind reduction dims to mesh
    # axes with partial-sum accumulate/forwarding epilogues.  Off restores
    # the parallel-only space (the reduction benchmarks' baseline column).
    spatial_reduction: bool = True
    # kernel-graph co-planning (repro.pipeline): allow producer->consumer
    # intermediates to be *forwarded* through the distributed on-chip
    # memories instead of spilled to DRAM.  Off restores fully independent
    # per-kernel planning (every edge pays the DRAM round trip) — the
    # pipeline benchmarks' `dram_roundtrip_us` baseline.  Ignored by the
    # single-kernel planners.
    pipeline_forwarding: bool = True
    # process-parallel search sharding (plan_kernel_multi): None = resolve
    # from REPRO_PLANNER_WORKERS (default os.cpu_count()); 0/1 = inline.
    # Selection-invariant, so it is excluded from plan-cache keys
    # (plancache.keying.budget_signature).
    workers: Optional[int] = None


FAST_SEARCH_ENV = "REPRO_FAST_SEARCH"
ENGINE_ENV = "REPRO_COST_ENGINE"        # "batch" (default) | "scalar"

# invocation counters (tests and the plancache acceptance criteria assert a
# cache hit performs zero planner invocations)
PLAN_CALLS = {"plan_kernel": 0, "plan_kernel_multi": 0}


def fast_search_enabled() -> bool:
    return os.environ.get(FAST_SEARCH_ENV, "").lower() in ("1", "true", "on",
                                                           "yes")


def resolve_engine(engine: Optional[str] = None) -> str:
    """The cost backend actually used: the caller's choice, else
    ``REPRO_COST_ENGINE``, else ``batch`` — degraded to ``scalar`` when
    numpy is unavailable.  Never part of cache keys: both engines select
    identical plans."""
    e = (engine or os.environ.get(ENGINE_ENV, "") or "batch").lower()
    if e not in ("batch", "scalar"):
        raise ValueError(f"unknown cost engine {e!r} (batch|scalar)")
    if e == "batch" and not batch_cost.HAVE_NUMPY:
        e = "scalar"
    return e


def effective_budget(budget: Optional[SearchBudget] = None) -> SearchBudget:
    """Resolve the budget actually searched: the caller's budget, shrunk when
    ``REPRO_FAST_SEARCH=1`` (CI / test-latency knob).  Cache keys are computed
    from the *effective* budget so fast and full searches never collide."""
    b = budget or SearchBudget()
    if not fast_search_enabled():
        return b
    # top_k floor of 3 (was 2): the model costs split-K twins against flat
    # plans close enough that the reduction winner routinely sits at rank
    # 2-3; profiling it is what lets fast-search CI runs still select it
    # (the wave-class simulator makes the extra profile essentially free)
    return replace(
        b,
        top_k=min(b.top_k, 3),
        max_mappings=min(b.max_mappings, 24),
        max_plans_per_mapping=min(b.max_plans_per_mapping, 12),
        max_candidates=min(b.max_candidates, 2000),
        max_per_load=min(b.max_per_load, 6),
        max_programs=min(b.max_programs, 16) if b.max_programs else 16)


def budget_for_deadline(budget: Optional[SearchBudget],
                        remaining_s: float) -> SearchBudget:
    """Trim a search budget to what is plausibly searchable in
    ``remaining_s`` seconds (the plan service's rung-3 knob).

    A deterministic step ladder, not a continuous scaler: the trimmed
    budget must be reproducible for cache keying and testing, so the
    remaining time only selects one of three fixed trim levels.  With ten
    seconds or more (or an unbounded deadline) the budget is returned
    unchanged — full-budget resolution through the service stays
    bit-identical to calling the planner directly.
    """
    b = effective_budget(budget)
    if remaining_s == float("inf") or remaining_s >= 10.0:
        return b
    if remaining_s >= 1.0:
        return replace(
            b,
            top_k=min(b.top_k, 3),
            max_mappings=min(b.max_mappings, 64),
            max_plans_per_mapping=min(b.max_plans_per_mapping, 24),
            max_candidates=min(b.max_candidates, 2000),
            max_programs=min(b.max_programs, 8) if b.max_programs else 8)
    if remaining_s >= 0.1:
        return replace(
            b,
            top_k=min(b.top_k, 2),
            max_mappings=min(b.max_mappings, 24),
            max_plans_per_mapping=min(b.max_plans_per_mapping, 12),
            max_candidates=min(b.max_candidates, 500),
            max_per_load=min(b.max_per_load, 6),
            max_programs=min(b.max_programs, 4) if b.max_programs else 4)
    return replace(
        b,
        top_k=1,
        max_mappings=min(b.max_mappings, 8),
        max_plans_per_mapping=min(b.max_plans_per_mapping, 4),
        max_candidates=min(b.max_candidates, 120),
        max_per_load=min(b.max_per_load, 4),
        max_programs=min(b.max_programs, 2) if b.max_programs else 2)


# --------------------------------------------------------------------------
# Streaming candidate generation
# --------------------------------------------------------------------------
def _swap_pairs(hw: HardwareModel) -> List[Tuple[str, str]]:
    """Mesh-dim pairs the hardware is exactly symmetric under: equal sizes,
    equal ring bandwidth, and a DRAM-channel map equivariant with the swap
    (channels permute consistently).  Relabeling such dims permutes cores
    and channels without changing any contention census, so a mapping and
    its swapped image cost bit-identically under both the analytic model
    and the wave-class simulator (the estimate memo's interconnect
    canonicalization rests on the same fact).  Cached per instance."""
    pairs = hw.__dict__.get("_swap_pairs")
    if pairs is not None:
        return pairs
    import itertools as _it
    dims = hw.mesh_dims
    scaleout = hw.core.scaleout
    disabled = hw.disabled_core_set()
    pairs = []
    for i in range(len(dims)):
        for j in range(i + 1, len(dims)):
            (d1, s1), (d2, s2) = dims[i], dims[j]
            if s1 != s2 or s1 <= 1:
                continue
            # a fault overlay breaks the swap symmetry unless the disabled
            # set is itself invariant under it (a hole at (3, 5) makes the
            # swapped mapping activate different physical cores)
            if disabled:
                i1, i2 = scaleout.index(d1), scaleout.index(d2)

                def _swapped(c, a=i1, b=i2):
                    c = list(c)
                    c[a], c[b] = c[b], c[a]
                    return tuple(c)

                if {_swapped(c) for c in disabled} != set(disabled):
                    continue
            ic1, ic2 = hw.interconnect_along(d1), hw.interconnect_along(d2)
            if (ic1 is None) != (ic2 is None):
                continue
            if ic1 is not None and ic1.bandwidth_gbps != ic2.bandwidth_gbps:
                continue
            perm: dict = {}
            ok = True
            for pt in _it.product(*[range(s) for _, s in dims]):
                env = dict(zip([d for d, _ in dims], pt))
                sw = dict(env)
                sw[d1], sw[d2] = env[d2], env[d1]
                ch, ch_sw = hw.channel_of_core(env), hw.channel_of_core(sw)
                if perm.setdefault(ch, ch_sw) != ch_sw:
                    ok = False
                    break
            if ok and len(set(perm.values())) == len(perm):
                pairs.append((d1, d2))
    hw.__dict__["_swap_pairs"] = pairs
    return pairs


def _dedup_twin_mappings(mappings: Tuple[Mapping, ...],
                         hw: HardwareModel) -> Tuple[Mapping, ...]:
    """Drop mappings that provably cost bit-identically to an earlier one:

    * binds to size-1 hardware dims without an interconnect (wormhole_1x8's
      ``x``, the TPU chip's ``u``) contribute digit 0 with any stride —
      every cost input (grid indices, reuse annotations, utilization, wave
      structure) is unchanged with or without them;
    * on meshes symmetric under a dim swap (:func:`_swap_pairs`, e.g. the
      8x8 Wormhole's ``x``/``y``), a mapping and its relabeled image are
      the same machine program on permuted cores.

    Twin candidates tie exactly, and ties already resolve to the earliest
    twin's canonical stream index, so skipping the later twins changes no
    selection — only the redundant enumeration and ranking work the
    estimate memo used to absorb one layer further down (~3x of the 1x8
    space, ~2x of the symmetric-mesh space).
    """
    ones = {d for d, s in hw.mesh_dims
            if s == 1 and hw.interconnect_along(d) is None}
    pairs = _swap_pairs(hw)
    if not ones and not pairs:
        return mappings

    def reduced(spatial):
        return tuple(b for b in spatial if b.hw_dim not in ones) \
            if ones else spatial

    seen = set()
    out = []
    for m in mappings:
        key = (reduced(m.spatial), m.temporal, m.reduce_style)
        if key in seen:
            continue
        dup = False
        for d1, d2 in pairs:
            swap = {d1: d2, d2: d1}
            sw_key = (tuple(SpatialBind(swap.get(b.hw_dim, b.hw_dim),
                                        b.hw_size, b.grid_dim, b.reduce)
                            for b in key[0]), m.temporal, m.reduce_style)
            if sw_key in seen:
                dup = True
                break
        if dup:
            continue
        seen.add(key)
        out.append(m)
    return tuple(out)


def _filtered_mappings(program: TileProgram, hw: HardwareModel,
                       budget: SearchBudget) -> Tuple[Mapping, ...]:
    mappings = _dedup_twin_mappings(
        enumerate_mappings(program, hw, max_candidates=budget.max_mappings,
                           allow_reduction=budget.spatial_reduction),
        hw)
    if budget.min_utilization > 0:
        best_u = max((m.utilization() for m in mappings), default=0.0)
        mappings = tuple(m for m in mappings
                         if m.utilization() >= budget.min_utilization * best_u)
    return tuple(mappings)


def iter_plan_stream(program: TileProgram, hw: HardwareModel,
                     budget: SearchBudget, *,
                     mappings: Optional[Sequence[Mapping]] = None
                     ) -> Iterator[Tuple[Mapping, DataflowPlan]]:
    """Stream candidate plans mapping by mapping (reuse analysis and store
    placement run once per mapping, not once per plan).  Honors the same
    ``max_plans_per_mapping`` / ``max_candidates`` truncation — and yields in
    the same order — as the historical list-building enumeration.
    ``mappings`` lets callers that already enumerated the (budget-filtered)
    mapping space avoid re-enumerating it."""
    if mappings is None:
        mappings = _filtered_mappings(program, hw, budget)
    n = 0
    for mapping in mappings:
        combos, stores = memop_choices_with_stores(
            mapping, hw, max_per_load=budget.max_per_load)
        for combo in combos[:budget.max_plans_per_mapping]:
            yield mapping, DataflowPlan(mapping, combo, stores)
            n += 1
            if n >= budget.max_candidates:
                return


def enumerate_plans(program: TileProgram, hw: HardwareModel,
                    budget: SearchBudget) -> Tuple[List[DataflowPlan], int]:
    """Materialized form of :func:`iter_plan_stream` (kept for callers that
    want the full list; the planner itself streams)."""
    mappings = _filtered_mappings(program, hw, budget)
    plans = [p for _, p in iter_plan_stream(program, hw, budget,
                                            mappings=mappings)]
    return plans, len(mappings)


def _combo_ablation_ok(mapping: Mapping, combo, spatial: bool,
                       temporal: bool) -> bool:
    """The ablation filter at combo level — the single predicate both cost
    engines apply, so their candidate sets cannot diverge."""
    if not spatial and any(c.bcast_axes for c in combo):
        return False
    if not temporal:
        n = len(mapping.temporal) + len(mapping.program.seq_dims)
        if any(c.hoist.level != n for c in combo):
            return False
    return True


def _ablation_ok(plan: DataflowPlan, spatial: bool, temporal: bool) -> bool:
    return _combo_ablation_ok(plan.mapping, plan.loads, spatial, temporal)


# --------------------------------------------------------------------------
# Branch-and-bound top-k ranking
# --------------------------------------------------------------------------
@dataclass
class _SearchStats:
    n_candidates: int = 0
    n_mappings: int = 0
    n_pruned: int = 0
    n_estimated: int = 0
    n_mappings_pruned: int = 0
    n_infeasible_programs: int = 0
    first_failure: str = ""
    # per-phase wall seconds (enumerate/estimate/bnb/simulate) accumulated
    # during the search and flushed once into the metrics registry by
    # _finish (workers ship theirs back through the chunk-result dict)
    phases: Dict[str, float] = field(default_factory=dict)

    def note_failure(self, msg: str) -> None:
        self.n_infeasible_programs += 1
        if not self.first_failure:
            self.first_failure = msg

    def add_phase(self, phase: str, seconds: float) -> None:
        self.phases[phase] = self.phases.get(phase, 0.0) + seconds

    def merge_phases(self, phases: Optional[Dict[str, float]]) -> None:
        for k, v in (phases or {}).items():
            self.phases[k] = self.phases.get(k, 0.0) + v


# tolerance on the prune test: the bound is mathematically <= the estimate,
# but both are float expressions; the margin keeps ulp-level rounding from
# ever discarding a true top-k member (costs this close are re-estimated)
_BOUND_SLACK = 1e-9

# smallest combo list worth the SoA setup: below this the scalar path (dict
# bounds + the cross-mapping estimate memo) is cheaper than building numpy
# tables.  Purely an execution choice — both paths produce bit-identical
# costs, so the threshold can never change the selected top-k.
_BATCH_MIN_COMBOS = 10


def _cost_signature(ctx: "BoundContext", plan: DataflowPlan,
                    transfers, pol: bool):
    """Exact memo key for :func:`estimate`: two plans with equal signatures
    produce bit-identical :class:`PlanCost` values.

    The signature captures every input the model reads — the loop nest, the
    per-transfer (level, per-resource demand, traffic, footprint) tuples,
    utilization/active-core counts, the program identity (body, flops,
    accumulators), and the overlap mode.  Interconnect names are canonicalized
    to (pool bandwidth, first-appearance rank), so plans isomorphic under a
    bandwidth-preserving ring renaming — e.g. the x<->y twins of a symmetric
    mesh, or mappings differing only in size-1 spatial binds — share one
    estimate instead of recomputing identical arithmetic."""
    ring_rank: dict = {}

    def canon(r):
        if r in ("dram", "l1"):
            return r
        got = ring_rank.get(r)
        if got is None:
            got = ring_rank[r] = (ctx.pools[r], len(ring_rank))
        return got

    tr_sig = tuple(
        (t.kind, t.level,
         tuple(sorted((str(canon(r)), b) for r, b in t.demand.items())),
         t.dram_bytes, t.noc_bytes)
        for t in transfers)
    buf_sig = tuple((c.hoist.footprint_tiles, c.access.tile_bytes,
                     c.hoist.level) for c in plan.loads)
    return (id(plan.program), tuple(ctx.loops), pol, ctx.utilization,
            ctx.active_cores, tr_sig, buf_sig)


def _rank_mapping_batch(p_idx: int, m_idx: int, mapping: Mapping, stores,
                        combos, hw: HardwareModel, budget: SearchBudget, *,
                        spatial_reuse: bool, temporal_reuse: bool,
                        use_bound: bool, heap: List[tuple],
                        stats: _SearchStats, demands=None) -> int:
    """Evaluate every combo of one mapping through the SoA batch engine and
    push survivors into the shared top-k heap.  Returns the number of
    candidates that contributed (streamed past the ablation filter).

    Heap semantics match the scalar loop exactly: candidates enter in
    canonical combo order with (cost, canonical-index) keys, so ties
    resolve identically.  The bound prune uses the k-th best *at mapping
    entry* — a (weakly) larger threshold than the scalar loop's
    per-candidate refresh, which can only prune less, never differently.
    """
    k = budget.top_k
    pol = budget.pipeline_outer_levels
    if spatial_reuse and temporal_reuse:
        ok_idx = list(range(len(combos)))
    else:
        ok_idx = [ci for ci, combo in enumerate(combos)
                  if _combo_ablation_ok(mapping, combo, spatial_reuse,
                                        temporal_reuse)]
    stats.n_candidates += len(ok_idx)
    if not ok_idx:
        return 0
    batch = batch_cost.MappingBatch(mapping, stores, hw,
                                    [combos[ci] for ci in ok_idx],
                                    pipeline_outer_levels=pol,
                                    demands=demands)
    np = batch_cost.np
    rows = np.arange(len(ok_idx))
    if use_bound and len(heap) >= k:
        worst = -heap[0][0]
        keep = batch.lower_bounds() <= worst * (1.0 + _BOUND_SLACK)
        stats.n_pruned += int((~keep).sum())
        rows = rows[keep]
    if not len(rows):
        return len(ok_idx)
    _t_est = time.perf_counter()
    with trace.span("planner.batch_estimate", n_rows=len(rows)):
        costs = batch.estimate_rows(rows)
    stats.add_phase("estimate", time.perf_counter() - _t_est)
    stats.n_estimated += len(rows)
    for j, r in enumerate(rows):
        c_idx = ok_idx[int(r)]
        total = float(costs.total[j])
        key = (-total, (-p_idx, -m_idx, -c_idx))
        if len(heap) >= k and not key > heap[0][:2]:
            continue
        cand = Candidate(DataflowPlan(mapping, combos[c_idx], stores),
                         costs.cost(j), index=(p_idx, m_idx, c_idx))
        item = key + (cand,)
        if len(heap) < k:
            heapq.heappush(heap, item)
        else:
            heapq.heapreplace(heap, item)
    return len(ok_idx)


def _rank_streamed(programs: Sequence[TileProgram], hw: HardwareModel,
                   budget: SearchBudget, *, spatial_reuse: bool,
                   temporal_reuse: bool, use_bound: bool,
                   catch_infeasible: bool, stats: _SearchStats,
                   engine: Optional[str] = None) -> List[Candidate]:
    """Rank the pooled candidate space of ``programs``, returning the top-k
    by (model cost, canonical stream order) — bit-identical to estimating
    every candidate and stable-sorting, but:

    * plans whose admissible lower bound already exceeds the current k-th
      best skip the full estimate;
    * whole mappings are skipped when their compute floor (``t_body x waves
      x inner iterations`` — the pipelined-loop formula is ``>= I*t_body``
      at every level) exceeds the k-th best; mappings are also *processed*
      in ascending-floor order so the heap converges before the bulk of the
      space streams by.  Candidates carry their canonical (program, mapping,
      combo) indices, so reordered processing still resolves cost ties
      exactly as the canonical stable sort would.  Both reorder and skip
      engage only when the ``max_candidates`` truncation provably cannot
      fire for the program (otherwise skipping would shift which plans the
      cap admits), which keeps the explored set identical;
    * bit-equal estimates (size-1-bind twins, symmetric-mesh x<->y twins)
      are shared through an exact cost-signature memo.
    """
    engine = resolve_engine(engine)
    if not spatial_reuse and budget.spatial_reduction:
        # the spatial-reuse ablation (paper Table 1) must also drop the
        # spatial-reduction space: partial-sum forwarding/accumulation is a
        # spatially-cooperative dataflow, so the "no spatial reuse" arm
        # would otherwise still contain cross-core plans
        budget = replace(budget, spatial_reduction=False)
    k = budget.top_k
    pol = budget.pipeline_outer_levels
    heap: List[tuple] = []   # (-cost, (-p, -m, -c), Candidate): max-heap
    est_memo: dict = {}
    # phase attribution: enumerate/estimate are timed directly; the branch-
    # and-bound residual (bounds, heap, memo lookups) is everything else
    # this function spends (observation only — never read back)
    _t_rank0 = time.perf_counter()
    _acc0 = (stats.phases.get("enumerate", 0.0)
             + stats.phases.get("estimate", 0.0))
    for p_idx, prog in enumerate(programs):
        contributed = 0
        # feasibility failures (validation, capacity, degenerate spaces)
        # raised by the *enumeration* layers drop the program but are
        # counted and surfaced; anything raised by the cost model — and any
        # non-(RuntimeError|ValueError) — is a planner bug and propagates
        try:
            _t_en = time.perf_counter()
            with trace.span("planner.enumerate", program=prog.name):
                mappings = _filtered_mappings(prog, hw, budget)
            stats.add_phase("enumerate", time.perf_counter() - _t_en)
        except (RuntimeError, ValueError) as e:
            if not catch_infeasible:
                raise
            stats.note_failure(f"{prog.name}: {e}")
            continue
        stats.n_mappings += len(mappings)
        cap_safe = (len(mappings) * budget.max_plans_per_mapping
                    <= budget.max_candidates)
        t_body = body_compute_seconds(mappings[0], hw) if mappings else 0.0
        # per-mapping inner iterations: split-K mappings divide the
        # sequential extents, so the compute floor must shrink with them
        # (it stays admissible: estimate >= t_body * prod(effective loops))
        floors = [t_body * m.n_waves() * m.inner_iters() for m in mappings]
        m_order: Sequence[int] = range(len(mappings))
        if use_bound and cap_safe:
            m_order = sorted(m_order, key=lambda i: floors[i])
        n_streamed = 0
        floor_pruned = 0
        for m_idx in m_order:
            mapping = mappings[m_idx]
            if use_bound and cap_safe and len(heap) >= k and \
                    floors[m_idx] > (-heap[0][0]) * (1.0 + _BOUND_SLACK):
                stats.n_mappings_pruned += 1
                floor_pruned += 1
                continue
            demands = {} if engine == "batch" else None
            try:
                _t_en = time.perf_counter()
                combos, stores = memop_choices_with_stores(
                    mapping, hw, max_per_load=budget.max_per_load,
                    max_plans=budget.max_plans_per_mapping, demands=demands)
                stats.add_phase("enumerate", time.perf_counter() - _t_en)
            except (RuntimeError, ValueError) as e:
                if not catch_infeasible:
                    raise
                if contributed == 0:      # else: partial program, keep plans
                    stats.note_failure(f"{prog.name}: {e}")
                    contributed = -1      # already counted infeasible
                elif not stats.first_failure:
                    stats.first_failure = f"{prog.name}: {e}"
                break                     # drop the rest of this program
            combos = combos[:budget.max_plans_per_mapping]
            if engine == "batch" and len(combos) >= _BATCH_MIN_COMBOS:
                room = budget.max_candidates - n_streamed
                take = combos[:room] if len(combos) > room else combos
                n_streamed += len(take)
                contributed += _rank_mapping_batch(
                    p_idx, m_idx, mapping, stores, take, hw, budget,
                    spatial_reuse=spatial_reuse,
                    temporal_reuse=temporal_reuse, use_bound=use_bound,
                    heap=heap, stats=stats, demands=demands)
                if n_streamed >= budget.max_candidates:
                    break
                continue
            ctx: Optional[BoundContext] = None
            for c_idx, combo in enumerate(combos):
                n_streamed += 1
                plan = DataflowPlan(mapping, combo, stores)
                if _ablation_ok(plan, spatial_reuse, temporal_reuse):
                    stats.n_candidates += 1
                    contributed += 1
                    if ctx is None:
                        ctx = BoundContext(mapping, stores, hw,
                                           pipeline_outer_levels=pol)
                    skip = False
                    if use_bound and len(heap) >= k:
                        worst = -heap[0][0]
                        if ctx.lower_bound(plan) > \
                                worst * (1.0 + _BOUND_SLACK):
                            stats.n_pruned += 1
                            skip = True
                    if not skip:
                        transfers = ctx.transfers_for(plan)
                        key = _cost_signature(ctx, plan, transfers, pol)
                        cost = est_memo.get(key)
                        if cost is None:
                            _t_est = time.perf_counter()
                            cost = estimate(plan, hw,
                                            pipeline_outer_levels=pol,
                                            transfers=transfers)
                            stats.add_phase(
                                "estimate", time.perf_counter() - _t_est)
                            est_memo[key] = cost
                            stats.n_estimated += 1
                        item = (-cost.total_s, (-p_idx, -m_idx, -c_idx),
                                Candidate(plan, cost,
                                          index=(p_idx, m_idx, c_idx)))
                        if len(heap) < k:
                            heapq.heappush(heap, item)
                        elif item > heap[0]:
                            heapq.heapreplace(heap, item)
                if n_streamed >= budget.max_candidates:
                    break
            if n_streamed >= budget.max_candidates:
                break
        # a program whose every mapping was skipped by the floor bound is
        # feasible (just provably worse than the top-k) — only count it
        # infeasible when nothing contributed *and* nothing was pruned
        if contributed == 0 and floor_pruned == 0 and catch_infeasible:
            stats.note_failure(f"{prog.name}: no feasible plan")
    _acc1 = (stats.phases.get("enumerate", 0.0)
             + stats.phases.get("estimate", 0.0))
    stats.add_phase("bnb", max(0.0, (time.perf_counter() - _t_rank0)
                               - (_acc1 - _acc0)))
    return [it[2] for it in sorted(
        heap, key=lambda it: (-it[0], -it[1][0], -it[1][1], -it[1][2]))]


def _flush_search_metrics(stats: _SearchStats, kernel: str,
                          plan_seconds: float) -> None:
    """Publish one completed search into the unified metrics registry
    (phases merged across worker shards; one flush per search)."""
    for phase, secs in sorted(stats.phases.items()):
        metrics.inc("planner_phase_seconds_total", secs, phase=phase)
    metrics.inc("planner_searches_total")
    metrics.inc("planner_candidates_total", stats.n_candidates)
    metrics.inc("planner_mappings_total", stats.n_mappings)
    metrics.inc("planner_estimated_total", stats.n_estimated)
    metrics.inc("planner_pruned_total", stats.n_pruned, kind="bound")
    metrics.inc("planner_pruned_total", stats.n_mappings_pruned,
                kind="mapping_floor")
    if stats.n_infeasible_programs:
        metrics.inc("planner_infeasible_programs_total",
                    stats.n_infeasible_programs)
    metrics.observe("planner_plan_seconds", plan_seconds, kernel=kernel)


def _finish(topk: List[Candidate], *, kernel: str, hw: HardwareModel,
            profile: bool, stats: _SearchStats, t0: float,
            engine: Optional[str] = None) -> PlanResult:
    if profile:
        _t_sim = time.perf_counter()
        with trace.span("planner.profile", kernel=kernel, n_topk=len(topk)):
            if resolve_engine(engine) == "batch":
                sims = batch_cost.simulate_plans([c.plan for c in topk], hw)
                for c, s in zip(topk, sims):
                    c.sim = s
            else:
                for c in topk:
                    c.sim = simulate(c.plan, hw)
        stats.add_phase("simulate", time.perf_counter() - _t_sim)
        topk.sort(key=lambda c: c.final_s)
    best = topk[0]
    log = []
    if stats.n_infeasible_programs:
        log.append(f"infeasible_programs={stats.n_infeasible_programs}")
    if stats.first_failure:
        log.append(f"first_failure: {stats.first_failure}")
    _flush_search_metrics(stats, kernel, time.perf_counter() - t0)
    return PlanResult(
        kernel=kernel, hw_name=hw.name, best=best, topk=topk,
        n_candidates=stats.n_candidates, n_mappings=stats.n_mappings,
        plan_seconds=time.perf_counter() - t0, log=log,
        n_pruned=stats.n_pruned, n_estimated=stats.n_estimated,
        n_wave_classes=best.sim.n_wave_classes if best.sim else 0,
        n_mappings_pruned=stats.n_mappings_pruned,
        n_infeasible_programs=stats.n_infeasible_programs)


def plan_kernel(program: TileProgram, hw: HardwareModel, *,
                budget: Optional[SearchBudget] = None,
                profile: bool = True,
                spatial_reuse: bool = True,
                temporal_reuse: bool = True,
                cache: Optional[Any] = None,
                use_bound: bool = True,
                engine: Optional[str] = None) -> PlanResult:
    """Run the full TileLoom pipeline for one program on one target.

    ``spatial_reuse`` / ``temporal_reuse`` disable the respective passes for
    the paper's ablations (Table 1 / Fig 8): with spatial reuse off every load
    is a per-core global load; with temporal reuse off every load stays at the
    innermost level.

    ``cache`` is a :class:`repro.plancache.PlanCache` (duck-typed); a hit
    returns the persisted result without searching, a miss stores the fresh
    result after planning.

    ``use_bound=False`` disables branch-and-bound pruning (every candidate is
    fully estimated — the exhaustive oracle the equivalence tests compare
    against; selections are identical either way).

    ``engine`` picks the cost backend (``"batch"``/``"scalar"``, see
    :func:`resolve_engine`); selection is identical on either, so the
    choice never enters cache keys.
    """
    trace.refresh_from_env()
    budget = effective_budget(budget)
    if cache is not None:
        hit = cache.get_result([program], hw, budget, profile=profile,
                               spatial_reuse=spatial_reuse,
                               temporal_reuse=temporal_reuse, entry="kernel")
        if hit is not None:
            return hit
    PLAN_CALLS["plan_kernel"] += 1
    t0 = time.perf_counter()
    stats = _SearchStats()
    with trace.span("planner.plan_kernel", kernel=program.name, hw=hw.name):
        topk = _rank_streamed([program], hw, budget,
                              spatial_reuse=spatial_reuse,
                              temporal_reuse=temporal_reuse,
                              use_bound=use_bound,
                              catch_infeasible=False, stats=stats,
                              engine=engine)
        if not topk:
            raise RuntimeError(
                f"no feasible plan for {program.name} on {hw.name} "
                f"(local memory too small for any tiling?)")
        result = _finish(topk, kernel=program.name, hw=hw,
                         profile=profile, stats=stats, t0=t0, engine=engine)
    if cache is not None:
        cache.put_result([program], hw, budget, result, profile=profile,
                         spatial_reuse=spatial_reuse,
                         temporal_reuse=temporal_reuse, entry="kernel")
    return result


def plan_kernel_multi(programs: Sequence[TileProgram], hw: HardwareModel, *,
                      budget: Optional[SearchBudget] = None,
                      profile: bool = True,
                      spatial_reuse: bool = True,
                      temporal_reuse: bool = True,
                      cache: Optional[Any] = None,
                      use_bound: bool = True,
                      engine: Optional[str] = None) -> PlanResult:
    """Front-end block-shape exploration (S2.1): plan every candidate program
    (one per block shape) and keep the global best.  Ranking pools candidates
    across programs before the top-k profiling cut, exactly as the paper's
    front-end + planner interact.

    Programs whose search raises a feasibility error (``RuntimeError`` /
    ``ValueError``: capacity, validation, degenerate spaces) or yields no
    plan are counted in ``PlanResult.n_infeasible_programs`` with the first
    failure message appended to ``PlanResult.log``; any other exception is a
    planner bug and propagates.

    With a ``cache``, a hit skips the search entirely; a miss warm-starts it
    by reordering the candidate programs around the nearest cached plan of
    the same kernel template (then ``budget.max_programs``, if set, trims
    the tail of the reordered list).

    With ``budget.workers`` (or ``REPRO_PLANNER_WORKERS``) above 1 the
    program list is sharded across a process pool
    (``repro.parallel.search_exec``); the merged result selects the exact
    top-k the inline search would, with search-efficiency counters
    (``n_pruned``/``n_estimated``...) reflecting the per-shard searches.
    """
    trace.refresh_from_env()
    budget = effective_budget(budget)
    programs = list(programs)
    requested = programs                 # the cache key covers the full
    if cache is not None:                # requested candidate set, pre-trim
        hit = cache.get_result(requested, hw, budget, profile=profile,
                               spatial_reuse=spatial_reuse,
                               temporal_reuse=temporal_reuse)
        if hit is not None:
            return hit
        programs = cache.order_programs(programs, hw)
    if budget.max_programs and len(programs) > budget.max_programs:
        programs = programs[:budget.max_programs]
    PLAN_CALLS["plan_kernel_multi"] += 1
    t0 = time.perf_counter()
    stats = _SearchStats()
    kernel = programs[0].name.split("_b")[0] if programs else "?"
    with trace.span("planner.plan_kernel_multi", kernel=kernel, hw=hw.name,
                    n_programs=len(programs)):
        topk = None
        if len(programs) > 1:
            from repro.parallel import search_exec
            workers = search_exec.resolve_workers(budget.workers)
            if workers > 1:
                topk = search_exec.rank_sharded(
                    programs, hw, budget, spatial_reuse=spatial_reuse,
                    temporal_reuse=temporal_reuse, use_bound=use_bound,
                    catch_infeasible=True, engine=engine, stats=stats,
                    workers=workers)
        if topk is None:                 # inline (workers<=1 or unshardable)
            topk = _rank_streamed(programs, hw, budget,
                                  spatial_reuse=spatial_reuse,
                                  temporal_reuse=temporal_reuse,
                                  use_bound=use_bound, catch_infeasible=True,
                                  stats=stats, engine=engine)
        if not topk:
            raise RuntimeError("no feasible plan across any block shape"
                               + (f" ({stats.first_failure})"
                                  if stats.first_failure else ""))
        result = _finish(topk, kernel=kernel, hw=hw,
                         profile=profile, stats=stats, t0=t0, engine=engine)
    if cache is not None:
        cache.put_result(requested, hw, budget, result, profile=profile,
                         spatial_reuse=spatial_reuse,
                         temporal_reuse=temporal_reuse)
    return result


def _apply_ablations(plans: List[DataflowPlan], spatial: bool,
                     temporal: bool) -> List[DataflowPlan]:
    return [p for p in plans if _ablation_ok(p, spatial, temporal)]
