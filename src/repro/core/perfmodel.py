"""Analytic performance model (paper S2.5).

Evaluates a :class:`DataflowPlan` hierarchically from the innermost loop
outward, exactly as the paper describes:

* **compute** — each tile op is decomposed onto its unit type; ``N``
  independent intrinsics on ``U`` units issuing ``r``/cycle cost
  ``N/(U*r)`` cycles; ops in the same dependence segment but on different
  unit types overlap (max), segments serialize (sum);
* **overlap** — the innermost loop runs as a double-buffered
  load-compute-store pipeline:
  ``T ~ (I-2)*max(Tl+Ts, Tc) + max(Tl,Tc) + max(Ts,Tc) + Tl + Ts``;
* **contention** — concurrent transfers are grouped by the ``df`` resources
  they occupy; each resource's nominal bandwidth is partitioned among its
  users, transfers on disjoint resources proceed in parallel
  (``T = max over resources of sum(demand)/bandwidth``).

The model deliberately stays coarse (the paper: "calibrated to be accurate
enough to distinguish compute-bound from memory-bound mappings") — the
event-driven ``simulator.py`` plays the role of the paper's on-hardware
profiling stage for the top-k candidates.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping as TMapping, Optional, Sequence, Tuple

from .hw import HardwareModel, Interconnect
from .mapping import Mapping as _Mapping
from .plan import DataflowPlan
from .reuse import (ForwardLeg, MemOpChoice, StorePlacement,
                    edge_forward_demand, memop_demand)


@dataclass(frozen=True)
class PlanCost:
    """Everything the ranking and the reports need."""
    total_s: float
    compute_s: float                    # pure compute time (body x iters)
    inner_load_s: float                 # per-innermost-iteration load time
    inner_store_s: float
    hoisted_s: float                    # serialized out-of-loop transfer time
    dram_bytes: float                   # total off-chip traffic (whole array)
    noc_bytes: float                    # total NoC traffic (whole array)
    flops: float
    buffer_bytes: int
    utilization: float
    bound: str                          # "compute" | "memory" | "noc"

    @property
    def tflops(self) -> float:
        return self.flops / self.total_s / 1e12 if self.total_s > 0 else 0.0


# --------------------------------------------------------------------------
# Compute cost of the innermost tile body (per core)
# --------------------------------------------------------------------------
def body_compute_seconds(plan_or_mapping, hw: HardwareModel) -> float:
    mapping = getattr(plan_or_mapping, "mapping", plan_or_mapping)
    prog = mapping.program
    core = hw.core
    clock_hz = hw.clock_ghz * 1e9
    segments: Dict[int, Dict[str, float]] = {}
    for op in prog.body:
        seg = segments.setdefault(op.segment, {})
        if op.unit == "mat":
            if core.mat is None:
                raise ValueError(f"{hw.name} has no matrix unit for op {op.kind}")
            n_intr = op.work / core.mat.flops_per_intrinsic
            cycles = n_intr / (core.mat.count * core.mat.intrinsics_per_cycle)
        elif op.unit == "vec":
            if core.vec is None:
                raise ValueError(f"{hw.name} has no vector unit for op {op.kind}")
            n_intr = op.work / core.vec.width
            cycles = n_intr / (core.vec.count * core.vec.intrinsics_per_cycle)
        else:
            lat = core.scalar.latency_cycles if core.scalar else 1.0
            cycles = op.work * lat
        seg[op.unit] = seg.get(op.unit, 0.0) + cycles
    total_cycles = 0.0
    for seg in segments.values():
        total_cycles += max(seg.values())      # unit types overlap in a segment
    return total_cycles / clock_hz


# --------------------------------------------------------------------------
# Memory-op timing with contention
# --------------------------------------------------------------------------
@dataclass
class _Transfer:
    """One memory operation instance at some loop level, with its aggregate
    (whole-array) demand on each df resource per issue."""
    name: str
    level: int
    kind: str                           # "load" | "store"
    demand: Dict[str, float]            # resource -> bytes per issue (array-wide)
    dram_bytes: float
    noc_bytes: float


def _resource_pools(hw: HardwareModel) -> Dict[str, float]:
    """Aggregate bandwidth pools (bytes/s)."""
    pools: Dict[str, float] = {}
    pools["dram"] = hw.global_mem.bandwidth_gbps * 1e9 * hw.global_channels()
    for ic in hw.interconnects:
        pools[ic.name] = ic.bandwidth_gbps * 1e9 * hw.links_of(ic)
    pools["l1"] = hw.local_mem.bandwidth_gbps * 1e9 * hw.n_cores
    return pools


def _load_transfer(c: MemOpChoice, mapping: _Mapping,
                   hw: HardwareModel) -> _Transfer:
    demand, dram_bytes, noc_bytes = memop_demand(c, mapping, hw)
    return _Transfer(c.access.label(), c.hoist.level, "load",
                     demand, dram_bytes, noc_bytes)


def forward_transfer(access, level: int, leg: ForwardLeg, mapping: _Mapping,
                     hw: HardwareModel, kind: str) -> _Transfer:
    """The :class:`_Transfer` of an access riding a forwarded inter-kernel
    edge (``pipeline`` co-planning): on-chip demand from
    :func:`~repro.core.reuse.edge_forward_demand`, zero DRAM.  A ``free``
    leg is the graph bound's zero-cost floor."""
    if leg.kind == "free":
        return _Transfer(access.label(), level, kind, {}, 0.0, 0.0)
    demand, noc_bytes = edge_forward_demand(access, mapping,
                                            leg.shuffle_axes, hw)
    return _Transfer(access.label(), level, kind, demand, 0.0, noc_bytes)


def _store_transfer(s: StorePlacement, mapping: _Mapping,
                    hw: HardwareModel) -> _Transfer:
    active = mapping.active_cores()
    tb = s.access.tile_bytes
    if s.reduce_axes:
        # spatial reduction: the cores along the reduce binds hold partial
        # sums of the same output tile
        r_act = mapping.active_reduce_factor()
        if s.reduce_style == "accum":
            # every partial read-modify-writes the tile in global memory
            bytes_all = 2.0 * tb * active
            demand = {"dram": bytes_all, "l1": float(tb * active)}
            return _Transfer(s.access.label(), s.level, "store", demand,
                             bytes_all, 0.0)
        # forwarding (tree/chain): each non-owner partial crosses the axis
        # NoC exactly once, in per-axis stages (the staged-multicast
        # accounting in reverse); only the owner core touches the store
        # path.  The demand model costs both styles identically (same bytes
        # on the same resources); the simulator's hop-depth term separates
        # them.
        owners = max(1, active // r_act)
        demand = {"dram": float(tb * owners)}
        noc_bytes = 0.0
        planes = active
        for a, digits in mapping.reduce_stages():
            if a not in s.reduce_axes:
                continue
            groups = max(1, planes // digits)
            leg = float(tb * (digits - 1) * groups)
            ic = hw.interconnect_along(a)
            if ic is not None:
                demand[ic.name] = demand.get(ic.name, 0.0) + leg
            noc_bytes += leg
            planes = groups
        demand["l1"] = float(tb * active)
        return _Transfer(s.access.label(), s.level, "store", demand,
                         float(tb * owners), noc_bytes)
    bytes_all = tb * active
    demand = {"dram": bytes_all, "l1": bytes_all}
    return _Transfer(s.access.label(), s.level, "store", demand, bytes_all, 0.0)


def _contended_time(transfers: Sequence[_Transfer],
                    pools: TMapping[str, float]) -> float:
    """Paper's contention rule: partition each resource's bandwidth among its
    users; disjoint resources in parallel -> makespan = max over resources of
    (total demand / bandwidth)."""
    if not transfers:
        return 0.0
    busy: Dict[str, float] = {}
    for t in transfers:
        for res, b in t.demand.items():
            busy[res] = busy.get(res, 0.0) + b / pools[res]
    # a free forwarded leg contributes a transfer with empty demand
    return max(busy.values(), default=0.0)


# --------------------------------------------------------------------------
# Pipelined loop formula (paper S2.5, Figure 4)
# --------------------------------------------------------------------------
def pipelined_loop_time(I: int, t_load: float, t_store: float,
                        t_body: float) -> float:
    if I <= 0:
        return 0.0
    if I == 1:
        return t_load + t_body + t_store
    steady = (I - 2) * max(t_load + t_store, t_body)
    return (steady + max(t_load, t_body) + max(t_store, t_body)
            + t_load + t_store)


# --------------------------------------------------------------------------
# End-to-end estimation
# --------------------------------------------------------------------------
def plan_transfers(plan: DataflowPlan, hw: HardwareModel, *,
                   fwd: Optional[TMapping[str, ForwardLeg]] = None
                   ) -> List[_Transfer]:
    """The plan's full transfer list — exactly what :func:`estimate`
    consumes.  ``fwd`` reroutes forwarded-edge accesses on-chip
    (reduce-combining stores never forward: the pipeline legality rule
    spills them, so their leg is ignored)."""
    m = plan.mapping
    if not fwd:
        return ([_load_transfer(c, m, hw) for c in plan.loads]
                + [_store_transfer(s, m, hw) for s in plan.stores])
    transfers: List[_Transfer] = []
    for c in plan.loads:
        leg = fwd.get(c.access.tensor.name)
        transfers.append(
            forward_transfer(c.access, c.hoist.level, leg, m, hw, "load")
            if leg is not None else _load_transfer(c, m, hw))
    for s in plan.stores:
        leg = fwd.get(s.access.tensor.name)
        transfers.append(
            forward_transfer(s.access, s.level, leg, m, hw, "store")
            if leg is not None and not s.reduce_axes
            else _store_transfer(s, m, hw))
    return transfers


def estimate(plan: DataflowPlan, hw: HardwareModel, *,
             pipeline_outer_levels: bool = False,
             transfers: Optional[Sequence[_Transfer]] = None,
             fwd: Optional[TMapping[str, ForwardLeg]] = None) -> PlanCost:
    """Estimate end-to-end execution time of one candidate plan.

    ``pipeline_outer_levels=False`` is the paper-faithful model (overlap only
    in the innermost loop).  ``True`` additionally double-buffers hoisted
    transfers against the inner loop body — the beyond-paper "collective /
    compute overlap" optimization evaluated in EXPERIMENTS.md SPerf.

    ``transfers`` may be supplied by callers that already materialized the
    plan's transfer list (``BoundContext.transfers_for``); it must equal
    what this function would rebuild.

    ``fwd`` maps tensor names to :class:`~repro.core.reuse.ForwardLeg`\\ s for
    accesses riding a forwarded inter-kernel edge (the pipeline co-planner):
    those transfers are priced on-chip (L1 + re-shuffle rings) instead of
    through DRAM.  ``None``/empty leaves the model bit-identical to the
    historical single-kernel path.
    """
    m = plan.mapping
    prog = m.program
    pools = _resource_pools(hw)

    # per-core effective loop nest: reduce binds divide sequential extents
    loops: List[Tuple[str, int]] = list(m.cost_loops())
    n = len(loops)

    if transfers is None:
        transfers = plan_transfers(plan, hw, fwd=fwd)
    by_level: Dict[int, List[_Transfer]] = {}
    for t in transfers:
        by_level.setdefault(t.level, []).append(t)

    t_body = body_compute_seconds(plan, hw)

    # traffic bookkeeping (drives the paper's "-70% DRAM accesses" ablation
    # and the roofline reports)
    dram_bytes = noc_bytes = 0.0
    for tr, issues in ((tr, _issues_at(tr.level, loops)) for tr in transfers):
        dram_bytes += tr.dram_bytes * issues
        noc_bytes += tr.noc_bytes * issues

    # innermost level: pipelined load/compute/store (levels index positions:
    # ops at level L sit between loop L-1 and loop L; level n = in-body)
    inner = by_level.get(n, [])
    t_load_in = _contended_time([t for t in inner if t.kind == "load"], pools)
    t_store_in = _contended_time([t for t in inner if t.kind == "store"], pools)

    hoisted_s = 0.0
    if n == 0:
        total = t_load_in + t_body + t_store_in
    else:
        _, I_in = loops[-1]
        # consumes loop n-1 and the level-n (in-body) memory ops
        total = pipelined_loop_time(I_in, t_load_in, t_store_in, t_body)
        for lvl in range(n - 2, -1, -1):        # consume loop `lvl`
            ops = by_level.get(lvl + 1, [])
            t_ops_load = _contended_time([t for t in ops if t.kind == "load"], pools)
            t_ops_store = _contended_time([t for t in ops if t.kind == "store"], pools)
            _, I = loops[lvl]
            if pipeline_outer_levels and (t_ops_load + t_ops_store) > 0:
                new_total = pipelined_loop_time(I, t_ops_load, t_ops_store, total)
                hoisted_s += max(0.0, new_total - I * total)
                total = new_total
            else:
                total = I * (total + t_ops_load + t_ops_store)
                hoisted_s += I * (t_ops_load + t_ops_store)
        # level-0 ops (once per core, outside all temporal loops)
        ops0 = by_level.get(0, [])
        t0 = _contended_time(ops0, pools)
        total += t0
        hoisted_s += t0

    flops = prog.mat_flops() + sum(op.work for op in prog.body
                                   if op.unit != "mat") * prog.inner_iters * prog.n_blocks

    compute_total = t_body * math.prod(e for _, e in loops) if loops else t_body
    util = m.utilization()

    # classify the bottleneck via the three roofline-style terms
    t_dram = dram_bytes / pools["dram"]
    noc_pools = {k: v for k, v in pools.items() if k not in ("dram", "l1")}
    # per-resource accumulation for the NoC term
    noc_busy: Dict[str, float] = {}
    for tr in transfers:
        issues = _issues_at(tr.level, loops)
        for res, b in tr.demand.items():
            if res in noc_pools:
                noc_busy[res] = noc_busy.get(res, 0.0) + b * issues / noc_pools[res]
    t_noc = max(noc_busy.values()) if noc_busy else 0.0
    terms = {"compute": compute_total, "memory": t_dram, "noc": t_noc}
    bound = max(terms, key=terms.get)

    return PlanCost(total_s=total, compute_s=compute_total,
                    inner_load_s=t_load_in, inner_store_s=t_store_in,
                    hoisted_s=hoisted_s, dram_bytes=dram_bytes,
                    noc_bytes=noc_bytes, flops=flops,
                    buffer_bytes=plan.buffer_bytes(), utilization=util,
                    bound=bound)


def _issues_at(level: int, loops: Sequence[Tuple[str, int]]) -> int:
    k = 1
    for _, e in loops[:level]:
        k *= e
    return k


def cost_breakdown(plan: DataflowPlan, hw: HardwareModel, *,
                   pipeline_outer_levels: bool = False,
                   fwd: Optional[TMapping[str, ForwardLeg]] = None) -> Dict:
    """Per-resource decomposition of :func:`estimate` for introspection
    (``repro.obs.explain``): total busy-seconds and bytes each df resource
    (dram, every NoC ring class, l1) absorbs over the whole kernel, plus
    the per-transfer contributions and the :class:`PlanCost` itself.

    Pure read-only companion of :func:`estimate` — it reuses the identical
    transfer list and pools, so ``breakdown["cost"]`` is bit-identical to a
    direct ``estimate()`` call with the same arguments.
    """
    m = plan.mapping
    pools = _resource_pools(hw)
    loops: List[Tuple[str, int]] = list(m.cost_loops())
    transfers = plan_transfers(plan, hw, fwd=fwd)
    resources: Dict[str, Dict[str, float]] = {
        res: {"busy_s": 0.0, "bytes": 0.0} for res in pools}
    per_transfer = []
    for tr in transfers:
        issues = _issues_at(tr.level, loops)
        row = {"name": tr.name, "kind": tr.kind, "level": tr.level,
               "issues": issues, "dram_bytes": tr.dram_bytes * issues,
               "noc_bytes": tr.noc_bytes * issues, "resources": {}}
        for res, b in tr.demand.items():
            busy = b * issues / pools[res]
            resources[res]["busy_s"] += busy
            resources[res]["bytes"] += b * issues
            row["resources"][res] = {"busy_s": busy, "bytes": b * issues}
        per_transfer.append(row)
    cost = estimate(plan, hw, pipeline_outer_levels=pipeline_outer_levels,
                    transfers=transfers)
    return {"cost": cost, "compute_s": cost.compute_s,
            "resources": resources, "transfers": per_transfer,
            "pools_bytes_per_s": dict(pools)}


# --------------------------------------------------------------------------
# Admissible lower bound (branch-and-bound ranking, DESIGN_SEARCHPERF.md)
# --------------------------------------------------------------------------
class BoundContext:
    """Per-mapping precomputation for a cheap admissible lower bound on
    :func:`estimate`.

    For every plan over this mapping, ``lower_bound(plan) <=
    estimate(plan, hw, pipeline_outer_levels=...).total_s`` (in either
    overlap mode), so the planner may skip the full estimate for any plan
    whose bound already exceeds the current k-th best cost without changing
    the selected top-k.  Two terms:

    * **compute**: the pipelined-loop formula satisfies ``T >= I * t_body``
      at every level, so ``t_body * prod(extents)`` bounds the total;
    * **traffic**: in the paper-faithful mode every level contributes its
      contended transfer time ``max_r(demand_r / pool_r) >= demand_r /
      pool_r`` serially, so summing per-resource busy time across levels
      bounds the total per resource; with ``pipeline_outer_levels`` the
      model lets different levels overlap, so only the per-(level,
      resource) maximum remains admissible.

    Store placements are mapping-constant and folded in at construction;
    per-load-option busy vectors are memoized, so a bound costs a few dict
    additions per plan instead of a full hierarchical walk.
    """

    def __init__(self, mapping: _Mapping, stores: Sequence[StorePlacement],
                 hw: HardwareModel, *, pipeline_outer_levels: bool = False):
        self.mapping = mapping
        self.hw = hw
        self.pipelined = pipeline_outer_levels
        self.pools = _resource_pools(hw)
        loops: List[Tuple[str, int]] = list(mapping.cost_loops())
        self.loops = loops
        self.compute_lb = body_compute_seconds(mapping, hw) \
            * math.prod(e for _, e in loops)
        self.utilization = mapping.utilization()
        self.active_cores = mapping.active_cores()
        self._store_trs = [_store_transfer(s, mapping, hw) for s in stores]
        self._store_busy: Dict[Tuple[int, str], float] = {}
        for tr in self._store_trs:
            issues = _issues_at(tr.level, loops)
            for r, b in tr.demand.items():
                key = (tr.level, r)
                self._store_busy[key] = self._store_busy.get(key, 0.0) \
                    + b * issues / self.pools[r]
        self._tr_memo: Dict[int, _Transfer] = {}
        self._memo: Dict[int, Dict[Tuple[int, str], float]] = {}

    def _load_tr(self, c: MemOpChoice) -> _Transfer:
        tr = self._tr_memo.get(id(c))
        if tr is None:
            tr = self._tr_memo[id(c)] = _load_transfer(c, self.mapping,
                                                       self.hw)
        return tr

    def transfers_for(self, plan: DataflowPlan) -> List[_Transfer]:
        """The plan's transfer list (loads memoized per option, stores
        shared) — exactly what :func:`estimate` would rebuild itself."""
        return [self._load_tr(c) for c in plan.loads] + self._store_trs

    def _load_busy(self, c: MemOpChoice) -> Dict[Tuple[int, str], float]:
        busy = self._memo.get(id(c))
        if busy is None:
            tr = self._load_tr(c)
            issues = _issues_at(tr.level, self.loops)
            busy = {(tr.level, r): b * issues / self.pools[r]
                    for r, b in tr.demand.items()}
            self._memo[id(c)] = busy
        return busy

    def lower_bound(self, plan: DataflowPlan) -> float:
        agg = dict(self._store_busy)
        for c in plan.loads:
            for key, v in self._load_busy(c).items():
                agg[key] = agg.get(key, 0.0) + v
        if self.pipelined:
            traffic = max(agg.values(), default=0.0)
        else:
            per_res: Dict[str, float] = {}
            for (_, r), v in agg.items():
                per_res[r] = per_res.get(r, 0.0) + v
            traffic = max(per_res.values(), default=0.0)
        return max(self.compute_lb, traffic)


def plan_lower_bound(plan: DataflowPlan, hw: HardwareModel, *,
                     pipeline_outer_levels: bool = False) -> float:
    """One-shot admissible lower bound on ``estimate(plan, hw).total_s``."""
    ctx = BoundContext(plan.mapping, plan.stores, hw,
                       pipeline_outer_levels=pipeline_outer_levels)
    return ctx.lower_bound(plan)
