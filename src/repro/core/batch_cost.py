"""Batched (structure-of-arrays) cost engine for the planner hot loop.

PR 2 made the cold search algorithmically cheap (branch-and-bound, wave
equivalence classes); what remains is pure Python evaluating one candidate
at a time.  This module rewrites the innermost cost loops of
``perfmodel.py`` / ``simulator.py`` into array form:

* :class:`MappingBatch` materializes every surviving (plan, combo)
  candidate of one mapping into numpy arrays — per-(level, resource) busy
  rates, per-level transfer times, traffic terms, buffer bytes — and
  computes the admissible lower bound and the full hierarchical
  :func:`~repro.core.perfmodel.estimate` for the whole batch at once;
* :func:`simulate_plans` computes the wave-equivalence-class simulation
  with the per-core inner loop vectorized over the active-core set
  (sharing the per-mapping class decomposition across plans).

The scalar functions (``estimate``, ``plan_lower_bound``, ``simulate``)
remain the per-plan API and the test oracle.  **Selection identity is a
hard requirement**: every vectorized expression mirrors the scalar code's
floating-point operation order exactly — accumulation across load slots
happens slot-by-slot (zeros from other levels are exact no-ops), store
contributions are added term-by-term after the loads, and the pipelined
loop formula is evaluated with the same association — so batch costs are
bit-identical to the scalar path and tie-breaking by canonical
(program, mapping, combo) index resolves identically
(``tests/test_search_equivalence.py`` pins this).  Only the lower bound
may differ by float rounding (different summation order across levels),
which the branch-and-bound slack already absorbs: pruning decisions can
shift between "pruned" and "estimated", never the selected top-k.

numpy is an optional dependency at import time: when it is unavailable the
planner transparently falls back to the scalar engine
(``repro.core.planner.resolve_engine``).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

try:                                   # gate, don't hard-require (CI installs
    import numpy as np                 # numpy; minimal images may lack it)
except ImportError:                    # pragma: no cover - exercised via env
    np = None

from ..obs import trace
from .hw import HardwareModel
from .mapping import Mapping as _Mapping
from .perfmodel import (PlanCost, _contended_time, _issues_at,
                        _resource_pools, _store_transfer,
                        body_compute_seconds, pipelined_loop_time)
from .plan import DataflowPlan
from .reuse import (ForwardLeg, MemOpChoice, StorePlacement,
                    _store_staging_tiles, memop_demand)
from .simulator import (SimResult, _core_coords, _loop_digit_groups,
                        _reduce_epilogue_cost)

HAVE_NUMPY = np is not None


def _pipelined_vec(I: int, t_load, t_store, t_body):
    """:func:`~repro.core.perfmodel.pipelined_loop_time` with the load (and
    possibly total) terms as arrays — identical expression structure, so
    each element reproduces the scalar result bit-for-bit."""
    if I <= 0:
        return t_load * 0.0
    if I == 1:
        return t_load + t_body + t_store
    steady = (I - 2) * np.maximum(t_load + t_store, t_body)
    return (steady + np.maximum(t_load, t_body) + np.maximum(t_store, t_body)
            + t_load + t_store)


class MappingBatch:
    """SoA cost engine for all memory-op combos of one mapping.

    Layout (R = #df resources, n = #temporal+sequential loops, so memory-op
    levels range 0..n; O = #distinct load options across the combos; C =
    #combos; L = #loads of the program):

    ======================  =======================  =========================
    array                   shape                    content
    ======================  =======================  =========================
    ``_opt_busy``           (O, n+1, R)              per-issue demand / pool
                                                     (the contention busy
                                                     rate), nonzero only at
                                                     the option's hoist level
    ``_opt_lb``             (O, n+1, R)              demand x issues / pool
                                                     (the bound's busy time)
    ``_opt_noc``            (O, R_noc)               demand x issues / pool on
                                                     NoC resources (bottleneck
                                                     classification)
    ``_opt_dram/_opt_nocb`` (O,)                     whole-run traffic bytes
    ``_opt_buf``            (O,)                     local-buffer bytes
    ``_idx``                (C, L)                   combo -> option rows
    ======================  =======================  =========================

    Store placements are mapping-constant: their per-level contended times,
    traffic terms, and bound busy are precomputed as scalars/small vectors
    and folded in term-by-term (matching the scalar accumulation order).
    """

    def __init__(self, mapping: _Mapping, stores: Sequence[StorePlacement],
                 hw: HardwareModel,
                 combos: Sequence[Tuple[MemOpChoice, ...]], *,
                 pipeline_outer_levels: bool = False,
                 demands: Optional[Dict[int, tuple]] = None):
        self.mapping = mapping
        self.stores = tuple(stores)
        self.hw = hw
        self.pol = pipeline_outer_levels
        self.n_combos = len(combos)

        pools = _resource_pools(hw)
        self.pools = pools
        res = list(pools)                       # dram, interconnects..., l1
        res_col = {r: i for i, r in enumerate(res)}
        noc_res = [r for r in res if r not in ("dram", "l1")]
        noc_col = {r: i for i, r in enumerate(noc_res)}
        R, Rn = len(res), len(noc_res)

        # per-core effective loop nest: reduce binds divide sequential
        # extents (identical to what estimate()/BoundContext build)
        loops: List[Tuple[str, int]] = list(mapping.cost_loops())
        self.loops = loops
        n = len(loops)
        self.n_levels = n
        issues = [_issues_at(lvl, loops) for lvl in range(n + 1)]

        prog = mapping.program
        self.t_body = body_compute_seconds(mapping, hw)
        self.compute_total = self.t_body * math.prod(e for _, e in loops) \
            if loops else self.t_body
        self.utilization = mapping.utilization()
        self.flops = prog.mat_flops() + sum(
            op.work for op in prog.body
            if op.unit != "mat") * prog.inner_iters * prog.n_blocks

        # ---- mapping-constant store terms ---------------------------------
        store_trs = [_store_transfer(s, mapping, hw) for s in self.stores]
        # per-level contended store time (the scalar helper itself, so the
        # constant is bit-identical to what estimate() would compute)
        self.store_time = [
            _contended_time([t for t in store_trs if t.level == lvl], pools)
            for lvl in range(n + 1)]
        # level-0 busy vectors, one per store (estimate's level-0 pass mixes
        # loads and stores in one census; adding the store terms one by one
        # after the loads reproduces its accumulation order)
        self._store_busy0 = []
        for tr in store_trs:
            if tr.level == 0:
                v = np.zeros(R)
                for r, b in tr.demand.items():
                    v[res_col[r]] += b / pools[r]
                self._store_busy0.append(v)
        # traffic terms, one per store, in store order
        self._store_dram = [tr.dram_bytes * issues[tr.level]
                            for tr in store_trs]
        self._store_noc = [tr.noc_bytes * issues[tr.level]
                           for tr in store_trs]
        # bound busy, accumulated store-by-store (BoundContext does the same)
        store_lb = np.zeros((n + 1, R))
        for tr in store_trs:
            for r, b in tr.demand.items():
                store_lb[tr.level, res_col[r]] += b * issues[tr.level] \
                    / pools[r]
        self._store_lb = store_lb

        self._base_buf = sum(s.access.tile_bytes * _store_staging_tiles(s)
                             for s in self.stores) \
            + prog.accumulator_bytes()

        # ---- load-option registry (one allocation per table, not one per
        # option: the planner builds hundreds of batches per kernel) -------
        rows: Dict[int, int] = {}
        opt_entries: List[Tuple[int, Dict[str, float]]] = []
        opt_dram: List[float] = []
        opt_nocb: List[float] = []
        opt_buf: List[int] = []

        def row_of(c: MemOpChoice) -> int:
            got = rows.get(id(c))
            if got is not None:
                return got
            dem = demands.get(id(c)) if demands is not None else None
            if dem is None:
                dem = memop_demand(c, mapping, hw)
            demand, dram_b, noc_b = dem
            lvl = c.hoist.level
            opt_entries.append((lvl, demand))
            opt_dram.append(dram_b * issues[lvl])
            opt_nocb.append(noc_b * issues[lvl])
            opt_buf.append(c.hoist.footprint_tiles * c.access.tile_bytes
                           * (2 if lvl == n else 1))
            rows[id(c)] = len(opt_entries) - 1
            return rows[id(c)]

        self.n_loads = len(combos[0]) if combos else 0
        self._idx = np.array([[row_of(c) for c in combo] for combo in combos],
                             dtype=np.intp).reshape(self.n_combos,
                                                    self.n_loads)
        O = len(opt_entries)
        busy = np.zeros((O, n + 1, R))
        nocv = np.zeros((O, Rn))
        for o, (lvl, demand) in enumerate(opt_entries):
            for r, b in demand.items():
                busy[o, lvl, res_col[r]] = b / pools[r]
                if r in noc_col:
                    # (b * issues) / pool — the scalar classification's exact
                    # operation order (the busy-rate x issues product below
                    # can differ in the last ulp, fine for the bound but not
                    # for reproducing estimate()'s bottleneck label)
                    nocv[o, noc_col[r]] = b * issues[lvl] / pools[r]
        self._opt_busy = busy
        # bound busy: each option is nonzero only at its own level, so one
        # broadcast multiply applies the right issues factor everywhere
        self._opt_lb = busy * np.array(issues, dtype=float)[None, :, None]
        self._opt_noc = nocv
        self._opt_dram = np.array(opt_dram) if opt_dram else np.zeros(0)
        self._opt_nocb = np.array(opt_nocb) if opt_nocb else np.zeros(0)
        self._opt_buf = np.array(opt_buf, dtype=np.int64) if opt_buf \
            else np.zeros(0, dtype=np.int64)
        self._Rn = Rn

    # ---------------------------------------------------------------- sums
    def _slot_sum(self, table: "np.ndarray", rows: "np.ndarray"):
        """Sum per-option rows across load slots, slot by slot — the scalar
        code accumulates transfers in ``plan.loads`` order, and adding the
        zero entries a mismatched level contributes is exact."""
        if self.n_loads == 0:
            shape = (len(rows),) + table.shape[1:]
            return np.zeros(shape)
        acc = table[self._idx[rows, 0]]
        for l in range(1, self.n_loads):
            acc = acc + table[self._idx[rows, l]]
        return acc

    # --------------------------------------------------------------- bound
    def lower_bounds(self) -> "np.ndarray":
        """Admissible lower bound per combo (vectorized
        :meth:`~repro.core.perfmodel.BoundContext.lower_bound`).  May differ
        from the scalar bound by float rounding (summation order across
        levels); the planner's pruning slack absorbs that."""
        rows = np.arange(self.n_combos)
        agg = self._store_lb[None] + self._slot_sum(self._opt_lb, rows) \
            if self.n_loads else np.broadcast_to(
                self._store_lb, (self.n_combos,) + self._store_lb.shape)
        if self.pol:
            traffic = agg.max(axis=(1, 2)) if agg.size else \
                np.zeros(self.n_combos)
        else:
            per_res = agg.sum(axis=1)
            traffic = per_res.max(axis=1) if per_res.size else \
                np.zeros(self.n_combos)
        return np.maximum(self.compute_total, traffic)

    # ------------------------------------------------------------ estimate
    def estimate_rows(self, rows: "np.ndarray") -> "_BatchCosts":
        """Full hierarchical estimate for the selected combo rows — the
        vectorized twin of :func:`~repro.core.perfmodel.estimate`, matched
        operation-for-operation so each column is bit-identical to the
        scalar result."""
        n = self.n_levels
        C = len(rows)
        busy = self._slot_sum(self._opt_busy, rows)      # (C, n+1, R)
        t_load = busy.max(axis=2) if busy.size else np.zeros((C, n + 1))

        # traffic: loads slot-by-slot, then stores term-by-term
        dram = self._slot_sum(self._opt_dram, rows)
        nocb = self._slot_sum(self._opt_nocb, rows)
        for term in self._store_dram:
            dram = dram + term
        for term in self._store_noc:
            nocb = nocb + term

        t_body = self.t_body
        st = self.store_time
        if n == 0:
            total = t_load[:, 0] + t_body + st[0]
            hoisted = np.zeros(C)
            inner_load = t_load[:, 0]
            inner_store = np.full(C, st[0])
        else:
            I_in = self.loops[-1][1]
            inner_load = t_load[:, n]
            inner_store = np.full(C, st[n])
            total = _pipelined_vec(I_in, t_load[:, n], st[n], t_body)
            hoisted = np.zeros(C)
            for lvl in range(n - 2, -1, -1):
                tol = t_load[:, lvl + 1]
                tos = st[lvl + 1]
                I = self.loops[lvl][1]
                if self.pol:
                    mask = (tol + tos) > 0
                    pipe = _pipelined_vec(I, tol, tos, total)
                    h_pipe = np.maximum(0.0, pipe - I * total)
                    ser = I * (total + tol + tos)
                    h_ser = I * (tol + tos)
                    total = np.where(mask, pipe, ser)
                    hoisted = hoisted + np.where(mask, h_pipe, h_ser)
                else:
                    total = I * (total + tol + tos)
                    hoisted = hoisted + I * (tol + tos)
            # level-0 ops: loads (already summed in slot order) then stores
            busy0 = busy[:, 0, :]
            for sv in self._store_busy0:
                busy0 = busy0 + sv
            t0 = busy0.max(axis=1) if busy0.size else np.zeros(C)
            total = total + t0
            hoisted = hoisted + t0

        # bottleneck classification (same tie order as max(terms, key=...):
        # compute beats memory beats noc on exact ties)
        t_dram = dram / self.pools["dram"]
        nbusy = self._slot_sum(self._opt_noc, rows)
        t_noc = nbusy.max(axis=1) if (self._Rn and nbusy.size) \
            else np.zeros(C)
        is_c = (self.compute_total >= t_dram) & (self.compute_total >= t_noc)
        is_m = ~is_c & (t_dram >= t_noc)

        buf = self._slot_sum(self._opt_buf, rows) + self._base_buf \
            if self.n_loads else np.full(C, self._base_buf, dtype=np.int64)
        return _BatchCosts(self, total, hoisted, inner_load, inner_store,
                           dram, nocb, buf, is_c, is_m)


class _BatchCosts:
    """Column view over one :meth:`MappingBatch.estimate_rows` result;
    :meth:`cost` materializes a scalar :class:`PlanCost` on demand (only
    candidates that enter the top-k heap pay for the dataclass)."""

    def __init__(self, batch, total, hoisted, inner_load, inner_store,
                 dram, noc, buf, is_c, is_m):
        self.batch = batch
        self.total = total
        self._hoisted = hoisted
        self._inner_load = inner_load
        self._inner_store = inner_store
        self._dram = dram
        self._noc = noc
        self._buf = buf
        self._is_c = is_c
        self._is_m = is_m

    def cost(self, j: int) -> PlanCost:
        b = self.batch
        bound = "compute" if self._is_c[j] else \
            ("memory" if self._is_m[j] else "noc")
        return PlanCost(
            total_s=float(self.total[j]), compute_s=float(b.compute_total),
            inner_load_s=float(self._inner_load[j]),
            inner_store_s=float(self._inner_store[j]),
            hoisted_s=float(self._hoisted[j]),
            dram_bytes=float(self._dram[j]), noc_bytes=float(self._noc[j]),
            flops=float(b.flops), buffer_bytes=int(self._buf[j]),
            utilization=b.utilization, bound=bound)


# ==========================================================================
# Vectorized wave-equivalence-class simulation
# ==========================================================================
class _MeshView:
    """Per-(mapping, hw) geometry shared by every plan of the mapping:
    core coordinates, DRAM-channel ids, and per-axis ring-instance ids."""

    def __init__(self, plan: DataflowPlan, hw: HardwareModel):
        self.coords = _core_coords(plan)
        self.n_cores = len(self.coords)
        ch_ids: Dict[Tuple[int, ...], int] = {}
        ch = []
        for c in self.coords:
            t = hw.channel_of_core(c)
            ch.append(ch_ids.setdefault(t, len(ch_ids)))
        self.ch_idx = np.array(ch, dtype=np.intp)
        self.n_channels = max(1, len(ch_ids))
        # ring instance ids: along axis a, cores sharing all non-a coords
        # share one ring (the scalar census keys rings by that tuple)
        self.groups: Dict[str, Tuple["np.ndarray", int]] = {}
        axes = {k for c in self.coords for k in c}
        for a in axes:
            gids: Dict[tuple, int] = {}
            g = []
            for c in self.coords:
                other = tuple(sorted((k, v) for k, v in c.items() if k != a))
                g.append(gids.setdefault(other, len(gids)))
            self.groups[a] = (np.array(g, dtype=np.intp), max(1, len(gids)))
        self.static_mask, self.per_loop = _loop_digit_groups(plan, self.coords,
                                                             hw)


def simulate_plans(plans: Sequence[DataflowPlan], hw: HardwareModel, *,
                   launch_overhead_s: float = 20e-6,
                   wave_overhead_s: float = 2e-6,
                   fwd: Optional[Sequence[Optional[Dict[str, ForwardLeg]]]]
                   = None) -> List[SimResult]:
    """Wave-equivalence-class simulation for a batch of plans, with the
    per-core inner loop of each class costed as numpy arrays over the
    active-core set (replacing ``simulate``'s O(cores x ops) Python loop).
    Identical math to :func:`repro.core.simulator.simulate` — the class
    walk is the same; only the per-core arithmetic is array-shaped — so
    totals and traffic agree with the scalar simulator bit-for-bit
    (asserted at 1e-12 by the equivalence tests).

    ``fwd`` is an optional per-plan sequence of forwarded-edge leg maps
    (``simulate``'s ``fwd`` parameter) — the pipeline co-planner's fused
    producer/consumer simulation; the batch path mirrors the scalar leg
    pricing operation-for-operation, so forwarded totals stay bit-identical
    (``==``) to the scalar simulator as well.

    Plans sharing a :class:`Mapping` object share the class decomposition
    and mesh geometry (the planner's top-k profiling pass benefits when
    several finalists ride one mapping).
    """
    legs = list(fwd) if fwd is not None else [None] * len(plans)
    if np is None:
        from .simulator import simulate
        return [simulate(p, hw, launch_overhead_s=launch_overhead_s,
                         wave_overhead_s=wave_overhead_s, fwd=f)
                for p, f in zip(plans, legs)]
    views: Dict[int, _MeshView] = {}
    out = []
    with trace.span("planner.simulate_plans", n_plans=len(plans)):
        for plan, f in zip(plans, legs):
            view = views.get(id(plan.mapping))
            if view is None:
                view = views[id(plan.mapping)] = _MeshView(plan, hw)
            out.append(_simulate_one(plan, hw, view, launch_overhead_s,
                                     wave_overhead_s, fwd=f))
    return out


def _simulate_one(plan: DataflowPlan, hw: HardwareModel, view: _MeshView,
                  launch_overhead_s: float,
                  wave_overhead_s: float, *,
                  fwd: Optional[Dict[str, ForwardLeg]] = None) -> SimResult:
    fwd = fwd or {}
    m = plan.mapping
    prog = m.program
    t_body = body_compute_seconds(plan, hw)
    n_cores = view.n_cores
    n_temporal = len(m.temporal)
    n_loops = n_temporal + len(prog.seq_dims)
    seq_extents = [e for _, e in m.seq_loops()]      # per-core (split) extents
    inner_I = seq_extents[-1] if seq_extents else 1
    outer_seq = math.prod(seq_extents[:-1]) if len(seq_extents) > 1 else 1
    red_act = m.active_reduce_factor()

    dram_bw = hw.global_mem.bandwidth_gbps * 1e9
    link_bw = {ic.name: ic.bandwidth_gbps * 1e9 for ic in hw.interconnects}
    l1_bw = hw.local_mem.bandwidth_gbps * 1e9
    sizes = dict(m.hw_dims)

    inner_loads = [c for c in plan.loads if c.hoist.level == n_loops]
    hoisted_loads = [c for c in plan.loads if c.hoist.level < n_loops]
    inner_stores = [s for s in plan.stores if s.level == n_loops]
    outer_stores = [s for s in plan.stores if s.level < n_loops]
    k_cut = [min(c.hoist.level, n_temporal) for c in hoisted_loads]

    # per-op producer masks and ring-axis handles (precomputed once)
    prod_mask = {}
    op_axes = {}
    for c in inner_loads:
        if c.bcast_axes:
            prod_mask[id(c)] = np.array(
                [all(co.get(a, 0) == 0 for a in c.bcast_axes)
                 for co in view.coords])
            op_axes[id(c)] = [(a, hw.interconnect_along(a))
                              for a in c.bcast_axes]

    n_waves = math.prod(t.extent for t in m.temporal) if m.temporal else 1

    def wave_cost(amask: int):
        active = np.array([i for i in range(n_cores) if (amask >> i) & 1],
                          dtype=np.intp)
        A = len(active)

        # --- contention census (integer counts: exact) ---------------------
        hist = np.bincount(view.ch_idx[active], minlength=view.n_channels)
        chan_counts = np.zeros(view.n_channels, dtype=np.int64)
        ring_counts = {a: np.zeros(g[1], dtype=np.int64)
                       for a, g in view.groups.items()}
        for c in inner_loads:
            leg = fwd.get(c.access.tensor.name)
            if leg is not None:
                # forwarded recv: no DRAM users; the re-shuffle rings count
                # one user per active core (every tile is distinct), sharing
                # the per-axis ring census with the multicast ops — exactly
                # the scalar census' shared (ring, instance) keying
                if leg.kind != "free":
                    for a in leg.shuffle_axes:
                        if hw.interconnect_along(a) is None:
                            continue
                        gid = view.groups[a][0][active]
                        ring_counts[a] += np.bincount(
                            gid, minlength=view.groups[a][1])
                continue
            if not c.bcast_axes:
                chan_counts += hist
            else:
                pmask = prod_mask[id(c)][active]
                if pmask.any():
                    chan_counts += np.bincount(
                        view.ch_idx[active[pmask]],
                        minlength=view.n_channels)
                for a, ic in op_axes[id(c)]:
                    if ic is None:
                        continue
                    gid = view.groups[a][0][active]
                    present = np.unique(gid)
                    ring_counts[a][present] += 1

        # --- per-core inner-loop time (vectorized over active cores) -------
        ch_users = chan_counts[view.ch_idx[active]]
        t_load = np.zeros(A)
        for c in inner_loads:
            tb = c.access.tile_bytes
            leg = fwd.get(c.access.tensor.name)
            if leg is not None:
                if leg.kind == "free":
                    continue
                # on-chip receive: remote L1 read + re-shuffle ring hops
                # (same expression order as the scalar path)
                t_leg = np.zeros(A) + tb / l1_bw
                for a in leg.shuffle_axes:
                    ic = hw.interconnect_along(a)
                    if ic is None:
                        continue
                    gid = view.groups[a][0][active]
                    r_users = np.maximum(1, ring_counts[a][gid])
                    t_leg = t_leg + tb / (link_bw[ic.name] / r_users)
                t_load = t_load + t_leg
                t_load = t_load + tb / l1_bw    # local landing, like any load
                continue
            if not c.bcast_axes:
                users = np.maximum(1, ch_users)
                t_load = t_load + tb / (dram_bw / users)
            else:
                users = np.maximum(1, ch_users)
                t_leg = np.where(prod_mask[id(c)][active],
                                 tb / (dram_bw / users), 0.0)
                t_noc = np.zeros(A)
                for a, ic in op_axes[id(c)]:
                    if ic is None:
                        continue
                    gid = view.groups[a][0][active]
                    r_users = np.maximum(1, ring_counts[a][gid])
                    t_noc = t_noc + tb / (link_bw[ic.name] / r_users)
                t_load = t_load + np.maximum(t_leg, t_noc)
            t_load = t_load + tb / l1_bw
        t_store = np.zeros(A)
        for s in inner_stores:
            leg = fwd.get(s.access.tensor.name)
            if leg is not None and not s.reduce_axes:
                if leg.kind != "free":
                    t_store = t_store + s.access.tile_bytes / l1_bw
                continue
            users = np.maximum(1, ch_users)
            t_store = t_store + s.access.tile_bytes / (dram_bw / users)
        if A:
            core_t = _pipelined_vec(inner_I, t_load, t_store, t_body)
            wave_time = float((core_t * outer_seq).max())
        else:                           # pragma: no cover - masked earlier
            wave_time = 0.0

        # --- hoisted transfers / traffic (identical to simulator.simulate) -
        n_active = A
        hoist_info = []
        for c in hoisted_loads:
            seq_issues = (math.prod(seq_extents[:c.hoist.level - n_temporal])
                          if c.hoist.level > n_temporal else 1)
            tb = c.access.tile_bytes * c.hoist.tiles_per_issue * seq_issues
            leg = fwd.get(c.access.tensor.name)
            if leg is not None:
                if leg.kind == "free":
                    hoist_info.append((0.0, 0.0, 0.0))
                    continue
                t_c = tb / l1_bw
                nb = 0.0
                for a in leg.shuffle_axes:
                    ic = hw.interconnect_along(a)
                    if ic is None:
                        continue
                    t_c += tb * sizes[a] / link_bw[ic.name]
                    nb += tb * n_active
                hoist_info.append((t_c, 0.0, nb))
                continue
            if c.bcast_axes:
                repl = math.prod(sizes[a] for a in c.bcast_axes)
                producers = max(1, n_active // repl)
                t_dram = tb * producers / (dram_bw * hw.global_channels())
                slowest_ring = min((link_bw[hw.interconnect_along(a).name]
                                    for a in c.bcast_axes
                                    if hw.interconnect_along(a)), default=None)
                t_nc = tb / slowest_ring if slowest_ring else 0.0
                t_c = max(t_dram, t_nc)
                db = tb * producers
                nb = 0.0
                planes = producers
                for a in c.bcast_axes:
                    nb += tb * (sizes[a] - 1) * planes
                    planes *= sizes[a]
            else:
                t_c = tb * n_active / (dram_bw * hw.global_channels())
                db = tb * n_active
                nb = 0.0
            hoist_info.append((t_c, db, nb))

        iters = inner_I * outer_seq
        inner_dram = inner_noc = 0.0
        for c in inner_loads:
            tb = c.access.tile_bytes * iters
            leg = fwd.get(c.access.tensor.name)
            if leg is not None:
                if leg.kind != "free":
                    for a in leg.shuffle_axes:
                        if hw.interconnect_along(a) is not None:
                            inner_noc += tb * n_active
                continue
            if c.bcast_axes:
                repl = math.prod(sizes[a] for a in c.bcast_axes)
                producers = max(1, n_active // repl)
                inner_dram += tb * producers
                planes = producers
                for a in c.bcast_axes:
                    inner_noc += tb * (sizes[a] - 1) * planes
                    planes *= sizes[a]
            else:
                inner_dram += tb * n_active
        for s in inner_stores:
            leg = fwd.get(s.access.tensor.name)
            if leg is not None and not s.reduce_axes:
                continue                        # on-chip: no DRAM bytes
            inner_dram += s.access.tile_bytes * iters * n_active
        ostore_t, ostore_dram, ostore_noc = _reduce_epilogue_cost(
            m, outer_stores, n_active, red_act, hw, dram_bw, link_bw,
            fwd=fwd, l1_bw=l1_bw)
        return (wave_time, inner_dram, inner_noc, hoist_info, ostore_t,
                ostore_dram, ostore_noc)

    # class walk: identical order and accumulation to simulator.simulate
    import itertools
    total = 0.0
    dram_bytes = 0.0
    noc_bytes = 0.0
    n_classes = 0
    cache: Dict[int, tuple] = {}
    per_loop = view.per_loop
    for combo in itertools.product(*per_loop) if per_loop else [()]:
        pop = 1
        amask = view.static_mask
        j = -1
        for i, (mask, zero, count) in enumerate(combo):
            pop *= count
            amask &= mask
            if not zero:
                j = i
        first = j == -1
        n_classes += 1
        if amask == 0:
            total += wave_overhead_s * pop
            continue
        cost = cache.get(amask)
        if cost is None:
            cost = cache[amask] = wave_cost(amask)
        (wave_time, inner_dram, inner_noc, hoist_info, ostore_t,
         ostore_dram, ostore_noc) = cost
        t_hoist = ostore_t
        dram_bytes += (inner_dram + ostore_dram) * pop
        noc_bytes += (inner_noc + ostore_noc) * pop
        for (t_c, db, nb), k in zip(hoist_info, k_cut):
            if first or j < k:
                t_hoist += t_c
                dram_bytes += db * pop
                noc_bytes += nb * pop
        total += (wave_time + t_hoist + wave_overhead_s) * pop

    total += launch_overhead_s
    flops = prog.mat_flops()
    return SimResult(total_s=total, dram_bytes=dram_bytes,
                     noc_bytes=noc_bytes, flops=flops, n_waves=n_waves,
                     wave_overhead_s=wave_overhead_s,
                     n_wave_classes=n_classes)
