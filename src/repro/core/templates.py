"""Vendor-library baselines (paper S3.2).

TTNN chooses between two hand-written dataflow templates:

* **TT-1D** — the output grid is flattened 1D across all cores; the smaller
  input matrix is loaded per-core from global memory while the other input is
  broadcast across the *entire* array.
* **TT-2D** — both inputs are streamed across the mesh systolically: A tiles
  broadcast along rows, B tiles along columns (output-stationary 2D dataflow).

plus a fixed block-size heuristic.  TTNN's selector between the two is a
shape-based rule.  We reimplement all three as fixed :class:`DataflowPlan`
constructors over our IR so the paper's Fig 5/6 comparisons can be reproduced;
the selector rule below is a documented stand-in for Tenstorrent's proprietary
strategy (DESIGN.md S4) and mirrors its published behaviour: 2D for large
balanced shapes, 1D otherwise.
"""
from __future__ import annotations

import math
from typing import List, Optional, Tuple

from .hw import HardwareModel
from .mapping import Mapping, SpatialBind, TemporalLoop
from .plan import DataflowPlan, make_plan
from .program import TileProgram, matmul_program, flash_attention_program
from .reuse import (HoistOption, MemOpChoice, analyze_reuse, hoist_options,
                    buffer_footprint_bytes, store_placement)


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def vendor_block_shape(M: int, N: int, K: int, hw: HardwareModel,
                       dtype_bytes: int = 2, *,
                       fill: Optional[Tuple[int, int]] = None
                       ) -> Tuple[int, int, int]:
    """Fixed vendor-style block heuristic: the largest square-ish power-of-two
    tile (multiple of the 32x32 hardware tile) such that A+B double-buffered
    plus the C accumulator fit in L1 — additionally capped so the output grid
    fills the core array (``fill=(cores_m, cores_n)``), which is what TTNN's
    block-size strategy ensures."""
    cap = hw.local_capacity()
    fm, fn = fill or (1, 1)
    best = (32, 32, 32)
    for b in (32, 64, 128, 256):
        bm = bn = b
        bk = min(b, 64)
        need = (2 * (bm * bk + bk * bn) * dtype_bytes
                + bm * bn * 4 + bm * bn * dtype_bytes)
        if need > cap:
            continue
        if bm > max(32, M // max(1, fm)) or bn > max(32, N // max(1, fn)):
            continue
        best = (bm, bn, bk)
    return best


def _mapping_2d(prog: TileProgram, hw: HardwareModel) -> Mapping:
    """gx -> x, gy -> y (the natural 2D output-stationary assignment)."""
    (ax, sx), (ay, sy) = hw.mesh_dims[0], hw.mesh_dims[-1]
    gx, gy = prog.grid_dims[0].name, prog.grid_dims[1].name
    spatial = (SpatialBind(ax, sx, gx), SpatialBind(ay, sy, gy))
    temporal = []
    for d, sf in ((prog.grid_dims[0], sx), (prog.grid_dims[1], sy)):
        ext = _ceil(d.extent, sf)
        if ext > 1:
            temporal.append(TemporalLoop(f"t_{d.name}", d.name, ext))
    return Mapping(prog, hw.name, hw.mesh_dims, spatial, tuple(temporal))


def _mapping_1d(prog: TileProgram, hw: HardwareModel,
                flat_dim: str) -> Mapping:
    """Flatten the whole mesh onto one grid dim (TT-1D's core order)."""
    spatial = tuple(SpatialBind(a, s, flat_dim) for a, s in hw.mesh_dims)
    sf = math.prod(s for _, s in hw.mesh_dims)
    temporal = []
    for d in prog.grid_dims:
        f = sf if d.name == flat_dim else 1
        ext = _ceil(d.extent, f)
        if ext > 1:
            temporal.append(TemporalLoop(f"t_{d.name}", d.name, ext))
    return Mapping(prog, hw.name, hw.mesh_dims, spatial, tuple(temporal))


def _choice(mapping: Mapping, hw: HardwareModel, tensor_name: str,
            bcast_axes: Tuple[str, ...], hoist_dependent_crossings: int = 0
            ) -> MemOpChoice:
    infos = {i.access.tensor.name: i for i in analyze_reuse(mapping, hw)
             if i.access.kind == "load"}
    info = infos[tensor_name]
    # filter requested broadcast axes down to the legally reusable ones
    legal = tuple(a for a in bcast_axes if a in info.spatial_axes)
    opts = hoist_options(info, mapping)
    idx = min(hoist_dependent_crossings, len(opts) - 1)
    return MemOpChoice(info.access, legal, opts[idx])


def tt1d_matmul_plan(M: int, N: int, K: int, hw: HardwareModel,
                     dtype_bytes: int = 2) -> DataflowPlan:
    n_cores = math.prod(s for _, s in hw.mesh_dims)
    # flatten cores over the output dim of the larger operand; broadcast the
    # smaller operand to the whole array
    a_bytes, b_bytes = M * K, K * N
    if a_bytes >= b_bytes:
        flat, bcast_tensor = "gx", "B"
        fill = (n_cores, 1)
    else:
        flat, bcast_tensor = "gy", "A"
        fill = (1, n_cores)
    bm, bn, bk = vendor_block_shape(M, N, K, hw, dtype_bytes, fill=fill)
    prog = matmul_program(M, N, K, bm=bm, bn=bn, bk=bk, dtype_bytes=dtype_bytes,
                          name="tt1d_matmul")
    mapping = _mapping_1d(prog, hw, flat)
    axes = tuple(a for a, _ in hw.mesh_dims)
    loads = (
        _choice(mapping, hw, "A",
                axes if bcast_tensor == "A" else ()),
        _choice(mapping, hw, "B",
                axes if bcast_tensor == "B" else ()),
    )
    return make_plan(mapping, loads, hw)


def tt2d_matmul_plan(M: int, N: int, K: int, hw: HardwareModel,
                     dtype_bytes: int = 2) -> DataflowPlan:
    bm, bn, bk = vendor_block_shape(M, N, K, hw, dtype_bytes)
    prog = matmul_program(M, N, K, bm=bm, bn=bn, bk=bk, dtype_bytes=dtype_bytes,
                          name="tt2d_matmul")
    mapping = _mapping_2d(prog, hw)
    ax = mapping.spatial[0].hw_dim        # bound to gx
    ay = mapping.spatial[1].hw_dim        # bound to gy
    # A[gx,k] is identical along the gy-axis -> broadcast along ay (rows);
    # B[k,gy] identical along the gx-axis -> broadcast along ax (cols).
    loads = (
        _choice(mapping, hw, "A", (ay,)),
        _choice(mapping, hw, "B", (ax,)),
    )
    return make_plan(mapping, loads, hw)


def ttnn_matmul_plan(M: int, N: int, K: int, hw: HardwareModel,
                     dtype_bytes: int = 2) -> DataflowPlan:
    """TTNN's fixed selector (documented stand-in, see module docstring):
    prefer the 2D systolic template when both output dims can fill the mesh
    and the shape is balanced; otherwise fall back to 1D."""
    rows = hw.mesh_dims[0][1]
    cols = hw.mesh_dims[-1][1]
    bm, bn, _ = vendor_block_shape(M, N, K, hw, dtype_bytes)
    fills_2d = (M >= rows * bm) and (N >= cols * bn)
    balanced = max(M, N) <= 8 * min(M, N)
    if fills_2d and balanced and rows > 1 and cols > 1:
        return tt2d_matmul_plan(M, N, K, hw, dtype_bytes)
    return tt1d_matmul_plan(M, N, K, hw, dtype_bytes)


def ttnn_flash_plan(batch_heads: int, seq_q: int, seq_kv: int, head_dim: int,
                    hw: HardwareModel, dtype_bytes: int = 2) -> DataflowPlan:
    """TTNN-like FlashAttention mapping: heads/queries flattened across cores,
    every core streams K/V directly from DRAM each iteration (the paper:
    "TTNN's default mapping ... repeatedly reloads these operands from
    DRAM")."""
    bq = 64 if seq_q >= 64 else 32
    bkv = 64 if seq_kv >= 64 else 32
    prog = flash_attention_program(batch_heads, seq_q, seq_kv, head_dim,
                                   bq=bq, bkv=bkv, dtype_bytes=dtype_bytes,
                                   name="ttnn_flash")
    mapping = _mapping_1d(prog, hw, "h")
    loads = (
        _choice(mapping, hw, "Q", ()),
        _choice(mapping, hw, "K", ()),
        _choice(mapping, hw, "V", ()),
    )
    return make_plan(mapping, loads, hw)
