"""Dataflow-agnostic tile-program IR (paper S2.2, Listing 1).

A :class:`TileProgram` is the Python isomorph of the paper's normalized MLIR
input: an ``affine.parallel`` loop over *grid dims* (the logical launch grid),
an ``scf.for`` nest over *sequential dims* inside each block, a set of memory
accesses whose tile-grid addresses are **affine functions of the loop indices**
(the front-end's "affinization" contract), and a ``linalg``-style tile-op body
that dataflow planning never touches.

Programs are built either directly (``matmul_program``,
``flash_attention_program``, ...) or from einsum-like specs by the mesh-level
planner bridge (``parallel/planner_bridge.py``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

from .affine import AffineExpr, AffineMap


@dataclass(frozen=True)
class TensorSpec:
    """A global tensor: logical element shape + dtype width."""
    name: str
    shape: Tuple[int, ...]
    dtype_bytes: int = 2          # bf16/fp16 default

    @property
    def bytes(self) -> int:
        return math.prod(self.shape) * self.dtype_bytes


@dataclass(frozen=True)
class TileAccess:
    """A load or store of one tile of ``tensor`` per innermost iteration.

    ``index`` maps loop dims -> tile-grid coordinates (NOT element offsets);
    ``tile_shape`` is the element shape of one tile.  This mirrors the paper's
    ``memref.reinterpret_cast`` + affine.apply idiom in Listing 1.
    """
    tensor: TensorSpec
    index: AffineMap
    tile_shape: Tuple[int, ...]
    kind: str = "load"            # "load" | "store"
    name: str = ""

    @property
    def tile_bytes(self) -> int:
        return math.prod(self.tile_shape) * self.tensor.dtype_bytes

    def depends_on(self, dim: str) -> bool:
        return self.index.depends_on(dim)

    def label(self) -> str:
        return self.name or f"{self.kind}_{self.tensor.name}"


@dataclass(frozen=True)
class TileOp:
    """One ``linalg`` op of the tile body.  ``unit`` selects the intra-core
    functional unit class (paper S2.5 decomposes ops onto mat/vec/scalar
    intrinsics); ``work`` is intrinsic-independent work: FLOPs for ``mat``,
    element-ops for ``vec``/``scalar``.  Ops sharing a ``segment`` index are
    independent and may run on different unit types concurrently; segments
    execute in sequence (the paper's dependence/segment model)."""
    kind: str                     # "matmul" | "exp" | "add" | "max" | ...
    unit: str                     # "mat" | "vec" | "scalar"
    work: float
    segment: int = 0


@dataclass(frozen=True)
class LoopDim:
    name: str
    extent: int


@dataclass(frozen=True)
class TileProgram:
    """The unit of planning: one kernel's logical grid + per-block program."""
    name: str
    grid_dims: Tuple[LoopDim, ...]       # affine.parallel (logical launch grid)
    seq_dims: Tuple[LoopDim, ...]        # scf.for inside a block (outer->inner)
    loads: Tuple[TileAccess, ...]
    stores: Tuple[TileAccess, ...]
    body: Tuple[TileOp, ...]
    # Accumulators live in local memory for the whole block execution
    # (e.g. the C tile of a GEMM): name -> bytes.
    accumulators: Tuple[Tuple[str, int], ...] = ()

    # -- queries -------------------------------------------------------------
    def dim(self, name: str) -> LoopDim:
        for d in self.grid_dims + self.seq_dims:
            if d.name == name:
                return d
        raise KeyError(name)

    @property
    def extents(self) -> Dict[str, int]:
        return {d.name: d.extent for d in self.grid_dims + self.seq_dims}

    @property
    def n_blocks(self) -> int:
        return math.prod(d.extent for d in self.grid_dims)

    @property
    def inner_iters(self) -> int:
        return math.prod(d.extent for d in self.seq_dims)

    def total_flops(self) -> float:
        per_iter = sum(op.work for op in self.body if op.unit == "mat")
        per_iter += sum(op.work for op in self.body if op.unit != "mat")
        return per_iter * self.inner_iters * self.n_blocks

    def mat_flops(self) -> float:
        return (sum(op.work for op in self.body if op.unit == "mat")
                * self.inner_iters * self.n_blocks)

    def accumulator_bytes(self) -> int:
        return sum(b for _, b in self.accumulators)

    def validate(self) -> None:
        """Front-end contract checks (affinization, bounded dims)."""
        dims = {d.name for d in self.grid_dims} | {d.name for d in self.seq_dims}
        for acc in self.loads + self.stores:
            extra = acc.index.dims - dims
            if extra:
                raise ValueError(
                    f"{self.name}: access {acc.label()} uses undeclared dims {extra}")
        for d in self.grid_dims + self.seq_dims:
            if d.extent <= 0:
                raise ValueError(f"{self.name}: dim {d.name} has extent {d.extent}")


# --------------------------------------------------------------------------
# Program builders (the "front-end" of the reproduction; see DESIGN.md S4:
# Triton/triton-shared is replaced by direct IR construction with the same
# affine-access discipline).
# --------------------------------------------------------------------------
def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def matmul_program(M: int, N: int, K: int, *, bm: int, bn: int, bk: int,
                   dtype_bytes: int = 2, acc_bytes: int = 4,
                   name: str = "matmul",
                   tensor_names: Tuple[str, str, str] = ("A", "B", "C")
                   ) -> TileProgram:
    """``C[M,N] = A[M,K] @ B[K,N]`` — output-stationary tiling, the paper's
    running example (Listing 1).  Grid = (gx over M-tiles, gy over N-tiles);
    sequential k over K-tiles; body = one (bm,bk)x(bk,bn) tile matmul.

    ``tensor_names`` renames (A, B, C) so chained kernels of a pipeline
    graph can share an intermediate tensor by name (e.g. the two GEMMs of an
    MLP both calling their shared activation "Y"); the default leaves every
    historical program — and therefore every cache key and golden — intact.
    """
    an, bn_name, cn = tensor_names
    A = TensorSpec(an, (M, K), dtype_bytes)
    B = TensorSpec(bn_name, (K, N), dtype_bytes)
    C = TensorSpec(cn, (M, N), dtype_bytes)
    gx, gy, k = "gx", "gy", "k"
    loads = (
        TileAccess(A, AffineMap.from_terms({gx: 1}, {k: 1}), (bm, bk), "load"),
        TileAccess(B, AffineMap.from_terms({k: 1}, {gy: 1}), (bk, bn), "load"),
    )
    stores = (
        TileAccess(C, AffineMap.from_terms({gx: 1}, {gy: 1}), (bm, bn), "store"),
    )
    body = (TileOp("matmul", "mat", work=2.0 * bm * bn * bk, segment=0),)
    return TileProgram(
        name=f"{name}_{M}x{N}x{K}_b{bm}x{bn}x{bk}",
        grid_dims=(LoopDim(gx, _ceil(M, bm)), LoopDim(gy, _ceil(N, bn))),
        seq_dims=(LoopDim(k, _ceil(K, bk)),),
        loads=loads, stores=stores, body=body,
        accumulators=(("C_acc", bm * bn * acc_bytes),))


def fused_matmul_program(M: int, N: int, K: int, *, bm: int, bn: int, bk: int,
                         epilogue_ops: Sequence[str] = ("exp", "sqrt"),
                         dtype_bytes: int = 2) -> TileProgram:
    """GEMM with a fused pointwise epilogue (paper Listing 5 shows
    linalg.matmul + linalg.exp + linalg.sqrt in one tile body)."""
    base = matmul_program(M, N, K, bm=bm, bn=bn, bk=bk, dtype_bytes=dtype_bytes,
                          name="fused_matmul")
    body = list(base.body)
    for i, op in enumerate(epilogue_ops):
        body.append(TileOp(op, "vec", work=float(bm * bn), segment=1 + i))
    return replace(base, body=tuple(body))


def flash_attention_program(batch_heads: int, seq_q: int, seq_kv: int,
                            head_dim: int, *, bq: int, bkv: int,
                            dtype_bytes: int = 2, causal: bool = False,
                            name: str = "flash_attention") -> TileProgram:
    """Non-causal FlashAttention forward (the paper's second workload).

    Grid = (h over batch*heads, gq over Q tiles); sequential kv over KV tiles.
    Per inner iteration the block computes S = Q K^T (mat), online-softmax
    statistics (vec), and P V (mat).  K/V tiles do not depend on gq — that is
    exactly the cross-query reuse the paper says TileLoom exploits ("key data
    tiles are reused on-chip across multiple query and value tiles").
    """
    H = batch_heads
    Q = TensorSpec("Q", (H, seq_q, head_dim), dtype_bytes)
    K = TensorSpec("K", (H, seq_kv, head_dim), dtype_bytes)
    V = TensorSpec("V", (H, seq_kv, head_dim), dtype_bytes)
    O = TensorSpec("O", (H, seq_q, head_dim), dtype_bytes)
    h, gq, kv = "h", "gq", "kv"
    loads = (
        TileAccess(Q, AffineMap.from_terms({h: 1}, {gq: 1}), (1, bq, head_dim), "load"),
        TileAccess(K, AffineMap.from_terms({h: 1}, {kv: 1}), (1, bkv, head_dim), "load"),
        TileAccess(V, AffineMap.from_terms({h: 1}, {kv: 1}), (1, bkv, head_dim), "load"),
    )
    stores = (
        TileAccess(O, AffineMap.from_terms({h: 1}, {gq: 1}), (1, bq, head_dim), "store"),
    )
    kv_tiles = _ceil(seq_kv, bkv)
    causal_frac = 0.5 + 0.5 / max(1, kv_tiles) if causal else 1.0
    body = (
        TileOp("qk_matmul", "mat", work=2.0 * bq * bkv * head_dim * causal_frac, segment=0),
        TileOp("softmax_stats", "vec", work=4.0 * bq * bkv * causal_frac, segment=1),
        TileOp("rescale", "vec", work=2.0 * bq * head_dim, segment=1),
        TileOp("pv_matmul", "mat", work=2.0 * bq * bkv * head_dim * causal_frac, segment=2),
    )
    return TileProgram(
        name=f"{name}_h{H}_q{seq_q}_kv{seq_kv}_d{head_dim}_b{bq}x{bkv}",
        grid_dims=(LoopDim(h, H), LoopDim(gq, _ceil(seq_q, bq))),
        seq_dims=(LoopDim(kv, kv_tiles),),
        loads=loads, stores=stores, body=body,
        accumulators=(("O_acc", bq * head_dim * 4), ("m_l", 2 * bq * 4)))


def flash_decode_program(batch_heads: int, seq_kv: int, head_dim: int, *,
                         bkv: int, dtype_bytes: int = 2,
                         name: str = "flash_decode") -> TileProgram:
    """Single-token decode attention: one query row per (batch, head) against
    a long KV cache.

    Grid = (h over batch*heads) only — decode has no query tiling — and the
    whole KV walk is the sequential loop ``s``: a pure online-softmax
    reduction.  That makes the kernel the canonical *reduction-bound* shape
    (StreamTensor's LLM-decode case): with few heads the mesh idles and the
    ``s`` loop serializes on single cores unless the planner binds it to a
    mesh axis (split-KV spatial reduction) and combines the per-split
    (m, l, acc) partials.
    """
    H = batch_heads
    Q = TensorSpec("Q", (H, 1, head_dim), dtype_bytes)
    K = TensorSpec("K", (H, seq_kv, head_dim), dtype_bytes)
    V = TensorSpec("V", (H, seq_kv, head_dim), dtype_bytes)
    O = TensorSpec("O", (H, 1, head_dim), dtype_bytes)
    h, s = "h", "s"
    loads = (
        TileAccess(Q, AffineMap.from_terms({h: 1}, {}), (1, 1, head_dim), "load"),
        TileAccess(K, AffineMap.from_terms({h: 1}, {s: 1}), (1, bkv, head_dim), "load"),
        TileAccess(V, AffineMap.from_terms({h: 1}, {s: 1}), (1, bkv, head_dim), "load"),
    )
    stores = (
        TileAccess(O, AffineMap.from_terms({h: 1}, {}), (1, 1, head_dim), "store"),
    )
    body = (
        TileOp("qk_matvec", "mat", work=2.0 * bkv * head_dim, segment=0),
        TileOp("softmax_stats", "vec", work=4.0 * bkv, segment=1),
        TileOp("rescale", "vec", work=2.0 * head_dim, segment=1),
        TileOp("pv_matvec", "mat", work=2.0 * bkv * head_dim, segment=2),
    )
    return TileProgram(
        name=f"{name}_h{H}_kv{seq_kv}_d{head_dim}_b{bkv}",
        grid_dims=(LoopDim(h, H),),
        seq_dims=(LoopDim(s, _ceil(seq_kv, bkv)),),
        loads=loads, stores=stores, body=body,
        accumulators=(("O_acc", head_dim * 4), ("m_l", 2 * 4)))


def qk_matmul_program(batch_heads: int, seq_q: int, seq_kv: int,
                      head_dim: int, *, bq: int, bkv: int,
                      dtype_bytes: int = 2,
                      name: str = "qk_matmul") -> TileProgram:
    """The *unfused* attention score kernel: ``S[h, q, kv] = Q @ K^T``.

    Where :func:`flash_attention_program` fuses the whole attention forward
    into one tile body, this is the first half of the two-kernel chain the
    pipeline planner co-plans (qk -> softmax+pv): grid = (h, gq, gkv), no
    sequential loop (the contraction over ``head_dim`` fits one tile), and
    the score tile ``S`` is the intermediate tensor the graph edge carries.
    """
    H = batch_heads
    Q = TensorSpec("Q", (H, seq_q, head_dim), dtype_bytes)
    K = TensorSpec("K", (H, seq_kv, head_dim), dtype_bytes)
    S = TensorSpec("S", (H, seq_q, seq_kv), dtype_bytes)
    h, gq, gkv = "h", "gq", "gkv"
    loads = (
        TileAccess(Q, AffineMap.from_terms({h: 1}, {gq: 1}), (1, bq, head_dim),
                   "load"),
        TileAccess(K, AffineMap.from_terms({h: 1}, {gkv: 1}),
                   (1, bkv, head_dim), "load"),
    )
    stores = (
        TileAccess(S, AffineMap.from_terms({h: 1}, {gq: 1}, {gkv: 1}),
                   (1, bq, bkv), "store"),
    )
    body = (TileOp("qk_matmul", "mat", work=2.0 * bq * bkv * head_dim,
                   segment=0),)
    return TileProgram(
        name=f"{name}_h{H}_q{seq_q}_kv{seq_kv}_d{head_dim}_b{bq}x{bkv}",
        grid_dims=(LoopDim(h, H), LoopDim(gq, _ceil(seq_q, bq)),
                   LoopDim(gkv, _ceil(seq_kv, bkv))),
        seq_dims=(),
        loads=loads, stores=stores, body=body,
        accumulators=(("S_acc", bq * bkv * 4),))


def softmax_pv_program(batch_heads: int, seq_q: int, seq_kv: int,
                       head_dim: int, *, bq: int, bkv: int,
                       dtype_bytes: int = 2,
                       name: str = "softmax_pv") -> TileProgram:
    """The second half of the unfused attention chain:
    ``O[h, q, d] = softmax(S) @ V`` with the online-softmax statistics
    computed over the ``kv`` walk.  Loads the score tensor ``S`` produced by
    :func:`qk_matmul_program` — tile shape ``(1, bq, bkv)`` matches the
    producer's store tile exactly, which is the pipeline forwarding
    legality requirement."""
    H = batch_heads
    S = TensorSpec("S", (H, seq_q, seq_kv), dtype_bytes)
    V = TensorSpec("V", (H, seq_kv, head_dim), dtype_bytes)
    O = TensorSpec("O", (H, seq_q, head_dim), dtype_bytes)
    h, gq, kv = "h", "gq", "kv"
    loads = (
        TileAccess(S, AffineMap.from_terms({h: 1}, {gq: 1}, {kv: 1}),
                   (1, bq, bkv), "load"),
        TileAccess(V, AffineMap.from_terms({h: 1}, {kv: 1}),
                   (1, bkv, head_dim), "load"),
    )
    stores = (
        TileAccess(O, AffineMap.from_terms({h: 1}, {gq: 1}),
                   (1, bq, head_dim), "store"),
    )
    body = (
        TileOp("softmax_stats", "vec", work=4.0 * bq * bkv, segment=0),
        TileOp("rescale", "vec", work=2.0 * bq * head_dim, segment=0),
        TileOp("pv_matmul", "mat", work=2.0 * bq * bkv * head_dim, segment=1),
    )
    return TileProgram(
        name=f"{name}_h{H}_q{seq_q}_kv{seq_kv}_d{head_dim}_b{bq}x{bkv}",
        grid_dims=(LoopDim(h, H), LoopDim(gq, _ceil(seq_q, bq))),
        seq_dims=(LoopDim(kv, _ceil(seq_kv, bkv)),),
        loads=loads, stores=stores, body=body,
        accumulators=(("O_acc", bq * head_dim * 4), ("m_l", 2 * bq * 4)))


def moe_gmm_program(n_experts: int, capacity: int, d_in: int, d_out: int, *,
                    bm: int, bn: int, bk: int, dtype_bytes: int = 2,
                    acc_bytes: int = 4, name: str = "moe_gmm",
                    tensor_names: Tuple[str, str, str] = ("X", "W", "O")
                    ) -> TileProgram:
    """Grouped per-expert GEMM (the MoE FFN contraction):
    ``O[e, cap, d_out] = X[e, cap, d_in] @ W[e, d_in, d_out]``.

    Grid = (e over experts, gi over capacity tiles, gj over d_out tiles);
    sequential ``k`` over d_in tiles — the expert-contraction reduction.
    Small per-expert capacities with a deep ``d_in`` leave the parallel grid
    thin, exactly where a split-K bind on ``k`` pays.

    ``tensor_names`` renames (X, W, O) for pipeline graphs chaining two
    expert contractions through a shared hidden tensor; the default keeps
    every historical program identical.
    """
    xn, wn, on = tensor_names
    X = TensorSpec(xn, (n_experts, capacity, d_in), dtype_bytes)
    W = TensorSpec(wn, (n_experts, d_in, d_out), dtype_bytes)
    O = TensorSpec(on, (n_experts, capacity, d_out), dtype_bytes)
    e, gi, gj, k = "e", "gi", "gj", "k"
    loads = (
        TileAccess(X, AffineMap.from_terms({e: 1}, {gi: 1}, {k: 1}),
                   (1, bm, bk), "load"),
        TileAccess(W, AffineMap.from_terms({e: 1}, {k: 1}, {gj: 1}),
                   (1, bk, bn), "load"),
    )
    stores = (
        TileAccess(O, AffineMap.from_terms({e: 1}, {gi: 1}, {gj: 1}),
                   (1, bm, bn), "store"),
    )
    body = (TileOp("matmul", "mat", work=2.0 * bm * bn * bk, segment=0),)
    return TileProgram(
        name=f"{name}_e{n_experts}_c{capacity}_{d_in}x{d_out}_b{bm}x{bn}x{bk}",
        grid_dims=(LoopDim(e, n_experts), LoopDim(gi, _ceil(capacity, bm)),
                   LoopDim(gj, _ceil(d_out, bn))),
        seq_dims=(LoopDim(k, _ceil(d_in, bk)),),
        loads=loads, stores=stores, body=body,
        accumulators=(("O_acc", bm * bn * acc_bytes),))


def block_shape_candidates(M: int, N: int, K: int, *,
                           granule: int = 32,
                           max_block: int = 256) -> Tuple[Tuple[int, int, int], ...]:
    """Front-end block-shape exploration (paper S2.1: "It explores candidate
    block shapes: tile sizes and layouts").  Powers-of-two multiples of the
    hardware granule (Tensix tiles are 32x32; TPU MXU lanes are 128)."""
    opts = []
    size = granule
    while size <= max_block:
        opts.append(size)
        size *= 2
    cands = []
    for bm in opts:
        if bm > max(granule, M):
            continue
        for bn in opts:
            if bn > max(granule, N):
                continue
            for bk in opts:
                if bk > max(granule, K):
                    continue
                cands.append((bm, bn, bk))
    return tuple(cands)
