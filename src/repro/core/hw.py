"""The ``df`` hardware-representation dialect (paper S2.4), in Python.

The paper encodes hardware as an MLIR dialect; we mirror it 1:1 as a small set
of frozen dataclasses plus a :class:`HardwareModel` container.  The three
abstraction layers of the paper are preserved:

* **scale-out**  — ``SpatialDim`` / ``Core`` / ``Interconnect``   (consumed by
  spatiotemporal mapping, S2.2)
* **memories**   — ``Memory`` / ``Mux``                            (consumed by
  data-movement planning, S2.3)
* **intra-core** — ``MatUnit`` / ``VecUnit`` / ``ScalarUnit``      (consumed by
  the performance model, S2.5)

``HardwareModel.df_text()`` renders the description in the paper's textual
``df``-dialect syntax so that tests can assert structural fidelity with the
paper's Listings 6-9.

Presets are provided for the paper's evaluation targets (Tenstorrent Wormhole
8x8 / 4x8 / 1x8, an IBM-Spyre-like 1D triple ring) and for the TPU-v5e targets
of the deployment layer (16x16 single pod, 2x16x16 multi-pod) — see DESIGN.md
S4 for the adaptation rationale.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from .affine import AffineExpr, AffineMap

GB = 1_000_000_000
MB = 1_000_000


# --------------------------------------------------------------------------
# df operators
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class SpatialDim:
    """``df.spatial_dim(size)`` — an abstract dimension indexing replicated
    hardware components (cores, memories, DRAM channels...)."""
    name: str
    size: int


@dataclass(frozen=True)
class MatUnit:
    """``df.mat(shape, throughput)`` — a matrix unit (MXU / Tensix FPU).

    ``shape=(m, k, n)`` is the intrinsic matmul tile; ``intrinsics_per_cycle``
    is the paper's per-unit issue rate ``r`` (may be fractional: an intrinsic
    that takes 128 cycles has r = 1/128).
    """
    name: str
    shape: Tuple[int, int, int]
    intrinsics_per_cycle: float
    count: int = 1

    @property
    def flops_per_intrinsic(self) -> int:
        m, k, n = self.shape
        return 2 * m * k * n

    def flops_per_cycle(self) -> float:
        return self.flops_per_intrinsic * self.intrinsics_per_cycle * self.count


@dataclass(frozen=True)
class VecUnit:
    """``df.vec(shape, throughput)`` — a vector/SIMD unit; ``width`` lanes,
    ``r`` intrinsic issues per cycle (one intrinsic = ``width`` element ops)."""
    name: str
    width: int
    intrinsics_per_cycle: float
    count: int = 1

    def elems_per_cycle(self) -> float:
        return self.width * self.intrinsics_per_cycle * self.count


@dataclass(frozen=True)
class ScalarUnit:
    """``df.scalar(latency)``."""
    name: str
    latency_cycles: float = 1.0


@dataclass(frozen=True)
class Core:
    """``df.core(scaleout, scalein)`` — a set of cores indexed by spatial dims
    with intra-core compute units."""
    name: str
    scaleout: Tuple[str, ...]                      # spatial-dim names
    mat: Optional[MatUnit] = None
    vec: Optional[VecUnit] = None
    scalar: Optional[ScalarUnit] = None


@dataclass(frozen=True)
class Memory:
    """``df.memory(scaleout, size, bandwidth)`` — replicated memories.

    ``bandwidth_gbps`` is per-instance port bandwidth.  ``level`` tags the role
    in the hierarchy ("local" scratchpad vs "global" DRAM/HBM) — the paper's
    listings distinguish these by how they are wired (mux vs interconnect); we
    keep an explicit tag as well for planner convenience.
    """
    name: str
    scaleout: Tuple[str, ...]
    size_bytes: int
    bandwidth_gbps: float
    level: str = "local"          # "local" | "global"

    def count(self, hw: "HardwareModel") -> int:
        n = 1
        for d in self.scaleout:
            n *= hw.dim(d).size
        return n


@dataclass(frozen=True)
class Mux:
    """``df.mux(dst, srcs, map)`` — 1-to-N connectivity (e.g. "each core
    accesses its local scratchpad", "groups of cores share a DRAM channel")."""
    name: str
    dst: str                       # component name (cores)
    src: str                       # component name (memories)
    map: AffineMap                 # dst coords -> src coords
    bandwidth_gbps: float


@dataclass(frozen=True)
class Interconnect:
    """``df.interconnects(components, map, bandwidth)`` — a set of links
    connecting ``src`` to ``dst`` instances per an affine map; per-link
    bandwidth.  For a 2D mesh's horizontal ring the map is
    ``(d0, d1) -> ((d0 + 1) mod X, d1)`` (paper Listing 6)."""
    name: str
    src: str
    dst: str
    map: AffineMap
    bandwidth_gbps: float

    def axis(self, dims: Sequence[str]) -> Optional[str]:
        """The spatial dim along which this interconnect moves data: the
        (single) output coordinate that is not the identity of its input dim.
        Returns None for non-shift topologies."""
        moved = []
        for i, d in enumerate(dims):
            e = self.map.exprs[i] if i < len(self.map.exprs) else None
            if e is None:
                continue
            identity = (e.coeffs == ((d, 1),) and e.const == 0
                        and e.mod is None and e.floordiv is None)
            if not identity:
                moved.append(d)
        return moved[0] if len(moved) == 1 else None


# --------------------------------------------------------------------------
# HardwareModel
# --------------------------------------------------------------------------
@dataclass
class HardwareModel:
    """A complete multi-layer df description of one target."""

    name: str
    clock_ghz: float
    spatial_dims: Tuple[SpatialDim, ...]
    core: Core
    local_mem: Memory
    core_to_local: Mux
    global_mem: Memory
    to_global: Mux                 # cores/L1 -> DRAM/HBM channel map
    interconnects: Tuple[Interconnect, ...]
    # Optional second-level scratch (e.g. TPU VMEM inside a chip whose "L1"
    # is HBM at the mesh planning level).
    scratch_mem: Optional[Memory] = None
    notes: str = ""
    # -- fault overlay (see with_faults) --------------------------------------
    # Coordinates are in ``core.scaleout`` order; link entries are
    # ``(interconnect_name, cumulative_bandwidth_factor)``.  Both participate
    # in ``df_text()`` so plan-cache keys distinguish degraded fabrics.
    disabled_cores: Tuple[Tuple[int, ...], ...] = ()
    degraded_links: Tuple[Tuple[str, float], ...] = ()

    # -- indexing ------------------------------------------------------------
    def dim(self, name: str) -> SpatialDim:
        for d in self.spatial_dims:
            if d.name == name:
                return d
        raise KeyError(name)

    @property
    def mesh_dims(self) -> Tuple[Tuple[str, int], ...]:
        """Spatial dims that index cores, in declaration order."""
        return tuple((d, self.dim(d).size) for d in self.core.scaleout)

    @property
    def n_cores(self) -> int:
        return math.prod(s for _, s in self.mesh_dims)

    # -- interconnect queries --------------------------------------------------
    def interconnect_along(self, axis: str) -> Optional[Interconnect]:
        # queried per load option per mapping (the reuse analysis and both
        # cost engines); the answer is a pure function of the immutable
        # interconnect tuple, so memoize per instance
        cache = self.__dict__.get("_ic_along")
        if cache is None:
            cache = self.__dict__["_ic_along"] = {}
        if axis not in cache:
            cache[axis] = next(
                (ic for ic in self.interconnects
                 if ic.src == self.local_mem.name
                 and ic.axis(self.core.scaleout) == axis), None)
        return cache[axis]

    def noc_axes(self) -> Tuple[str, ...]:
        axes = self.__dict__.get("_noc_axes")
        if axes is None:
            axes = self.__dict__["_noc_axes"] = tuple(
                a for a, _ in self.mesh_dims if self.interconnect_along(a))
        return axes

    def links_of(self, ic: Interconnect) -> int:
        """Total number of physical links the interconnect declares (one per
        source instance for shift maps)."""
        n = 1
        for d in self.core.scaleout:
            n *= self.dim(d).size
        return n

    # -- memory queries --------------------------------------------------------
    def global_channels(self) -> int:
        return self.global_mem.count(self)

    def channel_of_core(self, coords: Mapping[str, int]) -> Tuple[int, ...]:
        return self.to_global.map.evaluate(dict(coords))

    def cores_per_channel(self) -> int:
        return max(1, self.n_cores // max(1, self.global_channels()))

    def local_capacity(self) -> int:
        return self.local_mem.size_bytes

    # -- fault overlay ---------------------------------------------------------
    @property
    def is_degraded(self) -> bool:
        return bool(self.disabled_cores or self.degraded_links)

    def disabled_core_set(self) -> frozenset:
        """Disabled core coordinates as a frozenset of tuples (memoized)."""
        s = self.__dict__.get("_disabled_set")
        if s is None:
            s = self.__dict__["_disabled_set"] = frozenset(self.disabled_cores)
        return s

    def is_disabled(self, coords: Mapping[str, int]) -> bool:
        """Whether the core at ``coords`` (spatial-dim name -> index; unbound
        dims default to plane 0) is disabled by the fault overlay."""
        if not self.disabled_cores:
            return False
        key = tuple(coords.get(d, 0) for d in self.core.scaleout)
        return key in self.disabled_core_set()

    @property
    def healthy_cores(self) -> int:
        return self.n_cores - len(self.disabled_cores)

    def with_faults(self, disabled_cores: Sequence[Sequence[int]] = (),
                    degraded_links: Sequence[Tuple[str, float]] = ()
                    ) -> "HardwareModel":
        """A copy of this model with additional faults applied on top of any
        existing overlay.

        ``disabled_cores`` are core coordinates (``core.scaleout`` order) that
        no mapping may ever activate; ``degraded_links`` are
        ``(interconnect_name, factor)`` pairs scaling per-link bandwidth by
        ``factor`` (0 < factor <= 1).  Repeated degradation of the same link
        composes multiplicatively.  The copy keeps the base ``name`` — the
        overlay is distinguished by ``df_text()`` (and therefore by plan-cache
        hardware digests), not by renaming.
        """
        import dataclasses

        n_dims = len(self.core.scaleout)
        new_disabled = set(self.disabled_cores)
        for c in disabled_cores:
            t = tuple(int(v) for v in c)
            if len(t) != n_dims:
                raise ValueError(
                    f"disabled core {t} has {len(t)} coords; "
                    f"{self.name} cores are indexed by {self.core.scaleout}")
            for v, d in zip(t, self.core.scaleout):
                if not 0 <= v < self.dim(d).size:
                    raise ValueError(f"disabled core {t}: coord {d}={v} out of "
                                     f"range [0, {self.dim(d).size})")
            new_disabled.add(t)
        if len(new_disabled) >= self.n_cores:
            raise ValueError(f"cannot disable all {self.n_cores} cores of "
                             f"{self.name}")

        ic_names = {ic.name for ic in self.interconnects}
        factors: Dict[str, float] = dict(self.degraded_links)
        scale: Dict[str, float] = {}
        for name, f in degraded_links:
            if name not in ic_names:
                raise ValueError(f"unknown interconnect {name!r}; "
                                 f"available: {sorted(ic_names)}")
            f = float(f)
            if not 0.0 < f <= 1.0:
                raise ValueError(f"degradation factor for {name} must be in "
                                 f"(0, 1], got {f}")
            factors[name] = factors.get(name, 1.0) * f
            scale[name] = scale.get(name, 1.0) * f
        new_ics = tuple(
            dataclasses.replace(ic, bandwidth_gbps=ic.bandwidth_gbps * scale[ic.name])
            if ic.name in scale else ic
            for ic in self.interconnects)
        # dataclasses.replace re-runs __init__, so per-instance memo caches
        # (_ic_along, _noc_axes, _disabled_set, ...) are dropped in the copy.
        return dataclasses.replace(
            self, interconnects=new_ics,
            disabled_cores=tuple(sorted(new_disabled)),
            degraded_links=tuple(sorted(factors.items())))

    # -- compute queries -------------------------------------------------------
    def peak_flops_per_core(self) -> float:
        if self.core.mat is None:
            return 0.0
        return self.core.mat.flops_per_cycle() * self.clock_ghz * 1e9

    def peak_flops(self) -> float:
        return self.peak_flops_per_core() * self.n_cores

    def peak_vec_elems_per_core(self) -> float:
        if self.core.vec is None:
            return 0.0
        return self.core.vec.elems_per_cycle() * self.clock_ghz * 1e9

    # -- df-dialect text --------------------------------------------------------
    def df_text(self) -> str:
        lines: List[str] = [f"// df description of {self.name}"]
        for d in self.spatial_dims:
            lines.append(f"%{d.name} = df.spatial_dim {d.size}")
        core = self.core
        scalein = []
        if core.mat:
            m = core.mat
            lines.append(
                f"%{m.name} = df.mat {{shape=[{m.shape[0]}, {m.shape[1]}, "
                f"{m.shape[2]}], throughput={m.intrinsics_per_cycle:g}}}")
            scalein.append(f"%{m.name}")
        if core.vec:
            v = core.vec
            lines.append(
                f"%{v.name} = df.vec {{shape=[{v.width}], "
                f"throughput={v.intrinsics_per_cycle:g}}}")
            scalein.append(f"%{v.name}")
        if core.scalar:
            s = core.scalar
            lines.append(f"%{s.name} = df.scalar {{latency={s.latency_cycles:g}}}")
            scalein.append(f"%{s.name}")
        so = ", ".join(f"%{d}" for d in core.scaleout)
        si = f", scalein=({', '.join(scalein)})" if scalein else ""
        lines.append(f"%{core.name} = df.core {{scaleout=({so}){si}}}")
        for mem in filter(None, [self.local_mem, self.scratch_mem, self.global_mem]):
            so = ", ".join(f"%{d}" for d in mem.scaleout)
            lines.append(
                f"%{mem.name} = df.memory {{scaleout=({so}), size={mem.size_bytes}, "
                f"bandwidth={mem.bandwidth_gbps:g}}}")
        for mux in [self.core_to_local, self.to_global]:
            lines.append(
                f"%{mux.name} = df.mux %{mux.dst}, %{mux.src}, "
                f"{{map={_map_text(mux.map)}, bandwidth={mux.bandwidth_gbps:g}}}")
        for ic in self.interconnects:
            lines.append(
                f"%{ic.name} = df.interconnects %{ic.src}, %{ic.dst}, "
                f"{{map={_map_text(ic.map)}, bandwidth={ic.bandwidth_gbps:g}}}")
        # Fault overlay: rendered last so a fault-free model's text is
        # byte-identical to pre-overlay output.  Degraded links already show
        # in the interconnect bandwidths above; the explicit lines make the
        # overlay legible and fork the hardware digest for disabled cores.
        for c in self.disabled_cores:
            coords = ", ".join(str(v) for v in c)
            lines.append(f"df.fault disable %{core.name}[{coords}]")
        for lname, f in self.degraded_links:
            lines.append(f"df.fault degrade %{lname} {{factor={f:g}}}")
        return "\n".join(lines)


def _map_text(m: AffineMap) -> str:
    ins = sorted(m.dims)
    outs = ", ".join(repr(e) for e in m.exprs)
    return f"affine_map<({', '.join(ins)}) -> ({outs})>"


# --------------------------------------------------------------------------
# Presets — paper targets (Tenstorrent Wormhole n300 socket)
# --------------------------------------------------------------------------
def _ring_map(dims: Sequence[Tuple[str, int]], axis: str, stride: int = 1) -> AffineMap:
    exprs = []
    for d, size in dims:
        if d == axis:
            exprs.append((AffineExpr.var(d) + AffineExpr.const_expr(stride)).with_mod(size))
        else:
            exprs.append(AffineExpr.var(d))
    return AffineMap(tuple(exprs))


def wormhole(rows: int = 8, cols: int = 8) -> HardwareModel:
    """Tenstorrent Wormhole socket as described in paper Listings 6-8 and S3.1.

    Constants from the paper: 64 Tensix cores @ 1 GHz, 1024 FP16 ops/cycle/core
    (=> 64 TFLOP/s per socket), ~1.5 MB L1 per core (1_499_136 B) at 60 GB/s,
    NoC rings at 28 GB/s/link, 12 GB GDDR6 at 288 GB/s total across 4 edge
    channel groups (each 4x4 quadrant of cores shares one channel), 30 GB/s
    core<->DRAM link.  ``rows``/``cols`` select the paper's three logical
    configurations: 8x8 full mesh, 4x8 asymmetric submesh, 1x8 ring.
    """
    x = SpatialDim("x", rows)
    y = SpatialDim("y", cols)
    dims = (x, y)
    # Tensix FPU: a 32x32x32 intrinsic is 32768 MACs = 65536 flops; at the
    # nominal 1024 flops/cycle it retires every 64 cycles (r = 1/64).  The
    # paper calibrates df throughputs from isolated microbenchmarks (S3.1) and
    # observes sustained GEMM throughput "stabilizes around 45 TOP/s" (S3.3
    # footnote), i.e. ~0.7 of nominal peak — we plug the same sustained rate
    # into the df description: r = 0.7/64.
    fpu = MatUnit("FPU", (32, 32, 32), intrinsics_per_cycle=0.7 / 64.0)
    sfpu = VecUnit("SFPU", width=32, intrinsics_per_cycle=1.0)
    core = Core("cores", ("x", "y"), mat=fpu, vec=sfpu, scalar=ScalarUnit("RISCV", 1.0))
    l1 = Memory("l1", ("x", "y"), size_bytes=1_499_136, bandwidth_gbps=60.0, level="local")
    core_to_l1 = Mux("core_to_l1", "cores", "l1",
                     AffineMap.identity(["x", "y"]), bandwidth_gbps=60.0)
    groups_x, groups_y = max(1, rows // 4), max(1, cols // 4)
    dram_channels = groups_x * groups_y
    dram_idx = SpatialDim("dram_idx", dram_channels)
    dram_total_gbps = 288.0
    dram = Memory("drams", ("dram_idx",), size_bytes=12 * GB,
                  bandwidth_gbps=dram_total_gbps / dram_channels, level="global")
    # Paper Listing 7: channel = (d0 floordiv 4) + groups_x * (d1 floordiv 4)
    ch_map = AffineMap((_channel_expr(rows, cols),))
    to_dram = Mux("to_dram", "l1", "drams", ch_map, bandwidth_gbps=30.0)
    ics = []
    if rows > 1:
        ics.append(Interconnect("noc_h", "l1", "l1",
                                _ring_map([("x", rows), ("y", cols)], "x"), 28.0))
    if cols > 1:
        ics.append(Interconnect("noc_v", "l1", "l1",
                                _ring_map([("x", rows), ("y", cols)], "y"), 28.0))
    return HardwareModel(
        name=f"wormhole_{rows}x{cols}", clock_ghz=1.0, spatial_dims=(x, y, dram_idx),
        core=core, local_mem=l1, core_to_local=core_to_l1, global_mem=dram,
        to_global=to_dram, interconnects=tuple(ics),
        notes="Tenstorrent Wormhole n300 socket (paper S3.1, Listings 6-8)")


def _channel_expr(rows: int, cols: int) -> AffineExpr:
    """Composite channel map ``x//4 + groups_x*(y//4)`` (paper Listing 7) — the
    only non-single-floordiv map in the paper; implemented as a small subclass
    overriding ``evaluate`` so the rest of the algebra stays simple."""
    groups_x = max(1, rows // 4)

    class _E(AffineExpr):
        def evaluate(self, env: Mapping[str, int]) -> int:  # type: ignore[override]
            return (env.get("x", 0) // 4) + groups_x * (env.get("y", 0) // 4)

    return _E(coeffs=(("x", 1), ("y", 1)))  # dims recorded for dependence queries


def spyre_triple_ring(n: int = 32) -> HardwareModel:
    """IBM-Spyre-like 1D triple-ring (paper Fig 3 / Listing 9): one spatial
    dim, three ring interconnects with different hop strides and bandwidths."""
    p = SpatialDim("p", n)
    mat = MatUnit("PT", (32, 32, 32), intrinsics_per_cycle=1.0 / 64.0)
    vec = VecUnit("VU", width=64, intrinsics_per_cycle=1.0)
    core = Core("cores", ("p",), mat=mat, vec=vec)
    l0 = Memory("l0", ("p",), size_bytes=2 * MB, bandwidth_gbps=100.0, level="local")
    mux = Mux("core_to_l0", "cores", "l0", AffineMap.identity(["p"]), 100.0)
    hbm_idx = SpatialDim("hbm_idx", 4)
    hbm = Memory("lpddr", ("hbm_idx",), size_bytes=32 * GB, bandwidth_gbps=50.0,
                 level="global")
    to_hbm = Mux("to_lpddr", "l0", "lpddr",
                 AffineMap((AffineExpr.var("p").with_floordiv(max(1, n // 4)),)), 25.0)
    ics = (
        Interconnect("ring0", "l0", "l0", _ring_map([("p", n)], "p", 1), 32.0),
        Interconnect("ring1", "l0", "l0", _ring_map([("p", n)], "p", 2), 16.0),
        Interconnect("ring2", "l0", "l0", _ring_map([("p", n)], "p", 4), 8.0),
    )
    return HardwareModel(
        name=f"spyre_ring_{n}", clock_ghz=1.0, spatial_dims=(p, hbm_idx), core=core,
        local_mem=l0, core_to_local=mux, global_mem=hbm, to_global=to_hbm,
        interconnects=ics, notes="1D triple-ring example (paper Fig 3, Listing 9)")


# --------------------------------------------------------------------------
# Presets — TPU deployment targets (DESIGN.md S4 adaptation)
# --------------------------------------------------------------------------
TPU_V5E_PEAK_BF16 = 197e12      # FLOP/s per chip (assignment constant)
TPU_V5E_HBM_GBPS = 819.0        # GB/s per chip
TPU_V5E_ICI_GBPS = 50.0         # GB/s per link per direction
TPU_V5E_HBM_BYTES = 16 * GB
TPU_V5E_VMEM_BYTES = 128 * MB


def tpu_v5e_pod(data: int = 16, model: int = 16, pods: int = 1,
                clock_ghz: float = 0.94) -> HardwareModel:
    """A TPU-v5e pod described in the *same* df dialect, at mesh granularity:
    chips are the ``df.core``s, HBM is the per-core memory, ICI rings are the
    interconnects, and the host/DCN-attached storage is the "global" level.

    The MXU is a 128x128x128 intrinsic; r is chosen so the peak matches the
    assignment's 197 TFLOP/s bf16.  VMEM is exposed as ``scratch_mem`` and is
    what the Pallas BlockSpec planner sizes against (the paper's L1 analogue
    one level down).
    """
    dims = []
    core_dims = []
    if pods > 1:
        dims.append(SpatialDim("pod", pods)); core_dims.append("pod")
    dims.append(SpatialDim("data", data)); core_dims.append("data")
    dims.append(SpatialDim("model", model)); core_dims.append("model")
    intrinsic = (128, 128, 128)
    flops_per_intr = 2 * 128 ** 3
    r = TPU_V5E_PEAK_BF16 / (flops_per_intr * clock_ghz * 1e9)
    mxu = MatUnit("MXU", intrinsic, intrinsics_per_cycle=r)
    vpu = VecUnit("VPU", width=1024, intrinsics_per_cycle=4.0)
    core = Core("chips", tuple(core_dims), mat=mxu, vec=vpu,
                scalar=ScalarUnit("SC", 1.0))
    hbm = Memory("hbm", tuple(core_dims), size_bytes=TPU_V5E_HBM_BYTES,
                 bandwidth_gbps=TPU_V5E_HBM_GBPS, level="local")
    vmem = Memory("vmem", tuple(core_dims), size_bytes=TPU_V5E_VMEM_BYTES,
                  bandwidth_gbps=22_000.0, level="local")
    mux = Mux("chip_to_hbm", "chips", "hbm",
              AffineMap.identity(list(core_dims)), TPU_V5E_HBM_GBPS)
    host_idx = SpatialDim("host_idx", max(1, (data * model * pods) // 4))
    host = Memory("hostmem", ("host_idx",), size_bytes=512 * GB,
                  bandwidth_gbps=25.0, level="global")   # PCIe/DCN feed
    to_host = Mux("to_host", "hbm", "hostmem",
                  AffineMap((AffineExpr.var(core_dims[-1]).with_floordiv(4),)), 25.0)
    pairs = [(d, s) for d, s in ((n, next(x.size for x in dims if x.name == n))
                                 for n in core_dims)]
    ics = []
    for axis, size in pairs:
        if size > 1:
            bw = TPU_V5E_ICI_GBPS if axis != "pod" else 25.0   # DCN between pods
            ics.append(Interconnect(f"ici_{axis}", "hbm", "hbm",
                                    _ring_map(pairs, axis), bw))
    return HardwareModel(
        name=f"tpu_v5e_{'x'.join(str(s) for _, s in pairs)}", clock_ghz=clock_ghz,
        spatial_dims=tuple(dims) + (host_idx,), core=core, local_mem=hbm,
        core_to_local=mux, global_mem=host, to_global=to_host,
        interconnects=tuple(ics), scratch_mem=vmem,
        notes="TPU v5e pod at mesh granularity (DESIGN.md S4)")


def tpu_v5e_chip() -> HardwareModel:
    """A single TPU chip at *intra-chip* granularity for the Pallas BlockSpec
    planner: the 'cores' are the (8, 128)-lane compute over a 1x1 'mesh', the
    local memory is VMEM, and the 'global' memory is that chip's HBM.  This is
    the paper's original granularity (L1 scratchpad <-> DRAM) transplanted one
    level down the TPU hierarchy."""
    u = SpatialDim("u", 1)
    clock = 0.94
    r = TPU_V5E_PEAK_BF16 / (2 * 128 ** 3 * clock * 1e9)
    mxu = MatUnit("MXU", (128, 128, 128), intrinsics_per_cycle=r)
    vpu = VecUnit("VPU", width=1024, intrinsics_per_cycle=4.0)
    core = Core("tc", ("u",), mat=mxu, vec=vpu, scalar=ScalarUnit("SC", 1.0))
    vmem = Memory("vmem", ("u",), size_bytes=TPU_V5E_VMEM_BYTES,
                  bandwidth_gbps=22_000.0, level="local")
    mux = Mux("tc_to_vmem", "tc", "vmem", AffineMap.identity(["u"]), 22_000.0)
    hbm_idx = SpatialDim("hbm_idx", 1)
    hbm = Memory("hbm", ("hbm_idx",), size_bytes=TPU_V5E_HBM_BYTES,
                 bandwidth_gbps=TPU_V5E_HBM_GBPS, level="global")
    to_hbm = Mux("to_hbm", "vmem", "hbm", AffineMap((AffineExpr.const_expr(0),)),
                 TPU_V5E_HBM_GBPS)
    return HardwareModel(
        name="tpu_v5e_chip", clock_ghz=clock, spatial_dims=(u, hbm_idx), core=core,
        local_mem=vmem, core_to_local=mux, global_mem=hbm, to_global=to_hbm,
        interconnects=(), notes="single-chip VMEM/MXU model for BlockSpec planning")


PRESETS = {
    "wormhole_8x8": lambda: wormhole(8, 8),
    "wormhole_4x8": lambda: wormhole(4, 8),
    "wormhole_1x8": lambda: wormhole(1, 8),
    "spyre_ring": lambda: spyre_triple_ring(32),
    "tpu_v5e_pod": lambda: tpu_v5e_pod(16, 16, 1),
    "tpu_v5e_2pod": lambda: tpu_v5e_pod(16, 16, 2),
    "tpu_v5e_chip": tpu_v5e_chip,
}


def get_hw(name: str) -> HardwareModel:
    try:
        return PRESETS[name]()
    except KeyError as e:
        raise KeyError(f"unknown hardware preset {name!r}; "
                       f"available: {sorted(PRESETS)}") from e
