"""Spatiotemporal mapping (paper S2.2).

A mapping decides how the iteration space of the ``affine.parallel`` loop —
the logical multidimensional tile grid — is assigned to physical cores and to
time.  Following the paper, mappings are *tiling-based*: contiguous regions of
the iteration space go to contiguous spatial regions of the core array or to
contiguous temporal *waves*.

The design space is the paper's three coupled choices:

1. each parallel (grid) dim maps to **zero or more** hardware spatial dims;
2. when a grid dim is tiled by multiple spatial dims, the **tiling order**
   matters (different orders induce different layouts / reuse);
3. residual extents become **temporal wave loops** whose order is itself a
   design choice.

``enumerate_mappings`` produces the full space; each :class:`Mapping` then
yields the concrete loop-nest structure (Listing 2) and rewritten affine
accesses that reuse analysis and the performance model consume.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Dict, Iterator, List, Mapping as TMapping, Optional, Sequence, Tuple

from .affine import AffineExpr, AffineMap
from .hw import HardwareModel
from .program import LoopDim, TileAccess, TileProgram


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


@dataclass(frozen=True)
class SpatialBind:
    """One hardware spatial dim consumed by one grid dim."""
    hw_dim: str
    hw_size: int
    grid_dim: str


@dataclass(frozen=True)
class TemporalLoop:
    """A wave loop over the residual extent of one grid dim (Listing 2's
    ``%tx`` / ``%ty``)."""
    name: str                      # "t_<grid_dim>"
    grid_dim: str
    extent: int


@dataclass(frozen=True)
class Mapping:
    """A fixed spatiotemporal mapping of a program onto a hardware mesh.

    Loop-nest structure implied (outermost -> innermost), matching Listing 2:

        affine.parallel (hw spatial dims)        # truly parallel cores
          affine.for (temporal wave loops, in ``temporal`` order)
            scf.for (program sequential dims)
              <tile body>
    """
    program: TileProgram
    hw_name: str
    hw_dims: Tuple[Tuple[str, int], ...]          # full mesh (name, size)
    spatial: Tuple[SpatialBind, ...]              # tiling order: outer digit first
    temporal: Tuple[TemporalLoop, ...]            # outer -> inner

    # -- derived structure -----------------------------------------------------
    def spatial_for(self, grid_dim: str) -> Tuple[SpatialBind, ...]:
        return tuple(b for b in self.spatial if b.grid_dim == grid_dim)

    def spatial_factor(self, grid_dim: str) -> int:
        return math.prod(b.hw_size for b in self.spatial_for(grid_dim)) or 1

    def wave_extent(self, grid_dim: str) -> int:
        return _ceil(self.program.dim(grid_dim).extent, self.spatial_factor(grid_dim))

    def used_hw_dims(self) -> Tuple[str, ...]:
        return tuple(b.hw_dim for b in self.spatial)

    def idle_hw_dims(self) -> Tuple[Tuple[str, int], ...]:
        used = set(self.used_hw_dims())
        return tuple((n, s) for n, s in self.hw_dims if n not in used)

    def active_cores(self) -> int:
        # called once per load option by the demand model — cache on the
        # frozen instance (does not enter dataclass eq/hash)
        n = self.__dict__.get("_active_cores")
        if n is None:
            n = 1
            for b in self.spatial:
                n *= min(b.hw_size, self.program.dim(b.grid_dim).extent)
            object.__setattr__(self, "_active_cores", n)
        return n

    def total_cores(self) -> int:
        return math.prod(s for _, s in self.hw_dims)

    def utilization(self) -> float:
        """Fraction of (core x wave) slots holding real (non-padded) tiles."""
        u = 1.0
        for d in self.program.grid_dims:
            padded = self.spatial_factor(d.name) * self.wave_extent(d.name)
            u *= d.extent / padded
        # idle hw dims waste whole planes of the machine
        for _, s in self.idle_hw_dims():
            u /= s
        return u

    def n_waves(self) -> int:
        return math.prod(t.extent for t in self.temporal) or 1

    # -- index rewriting ---------------------------------------------------------
    def grid_index_expr(self, grid_dim: str) -> AffineExpr:
        """Reconstruct the logical grid index from (wave, spatial digits).

        With binds [h1(s1), h2(s2)] (tiling order: h1 outer) and wave t:
            g = t * s1 * s2 + h1 * s2 + h2

        Memoized per instance: the reuse analysis rewrites every access of
        every mapping through these expressions.
        """
        cache = self.__dict__.get("_grid_exprs")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_grid_exprs", cache)
        hit = cache.get(grid_dim)
        if hit is not None:
            return hit
        expr = self._grid_index_expr(grid_dim)
        cache[grid_dim] = expr
        return expr

    def _grid_index_expr(self, grid_dim: str) -> AffineExpr:
        binds = self.spatial_for(grid_dim)
        terms: Dict[str, int] = {}
        stride = 1
        for b in reversed(binds):              # innermost digit has stride 1
            terms[b.hw_dim] = stride
            stride *= b.hw_size
        t = self._temporal_for(grid_dim)
        if t is not None and t.extent > 1:
            terms[t.name] = stride
        elif t is not None:
            pass                                # extent-1 wave: index 0
        return AffineExpr.linear(terms)

    def _temporal_for(self, grid_dim: str) -> Optional[TemporalLoop]:
        for t in self.temporal:
            if t.grid_dim == grid_dim:
                return t
        return None

    def rewrite_access(self, access: TileAccess) -> AffineMap:
        """Substitute grid dims with their (wave, spatial) reconstruction.

        Cached on the (shared, frozen) access object keyed by the grid
        expressions actually substituted: mappings that reconstruct the
        access's grid dims identically — very common across the enumerated
        space — share one rewritten map object (which in turn lets the
        downstream footprint analysis memoize per rewritten map).
        """
        m = access.index
        subs = tuple((d.name, self.grid_index_expr(d.name))
                     for d in self.program.grid_dims
                     if m.depends_on(d.name))
        cache = access.__dict__.get("_rewrite_cache")
        if cache is None:
            cache = {}
            object.__setattr__(access, "_rewrite_cache", cache)
        hit = cache.get(subs)
        if hit is not None:
            return hit
        for name, expr in subs:
            m = m.substitute(name, expr)
        cache[subs] = m
        return m

    # -- loop nest (for reuse analysis & printing) --------------------------------
    def loop_nest(self) -> Tuple[Tuple[str, str, int], ...]:
        """(kind, name, extent) outer->inner; kind in
        {"spatial", "temporal", "sequential"}."""
        nest: List[Tuple[str, str, int]] = []
        for b in self.spatial:
            nest.append(("spatial", b.hw_dim, b.hw_size))
        for t in self.temporal:
            nest.append(("temporal", t.name, t.extent))
        for d in self.program.seq_dims:
            nest.append(("sequential", d.name, d.extent))
        return tuple(nest)

    def extents_env(self) -> Dict[str, int]:
        env = dict(self.program.extents)
        for b in self.spatial:
            env[b.hw_dim] = b.hw_size
        for t in self.temporal:
            env[t.name] = t.extent
        return env

    def describe(self) -> str:
        sp = ", ".join(f"{b.grid_dim}->%{b.hw_dim}({b.hw_size})" for b in self.spatial)
        tp = ", ".join(f"{t.name}({t.extent})" for t in self.temporal)
        return f"[spatial: {sp or '-'} | temporal: {tp or '-'}]"

    def mlir_like(self) -> str:
        """Render the mapped loop structure in the paper's Listing-2 style."""
        lines = []
        sp_dims = ", ".join(f"%{b.hw_dim}" for b in self.spatial)
        sp_sizes = ", ".join(str(b.hw_size) for b in self.spatial)
        indent = ""
        if self.spatial:
            lines.append(f"affine.parallel ({sp_dims}) = (0) to ({sp_sizes}) {{")
            indent += "  "
        for t in self.temporal:
            lines.append(f"{indent}affine.for %{t.name} = 0 to {t.extent} {{")
            indent += "  "
        for d in self.program.seq_dims:
            lines.append(f"{indent}scf.for %{d.name} = 0 to {d.extent} {{")
            indent += "  "
        lines.append(f"{indent}// tile body: "
                     + ", ".join(op.kind for op in self.program.body))
        while indent:
            indent = indent[:-2]
            lines.append(f"{indent}}}")
        return "\n".join(lines)


# --------------------------------------------------------------------------
# Enumeration
# --------------------------------------------------------------------------
def enumerate_mappings(program: TileProgram, hw: HardwareModel, *,
                       allow_idle_dims: bool = True,
                       max_candidates: int = 512) -> Tuple[Mapping, ...]:
    """Enumerate the paper's mapping design space.

    For every function ``hw_dim -> grid_dim | idle`` we derive the set of
    spatial binds; for every grid dim bound to >=2 hw dims we expand all tiling
    orders; residual grid dims with wave extent > 1 generate temporal loops in
    all orders.  Degenerate duplicates (idle dims that could host work while a
    grid dim still has residual extent) are kept only if ``allow_idle_dims`` —
    they are occasionally optimal for very small grids (paper S3.2 small-shape
    regime).
    """
    program.validate()
    mesh = hw.mesh_dims
    grid_names = [d.name for d in program.grid_dims]
    choices = [grid_names + [None] for _ in mesh]
    out: List[Mapping] = []
    seen = set()
    for combo in itertools.product(*choices):
        # binds grouped by grid dim, in mesh order
        by_grid: Dict[str, List[Tuple[str, int]]] = {}
        for (hw_name, hw_size), g in zip(mesh, combo):
            if g is not None:
                by_grid.setdefault(g, []).append((hw_name, hw_size))
        if not allow_idle_dims and len(by_grid) == 0 and grid_names:
            continue
        # skip assignments where a hw dim is idle while unassigned grid dims
        # exist *and* idle dims are disallowed
        if not allow_idle_dims:
            idle = len(mesh) - sum(len(v) for v in by_grid.values())
            unassigned = [g for g in grid_names if g not in by_grid]
            if idle > 0 and unassigned:
                continue
        # expand tiling orders per grid dim with multiple binds
        order_spaces = []
        for g in grid_names:
            binds = by_grid.get(g, [])
            if len(binds) > 1:
                order_spaces.append([tuple(p) for p in itertools.permutations(binds)])
            else:
                order_spaces.append([tuple(binds)])
        for orders in itertools.product(*order_spaces):
            spatial: List[SpatialBind] = []
            for g, binds in zip(grid_names, orders):
                for hw_name, hw_size in binds:
                    spatial.append(SpatialBind(hw_name, hw_size, g))
            # temporal loops for residual extents
            residual = []
            for d in program.grid_dims:
                sf = math.prod(b.hw_size for b in spatial if b.grid_dim == d.name) or 1
                ext = _ceil(d.extent, sf)
                residual.append((d.name, ext))
            movable = [(g, e) for g, e in residual if e > 1]
            fixed = [(g, e) for g, e in residual if e <= 1]
            temporal_orders = (list(itertools.permutations(movable))
                               if movable else [()])
            for t_order in temporal_orders:
                temporal = tuple(TemporalLoop(f"t_{g}", g, e) for g, e in t_order)
                # extent-1 waves are dropped (index fixed at 0)
                m = Mapping(program=program, hw_name=hw.name, hw_dims=mesh,
                            spatial=tuple(spatial), temporal=temporal)
                key = (m.spatial, m.temporal)
                if key in seen:
                    continue
                seen.add(key)
                out.append(m)
                if len(out) >= max_candidates:
                    return tuple(out)
    return tuple(out)
