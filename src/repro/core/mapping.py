"""Spatiotemporal mapping (paper S2.2).

A mapping decides how the iteration space of the ``affine.parallel`` loop —
the logical multidimensional tile grid — is assigned to physical cores and to
time.  Following the paper, mappings are *tiling-based*: contiguous regions of
the iteration space go to contiguous spatial regions of the core array or to
contiguous temporal *waves*.

The design space is the paper's three coupled choices:

1. each parallel (grid) dim maps to **zero or more** hardware spatial dims;
2. when a grid dim is tiled by multiple spatial dims, the **tiling order**
   matters (different orders induce different layouts / reuse);
3. residual extents become **temporal wave loops** whose order is itself a
   design choice.

``enumerate_mappings`` produces the full space; each :class:`Mapping` then
yields the concrete loop-nest structure (Listing 2) and rewritten affine
accesses that reuse analysis and the performance model consume.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Dict, Iterator, List, Mapping as TMapping, Optional, Sequence, Tuple

from .affine import AffineExpr, AffineMap
from .hw import HardwareModel
from .program import LoopDim, TileAccess, TileProgram


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


@dataclass(frozen=True)
class SpatialBind:
    """One hardware spatial dim consumed by one loop dim.

    ``reduce=False`` binds a parallel grid dim (the historical case).
    ``reduce=True`` binds a *reduction* (sequential) dim: the cores along
    ``hw_dim`` each execute a contiguous chunk of the sequential loop and
    produce partial results that must be combined (split-K style spatial
    reduction; ``grid_dim`` then names a program *seq* dim).
    """
    hw_dim: str
    hw_size: int
    grid_dim: str
    reduce: bool = False


@dataclass(frozen=True)
class TemporalLoop:
    """A wave loop over the residual extent of one grid dim (Listing 2's
    ``%tx`` / ``%ty``)."""
    name: str                      # "t_<grid_dim>"
    grid_dim: str
    extent: int


@dataclass(frozen=True)
class Mapping:
    """A fixed spatiotemporal mapping of a program onto a hardware mesh.

    Loop-nest structure implied (outermost -> innermost), matching Listing 2:

        affine.parallel (hw spatial dims)        # truly parallel cores
          affine.for (temporal wave loops, in ``temporal`` order)
            scf.for (program sequential dims)
              <tile body>
    """
    program: TileProgram
    hw_name: str
    hw_dims: Tuple[Tuple[str, int], ...]          # full mesh (name, size)
    spatial: Tuple[SpatialBind, ...]              # tiling order: outer digit first
    temporal: Tuple[TemporalLoop, ...]            # outer -> inner
    # how spatial partial sums along reduce binds are combined ("" = no
    # reduce binds): "accum" = accumulate-in-place at global memory (RMW),
    # "tree"/"chain" = partials forwarded along the axis NoC to an
    # owner core (log-depth combining tree / neighbor chain) which stores.
    reduce_style: str = ""

    # -- derived structure -----------------------------------------------------
    def spatial_for(self, grid_dim: str) -> Tuple[SpatialBind, ...]:
        return tuple(b for b in self.spatial
                     if b.grid_dim == grid_dim and not b.reduce)

    def spatial_factor(self, grid_dim: str) -> int:
        return math.prod(b.hw_size for b in self.spatial_for(grid_dim)) or 1

    # -- spatial reduction (split-K) -------------------------------------------
    def reduce_binds(self) -> Tuple[SpatialBind, ...]:
        return tuple(b for b in self.spatial if b.reduce)

    def reduce_for(self, seq_dim: str) -> Tuple[SpatialBind, ...]:
        return tuple(b for b in self.spatial
                     if b.reduce and b.grid_dim == seq_dim)

    def reduce_factor(self, seq_dim: str) -> int:
        """Number of mesh slots the sequential dim is split across."""
        return math.prod(b.hw_size for b in self.reduce_for(seq_dim)) or 1

    def seq_extent(self, seq_dim: str) -> int:
        """Per-core residual extent of one sequential loop: the declared
        extent divided (ceil) across the dim's reduce binds."""
        ext = self.program.dim(seq_dim).extent
        f = self.reduce_factor(seq_dim)
        return _ceil(ext, f) if f > 1 else ext

    def seq_loops(self) -> Tuple[Tuple[str, int], ...]:
        """(name, effective extent) of the sequential nest, outer -> inner."""
        loops = self.__dict__.get("_seq_loops")
        if loops is None:
            loops = tuple((d.name, self.seq_extent(d.name))
                          for d in self.program.seq_dims)
            object.__setattr__(self, "_seq_loops", loops)
        return loops

    def cost_loops(self) -> Tuple[Tuple[str, int], ...]:
        """The schedulable (temporal + sequential) loop nest with per-core
        effective extents — the single loop list every cost layer
        (perfmodel, bound context, batch engine, reuse hoisting) consumes,
        so split-K extents cannot diverge between them."""
        loops = self.__dict__.get("_cost_loops")
        if loops is None:
            loops = tuple((t.name, t.extent) for t in self.temporal) \
                + self.seq_loops()
            object.__setattr__(self, "_cost_loops", loops)
        return loops

    def inner_iters(self) -> int:
        """Per-core sequential iterations per wave (split-K divides this)."""
        return math.prod(e for _, e in self.seq_loops()) or 1

    def active_reduce_factor(self) -> int:
        """Active mesh slots along the reduce binds: digits whose sequential
        chunk is non-empty (exact for the single-axis splits the enumerator
        produces; ragged splits leave trailing digits idle)."""
        n = 1
        for d in self.program.seq_dims:
            if self.reduce_factor(d.name) > 1:
                n *= _ceil(d.extent, self.seq_extent(d.name))
        return n

    def reduce_stages(self) -> Tuple[Tuple[str, int], ...]:
        """Per-axis stages of the partial-sum combine, outer -> inner:
        ``(hw_dim, active digits along it)``.  The cost layers charge one
        staged combining leg per stage (mirroring the staged-multicast
        accounting of broadcasts), so a multi-bind reduction is never
        double-counted.  Single binds (all the enumerator emits) carry the
        dim's exact active-digit count, making the stage product equal
        :meth:`active_reduce_factor`; for a (deserialized) multi-bind dim
        the raggedness is attributed to the outermost digit (mixed-radix
        ceiling), which can overcount idle trailing digits — a modeling
        approximation only reachable outside the enumerated space."""
        stages = self.__dict__.get("_reduce_stages")
        if stages is None:
            out: List[Tuple[str, int]] = []
            for d in self.program.seq_dims:
                binds = self.reduce_for(d.name)
                if not binds:
                    continue
                digits = _ceil(d.extent, self.seq_extent(d.name))
                inner = math.prod(b.hw_size for b in binds[1:]) or 1
                out.append((binds[0].hw_dim, _ceil(digits, inner)))
                for b in binds[1:]:
                    out.append((b.hw_dim, b.hw_size))
            stages = tuple(out)
            object.__setattr__(self, "_reduce_stages", stages)
        return stages

    def wave_extent(self, grid_dim: str) -> int:
        return _ceil(self.program.dim(grid_dim).extent, self.spatial_factor(grid_dim))

    def used_hw_dims(self) -> Tuple[str, ...]:
        return tuple(b.hw_dim for b in self.spatial)

    def idle_hw_dims(self) -> Tuple[Tuple[str, int], ...]:
        used = set(self.used_hw_dims())
        return tuple((n, s) for n, s in self.hw_dims if n not in used)

    def active_cores(self) -> int:
        # called once per load option by the demand model — cache on the
        # frozen instance (does not enter dataclass eq/hash)
        n = self.__dict__.get("_active_cores")
        if n is None:
            n = 1
            for b in self.spatial:
                if not b.reduce:
                    n *= min(b.hw_size, self.program.dim(b.grid_dim).extent)
            n *= self.active_reduce_factor()
            object.__setattr__(self, "_active_cores", n)
        return n

    def total_cores(self) -> int:
        return math.prod(s for _, s in self.hw_dims)

    # -- fault feasibility -----------------------------------------------------
    def conflicts_with_faults(self, hw: HardwareModel) -> bool:
        """True iff any disabled core of ``hw`` would ever be active under
        this mapping — i.e. the mapping is infeasible on the degraded fabric.

        Ever-active reduces to active-at-the-all-zero-wave: each grid index
        is ``t * stride + digit(core)`` with ``stride >= 0``, so a core's
        activity threshold over any wave loop is monotone and the wave-0
        active set is the union over all waves (the same monotonicity the
        wave-class simulator's threshold grouping relies on).  Cores on
        idle hardware dims occupy implicit plane 0 (mirroring
        ``simulator._core_coords``), so a disabled core with a nonzero
        idle-dim coordinate never conflicts.
        """
        if not hw.disabled_cores:
            return False
        used = set(self.used_hw_dims())
        for full in hw.disabled_cores:
            env: Dict[str, int] = {}
            on_plane0 = True
            for dname, v in zip(hw.core.scaleout, full):
                if dname in used:
                    env[dname] = v
                elif v != 0:
                    on_plane0 = False
                    break
            if not on_plane0:
                continue
            for t in self.temporal:
                env[t.name] = 0
            active = True
            for gd in self.program.grid_dims:
                if self.grid_index_expr(gd.name).evaluate(env) >= gd.extent:
                    active = False
                    break
            if active:
                for sd in self.program.seq_dims:
                    if self.reduce_factor(sd.name) > 1 \
                            and self.seq_index_expr(sd.name).evaluate(
                                {**env, sd.name: 0}) >= sd.extent:
                        active = False
                        break
            if active:
                return True
        return False

    def utilization(self) -> float:
        """Fraction of (core x wave) slots holding real (non-padded) tiles."""
        u = 1.0
        for d in self.program.grid_dims:
            padded = self.spatial_factor(d.name) * self.wave_extent(d.name)
            u *= d.extent / padded
        # split reduction dims pad to (mesh slots x per-core chunk)
        for d in self.program.seq_dims:
            f = self.reduce_factor(d.name)
            if f > 1:
                u *= d.extent / (f * self.seq_extent(d.name))
        # idle hw dims waste whole planes of the machine
        for _, s in self.idle_hw_dims():
            u /= s
        return u

    def n_waves(self) -> int:
        return math.prod(t.extent for t in self.temporal) or 1

    # -- index rewriting ---------------------------------------------------------
    def grid_index_expr(self, grid_dim: str) -> AffineExpr:
        """Reconstruct the logical grid index from (wave, spatial digits).

        With binds [h1(s1), h2(s2)] (tiling order: h1 outer) and wave t:
            g = t * s1 * s2 + h1 * s2 + h2

        Memoized per instance: the reuse analysis rewrites every access of
        every mapping through these expressions.
        """
        cache = self.__dict__.get("_grid_exprs")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_grid_exprs", cache)
        hit = cache.get(grid_dim)
        if hit is not None:
            return hit
        expr = self._grid_index_expr(grid_dim)
        cache[grid_dim] = expr
        return expr

    def _grid_index_expr(self, grid_dim: str) -> AffineExpr:
        binds = self.spatial_for(grid_dim)
        terms: Dict[str, int] = {}
        stride = 1
        for b in reversed(binds):              # innermost digit has stride 1
            terms[b.hw_dim] = stride
            stride *= b.hw_size
        t = self._temporal_for(grid_dim)
        if t is not None and t.extent > 1:
            terms[t.name] = stride
        elif t is not None:
            pass                                # extent-1 wave: index 0
        return AffineExpr.linear(terms)

    def _temporal_for(self, grid_dim: str) -> Optional[TemporalLoop]:
        for t in self.temporal:
            if t.grid_dim == grid_dim:
                return t
        return None

    def seq_index_expr(self, seq_dim: str) -> AffineExpr:
        """Reconstruct the logical sequential index from the reduce-bind
        digits and the per-core loop variable (blocked split: core digit d
        along a reduce bind owns the contiguous chunk
        ``[d * seq_extent, (d+1) * seq_extent)``):

            k_global = digit(core) * seq_extent + k_local
        """
        cache = self.__dict__.get("_grid_exprs")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_grid_exprs", cache)
        key = ("seq", seq_dim)
        hit = cache.get(key)
        if hit is not None:
            return hit
        binds = self.reduce_for(seq_dim)
        terms: Dict[str, int] = {seq_dim: 1}
        stride = self.seq_extent(seq_dim)
        for b in reversed(binds):          # innermost digit has stride E_eff
            terms[b.hw_dim] = stride
            stride *= b.hw_size
        expr = AffineExpr.linear(terms)
        cache[key] = expr
        return expr

    def rewrite_access(self, access: TileAccess) -> AffineMap:
        """Substitute grid dims with their (wave, spatial) reconstruction and
        reduce-bound sequential dims with their (digit, local) split.

        Cached on the (shared, frozen) access object keyed by the grid
        expressions actually substituted: mappings that reconstruct the
        access's grid dims identically — very common across the enumerated
        space — share one rewritten map object (which in turn lets the
        downstream footprint analysis memoize per rewritten map).
        """
        m = access.index
        subs = tuple((d.name, self.grid_index_expr(d.name))
                     for d in self.program.grid_dims
                     if m.depends_on(d.name))
        subs += tuple((d.name, self.seq_index_expr(d.name))
                      for d in self.program.seq_dims
                      if self.reduce_factor(d.name) > 1
                      and m.depends_on(d.name))
        cache = access.__dict__.get("_rewrite_cache")
        if cache is None:
            cache = {}
            object.__setattr__(access, "_rewrite_cache", cache)
        hit = cache.get(subs)
        if hit is not None:
            return hit
        for name, expr in subs:
            m = m.substitute(name, expr)
        cache[subs] = m
        return m

    # -- loop nest (for reuse analysis & printing) --------------------------------
    def loop_nest(self) -> Tuple[Tuple[str, str, int], ...]:
        """(kind, name, extent) outer->inner; kind in
        {"spatial", "temporal", "sequential"}."""
        nest: List[Tuple[str, str, int]] = []
        for b in self.spatial:
            nest.append(("spatial", b.hw_dim, b.hw_size))
        for t in self.temporal:
            nest.append(("temporal", t.name, t.extent))
        for name, ext in self.seq_loops():
            nest.append(("sequential", name, ext))
        return tuple(nest)

    def extents_env(self) -> Dict[str, int]:
        env = dict(self.program.extents)
        for name, ext in self.seq_loops():
            env[name] = ext
        for b in self.spatial:
            env[b.hw_dim] = b.hw_size
        for t in self.temporal:
            env[t.name] = t.extent
        return env

    def describe(self) -> str:
        sp = ", ".join(
            f"{b.grid_dim}{'=>' if b.reduce else '->'}%{b.hw_dim}({b.hw_size})"
            for b in self.spatial)
        tp = ", ".join(f"{t.name}({t.extent})" for t in self.temporal)
        red = f" | reduce: {self.reduce_style}" if self.reduce_style else ""
        return f"[spatial: {sp or '-'} | temporal: {tp or '-'}{red}]"

    def mlir_like(self) -> str:
        """Render the mapped loop structure in the paper's Listing-2 style."""
        lines = []
        sp_dims = ", ".join(f"%{b.hw_dim}" for b in self.spatial)
        sp_sizes = ", ".join(str(b.hw_size) for b in self.spatial)
        indent = ""
        if self.spatial:
            lines.append(f"affine.parallel ({sp_dims}) = (0) to ({sp_sizes}) {{")
            indent += "  "
        for t in self.temporal:
            lines.append(f"{indent}affine.for %{t.name} = 0 to {t.extent} {{")
            indent += "  "
        for name, ext in self.seq_loops():
            lines.append(f"{indent}scf.for %{name} = 0 to {ext} {{")
            indent += "  "
        lines.append(f"{indent}// tile body: "
                     + ", ".join(op.kind for op in self.program.body))
        while indent:
            indent = indent[:-2]
            lines.append(f"{indent}}}")
        return "\n".join(lines)


# --------------------------------------------------------------------------
# Enumeration
# --------------------------------------------------------------------------
# enumeration order of the reduction styles: the analytic model costs
# "tree" and "chain" identically (same per-resource demand; only the
# simulator's hop-depth term separates them), so the log-depth tree — the
# one the profiling stage prefers — must take the earlier canonical index
# and win exact model-cost ties.
REDUCE_STYLES = ("tree", "chain", "accum")


def enumerate_mappings(program: TileProgram, hw: HardwareModel, *,
                       allow_idle_dims: bool = True,
                       max_candidates: int = 512,
                       allow_reduction: bool = True) -> Tuple[Mapping, ...]:
    """Enumerate the paper's mapping design space.

    For every function ``hw_dim -> grid_dim | idle`` we derive the set of
    spatial binds; for every grid dim bound to >=2 hw dims we expand all tiling
    orders; residual grid dims with wave extent > 1 generate temporal loops in
    all orders.  Degenerate duplicates (idle dims that could host work while a
    grid dim still has residual extent) are kept only if ``allow_idle_dims`` —
    they are occasionally optimal for very small grids (paper S3.2 small-shape
    regime).

    With ``allow_reduction`` a second pass extends the space with **spatial
    reductions**: one hardware dim hosts a sequential (reduction) dim via a
    ``reduce=True`` bind (split-K), crossed with every parallel assignment of
    the remaining dims and every partial-combining style
    (:data:`REDUCE_STYLES`).  The pass runs strictly *after* the parallel
    space so existing mappings keep their canonical indices (exact cost ties
    still resolve to the historical plan), and it has its own
    ``max_candidates`` allowance so a large parallel space cannot starve the
    reduction space out of a capped enumeration.
    """
    program.validate()
    mesh = hw.mesh_dims
    grid_names = [d.name for d in program.grid_dims]
    out: List[Mapping] = []
    seen = set()
    # Degraded fabrics: mappings that would ever activate a disabled core
    # are infeasible and never enter the candidate list.  The guard keeps
    # the fault-free path byte-identical (same mappings, same canonical
    # indices) — `conflicts_with_faults` is only consulted when an overlay
    # is present.
    degraded = bool(hw.disabled_cores)

    def expand(par_mesh, combo, extra_binds, styles, cap):
        """Expand one parallel assignment (``combo`` over ``par_mesh``) into
        mappings: tiling orders x temporal orders x styles, with
        ``extra_binds`` (the reduce binds) appended to the spatial tuple.
        Returns False when the cap was hit."""
        by_grid: Dict[str, List[Tuple[str, int]]] = {}
        for (hw_name, hw_size), g in zip(par_mesh, combo):
            if g is not None:
                by_grid.setdefault(g, []).append((hw_name, hw_size))
        if not allow_idle_dims and len(by_grid) == 0 and grid_names \
                and not extra_binds:
            return True
        # skip assignments where a hw dim is idle while unassigned grid dims
        # exist *and* idle dims are disallowed
        if not allow_idle_dims:
            idle = len(par_mesh) - sum(len(v) for v in by_grid.values())
            unassigned = [g for g in grid_names if g not in by_grid]
            if idle > 0 and unassigned:
                return True
        # expand tiling orders per grid dim with multiple binds
        order_spaces = []
        for g in grid_names:
            binds = by_grid.get(g, [])
            if len(binds) > 1:
                order_spaces.append([tuple(p)
                                     for p in itertools.permutations(binds)])
            else:
                order_spaces.append([tuple(binds)])
        for orders in itertools.product(*order_spaces):
            spatial: List[SpatialBind] = []
            for g, binds in zip(grid_names, orders):
                for hw_name, hw_size in binds:
                    spatial.append(SpatialBind(hw_name, hw_size, g))
            spatial.extend(extra_binds)
            # temporal loops for residual extents
            residual = []
            for d in program.grid_dims:
                sf = math.prod(b.hw_size for b in spatial
                               if b.grid_dim == d.name and not b.reduce) or 1
                ext = _ceil(d.extent, sf)
                residual.append((d.name, ext))
            movable = [(g, e) for g, e in residual if e > 1]
            temporal_orders = (list(itertools.permutations(movable))
                               if movable else [()])
            for t_order in temporal_orders:
                temporal = tuple(TemporalLoop(f"t_{g}", g, e)
                                 for g, e in t_order)
                # extent-1 waves are dropped (index fixed at 0)
                for style in styles:
                    m = Mapping(program=program, hw_name=hw.name,
                                hw_dims=mesh, spatial=tuple(spatial),
                                temporal=temporal, reduce_style=style)
                    key = (m.spatial, m.temporal, style)
                    if key in seen:
                        continue
                    seen.add(key)
                    if degraded and m.conflicts_with_faults(hw):
                        continue
                    out.append(m)
                    if len(out) >= cap:
                        return False
        return True

    # ---- pass 1: the historical parallel-only space -----------------------
    # a cap hit ends *this pass* (truncating exactly the tail the historical
    # enumeration truncated) but not the reduction pass below, which owns an
    # equal allowance — so small `max_mappings` budgets (REPRO_FAST_SEARCH)
    # still see split-K candidates
    choices = [grid_names + [None] for _ in mesh]
    for combo in itertools.product(*choices):
        if not expand(mesh, combo, (), ("",), max_candidates):
            break

    # ---- pass 2: spatial reductions (split-K binds) -----------------------
    # One sequential dim is bound to one hardware axis; the output must be
    # invariant to the whole sequential nest (the accumulator pattern) so
    # the partial combine is a single epilogue after the per-core loops.
    if not allow_reduction or not program.seq_dims:
        return tuple(out)
    seq_names = {d.name for d in program.seq_dims}
    if any(st.index.dims & seq_names for st in program.stores):
        return tuple(out)
    cap2 = len(out) + max_candidates
    for ax_i, (ax_name, ax_size) in enumerate(mesh):
        if ax_size <= 1:
            continue
        # forwarding needs a NoC ring along the axis; accumulate-in-place
        # only needs the store path
        styles = (REDUCE_STYLES if hw.interconnect_along(ax_name) is not None
                  else ("accum",))
        rest = tuple(m for j, m in enumerate(mesh) if j != ax_i)
        rest_choices = [grid_names + [None] for _ in rest]
        for rd in program.seq_dims:
            if rd.extent <= 1:
                continue
            rbind = (SpatialBind(ax_name, ax_size, rd.name, reduce=True),)
            for combo in (itertools.product(*rest_choices) if rest else [()]):
                if not expand(rest, combo, rbind, styles, cap2):
                    return tuple(out)
    return tuple(out)
