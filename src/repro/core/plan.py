"""Dataflow-aware plan representation (paper Listing 5).

A :class:`DataflowPlan` fixes everything the planner decides: the
spatiotemporal mapping, plus one :class:`~repro.core.reuse.MemOpChoice` per
load (broadcast pattern + hoist point) and the derived store placements.  It
is the Python analogue of the paper's "dataflow-aware MLIR": loop nest +
annotated memory operations bound to concrete ``df`` resources.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .hw import HardwareModel
from .mapping import Mapping
from .reuse import (MemOpChoice, StorePlacement, analyze_reuse,
                    buffer_footprint_bytes, store_placement)


@dataclass(frozen=True)
class DataflowPlan:
    mapping: Mapping
    loads: Tuple[MemOpChoice, ...]
    stores: Tuple[StorePlacement, ...]

    @property
    def program(self):
        return self.mapping.program

    def buffer_bytes(self) -> int:
        return buffer_footprint_bytes(self.loads, self.stores, self.mapping)

    def describe(self) -> str:
        parts = [self.mapping.describe()]
        for c in self.loads:
            tag = "+".join(c.bcast_axes) if c.bcast_axes else "global"
            parts.append(f"{c.access.tensor.name}:{tag}@L{c.hoist.level}")
        return " ".join(parts)

    def mlir_like(self, hw: HardwareModel) -> str:
        """Render in the paper's Listing-5 style: the mapped loop nest with
        per-level alloc/load annotations."""
        loops: List[Tuple[str, str, int]] = []
        for b in self.mapping.spatial:
            loops.append(("parallel", b.hw_dim, b.hw_size))
        n_par = len(loops)
        for t in self.mapping.temporal:
            loops.append(("for", t.name, t.extent))
        for name, ext in self.mapping.seq_loops():   # per-core split extents
            loops.append(("for", name, ext))
        by_level: Dict[int, List[str]] = {}
        for c in self.loads:
            ann = c.annotate(hw)
            alloc = (f"alloc {c.access.tensor.name} "
                     f"{{target_buffer=%{hw.local_mem.name}, "
                     f"size={c.hoist.footprint_tiles * c.access.tile_bytes}}}")
            by_level.setdefault(c.hoist.level, []).extend([alloc, ann])
        store_lines: Dict[int, List[str]] = {}
        for s in self.stores:
            if s.reduce_axes:
                axes = ", ".join(f"%{a}" for a in s.reduce_axes)
                ann = (f"store {s.access.tensor.name} "
                       f"{{type=\"reduce_{s.reduce_style}\", "
                       f"axes={{{axes}}}}}")
            else:
                ann = f"store {s.access.tensor.name} {{type=\"global\"}}"
            store_lines.setdefault(s.level, []).append(ann)
        lines: List[str] = []
        indent = ""
        # emit loops; memory-op level L sits just inside the L-th temporal loop
        lvl = 0
        for kind, name, ext in loops:
            if kind == "parallel":
                lines.append(f"{indent}affine.parallel (%{name}) = 0 to {ext} {{")
            else:
                for text in by_level.get(lvl, []):
                    lines.append(f"{indent}{text}")
                lines.append(f"{indent}affine.for %{name} = 0 to {ext} {{")
                lvl += 1
            indent += "  "
        for text in by_level.get(lvl, []):
            lines.append(f"{indent}{text}")
        for op in self.program.body:
            lines.append(f"{indent}linalg.{op.kind} ...")
        for s_lvl in sorted(store_lines, reverse=True):
            for text in store_lines[s_lvl]:
                lines.append(f"{indent}{text}")
        while indent:
            indent = indent[:-2]
            lines.append(f"{indent}}}")
        return "\n".join(lines)


def make_plan(mapping: Mapping, loads: Sequence[MemOpChoice],
              hw: HardwareModel) -> DataflowPlan:
    infos = analyze_reuse(mapping, hw)
    stores = tuple(store_placement(i, mapping)
                   for i in infos if i.access.kind == "store")
    return DataflowPlan(mapping, tuple(loads), stores)
