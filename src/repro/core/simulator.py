"""Wave-accurate machine simulator — the "profile on hardware" stage.

The paper's two-step selection ranks candidates with the coarse analytic model
and then *profiles the top-k on real hardware*.  This container has no
Tenstorrent card, so the profiling stage is played by this simulator, which is
deliberately **higher-fidelity than the ranking model** so the two-step flow
stays non-circular (see DESIGN.md S4):

analytic model (perfmodel.py)           simulator (this file)
--------------------------------------  -----------------------------------------
aggregate bandwidth pools               per-DRAM-channel and per-NoC-ring
                                        contention, resolved per wave
waves folded into closed-form loops     every wave executed; ragged final waves
                                        and partially-active meshes cost real time
no launch cost                          per-wave dispatch/barrier overhead
                                        (reproduces the paper's small-shape
                                        degradation, S3.2 / Fig 9)
steady-state pipeline formula           explicit fill/drain per wave, barrier at
                                        wave boundaries (no cross-wave overlap)

The simulator consumes the same :class:`DataflowPlan` and df hardware
description as the model.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from .hw import HardwareModel
from .perfmodel import body_compute_seconds, pipelined_loop_time
from .plan import DataflowPlan
from .reuse import MemOpChoice, StorePlacement


@dataclass(frozen=True)
class SimResult:
    total_s: float
    dram_bytes: float
    noc_bytes: float
    flops: float
    n_waves: int
    wave_overhead_s: float

    @property
    def tflops(self) -> float:
        return self.flops / self.total_s / 1e12 if self.total_s > 0 else 0.0


def _core_coords(plan: DataflowPlan) -> List[Dict[str, int]]:
    dims = [(b.hw_dim, b.hw_size) for b in plan.mapping.spatial]
    if not dims:
        return [{}]
    names = [d for d, _ in dims]
    return [dict(zip(names, pt))
            for pt in itertools.product(*[range(s) for _, s in dims])]


def _wave_envs(plan: DataflowPlan) -> List[Dict[str, int]]:
    ts = plan.mapping.temporal
    if not ts:
        return [{}]
    names = [t.name for t in ts]
    return [dict(zip(names, pt))
            for pt in itertools.product(*[range(t.extent) for t in ts])]


def _is_active(plan: DataflowPlan, env: Dict[str, int]) -> bool:
    """A (core, wave) slot is active iff every grid index is in range
    (ragged final waves leave cores idle — real cost the model ignores)."""
    m = plan.mapping
    for d in m.program.grid_dims:
        idx = m.grid_index_expr(d.name).evaluate(env)
        if idx >= d.extent:
            return False
    return True


def simulate(plan: DataflowPlan, hw: HardwareModel, *,
             launch_overhead_s: float = 20e-6,
             wave_overhead_s: float = 2e-6,
             max_waves_exact: int = 4096) -> SimResult:
    """Simulate plan execution wave by wave.

    For each wave: per-core inner-loop time uses the double-buffered pipeline
    with *per-channel* / *per-ring* effective bandwidths resolved from the set
    of cores actually active in this wave; the wave completes at the max over
    cores (barrier), plus a dispatch overhead.  Hoisted transfers are charged
    at the wave where their enclosing temporal index changes.
    """
    m = plan.mapping
    prog = m.program
    t_body = body_compute_seconds(plan, hw)
    coords = _core_coords(plan)
    waves = _wave_envs(plan)
    n_temporal = len(m.temporal)
    n_loops = n_temporal + len(prog.seq_dims)
    seq_extents = [d.extent for d in prog.seq_dims]
    inner_I = seq_extents[-1] if seq_extents else 1
    outer_seq = math.prod(seq_extents[:-1]) if len(seq_extents) > 1 else 1

    # wave decimation for very large temporal spaces: simulate a stride-sample
    # and scale (documented fidelity cut; exact below max_waves_exact)
    stride = max(1, len(waves) // max_waves_exact)
    sampled = waves[::stride]
    scale = len(waves) / len(sampled)

    dram_bw = hw.global_mem.bandwidth_gbps * 1e9
    link_bw = {ic.name: ic.bandwidth_gbps * 1e9 for ic in hw.interconnects}
    l1_bw = hw.local_mem.bandwidth_gbps * 1e9
    sizes = dict(m.hw_dims)

    total = 0.0
    dram_bytes = 0.0
    noc_bytes = 0.0
    prev_env: Dict[str, int] = {}

    # pre-split ops
    inner_loads = [c for c in plan.loads if c.hoist.level == n_loops]
    hoisted_loads = [c for c in plan.loads if c.hoist.level < n_loops]
    inner_stores = [s for s in plan.stores if s.level == n_loops]
    outer_stores = [s for s in plan.stores if s.level < n_loops]

    for env in sampled:
        active = [c for c in coords if _is_active(plan, {**c, **env})]
        if not active:
            total += wave_overhead_s
            continue

        # --- contention census for this wave -------------------------------
        # DRAM channels: one user per fetching core per op.  NoC rings: one
        # user per *multicast operation* per ring instance (a ring multicast
        # carries the tile once regardless of receiver count).
        chan_users: Dict[Tuple[int, ...], int] = {}
        ring_users: Dict[Tuple[str, Tuple[Tuple[str, int], ...]], int] = {}

        for c in inner_loads:
            if not c.bcast_axes:
                for core in active:
                    ch = hw.channel_of_core(core)
                    chan_users[ch] = chan_users.get(ch, 0) + 1
            else:
                seen_rings = set()
                for core in active:
                    # producer cores (coordinate 0 along every bcast axis)
                    # fetch from DRAM once
                    if all(core.get(a, 0) == 0 for a in c.bcast_axes):
                        ch = hw.channel_of_core(core)
                        chan_users[ch] = chan_users.get(ch, 0) + 1
                    for a in c.bcast_axes:
                        ic = hw.interconnect_along(a)
                        if ic is None:
                            continue
                        other = tuple(sorted((k, v) for k, v in core.items()
                                             if k != a))
                        key = (id(c), ic.name, other)
                        if key in seen_rings:
                            continue
                        seen_rings.add(key)
                        rk = (ic.name, other)
                        ring_users[rk] = ring_users.get(rk, 0) + 1

        # --- per-core inner-loop time ---------------------------------------
        wave_time = 0.0
        for core in active:
            t_load = 0.0
            for c in inner_loads:
                tb = c.access.tile_bytes
                if not c.bcast_axes:
                    ch = hw.channel_of_core(core)
                    users = max(1, chan_users.get(ch, 1))
                    t_load += tb / (dram_bw / users)
                else:
                    t_leg = 0.0
                    if all(core.get(a, 0) == 0 for a in c.bcast_axes):
                        ch = hw.channel_of_core(core)
                        users = max(1, chan_users.get(ch, 1))
                        t_leg = tb / (dram_bw / users)
                    t_noc = 0.0
                    for a in c.bcast_axes:
                        ic = hw.interconnect_along(a)
                        if ic is None:
                            continue
                        other = tuple(sorted((k, v) for k, v in core.items() if k != a))
                        users = max(1, ring_users.get((ic.name, other), 1))
                        t_noc += tb / (link_bw[ic.name] / users)
                    t_load += max(t_leg, t_noc)       # cut-through pipelining
                t_load += tb / l1_bw
            t_store = 0.0
            for s in inner_stores:
                ch = hw.channel_of_core(core)
                users = max(1, chan_users.get(ch, 1))
                t_store += s.access.tile_bytes / (dram_bw / max(1, users))
            core_t = pipelined_loop_time(inner_I, t_load, t_store, t_body)
            core_t *= outer_seq
            wave_time = max(wave_time, core_t)

        # --- hoisted transfers at temporal boundaries ------------------------
        t_hoist = 0.0
        for c in hoisted_loads:
            # reload when any temporal loop outer to the hoist level changed;
            # loads hoisted *within* the sequential nest re-issue once per
            # iteration of the seq loops outer to their level
            changed = (not prev_env) or any(
                env.get(t.name, 0) != prev_env.get(t.name, 0)
                for t in m.temporal[:min(c.hoist.level, n_temporal)])
            if changed:
                seq_issues = (math.prod(seq_extents[:c.hoist.level - n_temporal])
                              if c.hoist.level > n_temporal else 1)
                tb = c.access.tile_bytes * c.hoist.tiles_per_issue * seq_issues
                if c.bcast_axes:
                    repl = math.prod(sizes[a] for a in c.bcast_axes)
                    producers = max(1, len(active) // repl)
                    t_dram = tb * producers / (dram_bw * hw.global_channels())
                    slowest_ring = min((link_bw[hw.interconnect_along(a).name]
                                        for a in c.bcast_axes
                                        if hw.interconnect_along(a)), default=None)
                    t_nc = tb / slowest_ring if slowest_ring else 0.0
                    t_hoist += max(t_dram, t_nc)
                    dram_bytes += tb * producers * scale
                    planes = producers
                    for a in c.bcast_axes:
                        noc_bytes += tb * (sizes[a] - 1) * planes * scale
                        planes *= sizes[a]
                else:
                    t_hoist += tb * len(active) / (dram_bw * hw.global_channels())
                    dram_bytes += tb * len(active) * scale

        # --- traffic bookkeeping for inner ops ------------------------------
        iters = inner_I * outer_seq
        for c in inner_loads:
            tb = c.access.tile_bytes * iters
            if c.bcast_axes:
                repl = math.prod(sizes[a] for a in c.bcast_axes)
                producers = max(1, len(active) // repl)
                dram_bytes += tb * producers * scale
                planes = producers
                for a in c.bcast_axes:
                    noc_bytes += tb * (sizes[a] - 1) * planes * scale
                    planes *= sizes[a]
            else:
                dram_bytes += tb * len(active) * scale
        for s in inner_stores:
            dram_bytes += s.access.tile_bytes * iters * len(active) * scale
        for s in outer_stores:
            dram_bytes += s.access.tile_bytes * len(active) * scale
            t_hoist += s.access.tile_bytes * len(active) / (dram_bw * hw.global_channels())

        total += wave_time + t_hoist + wave_overhead_s
        prev_env = env

    total *= scale
    total += launch_overhead_s        # per-kernel dispatch cost (paper S3.2:
    #                                   small shapes dominated by overheads)
    flops = prog.mat_flops()
    return SimResult(total_s=total, dram_bytes=dram_bytes, noc_bytes=noc_bytes,
                     flops=flops, n_waves=len(waves),
                     wave_overhead_s=wave_overhead_s)
