"""Wave-accurate machine simulator — the "profile on hardware" stage.

The paper's two-step selection ranks candidates with the coarse analytic model
and then *profiles the top-k on real hardware*.  This container has no
Tenstorrent card, so the profiling stage is played by this simulator, which is
deliberately **higher-fidelity than the ranking model** so the two-step flow
stays non-circular (see DESIGN.md S4):

analytic model (perfmodel.py)           simulator (this file)
--------------------------------------  -----------------------------------------
aggregate bandwidth pools               per-DRAM-channel and per-NoC-ring
                                        contention, resolved per wave
waves folded into closed-form loops     every wave accounted for; ragged final
                                        waves and partially-active meshes cost
                                        real time
no launch cost                          per-wave dispatch/barrier overhead
                                        (reproduces the paper's small-shape
                                        degradation, S3.2 / Fig 9)
steady-state pipeline formula           explicit fill/drain per wave, barrier at
                                        wave boundaries (no cross-wave overlap)

The simulator consumes the same :class:`DataflowPlan` and df hardware
description as the model.

Two entry points:

* :func:`simulate` — the fast **wave-equivalence-class** path (exact).  A
  wave's cost is fully determined by (a) the set of cores active in it and
  (b) which temporal indices changed relative to the previous wave (that is
  what triggers hoisted reloads).  Both are functions of a tiny per-wave
  signature, so the full wave space collapses into a handful of equivalence
  classes: each class is costed once and multiplied by its population (see
  DESIGN_SEARCHPERF.md for the argument).  There is no sampling cut — the
  old ``max_waves_exact`` stride decimation is retired.
* :func:`simulate_reference` — the original wave-by-wave loop, kept as the
  oracle for ``tests/test_search_equivalence.py`` (and for its stride-sample
  mode, should anyone want the historical behaviour).
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Dict, List, Mapping as TMapping, Optional, Sequence, Tuple

from .hw import HardwareModel
from .perfmodel import body_compute_seconds, pipelined_loop_time
from .plan import DataflowPlan
from .reuse import ForwardLeg, MemOpChoice, StorePlacement


@dataclass(frozen=True)
class SimResult:
    total_s: float
    dram_bytes: float
    noc_bytes: float
    flops: float
    n_waves: int
    wave_overhead_s: float
    n_wave_classes: int = 0       # equivalence classes costed (0 = reference path)

    @property
    def tflops(self) -> float:
        return self.flops / self.total_s / 1e12 if self.total_s > 0 else 0.0


def _core_coords(plan: DataflowPlan) -> List[Dict[str, int]]:
    dims = [(b.hw_dim, b.hw_size) for b in plan.mapping.spatial]
    if not dims:
        return [{}]
    names = [d for d, _ in dims]
    return [dict(zip(names, pt))
            for pt in itertools.product(*[range(s) for _, s in dims])]


def _wave_envs(plan: DataflowPlan) -> List[Dict[str, int]]:
    ts = plan.mapping.temporal
    if not ts:
        return [{}]
    names = [t.name for t in ts]
    return [dict(zip(names, pt))
            for pt in itertools.product(*[range(t.extent) for t in ts])]


def _is_active(plan: DataflowPlan, env: Dict[str, int]) -> bool:
    """A (core, wave) slot is active iff every grid index is in range and,
    under a reduce bind, the core's sequential chunk is non-empty (ragged
    final waves / ragged splits leave cores idle — real cost the model
    ignores)."""
    m = plan.mapping
    for d in m.program.grid_dims:
        idx = m.grid_index_expr(d.name).evaluate(env)
        if idx >= d.extent:
            return False
    for d in m.program.seq_dims:
        if m.reduce_factor(d.name) > 1:
            if m.seq_index_expr(d.name).evaluate({**env, d.name: 0}) \
                    >= d.extent:
                return False
    return True


def _reduce_epilogue_cost(mapping, outer_stores, n_active: int, red_act: int,
                          hw: HardwareModel, dram_bw: float,
                          link_bw: Dict[str, float], *,
                          fwd: Optional[TMapping[str, ForwardLeg]] = None,
                          l1_bw: float = 0.0
                          ) -> Tuple[float, float, float]:
    """Per-wave hoisted-store cost (time, dram bytes, noc bytes), including
    the spatial-reduction epilogue.  ``accum`` read-modify-writes every
    partial through the store path; ``tree``/``chain`` forward partials over
    the axis NoC in per-axis stages (log-depth combining tree vs ``r - 1``
    neighbor hops per stage) and only the owner core stores.  Shared
    verbatim by the wave-class simulator, the reference loop, and the
    vectorized engine so the three stay bit-identical.

    ``fwd`` marks stores riding a forwarded inter-kernel edge (pipeline
    co-planning): a plain forwarded store writes the producing core's L1
    (all cores concurrently: ``tb / l1_bw``) and touches no DRAM; a
    ``free`` leg costs nothing (the graph bound's floor).  Reduce-combining
    stores ignore the leg — the pipeline legality rule spills them."""
    chans = hw.global_channels()
    t = db = nb = 0.0
    for s in outer_stores:
        tb = s.access.tile_bytes
        leg = fwd.get(s.access.tensor.name) if fwd else None
        if leg is not None and not s.reduce_axes:
            if leg.kind != "free":
                t += tb / l1_bw
            continue
        if s.reduce_axes and red_act > 1:
            if s.reduce_style == "accum":
                db += 2.0 * tb * n_active
                t += 2.0 * tb * n_active / (dram_bw * chans)
                continue
            owners = max(1, n_active // red_act)
            planes = n_active
            for a, digits in mapping.reduce_stages():
                if a not in s.reduce_axes:
                    continue
                groups = max(1, planes // digits)
                depth = (math.ceil(math.log2(digits))
                         if s.reduce_style == "tree" else digits - 1)
                ic = hw.interconnect_along(a)
                if ic is not None:
                    t += depth * tb / link_bw[ic.name]
                nb += tb * (digits - 1) * groups
                planes = groups
            t += tb * owners / (dram_bw * chans)
            db += tb * owners
        else:
            db += tb * n_active
            t += tb * n_active / (dram_bw * chans)
    return t, db, nb


# --------------------------------------------------------------------------
# Fast path: wave equivalence classes
# --------------------------------------------------------------------------
# A wave is one point of the temporal loop nest, iterated lexicographically
# (outer loop first — the order _wave_envs produces).  Its cost depends on
# exactly two things:
#
# 1. the **active-core set**: core c is active iff every grid index is in
#    range.  Each grid dim's index is ``t_g * stride + digit(c)``, so per
#    grid dim the active predicate depends only on that dim's own wave value
#    — the overall active set is the intersection of per-loop core masks;
# 2. the **changed-temporal mask**: a load hoisted to level L re-issues when
#    any of the first min(L, n_temporal) temporal indices changed.  In
#    lexicographic iteration the changed positions of a wave are exactly
#    {j..n-1} where j is the wave's last non-zero digit (odometer carry), so
#    "some of the first k loops changed" == (j < k); the first wave changes
#    everything.
#
# Group waves by (per-loop mask, per-loop digit==0) and every member shares
# both ingredients — cost one representative, multiply by the population.

_LoopGroup = Tuple[int, bool, int]          # (core mask, digit == 0, population)


def _loop_digit_groups(plan: DataflowPlan, coords: Sequence[Dict[str, int]],
                       hw: Optional[HardwareModel] = None
                       ) -> Tuple[int, List[List[_LoopGroup]]]:
    """Per temporal loop, group digit values by the core mask they induce
    (keeping value 0 separate — it feeds the odometer-carry bookkeeping).
    Returns (static mask from waveless grid dims, per-loop group lists).

    When ``hw`` carries a fault overlay, its disabled cores are removed
    from the static mask — exactly like a waveless grid dim idling a core
    for the whole kernel — so both wave-class engines (scalar and batch
    call this shared helper) exclude dead cores identically.  Fault-free
    models contribute nothing, keeping the healthy path byte-identical.
    """
    m = plan.mapping
    prog = m.program
    n_cores = len(coords)
    full = (1 << n_cores) - 1
    with_loop = {t.grid_dim for t in m.temporal}

    static_mask = full
    if hw is not None and hw.disabled_cores:
        for i, c in enumerate(coords):
            if hw.is_disabled(c):
                static_mask &= ~(1 << i)
    for d in prog.grid_dims:
        if d.name in with_loop:
            continue
        expr = m.grid_index_expr(d.name)
        mask = 0
        for i, c in enumerate(coords):
            if expr.evaluate(c) < d.extent:
                mask |= 1 << i
        static_mask &= mask
    # reduce binds: cores whose sequential chunk is empty (ragged split)
    # idle for the whole kernel — a static mask, like waveless grid dims
    for d in prog.seq_dims:
        if m.reduce_factor(d.name) <= 1:
            continue
        expr = m.seq_index_expr(d.name)
        mask = 0
        for i, c in enumerate(coords):
            if expr.evaluate({**c, d.name: 0}) < d.extent:
                mask |= 1 << i
        static_mask &= mask

    per_loop: List[List[_LoopGroup]] = []
    for t in m.temporal:
        d = prog.dim(t.grid_dim)
        expr = m.grid_index_expr(t.grid_dim)
        E = t.extent
        agg: Dict[Tuple[int, bool], int] = {}
        exotic = any(e is not None for e in (expr.mod, expr.floordiv))
        if not exotic:
            # grid index = v * stride + digit(core) is monotone in the wave
            # value v, so each core has one threshold T below which it is
            # active; the mask over cores changes at most n_cores times.
            stride = expr.coeff_of(t.name)
            thresholds = []
            for c in coords:
                base = expr.evaluate({**c, t.name: 0})
                if stride <= 0:
                    thresholds.append(E if base < d.extent else 0)
                else:
                    n_active = -(-(d.extent - base) // stride)  # ceil
                    thresholds.append(max(0, min(E, n_active)))
            cuts = sorted({T for T in thresholds if 0 < T < E})
            for lo, hi in zip([0] + cuts, cuts + [E]):
                if hi <= lo:
                    continue
                mask = 0
                for i, T in enumerate(thresholds):
                    if T > lo:
                        mask |= 1 << i
                if lo == 0:
                    agg[(mask, True)] = agg.get((mask, True), 0) + 1
                    if hi > 1:
                        agg[(mask, False)] = agg.get((mask, False), 0) + hi - 1
                else:
                    agg[(mask, False)] = agg.get((mask, False), 0) + hi - lo
        else:  # pragma: no cover - no current grid expr uses mod/floordiv
            for v in range(E):
                mask = 0
                for i, c in enumerate(coords):
                    if expr.evaluate({**c, t.name: v}) < d.extent:
                        mask |= 1 << i
                key = (mask, v == 0)
                agg[key] = agg.get(key, 0) + 1
        per_loop.append([(mask, zero, count)
                         for (mask, zero), count in agg.items()])
    return static_mask, per_loop


def simulate(plan: DataflowPlan, hw: HardwareModel, *,
             launch_overhead_s: float = 20e-6,
             wave_overhead_s: float = 2e-6,
             fwd: Optional[TMapping[str, ForwardLeg]] = None,
             record: Optional[List[dict]] = None) -> SimResult:
    """Simulate plan execution by wave equivalence class (exact).

    For each class: per-core inner-loop time uses the double-buffered pipeline
    with *per-channel* / *per-ring* effective bandwidths resolved from the set
    of cores active in its waves; a wave completes at the max over cores
    (barrier), plus a dispatch overhead.  Hoisted transfers are charged at the
    waves where their enclosing temporal index changes.  Identical math to
    :func:`simulate_reference` at stride 1, without visiting every wave.

    ``fwd`` maps tensor names to :class:`~repro.core.reuse.ForwardLeg`\\ s
    for accesses riding a forwarded inter-kernel edge (the pipeline
    co-planner's two-phase producer/consumer execution): a ``send`` store
    lands in the producing core's L1, a ``recv`` load reads the tile back
    from distributed L1 — crossing one NoC ring per mismatched spatial
    digit (``shuffle_axes``, each ring contended by every active core
    pulling through it) — and neither touches DRAM.  ``None``/empty keeps
    the simulation bit-identical to the historical single-kernel path.

    ``record``, when given a list, receives one dict per wave equivalence
    class — population, active-core mask, wave/hoist/overhead seconds and
    DRAM/NoC bytes (class totals) — the raw material for the simulated
    resource timelines ``repro.obs.explain`` renders.  It is append-only
    bookkeeping of values already computed: passing it changes no cost.
    """
    fwd = fwd or {}
    m = plan.mapping
    prog = m.program
    t_body = body_compute_seconds(plan, hw)
    coords = _core_coords(plan)
    n_cores = len(coords)
    n_temporal = len(m.temporal)
    n_loops = n_temporal + len(prog.seq_dims)
    seq_extents = [e for _, e in m.seq_loops()]      # per-core (split) extents
    inner_I = seq_extents[-1] if seq_extents else 1
    outer_seq = math.prod(seq_extents[:-1]) if len(seq_extents) > 1 else 1
    red_act = m.active_reduce_factor()

    dram_bw = hw.global_mem.bandwidth_gbps * 1e9
    link_bw = {ic.name: ic.bandwidth_gbps * 1e9 for ic in hw.interconnects}
    l1_bw = hw.local_mem.bandwidth_gbps * 1e9
    sizes = dict(m.hw_dims)

    inner_loads = [c for c in plan.loads if c.hoist.level == n_loops]
    hoisted_loads = [c for c in plan.loads if c.hoist.level < n_loops]
    inner_stores = [s for s in plan.stores if s.level == n_loops]
    outer_stores = [s for s in plan.stores if s.level < n_loops]
    k_cut = [min(c.hoist.level, n_temporal) for c in hoisted_loads]

    static_mask, per_loop = _loop_digit_groups(plan, coords, hw)
    n_waves = math.prod(t.extent for t in m.temporal) if m.temporal else 1

    def wave_cost(amask: int):
        """Everything about one active-core set, per wave (no population):
        barrier time, inner-op traffic, per-hoisted-load (time, dram, noc)
        when its reload triggers, and the per-wave outer-store cost."""
        active = [coords[i] for i in range(n_cores) if (amask >> i) & 1]

        # --- contention census ---------------------------------------------
        # DRAM channels: one user per fetching core per op.  NoC rings: one
        # user per *multicast operation* per ring instance (a ring multicast
        # carries the tile once regardless of receiver count).
        chan_users: Dict[Tuple[int, ...], int] = {}
        ring_users: Dict[Tuple[str, Tuple[Tuple[str, int], ...]], int] = {}
        for c in inner_loads:
            leg = fwd.get(c.access.tensor.name)
            if leg is not None:
                # forwarded recv: no DRAM users; each active core pulls its
                # own tile through the re-shuffle rings (per-core users, not
                # per-multicast — every tile is distinct)
                if leg.kind != "free":
                    for core in active:
                        for a in leg.shuffle_axes:
                            ic = hw.interconnect_along(a)
                            if ic is None:
                                continue
                            other = tuple(sorted((k, v)
                                                 for k, v in core.items()
                                                 if k != a))
                            rk = (ic.name, other)
                            ring_users[rk] = ring_users.get(rk, 0) + 1
                continue
            if not c.bcast_axes:
                for core in active:
                    ch = hw.channel_of_core(core)
                    chan_users[ch] = chan_users.get(ch, 0) + 1
            else:
                seen_rings = set()
                for core in active:
                    # producer cores (coordinate 0 along every bcast axis)
                    # fetch from DRAM once
                    if all(core.get(a, 0) == 0 for a in c.bcast_axes):
                        ch = hw.channel_of_core(core)
                        chan_users[ch] = chan_users.get(ch, 0) + 1
                    for a in c.bcast_axes:
                        ic = hw.interconnect_along(a)
                        if ic is None:
                            continue
                        other = tuple(sorted((k, v) for k, v in core.items()
                                             if k != a))
                        key = (id(c), ic.name, other)
                        if key in seen_rings:
                            continue
                        seen_rings.add(key)
                        rk = (ic.name, other)
                        ring_users[rk] = ring_users.get(rk, 0) + 1

        # --- per-core inner-loop time --------------------------------------
        wave_time = 0.0
        for core in active:
            t_load = 0.0
            for c in inner_loads:
                tb = c.access.tile_bytes
                leg = fwd.get(c.access.tensor.name)
                if leg is not None:
                    if leg.kind == "free":
                        continue
                    # on-chip receive: remote L1 read + re-shuffle ring hops
                    t_leg = tb / l1_bw
                    for a in leg.shuffle_axes:
                        ic = hw.interconnect_along(a)
                        if ic is None:
                            continue
                        other = tuple(sorted((k, v) for k, v in core.items()
                                             if k != a))
                        users = max(1, ring_users.get((ic.name, other), 1))
                        t_leg += tb / (link_bw[ic.name] / users)
                    t_load += t_leg
                    t_load += tb / l1_bw        # local landing, like any load
                    continue
                if not c.bcast_axes:
                    ch = hw.channel_of_core(core)
                    users = max(1, chan_users.get(ch, 1))
                    t_load += tb / (dram_bw / users)
                else:
                    t_leg = 0.0
                    if all(core.get(a, 0) == 0 for a in c.bcast_axes):
                        ch = hw.channel_of_core(core)
                        users = max(1, chan_users.get(ch, 1))
                        t_leg = tb / (dram_bw / users)
                    t_noc = 0.0
                    for a in c.bcast_axes:
                        ic = hw.interconnect_along(a)
                        if ic is None:
                            continue
                        other = tuple(sorted((k, v) for k, v in core.items()
                                             if k != a))
                        users = max(1, ring_users.get((ic.name, other), 1))
                        t_noc += tb / (link_bw[ic.name] / users)
                    t_load += max(t_leg, t_noc)       # cut-through pipelining
                t_load += tb / l1_bw
            t_store = 0.0
            for s in inner_stores:
                leg = fwd.get(s.access.tensor.name)
                if leg is not None and not s.reduce_axes:
                    if leg.kind != "free":
                        t_store += s.access.tile_bytes / l1_bw
                    continue
                ch = hw.channel_of_core(core)
                users = max(1, chan_users.get(ch, 1))
                t_store += s.access.tile_bytes / (dram_bw / max(1, users))
            core_t = pipelined_loop_time(inner_I, t_load, t_store, t_body)
            core_t *= outer_seq
            wave_time = max(wave_time, core_t)

        # --- hoisted transfers at temporal boundaries ----------------------
        n_active = len(active)
        hoist_info = []
        for c in hoisted_loads:
            # reload when any temporal loop outer to the hoist level changed;
            # loads hoisted *within* the sequential nest re-issue once per
            # iteration of the seq loops outer to their level
            seq_issues = (math.prod(seq_extents[:c.hoist.level - n_temporal])
                          if c.hoist.level > n_temporal else 1)
            tb = c.access.tile_bytes * c.hoist.tiles_per_issue * seq_issues
            leg = fwd.get(c.access.tensor.name)
            if leg is not None:
                if leg.kind == "free":
                    hoist_info.append((0.0, 0.0, 0.0))
                    continue
                # bulk on-chip receive: every active core pulls its slab from
                # distributed L1 concurrently; each mismatched axis carries
                # the whole per-ring slab set through its ring serially
                t_c = tb / l1_bw
                nb = 0.0
                for a in leg.shuffle_axes:
                    ic = hw.interconnect_along(a)
                    if ic is None:
                        continue
                    t_c += tb * sizes[a] / link_bw[ic.name]
                    nb += tb * n_active
                hoist_info.append((t_c, 0.0, nb))
                continue
            if c.bcast_axes:
                repl = math.prod(sizes[a] for a in c.bcast_axes)
                producers = max(1, n_active // repl)
                t_dram = tb * producers / (dram_bw * hw.global_channels())
                slowest_ring = min((link_bw[hw.interconnect_along(a).name]
                                    for a in c.bcast_axes
                                    if hw.interconnect_along(a)), default=None)
                t_nc = tb / slowest_ring if slowest_ring else 0.0
                t_c = max(t_dram, t_nc)
                db = tb * producers
                nb = 0.0
                planes = producers
                for a in c.bcast_axes:
                    nb += tb * (sizes[a] - 1) * planes
                    planes *= sizes[a]
            else:
                t_c = tb * n_active / (dram_bw * hw.global_channels())
                db = tb * n_active
                nb = 0.0
            hoist_info.append((t_c, db, nb))

        # --- traffic bookkeeping for inner ops -----------------------------
        iters = inner_I * outer_seq
        inner_dram = inner_noc = 0.0
        for c in inner_loads:
            tb = c.access.tile_bytes * iters
            leg = fwd.get(c.access.tensor.name)
            if leg is not None:
                if leg.kind != "free":
                    for a in leg.shuffle_axes:
                        if hw.interconnect_along(a) is not None:
                            inner_noc += tb * n_active
                continue
            if c.bcast_axes:
                repl = math.prod(sizes[a] for a in c.bcast_axes)
                producers = max(1, n_active // repl)
                inner_dram += tb * producers
                planes = producers
                for a in c.bcast_axes:
                    inner_noc += tb * (sizes[a] - 1) * planes
                    planes *= sizes[a]
            else:
                inner_dram += tb * n_active
        for s in inner_stores:
            leg = fwd.get(s.access.tensor.name)
            if leg is not None and not s.reduce_axes:
                continue                        # on-chip: no DRAM bytes
            inner_dram += s.access.tile_bytes * iters * n_active
        ostore_t, ostore_dram, ostore_noc = _reduce_epilogue_cost(
            m, outer_stores, n_active, red_act, hw, dram_bw, link_bw,
            fwd=fwd, l1_bw=l1_bw)
        return (wave_time, inner_dram, inner_noc, hoist_info, ostore_t,
                ostore_dram, ostore_noc)

    total = 0.0
    dram_bytes = 0.0
    noc_bytes = 0.0
    n_classes = 0
    cache: Dict[int, tuple] = {}
    for combo in itertools.product(*per_loop) if per_loop else [()]:
        pop = 1
        amask = static_mask
        j = -1                          # last non-zero digit position
        for i, (mask, zero, count) in enumerate(combo):
            pop *= count
            amask &= mask
            if not zero:
                j = i
        first = j == -1                 # the all-zero wave (population 1)
        n_classes += 1
        if amask == 0:
            total += wave_overhead_s * pop
            if record is not None:
                record.append({
                    "population": pop, "active_mask": 0, "n_active": 0,
                    "wave_s": 0.0, "hoist_s": 0.0,
                    "overhead_s": wave_overhead_s,
                    "dram_bytes": 0.0, "noc_bytes": 0.0})
            continue
        cost = cache.get(amask)
        if cost is None:
            cost = cache[amask] = wave_cost(amask)
        (wave_time, inner_dram, inner_noc, hoist_info, ostore_t,
         ostore_dram, ostore_noc) = cost
        t_hoist = ostore_t
        cls_dram = (inner_dram + ostore_dram) * pop
        cls_noc = (inner_noc + ostore_noc) * pop
        dram_bytes += (inner_dram + ostore_dram) * pop
        noc_bytes += (inner_noc + ostore_noc) * pop
        for (t_c, db, nb), k in zip(hoist_info, k_cut):
            if first or j < k:
                t_hoist += t_c
                dram_bytes += db * pop
                noc_bytes += nb * pop
                cls_dram += db * pop
                cls_noc += nb * pop
        total += (wave_time + t_hoist + wave_overhead_s) * pop
        if record is not None:
            record.append({
                "population": pop, "active_mask": amask,
                "n_active": bin(amask).count("1"),
                "wave_s": wave_time, "hoist_s": t_hoist,
                "overhead_s": wave_overhead_s,
                "dram_bytes": cls_dram, "noc_bytes": cls_noc})

    total += launch_overhead_s        # per-kernel dispatch cost (paper S3.2:
    #                                   small shapes dominated by overheads)
    flops = prog.mat_flops()
    return SimResult(total_s=total, dram_bytes=dram_bytes, noc_bytes=noc_bytes,
                     flops=flops, n_waves=n_waves,
                     wave_overhead_s=wave_overhead_s,
                     n_wave_classes=n_classes)


# --------------------------------------------------------------------------
# Reference path: explicit wave-by-wave loop (test oracle)
# --------------------------------------------------------------------------
def simulate_reference(plan: DataflowPlan, hw: HardwareModel, *,
                       launch_overhead_s: float = 20e-6,
                       wave_overhead_s: float = 2e-6,
                       max_waves_exact: int = 4096) -> SimResult:
    """Simulate plan execution wave by wave (the original O(waves x cores x
    ops) loop).  Exact below ``max_waves_exact`` waves; beyond that it
    stride-samples and scales (the historical fidelity cut the class-based
    :func:`simulate` retires).  Kept as the oracle for equivalence tests.
    """
    m = plan.mapping
    prog = m.program
    t_body = body_compute_seconds(plan, hw)
    coords = _core_coords(plan)
    waves = _wave_envs(plan)
    n_temporal = len(m.temporal)
    n_loops = n_temporal + len(prog.seq_dims)
    seq_extents = [e for _, e in m.seq_loops()]      # per-core (split) extents
    inner_I = seq_extents[-1] if seq_extents else 1
    outer_seq = math.prod(seq_extents[:-1]) if len(seq_extents) > 1 else 1
    red_act = m.active_reduce_factor()

    stride = max(1, len(waves) // max_waves_exact)
    sampled = waves[::stride]
    scale = len(waves) / len(sampled)

    dram_bw = hw.global_mem.bandwidth_gbps * 1e9
    link_bw = {ic.name: ic.bandwidth_gbps * 1e9 for ic in hw.interconnects}
    l1_bw = hw.local_mem.bandwidth_gbps * 1e9
    sizes = dict(m.hw_dims)

    total = 0.0
    dram_bytes = 0.0
    noc_bytes = 0.0
    prev_env: Dict[str, int] = {}

    # pre-split ops
    inner_loads = [c for c in plan.loads if c.hoist.level == n_loops]
    hoisted_loads = [c for c in plan.loads if c.hoist.level < n_loops]
    inner_stores = [s for s in plan.stores if s.level == n_loops]
    outer_stores = [s for s in plan.stores if s.level < n_loops]

    for env in sampled:
        active = [c for c in coords if _is_active(plan, {**c, **env})
                  and not hw.is_disabled(c)]
        if not active:
            total += wave_overhead_s
            continue

        # --- contention census for this wave -------------------------------
        # DRAM channels: one user per fetching core per op.  NoC rings: one
        # user per *multicast operation* per ring instance (a ring multicast
        # carries the tile once regardless of receiver count).
        chan_users: Dict[Tuple[int, ...], int] = {}
        ring_users: Dict[Tuple[str, Tuple[Tuple[str, int], ...]], int] = {}

        for c in inner_loads:
            if not c.bcast_axes:
                for core in active:
                    ch = hw.channel_of_core(core)
                    chan_users[ch] = chan_users.get(ch, 0) + 1
            else:
                seen_rings = set()
                for core in active:
                    # producer cores (coordinate 0 along every bcast axis)
                    # fetch from DRAM once
                    if all(core.get(a, 0) == 0 for a in c.bcast_axes):
                        ch = hw.channel_of_core(core)
                        chan_users[ch] = chan_users.get(ch, 0) + 1
                    for a in c.bcast_axes:
                        ic = hw.interconnect_along(a)
                        if ic is None:
                            continue
                        other = tuple(sorted((k, v) for k, v in core.items()
                                             if k != a))
                        key = (id(c), ic.name, other)
                        if key in seen_rings:
                            continue
                        seen_rings.add(key)
                        rk = (ic.name, other)
                        ring_users[rk] = ring_users.get(rk, 0) + 1

        # --- per-core inner-loop time ---------------------------------------
        wave_time = 0.0
        for core in active:
            t_load = 0.0
            for c in inner_loads:
                tb = c.access.tile_bytes
                if not c.bcast_axes:
                    ch = hw.channel_of_core(core)
                    users = max(1, chan_users.get(ch, 1))
                    t_load += tb / (dram_bw / users)
                else:
                    t_leg = 0.0
                    if all(core.get(a, 0) == 0 for a in c.bcast_axes):
                        ch = hw.channel_of_core(core)
                        users = max(1, chan_users.get(ch, 1))
                        t_leg = tb / (dram_bw / users)
                    t_noc = 0.0
                    for a in c.bcast_axes:
                        ic = hw.interconnect_along(a)
                        if ic is None:
                            continue
                        other = tuple(sorted((k, v) for k, v in core.items() if k != a))
                        users = max(1, ring_users.get((ic.name, other), 1))
                        t_noc += tb / (link_bw[ic.name] / users)
                    t_load += max(t_leg, t_noc)       # cut-through pipelining
                t_load += tb / l1_bw
            t_store = 0.0
            for s in inner_stores:
                ch = hw.channel_of_core(core)
                users = max(1, chan_users.get(ch, 1))
                t_store += s.access.tile_bytes / (dram_bw / max(1, users))
            core_t = pipelined_loop_time(inner_I, t_load, t_store, t_body)
            core_t *= outer_seq
            wave_time = max(wave_time, core_t)

        # --- hoisted transfers at temporal boundaries ------------------------
        t_hoist = 0.0
        for c in hoisted_loads:
            # reload when any temporal loop outer to the hoist level changed;
            # loads hoisted *within* the sequential nest re-issue once per
            # iteration of the seq loops outer to their level
            changed = (not prev_env) or any(
                env.get(t.name, 0) != prev_env.get(t.name, 0)
                for t in m.temporal[:min(c.hoist.level, n_temporal)])
            if changed:
                seq_issues = (math.prod(seq_extents[:c.hoist.level - n_temporal])
                              if c.hoist.level > n_temporal else 1)
                tb = c.access.tile_bytes * c.hoist.tiles_per_issue * seq_issues
                if c.bcast_axes:
                    repl = math.prod(sizes[a] for a in c.bcast_axes)
                    producers = max(1, len(active) // repl)
                    t_dram = tb * producers / (dram_bw * hw.global_channels())
                    slowest_ring = min((link_bw[hw.interconnect_along(a).name]
                                        for a in c.bcast_axes
                                        if hw.interconnect_along(a)), default=None)
                    t_nc = tb / slowest_ring if slowest_ring else 0.0
                    t_hoist += max(t_dram, t_nc)
                    dram_bytes += tb * producers * scale
                    planes = producers
                    for a in c.bcast_axes:
                        noc_bytes += tb * (sizes[a] - 1) * planes * scale
                        planes *= sizes[a]
                else:
                    t_hoist += tb * len(active) / (dram_bw * hw.global_channels())
                    dram_bytes += tb * len(active) * scale

        # --- traffic bookkeeping for inner ops ------------------------------
        iters = inner_I * outer_seq
        for c in inner_loads:
            tb = c.access.tile_bytes * iters
            if c.bcast_axes:
                repl = math.prod(sizes[a] for a in c.bcast_axes)
                producers = max(1, len(active) // repl)
                dram_bytes += tb * producers * scale
                planes = producers
                for a in c.bcast_axes:
                    noc_bytes += tb * (sizes[a] - 1) * planes * scale
                    planes *= sizes[a]
            else:
                dram_bytes += tb * len(active) * scale
        for s in inner_stores:
            dram_bytes += s.access.tile_bytes * iters * len(active) * scale
        ostore_t, ostore_dram, ostore_noc = _reduce_epilogue_cost(
            m, outer_stores, len(active), red_act, hw, dram_bw, link_bw)
        t_hoist += ostore_t
        dram_bytes += ostore_dram * scale
        noc_bytes += ostore_noc * scale

        total += wave_time + t_hoist + wave_overhead_s
        prev_env = env

    total *= scale
    total += launch_overhead_s        # per-kernel dispatch cost (paper S3.2:
    #                                   small shapes dominated by overheads)
    flops = prog.mat_flops()
    return SimResult(total_s=total, dram_bytes=dram_bytes, noc_bytes=noc_bytes,
                     flops=flops, n_waves=len(waves),
                     wave_overhead_s=wave_overhead_s)
