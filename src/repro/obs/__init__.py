"""Planner observability: structured tracing, unified metrics, plan explain.

Three deliberately-decoupled layers (DESIGN_OBS.md):

* :mod:`repro.obs.trace` — a low-overhead span tracer (context-manager +
  decorator API) threaded through the whole planning stack and exported as
  Chrome trace-event JSON (``REPRO_TRACE=<path>`` or
  ``benchmarks/run.py --trace``), with per-worker span buffers merged
  across process boundaries by ``repro.parallel.search_exec``;
* :mod:`repro.obs.metrics` — a process-wide counter/gauge/histogram
  registry with labeled series and a JSON snapshot; the planner's phase
  timings, plancache hit/miss/bypass counters, ``lower_jax`` planner
  fallbacks and worker shard timings all land here;
* :mod:`repro.obs.explain` — plan introspection: per-plan simulated
  resource timelines, an ASCII mesh-utilization heatmap, and
  winner-vs-runner-up per-resource cost diffs
  (``python -m repro.obs explain <suite/cell>``).

The serving stack (PR 10) adds four more stdlib-only layers:

* :mod:`repro.obs.context` — contextvar request/incident correlation IDs
  stamped onto every span, metric exemplar and flight-recorder event;
* :mod:`repro.obs.flightrec` — a bounded ring buffer of structured
  serving events (rung decisions, breaker transitions, faults,
  containment, QoS shed, violations) dumped atomically and rendered by
  ``python -m repro.obs incident <dump>``;
* :mod:`repro.obs.slo` — sliding-window deadline-attainment / rung
  distribution / blast-radius tracking with multi-window burn-rate
  alerts that fire flight-recorder events;
* :mod:`repro.obs.expo` — Prometheus text exposition of the metrics
  registry plus the ``launch/serve.py --introspect-port`` HTTP endpoint
  (``/metrics``, ``/healthz``, ``/slo``, ``/plans``, ``/tenants``).

``trace``, ``metrics``, ``context``, ``flightrec``, ``slo`` and ``expo``
are stdlib-only and import nothing from ``repro.core`` (the core planner
imports *them*); ``explain`` sits above the planner and may import
everything.

The hard invariant of the whole package: **observation never perturbs
planning** — best plans, costs, and cache keys are bit-identical with
tracing on or off, at any worker count (``tests/test_obs.py`` pins this).
"""
from . import context, expo, flightrec, metrics, slo, trace

__all__ = ["context", "expo", "flightrec", "metrics", "slo", "trace"]
