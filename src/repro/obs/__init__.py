"""Planner observability: structured tracing, unified metrics, plan explain.

Three deliberately-decoupled layers (DESIGN_OBS.md):

* :mod:`repro.obs.trace` — a low-overhead span tracer (context-manager +
  decorator API) threaded through the whole planning stack and exported as
  Chrome trace-event JSON (``REPRO_TRACE=<path>`` or
  ``benchmarks/run.py --trace``), with per-worker span buffers merged
  across process boundaries by ``repro.parallel.search_exec``;
* :mod:`repro.obs.metrics` — a process-wide counter/gauge/histogram
  registry with labeled series and a JSON snapshot; the planner's phase
  timings, plancache hit/miss/bypass counters, ``lower_jax`` planner
  fallbacks and worker shard timings all land here;
* :mod:`repro.obs.explain` — plan introspection: per-plan simulated
  resource timelines, an ASCII mesh-utilization heatmap, and
  winner-vs-runner-up per-resource cost diffs
  (``python -m repro.obs explain <suite/cell>``).

``trace`` and ``metrics`` are stdlib-only and import nothing from
``repro.core`` (the core planner imports *them*); ``explain`` sits above
the planner and may import everything.

The hard invariant of the whole package: **observation never perturbs
planning** — best plans, costs, and cache keys are bit-identical with
tracing on or off, at any worker count (``tests/test_obs.py`` pins this).
"""
from . import metrics, trace

__all__ = ["metrics", "trace"]
