"""CLI for the observability layer.

Usage::

    python -m repro.obs explain <suite/cell> [--no-cache] [--workers N]
    python -m repro.obs explain --list
    python -m repro.obs metrics [--json]
    python -m repro.obs incident <dump.json> [--rid ID] [--json]

``explain`` re-resolves one benchmark cell (read-through the plan cache
by default, so warmed cells render without re-searching) and prints the
simulated timeline, mesh heatmap and winner-vs-runner-up diff — see
``repro.obs.explain``.  ``metrics`` prints the unified registry snapshot
of this process (mostly useful after an in-process run; launchers and
benchmarks honor ``REPRO_METRICS=<path>`` to persist theirs).
``incident`` renders a flight-recorder dump (``REPRO_FLIGHTREC=<path>``
or ``serve.py --flightrec``) as per-request/incident timelines: which
rung answered, why, how long each step took, what it displaced.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import metrics


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    ex = sub.add_parser("explain", help="render one benchmark cell")
    ex.add_argument("cell", nargs="?",
                    help="plan_speed cell name, e.g. "
                         "gemm/wormhole_8x8/M1024_N1024_K4096 or "
                         "pipeline/mlp2/M16384_d128_f512")
    ex.add_argument("--list", action="store_true",
                    help="print the resolvable cell names and exit")
    ex.add_argument("--no-cache", action="store_true",
                    help="plan cold instead of read-through the plan cache")
    ex.add_argument("--workers", type=int, default=1,
                    help="planner worker count for the resolve (default 1)")
    mt = sub.add_parser("metrics", help="print this process's registry")
    mt.add_argument("--json", action="store_true", dest="as_json",
                    help="raw JSON snapshot (default: same)")
    inc = sub.add_parser("incident",
                         help="render a flight-recorder dump as "
                              "per-request timelines")
    inc.add_argument("dump", help="dump path (REPRO_FLIGHTREC / "
                                  "serve.py --flightrec output)")
    inc.add_argument("--rid", default=None,
                     help="only the timeline of one request/incident ID")
    inc.add_argument("--json", action="store_true", dest="as_json",
                     help="print the raw dump JSON instead of rendering")
    args = ap.parse_args(argv)

    if args.cmd == "metrics":
        print(json.dumps(metrics.snapshot(), indent=1, sort_keys=True))
        return 0

    if args.cmd == "incident":
        from . import flightrec
        try:
            doc = flightrec.load_dump(args.dump)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        if args.as_json:
            print(json.dumps(doc, indent=1, sort_keys=True))
        else:
            print(flightrec.render_incident(doc, rid=args.rid))
        return 0

    from . import explain as ex_mod
    if args.list:
        for name in ex_mod.known_cells():
            print(name)
        return 0
    if not args.cell:
        ap.error("explain needs a cell name (or --list)")
    cache = None
    if not args.no_cache:
        from repro.plancache import PlanCache
        cache = PlanCache()
    try:
        print(ex_mod.explain(args.cell, cache=cache, workers=args.workers))
    except ex_mod.CellError as e:
        print(f"error: {e}", file=sys.stderr)
        print("use --list for resolvable cells", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
