"""Bounded flight recorder for the serving stack (stdlib-only).

A ring buffer of *structured events* — plan-request rung decisions,
circuit-breaker transitions, fault injections, containment-ladder rungs,
QoS shed/evictions, isolation-validator violations, SLO burn alerts,
pool-worker failures — each stamped with a wall-clock time, a monotonic
sequence number, and the active request/incident ID
(:mod:`repro.obs.context`).  The buffer is capacity-bounded (oldest
events drop, a ``dropped`` counter keeps the loss honest) so the
recorder can stay on for the lifetime of a serving process.

Off by default with one attribute load per :func:`record` call when off
— the same near-zero-cost discipline as the tracer, and the same
invariant: the recorder only *observes*; with it off (or on), planning
and serving decisions are bit-identical.

Enable with ``REPRO_FLIGHTREC=<path>`` (the launchers call
:func:`refresh_from_env`; the dump is written at interpreter exit —
which includes ``SystemExit`` paths like the serve driver's containment
assertion) or programmatically with :func:`enable`.  The dump is written
atomically (tmp + ``os.replace``) so an orchestrator's SIGKILL can tear
at most the tmp file, never the dump.  Isolation violations additionally
force an immediate dump (:mod:`repro.tenancy.validator` calls
:func:`dump` with ``reason="isolation_violation"``): the buffer at the
moment of the violation is exactly the evidence an incident review
needs.

``python -m repro.obs incident <dump>`` renders the per-request
timeline: which rung answered each request, why (the resolution log),
how long each step took, and what it displaced (re-planned tenants,
evicted best-effort deadlines).
"""
from __future__ import annotations

import atexit
import json
import os
import sys
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

FLIGHTREC_ENV = "REPRO_FLIGHTREC"
CAP_ENV = "REPRO_FLIGHTREC_CAP"
DEFAULT_CAPACITY = 2048

#: The event taxonomy (DESIGN_OBS.md).  ``record`` accepts any kind —
#: the tuple documents the canonical ones the stack emits.
KINDS = ("plan_request", "breaker", "fault", "replan", "containment",
         "qos_shed", "qos_evict", "violation", "slo_alert", "pool_failure")


def _json_safe(v: Any) -> Any:
    """Copy-normalize a field value at record time: events must not hold
    references to caller state that mutates later (log lists especially —
    a torn buffer is exactly what the recorder exists to rule out)."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (list, tuple, set, frozenset)):
        return [_json_safe(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _json_safe(x) for k, x in v.items()}
    return str(v)


class FlightRecorder:
    """The ring buffer.  One module-level instance (:data:`RECORDER`) is
    the intended deployment; the class is separate for tests."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY) -> None:
        self.on = False
        self.path: Optional[str] = None
        self.capacity = capacity
        self._events: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._seq = 0
        self.dropped = 0
        self.started = time.time()
        self._atexit_armed = False

    # ----------------------------------------------------------- control
    def enable(self, path: Optional[str] = None,
               capacity: Optional[int] = None) -> None:
        if capacity is not None and capacity != self.capacity:
            with self._lock:
                self.capacity = capacity
                self._events = deque(self._events, maxlen=capacity)
        self.on = True
        if path:
            self.path = path
            if not self._atexit_armed:
                self._atexit_armed = True
                atexit.register(self._atexit_dump)

    def disable(self) -> None:
        self.on = False

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0
            self._seq = 0

    # ------------------------------------------------------------ record
    def record(self, kind: str, **fields: Any) -> None:
        """Append one event (no-op when off).  The active correlation ID
        is stamped automatically; fields are copy-normalized to JSON-safe
        values at record time."""
        if not self.on:
            return
        from . import context
        ev: Dict[str, Any] = {"kind": kind, "t": time.time(),
                              "rid": context.current()}
        for k, v in fields.items():
            ev[k] = _json_safe(v)
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self.dropped += 1
            self._seq += 1
            ev["seq"] = self._seq
            self._events.append(ev)

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    # -------------------------------------------------------------- dump
    def dump(self, path: Optional[str] = None,
             reason: str = "explicit") -> Optional[str]:
        """Write the buffer as JSON to ``path`` (default: the armed path).
        Atomic: tmp + ``os.replace``.  Returns the path written, or None
        when no destination is known."""
        path = path or self.path
        if not path:
            return None
        with self._lock:
            events = list(self._events)
            dropped = self.dropped
        doc = {
            "meta": {
                "pid": os.getpid(),
                "argv": list(sys.argv),
                "started": self.started,
                "dumped": time.time(),
                "reason": reason,
                "capacity": self.capacity,
                "n_events": len(events),
                "dropped": dropped,
            },
            "events": events,
        }
        tmp = f"{path}.{os.getpid()}.tmp"
        try:
            with open(tmp, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True, default=str)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                try:
                    os.remove(tmp)
                except OSError:
                    pass
        return path

    def _atexit_dump(self) -> None:
        try:
            self.dump(reason="atexit")
        except OSError:
            pass


RECORDER = FlightRecorder()


# ------------------------------------------------- module-level convenience
def enabled() -> bool:
    return RECORDER.on


def enable(path: Optional[str] = None,
           capacity: Optional[int] = None) -> None:
    RECORDER.enable(path, capacity)


def disable() -> None:
    RECORDER.disable()


def clear() -> None:
    RECORDER.clear()


def record(kind: str, **fields: Any) -> None:
    if not RECORDER.on:                  # the entire disabled cost
        return
    RECORDER.record(kind, **fields)


def events() -> List[Dict[str, Any]]:
    return RECORDER.events()


def dump(path: Optional[str] = None,
         reason: str = "explicit") -> Optional[str]:
    return RECORDER.dump(path, reason=reason)


def refresh_from_env() -> None:
    """Arm the recorder from ``REPRO_FLIGHTREC=<path>`` (capacity from
    ``REPRO_FLIGHTREC_CAP``).  Called by the launchers at startup; a
    programmatic :func:`enable` is unaffected when the env is unset."""
    path = os.environ.get(FLIGHTREC_ENV, "").strip()
    if not path:
        return
    cap: Optional[int] = None
    raw = os.environ.get(CAP_ENV, "").strip()
    if raw:
        try:
            cap = max(1, int(raw))
        except ValueError:
            cap = None
    enable(path, capacity=cap)


# ------------------------------------------------------- incident renderer
def load_dump(path: str) -> Dict[str, Any]:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "events" not in doc:
        raise ValueError(f"{path}: not a flight-recorder dump "
                         f"(no 'events' key)")
    return doc


def _fmt_ms(seconds: Any) -> str:
    try:
        return f"{float(seconds) * 1e3:.1f}ms"
    except (TypeError, ValueError):
        return "?"


def _fmt_event(ev: Dict[str, Any]) -> tuple:
    """One summary line + indented detail lines for an event."""
    kind = ev.get("kind", "?")
    detail: List[str] = []
    if kind == "plan_request":
        line = (f"rung={ev.get('rung')} outcome={ev.get('outcome')} "
                f"{_fmt_ms(ev.get('seconds'))} "
                f"deadline={ev.get('deadline_ms')}ms")
        if ev.get("background"):
            line += " background=yes"
        if ev.get("key"):
            line += f" key={str(ev['key'])[:12]}"
        detail = [str(l) for l in ev.get("log") or []]
    elif kind == "breaker":
        line = (f"{ev.get('key')}: {ev.get('from')} -> {ev.get('to')}")
    elif kind == "fault":
        what = ev.get("cores") or ev.get("link") or ev.get("cell") or ""
        line = f"cause={ev.get('cause')} {what}"
    elif kind == "replan":
        line = (f"cause={ev.get('cause')} rung={ev.get('rung')} "
                f"{_fmt_ms(ev.get('seconds'))} "
                f"within_budget={ev.get('within_budget')}")
    elif kind == "containment":
        line = (f"cause={ev.get('cause')} owner={ev.get('owner')} "
                f"rung={ev.get('rung')} "
                f"blast_radius={ev.get('blast_radius')} "
                f"{_fmt_ms(ev.get('seconds'))}")
        repl = ev.get("replanned") or []
        if repl:
            line += f" replanned={','.join(str(t) for t in repl)}"
        detail = [str(l) for l in ev.get("log") or []]
    elif kind in ("qos_shed", "qos_evict"):
        line = f"tenant={ev.get('tenant')}"
    elif kind == "violation":
        probs = ev.get("problems") or []
        line = f"{len(probs)} problem(s)"
        detail = [str(p) for p in probs]
    elif kind == "slo_alert":
        line = (f"state={ev.get('state')} "
                f"fast_burn={ev.get('fast_burn')} "
                f"slow_burn={ev.get('slow_burn')} "
                f"attainment={ev.get('attainment')}")
    elif kind == "pool_failure":
        line = f"{ev.get('error')} in {ev.get('where')}"
    else:
        skip = {"kind", "t", "rid", "seq"}
        line = " ".join(f"{k}={ev[k]}" for k in sorted(ev)
                        if k not in skip)
    return line, detail


def render_incident(doc: Dict[str, Any],
                    rid: Optional[str] = None) -> str:
    """Reconstruct the per-request/incident timeline from a dump.

    Events are grouped by correlation ID (first-seen order, uncorrelated
    events last), each group rendered as offsets from its first event —
    which rung answered, why (log lines), how long, what it displaced.
    ``rid`` filters to one group.
    """
    meta = doc.get("meta", {})
    events = sorted(doc.get("events", []),
                    key=lambda e: e.get("seq", 0))
    groups: Dict[Optional[str], List[Dict[str, Any]]] = {}
    order: List[Optional[str]] = []
    for ev in events:
        g = ev.get("rid")
        if g not in groups:
            groups[g] = []
            order.append(g)
    for ev in events:
        groups[ev.get("rid")].append(ev)
    if None in order:                    # uncorrelated events render last
        order.remove(None)
        order.append(None)
    if rid is not None:
        if rid not in groups:
            known = ", ".join(str(g) for g in order if g)
            return (f"no events for rid {rid!r}; "
                    f"known ids: {known or '(none)'}")
        order = [rid]

    out: List[str] = []
    out.append(f"flight recorder: {len(events)} events "
               f"({meta.get('dropped', 0)} dropped, "
               f"cap {meta.get('capacity', '?')}), "
               f"pid {meta.get('pid', '?')}, "
               f"reason {meta.get('reason', '?')}")
    by_kind: Dict[str, int] = {}
    for ev in events:
        by_kind[ev.get("kind", "?")] = by_kind.get(ev.get("kind", "?"),
                                                   0) + 1
    out.append("  " + "  ".join(f"{k}={n}"
                                for k, n in sorted(by_kind.items())))
    for g in order:
        evs = groups[g]
        t0 = evs[0].get("t", 0.0)
        span_ms = (evs[-1].get("t", t0) - t0) * 1e3
        label = g if g is not None else "(uncorrelated)"
        out.append("")
        out.append(f"{label}  ({len(evs)} events, {span_ms:.1f}ms)")
        for ev in evs:
            dt = (ev.get("t", t0) - t0) * 1e3
            line, detail = _fmt_event(ev)
            out.append(f"  +{dt:8.1f}ms  {ev.get('kind', '?'):<12} {line}")
            for d in detail:
                out.append(f"        | {d}")
    return "\n".join(out)
