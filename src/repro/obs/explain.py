"""Plan introspection: why did the planner pick *this* dataflow?

``python -m repro.obs explain <suite/cell>`` re-resolves one benchmark
cell (same programs, same budgets, same plan-cache keys as
``benchmarks/plan_speed.py``) and renders:

* the **simulated resource timeline** — the wave-class records of the
  event simulator (``repro.core.simulator.simulate(..., record=[])``):
  per class its population, active-core count, wave/hoist/overhead
  seconds and DRAM/NoC bytes, with a proportional ASCII bar;
* an **ASCII mesh heatmap** — per-core busy time accumulated over the
  wave classes (population x wave seconds for every class whose active
  mask covers the core), scaled to a 10-glyph ramp;
* the **winner-vs-runner-up diff** — per-resource busy seconds and bytes
  from :func:`repro.core.perfmodel.cost_breakdown` for the top two
  candidates, so "why not the runner-up" is answerable from the df
  resource that separates them;
* for **pipeline cells**, the per-edge forward-vs-spill delta: each edge
  of the winning graph plan is flipped in isolation and the affected
  nodes re-simulated, so every forwarding decision carries its marginal
  end-to-end cost.

Resolution is read-through-cached: pass a :class:`repro.plancache.PlanCache`
(the CLI default) and previously-planned cells render without re-searching.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core import (SearchBudget, block_shape_candidates,
                        flash_attention_program, flash_decode_program,
                        get_hw, matmul_program, moe_gmm_program,
                        plan_kernel_multi, simulate)
from repro.core.perfmodel import cost_breakdown
from repro.core.simulator import _core_coords
from repro.pipeline import (attn_qk_pv_graph, forward_spec, mlp2_graph,
                            moe_ffn_graph, node_legs, plan_pipeline,
                            simulate_nodes)

# ---------------------------------------------------------------------------
# Benchmark-suite mirrors.  These constants intentionally duplicate
# benchmarks/common.py, benchmarks/plan_speed.py, benchmarks/reduction_table.py
# and benchmarks/pipeline_table.py: explain must resolve the *same* programs
# under the *same* budgets so its plans (and plan-cache keys) match what the
# benchmark JSON reports.  benchmarks/ is not an installed package, so the
# values are mirrored rather than imported; tests/test_obs.py pins them.
# ---------------------------------------------------------------------------
DEFAULT_BUDGET = SearchBudget(top_k=5, max_plans_per_mapping=48,
                              max_candidates=8000)
FLASH_BUDGET = SearchBudget(top_k=5, max_plans_per_mapping=48)
REDUCTION_BUDGET = SearchBudget(top_k=5, max_plans_per_mapping=48,
                                max_candidates=8000)
PIPELINE_BUDGET = SearchBudget(top_k=4, max_plans_per_mapping=48,
                               max_candidates=8000)
GEMM_BLOCKS = ((64, 64, 64), (128, 128, 64), (128, 64, 128),
               (128, 128, 128))
ATTN_BLOCKS = ((64, 64), (128, 128), (128, 256), (256, 128))

_RAMP = " .:-=+*#%@"


class CellError(ValueError):
    """Unrecognized or malformed cell name."""


def _parse(pattern: str, text: str, cell: str) -> Tuple[int, ...]:
    m = re.fullmatch(pattern, text)
    if m is None:
        raise CellError(f"malformed cell {cell!r} (want {pattern})")
    return tuple(int(g) for g in m.groups())


def resolve_kernel_cell(cell: str, *, cache: Any = None,
                        workers: Optional[int] = 1):
    """(PlanResult, HardwareModel) for a single-kernel plan_speed cell
    (``gemm/<hw>/...``, ``flash/...`` or ``reduction/<family>/...``)."""
    from dataclasses import replace
    parts = cell.split("/")
    suite = parts[0]
    if suite == "gemm" and len(parts) == 3:
        hw = get_hw(parts[1])
        M, N, K = _parse(r"M(\d+)_N(\d+)_K(\d+)", parts[2], cell)
        progs = [matmul_program(M, N, K, bm=bm, bn=bn, bk=bk)
                 for bm, bn, bk in block_shape_candidates(M, N, K)]
        budget = DEFAULT_BUDGET
    elif suite == "flash" and len(parts) == 2:
        hw = get_hw("wormhole_8x8")
        bh, seq = _parse(r"h(\d+)_s(\d+)", parts[1], cell)
        progs = [flash_attention_program(bh, seq, seq, 64, bq=bq, bkv=bkv)
                 for bq in (32, 64, 128) for bkv in (32, 64, 128)]
        budget = FLASH_BUDGET
    elif suite == "reduction" and len(parts) == 3:
        hw = get_hw("wormhole_8x8")
        budget = REDUCTION_BUDGET
        fam = parts[1]
        if fam == "gemm_ts":
            M, N, K = _parse(r"M(\d+)_N(\d+)_K(\d+)", parts[2], cell)
            progs = [matmul_program(M, N, K, bm=bm, bn=bn, bk=bk)
                     for bm in (32, 64) for bn in (32, 64)
                     for bk in (64, 128)]
        elif fam == "flash_decode":
            H, S, D = _parse(r"h(\d+)_kv(\d+)_d(\d+)", parts[2], cell)
            progs = [flash_decode_program(H, S, D, bkv=bkv)
                     for bkv in (32, 64, 128)]
        elif fam == "moe_gmm":
            E, cap, din, dout = _parse(r"e(\d+)_c(\d+)_(\d+)x(\d+)",
                                       parts[2], cell)
            progs = [moe_gmm_program(E, cap, din, dout, bm=bm, bn=64, bk=bk)
                     for bm in (64, 128) for bk in (64, 128)]
        else:
            raise CellError(f"unknown reduction family {fam!r} in {cell!r}")
    else:
        raise CellError(f"unknown kernel cell {cell!r}")
    if workers is not None:
        budget = replace(budget, workers=workers)
    return plan_kernel_multi(progs, hw, budget=budget, cache=cache), hw


def resolve_pipeline_cell(cell: str, *, cache: Any = None,
                          workers: Optional[int] = 1):
    """(graph, co-planned GraphPlan, forwarding-off GraphPlan, hw) for a
    ``pipeline/<family>/...`` cell."""
    from dataclasses import replace
    parts = cell.split("/")
    if len(parts) != 3 or parts[0] != "pipeline":
        raise CellError(f"unknown pipeline cell {cell!r}")
    fam = parts[1]
    if fam == "mlp2":
        M, D, F = _parse(r"M(\d+)_d(\d+)_f(\d+)", parts[2], cell)
        mk = lambda: mlp2_graph(M, D, F, blocks=GEMM_BLOCKS)  # noqa: E731
    elif fam == "attn":
        H, Sq, Skv, Dh = _parse(r"h(\d+)_q(\d+)_kv(\d+)_d(\d+)",
                                parts[2], cell)
        mk = lambda: attn_qk_pv_graph(H, Sq, Skv, Dh,  # noqa: E731
                                      blocks=ATTN_BLOCKS)
    elif fam == "moe_ffn":
        E, C, Dm, Df = _parse(r"e(\d+)_c(\d+)_(\d+)x(\d+)", parts[2], cell)
        mk = lambda: moe_ffn_graph(E, C, Dm, Df,  # noqa: E731
                                   blocks=GEMM_BLOCKS)
    else:
        raise CellError(f"unknown pipeline family {fam!r} in {cell!r}")
    budget = PIPELINE_BUDGET
    if workers is not None:
        budget = replace(budget, workers=workers)
    graph = mk()
    co = plan_pipeline(graph, hw := get_hw("wormhole_8x8"), budget=budget,
                       cache=cache)
    base = plan_pipeline(mk(), hw,
                         budget=replace(budget, pipeline_forwarding=False),
                         cache=cache)
    return graph, co, base, hw


# ---------------------------------------------------------------------------
# Rendering
# ---------------------------------------------------------------------------
def _bar(frac: float, width: int = 24) -> str:
    n = int(round(max(0.0, min(1.0, frac)) * width))
    return "#" * n + "." * (width - n)


def timeline_lines(plan, hw, record: Optional[List[dict]] = None
                   ) -> List[str]:
    """Wave-class timeline of one plan's simulation (``record`` may be a
    pre-captured ``simulate(..., record=...)`` list to avoid re-running)."""
    if record is None:
        record = []
        simulate(plan, hw, record=record)
    tot = sum(r["population"] * (r["wave_s"] + r["hoist_s"])
              for r in record) or 1.0
    lines = ["wave-class timeline "
             f"({len(record)} classes, {sum(r['population'] for r in record)}"
             " waves):",
             "  cls  pop  cores  wave_us  hoist_us   dram_KB    noc_KB  "
             "share"]
    for i, r in enumerate(record):
        share = r["population"] * (r["wave_s"] + r["hoist_s"]) / tot
        lines.append(
            f"  {i:3d} {r['population']:4d}  {r['n_active']:5d} "
            f"{r['wave_s'] * 1e6:8.2f} {r['hoist_s'] * 1e6:9.2f} "
            f"{r['dram_bytes'] / 1024:9.1f} {r['noc_bytes'] / 1024:9.1f}  "
            f"|{_bar(share)}| {share * 100:5.1f}%")
    return lines


def mesh_heatmap_lines(plan, hw, record: Optional[List[dict]] = None
                       ) -> List[str]:
    """ASCII per-core busy-time heatmap over the mesh (rows = first mesh
    dim, cols = second; 1D meshes render one row)."""
    if record is None:
        record = []
        simulate(plan, hw, record=record)
    coords = _core_coords(plan)
    busy = [0.0] * len(coords)
    for r in record:
        amask, w = r["active_mask"], r["wave_s"] + r["hoist_s"]
        if not amask or w <= 0:
            continue
        for i in range(len(coords)):
            if (amask >> i) & 1:
                busy[i] += r["population"] * w
    mesh = list(hw.mesh_dims)
    ax_r, n_r = mesh[0] if mesh else ("", 1)
    ax_c, n_c = mesh[1] if len(mesh) > 1 else ("", 1)
    grid = [[0.0] * n_c for _ in range(n_r)]
    for c, b in zip(coords, busy):
        grid[c.get(ax_r, 0)][c.get(ax_c, 0)] += b
    peak = max((b for row in grid for b in row), default=0.0)
    lines = [f"mesh utilization ({ax_r or 'core'} x {ax_c or '-'}, "
             f"peak core busy {peak * 1e6:.1f}us, "
             f"ramp '{_RAMP.strip() or _RAMP}'):"]
    for row in grid:
        glyphs = "".join(
            _RAMP[min(len(_RAMP) - 1,
                      int(b / peak * (len(_RAMP) - 1)))] if peak else _RAMP[0]
            for b in row)
        lines.append("  |" + glyphs + "|")
    return lines


def diff_lines(winner, runner, hw) -> List[str]:
    """Winner-vs-runner-up per-resource busy-seconds diff (Candidates)."""
    bw = cost_breakdown(winner.plan, hw)
    br = cost_breakdown(runner.plan, hw)
    lines = [
        "winner vs runner-up:",
        f"  winner    : {winner.plan.describe()}",
        f"  runner-up : {runner.plan.describe()}",
        f"  final_us  : {winner.final_s * 1e6:.2f} vs "
        f"{runner.final_s * 1e6:.2f} "
        f"({(runner.final_s - winner.final_s) * 1e6:+.2f})",
        f"  compute_us: {bw['compute_s'] * 1e6:.2f} vs "
        f"{br['compute_s'] * 1e6:.2f}",
        f"  bound     : {bw['cost'].bound} vs {br['cost'].bound}",
        "  resource        winner_us   runner_us    delta_us",
    ]
    for res in sorted(set(bw["resources"]) | set(br["resources"])):
        w = bw["resources"].get(res, {}).get("busy_s", 0.0)
        r = br["resources"].get(res, {}).get("busy_s", 0.0)
        lines.append(f"  {res:<14} {w * 1e6:11.2f} {r * 1e6:11.2f} "
                     f"{(r - w) * 1e6:+11.2f}")
    return lines


def edge_flip_deltas(graph, hw, plan) -> List[Dict[str, Any]]:
    """Marginal cost of every edge decision of a winning GraphPlan: flip
    each edge in isolation (forward <-> spill), re-simulate the two
    endpoint nodes with the flipped leg set, and report the end-to-end
    delta (positive = the planner's decision is that much faster)."""
    chosen = {name: c.plan for name, c in plan.nodes.items()}
    specs = {}
    for e in graph.edges:
        ek = (e.src, e.dst, e.tensor)
        specs[ek] = forward_spec(graph, e, chosen[e.src], chosen[e.dst], hw)
    fwd_now = {d.key: d.forwarded for d in plan.decisions}
    out = []
    for d in plan.decisions:
        ek = d.key
        row = {"edge": d.describe(), "forwarded": d.forwarded,
               "resident_bytes": d.resident_bytes,
               "shuffle_axes": d.shuffle_axes, "flip_delta_us": None}
        if not d.forwarded and specs.get(ek) is None:
            row["note"] = "no legal forward for the chosen pair"
            out.append(row)
            continue
        flipped = dict(fwd_now)
        flipped[ek] = not d.forwarded
        affected = {d.src, d.dst}
        cur = sum(plan.node_sims[n].total_s for n in affected)
        legs = {n: node_legs(graph, n, specs, flipped) for n in affected}
        sims = simulate_nodes(graph, {n: chosen[n] for n in affected},
                              legs, hw)
        row["flip_delta_us"] = (sims.total_s - cur) * 1e6
        out.append(row)
    return out


def explain_kernel(cell: str, *, cache: Any = None,
                   workers: Optional[int] = 1) -> str:
    res, hw = resolve_kernel_cell(cell, cache=cache, workers=workers)
    best = res.best
    record: List[dict] = []
    sim = simulate(best.plan, hw, record=record)
    lines = [
        f"cell {cell} on {hw.name}",
        f"  best plan : {best.plan.describe()}",
        f"  simulated : {sim.total_s * 1e6:.2f}us "
        f"({sim.tflops:.2f} TFLOP/s, {sim.n_wave_classes}/{sim.n_waves} "
        "wave classes)",
        f"  model     : {best.cost.total_s * 1e6:.2f}us "
        f"(bound={best.cost.bound})",
        f"  search    : {res.n_candidates} candidates, "
        f"{res.n_estimated} estimated, {res.n_pruned} pruned, "
        f"{res.plan_seconds:.2f}s",
        "",
    ]
    lines += timeline_lines(best.plan, hw, record)
    lines.append("")
    lines += mesh_heatmap_lines(best.plan, hw, record)
    if len(res.topk) > 1:
        lines.append("")
        lines += diff_lines(best, res.topk[1], hw)
    return "\n".join(lines)


def explain_pipeline(cell: str, *, cache: Any = None,
                     workers: Optional[int] = 1) -> str:
    graph, co, base, hw = resolve_pipeline_cell(cell, cache=cache,
                                                workers=workers)
    lines = [
        f"cell {cell} on {hw.name} "
        f"({len(graph.nodes)} nodes, {len(graph.edges)} edges)",
        f"  co-planned : {co.total_s * 1e6:.2f}us "
        f"({co.n_forwarded()}/{len(co.decisions)} edges forwarded)",
        f"  independent: {base.total_s * 1e6:.2f}us (every edge spilled)",
        f"  improvement: {co.improvement:.3f}x   "
        f"dram roundtrip {co.dram_roundtrip_s * 1e6:.2f}us",
        "",
        "per-edge decisions (flip delta = end-to-end cost of reversing "
        "just this edge):",
    ]
    for row in edge_flip_deltas(graph, hw, co):
        extra = f"  resident={row['resident_bytes']}B" \
            if row["forwarded"] else ""
        if row["flip_delta_us"] is None:
            lines.append(f"  {row['edge']}{extra}  "
                         f"[{row.get('note', 'n/a')}]")
        else:
            lines.append(f"  {row['edge']}{extra}  "
                         f"flip_delta={row['flip_delta_us']:+.2f}us")
    lines.append("")
    lines.append("per-node edge-adjusted simulations:")
    for name, sim in co.node_sims.items():
        cand = co.nodes[name]
        standalone = cand.sim.total_s if cand.sim else float("nan")
        lines.append(f"  {name:<10} {sim.total_s * 1e6:9.2f}us "
                     f"(standalone {standalone * 1e6:9.2f}us)  "
                     f"{cand.plan.describe()}")
    name0 = next(iter(co.nodes))
    lines.append("")
    lines.append(f"winning node {name0!r} timeline:")
    lines += ["  " + ln for ln in
              timeline_lines(co.nodes[name0].plan, hw)]
    lines += ["  " + ln for ln in
              mesh_heatmap_lines(co.nodes[name0].plan, hw)]
    return "\n".join(lines)


def explain(cell: str, *, cache: Any = None,
            workers: Optional[int] = 1) -> str:
    """Render one benchmark cell (dispatches on the suite prefix)."""
    if cell.startswith("pipeline/"):
        return explain_pipeline(cell, cache=cache, workers=workers)
    return explain_kernel(cell, cache=cache, workers=workers)


def known_cells() -> List[str]:
    """The plan_speed cell names explain can resolve (mirrors the
    benchmark sweep at ``full=False``)."""
    cells: List[str] = []
    for hw_name in ("wormhole_1x8", "wormhole_4x8", "wormhole_8x8"):
        for M in (1024, 4096, 16384):
            for N in (1024, 4096, 16384):
                cells.append(f"gemm/{hw_name}/M{M}_N{N}_K4096")
    for heads in (64, 128):
        for seq in (512, 1024, 2048, 4096, 8192):
            cells.append(f"flash/h{(8192 // seq) * heads}_s{seq}")
    for M, N, K in ((256, 256, 65536), (512, 256, 32768),
                    (256, 1024, 32768), (512, 512, 16384)):
        cells.append(f"reduction/gemm_ts/M{M}_N{N}_K{K}")
    for H, S, D in ((16, 32768, 128), (32, 65536, 64), (8, 131072, 128)):
        cells.append(f"reduction/flash_decode/h{H}_kv{S}_d{D}")
    for E, cap, din, dout in ((8, 128, 16384, 512), (4, 256, 32768, 256)):
        cells.append(f"reduction/moe_gmm/e{E}_c{cap}_{din}x{dout}")
    for M, D, F in ((16384, 128, 512), (32768, 128, 512)):
        cells.append(f"pipeline/mlp2/M{M}_d{D}_f{F}")
    for H, Sq, Skv, Dh in ((8, 4096, 1024, 64), (8, 2048, 2048, 64)):
        cells.append(f"pipeline/attn/h{H}_q{Sq}_kv{Skv}_d{Dh}")
    for E, C, Dm, Df in ((8, 2048, 128, 512), (8, 1024, 128, 512)):
        cells.append(f"pipeline/moe_ffn/e{E}_c{C}_{Dm}x{Df}")
    return cells
