"""Sliding-window SLO tracker for the serving stack (stdlib-only).

Tracks the three serving questions the plan-service/tenancy stack must
answer about itself:

* **deadline attainment** — fraction of plan requests answered within
  their deadline, over a *fast* window (default 60s) and a *slow* window
  (default 600s);
* **rung distribution** — which ladder rung answered, over the slow
  window (a healthy service answers from ``cache``; a drift toward
  ``fallback`` is the early-warning signal the attainment number lags);
* **blast radius** — per-tenant containment incidents and how many
  innocent tenants each displaced.

Alerting follows the multi-window burn-rate scheme (Google SRE workbook):
``burn = miss_rate / (1 - target)`` (how many times faster than the
error budget allows we are burning it), and the alert fires only when
*both* the fast and the slow window exceed the threshold — the fast
window gives detection latency, the slow window keeps one bad second
from paging.  Transitions are edge-triggered: each ``ok -> firing`` and
``firing -> ok`` edge emits one ``slo_alert`` flight-recorder event and
bumps ``slo_alert_transitions_total`` — state, not a per-request siren.

The tracker is off by default (the serve launcher enables it); when off,
:func:`note_request` is one attribute load.  Like every obs module it
only observes — nothing reads it back to make a serving decision.  The
clock is injectable so tests can replay a week of traffic in
microseconds.
"""
from __future__ import annotations

import os
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, Optional

TARGET_ENV = "REPRO_SLO_TARGET"
FAST_ENV = "REPRO_SLO_FAST_S"
SLOW_ENV = "REPRO_SLO_SLOW_S"
BURN_ENV = "REPRO_SLO_BURN"

DEFAULT_TARGET = 0.99
DEFAULT_FAST_S = 60.0
DEFAULT_SLOW_S = 600.0
#: 14.4 = burning a 30-day budget in 2 days (the workbook's page-now
#: threshold); scaled windows keep the same constant meaningful.
DEFAULT_BURN = 14.4


class SLOTracker:
    """Sliding-window attainment/burn-rate tracker.

    One module-level instance (:data:`TRACKER`) serves the process; the
    class is separate so tests can drive a private one with a fake clock.
    """

    def __init__(self, target: float = DEFAULT_TARGET,
                 fast_s: float = DEFAULT_FAST_S,
                 slow_s: float = DEFAULT_SLOW_S,
                 burn_threshold: float = DEFAULT_BURN,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self.on = False
        self.target = target
        self.fast_s = fast_s
        self.slow_s = max(slow_s, fast_s)
        self.burn_threshold = burn_threshold
        self.clock = clock
        self._lock = threading.Lock()
        # (t, ok, rung, tenant) per request, pruned past the slow window
        self._requests: deque = deque()
        # (t, owner, blast_radius, rung) per containment incident
        self._incidents: deque = deque()
        self.alert_state = "ok"
        self.alert_since: Optional[float] = None
        self.transitions = 0

    # ----------------------------------------------------------- control
    def enable(self) -> None:
        self.on = True

    def disable(self) -> None:
        self.on = False

    def clear(self) -> None:
        with self._lock:
            self._requests.clear()
            self._incidents.clear()
            self.alert_state = "ok"
            self.alert_since = None
            self.transitions = 0

    def configure_from_env(self) -> None:
        """Apply ``REPRO_SLO_{TARGET,FAST_S,SLOW_S,BURN}`` overrides."""
        for env, attr in ((TARGET_ENV, "target"), (FAST_ENV, "fast_s"),
                          (SLOW_ENV, "slow_s"), (BURN_ENV, "burn_threshold")):
            raw = os.environ.get(env, "").strip()
            if raw:
                try:
                    setattr(self, attr, float(raw))
                except ValueError:
                    pass
        self.slow_s = max(self.slow_s, self.fast_s)

    # ------------------------------------------------------------ intake
    def note_request(self, ok: bool, rung: str,
                     seconds: float = 0.0,
                     tenant: Optional[str] = None) -> None:
        """One plan request answered: ``ok`` is deadline attainment
        (rung outcome, not plan quality).  Re-evaluates the burn alert."""
        if not self.on:
            return
        now = self.clock()
        with self._lock:
            self._requests.append((now, bool(ok), str(rung), tenant))
            self._prune(now)
            self._check_alert(now)

    def note_containment(self, owner: str, blast_radius: int,
                         rung: str = "") -> None:
        """One tenancy containment incident attributed to ``owner``
        displacing ``blast_radius`` tenants (including the owner)."""
        if not self.on:
            return
        now = self.clock()
        with self._lock:
            self._incidents.append((now, str(owner), int(blast_radius),
                                    str(rung)))
            self._prune(now)

    # ---------------------------------------------------------- internal
    def _prune(self, now: float) -> None:
        horizon = now - self.slow_s
        while self._requests and self._requests[0][0] < horizon:
            self._requests.popleft()
        while self._incidents and self._incidents[0][0] < horizon:
            self._incidents.popleft()

    def _window(self, now: float, width: float) -> Dict[str, float]:
        t0 = now - width
        total = miss = 0
        for t, ok, _rung, _tenant in self._requests:
            if t >= t0:
                total += 1
                if not ok:
                    miss += 1
        miss_rate = (miss / total) if total else 0.0
        budget = 1.0 - self.target
        burn = (miss_rate / budget) if budget > 0 else (
            float("inf") if miss else 0.0)
        return {"total": total, "miss": miss,
                "attainment": 1.0 - miss_rate, "burn": burn}

    def _check_alert(self, now: float) -> None:
        fast = self._window(now, self.fast_s)
        slow = self._window(now, self.slow_s)
        firing = (fast["total"] > 0 and slow["total"] > 0
                  and fast["burn"] >= self.burn_threshold
                  and slow["burn"] >= self.burn_threshold)
        state = "firing" if firing else "ok"
        if state == self.alert_state:
            return
        self.alert_state = state
        self.alert_since = now
        self.transitions += 1
        # Emit outside the registry's own locking concerns but inside
        # ours: flightrec/metrics use their own locks and never call back.
        from . import flightrec, metrics
        flightrec.record("slo_alert", state=state,
                         fast_burn=round(fast["burn"], 3),
                         slow_burn=round(slow["burn"], 3),
                         attainment=round(slow["attainment"], 5),
                         threshold=self.burn_threshold)
        metrics.inc("slo_alert_transitions_total", state=state)

    # ------------------------------------------------------------ report
    def report(self) -> Dict[str, Any]:
        """Plain-JSON view for ``/slo`` and the smoke lane."""
        now = self.clock()
        with self._lock:
            self._prune(now)
            fast = self._window(now, self.fast_s)
            slow = self._window(now, self.slow_s)
            rungs: Dict[str, int] = {}
            for _t, _ok, rung, _tenant in self._requests:
                rungs[rung] = rungs.get(rung, 0) + 1
            tenants: Dict[str, Dict[str, Any]] = {}
            for _t, owner, blast, rung in self._incidents:
                rec = tenants.setdefault(owner, {
                    "incidents": 0, "blast_radius_max": 0,
                    "blast_radius_sum": 0, "rungs": {}})
                rec["incidents"] += 1
                rec["blast_radius_sum"] += blast
                rec["blast_radius_max"] = max(rec["blast_radius_max"],
                                              blast)
                if rung:
                    rec["rungs"][rung] = rec["rungs"].get(rung, 0) + 1
            return {
                "enabled": self.on,
                "target": self.target,
                "burn_threshold": self.burn_threshold,
                "windows": {"fast_s": self.fast_s, "slow_s": self.slow_s},
                "fast": fast,
                "slow": slow,
                "rungs": rungs,
                "tenants": tenants,
                "alert": {"state": self.alert_state,
                          "since": self.alert_since,
                          "transitions": self.transitions},
            }


TRACKER = SLOTracker()


# ------------------------------------------------- module-level convenience
def enabled() -> bool:
    return TRACKER.on


def enable() -> None:
    TRACKER.configure_from_env()
    TRACKER.enable()


def disable() -> None:
    TRACKER.disable()


def clear() -> None:
    TRACKER.clear()


def note_request(ok: bool, rung: str, seconds: float = 0.0,
                 tenant: Optional[str] = None) -> None:
    if not TRACKER.on:                   # the entire disabled cost
        return
    TRACKER.note_request(ok, rung, seconds, tenant)


def note_containment(owner: str, blast_radius: int,
                     rung: str = "") -> None:
    if not TRACKER.on:
        return
    TRACKER.note_containment(owner, blast_radius, rung)


def report() -> Dict[str, Any]:
    return TRACKER.report()
