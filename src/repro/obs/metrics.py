"""Unified metrics registry for the planning stack (stdlib-only).

One process-wide :class:`Registry` of counters, gauges and histograms with
labeled series, replacing the scattered ad-hoc signals that grew over the
first five PRs: ``lower_jax.PLANNER_FALLBACKS``, the plancache
``CacheStats`` silo, ``_SearchStats`` pruning counters, and worker shard
timings all publish here, so ``plan_speed`` and the serve/train launchers
can emit one coherent JSON blob (:func:`snapshot`).

Metric identity is ``(name, frozenset(labels.items()))`` — one metric
object per name, one series per label combination::

    metrics.counter("plancache_get_total", result="hit_mem").inc()
    metrics.observe("planner_phase_seconds", 0.12, phase="estimate")
    metrics.snapshot()  # -> plain-JSON dict

Everything is guarded by a single registry lock; increments are cheap
(dict lookup + float add) but, like the tracer, this module only ever
*observes* — nothing in the planner reads a metric back to make a
decision, which is what keeps instrumented and uninstrumented searches
bit-identical.

The canonical metric names and label sets live in DESIGN_OBS.md.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

METRICS_ENV = "REPRO_METRICS"

#: Process start as this module saw it — the snapshot meta block's epoch.
_START_TIME = time.time()

from . import context  # noqa: E402  (no cycle: context imports nothing)

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Mapping[str, Any]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Series:
    """One (metric, label-set) time series.  ``rid`` is the exemplar:
    the correlation ID active at the last correlated update (None until
    one happens) — per-series, not per-increment, so request IDs never
    explode label cardinality."""
    __slots__ = ("labels", "value", "rid")

    def __init__(self, labels: LabelKey) -> None:
        self.labels = labels
        self.value = 0.0
        self.rid: Optional[str] = None


class _HistSeries:
    """Histogram series: count / sum / min / max plus fixed log-ish buckets
    (seconds-oriented; fine for the planner's ms-to-minutes range)."""
    __slots__ = ("labels", "count", "sum", "min", "max", "buckets", "rid")

    BOUNDS = (1e-4, 1e-3, 1e-2, 0.1, 1.0, 10.0, 100.0)

    def __init__(self, labels: LabelKey) -> None:
        self.labels = labels
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.buckets = [0] * (len(self.BOUNDS) + 1)
        self.rid: Optional[str] = None

    def observe(self, v: float) -> None:
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        for i, b in enumerate(self.BOUNDS):
            if v <= b:
                self.buckets[i] += 1
                return
        self.buckets[-1] += 1


class Counter:
    """Monotonic counter with labeled series."""

    def __init__(self, registry: "Registry", name: str,
                 help_: str = "") -> None:
        self._registry = registry
        self.name = name
        self.help = help_
        self._series: Dict[LabelKey, _Series] = {}

    def labels(self, **labels: Any) -> "_BoundCounter":
        return _BoundCounter(self, _label_key(labels))

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        self._bump(_label_key(labels), amount)

    def _bump(self, key: LabelKey, amount: float) -> None:
        rid = context.current()
        with self._registry._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = _Series(key)
            s.value += amount
            if rid is not None:
                s.rid = rid

    def value(self, **labels: Any) -> float:
        with self._registry._lock:
            s = self._series.get(_label_key(labels))
            return s.value if s is not None else 0.0

    def total(self) -> float:
        with self._registry._lock:
            return sum(s.value for s in self._series.values())

    def clear(self) -> None:
        """Drop every series (used by compat shims like
        ``lower_jax.clear_block_caches`` that must re-zero a signal)."""
        with self._registry._lock:
            self._series.clear()


class _BoundCounter:
    __slots__ = ("_counter", "_key")

    def __init__(self, counter: Counter, key: LabelKey) -> None:
        self._counter = counter
        self._key = key

    def inc(self, amount: float = 1.0) -> None:
        self._counter._bump(self._key, amount)


class Gauge:
    """Last-value-wins gauge with labeled series."""

    def __init__(self, registry: "Registry", name: str,
                 help_: str = "") -> None:
        self._registry = registry
        self.name = name
        self.help = help_
        self._series: Dict[LabelKey, _Series] = {}

    def set(self, value: float, **labels: Any) -> None:
        key = _label_key(labels)
        rid = context.current()
        with self._registry._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = _Series(key)
            s.value = float(value)
            if rid is not None:
                s.rid = rid

    def add(self, amount: float, **labels: Any) -> None:
        key = _label_key(labels)
        rid = context.current()
        with self._registry._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = _Series(key)
            s.value += amount
            if rid is not None:
                s.rid = rid

    def value(self, **labels: Any) -> float:
        with self._registry._lock:
            s = self._series.get(_label_key(labels))
            return s.value if s is not None else 0.0


class Histogram:
    """Distribution metric (count/sum/min/max + coarse buckets)."""

    def __init__(self, registry: "Registry", name: str,
                 help_: str = "") -> None:
        self._registry = registry
        self.name = name
        self.help = help_
        self._series: Dict[LabelKey, _HistSeries] = {}

    def observe(self, value: float, **labels: Any) -> None:
        key = _label_key(labels)
        rid = context.current()
        with self._registry._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = _HistSeries(key)
            s.observe(float(value))
            if rid is not None:
                s.rid = rid

    def series(self, **labels: Any) -> Optional[_HistSeries]:
        with self._registry._lock:
            return self._series.get(_label_key(labels))


class Registry:
    """Process-wide metric store.  One metric object per name; the type of
    the first registration wins and a mismatched re-registration raises."""

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._metrics: Dict[str, Any] = {}

    def _get(self, cls, name: str, help_: str):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(self, name, help_)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, help_: str = "") -> Counter:
        return self._get(Counter, name, help_)

    def gauge(self, name: str, help_: str = "") -> Gauge:
        return self._get(Gauge, name, help_)

    def histogram(self, name: str, help_: str = "") -> Histogram:
        return self._get(Histogram, name, help_)

    def reset(self) -> None:
        """Forget everything (tests; also the per-bench-cell phase delta
        helpers snapshot-and-diff instead of resetting)."""
        with self._lock:
            self._metrics.clear()

    def snapshot(self) -> Dict[str, Any]:
        """Plain-JSON view: ``{name: {type, help, series: [{labels, ...}]}}``
        plus a ``_meta`` block (pid, start time, uptime, plancache schema)
        so scraped blobs are self-describing.  Counter/gauge series carry
        ``value``; histogram series carry ``count``/``sum``/``min``/
        ``max``/``buckets``; any series touched inside a correlation
        scope carries its last ``rid`` exemplar.  ``_meta`` has no
        ``type`` key, which is what keeps the diff-style consumers
        (:func:`counter_totals`, :func:`diff_counters`) oblivious to it.
        """
        out: Dict[str, Any] = {}
        with self._lock:
            for name, m in sorted(self._metrics.items()):
                series: List[Dict[str, Any]] = []
                if isinstance(m, Histogram):
                    mtype = "histogram"
                    for s in m._series.values():
                        d = {
                            "labels": dict(s.labels),
                            "count": s.count,
                            "sum": s.sum,
                            "min": s.min if s.count else None,
                            "max": s.max if s.count else None,
                            "buckets": {
                                "le": list(_HistSeries.BOUNDS) + ["inf"],
                                "counts": list(s.buckets),
                            },
                        }
                        if s.rid is not None:
                            d["rid"] = s.rid
                        series.append(d)
                else:
                    mtype = "counter" if isinstance(m, Counter) else "gauge"
                    for s in m._series.values():
                        d = {"labels": dict(s.labels), "value": s.value}
                        if s.rid is not None:
                            d["rid"] = s.rid
                        series.append(d)
                series.sort(key=lambda d: sorted(d["labels"].items()))
                out[name] = {"type": mtype, "help": m.help, "series": series}
        out["_meta"] = _meta_block()
        return out


def _meta_block() -> Dict[str, Any]:
    """Self-description for scraped snapshots.  The plancache schema
    version rides along so a scrape can be matched against the on-disk
    plan store it was taken next to (import kept lazy and fallible:
    metrics must stay importable from anywhere in the stack)."""
    try:
        from repro.plancache.keying import SCHEMA_VERSION
        schema: Optional[int] = SCHEMA_VERSION
    except Exception:
        schema = None
    now = time.time()
    return {"pid": os.getpid(), "start_time": _START_TIME,
            "uptime_s": now - _START_TIME, "plancache_schema": schema}


REGISTRY = Registry()

# ------------------------------------------------- module-level convenience
counter = REGISTRY.counter
gauge = REGISTRY.gauge
histogram = REGISTRY.histogram
snapshot = REGISTRY.snapshot
reset = REGISTRY.reset


def inc(name: str, amount: float = 1.0, **labels: Any) -> None:
    REGISTRY.counter(name).inc(amount, **labels)


def set_gauge(name: str, value: float, **labels: Any) -> None:
    REGISTRY.gauge(name).set(value, **labels)


def observe(name: str, value: float, **labels: Any) -> None:
    REGISTRY.histogram(name).observe(value, **labels)


# ------------------------------------------------------- snapshot utilities
def counter_totals(snap: Mapping[str, Any],
                   names: Optional[Iterable[str]] = None
                   ) -> Dict[str, float]:
    """Sum each counter's series into ``{name: total}`` (optionally only
    the listed names).  Used by diff-style consumers like the per-cell
    phase breakdown in benchmarks/plan_speed.py."""
    out: Dict[str, float] = {}
    for name, m in snap.items():
        if m.get("type") != "counter":
            continue
        if names is not None and name not in names:
            continue
        out[name] = sum(s["value"] for s in m["series"])
    return out


def diff_counters(before: Mapping[str, Any], after: Mapping[str, Any]
                  ) -> Dict[str, Dict[str, float]]:
    """Per-series counter deltas between two snapshots:
    ``{name: {label-repr: delta}}``, dropping zero deltas."""
    out: Dict[str, Dict[str, float]] = {}
    for name, m in after.items():
        if m.get("type") != "counter":
            continue
        prev = {}
        if name in before and before[name].get("type") == "counter":
            prev = {json.dumps(s["labels"], sort_keys=True): s["value"]
                    for s in before[name]["series"]}
        deltas: Dict[str, float] = {}
        for s in m["series"]:
            key = json.dumps(s["labels"], sort_keys=True)
            d = s["value"] - prev.get(key, 0.0)
            if d:
                deltas[key] = d
        if deltas:
            out[name] = deltas
    return out


def hist_quantile(series: Mapping[str, Any], q: float) -> Optional[float]:
    """Estimate the ``q``-quantile of a snapshot histogram series (the
    ``{count, min, max, buckets}`` dict :meth:`Registry.snapshot` emits).

    Linear interpolation inside the covering bucket, clamped to the
    observed ``[min, max]`` so the coarse log bounds can't report a p99
    above the largest value actually seen.

    Boundary contract: ``None`` series or empty histogram -> ``None``;
    ``q <= 0`` -> observed min; ``q >= 1`` -> observed max (exact, not
    interpolated); a single-bucket histogram interpolates within
    ``[min, max]`` instead of within the much coarser bucket; a series
    without bucket data (foreign/minimal snapshots) degrades to linear
    interpolation between min and max."""
    if not series:
        return None
    count = int(series.get("count") or 0)
    if count <= 0:
        return None
    lo = float(series.get("min") if series.get("min") is not None else 0.0)
    hi = float(series.get("max") if series.get("max") is not None else lo)
    q = min(1.0, max(0.0, float(q)))
    if q <= 0.0 or count == 1 or lo == hi:
        return lo if q <= 0.0 else (hi if q >= 1.0 else lo)
    if q >= 1.0:
        return hi
    buckets = series.get("buckets") or {}
    bounds = list(buckets.get("le") or [])
    counts = list(buckets.get("counts") or [])
    if not bounds or not counts or sum(counts) <= 0:
        return lo + (hi - lo) * q
    if sum(1 for n in counts if n > 0) == 1:
        # Single occupied bucket: the bucket edges say nothing the
        # observed extremes don't say better.
        return lo + (hi - lo) * q
    rank = q * count
    seen = 0.0
    prev_bound = 0.0
    for bound, n in zip(bounds, counts):
        if n <= 0:
            if bound != "inf":
                prev_bound = float(bound)
            continue
        if seen + n >= rank:
            upper = hi if bound == "inf" else float(bound)
            frac = (rank - seen) / n
            est = prev_bound + (upper - prev_bound) * frac
            return min(hi, max(lo, est))
        seen += n
        if bound != "inf":
            prev_bound = float(bound)
    return hi


def dump(path: Optional[str] = None) -> Optional[str]:
    """Write the snapshot as JSON to ``path`` or ``$REPRO_METRICS``.
    Returns the path written, or None when no destination is known.

    Atomic (tmp + rename, like the plancache's stats writes): launchers
    dump on exit and are routinely SIGKILLed by orchestrators, and a
    torn half-JSON is worse for the scraper than a stale complete one.
    """
    path = path or os.environ.get(METRICS_ENV, "").strip() or None
    if not path:
        return None
    tmp = f"{path}.{os.getpid()}.tmp"
    try:
        with open(tmp, "w") as f:
            json.dump(snapshot(), f, indent=2, sort_keys=True)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            try:
                os.remove(tmp)
            except OSError:
                pass
    return path
