"""Prometheus text exposition + live introspection endpoint (stdlib-only).

:func:`render_prometheus` converts one :func:`repro.obs.metrics.snapshot`
into text exposition format 0.0.4 — the same snapshot dict the JSON
consumers get, taken once, so `/metrics` is *snapshot-consistent*: every
line in one scrape comes from the same instant, never a counter from
before a containment event next to a histogram from after it.

Rendering rules:

* counters/gauges emit one ``name{labels} value`` line per series with
  ``# TYPE``/``# HELP`` headers;
* histograms emit the cumulative ``_bucket{le=...}`` ladder (our
  snapshot stores per-bucket counts; the renderer accumulates) plus
  ``_sum``/``_count`` and a terminal ``le="+Inf"`` bucket;
* label values are escaped per the spec (backslash, double-quote,
  newline); metric/label names in this codebase are already
  ``[a-z_][a-z0-9_]*`` and are emitted as-is;
* the snapshot's ``_meta`` block becomes ``repro_process_*`` gauges so a
  scrape is self-describing without parsing JSON;
* per-series ``rid`` exemplars stay in the JSON snapshot only — classic
  text format has no exemplar syntax, and emitting them would break the
  format validation the CI lane runs.

:class:`IntrospectionServer` wraps the renderer in a stdlib
``ThreadingHTTPServer`` on a daemon thread: ``/metrics`` (exposition),
``/healthz``, ``/slo`` (:func:`repro.obs.slo.report`), plus any JSON
provider the launcher registers (``/plans``, ``/tenants``).  Handlers
only *read* snapshots; nothing a scrape does can perturb planning.
"""
from __future__ import annotations

import json
import math
import re
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, List, Mapping, Optional

from . import metrics, slo

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def escape_label_value(v: str) -> str:
    return (v.replace("\\", "\\\\").replace('"', '\\"')
             .replace("\n", "\\n"))


def escape_help(v: str) -> str:
    return v.replace("\\", "\\\\").replace("\n", "\\n")


def _fmt_value(v: Any) -> str:
    f = float(v)
    if math.isnan(f):
        return "NaN"
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _labels_str(labels: Mapping[str, str],
                extra: Optional[Mapping[str, str]] = None) -> str:
    items = list(labels.items())
    if extra:
        items += list(extra.items())
    if not items:
        return ""
    inner = ",".join(f'{k}="{escape_label_value(str(v))}"'
                     for k, v in items)
    return "{" + inner + "}"


def _sanitize_name(name: str) -> str:
    if _NAME_RE.match(name):
        return name
    return re.sub(r"[^a-zA-Z0-9_:]", "_", name) or "_"


def render_prometheus(snap: Optional[Mapping[str, Any]] = None) -> str:
    """Render one metrics snapshot as text exposition format 0.0.4."""
    if snap is None:
        snap = metrics.snapshot()
    out: List[str] = []
    meta = snap.get("_meta")
    if isinstance(meta, dict):
        for key, mname in (("start_time", "repro_process_start_time_seconds"),
                           ("uptime_s", "repro_process_uptime_seconds"),
                           ("pid", "repro_process_pid"),
                           ("plancache_schema",
                            "repro_plancache_schema_version")):
            if key in meta and meta[key] is not None:
                out.append(f"# TYPE {mname} gauge")
                out.append(f"{mname} {_fmt_value(meta[key])}")
    for name in sorted(snap):
        m = snap[name]
        if not isinstance(m, dict) or "type" not in m:
            continue                     # _meta and future non-metric blocks
        mtype = m["type"]
        pname = _sanitize_name(name)
        if m.get("help"):
            out.append(f"# HELP {pname} {escape_help(m['help'])}")
        out.append(f"# TYPE {pname} {mtype}")
        for s in m.get("series", []):
            labels = s.get("labels", {})
            if mtype == "histogram":
                bounds = s["buckets"]["le"]
                counts = s["buckets"]["counts"]
                cum = 0
                for bound, n in zip(bounds, counts):
                    cum += n
                    le = "+Inf" if bound == "inf" else _fmt_value(bound)
                    out.append(f"{pname}_bucket"
                               f"{_labels_str(labels, {'le': le})} {cum}")
                out.append(f"{pname}_sum{_labels_str(labels)} "
                           f"{_fmt_value(s['sum'])}")
                out.append(f"{pname}_count{_labels_str(labels)} "
                           f"{s['count']}")
            else:
                out.append(f"{pname}{_labels_str(labels)} "
                           f"{_fmt_value(s['value'])}")
    return "\n".join(out) + "\n"


def validate_exposition(text: str) -> List[str]:
    """Syntax-check text exposition format; returns a list of problems
    (empty = valid).  This is the checker the CI smoke lane and the unit
    tests run against a live ``/metrics`` scrape."""
    problems: List[str] = []
    typed: Dict[str, str] = {}
    sample_re = re.compile(
        r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"          # metric name
        r"(\{(.*)\})?"                           # optional label block
        r" (NaN|[+-]?Inf|[+-]?[0-9]*\.?[0-9]+(?:[eE][+-]?[0-9]+)?)"
        r"( [0-9]+)?$")                          # optional timestamp
    label_re = re.compile(
        r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
    for i, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split(" ")
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                problems.append(f"line {i}: malformed TYPE line: {line!r}")
            else:
                typed[parts[2]] = parts[3]
            continue
        if line.startswith("# HELP ") or line.startswith("#"):
            continue
        mm = sample_re.match(line)
        if not mm:
            problems.append(f"line {i}: malformed sample: {line!r}")
            continue
        name, _block, inner, _value, _ts = mm.groups()
        base = re.sub(r"_(bucket|sum|count)$", "", name)
        if name not in typed and base not in typed:
            problems.append(f"line {i}: sample {name!r} has no TYPE line")
        if inner:
            consumed = label_re.sub("", inner).replace(",", "").strip()
            if consumed:
                problems.append(
                    f"line {i}: malformed labels {inner!r}")
            for lname, _lval in label_re.findall(inner):
                if not _LABEL_RE.match(lname):
                    problems.append(
                        f"line {i}: bad label name {lname!r}")
    return problems


# --------------------------------------------------- introspection server
class _Handler(BaseHTTPRequestHandler):
    def log_message(self, fmt: str, *args: Any) -> None:  # silence stderr
        pass

    def _send(self, code: int, body: bytes, ctype: str) -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_json(self, obj: Any, code: int = 200) -> None:
        body = json.dumps(obj, indent=1, sort_keys=True,
                          default=str).encode()
        self._send(code, body, "application/json")

    def do_GET(self) -> None:  # noqa: N802 (http.server API)
        path = self.path.split("?", 1)[0].rstrip("/") or "/"
        try:
            if path == "/metrics":
                self._send(200, render_prometheus().encode(), CONTENT_TYPE)
            elif path == "/healthz":
                self._send_json({"ok": True,
                                 "uptime_s": time.time() - self.server.t0})
            elif path == "/slo":
                self._send_json(slo.report())
            elif path == "/":
                self._send_json({"endpoints": sorted(
                    ["/metrics", "/healthz", "/slo"]
                    + list(self.server.providers))})
            elif path in self.server.providers:
                self._send_json(self.server.providers[path]())
            else:
                self._send_json({"error": f"no such endpoint {path}"},
                                code=404)
        except BrokenPipeError:
            pass
        except Exception as e:  # a broken provider must not kill the thread
            try:
                self._send_json({"error": f"{type(e).__name__}: {e}"},
                                code=500)
            except OSError:
                pass


class IntrospectionServer:
    """Read-only HTTP introspection on a daemon thread.

    ``port=0`` binds an ephemeral port (tests, CI); :attr:`port` after
    :meth:`start` is the bound one.  :meth:`add_provider` registers extra
    JSON endpoints (``/plans``, ``/tenants``) as zero-arg callables
    evaluated per request — always the live view, never a startup copy.
    """

    def __init__(self, port: int = 0, host: str = "127.0.0.1") -> None:
        self.host = host
        self.port = port
        self.t0 = time.time()
        self.providers: Dict[str, Callable[[], Any]] = {}
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    def add_provider(self, path: str, fn: Callable[[], Any]) -> None:
        if not path.startswith("/"):
            path = "/" + path
        self.providers[path.rstrip("/")] = fn

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "IntrospectionServer":
        httpd = ThreadingHTTPServer((self.host, self.port), _Handler)
        httpd.daemon_threads = True
        httpd.t0 = self.t0                       # type: ignore[attr-defined]
        httpd.providers = self.providers         # type: ignore[attr-defined]
        self.port = httpd.server_address[1]
        self._httpd = httpd
        self._thread = threading.Thread(
            target=httpd.serve_forever, kwargs={"poll_interval": 0.2},
            name="repro-introspect", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
