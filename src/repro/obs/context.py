"""Request-correlation IDs for the serving stack (stdlib-only).

One :mod:`contextvars` variable holds the *current* request/incident ID.
Everything downstream that observes — spans (:mod:`repro.obs.trace`),
metric exemplars (:mod:`repro.obs.metrics`), flight-recorder events
(:mod:`repro.obs.flightrec`) — reads it with :func:`current` and stamps
whatever it records, so one serving decision can be reconstructed across
the plan service, the re-plan ladder, the tenancy runtime and the pool
workers after the fact (``python -m repro.obs incident``).

Propagation rules (DESIGN_OBS.md):

* :func:`correlate` *reuses* an already-set ID — a plan-service resolve
  nested inside a tenancy containment incident inherits the incident ID
  instead of minting its own, which is exactly what makes the incident
  timeline reconstructable;
* worker processes receive the parent's ID explicitly with each job
  (``repro.parallel.search_exec`` ships it alongside the trace flag) and
  :func:`attach` it before running, so worker spans land on the same ID;
* IDs are never read back to make a decision — correlation is
  observation, and the bit-identity invariant of the whole obs layer
  applies unchanged.

IDs are ``<prefix>-<pid hex>-<counter hex>``: unique within a process
tree without importing :mod:`uuid` or reading a clock (both banned from
hot paths), and stable enough to grep across a dump, a trace, and a
metrics snapshot.
"""
from __future__ import annotations

import contextvars
import itertools
import os
from contextlib import contextmanager
from typing import Iterator, Optional

_VAR: contextvars.ContextVar[Optional[str]] = contextvars.ContextVar(
    "repro_request_id", default=None)
_COUNTER = itertools.count(1)


def current() -> Optional[str]:
    """The active request/incident ID, or None outside any correlation."""
    return _VAR.get()


def new_id(prefix: str = "req") -> str:
    """Mint a fresh ID (does not set it; see :func:`correlate`)."""
    return f"{prefix}-{os.getpid():x}-{next(_COUNTER):04x}"


def attach(rid: Optional[str]) -> contextvars.Token:
    """Set the current ID directly (worker-process entry; pair with
    :func:`detach`)."""
    return _VAR.set(rid)


def detach(token: contextvars.Token) -> None:
    _VAR.reset(token)


@contextmanager
def correlate(prefix: str = "req",
              rid: Optional[str] = None) -> Iterator[str]:
    """Scope a correlation ID.

    With ``rid=None`` (the normal case) an already-active ID is *reused*
    — nested work stays on the enclosing request/incident — and a fresh
    one is minted only at the outermost entry point.  Passing ``rid``
    explicitly forces that ID for the scope (dump replay, tests).
    """
    if rid is None:
        cur = _VAR.get()
        if cur is not None:
            yield cur
            return
        rid = new_id(prefix)
    token = _VAR.set(rid)
    try:
        yield rid
    finally:
        _VAR.reset(token)
