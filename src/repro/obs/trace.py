"""Structured span tracer for the planning stack (Chrome trace-event JSON).

Disabled by default with near-zero cost when off: :func:`span` is one
attribute load plus returning a shared no-op context manager, and
:func:`traced`-wrapped functions pay one ``if`` per call.  Enabled via
``REPRO_TRACE=<path>`` (the file is written at interpreter exit, and by
:func:`write` explicitly), :func:`enable`, or ``benchmarks/run.py
--trace``.

Spans are Chrome trace-event *complete* events (``"ph": "X"``)::

    {"name": ..., "cat": ..., "ph": "X", "ts": <us>, "dur": <us>,
     "pid": ..., "tid": ..., "args": {...}}

``ts`` is wall-clock microseconds derived from one per-process epoch
(``time.time() - time.perf_counter()`` at import), so spans recorded in
different processes land on one comparable timeline: worker processes
buffer their spans in memory (``repro.parallel.search_exec`` passes a
``trace`` flag with each job), :func:`drain` hands them back through the
existing chunk-result path, and the parent :func:`ingest`\\ s them with the
worker's ``pid``/``tid`` preserved — the cross-process merge protocol
documented in DESIGN_OBS.md.

Invariant: the tracer only *observes* (two clock reads and a dict append
per span).  It never feeds anything back into planning, so traced and
untraced searches select bit-identical plans (``tests/test_obs.py``).
"""
from __future__ import annotations

import atexit
import functools
import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional

TRACE_ENV = "REPRO_TRACE"

from . import context  # noqa: E402  (no cycle: context imports nothing)

# wall-clock anchor for perf_counter timestamps; computed once per process
# so every span of a process shares one epoch (fork children inherit the
# parent's, spawn children recompute — both express the same wall clock)
_EPOCH = time.time() - time.perf_counter()


class _State:
    __slots__ = ("on", "path", "events", "lock", "atexit_armed")

    def __init__(self) -> None:
        self.on = False
        self.path: Optional[str] = None
        self.events: List[Dict[str, Any]] = []
        self.lock = threading.Lock()
        self.atexit_armed = False


_STATE = _State()


def enabled() -> bool:
    """Whether spans are being collected right now."""
    return _STATE.on


def enable(path: Optional[str] = None) -> None:
    """Start collecting spans.  With ``path``, also arm an atexit write of
    the Chrome trace JSON there (idempotent)."""
    _STATE.on = True
    if path:
        _STATE.path = path
        if not _STATE.atexit_armed:
            _STATE.atexit_armed = True
            atexit.register(_atexit_write)


def disable() -> None:
    """Stop collecting (buffered events are kept until :func:`clear`)."""
    _STATE.on = False


def clear() -> None:
    with _STATE.lock:
        _STATE.events.clear()


def refresh_from_env() -> None:
    """Re-resolve the ``REPRO_TRACE`` env var.  Called by the planner entry
    points (``plan_kernel`` / ``plan_kernel_multi`` / ``plan_pipeline``) so
    an env flip after import still takes effect, while the per-span check
    stays a single attribute load."""
    path = os.environ.get(TRACE_ENV, "").strip()
    if path:
        enable(path)
    elif _STATE.path is not None and not path:
        # env-driven tracing withdrawn; explicit enable(None) is unaffected
        _STATE.on = False
        _STATE.path = None


def _record(name: str, cat: str, t0: float, t1: float,
            args: Optional[Dict[str, Any]]) -> None:
    ev: Dict[str, Any] = {
        "name": name, "cat": cat, "ph": "X",
        "ts": (_EPOCH + t0) * 1e6, "dur": max(0.0, (t1 - t0) * 1e6),
        "pid": os.getpid(), "tid": threading.get_ident(),
    }
    rid = context.current()
    if rid is not None:
        # correlation ID rides in args so Perfetto's span view shows it
        # and `repro.obs incident` can join spans against the recorder
        args = dict(args) if args else {}
        args["rid"] = rid
    if args:
        ev["args"] = args
    with _STATE.lock:
        _STATE.events.append(ev)


class _Span:
    """Active span context manager (only constructed when tracing is on)."""
    __slots__ = ("name", "cat", "args", "t0")

    def __init__(self, name: str, cat: str, args: Dict[str, Any]) -> None:
        self.name = name
        self.cat = cat
        self.args = args
        self.t0 = 0.0

    def __enter__(self) -> "_Span":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        _record(self.name, self.cat, self.t0, time.perf_counter(), self.args)


class _NullSpan:
    """Shared no-op context manager: the entire disabled-tracing cost."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL = _NullSpan()


def span(name: str, cat: str = "planner", **args: Any):
    """``with trace.span("planner.enumerate", program=p.name): ...``"""
    if not _STATE.on:
        return _NULL
    return _Span(name, cat, args)


def traced(name: Optional[str] = None, cat: str = "planner"
           ) -> Callable[[Callable], Callable]:
    """Decorator form of :func:`span` (span name defaults to the function's
    qualified name)."""
    def deco(fn: Callable) -> Callable:
        sname = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            if not _STATE.on:
                return fn(*a, **kw)
            t0 = time.perf_counter()
            try:
                return fn(*a, **kw)
            finally:
                _record(sname, cat, t0, time.perf_counter(), None)
        return wrapper
    return deco


# ------------------------------------------------------- cross-process merge
def drain() -> List[Dict[str, Any]]:
    """Hand back (and clear) the buffered events — what a worker process
    attaches to its chunk result for the parent to :func:`ingest`."""
    with _STATE.lock:
        out = list(_STATE.events)
        _STATE.events.clear()
    return out


def ingest(events: Optional[List[Dict[str, Any]]]) -> None:
    """Merge another process's drained events into this buffer.  Events
    keep their original ``pid``/``tid``/``ts`` (one shared wall-clock
    epoch), so the exported trace shows every worker as its own process
    track."""
    if not events:
        return
    with _STATE.lock:
        _STATE.events.extend(events)


def events() -> List[Dict[str, Any]]:
    with _STATE.lock:
        return list(_STATE.events)


# ------------------------------------------------------------------- export
def write(path: Optional[str] = None) -> Optional[str]:
    """Write the buffered events as Chrome trace-event JSON (perfetto /
    ``chrome://tracing`` loadable).  Returns the path written, or None when
    no destination is known."""
    path = path or _STATE.path
    if not path:
        return None
    with _STATE.lock:
        evs = sorted(_STATE.events, key=lambda e: (e["pid"], e["tid"],
                                                   e["ts"]))
    with open(path, "w") as f:
        json.dump({"traceEvents": evs, "displayTimeUnit": "ms"}, f)
    return path


def _atexit_write() -> None:
    try:
        write()
    except OSError:
        pass


# --------------------------------------------------------------- validation
REQUIRED_KEYS = ("ph", "ts", "pid", "tid", "name")


def validate_chrome_trace(doc: Any) -> List[str]:
    """Schema-check a loaded Chrome trace document.  Returns a list of
    problems (empty = valid): required keys per event, numeric ``ts`` /
    ``dur``, and monotonic span nesting per ``(pid, tid)`` — two complete
    events on one thread must be disjoint or properly nested (a context
    manager tracer cannot legally produce partial overlap)."""
    problems: List[str] = []
    evs = doc.get("traceEvents") if isinstance(doc, dict) else doc
    if not isinstance(evs, list):
        return ["top level is neither an event array nor {'traceEvents': []}"]
    if not evs:
        problems.append("no events")
    tracks: Dict[tuple, List[tuple]] = {}
    for i, ev in enumerate(evs):
        for k in REQUIRED_KEYS:
            if k not in ev:
                problems.append(f"event {i} missing key {k!r}")
        if not isinstance(ev.get("ts"), (int, float)):
            problems.append(f"event {i} has non-numeric ts")
            continue
        if ev.get("ph") == "X":
            if not isinstance(ev.get("dur"), (int, float)):
                problems.append(f"event {i} missing numeric dur")
                continue
            tracks.setdefault((ev.get("pid"), ev.get("tid")), []).append(
                (float(ev["ts"]), float(ev["dur"]), ev.get("name", "?")))
    eps = 0.5                       # us: clock-granularity slack
    for (pid, tid), spans in tracks.items():
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack: List[tuple] = []
        for ts, dur, name in spans:
            while stack and stack[-1][0] + stack[-1][1] <= ts + eps:
                stack.pop()
            if stack and ts + dur > stack[-1][0] + stack[-1][1] + eps:
                problems.append(
                    f"pid={pid} tid={tid}: span {name!r} [{ts:.1f},"
                    f"{ts + dur:.1f}] partially overlaps "
                    f"{stack[-1][2]!r} [{stack[-1][0]:.1f},"
                    f"{stack[-1][0] + stack[-1][1]:.1f}]")
            stack.append((ts, dur, name))
    return problems
