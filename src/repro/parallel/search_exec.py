"""Process-parallel search executor: shard a planner search across cores.

``plan_kernel_multi``'s candidate space is a pooled stream over independent
programs (one per block shape), so the program list shards cleanly: each
worker ranks a contiguous chunk with the normal branch-and-bound engine
and returns its top-k serialized through the plancache serializers, each
candidate carrying its canonical (program, mapping, combo) stream index.
The parent merges by ``(model cost, canonical index)`` — exactly the key
the sequential heap sorts by — so the selected top-k and every tie-break
are bit-identical to the inline search regardless of worker count (the
per-candidate costs themselves are deterministic: both cost engines
produce the same floats in any process).  Only the search-efficiency
counters (``n_pruned``/``n_estimated``/...) depend on sharding, because
each shard's incumbent threshold converges independently.

The pool is cached module-wide so repeated planning calls amortize worker
start-up, and workers start via ``fork`` where available (see
``_mp_context`` — overridable with ``REPRO_PLANNER_MP``).
``REPRO_PLANNER_WORKERS`` sets the default worker count (unset =
``os.cpu_count()``; ``0``/``1`` = inline); worker processes pin it to 1
so nested searches never oversubscribe.

:func:`map_jobs` is the generic job-level variant used by the AOT warm
sweep (``python -m repro.plancache warm --jobs N``) and
``planner_bridge.plan_mesh_many``: results return in submission order and
each worker publishes into the shared on-disk plan store (pid-unique
temp-file renames + the advisory stats lock keep that coherent).
"""
from __future__ import annotations

import atexit
import dataclasses
import multiprocessing
import os
import pickle
import time
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs import context, flightrec, metrics, trace

WORKERS_ENV = "REPRO_PLANNER_WORKERS"
MP_CONTEXT_ENV = "REPRO_PLANNER_MP"      # fork | spawn | forkserver

# Deterministic worker-crash injection (repro.runtime.faults arms this):
# the env var names a marker file; the first worker task to claim it
# removes the file and hard-exits, breaking the pool exactly once.
CRASH_ENV = "REPRO_FAULT_WORKER_CRASH"

# Pool-failure policy: a BrokenProcessPool (worker OOM-killed, crashed, or
# torn down by a signal) is retried on a fresh pool with exponential
# backoff; pickling errors are permanent and fail fast.  Callers fall back
# to the inline search when retries are exhausted, so a dying pool degrades
# throughput but never the result.
_POOL_RETRIES = 2
_POOL_BACKOFF_S = 0.05


def _maybe_crash_worker() -> None:
    """One-shot injected crash (see :data:`CRASH_ENV`).  Claiming the
    marker file is atomic (``os.remove`` succeeds in exactly one process),
    so a schedule arms exactly one crash no matter how many workers race."""
    marker = os.environ.get(CRASH_ENV, "").strip()
    if not marker:
        return
    try:
        os.remove(marker)
    except OSError:
        return                           # already claimed (or never armed)
    os._exit(17)


def _mp_context():
    """Worker start method.  ``fork`` where available and safe: no
    interpreter restart and no re-execution of the caller's ``__main__``
    (spawn runs the parent's main module in every worker, which breaks
    stdin scripts and console-script entry points).  Fork is avoided once
    JAX is loaded in the parent — its runtime is multithreaded and
    fork-hostile — which a pure planning process (benchmarks, the AOT
    warm driver) never triggers.  ``REPRO_PLANNER_MP`` overrides."""
    name = os.environ.get(MP_CONTEXT_ENV, "").strip().lower()
    if not name:
        import sys
        forkable = "fork" in multiprocessing.get_all_start_methods()
        name = "fork" if forkable and "jax" not in sys.modules else "spawn"
    return multiprocessing.get_context(name)


def resolve_workers(workers: Optional[int] = None) -> int:
    """The effective worker count: the caller's explicit value, else
    ``REPRO_PLANNER_WORKERS``, else ``os.cpu_count()``.  Values <= 1 (and
    unparsable env text) mean inline."""
    if workers is None:
        raw = os.environ.get(WORKERS_ENV, "").strip()
        if raw:
            try:
                workers = int(raw)
            except ValueError:
                workers = 1
        else:
            workers = os.cpu_count() or 1
    return workers if workers > 1 else 1


# ------------------------------------------------------------------ pool
_POOL: Optional[ProcessPoolExecutor] = None
_POOL_WORKERS = 0


def _get_pool(workers: int) -> ProcessPoolExecutor:
    """Module-wide spawn pool, grown (never shrunk) to ``workers``."""
    global _POOL, _POOL_WORKERS
    if _POOL is not None and _POOL_WORKERS >= workers:
        return _POOL
    if _POOL is not None:
        _POOL.shutdown(wait=False, cancel_futures=True)
    _POOL = ProcessPoolExecutor(max_workers=workers, mp_context=_mp_context())
    _POOL_WORKERS = workers
    return _POOL


def shutdown_pool() -> None:
    global _POOL, _POOL_WORKERS
    if _POOL is not None:
        _POOL.shutdown(wait=False, cancel_futures=True)
        _POOL = None
        _POOL_WORKERS = 0


atexit.register(shutdown_pool)


def _run_pool_tasks(fn: Callable[[Any], Any], tasks: Sequence[Any],
                    workers: int, *, label: str) -> Optional[List[Any]]:
    """Submit one task per ``fn(task)`` call and collect results in
    submission order, surviving crashed workers: a broken pool is torn
    down and the whole batch retried on a fresh pool (bounded, with
    exponential backoff).  Returns None when the pool is truly unusable —
    pickling failure, or retries exhausted — and the caller runs inline.
    Tasks must therefore be idempotent (every current caller's are: pure
    ranking, or content-addressed store publishes)."""
    delay = _POOL_BACKOFF_S
    for attempt in range(_POOL_RETRIES + 1):
        try:
            pool = _get_pool(workers)
            futs = [pool.submit(fn, t) for t in tasks]
            return [f.result() for f in futs]
        except (OSError, pickle.PicklingError, BrokenProcessPool) as e:
            shutdown_pool()              # a broken pool never recovers
            metrics.inc("search_pool_failures_total",
                        kind=type(e).__name__, where=label)
            flightrec.record("pool_failure", error=type(e).__name__,
                             where=label, attempt=attempt,
                             will_retry=(not isinstance(
                                 e, pickle.PicklingError)
                                 and attempt < _POOL_RETRIES))
            if isinstance(e, pickle.PicklingError) \
                    or attempt == _POOL_RETRIES:
                return None
            time.sleep(delay)
            delay *= 2
    return None


# ------------------------------------------------------------ hw transport
def hw_spec(hw) -> Optional[Tuple[str, Any]]:
    """A cross-process handle for a HardwareModel: preset name when the
    model is a registered preset (Wormhole's composite channel map is a
    local class and cannot pickle), a ``preset_faults`` triple when the
    model is a preset plus a fault overlay (degraded fabrics inherit the
    unpicklable channel map), else pickled bytes, else None (caller must
    run inline)."""
    from repro.core.hw import PRESETS
    if hw.name in PRESETS:
        try:
            if PRESETS[hw.name]().df_text() == hw.df_text():
                return ("preset", hw.name)
            if hw.is_degraded:
                rebuilt = PRESETS[hw.name]().with_faults(
                    hw.disabled_cores, hw.degraded_links)
                if rebuilt.df_text() == hw.df_text():
                    return ("preset_faults",
                            (hw.name, hw.disabled_cores, hw.degraded_links))
        except Exception:
            pass
    try:
        return ("pickle", pickle.dumps(hw))
    except Exception:
        return None


def hw_from_spec(spec: Tuple[str, Any]):
    kind, val = spec
    if kind == "preset":
        from repro.core.hw import get_hw
        return get_hw(val)
    if kind == "preset_faults":
        from repro.core.hw import get_hw
        name, disabled, links = val
        return get_hw(name).with_faults(disabled, links)
    return pickle.loads(val)


# --------------------------------------------------------------- sharding
def _chunk_bounds(n: int, chunks: int) -> List[Tuple[int, int]]:
    """Contiguous near-equal [start, stop) chunks covering range(n)."""
    base, extra = divmod(n, chunks)
    bounds = []
    start = 0
    for i in range(chunks):
        stop = start + base + (1 if i < extra else 0)
        bounds.append((start, stop))
        start = stop
    return bounds


def _worker_rank(task: Dict[str, Any]) -> Dict[str, Any]:
    """Rank one program chunk (runs in a worker process).  Returns the
    chunk's top-k as serialized candidates with *global* canonical indices
    plus the chunk's search counters — and, when the parent is tracing,
    the worker's buffered spans (the parent ingests them so the exported
    trace shows every worker process; workers never write trace files
    themselves, which would clobber the parent's ``REPRO_TRACE`` path)."""
    os.environ[WORKERS_ENV] = "1"        # no nested pools
    _maybe_crash_worker()
    # adopt the parent's correlation ID (shipped with the job, like the
    # trace flag) so worker spans land on the request's timeline; attach
    # overwrites, so a reused worker never keeps a previous task's ID
    context.attach(task.get("rid"))
    from repro.core import planner
    from repro.plancache import serialize
    tracing = bool(task.get("trace"))
    # a forked worker inherits the parent's span buffer — clear it so the
    # drain below returns only this task's spans (no duplicates)
    trace.clear()
    if tracing:
        trace.enable()
    else:
        trace.disable()
    t0 = time.perf_counter()
    programs = [serialize.program_from_dict(d) for d in task["programs"]]
    hw = hw_from_spec(task["hw"])
    budget = planner.SearchBudget(**task["budget"])
    stats = planner._SearchStats()
    with trace.span("planner.worker_rank", cat="worker",
                    n_programs=len(programs), p_base=task["p_base"]):
        topk = planner._rank_streamed(
            programs, hw, budget, spatial_reuse=task["spatial_reuse"],
            temporal_reuse=task["temporal_reuse"],
            use_bound=task["use_bound"],
            catch_infeasible=task["catch_infeasible"], stats=stats,
            engine=task["engine"])
    out = []
    p_base = task["p_base"]
    for c in topk:
        d = serialize.candidate_to_dict(c)
        p, m, ci = c.index
        d["index"] = [p + p_base, m, ci]
        out.append(d)
    return {"topk": out, "stats": dataclasses.asdict(stats),
            "wall_s": time.perf_counter() - t0,
            "spans": trace.drain() if tracing else []}


def rank_sharded(programs: Sequence, hw, budget, *, spatial_reuse: bool,
                 temporal_reuse: bool, use_bound: bool,
                 catch_infeasible: bool, engine: Optional[str],
                 stats, workers: int) -> Optional[List]:
    """Shard ``_rank_streamed`` over ``workers`` processes and merge.

    Returns the merged top-k Candidate list, or None when sharding is
    unavailable (unpicklable hardware model, pool failure) — the caller
    then runs inline.  ``stats`` is only mutated on success.  Planner bugs
    raised inside a worker (anything ``_rank_streamed`` would propagate
    inline, e.g. TypeError from a malformed program) re-raise here.
    """
    from repro.core import planner
    from repro.plancache import serialize
    spec = hw_spec(hw)
    if spec is None:
        return None
    n = len(programs)
    workers = min(workers, n)
    # resolve the engine here: workers must not re-read REPRO_COST_ENGINE
    # from the (potentially stale) environment they were started with
    engine = planner.resolve_engine(engine)
    wbudget = dataclasses.asdict(dataclasses.replace(budget, workers=1))
    tasks = []
    for start, stop in _chunk_bounds(n, workers):
        tasks.append({
            "programs": [serialize.program_to_dict(p)
                         for p in programs[start:stop]],
            "p_base": start,
            "hw": spec,
            "budget": wbudget,
            "spatial_reuse": spatial_reuse,
            "temporal_reuse": temporal_reuse,
            "use_bound": use_bound,
            "catch_infeasible": catch_infeasible,
            "engine": engine,
            "trace": trace.enabled(),
            "rid": context.current(),
        })
    results = _run_pool_tasks(_worker_rank, tasks, workers,
                              label="rank_sharded")
    if results is None:
        return None
    entries = []
    for res in results:                  # chunk order == program order
        w = res["stats"]
        stats.n_candidates += w["n_candidates"]
        stats.n_mappings += w["n_mappings"]
        stats.n_pruned += w["n_pruned"]
        stats.n_estimated += w["n_estimated"]
        stats.n_mappings_pruned += w["n_mappings_pruned"]
        stats.n_infeasible_programs += w["n_infeasible_programs"]
        stats.merge_phases(w.get("phases"))
        if w["first_failure"] and not stats.first_failure:
            stats.first_failure = w["first_failure"]
        metrics.observe("planner_shard_seconds", res.get("wall_s", 0.0))
        trace.ingest(res.get("spans"))
        for d in res["topk"]:
            c = serialize.candidate_from_dict(d)
            entries.append(((c.cost.total_s,) + tuple(c.index), c))
    entries.sort(key=lambda e: e[0])     # (cost, p, m, c): the heap's order
    return [c for _, c in entries[:budget.top_k]]


# ------------------------------------------------------- node-level pools
def _plan_node_pool_job(task: Dict[str, Any]) -> Dict[str, Any]:
    """Build one pipeline node's candidate pool (per-block-shape B&B +
    profiling, ``repro.pipeline.planner.node_candidate_pool``) in a worker
    process; returns the serialized candidates in pool order (plus the
    worker's buffered spans when the parent is tracing)."""
    os.environ[WORKERS_ENV] = "1"        # no nested pools
    _maybe_crash_worker()
    context.attach(task.get("rid"))      # see _worker_rank
    from repro.core import planner
    from repro.pipeline.planner import node_candidate_pool
    from repro.plancache import serialize
    tracing = bool(task.get("trace"))
    trace.clear()                        # drop any fork-inherited buffer
    if tracing:
        trace.enable()
    else:
        trace.disable()
    t0 = time.perf_counter()
    programs = [serialize.program_from_dict(d) for d in task["programs"]]
    hw = hw_from_spec(task["hw"])
    budget = planner.SearchBudget(**task["budget"])
    with trace.span("pipeline.worker_node_pool", cat="worker",
                    n_programs=len(programs)):
        pool = node_candidate_pool(programs, hw, budget,
                                   engine=task["engine"])
    return {"pool": [serialize.candidate_to_dict(c) for c in pool],
            "wall_s": time.perf_counter() - t0,
            "spans": trace.drain() if tracing else []}


def plan_node_pools(program_lists: Sequence[Sequence], hw, budget, *,
                    engine: Optional[str], workers: int) -> Optional[List]:
    """Shard the per-node candidate-pool searches of a pipeline graph
    across the worker pool — one job per node (each node's search is itself
    the normal inline two-step selection, so pools are bit-identical to the
    sequential per-node loop).  Returns per-node Candidate lists in node
    order, or None when sharding is unavailable (caller runs inline)."""
    from repro.core import planner
    from repro.plancache import serialize
    spec = hw_spec(hw)
    if spec is None:
        return None
    engine = planner.resolve_engine(engine)
    wbudget = dataclasses.asdict(dataclasses.replace(budget, workers=1))
    tasks = [{
        "programs": [serialize.program_to_dict(p) for p in progs],
        "hw": spec,
        "budget": wbudget,
        "engine": engine,
        "trace": trace.enabled(),
        "rid": context.current(),
    } for progs in program_lists]
    results = _run_pool_tasks(_plan_node_pool_job, tasks,
                              min(workers, len(tasks)),
                              label="plan_node_pools")
    if results is None:
        return None
    pools = []
    for res in results:
        metrics.observe("planner_shard_seconds", res.get("wall_s", 0.0))
        trace.ingest(res.get("spans"))
        pools.append([serialize.candidate_from_dict(d)
                      for d in res["pool"]])
    return pools


# ---------------------------------------------------------------- map_jobs
def _repro_env() -> Dict[str, Optional[str]]:
    """Snapshot of the planner/registry env contract.  The pool is
    persistent, so workers hold whatever environment existed at their
    start — a parent that redirects ``REPRO_PLAN_CACHE_DIR`` or toggles
    ``REPRO_FAST_SEARCH`` afterwards must ship the current values with
    each job or the workers plan against stale settings."""
    keys = ("REPRO_PLAN_CACHE_DIR", "REPRO_PLAN_CACHE", "REPRO_FAST_SEARCH",
            "REPRO_COST_ENGINE")
    return {k: os.environ.get(k) for k in keys}


def _run_with_env(task: Tuple[Dict[str, Optional[str]],
                              Callable[[Any], Any], Any, Optional[str]]
                  ) -> Any:
    env, fn, job, rid = task
    for k, v in env.items():
        if v is None:
            os.environ.pop(k, None)
        else:
            os.environ[k] = v
    _maybe_crash_worker()
    context.attach(rid)                  # see _worker_rank
    return fn(job)


def map_jobs(fn: Callable[[Any], Any], jobs: Sequence[Any],
             workers: int) -> List[Any]:
    """Run ``fn(job)`` for every job, sharded across worker processes
    (``fn`` must be a module-level importable function).  Each job carries
    the parent's current ``REPRO_*`` environment (see :func:`_repro_env`),
    and results arrive in submission order, so output is deterministic
    regardless of completion order.  ``workers <= 1`` (or a single job)
    runs inline.

    Jobs must be idempotent: a crashed worker breaks the whole pool, and
    the batch is retried on a fresh pool (:func:`_run_pool_tasks`) — with
    the entire batch run inline as the last resort — so partially
    completed side effects (content-addressed store puts) repeat."""
    jobs = list(jobs)
    workers = min(workers, len(jobs))
    if workers <= 1:
        return [fn(j) for j in jobs]
    env = _repro_env()
    rid = context.current()
    results = _run_pool_tasks(_run_with_env,
                              [(env, fn, j, rid) for j in jobs],
                              workers, label="map_jobs")
    if results is None:                  # pool unusable: degrade, don't die
        return [fn(j) for j in jobs]
    return results
