"""Logical-axis sharding: the bridge between model code and the mesh.

Model code annotates activations with *logical* axes (``constrain(x,
("batch", "seq", "embed"))``) and parameters carry logical axes from their
LeafSpecs.  A :class:`ShardingPlan` maps logical axes -> mesh axes; the
TileLoom planner bridge (``planner_bridge.py``) *produces* these plans by
planning the model's dominant tile programs on the pod-level df description —
fixed plans (pure-DP, megatron-TP, ...) are also provided as the vendor-style
baselines.

Divisibility-safe: a mesh axis that does not divide the corresponding dim is
dropped from the spec (GSPMD would pad; we prefer explicit replication so the
dry-run memory analysis stays honest).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class ShardingPlan:
    """logical axis -> mesh axis (or axes) mapping + plan metadata."""
    name: str
    rules: Tuple[Tuple[str, MeshAxes], ...]
    description: str = ""

    def mesh_axes(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        for k, v in self.rules:
            if k == logical:
                return v
        return None

    def with_rule(self, logical: str, axes: MeshAxes) -> "ShardingPlan":
        rules = tuple((k, v) for k, v in self.rules if k != logical)
        return replace(self, rules=rules + ((logical, axes),))

    def spec(self, axes: Sequence[Optional[str]],
             shape: Optional[Tuple[int, ...]] = None,
             mesh: Optional[Mesh] = None) -> P:
        """PartitionSpec for a tensor with the given logical axes; drops mesh
        axes that do not divide the dim or are already used."""
        used: set = set()
        parts = []
        for i, ax in enumerate(axes):
            m = self.mesh_axes(ax)
            if m is None:
                parts.append(None)
                continue
            ms = (m,) if isinstance(m, str) else tuple(m)
            ms = tuple(a for a in ms if a not in used)
            if mesh is not None:
                ok = []
                size = 1
                for a in ms:
                    if a not in mesh.shape:
                        continue
                    size *= mesh.shape[a]
                    ok.append(a)
                ms = tuple(ok)
                if shape is not None and ms:
                    total = int(np.prod([mesh.shape[a] for a in ms]))
                    if shape[i] % total != 0:
                        # try the prefix that divides
                        ms2 = []
                        tot = 1
                        for a in ms:
                            if shape[i] % (tot * mesh.shape[a]) == 0:
                                ms2.append(a)
                                tot *= mesh.shape[a]
                        ms = tuple(ms2)
            if not ms:
                parts.append(None)
            else:
                used.update(ms)
                parts.append(ms[0] if len(ms) == 1 else ms)
        return P(*parts)


# ---------------------------------------------------------------- context
class _Ctx(threading.local):
    def __init__(self):
        self.plan: Optional[ShardingPlan] = None
        self.mesh: Optional[Mesh] = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_plan(plan: ShardingPlan, mesh: Mesh):
    prev = (_CTX.plan, _CTX.mesh)
    _CTX.plan, _CTX.mesh = plan, mesh
    try:
        yield
    finally:
        _CTX.plan, _CTX.mesh = prev


def current_plan() -> Optional[ShardingPlan]:
    return _CTX.plan


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op outside a plan."""
    plan, mesh = _CTX.plan, _CTX.mesh
    if plan is None or mesh is None:
        return x
    if len(axes) != x.ndim:
        return x
    spec = plan.spec(tuple(axes), tuple(x.shape), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# -------------------------------------------------------- pytree helpers
def tree_shardings(axes_tree: Any, shapes_tree: Any, plan: ShardingPlan,
                   mesh: Mesh) -> Any:
    """NamedSharding pytree for params/opt-state given their logical axes."""
    def one(axes, shaped):
        return NamedSharding(mesh, plan.spec(axes, tuple(shaped.shape), mesh))
    return jax.tree.map(one, axes_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


# ------------------------------------------------------------ fixed plans
def pure_dp_plan() -> ShardingPlan:
    """Everything replicated, batch over all mesh axes — the 'TT-1D-like'
    trivial baseline at mesh level."""
    return ShardingPlan(
        name="pure_dp",
        rules=(("batch", ("pod", "data", "model")),),
        description="data parallel only; parameters replicated")


def megatron_tp_plan() -> ShardingPlan:
    """The fixed vendor-style template: DP over (pod,data), megatron TP over
    'model' for heads/ffn/vocab/experts."""
    return ShardingPlan(
        name="megatron_tp",
        rules=(
            ("batch", ("pod", "data")),
            ("q_heads", "model"),
            ("kv_heads", "model"),
            ("ffn", "model"),
            ("vocab", "model"),
            ("experts", "model"),
            ("ssm_heads", "model"),
        ),
        description="DP x megatron-TP template")


def sequence_parallel_plan() -> ShardingPlan:
    """Long-context plan: sequence sharded over 'model' (ring-attention
    style), used for 32k prefill / 500k decode when batch is tiny."""
    return ShardingPlan(
        name="sequence_parallel",
        rules=(
            ("batch", ("pod", "data")),
            ("seq", "model"),
            ("kv_seq", "model"),
            ("ffn", None),
            ("q_heads", None),
        ),
        description="DP x sequence-parallel (ring) template")


def expert_parallel_plan() -> ShardingPlan:
    """MoE plan: experts over 'model', batch over (pod,data); dense layers
    megatron-TP."""
    return ShardingPlan(
        name="expert_parallel",
        rules=(
            ("batch", ("pod", "data")),
            ("experts", "model"),
            ("q_heads", "model"),
            ("kv_heads", "model"),
            ("ffn", "model"),
            ("vocab", "model"),
        ),
        description="DP x EP(+TP) template")


FIXED_PLANS = {
    "pure_dp": pure_dp_plan,
    "megatron_tp": megatron_tp_plan,
    "sequence_parallel": sequence_parallel_plan,
    "expert_parallel": expert_parallel_plan,
}
