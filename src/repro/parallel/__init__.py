# Distribution layer: logical-axis sharding + the TileLoom mesh planner bridge.
from .sharding import (FIXED_PLANS, ShardingPlan, constrain, current_plan,
                       tree_shardings, use_plan)

__all__ = ["FIXED_PLANS", "ShardingPlan", "constrain", "current_plan",
           "tree_shardings", "use_plan"]
