# Distribution layer: logical-axis sharding, the TileLoom mesh planner
# bridge, and the process-parallel search executor.
#
# Submodule imports are lazy (PEP 562): `sharding` and `planner_bridge`
# pull in jax, but the planner core only needs `search_exec` (jax-free) —
# eagerly importing the package here would bill a full jax import to the
# first cold `plan_kernel_multi` call.
from typing import TYPE_CHECKING

__all__ = ["FIXED_PLANS", "ShardingPlan", "constrain", "current_plan",
           "tree_shardings", "use_plan"]

if TYPE_CHECKING:                        # pragma: no cover - type-checkers only
    from .sharding import (FIXED_PLANS, ShardingPlan, constrain,
                           current_plan, tree_shardings, use_plan)


def __getattr__(name: str):
    if name in __all__:
        from . import sharding
        return getattr(sharding, name)
    if name in ("sharding", "planner_bridge", "search_exec"):
        import importlib
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
