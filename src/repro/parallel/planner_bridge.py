"""TileLoom mesh planning: choose the sharding layout like the paper chooses
dataflows.

The pod is described in the same df dialect (``core.hw.tpu_v5e_pod``); a
candidate :class:`ShardingPlan` corresponds 1:1 to a TileLoom spatiotemporal
mapping + memory-op choice of the model's dominant tile program:

==============================  ==============================================
ShardingPlan                    TileLoom plan on C[tokens,ffn]=X[tokens,d]W[d,ffn]
==============================  ==============================================
megatron_tp                     tokens->data, ffn->model; X broadcast along
                                'model' (the TP all-gather); W broadcast along
                                'data' hoisted to level 0 (weights resident)
pure_dp                         tokens->(data,model) flattened; W broadcast to
                                the whole array hoisted to level 0 (replicated)
zero3 (fsdp)                    tokens->(data,model); W broadcast *inside* the
                                layer loop (per-use weight gather = ZeRO-3)
sequence_parallel               seq->model (ring dataflow); per-chip full W
expert_parallel                 experts->model; token tiles all-to-all (the a2a
                                is the EP analogue of the paper's broadcasts)
==============================  ==============================================

Two-step selection, exactly as the paper: (1) the analytic model below ranks
candidates — compute / HBM / per-axis ICI terms with the paper's contention
rule (demand over df-declared link bandwidth) and capacity pruning (candidate
whose per-chip params+optimizer+activations exceed HBM is discarded);
(2) the surviving top-k are validated by ``launch/dryrun.py``'s
``.lower().compile()`` + cost analysis (the "profile on hardware" stage).

``tileloom_view()`` renders the chosen plan back as the corresponding df tile
program mapping for the reports.
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro import plancache
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.core.hw import (HardwareModel, TPU_V5E_HBM_BYTES, TPU_V5E_HBM_GBPS,
                           TPU_V5E_ICI_GBPS, TPU_V5E_PEAK_BF16, tpu_v5e_pod)
from repro.models.api import ModelAPI, build_model
from .sharding import (ShardingPlan, expert_parallel_plan, megatron_tp_plan,
                       pure_dp_plan, sequence_parallel_plan)

DCN_GBPS = 25.0          # cross-pod links (df 'pod' axis interconnect)


def is_train_or_prefill(shape: ShapeConfig) -> bool:
    return shape.kind in ("train", "prefill")


# small helper since ShardingPlan is frozen
def _rename(plan: ShardingPlan, name: str) -> ShardingPlan:
    return ShardingPlan(name=name, rules=plan.rules,
                        description=plan.description)


def _tp2d() -> ShardingPlan:
    """2D tensor parallelism for 100B+ models: activations' embed dim sharded
    over 'data' (contraction-parallel partial matmuls + all-reduce), sequence
    over 'model'.  No weight gather at all — the only layout where the
    405B-class weights never move (XLA hoists ZeRO-3's per-layer gather to a
    whole-stack gather, 50 GB/device; measured in the dry-run)."""
    return ShardingPlan(
        name="tp2d",
        rules=(
            ("batch", ("pod",)),
            ("seq", "model"),
            ("kv_seq", "model"),
            ("embed", "data"),
            ("ffn", "model"),
            ("q_heads", "model"),
            ("kv_heads", "model"),
            ("vocab", "model"),
            ("experts", "model"),
        ),
        description="2D TP: embed over data (psum matmuls), seq over model")


def _zero3() -> ShardingPlan:
    """megatron-TP + ZeRO-3: the params' 'embed' axis is sharded over 'data'
    (activations are unaffected: their 'batch' axis already occupies 'data',
    and ShardingPlan.spec never reuses a mesh axis)."""
    return _rename(megatron_tp_plan().with_rule("embed", "data"), "zero3")


@dataclass
class MeshPlanCost:
    compute_s: float
    memory_s: float
    collective_s: float
    hbm_bytes_per_chip: float
    collective_bytes: float
    feasible: bool
    dominant: str

    @property
    def total_s(self) -> float:
        # paper's overlap model at steady state: compute overlaps transfers
        return max(self.compute_s, self.memory_s, self.collective_s)


@dataclass
class MeshPlanResult:
    plan: ShardingPlan
    cost: MeshPlanCost
    notes: str = ""
    # search-efficiency counters of the ranking that produced this result
    # (mirrors PlanResult.n_pruned/n_estimated at mesh granularity; the
    # same stats for every result of one plan_mesh call)
    stats: Optional[Dict[str, int]] = None


def _mesh_sizes(multi_pod: bool) -> Dict[str, int]:
    return ({"pod": 2, "data": 16, "model": 16} if multi_pod
            else {"data": 16, "model": 16})


def _shard_factor(plan: ShardingPlan, logical: str, sizes: Dict[str, int]
                  ) -> int:
    m = plan.mesh_axes(logical)
    if m is None:
        return 1
    axes = (m,) if isinstance(m, str) else m
    return math.prod(sizes.get(a, 1) for a in axes)


def estimate_plan(api: ModelAPI, shape: ShapeConfig, plan: ShardingPlan,
                  tcfg: TrainConfig, *, multi_pod: bool = False
                  ) -> MeshPlanCost:
    """Analytic three-term cost of one (plan, arch, shape) cell on the pod df
    model.  Mirrors core/perfmodel.py at mesh granularity."""
    cfg = api.cfg
    sizes = _mesh_sizes(multi_pod)
    chips = math.prod(sizes.values())
    B, S = shape.global_batch, shape.seq_len
    dt = 2  # bf16 activations

    n_params = api.n_params()
    n_active = api.n_active_params()
    is_train = shape.kind == "train"
    tokens = B * (S if shape.kind != "decode" else 1)

    # ---- compute term ----------------------------------------------------
    flops = (6.0 if is_train else 2.0) * n_active * tokens
    if cfg.family in ("dense", "moe", "vlm", "audio") and shape.kind != "decode":
        flops += 2.0 * (3.0 if is_train else 1.0) * B * S * S * \
            cfg.n_heads * cfg.head_dim_ * cfg.n_layers * 0.5
    compute_s = flops / (chips * TPU_V5E_PEAK_BF16)

    # ---- memory (HBM) term -------------------------------------------------
    p_bytes = jnp.dtype(cfg.param_dtype).itemsize
    tp = _shard_factor(plan, "ffn", sizes)
    zero = _shard_factor(plan, "embed", sizes)
    ep = _shard_factor(plan, "experts", sizes) if cfg.n_experts else 1
    if cfg.n_experts and ep > tp:
        tp = ep              # expert sharding dominates the FFN weights
    params_per_chip = n_params * p_bytes / (tp * zero)
    if tcfg.optimizer == "adafactor":
        opt_mult = 0.05          # factored second moments: ~N/d per matrix
    else:
        opt_mult = {"float32": 8, "bfloat16": 4}.get(tcfg.opt_state_dtype, 8)
    opt_per_chip = (n_params * opt_mult / (tp * zero)) if is_train else 0.0
    grad_per_chip = (n_params * 4 / (tp * zero)) if is_train else 0.0
    dp = _shard_factor(plan, "batch", sizes)
    sp = _shard_factor(plan, "seq", sizes)
    # activations carry the embed dim sharded only when 'batch' does not
    # already occupy the same mesh axis (ShardingPlan.spec drops reuses)
    b_ax = str(plan.mesh_axes("batch"))
    e_ax = plan.mesh_axes("embed")
    act_emb = _shard_factor(plan, "embed", sizes) if (
        e_ax and str(e_ax) not in b_ax) else 1
    mb = max(1, tcfg.microbatches) if is_train else 1
    tokens_chip = tokens / max(1, dp * sp * act_emb) / mb
    if is_train:
        # scan-over-layers remat: one carry (layer input) saved per layer,
        # x2 for backward temporaries (calibrated against dry-run
        # memory_analysis on qwen2.5-3b: 30 GB at mb=1 -> 9.4 GB at mb=4)
        act_per_chip = 2 * cfg.n_layers * tokens_chip * cfg.d_model * dt \
            + 8 * tokens_chip * cfg.d_model * dt
    else:
        act_per_chip = 2 * tokens_chip * cfg.d_model * dt
    if shape.kind == "decode":
        # KV cache / recurrent state resident in HBM
        if cfg.family == "ssm":
            cache = cfg.n_layers * B * cfg.d_model * 64 * 4
        else:
            cache = (cfg.n_layers * B * S * cfg.n_kv_heads * cfg.head_dim_
                     * 2 * 2)
        kvh = min(_shard_factor(plan, "kv_heads", sizes),
                  max(1, cfg.n_kv_heads))
        kvs = _shard_factor(plan, "kv_seq", sizes) * kvh
        act_per_chip += cache / max(1, min(dp, B) * kvs)
    hbm_per_chip = params_per_chip + opt_per_chip + grad_per_chip \
        + act_per_chip
    if zero > 1 and act_emb == 1 and is_train_or_prefill(shape) \
           :
        # ZeRO-3 via GSPMD: XLA hoists the per-layer weight all-gather into a
        # whole-stack gather (measured: llama3-405b 50 GB/device), so the
        # gathered stack is transiently resident sharded only by TP.  Decode
        # is exempt: its activations are MBs, XLA reshards those instead.
        hbm_per_chip += n_params * p_bytes / tp
    # bytes actually streamed per step: weights once (+grad/opt traffic when
    # training) + activations
    hbm_traffic = ((params_per_chip * (3 if is_train else 1)
                    + opt_per_chip) * (mb if zero > 1 else 1)
                   + act_per_chip * 2 * mb)
    memory_s = hbm_traffic / (TPU_V5E_HBM_GBPS * 1e9)

    # ---- collective term (per-axis df interconnects, paper contention rule)
    ici = TPU_V5E_ICI_GBPS * 1e9
    dcn = DCN_GBPS * 1e9
    busy: Dict[str, float] = {"data": 0.0, "model": 0.0, "pod": 0.0}
    act_bytes = tokens * cfg.d_model * dt
    if tp > 1:
        # TP all-gather + reduce-scatter per layer, fwd (+2x bwd in training)
        n_coll = 2 * cfg.n_layers * (3 if is_train else 1)
        busy["model"] += n_coll * (act_bytes / max(1, dp)) * (tp - 1) / tp
    if zero > 1:
        # ZeRO-3 weight all-gather per step (fwd + bwd re-gather)
        busy["data"] += (n_params * p_bytes / tp) * (2 if is_train else 1)
    if is_train and dp > 1:
        g_bytes = n_params * 4 / (tp * zero)
        if tcfg.grad_compression == "int8":
            g_bytes /= 4
        busy["data"] += 2 * g_bytes * (min(dp, sizes["data"]) - 1) / dp
        if multi_pod and plan.mesh_axes("batch") and \
                "pod" in str(plan.mesh_axes("batch")):
            busy["pod"] += 2 * g_bytes / max(1, sizes.get("pod", 1))
    if cfg.n_experts and _shard_factor(plan, "experts", sizes) > 1:
        # EP all-to-all: k-routed token activations, there and back
        k = cfg.experts_per_token or 1
        busy["model"] += 2 * cfg.n_layers * (3 if is_train else 1) * \
            (tokens / max(1, dp)) * k * cfg.d_model * dt
    if sp > 1:
        # ring attention: K/V blocks circulate around the 'model' ring
        busy["model"] += (3 if is_train else 1) * cfg.n_layers * \
            2 * (tokens / sp) * cfg.n_kv_heads * cfg.head_dim_ * dt * (sp - 1)
    coll_terms = []
    for axis, b in busy.items():
        if b <= 0:
            continue
        bw = dcn if axis == "pod" else ici
        links = chips  # one link per chip per axis direction (torus)
        # aggregate pool: one link per chip along the axis ring; demand is
        # time-shared per the paper's contention rule
        coll_terms.append(b / (bw * chips / sizes.get(axis, 1)))
    collective_s = max(coll_terms) if coll_terms else 0.0
    coll_bytes = sum(busy.values())

    feasible = hbm_per_chip <= TPU_V5E_HBM_BYTES * 0.95
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    return MeshPlanCost(compute_s, memory_s, collective_s, hbm_per_chip,
                        coll_bytes, feasible, dominant)


def candidate_plans(cfg: ModelConfig, shape: ShapeConfig
                    ) -> List[ShardingPlan]:
    cands = [megatron_tp_plan(), _zero3(), pure_dp_plan()]
    if shape.kind == "train":
        # ZeRO-3 + sequence-parallel activations
        cands.insert(1, _rename(
            _zero3().with_rule("seq", "model").with_rule("kv_seq", "model"),
            "zero3_sp"))
        # 2D TP: required for the 100B+ archs (see module docstring)
        cands.insert(2, _tp2d())
    if shape.kind == "prefill":
        cands.insert(1, _tp2d())     # same reasoning for 32k prefill
    if cfg.n_experts:
        cands.insert(0, expert_parallel_plan())
        cands.append(_rename(expert_parallel_plan().with_rule(
            "embed", "data"), "expert_parallel_zero3"))
    if shape.kind != "train" and shape.seq_len >= 32768:
        cands.append(sequence_parallel_plan())
    if shape.kind == "decode":
        # sequence-split KV attention (flash-decode across the mesh): shard
        # the cache sequence over 'model' — essential when n_kv_heads < 16
        kv_split = megatron_tp_plan().with_rule("kv_seq", "model") \
            .with_rule("kv_heads", None).with_rule("q_heads", None)
        cands.insert(0, _rename(kv_split, "kv_sequence_split"))
        cands.insert(1, _rename(kv_split.with_rule("embed", "data"),
                                "kv_split_zero3"))

    return cands


# ------------------------------------------------------------ plan cache
def _axes_to_jsonable(axes) -> Any:
    return list(axes) if isinstance(axes, tuple) else axes


def _axes_from_jsonable(axes) -> Any:
    return tuple(axes) if isinstance(axes, list) else axes


def _mesh_result_to_dict(r: MeshPlanResult) -> Dict[str, Any]:
    return {
        "plan": {"name": r.plan.name,
                 "rules": [[k, _axes_to_jsonable(v)] for k, v in r.plan.rules],
                 "description": r.plan.description},
        "cost": dataclasses.asdict(r.cost),
        "notes": r.notes,
        "stats": r.stats,
    }


def _mesh_result_from_dict(d: Dict[str, Any]) -> MeshPlanResult:
    plan = ShardingPlan(
        name=d["plan"]["name"],
        rules=tuple((k, _axes_from_jsonable(v)) for k, v in d["plan"]["rules"]),
        description=d["plan"].get("description", ""))
    return MeshPlanResult(plan, MeshPlanCost(**d["cost"]),
                          d.get("notes", ""), d.get("stats"))


# bump whenever estimate_plan's cost logic or candidate_plans' plan set
# changes: persisted rankings are invalid under a different cost model
MESH_PLANNER_VERSION = 1


def _mesh_key(cfg: ModelConfig, shape: ShapeConfig, tcfg: TrainConfig,
              multi_pod: bool, top_k: int) -> str:
    hw = tpu_v5e_pod(pods=2 if multi_pod else 1)
    # only the fields estimate_plan actually reads go into the key: the
    # free-text shape name and schedule-only TrainConfig fields (lr, steps,
    # seed...) must not cause spurious misses — otherwise the AOT-warmed
    # registry cells (named "train_4k" etc.) could never be hit by the
    # launchers' ad-hoc ShapeConfig("serve"/"cli", ...) instances
    return plancache.request_key(
        "mesh_plan",
        {"cfg": dataclasses.asdict(cfg),
         "shape": {"seq_len": shape.seq_len,
                   "global_batch": shape.global_batch, "kind": shape.kind},
         "tcfg": {"optimizer": tcfg.optimizer,
                  "opt_state_dtype": tcfg.opt_state_dtype,
                  "microbatches": tcfg.microbatches,
                  "grad_compression": tcfg.grad_compression},
         "multi_pod": multi_pod, "top_k": top_k},
        hw, extra={"mesh_planner_version": MESH_PLANNER_VERSION})


def plan_mesh(api: ModelAPI, shape: ShapeConfig, tcfg: TrainConfig, *,
              multi_pod: bool = False, top_k: int = 3,
              cache: bool = True) -> List[MeshPlanResult]:
    """Rank candidate plans (paper step 1).  The dry-run compiles the top-k
    (paper step 2) and EXPERIMENTS.md records both.

    Rankings are persisted in the plan registry keyed on (model config,
    shape cell, train config, pod df model) — ``launch/serve.py`` and
    ``launch/train.py`` therefore start with a hot cache after
    ``python -m repro.plancache warm``.  ``cache=False`` forces a fresh
    ranking."""
    store = plancache.get_store() if cache else None
    key = None
    if store is not None:
        key = _mesh_key(api.cfg, shape, tcfg, multi_pod, top_k)
        ent = store.get(key)
        if ent is not None:
            try:
                return [_mesh_result_from_dict(d)
                        for d in ent["payload"]["results"]]
            except (KeyError, TypeError, ValueError):
                # decoded fine but doesn't deserialize: corrupt payload,
                # quarantine it and fall through to a fresh ranking
                store.quarantine(key, "deserialize")
    out = []
    t_rank = time.perf_counter()
    for plan in candidate_plans(api.cfg, shape):
        cost = estimate_plan(api, shape, plan, tcfg, multi_pod=multi_pod)
        out.append(MeshPlanResult(plan, cost))
    feasible = [r for r in out if r.cost.feasible]
    infeasible = [r for r in out if not r.cost.feasible]
    feasible.sort(key=lambda r: r.cost.total_s)
    for r in infeasible:
        r.notes = (f"pruned: {r.cost.hbm_bytes_per_chip / 1e9:.1f} GB/chip "
                   f"exceeds HBM (paper capacity rule)")
    ranked = feasible[:top_k] + infeasible
    # mirror core PlanResult's search counters so registry/report tooling
    # can treat both planners uniformly (capacity-infeasible plans are this
    # planner's "pruned" set; every candidate pays a full estimate)
    stats = {"n_candidates": len(out), "n_estimated": len(out),
             "n_pruned": len(infeasible),
             "rank_ms": int((time.perf_counter() - t_rank) * 1e3)}
    for r in ranked:
        r.stats = stats
    if store is not None and key is not None:
        store.put(key,
                  {"results": [_mesh_result_to_dict(r) for r in ranked]},
                  meta={"template": "mesh_plan",
                        "shape": [shape.seq_len, shape.global_batch],
                        "hw_name": "tpu_v5e_pod",
                        "arch": api.cfg.name, "kind": shape.kind,
                        "best": ranked[0].plan.name if ranked else None})
    return ranked


def plan_mesh_service(api: ModelAPI, shape: ShapeConfig, tcfg: TrainConfig,
                      *, service=None, multi_pod: bool = False,
                      top_k: int = 3, budget_ms: Optional[float] = None):
    """:func:`plan_mesh` through the deadline-bounded plan service: same
    ranking, plus rung/latency accounting and the never-raise contract.
    Returns a ``planservice.MeshPlanResponse``; ``service=None`` builds a
    throwaway one over the process-wide store."""
    from repro.planservice import PlanService
    svc = service if service is not None else PlanService()
    return svc.resolve_mesh(api, shape, tcfg, multi_pod=multi_pod,
                            top_k=top_k, budget_ms=budget_ms)


def _plan_mesh_job(payload) -> List[MeshPlanResult]:
    """One (arch, shape) mesh ranking, publishing into the shared disk
    registry — the unit both :func:`plan_mesh_many` and the AOT warm sweep
    (``plancache/warmjobs.py``) shard across worker processes."""
    arch, shape_name, tcfg_dict, multi_pod, top_k = payload
    from repro.configs import ARCHS
    from repro.configs.shapes import SHAPES
    api = build_model(ARCHS[arch])
    ranked = plan_mesh(api, SHAPES[shape_name], TrainConfig(**tcfg_dict),
                       multi_pod=multi_pod, top_k=top_k)
    plancache.get_store().flush_stats()
    return ranked


def _plan_mesh_job_isolated(payload) -> List[MeshPlanResult]:
    """Worker-process entry: pins the planner to inline search first (the
    sweep is already parallel at cell granularity)."""
    os.environ["REPRO_PLANNER_WORKERS"] = "1"
    return _plan_mesh_job(payload)


def plan_mesh_many(cells: Sequence[Tuple[str, str]], tcfg: TrainConfig, *,
                   multi_pod: bool = False, top_k: int = 3,
                   workers: Optional[int] = None
                   ) -> List[List[MeshPlanResult]]:
    """Rank many registry cells — ``(arch_name, shape_name)`` pairs —
    sharding across worker processes (``workers``; default
    ``REPRO_PLANNER_WORKERS`` / cpu count; <=1 = inline).

    Results return in cell order regardless of worker count, and every
    worker publishes its ranking into the shared on-disk plan registry
    (pid-unique temp renames + the advisory stats lock keep concurrent
    publishes coherent), so a sharded sweep leaves the exact cache state a
    sequential one would.  This is the mesh-granularity face of the search
    executor; the AOT warm sweep (``python -m repro.plancache warm
    --jobs``) rides the same worker pool.
    """
    from repro.parallel import search_exec
    n = search_exec.resolve_workers(workers)
    tcfg_dict = dataclasses.asdict(tcfg)
    jobs = [(arch, shape, tcfg_dict, multi_pod, top_k)
            for arch, shape in cells]
    if n <= 1:
        from repro.configs import ARCHS
        from repro.configs.shapes import SHAPES
        return [plan_mesh(build_model(ARCHS[a]), SHAPES[s], tcfg,
                          multi_pod=multi_pod, top_k=top_k)
                for a, s in cells]
    return search_exec.map_jobs(_plan_mesh_job_isolated, jobs, n)


def lower_reduction_bind(mapping) -> List[Dict[str, Any]]:
    """Lower a pod-level spatial-reduction mapping to XLA collectives.

    A ``reduce=True`` bind on a pod df axis (a core
    :class:`~repro.core.mapping.Mapping` planned on ``tpu_v5e_pod``) is the
    mesh-granularity face of split-K: every chip along the axis holds a
    partial sum of the same output shard.  The combining styles map onto
    collectives 1:1:

    * ``accum``  -> ``jax.lax.psum`` over the axis (all chips end with the
      reduced value in place — the ``tp2d`` plan's partial matmuls);
    * ``tree``   -> ``reduce_scatter`` + owner-shard store (log-depth
      combining; only one shard materializes the output);
    * ``chain``  -> a ``ppermute`` ring of partial accumulations (the
      neighbor-chain forwarding the Wormhole plans use on the NoC).

    Returns one descriptor per reduce bind (empty list = pure parallel
    mapping, no collective epilogue).
    """
    out: List[Dict[str, Any]] = []
    coll = {"accum": "psum", "tree": "reduce_scatter", "chain": "ppermute"}
    for b in mapping.reduce_binds():
        out.append({
            "axis": b.hw_dim,
            "reduction_dim": b.grid_dim,
            "n_split": int(mapping.active_reduce_factor()),
            "collective": coll.get(mapping.reduce_style, "psum"),
            "style": mapping.reduce_style,
        })
    return out


def lower_forwarded_edge(decision) -> Dict[str, Any]:
    """Lower one pipeline edge decision
    (:class:`repro.pipeline.EdgeDecision`) to its pod-level XLA realization.

    At mesh granularity the "distributed local memories" are the chips'
    HBMs, so a *forwarded* edge means the producer's output shard stays
    resident on-device with its buffer donated straight into the consumer
    (no host/DCN round trip), and each mismatched spatial digit becomes a
    re-shard collective on that axis:

    * aligned (no shuffle axes)  -> pure donation: producer and consumer
      agree on the sharding, XLA aliases the buffers;
    * shuffle axes               -> one ``all_to_all`` per mismatched mesh
      axis (the NoC re-shuffle leg's collective face).

    A *spilled* edge round-trips through the global level instead —
    device-to-host offload + reload, the pod analogue of the DRAM handoff.
    """
    if not decision.forwarded:
        return {
            "edge": [decision.src, decision.dst, decision.tensor],
            "placement": "offload",
            "transfer": "device_to_host+reload",
            "collectives": [],
        }
    return {
        "edge": [decision.src, decision.dst, decision.tensor],
        "placement": "resident",
        "transfer": "donate",
        "collectives": [{"axis": a, "collective": "all_to_all"}
                        for a in decision.shuffle_axes],
    }


def tileloom_view(plan: ShardingPlan, cfg: ModelConfig) -> str:
    """Render the plan as its TileLoom tile-program mapping (for reports)."""
    batch = plan.mesh_axes("batch") or "-"
    ffn = plan.mesh_axes("ffn") or plan.mesh_axes("experts") or "-"
    zero = plan.mesh_axes("embed")
    lines = [
        f"// TileLoom mapping of C[tokens,ffn] = X[tokens,d] @ W[d,ffn] "
        f"({plan.name})",
        f"tokens -> %{batch}; ffn -> %{ffn}",
        f"load_X {{type=\"broadcast\", resources={{%ici_model}}}}"
        if ffn != "-" else "load_X {type=\"local\"}",
    ]
    if zero:
        lines.append("load_W {type=\"broadcast\", level=inner, "
                     "resources={%ici_data}}  // ZeRO-3 per-use gather")

    else:
        lines.append("load_W {type=\"broadcast\", level=0, "
                     "resources={%ici_data}}  // weights resident")
    embed = plan.mesh_axes("embed")
    if embed and plan.name == "tp2d":
        # contraction (d) sharded: the chips along the axis hold split-K
        # partials — the pod-level reduce bind, lowered as a psum epilogue
        # (see lower_reduction_bind)
        lines.append(f"store_C {{type=\"reduce\", style=\"accum\", "
                     f"resources={{%ici_{embed}}}}}  // split-K psum")
    return "\n".join(lines)
