"""Runtime resilience: fault tolerance, elastic rescale, fault injection,
and degraded-mesh re-planning.

Imports are lazy (PEP 562): ``elastic`` pulls in JAX at import time, but
the fault-injection and re-plan layers are pure planner code — callers
like the benchmark harness and pool workers must be able to import them
without paying (or having) an accelerator runtime.
"""
from typing import TYPE_CHECKING

_EXPORTS = {
    "RescalePlan": "elastic",
    "apply_rescale": "elastic",
    "plan_rescale": "elastic",
    "viable_mesh_shapes": "elastic",
    "HeartbeatRegistry": "fault_tolerance",
    "RecoveryEvent": "fault_tolerance",
    "ResilientDriver": "fault_tolerance",
    "StragglerTracker": "fault_tolerance",
    "FaultSpec": "faults",
    "FaultSchedule": "faults",
    "parse_faults": "faults",
    "apply_env_faults": "faults",
    "ReplanOutcome": "replan",
    "ReplanOrchestrator": "replan",
    "plan_degraded": "replan",
    "best_submesh": "replan",
}

__all__ = sorted(_EXPORTS)

if TYPE_CHECKING:  # pragma: no cover - static analysis only
    from .elastic import (RescalePlan, apply_rescale, plan_rescale,
                          viable_mesh_shapes)
    from .fault_tolerance import (HeartbeatRegistry, RecoveryEvent,
                                  ResilientDriver, StragglerTracker)
    from .faults import FaultSchedule, FaultSpec, apply_env_faults, parse_faults
    from .replan import (ReplanOrchestrator, ReplanOutcome, best_submesh,
                         plan_degraded)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)
