from .elastic import RescalePlan, apply_rescale, plan_rescale, viable_mesh_shapes
from .fault_tolerance import (HeartbeatRegistry, RecoveryEvent, ResilientDriver,
                              StragglerTracker)
