"""Elastic scaling: re-plan + reshard when the device set changes.

The TileLoom thesis applied to cluster operations: a mapping is a *compiled
decision*, so losing a pod (or gaining one) is handled by (1) re-running the
mesh planner for the surviving device set, (2) restoring the latest
checkpoint resharded onto the new mesh (checkpoints are stored fully
gathered, so any mesh shape can load them), (3) resuming — the data pipeline
is deterministic in (seed, step) so no input state moves.

``plan_rescale`` is pure (testable without devices); ``apply_rescale``
performs the device_put resharding.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.configs.base import ShapeConfig, TrainConfig
from repro.models.api import ModelAPI
from repro.parallel.planner_bridge import MeshPlanResult, plan_mesh


@dataclass
class RescalePlan:
    old_devices: int
    new_devices: int
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    plan_name: str
    batch_note: str
    ranking: List[MeshPlanResult]


def viable_mesh_shapes(n_devices: int) -> List[Tuple[int, int]]:
    """(data, model) factorizations, squarest first."""
    out = []
    for d in range(1, n_devices + 1):
        if n_devices % d == 0:
            out.append((d, n_devices // d))
    out.sort(key=lambda dm: abs(math.log(dm[0] / dm[1])))
    return out


def plan_rescale(api: ModelAPI, shape: ShapeConfig, tcfg: TrainConfig, *,
                 old_devices: int, new_devices: int) -> RescalePlan:
    """Choose mesh shape + sharding plan for the new device count.  Keeps the
    global batch when divisible; otherwise documents the adjustment (exact
    reproducibility of the loss curve requires fixed global batch)."""
    shapes = viable_mesh_shapes(new_devices)
    best = shapes[0]
    note = ""
    if shape.global_batch % best[0] != 0:
        for cand in shapes:
            if shape.global_batch % cand[0] == 0:
                best = cand
                break
        else:
            note = (f"global_batch {shape.global_batch} not divisible by any "
                    f"data-axis choice of {new_devices} devices; batch "
                    f"padding required")
    ranking = plan_mesh(api, shape, tcfg, multi_pod=False)
    return RescalePlan(
        old_devices=old_devices, new_devices=new_devices,
        mesh_shape=best, mesh_axes=("data", "model"),
        plan_name=ranking[0].plan.name if ranking else "megatron_tp",
        batch_note=note, ranking=ranking)


def apply_rescale(tree, shardings) -> Any:
    """Reshard a (restored, host-resident) pytree onto the new mesh."""
    def one(x, s):
        return jax.device_put(x, s) if s is not None else x
    return jax.tree.map(one, tree, shardings,
                        is_leaf=lambda x: x is None)
