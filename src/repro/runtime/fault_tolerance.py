"""Fault tolerance for 1000+-node operation.

Three mechanisms, each exercised by tests with injected failures:

* **Heartbeats / failure detection** — every host reports (step, wall-time)
  into a :class:`HeartbeatRegistry`; a host silent for ``timeout_s`` is
  declared dead.  In a real deployment the registry is a small etcd/GCS
  object; the interface is identical.
* **Straggler mitigation** — per-step wall-times feed a rolling p50/p95
  tracker; a host persistently above ``straggler_factor x p50`` is flagged,
  and the driver's policy (``on_straggler``) can hot-swap it (elastic
  re-mesh) or deprioritize its shard.  This is the *detection* half the
  paper's static planner cannot do — and the re-plan half is exactly what a
  dataflow planner buys: a new mapping for the surviving device set.
* **Step-retry driver** — ``run_resilient_step`` wraps the train step;
  device/transfer failures raise, the driver restores from the checkpoint
  manager and replays (deterministic data => bitwise-identical recovery
  modulo the lost steps).
"""
from __future__ import annotations

import collections
import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple


@dataclass
class HostState:
    host: int
    last_step: int = -1
    last_seen: float = 0.0
    step_times: Deque[float] = field(default_factory=lambda:
                                     collections.deque(maxlen=64))


class HeartbeatRegistry:
    def __init__(self, n_hosts: int, *, timeout_s: float = 60.0,
                 now: Optional[float] = None):
        # registration counts as the first "seen" instant: a host that
        # never beats at all (crashed during bring-up, silent from birth)
        # must still time out rather than look eternally healthy
        t0 = now if now is not None else time.time()
        self.hosts: Dict[int, HostState] = {
            h: HostState(h, last_seen=t0) for h in range(n_hosts)}
        self.timeout_s = timeout_s

    def beat(self, host: int, step: int, step_time_s: float,
             now: Optional[float] = None) -> None:
        st = self.hosts[host]
        st.last_step = step
        st.last_seen = now if now is not None else time.time()
        st.step_times.append(step_time_s)

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        return [h for h, st in self.hosts.items()
                if now - st.last_seen > self.timeout_s]

    def alive_hosts(self, now: Optional[float] = None) -> List[int]:
        dead = set(self.dead_hosts(now))
        return [h for h in self.hosts if h not in dead]


class StragglerTracker:
    """Flags hosts persistently slower than ``factor x median`` step time."""

    def __init__(self, registry: HeartbeatRegistry, *,
                 factor: float = 1.5, min_samples: int = 8):
        self.reg = registry
        self.factor = factor
        self.min_samples = min_samples

    def medians(self) -> Dict[int, float]:
        return {h: statistics.median(st.step_times)
                for h, st in self.reg.hosts.items()
                if len(st.step_times) >= self.min_samples}

    def stragglers(self) -> List[int]:
        med = self.medians()
        if len(med) < 2:
            return []
        global_p50 = statistics.median(med.values())
        return [h for h, m in med.items() if m > self.factor * global_p50]


@dataclass
class RecoveryEvent:
    step: int
    kind: str                 # "restart" | "straggler" | "rescale"
    detail: str


class ResilientDriver:
    """Wraps a step function with checkpoint-restore-replay semantics.

    Recovery is *strictly* replay-from-checkpoint: after a failed step the
    in-memory ``state`` may hold a partially-applied update, so the driver
    never retries against it — it restores from the checkpoint manager and
    replays.  ``registry``/``tracker`` wire in failure and straggler
    detection; detections are recorded as :class:`RecoveryEvent`\\ s
    (``"straggler"`` / ``"rescale"``) and, for dead hosts, forwarded to
    ``rescale_fn(dead, alive)`` so an elastic re-mesh can run.
    """

    def __init__(self, step_fn: Callable, manager, *, max_retries: int = 3,
                 registry: Optional[HeartbeatRegistry] = None,
                 tracker: Optional["StragglerTracker"] = None,
                 rescale_fn: Optional[Callable] = None,
                 host: int = 0,
                 step_time_scale: Optional[Callable[[int], float]] = None,
                 clock: Callable[[], float] = time.time):
        self.step_fn = step_fn
        self.manager = manager
        self.max_retries = max_retries
        self.registry = registry
        self.tracker = tracker
        self.rescale_fn = rescale_fn
        self.host = host
        self.step_time_scale = step_time_scale
        self.clock = clock
        self.events: List[RecoveryEvent] = []
        self._flagged_stragglers: set = set()
        self._known_dead: set = set()

    def run(self, state, batches, *, start_step: int, n_steps: int,
            restore_fn: Optional[Callable] = None,
            on_step: Optional[Callable] = None):
        """Run steps with retry-on-failure.

        ``restore_fn() -> (state, step)`` rebuilds state from the latest
        checkpoint.  It is *required* whenever retries are allowed: replaying
        against the in-memory state after a failure would re-run on a
        possibly-corrupt tree, so the driver refuses up front rather than
        silently doing the unsafe thing (pass ``max_retries=0`` to fail
        fast instead).  ``on_step(step, state, metrics, dt)`` is called
        after each completed step (logging hook)."""
        if restore_fn is None and self.max_retries > 0:
            raise ValueError(
                "ResilientDriver.run: restore_fn is required when "
                "max_retries > 0 — recovery replays from the last "
                "checkpoint, never from in-memory state after a failed "
                "step.  Pass restore_fn=, or max_retries=0 to fail fast.")
        step = start_step
        retries = 0
        metrics = None
        while step < start_step + n_steps:
            batch = batches(step)
            try:
                t0 = self.clock()
                state, metrics = self.step_fn(state, batch)
                dt = self.clock() - t0
                # checkpoint step := number of COMPLETED steps, so a restore
                # resumes at exactly that step index (no replayed double step)
                done = step + 1
                if self.manager is not None and self.manager.should_save(done):
                    self.manager.save(state, done)
            except Exception as e:             # device loss, preemption, ...
                retries += 1
                self.events.append(RecoveryEvent(step, "restart", repr(e)))
                if retries > self.max_retries:
                    raise
                state, step = restore_fn()
                continue
            step += 1
            retries = 0
            self._observe(step, dt)
            if on_step is not None:
                on_step(step, state, metrics, dt)
        return state, step, metrics

    # ------------------------------------------------- detection plumbing
    def _observe(self, step: int, dt: float) -> None:
        """Report this host's heartbeat and turn tracker/registry state
        into recovery events (each host flagged at most once)."""
        now = self.clock()
        if self.registry is not None:
            scale = (self.step_time_scale(step)
                     if self.step_time_scale is not None else 1.0)
            self.registry.beat(self.host, step, dt * scale, now=now)
        if self.tracker is not None:
            for h in self.tracker.stragglers():
                if h not in self._flagged_stragglers:
                    self._flagged_stragglers.add(h)
                    self.events.append(RecoveryEvent(
                        step, "straggler",
                        f"host {h} > {self.tracker.factor:g}x median "
                        f"step time"))
        if self.registry is not None:
            dead = [h for h in self.registry.dead_hosts(now=now)
                    if h not in self._known_dead]
            if dead:
                self._known_dead.update(dead)
                alive = self.registry.alive_hosts(now=now)
                self.events.append(RecoveryEvent(
                    step, "rescale",
                    f"hosts {sorted(dead)} dead; rescale to "
                    f"{len(alive)} hosts"))
                if self.rescale_fn is not None:
                    self.rescale_fn(sorted(dead), alive)
