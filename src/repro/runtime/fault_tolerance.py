"""Fault tolerance for 1000+-node operation.

Three mechanisms, each exercised by tests with injected failures:

* **Heartbeats / failure detection** — every host reports (step, wall-time)
  into a :class:`HeartbeatRegistry`; a host silent for ``timeout_s`` is
  declared dead.  In a real deployment the registry is a small etcd/GCS
  object; the interface is identical.
* **Straggler mitigation** — per-step wall-times feed a rolling p50/p95
  tracker; a host persistently above ``straggler_factor x p50`` is flagged,
  and the driver's policy (``on_straggler``) can hot-swap it (elastic
  re-mesh) or deprioritize its shard.  This is the *detection* half the
  paper's static planner cannot do — and the re-plan half is exactly what a
  dataflow planner buys: a new mapping for the surviving device set.
* **Step-retry driver** — ``run_resilient_step`` wraps the train step;
  device/transfer failures raise, the driver restores from the checkpoint
  manager and replays (deterministic data => bitwise-identical recovery
  modulo the lost steps).
"""
from __future__ import annotations

import collections
import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple


@dataclass
class HostState:
    host: int
    last_step: int = -1
    last_seen: float = 0.0
    step_times: Deque[float] = field(default_factory=lambda:
                                     collections.deque(maxlen=64))


class HeartbeatRegistry:
    def __init__(self, n_hosts: int, *, timeout_s: float = 60.0):
        self.hosts: Dict[int, HostState] = {
            h: HostState(h) for h in range(n_hosts)}
        self.timeout_s = timeout_s

    def beat(self, host: int, step: int, step_time_s: float,
             now: Optional[float] = None) -> None:
        st = self.hosts[host]
        st.last_step = step
        st.last_seen = now if now is not None else time.time()
        st.step_times.append(step_time_s)

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        return [h for h, st in self.hosts.items()
                if st.last_seen and now - st.last_seen > self.timeout_s]

    def alive_hosts(self, now: Optional[float] = None) -> List[int]:
        dead = set(self.dead_hosts(now))
        return [h for h in self.hosts if h not in dead]


class StragglerTracker:
    """Flags hosts persistently slower than ``factor x median`` step time."""

    def __init__(self, registry: HeartbeatRegistry, *,
                 factor: float = 1.5, min_samples: int = 8):
        self.reg = registry
        self.factor = factor
        self.min_samples = min_samples

    def medians(self) -> Dict[int, float]:
        return {h: statistics.median(st.step_times)
                for h, st in self.reg.hosts.items()
                if len(st.step_times) >= self.min_samples}

    def stragglers(self) -> List[int]:
        med = self.medians()
        if len(med) < 2:
            return []
        global_p50 = statistics.median(med.values())
        return [h for h, m in med.items() if m > self.factor * global_p50]


@dataclass
class RecoveryEvent:
    step: int
    kind: str                 # "restart" | "straggler" | "rescale"
    detail: str


class ResilientDriver:
    """Wraps a step function with checkpoint-restore-replay semantics."""

    def __init__(self, step_fn: Callable, manager, *, max_retries: int = 3):
        self.step_fn = step_fn
        self.manager = manager
        self.max_retries = max_retries
        self.events: List[RecoveryEvent] = []

    def run(self, state, batches, *, start_step: int, n_steps: int,
            restore_fn: Optional[Callable] = None):
        """Run steps with retry-on-failure.  ``restore_fn(step) -> state``
        rebuilds state from the latest checkpoint (injected in tests)."""
        step = start_step
        retries = 0
        metrics = None
        while step < start_step + n_steps:
            batch = batches(step)
            try:
                state, metrics = self.step_fn(state, batch)
                # checkpoint step := number of COMPLETED steps, so a restore
                # resumes at exactly that step index (no replayed double step)
                done = step + 1
                if self.manager is not None and self.manager.should_save(done):
                    self.manager.save(state, done)
                step += 1
                retries = 0
            except Exception as e:             # device loss, preemption, ...
                retries += 1
                self.events.append(RecoveryEvent(step, "restart", repr(e)))
                if retries > self.max_retries:
                    raise
                if restore_fn is not None:
                    state, step = restore_fn()
        return state, step, metrics
