"""Elastic re-planning for degraded meshes (DESIGN_FAULTS.md).

The TileLoom thesis, applied to failure: a mapping is a compiled decision
over an explicit hardware representation, so losing a core or a link means
the *hardware changed* — the answer is a new plan for the surviving fabric,
found fast.  This module wires failure detection
(:class:`~repro.runtime.fault_tolerance.HeartbeatRegistry` /
:class:`StragglerTracker`) to the planner through a **degradation ladder**
with an explicit re-plan latency budget:

1. **cache hit** — the degraded fabric has its own plan-cache key
   (``HardwareModel.with_faults`` participates in ``df_text()``), so a
   pre-warmed fault pool (``python -m repro.plancache warm --faults``)
   answers a single-core failure with zero search;
2. **warm-started bounded search** — on a miss, candidate block shapes are
   re-ordered around the nearest *healthy-mesh* cached plan of the same
   template (the degraded digest has no neighbors yet), then searched
   under a trimmed budget on the degraded model — which enumerates only
   mappings that route around the dead cores;
3. **rectangular-submesh fallback** — guaranteed feasible: drop the mesh
   rows/columns containing dead cores along the axis that keeps the most
   cores and plan the clean smaller mesh.  The submesh plan also serves as
   a quality floor: whichever of rung 2/3 simulates faster is kept (one
   dead core on an 8x8 costs ~8/7 on the submesh, far better than the
   hole-avoiding full-mesh mappings).

Every re-plan emits ``replan_total{cause,rung}`` and ``replan_seconds``
through the PR 6 observability layer.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.hw import HardwareModel, Interconnect, SpatialDim, _ring_map
from repro.core.planner import (PlanResult, SearchBudget, effective_budget,
                                plan_kernel_multi)
from repro.core.program import TileProgram
from repro.obs import context, flightrec, metrics, trace

RUNGS = ("cache_hit", "warm_search", "bounded_search", "submesh_fallback")

#: Default trimmed budget for the in-incident bounded search (rung 2/3).
#: Deliberately smaller than the AOT warm budget: an online re-plan trades
#: a little plan quality for seconds of downtime.
REPLAN_BUDGET = SearchBudget(top_k=3, max_mappings=64,
                             max_plans_per_mapping=24, max_candidates=2000,
                             max_programs=8)


@dataclass
class ReplanOutcome:
    """One completed trip down the degradation ladder."""
    cause: str                  # core_kill | link_slow | straggler | manual
    rung: str                   # member of RUNGS: where the plan came from
    result: PlanResult
    hw: HardwareModel           # the model the chosen plan targets
    seconds: float
    within_budget: bool
    log: List[str] = field(default_factory=list)

    @property
    def plan(self):
        return self.result.best.plan


# --------------------------------------------------------------------------
# Rectangular-submesh fallback
# --------------------------------------------------------------------------
def _shrink_axis(hw: HardwareModel, axis: str, new_size: int,
                 dropped: Sequence[int]) -> HardwareModel:
    """A logical submesh of ``hw`` with ``axis`` shrunk to ``new_size``
    (the planes listed in ``dropped`` removed and survivors renumbered).

    Ring interconnects along the shrunk axis are rebuilt with the new
    modulus; the DRAM channel map is kept and evaluated at the renumbered
    coordinates (a documented approximation: survivors that change
    channel groups keep their old attribution).  The overlay is cleared —
    the submesh is healthy by construction.
    """
    dims = tuple(SpatialDim(d.name, new_size) if d.name == axis else d
                 for d in hw.spatial_dims)
    mesh = tuple((n, new_size if n == axis else s)
                 for n, s in hw.mesh_dims)
    ics = []
    for ic in hw.interconnects:
        if ic.axis(hw.core.scaleout) == axis:
            moved = next(e for e in ic.map.exprs
                         if not (e.coeffs == ((axis, 1),) and e.const == 0
                                 and e.mod is None and e.floordiv is None)
                         ) if ic.map.exprs else None
            stride = moved.const if moved is not None else 1
            ics.append(Interconnect(ic.name, ic.src, ic.dst,
                                    _ring_map(list(mesh), axis, stride),
                                    ic.bandwidth_gbps))
        else:
            ics.append(ic)
    note = (f"submesh of {hw.name}: {axis} planes {sorted(dropped)} "
            f"dropped ({new_size} survive)")
    return dataclasses.replace(
        hw, name=f"{hw.name}_sub_{axis}{new_size}", spatial_dims=dims,
        interconnects=tuple(ics), disabled_cores=(), degraded_links=(),
        notes=(hw.notes + "; " if hw.notes else "") + note)


def best_submesh(hw: HardwareModel) -> HardwareModel:
    """The largest healthy logical submesh of a degraded mesh.

    Each disabled core must sit on a dropped plane of *some* axis; the
    search assigns every fault to an axis and keeps the assignment whose
    combined cut preserves the most cores.  Single-axis assignments (all
    faults cut along one axis — the historical behavior) are tried first,
    so single-core failures and any case where one axis is optimal stay
    bit-identical (golden-gated); mixed assignments win only strictly.
    Two faults at (1, 2) and (5, 6) on an 8x8 used to cost two rows
    (48 cores left); dropping one row *and* one column keeps 7x7 = 49.

    Guaranteed feasible when any assignment leaves every axis at least
    one plane; the combo enumeration is capped (pure assignments only)
    for pathological fault counts.
    """
    if not hw.disabled_cores:
        return hw
    mesh = hw.mesh_dims
    faults = list(hw.disabled_cores)
    n_axes = len(mesh)

    def cut_of(assign: Tuple[int, ...]):
        """(remaining cores, per-axis dropped-plane sets) or None when the
        assignment empties an axis."""
        dropped: List[set] = [set() for _ in mesh]
        for f, a in zip(faults, assign):
            dropped[a].add(f[a])
        remaining = 1
        for i, (_, size) in enumerate(mesh):
            keep = size - len(dropped[i])
            if keep < 1:
                return None
            remaining *= keep
        return remaining, dropped

    candidates: List[Tuple[int, ...]] = [(i,) * len(faults)
                                         for i in range(n_axes)]
    if n_axes > 1 and len(faults) > 1 and n_axes ** len(faults) <= 256:
        candidates += [a for a in
                       itertools.product(range(n_axes), repeat=len(faults))
                       if len(set(a)) > 1]
    best: Optional[Tuple[int, List[set]]] = None
    for assign in candidates:
        cut = cut_of(assign)
        if cut is not None and (best is None or cut[0] > best[0]):
            best = cut
    if best is None:
        raise RuntimeError(f"no healthy submesh of {hw.name}: faults cover "
                           f"every plane of every axis")
    sub = hw
    for i, (axis, size) in enumerate(mesh):
        bad = sorted(best[1][i])
        if bad:
            sub = _shrink_axis(sub, axis, size - len(bad), bad)
    return sub


# --------------------------------------------------------------------------
# The degradation ladder
# --------------------------------------------------------------------------
def plan_degraded(programs: Sequence[TileProgram], hw: HardwareModel, *,
                  healthy_hw: Optional[HardwareModel] = None,
                  cache: Optional[Any] = None,
                  budget: Optional[SearchBudget] = None,
                  latency_budget_s: Optional[float] = 30.0,
                  cause: str = "manual",
                  compare_submesh: bool = True) -> ReplanOutcome:
    """Find the plan to run on the degraded fabric ``hw``, fast.

    Walks the ladder described in the module docstring.  ``healthy_hw``
    (default: ``hw`` sans overlay is unavailable, so pass the original
    model) seeds the warm-start ordering; ``latency_budget_s`` bounds the
    in-incident search — once exceeded, remaining search rungs are skipped
    in favor of the guaranteed submesh fallback (None = no deadline).
    The chosen result is published to ``cache`` under the *degraded* key,
    so the next identical failure is a rung-1 hit.
    """
    if not hw.is_degraded:
        raise ValueError("plan_degraded requires a fault overlay; plan the "
                         "healthy model with plan_kernel_multi")
    t0 = time.perf_counter()
    budget = effective_budget(budget if budget is not None
                              else replace(REPLAN_BUDGET))
    programs = list(programs)
    log: List[str] = []

    def _finish(rung: str, result: PlanResult,
                target: HardwareModel) -> ReplanOutcome:
        secs = time.perf_counter() - t0
        within = latency_budget_s is None or secs <= latency_budget_s
        metrics.inc("replan_total", cause=cause, rung=rung)
        metrics.observe("replan_seconds", secs, cause=cause)
        if not within:
            metrics.inc("replan_budget_exceeded_total", cause=cause)
        flightrec.record("replan", cause=cause, rung=rung, seconds=secs,
                         within_budget=within, hw=target.name, log=log)
        return ReplanOutcome(cause=cause, rung=rung, result=result,
                             hw=target, seconds=secs, within_budget=within,
                             log=log)

    # correlate("replan") reuses an enclosing incident/plan ID, so a
    # ladder trip nested under a fault event stays on the incident's
    # timeline; a direct plan_degraded call gets its own replan-* ID
    with context.correlate("replan"), \
         trace.span("replan.ladder", cat="replan", cause=cause,
                    hw=hw.name, n_faults=len(hw.disabled_cores)
                    + len(hw.degraded_links)):
        # ---- rung 1: exact degraded-key cache hit -------------------------
        if cache is not None:
            hit = cache.get_result(programs, hw, budget, profile=True,
                                   spatial_reuse=True, temporal_reuse=True)
            if hit is not None:
                log.append("rung 1: degraded-key cache hit (zero search)")
                return _finish("cache_hit", hit, hw)

        # ---- rung 2: warm-start ordering from the healthy mesh ------------
        ordered = programs
        warmed = False
        if cache is not None and programs:
            from repro.plancache import keying, warmstart
            seed_hw = healthy_hw if healthy_hw is not None else hw
            before = cache.store.stats.warm_starts
            ordered = warmstart.warm_order_from_store(
                cache.store, keying.template_signature(programs[0]),
                keying.hw_digest(seed_hw), keying.shape_vector(programs[0]),
                programs)
            warmed = cache.store.stats.warm_starts > before
            if warmed:
                log.append("rung 2: warm-start ordering from healthy-mesh "
                           "neighbor")

        # ---- rung 2/3: bounded search on the degraded model ---------------
        searched: Optional[PlanResult] = None
        deadline_hit = (latency_budget_s is not None
                        and time.perf_counter() - t0 > 0.5 * latency_budget_s)
        if deadline_hit:
            log.append("latency budget half-spent before search; skipping "
                       "to submesh fallback")
        else:
            try:
                searched = plan_kernel_multi(ordered, hw, budget=budget,
                                             profile=True)
                log.append(f"rung {'2' if warmed else '3'}: degraded-mesh "
                           f"search best {searched.best.final_s * 1e6:.1f}us")
            except (RuntimeError, ValueError) as e:
                log.append(f"degraded-mesh search infeasible: {e}")

        # ---- rung 4: rectangular-submesh fallback / quality floor ---------
        sub_result: Optional[PlanResult] = None
        sub_hw: Optional[HardwareModel] = None
        need_sub = searched is None or (compare_submesh
                                        and bool(hw.disabled_cores))
        if need_sub and hw.disabled_cores:
            sub_hw = best_submesh(hw)
            sub_result = plan_kernel_multi(programs, sub_hw, budget=budget,
                                           profile=True)
            log.append(f"rung 4: submesh {sub_hw.name} best "
                       f"{sub_result.best.final_s * 1e6:.1f}us")
        if searched is None and sub_result is None:
            raise RuntimeError(
                f"no feasible plan on {hw.name} (degraded search failed and "
                f"no disabled cores to cut a submesh around)")

        if sub_result is not None and (
                searched is None
                or sub_result.best.final_s < searched.best.final_s):
            rung, result, target = "submesh_fallback", sub_result, sub_hw
        else:
            rung = "warm_search" if warmed else "bounded_search"
            result, target = searched, hw

        if cache is not None:
            # published under the degraded key: the next identical failure
            # (or a pre-warmed pool) answers at rung 1 with zero search
            cache.put_result(programs, hw, budget, result, profile=True,
                             spatial_reuse=True, temporal_reuse=True)
        return _finish(rung, result, target)


# --------------------------------------------------------------------------
# Detection -> re-plan orchestration
# --------------------------------------------------------------------------
class ReplanOrchestrator:
    """Polls failure detection and walks the ladder when the fabric shrinks.

    ``host_cores`` maps heartbeat host ids to the mesh cores they drive
    (coords in ``hw.core.scaleout`` order).  A dead host kills its cores; a
    flagged straggler is treated the same way (hot-swap policy: route work
    off the slow host, reclaim on the next full re-plan).  Link faults come
    in through :meth:`degrade_links` (switch counters / SDN telemetry in a
    real deployment, :mod:`repro.runtime.faults` schedules in tests).
    """

    def __init__(self, hw: HardwareModel, programs: Sequence[TileProgram], *,
                 registry=None, tracker=None,
                 host_cores: Optional[Mapping[int, Sequence[Tuple[int, ...]]]]
                 = None,
                 cache: Optional[Any] = None,
                 budget: Optional[SearchBudget] = None,
                 latency_budget_s: Optional[float] = 30.0,
                 service: Optional[Any] = None,
                 tenancy: Optional[Any] = None) -> None:
        self.healthy_hw = hw
        self.current_hw = hw
        self.programs = list(programs)
        self.registry = registry
        self.tracker = tracker
        self.host_cores = dict(host_cores or {})
        self.cache = cache
        self.budget = budget
        self.latency_budget_s = latency_budget_s
        # a subscribed PlanService: fault events invalidate its breaker /
        # search-time state so degraded-key requests walk a fresh ladder
        self.service = service
        # a multi-tenant runtime (repro.tenancy.TenantRuntime): fault
        # events route through its contained per-partition ladder instead
        # of re-planning the whole fabric, and the orchestrator's methods
        # return its ContainedReplan events
        self.tenancy = tenancy
        self.outcomes: List[ReplanOutcome] = []
        self._handled_hosts: set = set()

    # ------------------------------------------------------------ faults
    def kill_cores(self, cores: Sequence[Tuple[int, ...]],
                   cause: str = "core_kill") -> Any:
        # one incident ID spans the fault event and every nested re-plan
        # (the tenancy path records its own fault/containment events)
        with context.correlate("incident"):
            if self.tenancy is not None:
                ev = None
                for c in cores:
                    ev = self.tenancy.kill_core(c)
                self.current_hw = self.tenancy.hw
                return ev
            flightrec.record("fault", cause=cause, cores=list(cores))
            self.current_hw = self.current_hw.with_faults(
                disabled_cores=cores)
            return self._replan(cause)

    def degrade_links(self, links: Sequence[Tuple[str, float]],
                      cause: str = "link_slow") -> Any:
        with context.correlate("incident"):
            if self.tenancy is not None:
                ev = None
                for name, factor in links:
                    ev = self.tenancy.slow_link(name, factor)
                self.current_hw = self.tenancy.hw
                return ev
            flightrec.record("fault", cause=cause, links=list(links))
            self.current_hw = self.current_hw.with_faults(
                degraded_links=links)
            return self._replan(cause)

    def poll(self, now: Optional[float] = None) -> Optional[ReplanOutcome]:
        """One detection sweep: declare dead/straggling hosts' cores
        disabled and re-plan.  Returns the outcome when the fabric changed,
        None when everything is healthy (no planner work at all)."""
        dead: List[Tuple[int, str]] = []
        if self.registry is not None:
            dead += [(h, "core_kill") for h in self.registry.dead_hosts(now)]
        if self.tracker is not None:
            dead += [(h, "straggler") for h in self.tracker.stragglers()]
        cores: List[Tuple[int, ...]] = []
        cause = "core_kill"
        for host, why in dead:
            if host in self._handled_hosts:
                continue
            self._handled_hosts.add(host)
            mapped = self.host_cores.get(host, ())
            if mapped:
                cores.extend(tuple(c) for c in mapped)
                cause = why
        new = [c for c in cores
               if tuple(c) not in self.current_hw.disabled_core_set()]
        if not new:
            return None
        return self.kill_cores(new, cause=cause)

    # ------------------------------------------------------------ ladder
    def _replan(self, cause: str) -> ReplanOutcome:
        out = plan_degraded(self.programs, self.current_hw,
                            healthy_hw=self.healthy_hw, cache=self.cache,
                            budget=self.budget,
                            latency_budget_s=self.latency_budget_s,
                            cause=cause)
        self.outcomes.append(out)
        if self.service is not None:
            self.service.note_fault(out)
        return out
