"""Deterministic, seeded fault injection (DESIGN_FAULTS.md).

Every recovery path in this repo is exercised by *injected* faults, never by
prose: a :class:`FaultSchedule` is a reproducible, seeded list of
:class:`FaultSpec` events that tests, the benchmark harness
(``REPRO_FAULTS``), and the launchers feed into the simulator and the
runtime drivers.

Fault kinds:

* ``core_kill``       — permanently disable one core; materialized as a
  :meth:`HardwareModel.with_faults` overlay, so the planner routes around it
  (degraded-mesh planning) and the simulators mask it out.
* ``link_slow``       — scale one interconnect's per-link bandwidth.
* ``host_straggler``  — multiply one host's step wall-times (feeds
  :class:`~repro.runtime.fault_tolerance.StragglerTracker` detection).
* ``worker_crash``    — hard-exit one search-pool worker (armed through
  ``repro.parallel.search_exec.CRASH_ENV``; exercises the pool's
  retry-then-inline hardening).

The module imports no accelerator runtime — it is safe to use from the
planner, the benchmark harness, and worker processes alike.
"""
from __future__ import annotations

import os
import random
import tempfile
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

FAULTS_ENV = "REPRO_FAULTS"

KINDS = ("core_kill", "link_slow", "host_straggler", "worker_crash")


@dataclass(frozen=True)
class FaultSpec:
    """One injected fault.  ``step`` is the (0-based) step index at which
    the fault takes effect; hardware faults are permanent from that step."""
    kind: str
    step: int = 0
    core: Optional[Tuple[int, ...]] = None     # core_kill
    link: str = ""                             # link_slow
    factor: float = 1.0                        # link_slow / host_straggler
    host: int = -1                             # host_straggler

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"kinds: {KINDS}")

    def describe(self) -> str:
        if self.kind == "core_kill":
            return f"core_kill{self.core}@{self.step}"
        if self.kind == "link_slow":
            return f"link_slow:{self.link}x{self.factor:g}@{self.step}"
        if self.kind == "host_straggler":
            return f"straggler:host{self.host}x{self.factor:g}@{self.step}"
        return f"worker_crash@{self.step}"


class FaultSchedule:
    """An ordered, deterministic fault timeline."""

    def __init__(self, faults: Sequence[FaultSpec] = ()) -> None:
        self.faults: Tuple[FaultSpec, ...] = tuple(
            sorted(faults, key=lambda f: (f.step, KINDS.index(f.kind),
                                          f.describe())))

    def __len__(self) -> int:
        return len(self.faults)

    def __iter__(self):
        return iter(self.faults)

    def describe(self) -> str:
        return "; ".join(f.describe() for f in self.faults) or "(no faults)"

    # ------------------------------------------------------------ queries
    def at(self, step: int) -> List[FaultSpec]:
        return [f for f in self.faults if f.step == step]

    def active(self, step: Optional[int] = None) -> List[FaultSpec]:
        """Faults in effect at ``step`` (None = all of them)."""
        if step is None:
            return list(self.faults)
        return [f for f in self.faults if f.step <= step]

    def degraded_hw(self, hw, step: Optional[int] = None):
        """``hw`` with every hardware fault active at ``step`` applied
        (:meth:`HardwareModel.with_faults`); the unchanged model when none
        are — the fault-free path stays byte-identical.

        A schedule describes faults on *a* fabric, but callers (the
        benchmark sweeps especially) apply it to many mesh shapes — faults
        that do not exist on ``hw`` (core coords out of range, unknown
        interconnect names) are skipped rather than raised, so one
        ``REPRO_FAULTS`` setting can degrade every mesh it fits.
        """
        dims = [hw.dim(d).size for d in hw.core.scaleout]
        ic_names = {ic.name for ic in hw.interconnects}
        cores = [f.core for f in self.active(step)
                 if f.kind == "core_kill" and f.core is not None
                 and len(f.core) == len(dims)
                 and all(0 <= v < s for v, s in zip(f.core, dims))]
        links = [(f.link, f.factor) for f in self.active(step)
                 if f.kind == "link_slow" and f.link in ic_names]
        if len({tuple(c) for c in cores} | hw.disabled_core_set()) >= hw.n_cores:
            cores = []  # would kill the whole fabric — nothing left to plan on
        if not cores and not links:
            return hw
        return hw.with_faults(disabled_cores=cores, degraded_links=links)

    def straggler_factor(self, host: int, step: int) -> float:
        """Multiplier on ``host``'s step wall-time at ``step`` (1.0 =
        healthy) — tests and the launch harness scale simulated step times
        by this to drive straggler detection."""
        out = 1.0
        for f in self.active(step):
            if f.kind == "host_straggler" and f.host == host:
                out *= f.factor
        return out

    def worker_crashes(self, step: Optional[int] = None) -> int:
        return sum(1 for f in self.active(step) if f.kind == "worker_crash")

    # ----------------------------------------------------- worker crashes
    def arm_worker_crash(self, directory: Optional[str] = None) -> str:
        """Arm one search-pool worker crash: create the one-shot marker
        file and export it via ``search_exec.CRASH_ENV``.  Returns the
        marker path; call :meth:`disarm_worker_crash` (or let the crash
        consume the marker) when done."""
        from repro.parallel.search_exec import CRASH_ENV
        fd, marker = tempfile.mkstemp(prefix="crash_", dir=directory)
        os.close(fd)
        os.environ[CRASH_ENV] = marker
        return marker

    @staticmethod
    def disarm_worker_crash() -> None:
        from repro.parallel.search_exec import CRASH_ENV
        marker = os.environ.pop(CRASH_ENV, "")
        if marker:
            try:
                os.remove(marker)
            except OSError:
                pass

    # ------------------------------------------------------------ seeding
    @classmethod
    def seeded(cls, seed: int, *, hw=None, n_steps: int = 1,
               n_hosts: int = 0, n_faults: int = 1,
               kinds: Optional[Sequence[str]] = None) -> "FaultSchedule":
        """Draw a reproducible schedule: same (seed, hw shape, args) =>
        same faults, on any machine.  ``kinds`` defaults to every kind the
        inputs support (core/link faults need ``hw``, stragglers need
        ``n_hosts``)."""
        rng = random.Random(seed)
        allowed = list(kinds) if kinds is not None else [
            k for k in KINDS
            if (k == "worker_crash"
                or (k == "host_straggler" and n_hosts > 0)
                or (k in ("core_kill", "link_slow") and hw is not None))]
        for k in allowed:
            if k not in KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        if not allowed:
            raise ValueError("no fault kind is drawable from the given "
                             "inputs (pass hw= and/or n_hosts=)")
        faults: List[FaultSpec] = []
        killed = set()
        for _ in range(n_faults):
            kind = rng.choice(allowed)
            step = rng.randrange(max(1, n_steps))
            if kind == "core_kill":
                sizes = [s for _, s in hw.mesh_dims]
                core = tuple(rng.randrange(s) for s in sizes)
                if core in killed or len(killed) + 1 >= hw.n_cores:
                    continue
                killed.add(core)
                faults.append(FaultSpec("core_kill", step, core=core))
            elif kind == "link_slow":
                ics = [ic.name for ic in hw.interconnects]
                if not ics:
                    continue
                faults.append(FaultSpec(
                    "link_slow", step, link=rng.choice(ics),
                    factor=round(rng.uniform(0.25, 0.75), 2)))
            elif kind == "host_straggler":
                faults.append(FaultSpec(
                    "host_straggler", step, host=rng.randrange(n_hosts),
                    factor=round(rng.uniform(2.0, 4.0), 2)))
            else:
                faults.append(FaultSpec("worker_crash", step))
        return cls(faults)


# ------------------------------------------------------------- env syntax
def parse_faults(text: str) -> FaultSchedule:
    """Parse the ``REPRO_FAULTS`` syntax: ``;``-separated items, each
    optionally suffixed ``@step`` (default step 0):

    * ``core:R,C``          — kill the core at mesh coords (R, C, ...)
    * ``link:NAME:FACTOR``  — slow interconnect NAME to FACTOR of nominal
    * ``straggler:HOST[:FACTOR]`` — host HOST runs FACTOR (default 3) slower
    * ``crash``             — crash one search-pool worker

    Example: ``REPRO_FAULTS="core:3,5;link:noc_h:0.5@2"``.

    Rejected with an actionable error (both used to be accepted and
    either failed much later or silently composed):

    * link factors outside ``(0, 1]`` — a factor of 0 models a *cut*
      link, which the bandwidth model cannot represent (use ``core:``
      kills to remove capacity); > 1 is a speed-up, not a fault;
    * duplicate items — killing the same core twice, or repeating any
      item verbatim, is almost always a typo'd schedule; link
      degradations compose *multiplicatively*, so a pasted duplicate
      would silently halve the bandwidth again.
    """
    faults: List[FaultSpec] = []
    seen_cores: dict = {}
    seen_items: dict = {}
    for raw in text.split(";"):
        item = raw.strip()
        if not item:
            continue
        first = seen_items.setdefault(item, raw)
        if first is not raw:
            raise ValueError(
                f"duplicate fault item {raw!r}: already specified; link "
                f"factors compose multiplicatively, so repeating an item "
                f"changes the schedule — drop the duplicate or change its "
                f"@step")
        step = 0
        if "@" in item:
            item, _, s = item.rpartition("@")
            step = int(s)
        parts = item.split(":")
        tag = parts[0].strip().lower()
        try:
            if tag == "core":
                core = tuple(int(v) for v in parts[1].split(","))
                prev = seen_cores.setdefault(core, raw)
                if prev is not raw:
                    raise ValueError(
                        f"core {core} already killed by {prev!r}; a core "
                        f"can only die once — remove one of the items")
                faults.append(FaultSpec("core_kill", step, core=core))
            elif tag == "link":
                factor = float(parts[2])
                if not 0.0 < factor <= 1.0:
                    raise ValueError(
                        f"link factor {factor:g} must be in (0, 1] — "
                        f"1.0 is nominal bandwidth, 0 would be a cut "
                        f"link (kill the adjacent cores instead)")
                faults.append(FaultSpec("link_slow", step, link=parts[1],
                                        factor=factor))
            elif tag == "straggler":
                factor = float(parts[2]) if len(parts) > 2 else 3.0
                faults.append(FaultSpec("host_straggler", step,
                                        host=int(parts[1]), factor=factor))
            elif tag == "crash":
                faults.append(FaultSpec("worker_crash", step))
            else:
                raise ValueError(f"unknown fault item {raw!r}")
        except (IndexError, ValueError) as e:
            raise ValueError(f"bad fault item {raw!r}: {e}") from e
    return FaultSchedule(faults)


def env_schedule() -> Optional[FaultSchedule]:
    """The schedule from ``REPRO_FAULTS``, or None when unset/empty."""
    text = os.environ.get(FAULTS_ENV, "").strip()
    return parse_faults(text) if text else None


def apply_env_faults(hw):
    """``hw`` degraded by every hardware fault in ``REPRO_FAULTS`` (any
    step), byte-identical pass-through when the variable is unset — the
    benchmark harness's injection point."""
    sched = env_schedule()
    return sched.degraded_hw(hw, None) if sched is not None else hw
