"""Optimizers (pure JAX — no optax offline): AdamW and Adafactor, with
warmup+cosine schedule, global-norm clipping, and configurable state dtype
(bf16 moments for the 405B-class configs so optimizer state fits HBM —
see configs/llama3_405b.py).

Optimizer state mirrors the param tree, so the same logical-axis sharding
rules apply (ZeRO-style sharding falls out of the ShardingPlan mapping the
'layers'/'embed'/'ffn' axes — no separate partitioner needed).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

Params = Any


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Params
    nu: Params


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Params          # row second-moment factors
    vc: Params          # col second-moment factors
    v: Params           # full second moment for <2D params


def lr_schedule(tcfg: TrainConfig) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        warm = jnp.minimum(1.0, (step + 1) / max(1, tcfg.warmup_steps))
        prog = jnp.clip((step - tcfg.warmup_steps)
                        / max(1, tcfg.total_steps - tcfg.warmup_steps), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return tcfg.learning_rate * warm * (0.1 + 0.9 * cos)
    return lr


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gnorm


# ------------------------------------------------------------------ AdamW
def adamw_init(params: Params, tcfg: TrainConfig) -> AdamWState:
    dt = jnp.dtype(tcfg.opt_state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def adamw_update(grads: Params, state: AdamWState, params: Params,
                 tcfg: TrainConfig) -> Tuple[Params, AdamWState, Dict]:
    lr = lr_schedule(tcfg)(state.step)
    grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
    b1, b2 = tcfg.b1, tcfg.b2
    step = state.step + 1
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        update = (m32 / c1) / (jnp.sqrt(v32 / c2) + tcfg.eps)
        new_p = p.astype(jnp.float32) - lr * (update
                                              + tcfg.weight_decay
                                              * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), \
        {"grad_norm": gnorm, "lr": lr}


# --------------------------------------------------------------- Adafactor
def adafactor_init(params: Params, tcfg: TrainConfig) -> AdafactorState:
    dt = jnp.dtype(tcfg.opt_state_dtype)

    def vr(p):
        return (jnp.zeros(p.shape[:-1], dt) if p.ndim >= 2
                else jnp.zeros((), dt))

    def vc(p):
        return (jnp.zeros(p.shape[:-2] + p.shape[-1:], dt) if p.ndim >= 2
                else jnp.zeros((), dt))

    def v(p):
        return jnp.zeros(p.shape, dt) if p.ndim < 2 else jnp.zeros((), dt)

    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          vr=jax.tree.map(vr, params),
                          vc=jax.tree.map(vc, params),
                          v=jax.tree.map(v, params))


def adafactor_update(grads: Params, state: AdafactorState, params: Params,
                     tcfg: TrainConfig) -> Tuple[Params, AdafactorState, Dict]:
    lr = lr_schedule(tcfg)(state.step)
    grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
    step = state.step + 1
    b2 = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(g, vr, vc, v, p):
        g32 = jnp.square(g.astype(jnp.float32)) + 1e-30
        if p.ndim >= 2:
            vr32 = vr.astype(jnp.float32) * b2 + jnp.mean(g32, -1) * (1 - b2)
            vc32 = vc.astype(jnp.float32) * b2 + jnp.mean(g32, -2) * (1 - b2)
            denom = (vr32[..., None] * vc32[..., None, :]
                     / (jnp.mean(vr32, -1)[..., None, None] + 1e-30))
            update = g.astype(jnp.float32) * jax.lax.rsqrt(denom + 1e-30)
            v32 = v
        else:
            v32 = v.astype(jnp.float32) * b2 + g32 * (1 - b2)
            update = g.astype(jnp.float32) * jax.lax.rsqrt(v32 + 1e-30)
            vr32, vc32 = vr, vc
        update = update / jnp.maximum(1.0, jnp.sqrt(jnp.mean(
            jnp.square(update))))
        new_p = (p.astype(jnp.float32) - lr * update
                 - lr * tcfg.weight_decay * p.astype(jnp.float32))
        cast = lambda a, ref: a.astype(ref.dtype) if hasattr(a, "astype") else a
        return (new_p.astype(p.dtype), cast(vr32, vr), cast(vc32, vc),
                cast(v32, v))

    out = jax.tree.map(upd, grads, state.vr, state.vc, state.v, params)
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), AdafactorState(step, pick(1), pick(2), pick(3)), \
        {"grad_norm": gnorm, "lr": lr}


# ------------------------------------------------------------------ facade
def opt_init(params: Params, tcfg: TrainConfig):
    return (adafactor_init if tcfg.optimizer == "adafactor"
            else adamw_init)(params, tcfg)


def opt_update(grads: Params, state, params: Params, tcfg: TrainConfig):
    return (adafactor_update if tcfg.optimizer == "adafactor"
            else adamw_update)(grads, state, params, tcfg)


def opt_state_axes(param_axes: Params, tcfg: TrainConfig):
    """Logical axes for the optimizer state (mirrors param axes)."""
    if tcfg.optimizer == "adafactor":
        drop_last = jax.tree.map(
            lambda a: a[:-1] if len(a) >= 2 else (),
            param_axes, is_leaf=lambda x: isinstance(x, tuple))
        drop_row = jax.tree.map(
            lambda a: a[:-2] + a[-1:] if len(a) >= 2 else (),
            param_axes, is_leaf=lambda x: isinstance(x, tuple))
        scalars = jax.tree.map(
            lambda a: a if len(a) < 2 else (),
            param_axes, is_leaf=lambda x: isinstance(x, tuple))
        return AdafactorState(step=(), vr=drop_last, vc=drop_row, v=scalars)
    return AdamWState(step=(), mu=param_axes, nu=param_axes)
