"""Serve-step builder: batched single-token decode with a sharded KV cache
(or recurrent state), jit-compiled with plan-derived shardings and cache
donation — the object the ``decode_*`` dry-run cells lower.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.api import ModelAPI
from repro.parallel.sharding import ShardingPlan, use_plan

Params = Any


def make_serve_step(api: ModelAPI, plan: Optional[ShardingPlan] = None,
                    mesh: Optional[Mesh] = None) -> Callable:
    """Returns ``serve_step(params, tokens, cache) -> (logits, cache)``."""

    def serve_step(params, tokens, cache):
        return api.decode_step(params, tokens, cache)

    if plan is not None and mesh is not None:
        def planned(params, tokens, cache):
            with use_plan(plan, mesh):
                return serve_step(params, tokens, cache)
        return planned
    return serve_step


def cache_shardings(api: ModelAPI, cache_abstract: Dict[str, Any],
                    plan: ShardingPlan, mesh: Mesh) -> Dict[str, Any]:
    axes = api.cache_axes()

    def one(ax, shaped):
        if len(ax) != len(shaped.shape):
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, plan.spec(ax, tuple(shaped.shape), mesh))

    return jax.tree.map(one, axes, cache_abstract,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


def param_shardings(api: ModelAPI, plan: ShardingPlan, mesh: Mesh):
    axes = api.param_axes()
    shapes = api.abstract_params()

    def one(ax, shaped):
        return NamedSharding(mesh, plan.spec(ax, tuple(shaped.shape), mesh))

    return jax.tree.map(one, axes, shapes,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


def jit_serve_step(api: ModelAPI, plan: ShardingPlan, mesh: Mesh,
                   cache_abstract: Dict[str, Any],
                   tokens_shape: Optional[Tuple[int, int]] = None):
    step = make_serve_step(api, plan, mesh)
    p_sh = param_shardings(api, plan, mesh)
    c_sh = cache_shardings(api, cache_abstract, plan, mesh)
    tok_sh = NamedSharding(mesh, plan.spec(("batch", None), tokens_shape,
                                           mesh))
    return jax.jit(step, in_shardings=(p_sh, tok_sh, c_sh),
                   out_shardings=(None, c_sh),
                   donate_argnums=(2,))
