"""Int8 error-feedback gradient compression.

Purpose at cluster scale: the DP/pod gradient reduction is the dominant
cross-pod collective (params x 4 bytes per step over DCN).  Quantizing the
reduced tensor to int8 with an error-feedback residual cuts the wire format
4x with negligible convergence impact (1-bit Adam / PowerSGD lineage).

Placement in this framework (documented in DESIGN.md S8): XLA does not expose
a compressed all-reduce primitive, so compression is applied (a) at the
microbatch gradient-accumulation boundary — the accumulator is held in int8 +
f32 scale + residual, which is also a real memory win — and (b) modeled as a
4x reduction of the collective roofline term when enabled (launch/roofline).
On a real cluster the same quantizer wraps a shard_map psum over the 'pod'
axis.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


class CompressedGrads(NamedTuple):
    q: Params            # int8 payload
    scale: Params        # per-tensor f32 scale
    residual: Params     # error-feedback carry (f32)


def init_residual(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads: Params, residual: Params
             ) -> Tuple[CompressedGrads, Params]:
    """Quantize grads+residual to int8; return compressed + new residual."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return q, scale, g32 - deq

    out = jax.tree.map(one, grads, residual)
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    q, scale, new_res = pick(0), pick(1), pick(2)
    return CompressedGrads(q, scale, new_res), new_res


def decompress(c: CompressedGrads) -> Params:
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, c.q, c.scale)


def roundtrip(grads: Params, residual: Params) -> Tuple[Params, Params]:
    """compress -> decompress, carrying the error-feedback residual.  This is
    the exact arithmetic a compressed all-reduce applies to the summands."""
    c, new_res = compress(grads, residual)
    return decompress(c), new_res
