"""Train-step builder: microbatched grad accumulation + optimizer update,
jit-compiled with plan-derived shardings.

The returned step is the object the dry-run lowers: its in/out shardings come
from the ShardingPlan the TileLoom mesh planner selected
(``parallel/planner_bridge.py``), and all model-internal activations are
constrained through the same plan via the logical-axis context.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import TrainConfig
from repro.models.api import ModelAPI
from repro.parallel.sharding import ShardingPlan, use_plan
from . import grad_compress, optimizer as opt

Params = Any


class TrainState:
    """Lightweight pytree container (registered below)."""

    def __init__(self, params, opt_state, residual=None):
        self.params = params
        self.opt_state = opt_state
        self.residual = residual

    def tree_flatten(self):
        return (self.params, self.opt_state, self.residual), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten, TrainState.tree_unflatten)


def init_state(api: ModelAPI, tcfg: TrainConfig, rng: jax.Array) -> TrainState:
    params = api.init(rng)
    res = (grad_compress.init_residual(params)
           if tcfg.grad_compression == "int8" else None)
    return TrainState(params, opt.opt_init(params, tcfg), res)


def abstract_state(api: ModelAPI, tcfg: TrainConfig) -> TrainState:
    """ShapeDtypeStruct state for the dry-run (no allocation)."""
    return jax.eval_shape(
        functools.partial(init_state, api, tcfg), jax.random.PRNGKey(0))


def state_logical_axes(api: ModelAPI, tcfg: TrainConfig) -> TrainState:
    paxes = api.param_axes()
    res = (paxes if tcfg.grad_compression == "int8" else None)
    return TrainState(paxes, opt.opt_state_axes(paxes, tcfg), res)


def _split_microbatches(batch: Dict[str, jax.Array], n: int):
    def re(x):
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])
    return jax.tree.map(re, batch)


def make_train_step(api: ModelAPI, tcfg: TrainConfig,
                    plan: Optional[ShardingPlan] = None,
                    mesh: Optional[Mesh] = None) -> Callable:
    """Returns ``train_step(state, batch) -> (state, metrics)`` (un-jitted;
    ``jit_train_step`` adds shardings + donation)."""

    def loss_of(params, mb):
        loss, metrics = api.loss_fn(params, mb)
        return loss, metrics

    paxes = api.param_axes()

    def constrain_grads(grads):
        """Pin gradient/accumulator sharding to the params' plan sharding —
        GSPMD does not reliably propagate through the microbatch scan, and an
        unconstrained f32 accumulator replicates (e.g. 100 GB/device for
        llama3-405b; caught by the dry-run memory analysis)."""
        if plan is None or mesh is None:
            return grads
        from jax.sharding import NamedSharding

        def one(g, ax):
            if not isinstance(ax, tuple) or len(ax) != g.ndim:
                return g
            return jax.lax.with_sharding_constraint(
                g, NamedSharding(mesh, plan.spec(ax, tuple(g.shape), mesh)))
        return jax.tree.map(one, grads, paxes)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        def compute(params):
            if tcfg.microbatches <= 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_of, has_aux=True)(params, batch)
                return constrain_grads(grads), loss, metrics
            mbs = _split_microbatches(batch, tcfg.microbatches)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (loss, _), grads = jax.value_and_grad(
                    loss_of, has_aux=True)(params, mb)
                g_acc = constrain_grads(jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads))
                return (g_acc, l_acc + loss), None

            g0 = constrain_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (g_acc, l_acc), _ = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), mbs)
            n = float(tcfg.microbatches)
            grads = jax.tree.map(lambda g: g / n, g_acc)
            loss = l_acc / n
            return grads, loss, {"loss": loss}

        grads, loss, metrics = compute(state.params)
        residual = state.residual
        if tcfg.grad_compression == "int8" and residual is not None:
            grads, residual = grad_compress.roundtrip(grads, residual)
        new_params, new_opt, opt_metrics = opt.opt_update(
            grads, state.opt_state, state.params, tcfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return TrainState(new_params, new_opt, residual), metrics

    if plan is not None and mesh is not None:
        def planned_step(state, batch):
            with use_plan(plan, mesh):
                return train_step(state, batch)
        return planned_step
    return train_step


def state_shardings(api: ModelAPI, tcfg: TrainConfig, plan: ShardingPlan,
                    mesh: Mesh) -> TrainState:
    """NamedSharding tree for the TrainState under a plan."""
    axes = state_logical_axes(api, tcfg)
    shapes = abstract_state(api, tcfg)

    def one(ax, shaped):
        if shaped is None:
            return None
        if ax is None or not isinstance(ax, tuple):
            ax = ()
        spec = plan.spec(ax, tuple(shaped.shape), mesh) \
            if len(ax) == len(shaped.shape) else P()
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, axes, shapes,
                        is_leaf=lambda x: x is None or (
                            isinstance(x, tuple)
                            and all(a is None or isinstance(a, str)
                                    for a in x)))


def batch_shardings(batch_specs: Dict[str, Any], plan: ShardingPlan,
                    mesh: Mesh) -> Dict[str, Any]:
    def one(shaped):
        nd = len(shaped.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        axes = ("batch",) + ("seq",) * 0 + (None,) * (nd - 1)
        # tokens/labels: (B, S); frames/patches: (B, L, D)
        if nd >= 2:
            axes = ("batch", "seq") + (None,) * (nd - 2)
        return NamedSharding(mesh, plan.spec(axes, tuple(shaped.shape), mesh))
    return jax.tree.map(one, batch_specs)


def jit_train_step(api: ModelAPI, tcfg: TrainConfig, plan: ShardingPlan,
                   mesh: Mesh, batch_specs: Dict[str, Any]):
    """jit with explicit in/out shardings + donation (the dry-run target)."""
    step = make_train_step(api, tcfg, plan, mesh)
    st_sh = state_shardings(api, tcfg, plan, mesh)
    b_sh = batch_shardings(batch_specs, plan, mesh)
    return jax.jit(step, in_shardings=(st_sh, b_sh),
                   out_shardings=(st_sh, None),
                   donate_argnums=(0,))
