# Training substrate: optimizers, microbatched train step, serve step,
# gradient compression.
from . import grad_compress, optimizer, serve_step, train_step
